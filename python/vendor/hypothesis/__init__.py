"""Vendored fallback shim for the `hypothesis` property-testing library.

Offline/bare CI runners often have jax but cannot reach PyPI for
hypothesis, which used to skip the L1/L2 oracle suites entirely
(ROADMAP "hypothesis on CI"). This shim implements just enough of the
hypothesis API for ``python/tests/test_{kernel,model}.py`` to run:

* ``@given(**strategies)`` — draws ``max_examples`` keyword sets from a
  *deterministic* per-example PRNG (seeded from the test's qualified
  name and the example index via crc32, never the salted ``hash()``),
  so failures reproduce across processes and machines;
* ``@settings(max_examples=..., deadline=...)`` — composes with
  ``given`` in either decorator order; ``deadline`` is accepted and
  ignored;
* ``strategies`` (``st``) — ``integers``, ``sampled_from``, ``lists``,
  and ``data()`` with mid-test ``data.draw(...)``.

No shrinking, no database, no coverage-guided generation — a failing
example simply raises with its drawn arguments visible in the traceback
(pytest shows the parameter values). ``python/conftest.py`` puts this
package on ``sys.path`` only when the real hypothesis is missing, so a
proper install always wins.
"""

import functools
import inspect
import random
import zlib

from . import strategies
from .strategies import DataStrategy

__all__ = ["given", "settings", "strategies", "HealthCheck", "example"]

__version__ = "0.0-ecoserve-shim"


def _stable_seed(name, index):
    """Cross-process-stable example seed (``hash()`` is salted; crc32 is
    not)."""
    return zlib.crc32(f"{name}:{index}".encode("utf-8"))


class settings:
    """Decorator recording example-count knobs for ``given``."""

    DEFAULT_MAX_EXAMPLES = 20

    def __init__(self, max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._shim_settings = self
        return fn


class HealthCheck:
    """API-compatibility stub: real hypothesis exposes suppressible
    health checks; the shim has none."""

    all = classmethod(lambda cls: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"


def example(*_args, **_kwargs):
    """API-compatibility stub: explicit examples are not replayed."""

    def deco(fn):
        return fn

    return deco


def given(**strats):
    """Drive the wrapped test with deterministically drawn keyword sets.

    Only keyword-style strategies are supported — which is how every
    EcoServe test invokes hypothesis.
    """
    if not strats:
        raise TypeError("shim given() requires keyword strategies")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_shim_settings", None) or getattr(
                fn, "_shim_settings", None
            )
            n = cfg.max_examples if cfg else settings.DEFAULT_MAX_EXAMPLES
            for i in range(n):
                rng = random.Random(_stable_seed(fn.__qualname__, i))
                drawn = {}
                for name, strat in strats.items():
                    drawn[name] = strat.example(rng)
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as err:
                    shown = {
                        k: v for k, v in drawn.items()
                        if not isinstance(strats[k], DataStrategy)
                    }
                    raise AssertionError(
                        f"falsifying example #{i} for {fn.__qualname__}: "
                        f"{shown!r}"
                    ) from err

        wrapper.hypothesis_shim = True
        # functools.wraps exposes the wrapped test's parameters through
        # __wrapped__, which pytest would then demand as fixtures; pin an
        # explicit zero-argument signature (inspect stops unwrapping at
        # the first __signature__ it finds).
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
