"""Strategies for the vendored hypothesis fallback shim.

Only the strategy surface the EcoServe test suites use is implemented:
``integers``, ``sampled_from``, ``lists``, and ``data``. Each strategy is
a tiny object with an ``example(rng)`` method drawing one value from a
seeded ``random.Random`` — the shim's ``@given`` drives it with a
deterministic per-example PRNG (see ``hypothesis/__init__.py``).
"""


class SearchStrategy:
    """Base class: a drawable distribution over values."""

    def example(self, rng):
        raise NotImplementedError

    def __repr__(self):  # pragma: no cover - debugging aid
        return type(self).__name__


class _Integers(SearchStrategy):
    """Uniform integers on [min_value, max_value], with the bounds
    themselves over-weighted (edge cases find bugs first)."""

    def __init__(self, min_value, max_value):
        if min_value > max_value:
            raise ValueError(f"integers({min_value}, {max_value}): empty range")
        self.min_value = min_value
        self.max_value = max_value

    def example(self, rng):
        roll = rng.random()
        if roll < 0.1:
            return self.min_value
        if roll < 0.2:
            return self.max_value
        return rng.randint(self.min_value, self.max_value)


class _SampledFrom(SearchStrategy):
    def __init__(self, elements):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from() needs a non-empty collection")

    def example(self, rng):
        return rng.choice(self.elements)


class _Lists(SearchStrategy):
    def __init__(self, elements, min_size=0, max_size=None):
        if max_size is None:
            max_size = min_size + 10
        if min_size > max_size:
            raise ValueError(f"lists(min_size={min_size}, max_size={max_size})")
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size

    def example(self, rng):
        size = rng.randint(self.min_size, self.max_size)
        return [self.elements.example(rng) for _ in range(size)]


class DataStrategy(SearchStrategy):
    """Marker strategy: ``@given(data=st.data())`` receives a
    [`DataObject`] for interactive mid-test draws."""

    def example(self, rng):
        return DataObject(rng)


class DataObject:
    """Interactive draws sharing the example's PRNG stream."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.example(self._rng)

    def __repr__(self):  # pragma: no cover - debugging aid
        return "data(...)"


def integers(min_value, max_value):
    return _Integers(min_value, max_value)


def sampled_from(elements):
    return _SampledFrom(elements)


def lists(elements, min_size=0, max_size=None):
    return _Lists(elements, min_size=min_size, max_size=max_size)


def data():
    return DataStrategy()
