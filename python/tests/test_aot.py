"""AOT pipeline tests: lowering produces loadable HLO text and a consistent
manifest/weights bundle. (The cross-language execute check lives on the Rust
side in rust/tests/pjrt_roundtrip.rs.)"""

import json
import os

import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M

CFG = M.TinyLMConfig()


def test_lower_prefill_text_structure():
    text = aot.lower_prefill(CFG, 16)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # tokens + prompt_len + 31 weights = 33 ENTRY parameters (nested
    # computations also declare parameters, so count inside ENTRY only).
    nparams = len(M.param_spec(CFG)) + 2
    assert text[text.find("ENTRY"):].count("parameter(") == nparams


def test_lower_decode_text_structure():
    text = aot.lower_decode(CFG, 2)
    assert text.startswith("HloModule")
    nparams = len(M.param_spec(CFG)) + 4
    assert text[text.find("ENTRY"):].count("parameter(") == nparams


def test_weights_bin_matches_manifest(tmp_path):
    index = aot.write_weights(CFG, str(tmp_path), seed=0)
    raw = np.fromfile(tmp_path / "weights.bin", dtype="<f4")
    total = sum(e["numel"] for e in index)
    assert raw.size == total
    # offsets are contiguous and ordered per param_spec
    off = 0
    for e, (name, shape) in zip(index, M.param_spec(CFG)):
        assert e["name"] == name
        assert e["offset"] == off
        assert e["numel"] == int(np.prod(shape)) if shape else 1
        off += e["numel"]
    # spot-check: first array is the embedding, equal to init_weights output
    w = M.init_weights(CFG, 0)
    emb = raw[: CFG.vocab * CFG.hidden].reshape(CFG.vocab, CFG.hidden)
    np.testing.assert_array_equal(emb, np.asarray(w[0]))


def test_weights_deterministic_across_seeds(tmp_path):
    a = aot.write_weights(CFG, str(tmp_path), seed=0)
    r1 = np.fromfile(tmp_path / "weights.bin", dtype="<f4").copy()
    aot.write_weights(CFG, str(tmp_path), seed=0)
    r2 = np.fromfile(tmp_path / "weights.bin", dtype="<f4")
    np.testing.assert_array_equal(r1, r2)
    aot.write_weights(CFG, str(tmp_path), seed=1)
    r3 = np.fromfile(tmp_path / "weights.bin", dtype="<f4")
    assert not np.array_equal(r1, r3)


def test_repo_artifacts_manifest_consistent():
    """If `make artifacts` has run, the checked manifest must match TinyLMConfig."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        "manifest.json")
    if not os.path.exists(path):
        return  # artifacts not built yet; Makefile ordering covers this
    with open(path) as f:
        man = json.load(f)
    c = man["config"]
    assert c["vocab"] == CFG.vocab
    assert c["layers"] == CFG.layers
    assert c["hidden"] == CFG.hidden
    assert c["kv_heads"] == CFG.kv_heads
    assert c["max_seq"] == CFG.max_seq
    assert [w["name"] for w in man["weights"]] == [n for n, _ in M.param_spec(CFG)]
