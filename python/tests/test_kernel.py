"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, dtypes, block sizes, and cache lengths; every case
asserts allclose against the reference. This is the CORE correctness signal
for the compute hot-spot — everything the Rust runtime executes flows
through these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import (
    attention_decode,
    flash_attention_prefill,
    mxu_utilization_estimate,
    vmem_bytes_prefill,
)

jax.config.update("jax_enable_x64", False)

DIMS = st.sampled_from([8, 16, 32])
SEQS = st.sampled_from([16, 32, 64, 128])


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s=SEQS,
    d=DIMS,
    block_q=st.sampled_from([16, 32, 64]),
    block_k=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_matches_ref(b, h, s, d, block_q, block_k, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, s, d), jnp.float32)
    k = rand(kk, (b, h, s, d), jnp.float32)
    v = rand(kv, (b, h, s, d), jnp.float32)
    out = flash_attention_prefill(q, k, v, block_q=block_q, block_k=block_k)
    exp = ref.attention_prefill_ref(q, k, v)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s=SEQS,
    d=DIMS,
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_non_causal(b, h, s, d, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, s, d), jnp.float32)
    k = rand(kk, (b, h, s, d), jnp.float32)
    v = rand(kv, (b, h, s, d), jnp.float32)
    out = flash_attention_prefill(q, k, v, causal=False)
    exp = ref.attention_prefill_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.integers(1, 4),
    smax=SEQS,
    d=DIMS,
    block_k=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_decode_matches_ref(b, h, smax, d, block_k, seed, data):
    lengths = jnp.asarray(
        data.draw(st.lists(st.integers(1, smax), min_size=b, max_size=b)),
        jnp.int32,
    )
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, d), jnp.float32)
    k = rand(kk, (b, h, smax, d), jnp.float32)
    v = rand(kv, (b, h, smax, d), jnp.float32)
    out = attention_decode(q, k, v, lengths, block_k=block_k)
    exp = ref.attention_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(out, exp, atol=2e-5, rtol=2e-5)


def test_decode_padding_is_ignored():
    """Garbage beyond `lengths` must not leak into the output — the property
    that makes shape-bucketed AOT executables safe."""
    b, h, smax, d = 2, 2, 64, 16
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, d), jnp.float32)
    k = rand(kk, (b, h, smax, d), jnp.float32)
    v = rand(kv, (b, h, smax, d), jnp.float32)
    lengths = jnp.array([10, 33], jnp.int32)
    out1 = attention_decode(q, k, v, lengths)
    # Poison the padded region with huge values.
    mask = jnp.arange(smax)[None, None, :, None] >= lengths[:, None, None, None]
    k2 = jnp.where(mask, 1e9, k)
    v2 = jnp.where(mask, -1e9, v)
    out2 = attention_decode(q, k2, v2, lengths)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


def test_prefill_causality():
    """Perturbing future tokens must not change earlier outputs."""
    b, h, s, d = 1, 2, 32, 16
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, h, s, d), jnp.float32)
    k = rand(kk, (b, h, s, d), jnp.float32)
    v = rand(kv, (b, h, s, d), jnp.float32)
    out1 = flash_attention_prefill(q, k, v)
    k2 = k.at[:, :, 20:, :].set(99.0)
    v2 = v.at[:, :, 20:, :].set(-99.0)
    out2 = flash_attention_prefill(q, k2, v2)
    np.testing.assert_allclose(out1[:, :, :20], out2[:, :, :20], atol=1e-5)


def test_bad_block_size_raises():
    q = jnp.zeros((1, 1, 48, 16))
    with pytest.raises(ValueError):
        flash_attention_prefill(q, q, q, block_q=32, block_k=32)


def test_decode_block_size_validation():
    q = jnp.zeros((1, 1, 16))
    k = jnp.zeros((1, 1, 48, 16))
    with pytest.raises(ValueError):
        attention_decode(q, k, k, jnp.array([1], jnp.int32), block_k=32)


class TestPerfEstimators:
    """Structural §Perf metrics (interpret=True wallclock is not a TPU proxy)."""

    def test_vmem_grows_with_blocks(self):
        small = vmem_bytes_prefill(16, 16, 32, 128)
        big = vmem_bytes_prefill(64, 64, 32, 128)
        assert big > small

    def test_vmem_fits_tpu_budget(self):
        # Default live-path tiles must fit a 16 MiB VMEM comfortably.
        assert vmem_bytes_prefill(32, 32, 32, 128) < 16 * 2**20 // 8

    def test_mxu_estimate_monotone(self):
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert (
            mxu_utilization_estimate(32, 32, 32)
            < mxu_utilization_estimate(64, 64, 64)
            <= 1.0
        )
