"""L2 correctness: TinyLM prefill/decode graphs vs the dense oracle.

Checks the exact properties the Rust serving engine depends on:
  * prefill over a padded bucket == dense forward over the unpadded prompt;
  * autoregressive prefill+decode chain == dense forward over the full
    sequence (the KV cache handoff is correct);
  * bucket choice does not change results (padding invariance);
  * decode batches mixing requests at different depths are independent.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M

CFG = M.TinyLMConfig()
W = M.init_weights(CFG)


def pad_prompt(prompt, bucket):
    out = jnp.zeros((1, bucket), jnp.int32)
    return out.at[0, : prompt.shape[0]].set(prompt)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 16),
    bucket=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_matches_dense(n, bucket, seed):
    if n > bucket:
        n = bucket
    rng = np.random.default_rng(seed)
    prompt = jnp.asarray(rng.integers(1, CFG.vocab, n), jnp.int32)
    logits, _, _ = M.prefill(CFG, pad_prompt(prompt, bucket),
                             jnp.asarray(n, jnp.int32), W)
    dense = M.full_forward_ref(CFG, prompt[None, :])
    np.testing.assert_allclose(logits[0], dense[0, -1], atol=5e-4, rtol=5e-4)


@settings(max_examples=6, deadline=None)
@given(
    n=st.integers(2, 12),
    steps=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_prefill_then_decode_chain(n, steps, seed):
    """Greedy generation through prefill+decode == dense forward each step."""
    rng = np.random.default_rng(seed)
    prompt = jnp.asarray(rng.integers(1, CFG.vocab, n), jnp.int32)
    bucket = 16
    logits, kc, vc = M.prefill(CFG, pad_prompt(prompt, bucket),
                               jnp.asarray(n, jnp.int32), W)
    smax = CFG.max_seq
    kc_full = jnp.zeros((CFG.layers, 1, CFG.kv_heads, smax, CFG.head_dim))
    vc_full = jnp.zeros_like(kc_full)
    kc_full = kc_full.at[:, :, :, :bucket].set(kc)
    vc_full = vc_full.at[:, :, :, :bucket].set(vc)

    seq = list(np.asarray(prompt))
    pos = n
    tok = int(jnp.argmax(logits[0]))
    for _ in range(steps):
        seq.append(tok)
        dl, nk, nv = M.decode(CFG, jnp.asarray([tok], jnp.int32),
                              jnp.asarray([pos], jnp.int32), kc_full, vc_full, W)
        dense = M.full_forward_ref(CFG, jnp.asarray(seq, jnp.int32)[None, :])
        np.testing.assert_allclose(dl[0], dense[0, -1], atol=5e-4, rtol=5e-4)
        # Write back the new KV rows exactly as the Rust KV manager does.
        kc_full = kc_full.at[:, 0, :, pos, :].set(nk[:, 0])
        vc_full = vc_full.at[:, 0, :, pos, :].set(nv[:, 0])
        pos += 1
        tok = int(jnp.argmax(dl[0]))


def test_bucket_padding_invariance():
    """The same prompt through different buckets produces identical logits."""
    prompt = jnp.asarray([3, 1, 4, 1, 5, 9, 2, 6], jnp.int32)
    outs = []
    for bucket in (16, 32, 64):
        logits, _, _ = M.prefill(CFG, pad_prompt(prompt, bucket),
                                 jnp.asarray(8, jnp.int32), W)
        outs.append(np.asarray(logits[0]))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4, rtol=1e-4)


def test_decode_batch_independence():
    """Request i's logits in a batch must not depend on request j."""
    smax = CFG.max_seq
    rng = np.random.default_rng(0)
    # Two requests at different depths with random (but valid) caches.
    kc = jnp.asarray(rng.normal(size=(CFG.layers, 2, CFG.kv_heads, smax,
                                      CFG.head_dim)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=kc.shape), jnp.float32)
    toks = jnp.asarray([7, 11], jnp.int32)
    poss = jnp.asarray([3, 60], jnp.int32)
    batched, _, _ = M.decode(CFG, toks, poss, kc, vc, W)
    solo0, _, _ = M.decode(CFG, toks[:1], poss[:1], kc[:, :1], vc[:, :1], W)
    solo1, _, _ = M.decode(CFG, toks[1:], poss[1:], kc[:, 1:], vc[:, 1:], W)
    np.testing.assert_allclose(batched[0], solo0[0], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(batched[1], solo1[0], atol=1e-4, rtol=1e-4)


def test_param_spec_roundtrip():
    spec = M.param_spec(CFG)
    assert len(spec) == len(W) == 1 + 7 * CFG.layers + 2
    for (name, shape), w in zip(spec, W):
        assert tuple(w.shape) == shape, name


def test_kv_bytes_per_token():
    # 2 (K+V) * L * Hkv * D * 4 bytes
    assert CFG.kv_bytes_per_token() == 2 * 4 * 2 * 32 * 4
