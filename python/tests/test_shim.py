"""The vendored hypothesis shim's own contract (no jax needed).

Loaded under an alias straight from python/vendor so these checks run —
and keep the shim honest — even when a real hypothesis install shadows
it on sys.path.
"""

import importlib.util
import pathlib
import sys

import pytest

_VENDOR = pathlib.Path(__file__).resolve().parents[1] / "vendor"
_ALIAS = "ecoserve_hypothesis_shim"


def _load_shim():
    if _ALIAS in sys.modules:
        return sys.modules[_ALIAS]
    spec = importlib.util.spec_from_file_location(
        _ALIAS,
        _VENDOR / "hypothesis" / "__init__.py",
        submodule_search_locations=[str(_VENDOR / "hypothesis")],
    )
    mod = importlib.util.module_from_spec(spec)
    sys.modules[_ALIAS] = mod
    spec.loader.exec_module(mod)
    return mod


shim = _load_shim()
st = shim.strategies


def test_given_runs_max_examples_times():
    calls = []

    @shim.settings(max_examples=7, deadline=None)
    @shim.given(n=st.integers(1, 16), d=st.sampled_from([8, 16, 32]))
    def probe(n, d):
        assert 1 <= n <= 16
        assert d in (8, 16, 32)
        calls.append((n, d))

    probe()
    assert len(calls) == 7


def test_settings_composes_in_either_decorator_order():
    calls = []

    @shim.given(n=st.integers(0, 5))
    def inner_given_first(n):
        calls.append(n)

    shim.settings(max_examples=3)(inner_given_first)()
    assert len(calls) == 3


def test_examples_are_deterministic_across_runs():
    def record():
        out = []

        @shim.settings(max_examples=10)
        @shim.given(n=st.integers(0, 2**31 - 1), xs=st.lists(st.integers(0, 9), min_size=2, max_size=4))
        def probe(n, xs):
            out.append((n, tuple(xs)))

        probe()
        return out

    assert record() == record()


def test_lists_respects_size_bounds():
    sizes = set()

    @shim.settings(max_examples=40)
    @shim.given(xs=st.lists(st.integers(1, 3), min_size=2, max_size=5))
    def probe(xs):
        sizes.add(len(xs))
        assert all(1 <= x <= 3 for x in xs)

    probe()
    assert sizes <= {2, 3, 4, 5}
    assert len(sizes) > 1, "size should vary across examples"


def test_data_draw_shares_the_example_stream():
    drawn = []

    @shim.settings(max_examples=5)
    @shim.given(b=st.integers(1, 4), data=st.data())
    def probe(b, data):
        xs = data.draw(st.lists(st.integers(1, 10), min_size=b, max_size=b))
        assert len(xs) == b
        drawn.append(tuple(xs))

    probe()
    assert len(drawn) == 5


def test_failing_example_surfaces_drawn_arguments():
    @shim.given(n=st.integers(1, 1))
    def probe(n):
        raise ValueError("boom")

    with pytest.raises(AssertionError, match=r"falsifying example #0.*'n': 1"):
        probe()


def test_integer_bounds_are_overweighted():
    seen = []

    @shim.settings(max_examples=60)
    @shim.given(n=st.integers(0, 1000))
    def probe(n):
        seen.append(n)

    probe()
    assert 0 in seen and 1000 in seen, "edges should appear quickly"


def test_degenerate_strategy_inputs_raise():
    with pytest.raises(ValueError):
        st.integers(5, 1)
    with pytest.raises(ValueError):
        st.sampled_from([])
    with pytest.raises(ValueError):
        st.lists(st.integers(0, 1), min_size=4, max_size=2)
