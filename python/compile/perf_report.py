"""§Perf L1/L2 structural report.

L1 (Pallas kernel): interpret=True wallclock is CPU-numpy, not a TPU proxy,
so kernel optimization is *structural*: sweep block shapes and report the
VMEM working set and MXU-utilization estimate per configuration; pick the
block sizes that maximize MXU occupancy within the VMEM budget.

L2 (JAX graph): inspect the lowered HLO for redundant work — parameter
counts, fusion counts, and the number of dot/while ops per executable
(layers x expected-dots means no recompute slipped in).

Usage:  cd python && python -m compile.perf_report
"""

from __future__ import annotations

import os

from .kernels.attention import mxu_utilization_estimate, vmem_bytes_prefill
from .model import TinyLMConfig


def l1_block_sweep() -> None:
    cfg = TinyLMConfig()
    d = cfg.head_dim
    s = cfg.max_seq
    print("== L1: Pallas flash-attention block sweep (structural) ==")
    print(f"model: head_dim={d}, max_seq={s}; VMEM budget 16 MiB/core")
    print(f"{'block_q':>8} {'block_k':>8} {'VMEM KiB':>10} {'MXU util':>9} {'fits':>5}")
    best = None
    for bq in (16, 32, 64, 128):
        for bk in (16, 32, 64, 128):
            if s % bq or s % bk:
                continue
            vmem = vmem_bytes_prefill(bq, bk, d, s)
            mxu = mxu_utilization_estimate(bq, bk, d)
            fits = vmem < 16 * 2**20
            print(f"{bq:>8} {bk:>8} {vmem / 1024:>10.1f} {mxu:>9.3f} {str(fits):>5}")
            if fits and (best is None or mxu > best[2]):
                best = (bq, bk, mxu)
    print(f"-> chosen blocks: q={best[0]}, k={best[1]} (MXU estimate {best[2]:.3f};"
          f" bounded by head_dim {d} < 128 lanes on TinyLM — a production-scale"
          f" head_dim of 128 reaches 1.0)")


def l2_hlo_audit(artifacts: str = "../artifacts") -> None:
    print("\n== L2: lowered-HLO audit (no redundant recompute) ==")
    cfg = TinyLMConfig()
    for name, dots_expected in [
        ("tiny_prefill_s64", None),
        ("tiny_decode_b8", None),
    ]:
        path = os.path.join(artifacts, f"{name}.hlo.txt")
        if not os.path.exists(path):
            print(f"{name}: artifacts not built")
            continue
        text = open(path).read()
        entry = text[text.find("ENTRY"):]
        fusions = text.count(" fusion(")
        dots = text.count(" dot(")
        whiles = text.count(" while(")
        customs = text.count("custom-call")
        print(f"{name}: {len(text)} chars, {dots} dot, {fusions} fusion, "
              f"{whiles} while, {customs} custom-call, "
              f"{entry.count('parameter(')} entry params")
        # Sanity: per layer we expect ~5 projection/FFN dots + attention
        # matmuls inside the pallas while-loops; dot count must be O(layers),
        # not O(layers^2) (which would indicate recompute).
        assert dots < cfg.layers * 16, f"suspicious dot count {dots}"
        assert customs == 0, "CPU path must not contain Mosaic custom-calls"
    print("-> no Mosaic custom-calls (interpret path), dot count linear in layers")


if __name__ == "__main__":
    l1_block_sweep()
    l2_hlo_audit()
