"""AOT pipeline: lower TinyLM prefill/decode graphs to HLO **text** artifacts.

Python runs exactly once (``make artifacts``); the Rust coordinator loads the
resulting ``artifacts/*.hlo.txt`` through the PJRT C API and never touches
Python again.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (all under --outdir, default ../artifacts):
  tiny_prefill_s{S}.hlo.txt   for S in PREFILL_BUCKETS
  tiny_decode_b{B}.hlo.txt    for B in DECODE_BUCKETS
  weights.bin                 f32 little-endian, param_spec order
  manifest.json               config + buckets + weight index (shapes/offsets)
  model.hlo.txt               stamp = copy of the largest prefill artifact
                              (keeps the Makefile freshness check single-file)
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

PREFILL_BUCKETS = (16, 32, 64, 128)
DECODE_BUCKETS = (1, 2, 4, 8, 16, 32)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_prefill(cfg: M.TinyLMConfig, s: int) -> str:
    """Lower prefill for bucket length `s`. Signature (positional order the
    Rust engine must follow): tokens i32[1,s], prompt_len i32[], weights..."""

    def fn(tokens, prompt_len, *weights):
        return M.prefill(cfg, tokens, prompt_len, list(weights))

    args = [
        jax.ShapeDtypeStruct((1, s), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
    ] + [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in M.param_spec(cfg)]
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_decode(cfg: M.TinyLMConfig, b: int) -> str:
    """Lower one decode step for batch bucket `b`. Signature: tokens i32[b],
    positions i32[b], k_cache f32[L,b,Hkv,Smax,D], v_cache ditto, weights..."""

    def fn(tokens, positions, k_cache, v_cache, *weights):
        return M.decode(cfg, tokens, positions, k_cache, v_cache, list(weights))

    kv_shape = (cfg.layers, b, cfg.kv_heads, cfg.max_seq, cfg.head_dim)
    args = [
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct(kv_shape, jnp.float32),
        jax.ShapeDtypeStruct(kv_shape, jnp.float32),
    ] + [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in M.param_spec(cfg)]
    return to_hlo_text(jax.jit(fn).lower(*args))


def write_weights(cfg: M.TinyLMConfig, outdir: str, seed: int) -> list:
    """Write weights.bin (flat f32 LE) and return the manifest index."""
    weights = M.init_weights(cfg, seed)
    index = []
    offset = 0
    path = os.path.join(outdir, "weights.bin")
    with open(path, "wb") as f:
        for (name, shape), w in zip(M.param_spec(cfg), weights):
            arr = np.asarray(w, dtype="<f4")
            f.write(arr.tobytes())
            index.append({
                "name": name,
                "shape": list(shape),
                "offset": offset,
                "numel": int(arr.size),
            })
            offset += int(arr.size)
    return index


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy single-file stamp path (Makefile compat)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--prefill-buckets", default=",".join(map(str, PREFILL_BUCKETS)))
    ap.add_argument("--decode-buckets", default=",".join(map(str, DECODE_BUCKETS)))
    args = ap.parse_args()

    outdir = args.outdir
    if args.out is not None:
        outdir = os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)

    cfg = M.TinyLMConfig()
    prefill_buckets = [int(x) for x in args.prefill_buckets.split(",") if x]
    decode_buckets = [int(x) for x in args.decode_buckets.split(",") if x]

    for s in prefill_buckets:
        assert s <= cfg.max_seq, f"bucket {s} exceeds max_seq {cfg.max_seq}"
        text = lower_prefill(cfg, s)
        path = os.path.join(outdir, f"tiny_prefill_s{s}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    for b in decode_buckets:
        text = lower_decode(cfg, b)
        path = os.path.join(outdir, f"tiny_decode_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    index = write_weights(cfg, outdir, args.seed)
    manifest = {
        "model": "tinylm",
        "seed": args.seed,
        "config": {
            "vocab": cfg.vocab,
            "layers": cfg.layers,
            "hidden": cfg.hidden,
            "heads": cfg.heads,
            "kv_heads": cfg.kv_heads,
            "ffn": cfg.ffn,
            "max_seq": cfg.max_seq,
            "head_dim": cfg.head_dim,
        },
        "prefill_buckets": prefill_buckets,
        "decode_buckets": decode_buckets,
        "weights": index,
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    # Makefile stamp: copy the largest prefill artifact to model.hlo.txt.
    stamp_src = os.path.join(outdir, f"tiny_prefill_s{max(prefill_buckets)}.hlo.txt")
    stamp_dst = os.path.join(outdir, "model.hlo.txt")
    with open(stamp_src) as src, open(stamp_dst, "w") as dst:
        dst.write(src.read())
    print(f"wrote {stamp_dst} (stamp), manifest.json, weights.bin")


if __name__ == "__main__":
    main()
