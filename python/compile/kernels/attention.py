"""Pallas attention kernels — the L1 compute hot-spot of EcoServe's instances.

Two kernels, matching the two phases the paper disaggregates in time:

  * ``flash_attention_prefill`` — causal flash attention for the prefill
    phase (compute-bound, AI ~ S per Table 2 of the paper).
  * ``attention_decode`` — single-token decode attention over a padded KV
    cache (memory-bound, AI ~ 1 per Table 2).

Hardware adaptation (paper targets CUDA; we target TPU-style Pallas, see
DESIGN.md §3): the CUDA threadblock/shared-memory schedule becomes a
BlockSpec-expressed HBM→VMEM schedule. Q is tiled into ``(block_q, D)``
VMEM-resident tiles via the grid; K/V stream through VMEM in ``(block_k, D)``
tiles inside an online-softmax ``fori_loop``. On a real TPU the ``q @ k.T``
tiles feed the MXU; here the kernels run with ``interpret=True`` (the CPU
PJRT plugin cannot execute Mosaic custom-calls) and correctness is asserted
against ``ref.py``.

VMEM footprint per grid step (f32 bytes):
    prefill: (block_q*D) * 2[acc] + 2*(block_k*D) + O(block_q*block_k)
    decode:  D * 3 + 2*(block_k*D) + O(block_k)
These numbers drive the §Perf block-size selection (see perfmodel notes in
DESIGN.md §9 and EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool):
    """Online-softmax flash attention over one (block_q, D) query tile.

    Grid is (B, H, S // block_q); the BlockSpec hands us the full K/V rows
    for this (batch, head) and one query tile. K/V are walked in block_k
    tiles with the numerically-stable streaming softmax recurrence
    (m = running max, l = running denominator, acc = running numerator).
    """
    q = q_ref[0, 0]  # (block_q, d)
    block_q, d = q.shape
    s = k_ref.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    q_idx = pl.program_id(2) * block_q + jax.lax.iota(jnp.int32, block_q)

    num_kb = s // block_k

    def body(kb, carry):
        m_prev, l_prev, acc_prev = carry
        k_tile = k_ref[0, 0, pl.ds(kb * block_k, block_k), :]  # (block_k, d)
        v_tile = v_ref[0, 0, pl.ds(kb * block_k, block_k), :]
        scores = (q @ k_tile.T) * scale  # (block_q, block_k)
        if causal:
            k_idx = kb * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = k_idx[None, :] <= q_idx[:, None]
            scores = jnp.where(mask, scores, NEG_INF)
        m_cur = jnp.maximum(m_prev, scores.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(scores - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=-1)
        acc_cur = acc_prev * alpha[:, None] + p @ v_tile
        return m_cur, l_cur, acc_cur

    m0 = jnp.full((block_q,), NEG_INF, dtype=q.dtype)
    l0 = jnp.zeros((block_q,), dtype=q.dtype)
    acc0 = jnp.zeros((block_q, d), dtype=q.dtype)
    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    o_ref[0, 0] = acc / l[:, None]


def flash_attention_prefill(q, k, v, *, block_q: int = 32, block_k: int = 32,
                            causal: bool = True, interpret: bool = True):
    """Causal flash attention for the prefill phase.

    Args:
      q, k, v: f32[B, H, S, D]; S must be divisible by block_q and block_k
        (the serving engine pads prompts to shape buckets, see runtime/engine).
      block_q, block_k: VMEM tile sizes (multiples of the MXU lane count on
        real hardware; defaults suit the TinyLM live-path buckets).

    Returns:
      f32[B, H, S, D].
    """
    b, h, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k:
        raise ValueError(f"S={s} must divide block_q={block_q}, block_k={block_k}")
    grid = (b, h, s // block_q)
    kernel = functools.partial(_prefill_kernel, block_k=block_k, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bi, hi, qi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, block_k: int):
    """Decode attention for one (batch, head): q is a single token's query.

    Walks the padded KV cache in block_k tiles, masking positions beyond
    this request's valid length (lengths vary per request inside a
    continuous batch — the padding mask is what makes shape-bucketed AOT
    executables correct).
    """
    q = q_ref[0, 0]  # (d,)
    d = q.shape[0]
    smax = k_ref.shape[2]
    length = len_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))

    num_kb = smax // block_k

    def body(kb, carry):
        m_prev, l_prev, acc_prev = carry
        k_tile = k_ref[0, 0, pl.ds(kb * block_k, block_k), :]  # (block_k, d)
        v_tile = v_ref[0, 0, pl.ds(kb * block_k, block_k), :]
        scores = (k_tile @ q) * scale  # (block_k,)
        k_idx = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        scores = jnp.where(k_idx < length, scores, NEG_INF)
        m_cur = jnp.maximum(m_prev, scores.max())
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(scores - m_cur)
        l_cur = l_prev * alpha + p.sum()
        acc_cur = acc_prev * alpha + p @ v_tile
        return m_cur, l_cur, acc_cur

    m0 = jnp.asarray(NEG_INF, dtype=q.dtype)
    l0 = jnp.asarray(0.0, dtype=q.dtype)
    acc0 = jnp.zeros((d,), dtype=q.dtype)
    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m0, l0, acc0))
    o_ref[0, 0] = acc / l


def attention_decode(q, k, v, lengths, *, block_k: int = 32, interpret: bool = True):
    """Single-token decode attention over a padded KV cache.

    Args:
      q: f32[B, H, D].
      k, v: f32[B, H, Smax, D] padded KV cache; Smax divisible by block_k.
      lengths: i32[B] valid positions per request (entries must be >= 1 —
        the engine always writes the current token's KV before attending).

    Returns:
      f32[B, H, D].
    """
    b, h, smax, d = k.shape
    block_k = min(block_k, smax)
    if smax % block_k:
        raise ValueError(f"Smax={smax} must divide block_k={block_k}")
    grid = (b, h)
    kernel = functools.partial(_decode_kernel, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi: (bi,)),
            pl.BlockSpec((1, 1, d), lambda bi, hi: (bi, hi, 0)),
            pl.BlockSpec((1, 1, smax, d), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, smax, d), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bi, hi: (bi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)


def vmem_bytes_prefill(block_q: int, block_k: int, d: int, s: int,
                       bytes_per_el: int = 4) -> int:
    """Estimated VMEM working set of one prefill grid step (see module doc)."""
    q_tile = block_q * d
    kv_tiles = 2 * block_k * d
    scores = block_q * block_k
    acc = block_q * d + 2 * block_q
    out = block_q * d
    return (q_tile + kv_tiles + scores + acc + out) * bytes_per_el


def mxu_utilization_estimate(block_q: int, block_k: int, d: int,
                             mxu: int = 128) -> float:
    """Fraction of MXU lanes a (block_q x d) @ (d x block_k) tile keeps busy.

    The systolic array processes min(dim, mxu)/mxu per axis; this is the
    product over the three matmul dims — the §Perf structural metric used in
    lieu of wallclock (interpret=True timings are CPU-numpy, not TPU).
    """
    eff = 1.0
    for dim in (block_q, d, block_k):
        eff *= min(dim, mxu) / mxu
    return eff
