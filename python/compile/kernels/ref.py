"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with nothing but `jax.numpy` so it is trivially auditable. The pytest
suite (python/tests/) asserts allclose between kernel and oracle across a
hypothesis-driven sweep of shapes and dtypes.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30  # large-but-finite mask value; avoids NaN from inf - inf


def attention_prefill_ref(q, k, v, *, causal: bool = True):
    """Reference multi-head attention for the prefill phase.

    Args:
      q, k, v: f32[B, H, S, D] (KV already expanded to H heads for GQA).
      causal: apply a lower-triangular mask.

    Returns:
      f32[B, H, S, D] attention output.
    """
    b, h, s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(s)[None, :]
        scores = jnp.where(ki <= qi, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def attention_decode_ref(q, k, v, lengths):
    """Reference single-token decode attention over a padded KV cache.

    Args:
      q: f32[B, H, D] — the new token's query.
      k, v: f32[B, H, Smax, D] — padded KV cache (positions >= lengths[b] are
        garbage and must not influence the output).
      lengths: i32[B] — number of valid cache positions per request.

    Returns:
      f32[B, H, D].
    """
    b, h, smax, d = k.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("bhd,bhkd->bhk", q, k) * scale
    mask = jnp.arange(smax)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhk,bhkd->bhd", probs, v)


def rmsnorm_ref(x, w, eps: float = 1e-6):
    """Reference RMSNorm over the last axis."""
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * w


def swiglu_ref(x, w_gate, w_up, w_down):
    """Reference SwiGLU feed-forward block: silu(x@Wg) * (x@Wu) @ Wd."""
    g = x @ w_gate
    u = x @ w_up
    return (g * jnp.reciprocal(1.0 + jnp.exp(-g)) * u) @ w_down
