"""L2: TinyLM — the JAX transformer the live serving path executes.

A small GQA transformer (RoPE + RMSNorm + SwiGLU, Llama-family architecture
scaled down per DESIGN.md §7) whose attention hot-spots are the Pallas
kernels in ``kernels/attention.py``. Two graphs are exported:

  * ``prefill(tokens, prompt_len, *weights)`` — full-prompt forward; returns
    the last *valid* position's logits plus the per-layer KV cache.
  * ``decode(tokens, positions, k_cache, v_cache, *weights)`` — one decode
    step for a continuous batch; positions vary per request (shape-bucketed
    batches mix requests at different depths). Returns logits and the new
    K/V rows only (the Rust KV manager owns the cache; shipping just the
    delta keeps the PJRT output copy at O(B·L·Hkv·D), not O(B·L·Hkv·Smax·D)).

Weights travel as an explicit flat list (``param_spec`` fixes the order);
``aot.py`` writes the same order into ``artifacts/weights.bin`` so the Rust
runtime can feed the executables positionally. Python never runs at serving
time — these functions exist only to be lowered to HLO text.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import attention_decode, flash_attention_prefill


@dataclasses.dataclass(frozen=True)
class TinyLMConfig:
    """Architecture hyper-parameters (names follow the paper's Table 1)."""

    vocab: int = 512          # byte-ish vocab; matches runtime/tokenizer.rs
    layers: int = 4           # L
    hidden: int = 256         # H
    heads: int = 8            # M
    kv_heads: int = 2         # GQA groups (CodeLlama/Qwen2-style)
    ffn: int = 1024           # SwiGLU inner dim
    max_seq: int = 128        # KV cache capacity per request
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    @property
    def qkv_out(self) -> int:
        return self.hidden + 2 * self.kv_heads * self.head_dim

    def kv_bytes_per_token(self, bytes_per_el: int = 4) -> int:
        """KV-cache footprint of one token (the paper's 2*L*Hkv*D*bytes)."""
        return 2 * self.layers * self.kv_heads * self.head_dim * bytes_per_el


def param_spec(cfg: TinyLMConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """The canonical (name, shape) list — single source of truth for the
    weight ordering shared by aot.py and the Rust runtime."""
    spec: List[Tuple[str, Tuple[int, ...]]] = [("embed", (cfg.vocab, cfg.hidden))]
    for i in range(cfg.layers):
        spec += [
            (f"l{i}.ln1", (cfg.hidden,)),
            (f"l{i}.wqkv", (cfg.hidden, cfg.qkv_out)),
            (f"l{i}.wo", (cfg.hidden, cfg.hidden)),
            (f"l{i}.ln2", (cfg.hidden,)),
            (f"l{i}.w_gate", (cfg.hidden, cfg.ffn)),
            (f"l{i}.w_up", (cfg.hidden, cfg.ffn)),
            (f"l{i}.w_down", (cfg.ffn, cfg.hidden)),
        ]
    spec += [("ln_f", (cfg.hidden,)), ("unembed", (cfg.hidden, cfg.vocab))]
    return spec


def init_weights(cfg: TinyLMConfig, seed: int = 0) -> List[jax.Array]:
    """Deterministic scaled-normal init, in param_spec order."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            out.append(jnp.ones(shape, jnp.float32))
        else:
            scale = 1.0 / jnp.sqrt(jnp.asarray(shape[0], jnp.float32))
            out.append(jax.random.normal(sub, shape, jnp.float32) * scale)
    return out


def _unflatten(cfg: TinyLMConfig, weights) -> dict:
    names = [n for n, _ in param_spec(cfg)]
    if len(weights) != len(names):
        raise ValueError(f"expected {len(names)} weights, got {len(weights)}")
    return dict(zip(names, weights))


def _rmsnorm(x, w, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope(x, positions, theta):
    """Rotary embedding. x: [..., T, n_heads, D]; positions: [..., T]."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _expand_kv(x, groups: int):
    """GQA: repeat KV heads to match query heads. x: [B, Hkv, ..., D]."""
    return jnp.repeat(x, groups, axis=1)


def _qkv(cfg: TinyLMConfig, x, wqkv):
    """Project and split into per-head q, k, v. x: [B, T, H]."""
    b, t, _ = x.shape
    qkv = x @ wqkv
    q = qkv[..., : cfg.hidden]
    k = qkv[..., cfg.hidden : cfg.hidden + cfg.kv_heads * cfg.head_dim]
    v = qkv[..., cfg.hidden + cfg.kv_heads * cfg.head_dim :]
    q = q.reshape(b, t, cfg.heads, cfg.head_dim)
    k = k.reshape(b, t, cfg.kv_heads, cfg.head_dim)
    v = v.reshape(b, t, cfg.kv_heads, cfg.head_dim)
    return q, k, v


def _swiglu(x, w_gate, w_up, w_down):
    g = x @ w_gate
    return (jax.nn.silu(g) * (x @ w_up)) @ w_down


def prefill(cfg: TinyLMConfig, tokens, prompt_len, weights, *,
            interpret: bool = True):
    """Prefill forward pass for one request padded to a shape bucket.

    Args:
      tokens: i32[1, S] prompt padded with zeros to bucket length S.
      prompt_len: i32[] true prompt length (1 <= prompt_len <= S).
      weights: flat list in param_spec order.

    Returns:
      logits: f32[1, vocab] at position prompt_len - 1.
      k_cache, v_cache: f32[L, 1, Hkv, S, D] (positions >= prompt_len are
        junk; the decode path masks them via per-request lengths).
    """
    p = _unflatten(cfg, weights)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = p["embed"][tokens]
    k_layers, v_layers = [], []
    for i in range(cfg.layers):
        h = _rmsnorm(x, p[f"l{i}.ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, h, p[f"l{i}.wqkv"])
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)
        # [B, T, heads, D] -> [B, heads, T, D]
        qh = q.transpose(0, 2, 1, 3)
        kh = k.transpose(0, 2, 1, 3)
        vh = v.transpose(0, 2, 1, 3)
        k_layers.append(kh)
        v_layers.append(vh)
        kx = _expand_kv(kh, cfg.heads // cfg.kv_heads)
        vx = _expand_kv(vh, cfg.heads // cfg.kv_heads)
        attn = flash_attention_prefill(qh, kx, vx, causal=True,
                                       interpret=interpret)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, cfg.hidden)
        x = x + attn @ p[f"l{i}.wo"]
        h2 = _rmsnorm(x, p[f"l{i}.ln2"], cfg.norm_eps)
        x = x + _swiglu(h2, p[f"l{i}.w_gate"], p[f"l{i}.w_up"], p[f"l{i}.w_down"])
    x = _rmsnorm(x, p["ln_f"], cfg.norm_eps)
    last = jnp.take_along_axis(
        x, (prompt_len - 1).reshape(1, 1, 1).astype(jnp.int32), axis=1
    )[:, 0, :]
    logits = last @ p["unembed"]
    k_cache = jnp.stack(k_layers)  # [L, B, Hkv, S, D]
    v_cache = jnp.stack(v_layers)
    return logits, k_cache, v_cache


def decode(cfg: TinyLMConfig, tokens, positions, k_cache, v_cache, weights, *,
           interpret: bool = True):
    """One decode step for a continuous batch of B requests.

    Args:
      tokens: i32[B] current token per request.
      positions: i32[B] index the new token occupies (== tokens generated so
        far + prompt length - ... i.e. the next free KV slot, 0-based).
      k_cache, v_cache: f32[L, B, Hkv, Smax, D] padded caches.
      weights: flat list in param_spec order.

    Returns:
      logits: f32[B, vocab]
      new_k, new_v: f32[L, B, Hkv, D] — this step's KV rows, which the Rust
        KV manager writes back at `positions` before the next step.
    """
    p = _unflatten(cfg, weights)
    b = tokens.shape[0]
    smax = k_cache.shape[3]
    x = p["embed"][tokens]  # [B, H]
    new_ks, new_vs = [], []
    lengths = positions + 1  # after inserting the current token
    for i in range(cfg.layers):
        h = _rmsnorm(x, p[f"l{i}.ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, h[:, None, :], p[f"l{i}.wqkv"])  # T=1
        q = _rope(q, positions[:, None], cfg.rope_theta)[:, 0]  # [B, heads, D]
        k = _rope(k, positions[:, None], cfg.rope_theta)[:, 0]  # [B, Hkv, D]
        v = v[:, 0]  # [B, Hkv, D]
        new_ks.append(k)
        new_vs.append(v)
        # Insert the new token's KV at its position (per-request offsets).
        upd = jax.vmap(
            lambda c, kn, pos: jax.lax.dynamic_update_slice(
                c, kn[:, None, :], (0, pos, 0)
            )
        )
        kc = upd(k_cache[i], k, positions)  # [B, Hkv, Smax, D]
        vc = upd(v_cache[i], v, positions)
        kx = _expand_kv(kc, cfg.heads // cfg.kv_heads)
        vx = _expand_kv(vc, cfg.heads // cfg.kv_heads)
        attn = attention_decode(q, kx, vx, lengths, interpret=interpret)
        attn = attn.reshape(b, cfg.hidden)
        x = x + attn @ p[f"l{i}.wo"]
        h2 = _rmsnorm(x, p[f"l{i}.ln2"], cfg.norm_eps)
        x = x + _swiglu(h2, p[f"l{i}.w_gate"], p[f"l{i}.w_up"], p[f"l{i}.w_down"])
    x = _rmsnorm(x, p["ln_f"], cfg.norm_eps)
    logits = x @ p["unembed"]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def full_forward_ref(cfg: TinyLMConfig, tokens):
    """Oracle: dense causal forward over an unpadded prompt, pure jnp
    (no Pallas), returning logits at every position. Used by tests to check
    prefill+decode agree with a straight-line forward pass."""
    from .kernels import ref

    weights = init_weights(cfg)
    p = _unflatten(cfg, weights)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = p["embed"][tokens]
    for i in range(cfg.layers):
        h = _rmsnorm(x, p[f"l{i}.ln1"], cfg.norm_eps)
        q, k, v = _qkv(cfg, h, p[f"l{i}.wqkv"])
        q = _rope(q, positions, cfg.rope_theta).transpose(0, 2, 1, 3)
        k = _rope(k, positions, cfg.rope_theta).transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        kx = _expand_kv(k, cfg.heads // cfg.kv_heads)
        vx = _expand_kv(v, cfg.heads // cfg.kv_heads)
        attn = ref.attention_prefill_ref(q, kx, vx, causal=True)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, s, cfg.hidden)
        x = x + attn @ p[f"l{i}.wo"]
        h2 = _rmsnorm(x, p[f"l{i}.ln2"], cfg.norm_eps)
        x = x + _swiglu(h2, p[f"l{i}.w_gate"], p[f"l{i}.w_up"], p[f"l{i}.w_down"])
    x = _rmsnorm(x, p["ln_f"], cfg.norm_eps)
    return x @ p["unembed"]
