"""Pytest wiring for accelerator-less / offline runners.

The L1/L2 test modules import jax (and the kernel/model suites also
hypothesis) at module scope, so on a bare CI runner they must be skipped
at *collection* time — a marker alone cannot rescue a failing import.
This conftest:

* puts ``python/`` on ``sys.path`` so ``from compile import ...`` works
  regardless of pytest's invocation directory;
* falls back to the vendored deterministic hypothesis shim
  (``python/vendor/hypothesis``) when the real library is missing, so
  the kernel/model oracle suites only ever skip on a missing *jax*;
* ignores test modules whose hard dependencies are missing (printed once
  so CI logs show what was skipped and why);
* tags every collected test with ``requires_jax`` / ``requires_pallas`` /
  ``requires_hypothesis`` markers so ``-m`` selections work on full
  installs.

The Pallas kernels default to ``interpret=True`` (see
compile/kernels/attention.py), so no accelerator is needed when jax and
hypothesis are present — the markers describe *library* needs, not
hardware.
"""

import importlib.util
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, _ROOT)

HAVE_JAX = importlib.util.find_spec("jax") is not None
HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

# No real hypothesis install: fall back to the vendored deterministic
# shim (python/vendor/hypothesis) so the kernel/model oracle suites run
# on bare runners instead of skipping. Appended to the *end* of the
# vendor dir lookup chain is not enough — the shim must be importable as
# `hypothesis` — but inserting after the project root keeps any real
# install (found above) authoritative.
USING_HYPOTHESIS_SHIM = False
if not HAVE_HYPOTHESIS:
    sys.path.insert(1, os.path.join(_ROOT, "vendor"))
    HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None
    USING_HYPOTHESIS_SHIM = HAVE_HYPOTHESIS
    if USING_HYPOTHESIS_SHIM:
        print(
            "conftest: real hypothesis missing; using the vendored shim "
            "(python/vendor/hypothesis, deterministic examples)",
            file=sys.__stderr__,
        )

# Module -> hard import dependencies that cannot be marker-skipped.
_NEEDS = {
    "tests/test_aot.py": ["jax"],
    "tests/test_model.py": ["jax", "hypothesis"],
    "tests/test_kernel.py": ["jax", "hypothesis"],
}

_available = {"jax": HAVE_JAX, "hypothesis": HAVE_HYPOTHESIS}

collect_ignore = []
_skip_notes = []
for module, needs in _NEEDS.items():
    missing = [n for n in needs if not _available[n]]
    if missing:
        collect_ignore.append(module)
        note = f"conftest: skipping {module} (missing: {', '.join(missing)})"
        _skip_notes.append(note)
        # sys.stderr is captured by pytest during collection; write to the
        # real stream so CI logs always show what was skipped and why.
        print(note, file=sys.__stderr__)


def pytest_report_header(config):
    notes = list(_skip_notes)
    if USING_HYPOTHESIS_SHIM:
        notes.append("hypothesis: vendored shim (python/vendor/hypothesis)")
    return notes


def pytest_collection_modifyitems(config, items):
    import pytest

    for item in items:
        path = str(item.fspath)
        if "test_shim" in path:
            continue  # the shim's own suite is dependency-free
        if "test_kernel" in path:
            item.add_marker(pytest.mark.requires_pallas)
        if "test_kernel" in path or "test_model" in path:
            item.add_marker(pytest.mark.requires_hypothesis)
        item.add_marker(pytest.mark.requires_jax)
