"""Pytest wiring for accelerator-less / offline runners.

The L1/L2 test modules import jax (and the kernel/model suites also
hypothesis) at module scope, so on a bare CI runner they must be skipped
at *collection* time — a marker alone cannot rescue a failing import.
This conftest:

* puts ``python/`` on ``sys.path`` so ``from compile import ...`` works
  regardless of pytest's invocation directory;
* ignores test modules whose hard dependencies are missing (printed once
  so CI logs show what was skipped and why);
* tags every collected test with ``requires_jax`` / ``requires_pallas`` /
  ``requires_hypothesis`` markers so ``-m`` selections work on full
  installs.

The Pallas kernels default to ``interpret=True`` (see
compile/kernels/attention.py), so no accelerator is needed when jax and
hypothesis are present — the markers describe *library* needs, not
hardware.
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

HAVE_JAX = importlib.util.find_spec("jax") is not None
HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

# Module -> hard import dependencies that cannot be marker-skipped.
_NEEDS = {
    "tests/test_aot.py": ["jax"],
    "tests/test_model.py": ["jax", "hypothesis"],
    "tests/test_kernel.py": ["jax", "hypothesis"],
}

_available = {"jax": HAVE_JAX, "hypothesis": HAVE_HYPOTHESIS}

collect_ignore = []
_skip_notes = []
for module, needs in _NEEDS.items():
    missing = [n for n in needs if not _available[n]]
    if missing:
        collect_ignore.append(module)
        note = f"conftest: skipping {module} (missing: {', '.join(missing)})"
        _skip_notes.append(note)
        # sys.stderr is captured by pytest during collection; write to the
        # real stream so CI logs always show what was skipped and why.
        print(note, file=sys.__stderr__)


def pytest_report_header(config):
    return _skip_notes


def pytest_collection_modifyitems(config, items):
    import pytest

    for item in items:
        path = str(item.fspath)
        if "test_kernel" in path:
            item.add_marker(pytest.mark.requires_pallas)
        if "test_kernel" in path or "test_model" in path:
            item.add_marker(pytest.mark.requires_hypothesis)
        item.add_marker(pytest.mark.requires_jax)
