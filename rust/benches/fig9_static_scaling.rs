//! Regenerates **Figure 9** — static coarse-grained scaling: P90 goodput
//! as the instance count doubles 1 → 2 → 4 → 8. The paper reports
//! *superlinear* scaling (5.6× at 4 instances for CodeLlama-34B): one
//! instance degenerates PaDG to NoDG (no ring to roll), so adding
//! instances buys interference room on top of raw capacity.
//!
//!     cargo bench --bench fig9_static_scaling
//!
//! Deviation note: the paper lists TP=2 for Qwen2-72B here, but a 72B
//! bf16 model (~145 GB weights) cannot fit two 48 GB L20s; we use TP=8 as
//! in its §4.2 end-to-end setup and scale 1 → 2 → 4 instances.

use ecoserve::config::{ClusterSpec, Deployment, ExperimentConfig, SystemKind};
use ecoserve::harness::goodput_search;
use ecoserve::metrics::Attainment;
use ecoserve::perfmodel::ModelSpec;
use ecoserve::util::threads::parallel_map;
use ecoserve::workload::Dataset;

fn main() {
    println!("== Figure 9: static coarse-grained scaling (P90 goodput, ShareGPT, L20) ==");
    for (model, tp, counts) in [
        (ModelSpec::codellama_34b(), 4usize, vec![1usize, 2, 4, 8]),
        (ModelSpec::qwen2_72b(), 8, vec![1, 2, 4]),
    ] {
        let jobs: Vec<usize> = counts.clone();
        let model_name = model.name;
        let results = parallel_map(jobs, counts.len(), |n| {
            let mut deployment =
                Deployment::paper_default(model.clone(), ClusterSpec::l20_cluster());
            deployment.tp = tp;
            deployment.pp = 1;
            deployment.gpus_used = n * tp;
            let mut cfg = ExperimentConfig::new(deployment, Dataset::sharegpt());
            cfg.duration = 180.0;
            cfg.warmup = 30.0;
            let g = goodput_search(SystemKind::EcoServe, &cfg, Attainment::P90);
            (n, g.rate)
        });
        println!("\n{model_name} (TP={tp}):");
        println!(
            "{:>10} {:>8} {:>14} {:>12} {:>12}",
            "instances", "GPUs", "goodput req/s", "speedup", "vs linear"
        );
        let base = results[0].1.max(1e-9);
        for (n, rate) in &results {
            let speedup = rate / base;
            let linear = *n as f64;
            println!(
                "{:>10} {:>8} {:>14.2} {:>11.2}x {:>11}",
                n,
                n * tp,
                rate,
                speedup,
                if speedup > linear * 1.02 {
                    "SUPERLINEAR"
                } else if speedup > linear * 0.9 {
                    "~linear"
                } else {
                    "sublinear"
                }
            );
        }
    }
    println!("\n(paper: 5.6x at 4 instances for CodeLlama-34B — superlinear because a");
    println!(" single instance cannot roll prefill windows across a ring)");
}
