//! Regenerates **Figure 11** — pipeline-parallelism compatibility:
//! throughput as the TPOT SLO relaxes from 100 ms to 500 ms for
//! EcoServe TP=4, EcoServe TP=2×PP=2, and vLLM (both layouts),
//! CodeLlama-34B / ShareGPT / L20.
//!
//!     cargo bench --bench fig11_pp_compat
//!
//! Expected shape (paper): PP gives no single-batch latency speedup, so at
//! tight TPOT SLOs the TP=4 layout wins; as the SLO relaxes past the
//! crossover, EcoServe's PP layout overtakes (cheap p2p hand-offs instead
//! of PCIe all-reduces) and plateaus above both vLLM variants — whose
//! constant prefill/decode alternation pays the pipeline fill/drain bubble
//! on every switch.

use ecoserve::config::{ClusterSpec, Deployment, ExperimentConfig, SystemKind};
use ecoserve::harness::goodput_search;
use ecoserve::metrics::Attainment;
use ecoserve::perfmodel::ModelSpec;
use ecoserve::util::threads::parallel_map;
use ecoserve::workload::Dataset;

fn main() {
    let slos_ms = [100.0, 200.0, 300.0, 400.0, 500.0];
    let layouts: [(&str, SystemKind, usize, usize); 4] = [
        ("EcoServe TP4", SystemKind::EcoServe, 4, 1),
        ("EcoServe TP2xPP2", SystemKind::EcoServe, 2, 2),
        ("vLLM TP4", SystemKind::Vllm, 4, 1),
        ("vLLM TP2xPP2", SystemKind::Vllm, 2, 2),
    ];

    let mut jobs = Vec::new();
    for &(label, system, tp, pp) in &layouts {
        for &slo_ms in &slos_ms {
            jobs.push((label, system, tp, pp, slo_ms));
        }
    }
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    let results = parallel_map(jobs, workers, |(label, system, tp, pp, slo_ms)| {
        let mut deployment = Deployment::paper_default(
            ModelSpec::codellama_34b(),
            ClusterSpec::l20_cluster(),
        );
        deployment.tp = tp;
        deployment.pp = pp;
        deployment.gpus_used = 32;
        let mut dataset = Dataset::sharegpt();
        dataset.slo_tpot = slo_ms / 1e3;
        let mut cfg = ExperimentConfig::new(deployment, dataset);
        cfg.duration = 180.0;
        cfg.warmup = 30.0;
        let g = goodput_search(system, &cfg, Attainment::P90);
        (label, slo_ms, g.rate)
    });

    println!("== Figure 11: P90 goodput (req/s) vs TPOT SLO — CodeLlama-34B, ShareGPT, L20 ==\n");
    print!("{:<18}", "layout");
    for slo in slos_ms {
        print!(" {:>9}", format!("{slo:.0}ms"));
    }
    println!();
    for &(label, _, _, _) in &layouts {
        print!("{label:<18}");
        for &slo in &slos_ms {
            let rate = results
                .iter()
                .find(|r| r.0 == label && r.1 == slo)
                .map(|r| r.2)
                .unwrap_or(f64::NAN);
            print!(" {:>9.2}", rate);
        }
        println!();
    }

    // Shape checks (see EXPERIMENTS.md F11 for the deviation discussion:
    // in our roofline the PP/TP crossover point sits above the highest
    // demand-driven batch size the workload reaches, so PP *converges
    // toward* TP as the SLO relaxes rather than fully overtaking it).
    let get = |label: &str, slo: f64| {
        results.iter().find(|r| r.0 == label && r.1 == slo).map(|r| r.2).unwrap_or(0.0)
    };
    let tight = get("EcoServe TP4", 100.0) >= get("EcoServe TP2xPP2", 100.0);
    let ratio_tight = get("EcoServe TP2xPP2", 100.0) / get("EcoServe TP4", 100.0).max(1e-9);
    let ratio_relaxed = get("EcoServe TP2xPP2", 500.0) / get("EcoServe TP4", 500.0).max(1e-9);
    let pp_gains = ratio_relaxed > ratio_tight + 0.15;
    let beats_vllm_tight = get("EcoServe TP2xPP2", 100.0) > get("vLLM TP2xPP2", 100.0)
        && get("EcoServe TP2xPP2", 200.0) > get("vLLM TP2xPP2", 200.0);
    println!("\nshape checks:");
    println!(
        "  TP wins at tight TPOT SLO:                  {}",
        if tight { "PASS" } else { "FAIL" }
    );
    println!(
        "  PP/TP ratio grows as SLO relaxes ({:.2} -> {:.2}): {}",
        ratio_tight,
        ratio_relaxed,
        if pp_gains { "PASS" } else { "FAIL" }
    );
    println!(
        "  EcoServe-PP beats vLLM-PP at tight SLOs:    {}",
        if beats_vllm_tight { "PASS" } else { "FAIL" }
    );
}
