//! Regenerates **Table 2** — approximate arithmetic intensity of the six
//! primary LLM operations, prefill vs decode — and checks the paper's
//! qualitative claims (prefill AI >> decode AI; prefill ~Θ(BS)/Θ(S),
//! decode ~Θ(B)/Θ(1)).
//!
//!     cargo bench --bench table2_arithmetic_intensity

use ecoserve::perfmodel::{table2_ops, Phase};

fn main() {
    // The paper leaves (B, S, H, M) symbolic; print a representative grid
    // so the asymptotic columns are visible numerically.
    println!("== Table 2: approximate arithmetic intensity (elements, bf16) ==\n");
    for (b, s) in [(1.0, 128.0), (8.0, 512.0), (64.0, 2048.0)] {
        let (h, m) = (8192.0, 64.0);
        println!("B={b}, S={s}, H={h}, M={m}");
        println!(
            "{:<20} {:>8} {:>12} {:>14} {:>10} {:>12}",
            "Operation", "Phase", "GFLOPs", "MBytes", "AI", "paper-approx"
        );
        for op in table2_ops(b, s, h, m, 2.0) {
            let approx = match (op.name, op.phase) {
                ("Attention QK^T" | "Attention (QK^T)V", Phase::Prefill) => format!("S={s}"),
                ("Attention QK^T" | "Attention (QK^T)V", Phase::Decode) => "1".to_string(),
                (_, Phase::Prefill) => format!("BS={}", b * s),
                (_, Phase::Decode) => format!("B={b}"),
            };
            println!(
                "{:<20} {:>8} {:>12.2} {:>14.2} {:>10.1} {:>12}",
                op.name,
                format!("{:?}", op.phase),
                op.flops / 1e9,
                op.bytes / 1e6,
                op.arithmetic_intensity(),
                approx
            );
        }
        println!();
    }

    // Paper claims, checked numerically over the grid:
    let mut ok = true;
    for (b, s) in [(1.0, 128.0), (8.0, 512.0), (64.0, 2048.0)] {
        let ops = table2_ops(b, s, 8192.0, 64.0, 2.0);
        for name in [
            "QKV Projection",
            "Attention QK^T",
            "Attention (QK^T)V",
            "Output Projection",
            "Dim Expansion",
            "Dim Reduction",
        ] {
            let p = ops.iter().find(|o| o.name == name && o.phase == Phase::Prefill).unwrap();
            let d = ops.iter().find(|o| o.name == name && o.phase == Phase::Decode).unwrap();
            if p.arithmetic_intensity() <= d.arithmetic_intensity() {
                ok = false;
                println!("VIOLATION: {name} prefill AI <= decode AI at B={b},S={s}");
            }
        }
    }
    println!(
        "paper claim check (prefill AI > decode AI for all six ops): {}",
        if ok { "PASS" } else { "FAIL" }
    );
}
