//! Regenerates **Figure 10** — dynamic fine-grained scaling: the request
//! rate ramps up in steps while the mitosis controller adds instances;
//! SLO attainment (sampled every 30 s) dips at each rate step and recovers
//! after each scale-up. N_l = 4, N_u = 16 as in the paper.
//!
//!     cargo bench --bench fig10_dynamic_scaling
//!
//! Calibration note: the paper ramps 20 → 50 req/s against its testbed's
//! per-instance capacity (~2.5 req/s); our analytical L20 instances
//! sustain ~3.6 req/s on ShareGPT, so the ramp is scaled to 16 → 40 req/s —
//! same relative overload trajectory, same expected figure shape.

use ecoserve::config::{ClusterSpec, Deployment, SystemParams};
use ecoserve::coordinator::padg::{AutoScalePolicy, EcoServeSystem};
use ecoserve::metrics::{Collector, SloSpec};
use ecoserve::perfmodel::ModelSpec;
use ecoserve::sim::run;
use ecoserve::workload::{Dataset, RampTrace, TraceGenerator};

fn main() {
    let mut deployment = Deployment::paper_default(
        ModelSpec::codellama_34b(),
        ClusterSpec::l20_cluster(),
    );
    deployment.gpus_used = 64; // allow growth to 16 instances (N_u)
    let dataset = Dataset::sharegpt();
    let slo = SloSpec::new(dataset.slo_ttft, dataset.slo_tpot);
    let mut params = SystemParams::default();
    params.n_lower = 4;
    params.n_upper = 16;

    let mut sys = EcoServeSystem::with_capacity(&deployment, slo, params, 8, 16);
    sys.autoscale = Some(AutoScalePolicy::default());

    let ramp = RampTrace { start_rate: 16.0, end_rate: 40.0, increments: 6, step_secs: 120.0 };
    let trace = TraceGenerator::new(dataset, 42).ramp(&ramp.steps());
    println!("== Figure 10: dynamic fine-grained scaling ==");
    println!(
        "ramp {} -> {} req/s in {} steps of {}s; start 8 instances, N_l=4 N_u=16\n",
        ramp.start_rate, ramp.end_rate, ramp.increments, ramp.step_secs
    );

    let mut metrics = Collector::new();
    let t0 = std::time::Instant::now();
    let stats = run(&mut sys, trace, ramp.total_duration() + 240.0, &mut metrics);

    println!("{:>7} {:>10} {:>10}  attainment (every 30s)", "t (s)", "attain %", "instances");
    let series = metrics.attainment_series(&slo, 30.0, ramp.total_duration());
    for (t, frac) in &series {
        let active = 8 + sys
            .scale_log
            .iter()
            .filter(|e| e.time <= *t && e.kind == "up")
            .count()
            - sys.scale_log.iter().filter(|e| e.time <= *t && e.kind == "down").count();
        let bar = "#".repeat((frac * 40.0) as usize);
        println!("{:>7.0} {:>10.1} {:>10}  {bar}", t, frac * 100.0, active);
    }

    println!("\nscale events:");
    for e in &sys.scale_log {
        println!("  t={:>6.1}s scale-{} -> {} active", e.time, e.kind, e.active_instances);
    }
    println!("\nfinal macros: {:?}", sys.mitosis.macros);
    sys.mitosis.check_invariants().expect("mitosis invariants hold");

    let dips_recovered = series.windows(2).filter(|w| w[1].1 > w[0].1 + 0.05).count();
    println!(
        "\nshape check: {} recovery upticks after dips (paper: attainment dips at each",
        dips_recovered
    );
    println!(
        " rate step and is restored by the newly added instance); {} sim events in {:?}",
        stats.events,
        t0.elapsed()
    );
}
