//! Regenerates **Figure 8** — end-to-end goodput under P50/P90/P99 SLO
//! attainment for all five systems across the full evaluation grid:
//! 3 models × 3 datasets × 2 clusters.
//!
//!     cargo bench --bench fig8_end_to_end_goodput            # full grid
//!     FIG8_QUICK=1 cargo bench --bench fig8_end_to_end_goodput  # 1 cell/cluster
//!
//! Absolute rates differ from the paper (our substrate is an analytical
//! simulator, not their testbed); the *shape* to verify: EcoServe ≥ NoDG
//! with the gap widening P50→P99 and smallest on Alpaca; FuDG collapsing
//! for Llama-30B (MHA KV) on commodity links and degrading further on
//! A800 (compute grows faster than bandwidth).

use ecoserve::config::{ClusterSpec, Deployment, ExperimentConfig, SystemKind};
use ecoserve::harness::goodput_search;
use ecoserve::metrics::Attainment;
use ecoserve::perfmodel::ModelSpec;
use ecoserve::util::threads::parallel_map;
use ecoserve::workload::Dataset;

fn main() {
    let quick = std::env::var("FIG8_QUICK").is_ok();
    let clusters = [ClusterSpec::l20_cluster(), ClusterSpec::a800_cluster()];
    let models = if quick {
        vec![ModelSpec::llama_30b()]
    } else {
        vec![ModelSpec::llama_30b(), ModelSpec::codellama_34b(), ModelSpec::qwen2_72b()]
    };
    let datasets = if quick {
        vec![Dataset::sharegpt()]
    } else {
        Dataset::all_paper()
    };
    let levels = Attainment::all();

    // Build the experiment grid.
    let mut cells = Vec::new();
    for cluster in &clusters {
        for model in &models {
            for dataset in &datasets {
                for level in levels {
                    for system in SystemKind::all() {
                        cells.push((cluster.clone(), model.clone(), dataset.clone(),
                                    level, system));
                    }
                }
            }
        }
    }
    eprintln!("fig8: {} goodput searches (FIG8_QUICK=1 for a subset)...", cells.len());

    let t0 = std::time::Instant::now();
    let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8);
    let results = parallel_map(cells, workers, |(cluster, model, dataset, level, system)| {
        let deployment = Deployment::paper_default(model.clone(), cluster.clone());
        let mut cfg = ExperimentConfig::new(deployment, dataset.clone());
        cfg.duration = 180.0;
        cfg.warmup = 30.0;
        let g = goodput_search(system, &cfg, level);
        (cluster.name, model.name, dataset.name, level, system, g.rate)
    });
    eprintln!("fig8: grid done in {:?}", t0.elapsed());

    // Print per-(cluster, model, dataset) blocks with all systems/levels.
    println!("== Figure 8: goodput (req/s) at SLO attainment levels ==");
    for cluster in &clusters {
        for model in &models {
            for dataset in &datasets {
                let block: Vec<_> = results
                    .iter()
                    .filter(|r| r.0 == cluster.name && r.1 == model.name && r.2 == dataset.name)
                    .collect();
                if block.is_empty() {
                    continue;
                }
                println!("\n--- {} | {} | {} ---", cluster.name, model.name, dataset.name);
                println!("{:<10} {:>8} {:>8} {:>8}", "system", "P50", "P90", "P99");
                for system in SystemKind::all() {
                    let rate = |lvl: Attainment| {
                        block
                            .iter()
                            .find(|r| r.4 == system && r.3 == lvl)
                            .map(|r| r.5)
                            .unwrap_or(f64::NAN)
                    };
                    println!(
                        "{:<10} {:>8.2} {:>8.2} {:>8.2}",
                        system.label(),
                        rate(Attainment::P50),
                        rate(Attainment::P90),
                        rate(Attainment::P99)
                    );
                }
            }
        }
    }

    // Headline aggregate: EcoServe's mean P90 improvement over each baseline
    // (the paper reports +83.76% vLLM, +71.97% Sarathi, +192.41% DistServe,
    // +218.22% MoonCake).
    println!("\n== EcoServe mean P90 goodput improvement over baselines ==");
    for baseline in [
        SystemKind::Vllm,
        SystemKind::Sarathi,
        SystemKind::DistServe,
        SystemKind::MoonCake,
    ] {
        let mut gains = Vec::new();
        for cluster in &clusters {
            for model in &models {
                for dataset in &datasets {
                    let find = |sys: SystemKind| {
                        results
                            .iter()
                            .find(|r| {
                                r.0 == cluster.name && r.1 == model.name
                                    && r.2 == dataset.name && r.4 == sys
                                    && r.3 == Attainment::P90
                            })
                            .map(|r| r.5)
                    };
                    if let (Some(eco), Some(base)) = (find(SystemKind::EcoServe), find(baseline)) {
                        if base > 0.05 {
                            gains.push((eco / base - 1.0) * 100.0);
                        } else if eco > 0.05 {
                            gains.push(300.0); // baseline failed outright; cap the ratio
                        }
                    }
                }
            }
        }
        let mean = gains.iter().sum::<f64>() / gains.len().max(1) as f64;
        println!(
            "  vs {:<10}: {:+.1}% (paper: vLLM +83.8, Sarathi +72.0, DistServe +192.4, \
             MoonCake +218.2)",
            baseline.label(),
            mean
        );
    }
}
