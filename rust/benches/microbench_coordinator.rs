//! Coordinator hot-path microbenchmarks (§Perf L3 evidence, not a paper
//! figure): per-decision routing latency, constraint-check cost, simulator
//! event throughput, proxy migration latency, and paged-KV gather
//! bandwidth. Targets (EXPERIMENTS.md §Perf): scheduling decision ≪ 1 ms;
//! simulator ≥ 2 M events/s; proxy migration ≪ 100 ms.
//!
//!     cargo bench --bench microbench_coordinator

use std::time::Instant;

use ecoserve::config::{ClusterSpec, Deployment, ExperimentConfig, SystemKind};
use ecoserve::coordinator::constraints::check_constraints;
use ecoserve::coordinator::proxy::{HandlerTable, InstanceHandler};
use ecoserve::coordinator::routing::{route, RoutingState};
use ecoserve::harness::run_once;
use ecoserve::metrics::SloSpec;
use ecoserve::perfmodel::ModelSpec;
use ecoserve::runtime::kv::{KvConfig, KvStore};
use ecoserve::sim::SimInstance;
use ecoserve::workload::{Dataset, Request};

fn bench<F: FnMut()>(name: &str, iters: u64, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:<44} {:>12.1} ns/op {:>14.0} ops/s", per * 1e9, 1.0 / per);
    per
}

fn main() {
    println!("== L3 coordinator microbenchmarks ==\n");
    let deployment = Deployment::paper_default(
        ModelSpec::codellama_34b(),
        ClusterSpec::l20_cluster(),
    );
    let slo = SloSpec::new(5.0, 0.1);

    // Populated instances for realistic constraint checks.
    let mut instances: Vec<SimInstance> = (0..8)
        .map(|i| SimInstance::new(i, deployment.timer(), 0.1))
        .collect();
    for (i, inst) in instances.iter_mut().enumerate() {
        for k in 0..40 {
            inst.admit(Request {
                id: (i * 100 + k) as u64,
                arrival: 0.0,
                input_len: 300,
                output_len: 200,
            });
        }
        // move them to running via a prefill+decode cycle
        let mut m = ecoserve::metrics::Collector::new();
        for _ in 0..40 {
            let d = inst.start_prefill(1, 0.0);
            inst.complete_batch(d, &mut m);
        }
        let d = inst.start_decode(1.0);
        inst.complete_batch(d, &mut m);
    }
    let req = Request { id: 9999, arrival: 10.0, input_len: 400, output_len: 150 };

    bench("constraint check (Algorithm 2)", 200_000, || {
        let v = check_constraints(&instances[3], &req, 10.0, &slo, 128, 0.7);
        std::hint::black_box(v);
    });

    let members: Vec<usize> = (0..8).collect();
    let mut rs = RoutingState::default();
    bench("routing decision (Algorithm 1, 8-ring)", 100_000, || {
        let out = route(&mut rs, &members, &instances, &req, 10.0, &slo, 128);
        std::hint::black_box(out);
    });

    // Proxy migration (paper budget: < 100 ms; re-init alternative ~3 min).
    let mut table_a = HandlerTable::default();
    for id in 0..16u64 {
        table_a
            .handlers
            .push(InstanceHandler::new(id, format!("n{}:50{}", id / 8, id), 4, 1, 150_000));
    }
    let per = bench("proxy migration (serialize+deserialize)", 100_000, || {
        let wire = table_a.export(7).unwrap();
        let mut b = HandlerTable::default();
        b.import(&wire).unwrap();
        let back = b.export(7).unwrap();
        table_a.import(&back).unwrap();
    });
    println!("  -> {:.3} us per migration vs paper's <100 ms budget", per * 1e6 / 2.0);

    // Paged-KV gather bandwidth (live-path hot loop).
    let kv_cfg = KvConfig { layers: 4, kv_heads: 2, head_dim: 32, max_seq: 128, block_tokens: 16 };
    let mut store = KvStore::new(kv_cfg.clone(), 64 * 128);
    let bucket = 16;
    let fake = vec![0.5f32; kv_cfg.layers * kv_cfg.kv_heads * 128 * kv_cfg.head_dim];
    for id in 0..16u64 {
        store.insert_prefill(id, &fake, &fake, 128, 100).unwrap();
    }
    let ids: Vec<u64> = (0..16).collect();
    let bytes_per_gather = (2 * kv_cfg.layers * bucket * kv_cfg.kv_heads
        * kv_cfg.max_seq * kv_cfg.head_dim * 4) as f64;
    let per = bench("KV gather (16 reqs -> [L,16,Hkv,128,D])", 2_000, || {
        let out = store.gather_batch(&ids, bucket).unwrap();
        std::hint::black_box(out);
    });
    println!("  -> {:.2} GB/s gather bandwidth", bytes_per_gather / per / 1e9);

    // End-to-end simulator throughput (the Fig-8 grid driver).
    let mut cfg = ExperimentConfig::new(deployment, Dataset::sharegpt());
    cfg.duration = 120.0;
    cfg.warmup = 20.0;
    let t0 = Instant::now();
    let r = run_once(SystemKind::EcoServe, &cfg, 10.0, None);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nsimulator end-to-end: {} events in {:.3}s = {:.2}M events/s (target >= 2M)",
        r.events,
        wall,
        r.events as f64 / wall / 1e6
    );
    println!("sim-seconds per wall-second: {:.0}", (cfg.duration + 240.0) / wall);
}
