//! Ablation study over EcoServe's scheduling design choices (the DESIGN.md
//! §8 knobs). Each row disables exactly one mechanism and measures strict
//! P90 attainment at a fixed near-capacity operating point.
//!
//!     cargo bench --bench ablation_padg
//!
//! Expected: full EcoServe on top; mean-slack (the paper's literal
//! Algorithm-2 line) loses TPOT attainment on short-output requests;
//! removing the window cap starves the ring on long-prompt workloads;
//! removing stickiness fragments windows; removing hysteresis multiplies
//! phase switches.

use ecoserve::config::{ClusterSpec, Deployment, ExperimentConfig, SystemKind, SystemParams};
use ecoserve::harness::run_once;
use ecoserve::perfmodel::ModelSpec;
use ecoserve::util::threads::parallel_map;
use ecoserve::workload::Dataset;

fn main() {
    let variants: Vec<(&str, fn(&mut SystemParams))> = vec![
        ("full EcoServe", |_| {}),
        ("mean slack (paper-literal)", |p| p.ablate_mean_slack = true),
        ("no window cap", |p| p.ablate_no_window_cap = true),
        ("no sticky routing", |p| p.ablate_no_sticky = true),
        ("no hysteresis", |p| p.ablate_no_hysteresis = true),
    ];
    let workloads = [
        (Dataset::sharegpt(), 14.0, 32),
        (Dataset::longbench(), 2.8, 32),
    ];

    println!("== EcoServe scheduler ablations (strict attainment at fixed load) ==\n");
    for (dataset, rate, gpus) in workloads {
        println!("--- {} @ {:.1} req/s, Llama-30B, L20, {} GPUs ---", dataset.name, rate, gpus);
        println!("{:<30} {:>10} {:>12} {:>12}", "variant", "attain %", "p90TTFT s", "p90TPOT ms");
        let jobs: Vec<_> = variants.iter().map(|(n, f)| (*n, *f)).collect();
        let rows = parallel_map(jobs, variants.len(), |(name, mutate)| {
            let mut d =
                Deployment::paper_default(ModelSpec::llama_30b(), ClusterSpec::l20_cluster());
            d.gpus_used = gpus;
            let mut cfg = ExperimentConfig::new(d, dataset.clone());
            cfg.duration = 180.0;
            cfg.warmup = 30.0;
            mutate(&mut cfg.params);
            let r = run_once(SystemKind::EcoServe, &cfg, rate, None);
            (name, r)
        });
        let full = rows[0].1.attainment;
        for (name, r) in &rows {
            println!(
                "{:<30} {:>10.1} {:>12.2} {:>12.1}{}",
                name,
                r.attainment * 100.0,
                r.summary.ttft_p90,
                r.summary.tpot_p90 * 1e3,
                if r.attainment + 1e-9 < full { "   (worse)" } else { "" }
            );
        }
        println!();
    }
}
