//! Regenerates **Table 3** — KV-cache generation rate of an all-prefill
//! 8-GPU node and the theoretical interconnect bandwidth required to move
//! that KV off-node (the FuDG feasibility argument), for Llama-30B and
//! CodeLlama-34B on L20 and A800 nodes.
//!
//!     cargo bench --bench table3_kv_bandwidth

use ecoserve::perfmodel::interconnect::{required_kv_bandwidth, LinkSpec};
use ecoserve::perfmodel::parallelism::ParallelCfg;
use ecoserve::perfmodel::{BatchTimer, GpuSpec, ModelSpec};

struct Row {
    model: ModelSpec,
    gpu: GpuSpec,
    tp: usize,
    paper_tokens: f64,
    paper_bw_gbs: f64,
}

fn main() {
    // Paper Table 3 reference values.
    let rows = [
        Row {
            model: ModelSpec::llama_30b(),
            gpu: GpuSpec::l20(),
            tp: 4,
            paper_tokens: 6584.6,
            paper_bw_gbs: 9.796,
        },
        Row {
            model: ModelSpec::llama_30b(),
            gpu: GpuSpec::a800(),
            tp: 2,
            paper_tokens: 26189.2,
            paper_bw_gbs: 38.96,
        },
        Row {
            model: ModelSpec::codellama_34b(),
            gpu: GpuSpec::l20(),
            tp: 4,
            paper_tokens: 6838.92,
            paper_bw_gbs: 1.25,
        },
        Row {
            model: ModelSpec::codellama_34b(),
            gpu: GpuSpec::a800(),
            tp: 2,
            paper_tokens: 25978.88,
            paper_bw_gbs: 4.76,
        },
    ];

    println!("== Table 3: KV generation rate + required bandwidth (8-GPU node, all prefill) ==\n");
    println!(
        "{:<16} {:>6} {:>11} {:>11} {:>8} {:>11} {:>11} {:>8}",
        "Model", "GPU", "tok/s", "paper", "ratio", "GB/s", "paper", "ratio"
    );
    let mut worst: f64 = 0.0;
    for r in &rows {
        let timer = BatchTimer::new(
            r.model.clone(),
            r.gpu.clone(),
            ParallelCfg::tp_only(r.tp, LinkSpec::pcie4()),
        );
        let per_node = (8 / r.tp) as f64;
        let toks = timer.prefill_tokens_per_sec(1024) * per_node;
        let bw = required_kv_bandwidth(toks, r.model.kv_bytes_per_token()) / 1e9;
        let tok_ratio = toks / r.paper_tokens;
        let bw_ratio = bw / r.paper_bw_gbs;
        worst = worst.max((tok_ratio - 1.0).abs()).max((bw_ratio - 1.0).abs());
        println!(
            "{:<16} {:>6} {:>11.1} {:>11.1} {:>8.2} {:>11.2} {:>11.2} {:>8.2}",
            r.model.name, r.gpu.name, toks, r.paper_tokens, tok_ratio, bw, r.paper_bw_gbs, bw_ratio
        );
    }
    println!("\nworst deviation from paper: {:.1}%", worst * 100.0);
    println!(
        "\nfeasibility vs links: 10GbE = {:.2} GB/s, 25G-RoCE = {:.2} GB/s, \
         400G-IB = {:.0} GB/s",
        LinkSpec::eth_10g().bandwidth / 1e9,
        LinkSpec::roce_25g().bandwidth / 1e9,
        LinkSpec::ib_400g().bandwidth / 1e9
    );
    println!("=> Llama-30B (MHA) KV cannot leave an L20 node over 10GbE (needs ~9.8 GB/s),");
    println!("   and A800 nodes need a 400Gbps-class fabric — the paper's FuDG cost argument.");
}
