//! Mitosis scaling demo (paper §3.5 / Figure 10): a request-rate ramp
//! drives the autoscaler; watch instances join macro instances, macros
//! split at N_u, and the attainment series recover after each scale-up.
//! Also demonstrates the serializable `InstanceHandler` proxy migrating
//! between macro-instance schedulers without touching the worker.
//!
//!     cargo run --release --example mitosis_demo

use ecoserve::config::{ClusterSpec, Deployment, SystemParams};
use ecoserve::coordinator::padg::{AutoScalePolicy, EcoServeSystem};
use ecoserve::coordinator::proxy::{HandlerTable, InstanceHandler};
use ecoserve::metrics::{Collector, SloSpec};
use ecoserve::perfmodel::ModelSpec;
use ecoserve::sim::run;
use ecoserve::workload::{Dataset, RampTrace, TraceGenerator};

fn main() {
    // CodeLlama-34B TP=4 on L20 — the paper's Figure 10 deployment.
    let mut deployment = Deployment::paper_default(
        ModelSpec::codellama_34b(),
        ClusterSpec::l20_cluster(),
    );
    deployment.gpus_used = 32;
    let dataset = Dataset::sharegpt();
    let slo = SloSpec::new(dataset.slo_ttft, dataset.slo_tpot);
    let mut params = SystemParams::default();
    params.n_lower = 4;
    params.n_upper = 16;

    // Start with 3 of 8 provisioned instances; the controller grows the
    // macro instance as the ramp (8 -> 22 req/s) overwhelms it.
    let mut sys = EcoServeSystem::with_capacity(&deployment, slo, params, 3, 8);
    sys.autoscale = Some(AutoScalePolicy::default());

    let ramp = RampTrace { start_rate: 8.0, end_rate: 22.0, increments: 6, step_secs: 60.0 };
    let gen = TraceGenerator::new(dataset.clone(), 42);
    let trace = gen.ramp(&ramp.steps());
    println!(
        "ramp {} -> {} req/s over {}s, starting with 3/8 instances (N_l=4, N_u=16)",
        ramp.start_rate, ramp.end_rate, ramp.total_duration()
    );

    let mut metrics = Collector::new();
    let stats = run(&mut sys, trace, ramp.total_duration() + 240.0, &mut metrics);

    println!("\nattainment per 30s window (Figure 10's y-axis):");
    let series = metrics.attainment_series(&slo, 30.0, ramp.total_duration());
    for (t, frac) in &series {
        let bar = "#".repeat((frac * 40.0) as usize);
        println!("  t={t:>5.0}s  {:>5.1}%  {bar}", frac * 100.0);
    }

    println!("\nscale events:");
    for e in &sys.scale_log {
        println!(
            "  t={:>6.1}s  scale {}  -> {} active instances",
            e.time, e.kind, e.active_instances
        );
    }
    println!("\nfinal macro topology: {:?}", sys.mitosis.macros);
    sys.mitosis.check_invariants().expect("mitosis invariants");

    // §3.5.2: logical migration via the serializable proxy — move one
    // instance handler from macro scheduler A to B and time it.
    let mut table_a = HandlerTable::default();
    let mut table_b = HandlerTable::default();
    for id in 0..4u64 {
        table_a
            .handlers
            .push(InstanceHandler::new(id, format!("node{}:500{}", id / 2, id), 4, 1, 150_000));
    }
    let t0 = std::time::Instant::now();
    let wire = table_a.export(2).expect("handler exists");
    let imported = table_b.import(&wire).expect("valid wire form");
    let dt = t0.elapsed();
    println!(
        "\nproxy migration of instance {} took {:?} (paper budget: <100ms; \
         \n re-initialization alternative: ~3 minutes of weight loading)",
        imported.actor_id, dt
    );
    println!("wire form: {wire}");
    println!("\nsim processed {} events in {:?}", stats.events, stats.wall_time);
}
