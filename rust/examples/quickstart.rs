//! Quickstart: simulate EcoServe vs vLLM on one workload and print the
//! goodput gap — the paper's headline comparison in miniature.
//!
//!     cargo run --release --example quickstart
//!
//! Runs in ~a minute (it performs two goodput searches on a 4-instance
//! CodeLlama-34B / L20 / ShareGPT deployment).

use ecoserve::config::{ClusterSpec, Deployment, ExperimentConfig, SystemKind};
use ecoserve::harness::goodput_search;
use ecoserve::metrics::Attainment;
use ecoserve::perfmodel::ModelSpec;
use ecoserve::workload::Dataset;

fn main() {
    // 4 instances of CodeLlama2-34B at TP=4 on the L20 cluster.
    let mut deployment = Deployment::paper_default(
        ModelSpec::codellama_34b(),
        ClusterSpec::l20_cluster(),
    );
    deployment.gpus_used = 16;
    let mut cfg = ExperimentConfig::new(deployment, Dataset::sharegpt());
    cfg.duration = 120.0;
    cfg.warmup = 20.0;

    println!(
        "deployment: {} instances of {} (TP={}) on {}, dataset {}",
        cfg.deployment.num_instances(),
        cfg.deployment.model.name,
        cfg.deployment.tp,
        cfg.deployment.cluster.name,
        cfg.dataset.name
    );
    println!(
        "searching P90 goodput (SLO: TTFT {:.0}s / TPOT {:.0}ms)...",
        cfg.dataset.slo_ttft,
        cfg.dataset.slo_tpot * 1e3
    );

    let eco = goodput_search(SystemKind::EcoServe, &cfg, Attainment::P90);
    let vllm = goodput_search(SystemKind::Vllm, &cfg, Attainment::P90);

    println!(
        "\n{:<10} {:>14} {:>16} {:>14}",
        "system", "goodput req/s", "p90 TTFT (s)", "p90 TPOT (ms)"
    );
    for g in [&eco, &vllm] {
        println!(
            "{:<10} {:>14.2} {:>16.2} {:>14.1}",
            g.system.label(),
            g.rate,
            g.summary.ttft_p90,
            g.summary.tpot_p90 * 1e3
        );
    }
    let gain = (eco.rate / vllm.rate.max(1e-9) - 1.0) * 100.0;
    println!("\nEcoServe goodput improvement over vLLM: {gain:+.1}%");
    println!(
        "(paper Figure 8 reports an 83.76% average P90 improvement over vLLM\
         \n across the full 3-model x 3-dataset x 2-cluster grid — run\
         \n `cargo bench --bench fig8_end_to_end_goodput` for the grid)"
    );
}
