//! END-TO-END LIVE SERVING — the three-layer stack under real load.
//!
//! Loads the TinyLM HLO artifacts (L2 JAX graphs embedding the L1 Pallas
//! flash-attention kernels, AOT-compiled by `make artifacts`), stands up N
//! PJRT-CPU instance workers, and drives a Poisson request stream through
//! the live PaDG coordinator — Algorithms 1+2 routing on measured prefill
//! EMAs and saved-TPOT slack. Python is not involved at any point of this
//! binary's execution.
//!
//!     make artifacts && cargo run --release --example serve_model -- \
//!         --instances 2 --rate 3 --duration 20
//!
//! Reports TTFT/TPOT percentiles, throughput, and SLO attainment; the run
//! is recorded in EXPERIMENTS.md §E2E.

use anyhow::{bail, Result};
use ecoserve::server::{serve_poisson, ServeConfig};
use ecoserve::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let mut cfg = ServeConfig::default();
    cfg.instances = args.get_usize("instances", 2);
    cfg.rate = args.get_f64("rate", 3.0);
    cfg.duration_secs = args.get_f64("duration", 20.0);
    cfg.seed = args.get_u64("seed", 42);
    let artifacts = args.get_or("artifacts", "artifacts");
    let dir = std::path::Path::new(&artifacts);
    if !dir.join("manifest.json").exists() {
        bail!("artifacts not found at {artifacts}; run `make artifacts` first");
    }

    println!(
        "serving TinyLM on {} PJRT-CPU instance(s), Poisson {} req/s for {}s",
        cfg.instances, cfg.rate, cfg.duration_secs
    );
    println!("(compiling {} executables per instance at startup...)", 10);
    let report = serve_poisson(dir, &cfg)?;
    print!("{}", report.render());
    if !report.fatal_errors.is_empty() {
        bail!("worker errors: {:?}", report.fatal_errors);
    }
    Ok(())
}
