//! Cluster-scale simulation: one cell of the paper's Figure 8 grid with
//! all five systems side by side at a chosen request rate, plus the
//! per-system latency breakdown.
//!
//!     cargo run --release --example cluster_sim -- \
//!         --model llama-30b --cluster l20 --dataset sharegpt --rate 6
//!
//! Use `--rate` to walk the load axis yourself: at low rates everyone
//! meets SLOs; as the rate rises, the baselines drop out in the order the
//! paper predicts (FuDG first on MHA models over Ethernet, then NoDG as
//! interference bites, EcoServe last).

use anyhow::Result;
use ecoserve::config::{ClusterSpec, Deployment, ExperimentConfig, SystemKind};
use ecoserve::harness::run_once;
use ecoserve::perfmodel::ModelSpec;
use ecoserve::util::cli::Args;
use ecoserve::util::threads::parallel_map;
use ecoserve::workload::Dataset;

fn main() -> Result<()> {
    let args = Args::from_env();
    let model = ModelSpec::by_name(&args.get_or("model", "llama-30b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let cluster = ClusterSpec::by_name(&args.get_or("cluster", "l20"))
        .ok_or_else(|| anyhow::anyhow!("unknown cluster"))?;
    let dataset = Dataset::by_name(&args.get_or("dataset", "sharegpt"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let rate = args.get_f64("rate", 6.0);

    let deployment = Deployment::paper_default(model, cluster);
    let mut cfg = ExperimentConfig::new(deployment, dataset);
    cfg.duration = args.get_f64("duration", 180.0);
    cfg.warmup = 30.0;
    cfg.seed = args.get_u64("seed", 42);

    println!(
        "{} x{} instances (TP={}) on {} | {} @ {:.1} req/s | SLO {:.0}s/{:.0}ms",
        cfg.deployment.model.name,
        cfg.deployment.num_instances(),
        cfg.deployment.tp,
        cfg.deployment.cluster.name,
        cfg.dataset.name,
        rate,
        cfg.dataset.slo_ttft,
        cfg.dataset.slo_tpot * 1e3,
    );

    let systems: Vec<SystemKind> = SystemKind::all().to_vec();
    let rows = parallel_map(systems, 5, |kind| {
        let r = run_once(kind, &cfg, rate, None);
        (kind, r)
    });

    println!(
        "\n{:<10} {:>10} {:>9} {:>12} {:>12} {:>12} {:>10}",
        "system", "attain %", "done", "p90TTFT s", "p90TPOT ms", "tok/s", "events"
    );
    for (kind, r) in rows {
        let s = &r.summary;
        println!(
            "{:<10} {:>10.1} {:>9} {:>12.2} {:>12.1} {:>12.0} {:>10}",
            kind.label(),
            r.attainment * 100.0,
            s.count,
            s.ttft_p90,
            s.tpot_p90 * 1e3,
            s.token_throughput,
            r.events,
        );
    }
    println!(
        "\n(attain % = strict SLO attainment over requests arriving in the\
         \n measurement window; incomplete requests count as violations)"
    );
    Ok(())
}
