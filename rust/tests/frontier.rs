//! Integration tests over the goodput-frontier subsystem: the adaptive
//! rate search finds, per scenario x system, the maximum sustainable rate
//! at a target per-class attainment — and the headline claim holds on the
//! frontier, not just at a fixed operating point: PaDG's max sustainable
//! rate at P90 strictly exceeds at least one baseline's on bursty load.
//! The same run feeds `BENCH_goodput.json`, whose contract is asserted
//! end-to-end here.

use std::time::Duration;

use ecoserve::config::{ClusterSpec, Deployment, SystemKind};
use ecoserve::frontier::{frontier_to_json, run_frontier, FrontierConfig};
use ecoserve::metrics::Attainment;
use ecoserve::perfmodel::ModelSpec;
use ecoserve::scenarios::{by_name, ScenarioConfig, SCHEMA_VERSION};
use ecoserve::util::json::Json;

/// The scenario-suite bursty deployment (Llama-30B's MHA KV makes the
/// FuDG baselines transfer-bound over commodity Ethernet), quick search.
fn bursty_cfg() -> FrontierConfig {
    let mut base = ScenarioConfig::default_l20();
    base.deployment = Deployment::paper_default(
        ModelSpec::llama_30b(),
        ClusterSpec::l20_cluster(),
    );
    base.deployment.gpus_used = 32; // 8 instances at TP=4
    base.duration_override = Some(90.0);
    let mut cfg = FrontierConfig::new(base, Attainment::P90);
    cfg.quick = true;
    cfg.autoscale = true;
    cfg
}

#[test]
fn padg_frontier_dominates_a_baseline_on_bursty_load() {
    let cfg = bursty_cfg();
    let bursty = by_name("bursty").expect("bursty scenario registered");
    let fronts = run_frontier(&[bursty], &cfg, &SystemKind::all(), 8);
    assert_eq!(fronts.len(), 1);
    let f = &fronts[0];
    // 5 fixed rows + the mitosis-on PaDG variant.
    assert_eq!(f.rows.len(), 6);

    let eco = f.row(SystemKind::EcoServe, false).expect("fixed PaDG row");
    assert!(
        eco.max_rate > 0.5,
        "PaDG sustained nothing on bursty load: curve {:?}",
        eco.curve
    );
    assert!(eco.attainment >= 0.90 - 1e-9, "{}", eco.attainment);

    let beaten: Vec<(SystemKind, f64)> = f
        .rows
        .iter()
        .filter(|r| r.system != SystemKind::EcoServe)
        .filter(|r| eco.max_rate > r.max_rate + 1e-9)
        .map(|r| (r.system, r.max_rate))
        .collect();
    assert!(
        !beaten.is_empty(),
        "PaDG max rate ({:.3} req/s) strictly exceeded no baseline: {:?}",
        eco.max_rate,
        f.rows
            .iter()
            .map(|r| (r.system.label(), r.variant_label(), r.max_rate))
            .collect::<Vec<_>>()
    );

    // The mitosis-on variant starts at N_l=4 of 8 instances and must
    // still sustain a positive rate on the same frontier.
    let mito = f.row(SystemKind::EcoServe, true).expect("mitosis-on row");
    assert!(mito.max_rate > 0.0, "curve {:?}", mito.curve);
    assert!(mito.max_rate <= f.scenario.sweep.ceiling + 1e-9);

    // Every cell carries a usable attainment curve (probes can exceed the
    // curve length when a bisection mid re-visits the floor rate).
    for cell in &f.rows {
        assert!(cell.probes >= 2, "{:?}", cell.system);
        assert!(cell.probes >= cell.curve.len());
        for w in cell.curve.windows(2) {
            assert!(w[0].rate < w[1].rate, "curve must be rate-sorted");
        }
    }

    // BENCH_goodput.json contract, end to end on real results.
    let wire = frontier_to_json(&fronts, &cfg, Duration::from_secs(1)).to_string();
    let parsed = Json::parse(&wire).expect("BENCH report must be valid JSON");
    assert_eq!(
        parsed.get("bench").unwrap().as_str(),
        Some("ecoserve-goodput-frontier")
    );
    assert_eq!(
        parsed.get("schema_version").unwrap().as_f64(),
        Some(SCHEMA_VERSION)
    );
    assert_eq!(parsed.get("level").unwrap().as_str(), Some("P90"));
    let systems = parsed
        .path(&["scenarios"])
        .and_then(|s| s.idx(0))
        .and_then(|s| s.get("systems"))
        .and_then(|s| s.as_arr())
        .expect("scenarios[0].systems");
    assert_eq!(systems.len(), 6);
    let eco_json = systems
        .iter()
        .find(|s| {
            s.get("system").and_then(|v| v.as_str()) == Some("EcoServe")
                && s.get("autoscale").and_then(|v| v.as_bool()) == Some(false)
        })
        .expect("EcoServe fixed row in JSON");
    let wired_rate = eco_json.get("max_rate_rps").unwrap().as_f64().unwrap();
    assert!((wired_rate - eco.max_rate).abs() < 1e-9);
    assert!(!eco_json.get("curve").unwrap().as_arr().unwrap().is_empty());
}
