//! Cross-language numerics: the Rust PJRT path must produce the same
//! logits as the Python/JAX graphs it was lowered from (to f32 precision).
//! Golden values were captured from `python/compile/model.py` at seed 0
//! (see EXPERIMENTS.md §E2E for the capture command).
//!
//! All tests skip (pass trivially) if `make artifacts` has not run.

use std::path::PathBuf;

use ecoserve::runtime::engine::{argmax, Engine};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("artifacts not built; skipping");
        None
    }
}

/// Python: prefill(cfg, pad([1..10], 16), 10, init_weights(cfg, 0)) gives
/// logits[0, :5] = [0.2025345, 1.5216597, 0.2671740, 0.5129205, 0.3006005].
#[test]
fn prefill_logits_match_jax_golden() {
    let Some(dir) = artifacts() else { return };
    let mut e = Engine::load(&dir, Some(4096)).unwrap();
    let prompt: Vec<u32> = (1..=10).collect();
    let out = e.prefill(1, &prompt).unwrap();
    let golden = [0.2025345f32, 1.5216597, 0.2671740, 0.5129205, 0.3006005];
    for (i, g) in golden.iter().enumerate() {
        assert!(
            (out.logits[i] - g).abs() < 2e-4,
            "logit[{i}] = {} vs jax {g}",
            out.logits[i]
        );
    }
}

/// The bucket choice must not change results (python tests assert the same
/// invariance on the JAX side).
#[test]
fn bucket_padding_invariance_in_rust() {
    let Some(dir) = artifacts() else { return };
    let mut e = Engine::load(&dir, Some(8192)).unwrap();
    let prompt: Vec<u32> = vec![3, 1, 4, 1, 5, 9, 2, 6];
    let a = e.prefill(1, &prompt).unwrap();
    // Force the next bucket by padding the prompt artificially longer and
    // comparing a fresh request at the same prompt (engine picks s16 for
    // 8 tokens; 20 tokens picks s32 — compare across engine instances).
    let t_small = argmax(&a.logits);
    e.release(1);
    // Re-run same prompt routed through the 32-bucket: construct a prompt
    // of 17+ tokens whose first 8 tokens... cannot alias; instead verify
    // determinism of the small bucket twice and the decode chain.
    let b = e.prefill(2, &prompt).unwrap();
    assert_eq!(argmax(&b.logits), t_small);
    for (x, y) in a.logits.iter().zip(b.logits.iter()) {
        assert_eq!(x, y, "prefill must be bitwise deterministic");
    }
    e.release(2);
}

/// Greedy generation through the engine matches itself across runs and
/// interleavings (continuous-batching correctness at the numerics level).
#[test]
fn generation_invariant_to_batch_composition() {
    let Some(dir) = artifacts() else { return };
    let mut e = Engine::load(&dir, Some(8192)).unwrap();

    let gen_solo = |e: &mut Engine, id: u64, prompt: &[u32], steps: usize| {
        let p = e.prefill(id, prompt).unwrap();
        let mut toks = vec![argmax(&p.logits)];
        for _ in 0..steps {
            let rows = e.decode(&[id], &[*toks.last().unwrap()]).unwrap();
            toks.push(argmax(&rows[0]));
        }
        e.release(id);
        toks
    };

    let pa: Vec<u32> = vec![10, 20, 30, 40];
    let pb: Vec<u32> = vec![7, 7, 7, 7, 7, 7];
    let solo_a = gen_solo(&mut e, 1, &pa, 4);
    let solo_b = gen_solo(&mut e, 2, &pb, 4);

    // Interleaved: both requests decode in shared batches.
    let la = e.prefill(3, &pa).unwrap();
    let lb = e.prefill(4, &pb).unwrap();
    let mut ta = vec![argmax(&la.logits)];
    let mut tb = vec![argmax(&lb.logits)];
    for _ in 0..4 {
        let rows = e.decode(&[3, 4], &[*ta.last().unwrap(), *tb.last().unwrap()]).unwrap();
        ta.push(argmax(&rows[0]));
        tb.push(argmax(&rows[1]));
    }
    assert_eq!(solo_a, ta, "request A diverged when batched with B");
    assert_eq!(solo_b, tb, "request B diverged when batched with A");
}

/// KV release and re-admission must not corrupt neighbouring requests.
#[test]
fn kv_reuse_after_release_is_clean() {
    let Some(dir) = artifacts() else { return };
    let mut e = Engine::load(&dir, Some(2048)).unwrap();
    let p1: Vec<u32> = vec![5, 6, 7, 8];
    let p2: Vec<u32> = vec![100, 101, 102];
    let a1 = e.prefill(1, &p1).unwrap();
    let first = argmax(&a1.logits);
    e.release(1);
    // Occupy the freed blocks with another request, then re-run request 1.
    let _ = e.prefill(2, &p2).unwrap();
    let a2 = e.prefill(3, &p1).unwrap();
    assert_eq!(argmax(&a2.logits), first);
    let rows = e.decode(&[3], &[first]).unwrap();
    assert_eq!(rows[0].len(), e.config.vocab);
}
