//! Integration tests over the trace-replay subsystem: the committed
//! fixture log drives the scenario suite and the goodput frontier
//! (including the mitosis-on PaDG variant) exactly like a synthetic
//! scenario, and the `record` exporter round-trips bit-for-bit.

use std::path::Path;
use std::time::Duration;

use ecoserve::config::SystemKind;
use ecoserve::frontier::{frontier_to_json, run_frontier, FrontierConfig};
use ecoserve::metrics::Attainment;
use ecoserve::scenarios::{by_name, run_system, Scenario, ScenarioConfig};
use ecoserve::util::json::Json;
use ecoserve::workload::ReplayTrace;

const FIXTURE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/replay_mixed.jsonl");

#[test]
fn fixture_log_parses_with_header_classes_and_native_rate() {
    let scenario = Scenario::from_log(Path::new(FIXTURE)).expect("committed fixture parses");
    assert!(scenario.is_replay());
    assert!(scenario.name.starts_with("replay:"), "{}", scenario.name);
    let trace = scenario.replay().unwrap();
    assert_eq!(trace.duration(), 60.0);
    assert_eq!(trace.warmup(), 6.0);
    assert!(trace.len() > 150, "{}", trace.len());
    // Header class table with per-class SLO datasets.
    assert_eq!(scenario.classes.len(), 2);
    assert_eq!(scenario.classes[0].name, "interactive");
    assert_eq!(scenario.classes[0].dataset.name, "Alpaca-gpt4");
    assert_eq!(scenario.classes[1].name, "batch");
    assert_eq!(scenario.classes[1].dataset.name, "LongBench");
    // Interactive dominates the mix and the shares sum to 1.
    let share: f64 = scenario.classes.iter().map(|c| c.share).sum();
    assert!((share - 1.0).abs() < 1e-9);
    assert!(scenario.classes[0].share > scenario.classes[1].share);
    // The nominal rate is the log's own offered rate.
    assert!((scenario.default_rate - trace.native_rate()).abs() < 1e-12);
    assert!(trace.native_rate() > 3.0 && trace.native_rate() < 5.0);
    // Sorted arrivals, replay-order ids.
    let reqs = scenario.build_trace(0, scenario.default_rate);
    assert_eq!(reqs.len(), trace.len());
    for w in reqs.windows(2) {
        assert!(w[0].arrival <= w[1].arrival && w[0].id < w[1].id);
    }
}

#[test]
fn fixture_replay_runs_through_the_scenario_suite() {
    let scenario = Scenario::from_log(Path::new(FIXTURE)).unwrap();
    let mut cfg = ScenarioConfig::default_l20();
    cfg.deployment.gpus_used = 16; // 4 instances — fast test
    let row = run_system(&scenario, &cfg, SystemKind::EcoServe);
    assert!(row.arrived > 100, "{}", row.arrived);
    assert!(row.completed > 0);
    assert_eq!(row.classes.len(), 2);
    // Per-class arrivals must equal the log's class mix inside the
    // scoring window — the class_of side-table contract, end to end.
    let trace = scenario.replay().unwrap();
    let (duration, warmup) = scenario.horizon_at(scenario.default_rate);
    let mut want = vec![0usize; 2];
    for rec in trace.records() {
        if rec.arrival >= warmup && rec.arrival < duration {
            want[rec.class] += 1;
        }
    }
    assert_eq!(row.classes[0].arrived, want[0]);
    assert_eq!(row.classes[1].arrived, want[1]);
    assert_eq!(row.arrived, want[0] + want[1]);
}

/// The acceptance criterion: `frontier --replay --quick` semantics — a
/// replayed log produces a frontier row set including the mitosis-on
/// PaDG variant, every cell searched through the same bracket+bisect
/// core, and the BENCH JSON carries the replay provenance block.
#[test]
fn fixture_replay_sweeps_the_frontier_with_mitosis_variant() {
    let scenario = Scenario::from_log(Path::new(FIXTURE)).unwrap();
    let mut base = ScenarioConfig::default_l20();
    base.deployment.gpus_used = 32; // 8 instances; mitosis starts at N_l=4
    let mut cfg = FrontierConfig::new(base, Attainment::P90);
    cfg.quick = true;
    cfg.autoscale = true;
    let systems = [SystemKind::EcoServe, SystemKind::Vllm];
    let fronts = run_frontier(&[scenario], &cfg, &systems, 4);
    assert_eq!(fronts.len(), 1);
    let f = &fronts[0];
    assert_eq!(f.rows.len(), 3, "2 fixed rows + the mitosis variant");

    let eco = f.row(SystemKind::EcoServe, false).expect("fixed PaDG row");
    assert!(eco.max_rate > 0.0, "curve {:?}", eco.curve);
    assert!(eco.max_rate <= f.scenario.sweep.ceiling + 1e-9);
    assert!(eco.attainment >= 0.90 - 1e-9, "{}", eco.attainment);
    assert!(!eco.classes.is_empty());

    let mito = f.row(SystemKind::EcoServe, true).expect("mitosis-on row");
    assert!(mito.max_rate > 0.0, "curve {:?}", mito.curve);
    for cell in &f.rows {
        assert!(cell.probes >= 2);
        for w in cell.curve.windows(2) {
            assert!(w[0].rate < w[1].rate, "curve must be rate-sorted");
        }
    }

    // BENCH provenance: the replay block names the log and its native
    // rate so a frontier computed from recorded traffic is identifiable.
    let wire = frontier_to_json(&fronts, &cfg, Duration::from_secs(1)).to_string();
    let parsed = Json::parse(&wire).expect("valid BENCH JSON");
    let sc = parsed.get("scenarios").unwrap().idx(0).unwrap();
    assert!(sc.get("name").unwrap().as_str().unwrap().starts_with("replay:"));
    let replay = sc.get("replay").expect("replay provenance block");
    assert_eq!(
        replay.get("source").unwrap().as_str(),
        Some("replay_mixed.jsonl")
    );
    assert!(replay.get("native_rate_rps").unwrap().as_f64().unwrap() > 3.0);
    assert_eq!(replay.get("recorded_duration_s").unwrap().as_f64(), Some(60.0));
    assert_eq!(parsed.get("autoscale_variant").unwrap().as_bool(), Some(true));
}

/// Round-trip: export a synthetic scenario with `record_log`, parse it
/// back, and the replayed trace at the native rate is the original
/// trace bit-for-bit — arrivals (to the bit), lengths, and class
/// attribution — modulo id retagging.
#[test]
fn record_then_replay_round_trips_bit_for_bit() {
    let synthetic = by_name("mixed-slo").unwrap();
    let (seed, rate) = (42, 6.0);
    let log = synthetic.record_log(seed, rate);
    let replayed = Scenario::from_replay(ReplayTrace::parse_named(&log, "roundtrip").unwrap());

    let original = synthetic.build_trace(seed, rate);
    let replay = replayed.build_trace(7, replayed.default_rate); // seed is ignored
    assert_eq!(original.len(), replay.len(), "request count must survive the round trip");
    for (a, b) in original.iter().zip(&replay) {
        assert_eq!(
            a.arrival.to_bits(),
            b.arrival.to_bits(),
            "arrival drifted through the wire format: {} vs {}",
            a.arrival,
            b.arrival
        );
        assert_eq!(a.input_len, b.input_len);
        assert_eq!(a.output_len, b.output_len);
        assert_eq!(synthetic.class_of(a.id), replayed.class_of(b.id));
    }
    // Class metadata survives too.
    assert_eq!(replayed.classes.len(), synthetic.classes.len());
    for (a, b) in synthetic.classes.iter().zip(&replayed.classes) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.dataset.name, b.dataset.name);
    }
    // The recorded horizon is the scenario's, so the native rate the
    // parser reconstructs matches the request count over that span.
    assert_eq!(replayed.duration, synthetic.duration);
    assert!(
        (replayed.default_rate - original.len() as f64 / synthetic.duration).abs() < 1e-12
    );
}

/// `--loop` on the committed fixture: tiling the 60s capture to a longer
/// horizon preserves every recorded arrival bit-for-bit inside each tile,
/// keeps the native rate and class mix, and round-trips through the wire
/// format unchanged.
#[test]
fn fixture_loop_tiles_round_trip_through_the_wire_format() {
    let base = ReplayTrace::from_file(Path::new(FIXTURE)).unwrap();
    let tiled = base.loop_to(170.0); // 60s capture -> 3 tiles
    assert_eq!(tiled.duration(), 180.0);
    assert_eq!(tiled.len(), 3 * base.len());
    assert!((tiled.native_rate() - base.native_rate()).abs() < 1e-12);
    assert_eq!(tiled.warmup(), base.warmup());
    let counts = base.class_counts();
    assert_eq!(
        tiled.class_counts(),
        counts.iter().map(|&c| 3 * c).collect::<Vec<_>>()
    );
    // Tile k is the capture shifted by k·60s, arrivals bit-for-bit where
    // the shift is exact, classes and lengths always.
    for (i, rec) in tiled.records().iter().enumerate() {
        let src = &base.records()[i % base.len()];
        let shift = (i / base.len()) as f64 * base.duration();
        assert_eq!(rec.arrival.to_bits(), (src.arrival + shift).to_bits());
        assert_eq!(rec.input_len, src.input_len);
        assert_eq!(rec.output_len, src.output_len);
        assert_eq!(rec.class, src.class);
    }
    // Wire-format round trip of the tiled log.
    let back = ReplayTrace::parse_named(&tiled.render(), "tiled").unwrap();
    assert_eq!(back.records(), tiled.records());
    assert_eq!(back.duration(), tiled.duration());

    // And the tiled log is a runnable scenario with the same class names.
    let scenario = Scenario::from_replay(tiled);
    assert_eq!(scenario.classes.len(), 2);
    assert_eq!(scenario.classes[0].name, "interactive");
    assert!((scenario.duration - 180.0).abs() < 1e-12);
    let reqs = scenario.build_trace(0, scenario.default_rate);
    assert_eq!(reqs.len(), 3 * base.len());
}

/// Time-warped probes preserve the offered-rate contract on the real
/// fixture: warping to rate r yields (about) r × window requests inside
/// the scored window, at every probe rate the frontier would visit.
#[test]
fn fixture_time_warp_hits_probe_rates() {
    let scenario = Scenario::from_log(Path::new(FIXTURE)).unwrap();
    let native = scenario.default_rate;
    for mult in [0.5, 1.0, 2.0, 4.0] {
        let rate = native * mult;
        let (duration, _) = scenario.horizon_at(rate);
        let reqs = scenario.build_trace_for(0, rate, duration);
        let offered = reqs.len() as f64 / duration;
        assert!(
            (offered - rate).abs() / rate < 0.05,
            "mult {mult}: offered {offered:.3} vs probe {rate:.3}"
        );
        // Lengths never warp.
        assert!(reqs.iter().all(|r| r.input_len >= 1 && r.output_len >= 1));
    }
}
