//! Early-abandon equivalence, end to end: running the goodput frontier
//! with doomed-probe abandonment ON must produce bit-for-bit the same
//! answers as running every probe to completion — identical max rates,
//! identical verdict at every probed rate, identical per-class scores,
//! identical `BENCH_goodput.json` (up to wall-clock fields). Only the
//! simulator *cost* may differ, and on overload probes it must shrink by
//! at least 2x.

use std::time::Duration;

use ecoserve::config::SystemKind;
use ecoserve::frontier::{frontier_to_json, run_frontier, FrontierConfig, ScenarioFrontier};
use ecoserve::metrics::Attainment;
use ecoserve::scenarios::{by_name, ScenarioConfig};
use ecoserve::util::json::Json;

fn quick_cfg(early_abandon: bool) -> FrontierConfig {
    let mut base = ScenarioConfig::default_l20();
    base.deployment.gpus_used = 16; // 4 instances — fast tests
    let mut cfg = FrontierConfig::new(base, Attainment::P90);
    cfg.quick = true;
    cfg.early_abandon = early_abandon;
    cfg
}

/// Strip every wall-clock field (the only legitimately nondeterministic
/// part of the BENCH report) so the rest can be compared as strings.
fn strip_walls(j: &mut Json) {
    match j {
        Json::Obj(m) => {
            m.remove("wall_s");
            for v in m.values_mut() {
                strip_walls(v);
            }
        }
        Json::Arr(v) => {
            for item in v.iter_mut() {
                strip_walls(item);
            }
        }
        _ => {}
    }
}

#[test]
fn frontier_answers_are_bit_identical_with_abandon_on_and_off() {
    let scenarios = vec![by_name("steady").unwrap(), by_name("bursty").unwrap()];
    let systems = [SystemKind::EcoServe, SystemKind::Vllm];
    let on_cfg = quick_cfg(true);
    let off_cfg = quick_cfg(false);
    let on: Vec<ScenarioFrontier> = run_frontier(&scenarios, &on_cfg, &systems, 4);
    let off: Vec<ScenarioFrontier> = run_frontier(&scenarios, &off_cfg, &systems, 4);
    assert_eq!(on.len(), 2);
    assert_eq!(off.len(), 2);

    let mut any_abandoned = false;
    let mut any_halved = false;
    for (fa, fb) in on.iter().zip(&off) {
        assert_eq!(fa.scenario.name, fb.scenario.name);
        assert_eq!(fa.rows.len(), fb.rows.len());
        for (a, b) in fa.rows.iter().zip(&fb.rows) {
            let tag = format!("{} / {}", fa.scenario.name, a.system.label());
            assert_eq!(a.system, b.system, "{tag}");
            // The answers: max rate, saturation, probe-by-probe curve.
            assert_eq!(a.max_rate.to_bits(), b.max_rate.to_bits(), "{tag}");
            assert_eq!(a.saturated, b.saturated, "{tag}");
            assert_eq!(a.probes, b.probes, "{tag}");
            assert_eq!(a.goodput_rps.to_bits(), b.goodput_rps.to_bits(), "{tag}");
            assert_eq!(a.attainment.to_bits(), b.attainment.to_bits(), "{tag}");
            assert_eq!(a.curve.len(), b.curve.len(), "{tag}");
            for (pa, pb) in a.curve.iter().zip(&b.curve) {
                assert_eq!(pa.rate.to_bits(), pb.rate.to_bits(), "{tag}");
                assert_eq!(pa.attainment.to_bits(), pb.attainment.to_bits(), "{tag}");
                assert_eq!(pa.goodput_rps.to_bits(), pb.goodput_rps.to_bits(), "{tag}");
                // Same verdict at every probed rate.
                assert_eq!(
                    pa.attainment >= 0.90 - 1e-12,
                    pb.attainment >= 0.90 - 1e-12,
                    "{tag} verdict flipped at {} req/s",
                    pa.rate
                );
            }
            assert_eq!(a.classes.len(), b.classes.len(), "{tag}");
            for (ca, cb) in a.classes.iter().zip(&b.classes) {
                assert_eq!(ca.class, cb.class, "{tag}");
                assert_eq!(ca.arrived, cb.arrived, "{tag}");
                assert_eq!(ca.met, cb.met, "{tag}");
                assert_eq!(ca.attainment.to_bits(), cb.attainment.to_bits(), "{tag}");
            }
            // The cost: abandonment must only ever shrink it.
            assert_eq!(b.perf.abandoned_probes, 0, "{tag}: off mode never aborts");
            assert_eq!(b.perf.events_saved, 0, "{tag}");
            assert!(a.perf.events <= b.perf.events, "{tag}");
            if a.perf.abandoned_probes > 0 {
                any_abandoned = true;
                // Events the full run spent on the probes the fast run
                // abandoned (passing probes are identical in both runs).
                let passing = a.perf.events - a.perf.abandoned_events;
                let off_on_failing = b.perf.events - passing;
                if a.perf.abandoned_events * 2 <= off_on_failing {
                    any_halved = true;
                }
            }
        }
    }
    assert!(any_abandoned, "no probe abandoned across 2 scenarios x 2 systems");
    assert!(
        any_halved,
        "abandonment never halved the event count on overload probes"
    );

    // BENCH_goodput.json, the shipped artifact, is identical up to wall
    // clocks.
    let mut ja = frontier_to_json(&on, &on_cfg, Duration::from_secs(1));
    let mut jb = frontier_to_json(&off, &off_cfg, Duration::from_secs(1));
    strip_walls(&mut ja);
    strip_walls(&mut jb);
    assert_eq!(ja.to_string(), jb.to_string());
}
