//! Docs-freshness gate: the committed documentation must match the code
//! it documents. `docs/CLI.md` embeds each subcommand's generated
//! `--help` verbatim, so this test re-renders every help text from the
//! live `COMMANDS` table and fails on any drift — adding a flag without
//! documenting it, or editing help text without regenerating the docs.
//! CI runs this as its docs step.

use ecoserve::scenarios::SCHEMA_VERSION;
use ecoserve::util::cli::COMMANDS;

fn read_doc(rel: &str) -> String {
    let path = format!("{}/../{rel}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{rel} unreadable: {e}"))
}

#[test]
fn cli_reference_contains_every_generated_help_text_verbatim() {
    let md = read_doc("docs/CLI.md");
    for spec in COMMANDS {
        let help = spec.help_text();
        assert!(
            md.contains(&help),
            "docs/CLI.md is stale for '{}': it must contain the generated \
             --help output verbatim. Expected block:\n{}",
            spec.name,
            help
        );
    }
}

#[test]
fn cli_reference_lists_every_registered_flag() {
    let md = read_doc("docs/CLI.md");
    for spec in COMMANDS {
        assert!(
            md.contains(&format!("## {}", spec.name)),
            "docs/CLI.md lost the '{}' section",
            spec.name
        );
        for f in spec.flags {
            assert!(
                md.contains(&format!("--{}", f.name)),
                "docs/CLI.md does not list --{} ({})",
                f.name,
                spec.name
            );
        }
    }
}

#[test]
fn bench_doc_covers_every_artifact_and_the_schema_version() {
    let md = read_doc("docs/BENCH.md");
    for bench in [
        "ecoserve-scenarios",
        "ecoserve-goodput-frontier",
        "ecoserve-simperf",
        "ecoserve-plan",
        "ecoserve-churn",
        "ecoserve-overload",
        "ecoserve-trace",
    ] {
        assert!(md.contains(bench), "docs/BENCH.md lost artifact {bench}");
    }
    // The version the docs quote must be the one the code emits.
    assert!(
        md.contains(&format!("`{SCHEMA_VERSION}`")),
        "docs/BENCH.md quotes a stale schema_version (code says {SCHEMA_VERSION})"
    );
    // The regression-gate baseline the docs point at must exist.
    assert!(md.contains("rust/ci/simperf_baseline.json"));
    let baseline = read_doc("rust/ci/simperf_baseline.json");
    assert!(
        baseline.contains("events_per_sec") && baseline.contains("tolerance"),
        "simperf baseline lost its gate fields"
    );
}

#[test]
fn readme_points_at_the_docs() {
    let md = read_doc("README.md");
    for doc in [
        "docs/ARCHITECTURE.md",
        "docs/CLI.md",
        "docs/BENCH.md",
        "docs/OBSERVABILITY.md",
    ] {
        assert!(md.contains(doc), "README.md does not link {doc}");
    }
}

#[test]
fn observability_doc_covers_the_recorder_surface() {
    let md = read_doc("docs/OBSERVABILITY.md");
    // The artifact name, the flag that produces it, and each derived
    // diagnostic family must be documented by name.
    for needle in [
        "ecoserve-trace",
        "--trace-out",
        "max_prefill_gap_s",
        "phase_overlap_frac",
        "miss_attribution",
        "perfetto",
    ] {
        assert!(md.contains(needle), "docs/OBSERVABILITY.md lost '{needle}'");
    }
    assert!(
        md.contains("schema_version"),
        "docs/OBSERVABILITY.md must tie the artifact to the shared schema version"
    );
}

#[test]
fn architecture_doc_pins_the_recorder_invariants() {
    let md = read_doc("docs/ARCHITECTURE.md");
    // The two new rows of the bit-identity invariant table.
    assert!(
        md.contains("Recorder off"),
        "docs/ARCHITECTURE.md lost the recorder-off invariant row"
    );
    assert!(
        md.contains("Trace determinism"),
        "docs/ARCHITECTURE.md lost the trace-determinism invariant row"
    );
    assert!(md.contains("rust/tests/trace.rs"));
}
