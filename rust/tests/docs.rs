//! Docs-freshness gate: the committed documentation must match the code
//! it documents. `docs/CLI.md` embeds each subcommand's generated
//! `--help` verbatim, so this test re-renders every help text from the
//! live `COMMANDS` table and fails on any drift — adding a flag without
//! documenting it, or editing help text without regenerating the docs.
//! CI runs this as its docs step.

use ecoserve::scenarios::SCHEMA_VERSION;
use ecoserve::util::cli::COMMANDS;

fn read_doc(rel: &str) -> String {
    let path = format!("{}/../{rel}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{rel} unreadable: {e}"))
}

#[test]
fn cli_reference_contains_every_generated_help_text_verbatim() {
    let md = read_doc("docs/CLI.md");
    for spec in COMMANDS {
        let help = spec.help_text();
        assert!(
            md.contains(&help),
            "docs/CLI.md is stale for '{}': it must contain the generated \
             --help output verbatim. Expected block:\n{}",
            spec.name,
            help
        );
    }
}

#[test]
fn cli_reference_lists_every_registered_flag() {
    let md = read_doc("docs/CLI.md");
    for spec in COMMANDS {
        assert!(
            md.contains(&format!("## {}", spec.name)),
            "docs/CLI.md lost the '{}' section",
            spec.name
        );
        for f in spec.flags {
            assert!(
                md.contains(&format!("--{}", f.name)),
                "docs/CLI.md does not list --{} ({})",
                f.name,
                spec.name
            );
        }
    }
}

#[test]
fn bench_doc_covers_every_artifact_and_the_schema_version() {
    let md = read_doc("docs/BENCH.md");
    for bench in [
        "ecoserve-scenarios",
        "ecoserve-goodput-frontier",
        "ecoserve-simperf",
        "ecoserve-plan",
        "ecoserve-churn",
        "ecoserve-overload",
    ] {
        assert!(md.contains(bench), "docs/BENCH.md lost artifact {bench}");
    }
    // The version the docs quote must be the one the code emits.
    assert!(
        md.contains(&format!("`{SCHEMA_VERSION}`")),
        "docs/BENCH.md quotes a stale schema_version (code says {SCHEMA_VERSION})"
    );
    // The regression-gate baseline the docs point at must exist.
    assert!(md.contains("rust/ci/simperf_baseline.json"));
    let baseline = read_doc("rust/ci/simperf_baseline.json");
    assert!(
        baseline.contains("events_per_sec") && baseline.contains("tolerance"),
        "simperf baseline lost its gate fields"
    );
}

#[test]
fn readme_points_at_the_docs() {
    let md = read_doc("README.md");
    for doc in ["docs/ARCHITECTURE.md", "docs/CLI.md", "docs/BENCH.md"] {
        assert!(md.contains(doc), "README.md does not link {doc}");
    }
}
