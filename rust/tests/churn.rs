//! Churn acceptance locks: recovery must *pay* under faults, and fault
//! injection must be as deterministic as the fault-free simulator.
//!
//! Two contracts are pinned here, both on fixed fault seeds:
//!
//! 1. **Recovery earns its keep** (`surge+preemption`, 4 instances): the
//!    recovery-on PaDG coordinator delivers strictly more SLO-meeting
//!    work than (a) its own `ablate_no_recovery` ablation on the exact
//!    same trace and fault timeline, and (b) the vLLM baseline's native
//!    fault handling in the same churn cell.
//! 2. **Bit-identical churn**: the same fault seed yields the same fault
//!    timeline, the same per-request records under both engine variants
//!    (`run_faulted` vs. `reference_run_faulted`), and a byte-identical
//!    `BENCH_churn.json` across independent suite runs.

use std::time::Duration;

use ecoserve::config::{SystemKind, SystemParams};
use ecoserve::coordinator::EcoServeSystem;
use ecoserve::metrics::{Collector, SloSpec};
use ecoserve::scenarios::{by_name, churn_to_json, run_churn_suite, run_system, ScenarioConfig};
use ecoserve::sim::{reference_run_faulted, run_faulted, FaultEvent, FaultSchedule};

/// 4 instances (16 L20 GPUs) — small enough for test wall time, large
/// enough that losing one instance removes a quarter of the fleet.
fn churn_cfg(duration: f64, rate: f64) -> ScenarioConfig {
    let mut cfg = ScenarioConfig::default_l20();
    cfg.deployment.gpus_used = 16;
    cfg.duration_override = Some(duration);
    cfg.rate = Some(rate);
    cfg.fault_seed = Some(7);
    cfg
}

/// Expand a scenario's churn profile exactly the way the driver does.
fn timeline(
    scenario: &ecoserve::scenarios::Scenario,
    cfg: &ScenarioConfig,
) -> Vec<(f64, FaultEvent)> {
    let (duration, warmup) = cfg.horizon(scenario);
    let schedule = FaultSchedule::generate(
        scenario.churn.as_ref().expect("churn scenario"),
        cfg.fault_seed.unwrap(),
        duration,
        warmup,
        cfg.deployment.num_instances(),
    );
    schedule.events(&cfg.deployment)
}

/// The ISSUE acceptance criterion: under `surge+preemption` at a fixed
/// rate and fault seed, recovery-on PaDG strictly beats both the vLLM
/// baseline and its own no-recovery ablation on delivered goodput.
#[test]
fn recovery_beats_the_baseline_and_its_own_ablation_under_preemption() {
    let s = by_name("surge+preemption").unwrap();
    let cfg = churn_cfg(90.0, 3.5);
    let (duration, warmup) = cfg.horizon(&s);
    let trace = s.build_trace_for(cfg.seed, cfg.rate.unwrap(), duration);
    let events = timeline(&s, &cfg);
    assert!(
        events.iter().any(|(_, e)| matches!(e, FaultEvent::InstanceDown { .. })),
        "the window must contain at least one preemption outage: {events:?}"
    );

    let sched = s.scheduler_dataset();
    let slo = SloSpec::new(sched.slo_ttft, sched.slo_tpot);
    let horizon = duration + 240.0;
    // Same trace, same fault timeline, one knob: does the coordinator
    // react to faults (re-route, health-gate, backfill) or not.
    let met_with = |params: SystemParams| {
        let mut sys = EcoServeSystem::new(&cfg.deployment, slo, params);
        let mut metrics = Collector::new();
        run_faulted(&mut sys, trace.clone(), &events, horizon, &mut metrics, false);
        metrics.window_records(warmup, duration).filter(|r| r.meets(&slo)).count()
    };
    let recovered = met_with(SystemParams::default());
    let ablated =
        met_with(SystemParams { ablate_no_recovery: true, ..SystemParams::default() });
    assert!(
        recovered > ablated,
        "recovery must strictly beat the ablation: {recovered} vs {ablated}"
    );

    // The baseline comparison runs through the public scenario surface —
    // the same cell a `--fault-seed` CLI run would score.
    let padg = run_system(&s, &cfg, SystemKind::EcoServe);
    let vllm = run_system(&s, &cfg, SystemKind::Vllm);
    assert!(padg.churn.is_some() && vllm.churn.is_some());
    assert!(
        padg.goodput_rps > vllm.goodput_rps,
        "PaDG recovery must strictly beat the baseline under churn: {} vs {}",
        padg.goodput_rps,
        vllm.goodput_rps
    );
}

/// Identical fault seeds are bit-identical: timeline, per-request
/// records under both engine variants, and the JSON artifact.
#[test]
fn identical_fault_seeds_are_bit_identical_across_runs_and_engines() {
    let s = by_name("steady+churn").unwrap();
    let cfg = churn_cfg(60.0, 2.0);
    let (duration, warmup) = cfg.horizon(&s);

    // The schedule itself is a pure function of (profile, seed).
    let events = timeline(&s, &cfg);
    assert_eq!(events, timeline(&s, &cfg));
    assert!(!events.is_empty());
    let mut other_seed = cfg.clone();
    other_seed.fault_seed = Some(8);
    assert_ne!(events, timeline(&s, &other_seed), "the seed must move the timeline");

    // Production heap engine vs. the reference engine: same faults, same
    // trace, bitwise-identical request records.
    let sched = s.scheduler_dataset();
    let slo = SloSpec::new(sched.slo_ttft, sched.slo_tpot);
    let trace = s.build_trace_for(cfg.seed, cfg.rate.unwrap(), duration);
    let horizon = duration + 240.0;
    let mut heap_sys = EcoServeSystem::new(&cfg.deployment, slo, SystemParams::default());
    let mut heap_metrics = Collector::new();
    run_faulted(&mut heap_sys, trace.clone(), &events, horizon, &mut heap_metrics, false);
    let mut ref_sys = EcoServeSystem::new(&cfg.deployment, slo, SystemParams::default());
    let mut ref_metrics = Collector::new();
    reference_run_faulted(&mut ref_sys, trace, &events, horizon, &mut ref_metrics);
    let heap_rows: Vec<_> = heap_metrics.window_records(warmup, duration).collect();
    let ref_rows: Vec<_> = ref_metrics.window_records(warmup, duration).collect();
    assert!(!heap_rows.is_empty());
    assert_eq!(heap_rows.len(), ref_rows.len());
    for (a, b) in heap_rows.iter().zip(&ref_rows) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.first_token.to_bits(), b.first_token.to_bits(), "req {}", a.id);
        assert_eq!(a.completion.to_bits(), b.completion.to_bits(), "req {}", a.id);
    }

    // Two independent suite runs serialize to the same bytes (wall time
    // is the caller's input, not measured inside the artifact).
    let systems = [SystemKind::EcoServe, SystemKind::Vllm];
    let first = run_churn_suite(&[s.clone()], &cfg, &systems, 4);
    let second = run_churn_suite(&[s], &cfg, &systems, 4);
    assert_eq!(
        churn_to_json(&first, &cfg, Duration::ZERO).to_string(),
        churn_to_json(&second, &cfg, Duration::ZERO).to_string()
    );
}
