//! Integration tests over the capacity planner: the acceptance claim
//! (PaDG beats at least one NoDG/FuDG config on goodput-per-dollar for
//! bursty traffic on L20 + commodity Ethernet), dominance-pruning
//! soundness (a pruned config, simulated anyway, never beats the
//! winner), roofline-ceiling soundness (no measured goodput exceeds its
//! candidate's bound), and the `BENCH_plan.json` contract on real
//! results.

use std::time::Duration;

use ecoserve::config::SystemKind;
use ecoserve::perfmodel::ModelSpec;
use ecoserve::planner::{
    enumerate_candidates, plan_to_json, run_plan_on, Candidate, CostModel, PlanConfig, PriceTier,
};
use ecoserve::scenarios::by_name;
use ecoserve::util::json::Json;

/// The paper's cost-effectiveness setting: bursty traffic, Llama-30B
/// (MHA KV makes FuDG transfer-bound over commodity Ethernet), the L20
/// cluster, 32-GPU budget.
fn bursty_plan_cfg() -> PlanConfig {
    let mut cfg = PlanConfig::quick(by_name("bursty").unwrap(), ModelSpec::llama_30b());
    cfg.max_gpus = Some(32);
    cfg
}

#[test]
fn padg_beats_a_baseline_on_goodput_per_dollar_on_bursty_l20() {
    let cfg = bursty_plan_cfg();
    // Trim the quick grid to the decisive shapes (TP4, 2 or 8 instances)
    // so the test stays affordable; the CI smoke runs the full quick set.
    let candidates: Vec<Candidate> = enumerate_candidates(&cfg)
        .into_iter()
        .filter(|c| c.deployment.tp == 4 && matches!(c.deployment.num_instances(), 2 | 8))
        .collect();
    assert_eq!(candidates.len(), 6, "2 shapes x {{PaDG, NoDG, FuDG}}");
    // Commodity interconnect only: quick mode prices the native tier.
    assert!(candidates
        .iter()
        .all(|c| c.deployment.cluster.inter_link.name == "10GbE"
            && c.deployment.cluster.intra_link.name == "PCIe4x16"));
    let outcome = run_plan_on(&cfg, candidates);
    assert_eq!(outcome.cells.len(), 6);

    // Cells are price-ordered and every measured goodput respects its
    // candidate's roofline ceiling — the fact pruning soundness rests on.
    for w in outcome.cells.windows(2) {
        assert!(
            w[0].candidate.price.total <= w[1].candidate.price.total + 1e-9,
            "cells must be price-sorted"
        );
    }
    for cell in &outcome.cells {
        if !cell.pruned() {
            assert!(
                cell.goodput_rps <= cell.candidate.roofline_ub + 1e-6,
                "{} {}: measured {} above roofline ceiling {}",
                cell.candidate.system.label(),
                cell.candidate.shape(),
                cell.goodput_rps,
                cell.candidate.roofline_ub
            );
        }
    }

    // The acceptance claim: some PaDG cell beats some NoDG/FuDG cell on
    // goodput per dollar.
    let eco_best = outcome
        .cells
        .iter()
        .filter(|c| !c.pruned() && c.candidate.system == SystemKind::EcoServe)
        .map(|c| c.value())
        .fold(0.0, f64::max);
    assert!(eco_best > 0.0, "PaDG sustained nothing on bursty load");
    let baseline_min = outcome
        .cells
        .iter()
        .filter(|c| !c.pruned() && c.candidate.system != SystemKind::EcoServe)
        .map(|c| c.value())
        .fold(f64::INFINITY, f64::min);
    assert!(
        eco_best > baseline_min + 1e-9,
        "PaDG best value {eco_best} beat no baseline (min {baseline_min}); cells: {:?}",
        outcome
            .cells
            .iter()
            .map(|c| (c.candidate.system.label(), c.candidate.shape(), c.value()))
            .collect::<Vec<_>>()
    );

    // The Pareto frontier is non-empty, price-ascending, goodput-strictly-
    // ascending, and contains the best-value cell's goodput level.
    assert!(!outcome.pareto.is_empty());
    for w in outcome.pareto.windows(2) {
        let (a, b) = (&outcome.cells[w[0]], &outcome.cells[w[1]]);
        assert!(a.candidate.price.total <= b.candidate.price.total + 1e-9);
        assert!(a.goodput_rps < b.goodput_rps);
    }
    let best = outcome.best_value.expect("a best-value cell exists");
    assert!(!outcome.cells[best].pruned());

    // BENCH_plan.json round-trips with the real results wired through.
    let wire = plan_to_json(&outcome, &cfg, Duration::from_secs(1)).to_string();
    let parsed = Json::parse(&wire).expect("BENCH_plan must be valid JSON");
    assert_eq!(parsed.get("bench").unwrap().as_str(), Some("ecoserve-plan"));
    assert_eq!(parsed.get("model").unwrap().as_str(), Some("Llama-30B"));
    assert_eq!(
        parsed.path(&["scenario", "name"]).unwrap().as_str(),
        Some("bursty")
    );
    let cands = parsed.get("candidates").unwrap().as_arr().unwrap();
    assert_eq!(cands.len(), 6);
    let wired_best = parsed.get("best_value").unwrap().as_usize().unwrap();
    assert_eq!(wired_best, best);
    let best_json = &cands[best];
    assert!(
        (best_json.get("goodput_per_dollar").unwrap().as_f64().unwrap()
            - outcome.cells[best].value())
        .abs()
            < 1e-9
    );
}

/// Dominance-pruning soundness: a config that is more expensive than a
/// measured cell already delivering its roofline ceiling is pruned
/// without simulation — and when simulated anyway, it cannot beat the
/// winner on goodput-per-dollar (here its true goodput is identical to
/// its cheap twin's, and its bill is 1000x worse; the ceiling override
/// is what makes the prune fire deterministically).
#[test]
fn pruned_configs_never_beat_the_winner_when_simulated() {
    let mut cfg = PlanConfig::quick(by_name("steady").unwrap(), ModelSpec::llama_30b());
    cfg.max_gpus = Some(16);
    cfg.duration_override = Some(40.0);
    let cost = CostModel::default();
    let scenario = cfg.scenario.clone();
    let base = |system: SystemKind, gpus: usize| {
        let mut d = ecoserve::config::Deployment::paper_default(
            ModelSpec::llama_30b(),
            ecoserve::config::ClusterSpec::l20_cluster(),
        );
        d.gpus_used = gpus;
        Candidate::new(system, d, &cost, &scenario)
    };
    // Four honest cheap candidates fill the first wave; the overpriced
    // twin (identical hardware, 1000x the bill, roofline ceiling pinned
    // below what the cheap cells certainly deliver) lands in wave two,
    // where dominance pruning sees the measured wave-one cells.
    let mut overpriced = base(SystemKind::EcoServe, 8);
    overpriced.price.total *= 1000.0;
    overpriced.price.gpu *= 1000.0;
    overpriced.roofline_ub = 0.05;
    let candidates = vec![
        base(SystemKind::EcoServe, 8),
        base(SystemKind::Vllm, 8),
        base(SystemKind::EcoServe, 16),
        base(SystemKind::Vllm, 16),
        overpriced.clone(),
    ];
    let outcome = run_plan_on(&cfg, candidates);
    assert_eq!(outcome.cells.len(), 5);
    let pruned: Vec<&ecoserve::planner::PlanCell> =
        outcome.cells.iter().filter(|c| c.pruned()).collect();
    assert_eq!(pruned.len(), 1, "exactly the overpriced twin is pruned");
    let pruned = pruned[0];
    assert!(pruned.candidate.price.total > 1000.0);
    assert_eq!(pruned.probes, 0, "pruned configs are never simulated");
    let dominator = pruned.pruned_by.expect("pruned_by points at a cell");
    let dom = &outcome.cells[dominator];
    assert!(!dom.pruned());
    assert!(dom.candidate.price.total <= pruned.candidate.price.total);

    // Simulate the pruned config anyway: same hardware as its cheap twin,
    // so the measurement succeeds — but it cannot beat the winner on
    // goodput-per-dollar, raise the Pareto frontier (its twin already
    // delivers the same goodput for 1/1000th the bill), or become the
    // cheapest cell meeting any target a cheaper cell meets.
    let forced = run_plan_on(&cfg, vec![overpriced]);
    let forced_cell = &forced.cells[0];
    assert!(!forced_cell.pruned(), "alone, nothing dominates it");
    let winner = &outcome.cells[outcome.best_value.expect("winner exists")];
    assert!(
        forced_cell.value() < winner.value(),
        "pruned config value {} must not beat the winner's {}",
        forced_cell.value(),
        winner.value()
    );
    // And it adds nothing to the Pareto frontier either: the dominator is
    // no more expensive, and its measured goodput covers the ceiling the
    // prune was justified by.
    assert!(dom.goodput_rps >= pruned.candidate.roofline_ub - 1e-9);
}

/// The spot tier prices both sides of its trade. A single-instance spot
/// box is the cheapest $/hr in the list — the GPU discount is real — but
/// its probes run under the spot reclaim churn (the lone instance is
/// preempted for 25s inside the measured window, with nowhere to reroute,
/// so ~1/4 of window arrivals blow the 5s TTFT SLO at any rate), and an
/// on-demand cell keeps the goodput-per-dollar crown.
#[test]
fn cheapest_spot_config_loses_the_crown_once_preemption_is_priced() {
    let mut cfg = PlanConfig::quick(by_name("steady").unwrap(), ModelSpec::llama_30b());
    cfg.duration_override = Some(60.0);
    let cost = CostModel::default();
    let deployment = |gpus: usize| {
        let mut d = ecoserve::config::Deployment::paper_default(
            ModelSpec::llama_30b(),
            ecoserve::config::ClusterSpec::l20_cluster(),
        );
        d.gpus_used = gpus;
        d
    };
    let candidates = vec![
        Candidate::with_tier(
            SystemKind::EcoServe,
            deployment(4),
            &cost,
            &cfg.scenario,
            PriceTier::Spot,
        ),
        Candidate::new(SystemKind::EcoServe, deployment(4), &cost, &cfg.scenario),
        Candidate::new(SystemKind::EcoServe, deployment(8), &cost, &cfg.scenario),
    ];
    let spot_total = candidates[0].price.total;
    assert!(
        candidates.iter().skip(1).all(|c| c.price.total > spot_total),
        "the spot twin must be the on-paper-cheapest config"
    );
    let outcome = run_plan_on(&cfg, candidates);
    assert_eq!(outcome.cells.len(), 3);
    assert!(outcome.cells.iter().all(|c| !c.pruned()), "one wave: nothing pruned");
    // Price-sorted, so the spot twin leads the table.
    let spot = &outcome.cells[0];
    assert_eq!(spot.candidate.tier, PriceTier::Spot);
    // The discount is real: same hardware, strictly smaller bill than its
    // on-demand twin.
    let od_twin = outcome
        .cells
        .iter()
        .find(|c| c.candidate.tier == PriceTier::OnDemand && c.candidate.deployment.gpus_used == 4)
        .expect("the on-demand twin is in the plan");
    assert!(spot.candidate.price.total < od_twin.candidate.price.total);
    assert_eq!(spot.candidate.roofline_ub, od_twin.candidate.roofline_ub);
    // But once the reclaim churn is priced into the measurement, the
    // crown goes to an on-demand cell.
    let winner = &outcome.cells[outcome.best_value.expect("a measured winner exists")];
    assert_eq!(
        winner.candidate.tier,
        PriceTier::OnDemand,
        "spot won goodput-per-dollar despite churn: spot value {} vs cells {:?}",
        spot.value(),
        outcome
            .cells
            .iter()
            .map(|c| (c.candidate.tier.label(), c.candidate.shape(), c.value()))
            .collect::<Vec<_>>()
    );
    assert!(spot.value() < winner.value());

    // The tier is stamped into BENCH_plan.json per candidate.
    let wire = plan_to_json(&outcome, &cfg, Duration::from_secs(1)).to_string();
    let parsed = Json::parse(&wire).expect("BENCH_plan must be valid JSON");
    let cands = parsed.get("candidates").unwrap().as_arr().unwrap();
    assert_eq!(cands[0].get("price_tier").unwrap().as_str(), Some("spot"));
    assert!(cands[1..]
        .iter()
        .all(|c| c.get("price_tier").unwrap().as_str() == Some("on-demand")));
}

/// More budget never yields lower best goodput: a zero per-cell budget
/// truncates every search after its mandatory first probe, and the max
/// sustainable rate it confirms — the quantity the goodput frontier is
/// built from — never exceeds the unbudgeted plan's.
#[test]
fn plan_budget_monotonicity() {
    let mut cfg = PlanConfig::quick(by_name("steady").unwrap(), ModelSpec::llama_30b());
    cfg.max_gpus = Some(16);
    cfg.duration_override = Some(40.0);
    let cost = CostModel::default();
    let mut d = ecoserve::config::Deployment::paper_default(
        ModelSpec::llama_30b(),
        ecoserve::config::ClusterSpec::l20_cluster(),
    );
    d.gpus_used = 16;
    let candidate = Candidate::new(SystemKind::EcoServe, d, &cost, &cfg.scenario);

    let mut tight = cfg.clone();
    tight.budget_s = Some(0.0);
    let cut = run_plan_on(&tight, vec![candidate.clone()]);
    let full = run_plan_on(&cfg, vec![candidate]);
    let (cut, full) = (&cut.cells[0], &full.cells[0]);
    assert!(cut.truncated, "zero budget must truncate");
    assert_eq!(cut.probes, 1);
    assert!(!full.truncated);
    assert!(
        cut.max_rate <= full.max_rate + 1e-9,
        "budgeted {} vs full {}",
        cut.max_rate,
        full.max_rate
    );
    // Whatever the truncated search confirmed is a real, sustained rate:
    // if the first probe passed, goodput is positive and attainment holds.
    if cut.max_rate > 0.0 {
        assert!(cut.goodput_rps > 0.0);
        assert!(cut.attainment >= 0.90 - 1e-9);
    }
}
