//! Flight-recorder acceptance locks.
//!
//! Four contracts are pinned here, all on fixed seeds:
//!
//! 1. **Recorder-off is free**: attaching the recorder never changes
//!    what the simulator computes — every scoring field of every system's
//!    row is bit-identical between a traced and an untraced run, and at
//!    the engine level the per-request records match bitwise with the
//!    sink attached or detached, under faults, on both engine variants.
//! 2. **PaDG bounds the prefill-availability gap** (the paper's rolling
//!    activation invariant, §2.3): on bursty load at the Llama-30B /
//!    32-GPU operating point, EcoServe's max arrival→first-token gap is
//!    strictly below vLLM's (NoDG: prefill queues behind decode under
//!    burst) and both FuDG systems' (MHA KV transfer congests commodity
//!    Ethernet, staging every first token).
//! 3. **Temporal disaggregation is pure**: EcoServe's phase-overlap
//!    fraction is exactly 0.0 — it never runs a mixed prefill/decode
//!    batch — while Sarathi's chunked-prefill hybrid batches put it
//!    strictly above zero.
//! 4. **Trace artifacts are deterministic**: same seed, same bytes, for
//!    both `BENCH_trace.json` and the Perfetto export — and the Perfetto
//!    document round-trips through the JSON parser.

use ecoserve::config::{SystemKind, SystemParams};
use ecoserve::coordinator::EcoServeSystem;
use ecoserve::metrics::{Collector, SloSpec};
use ecoserve::scenarios::{by_name, run_scenario, trace_suite_to_json, ScenarioConfig};
use ecoserve::sim::{reference_run_faulted, run_faulted, FaultEvent, FaultSchedule};
use ecoserve::trace::{to_perfetto, TraceEvent, TraceSink};
use ecoserve::util::json::Json;

/// 4 instances (16 L20 GPUs): small enough for test wall time.
fn quick_cfg(trace: bool) -> ScenarioConfig {
    let mut cfg = ScenarioConfig::default_l20();
    cfg.deployment.gpus_used = 16;
    cfg.duration_override = Some(60.0);
    cfg.rate = Some(2.0);
    cfg.trace = trace;
    cfg
}

/// The Llama-30B / 32-GPU / 5 req/s bursty operating point the suite's
/// headline test (`padg_beats_a_baseline_on_bursty_load`) already pins.
fn bursty_cfg() -> ScenarioConfig {
    use ecoserve::config::{ClusterSpec, Deployment};
    use ecoserve::perfmodel::ModelSpec;
    let mut cfg = ScenarioConfig::default_l20();
    cfg.deployment =
        Deployment::paper_default(ModelSpec::llama_30b(), ClusterSpec::l20_cluster());
    cfg.deployment.gpus_used = 32; // 8 instances at TP=4
    cfg.rate = Some(5.0);
    cfg.duration_override = Some(180.0);
    cfg.trace = true;
    cfg
}

/// Contract 1, suite level: for all five systems, a traced run and an
/// untraced run of the same cell agree bit-for-bit on every scoring
/// field, and only the traced run carries a capture.
#[test]
fn recorder_off_rows_are_bit_identical_to_traced_rows() {
    let scenario = by_name("bursty").unwrap();
    let off = run_scenario(&scenario, &quick_cfg(false), &SystemKind::all());
    let on = run_scenario(&scenario, &quick_cfg(true), &SystemKind::all());
    assert_eq!(off.rows.len(), 5);
    for (a, b) in off.rows.iter().zip(&on.rows) {
        assert_eq!(a.system, b.system);
        assert_eq!(a.arrived, b.arrived, "{}", a.system.label());
        assert_eq!(a.completed, b.completed, "{}", a.system.label());
        assert_eq!(a.met, b.met, "{}", a.system.label());
        assert_eq!(a.attainment.to_bits(), b.attainment.to_bits(), "{}", a.system.label());
        assert_eq!(a.goodput_rps.to_bits(), b.goodput_rps.to_bits(), "{}", a.system.label());
        assert_eq!(a.events, b.events, "{}", a.system.label());
        let (sa, sb) = (&a.summary, &b.summary);
        for (x, y) in [
            (sa.ttft_p50, sb.ttft_p50),
            (sa.ttft_p99, sb.ttft_p99),
            (sa.tpot_p50, sb.tpot_p50),
            (sa.tpot_p99, sb.tpot_p99),
            (sa.token_throughput, sb.token_throughput),
        ] {
            assert_eq!(x.to_bits(), y.to_bits(), "{}", a.system.label());
        }
        assert!(a.trace.is_none(), "{}: untraced run grew a capture", a.system.label());
        let cap = b.trace.as_ref().expect("traced run must carry a capture");
        assert!(cap.summary.events > 0, "{}: empty event log", b.system.label());
        assert!(cap.summary.requests > 0, "{}", b.system.label());
    }
}

/// Contract 1, engine level: with a fault timeline live, the recorder
/// changes nothing — records are bitwise identical with the sink
/// attached or detached, on both the production heap engine and the
/// reference engine, and the two same-engine event logs are identical.
#[test]
fn recorder_is_inert_under_faults_on_both_engines() {
    let scenario = by_name("steady+churn").unwrap();
    let mut cfg = quick_cfg(false);
    cfg.fault_seed = Some(7);
    let (duration, warmup) = cfg.horizon(&scenario);
    let schedule = FaultSchedule::generate(
        scenario.churn.as_ref().unwrap(),
        7,
        duration,
        warmup,
        cfg.deployment.num_instances(),
    );
    let events = schedule.events(&cfg.deployment);
    assert!(events.iter().any(|(_, e)| matches!(e, FaultEvent::InstanceDown { .. })));

    let sched = scenario.scheduler_dataset();
    let slo = SloSpec::new(sched.slo_ttft, sched.slo_tpot);
    let trace = scenario.build_trace_for(cfg.seed, cfg.rate.unwrap(), duration);
    let horizon = duration + 240.0;

    // (engine, sink?) → (window records, harvested event log).
    let mut run = |reference: bool, sink: bool| {
        let mut sys = EcoServeSystem::new(&cfg.deployment, slo, SystemParams::default());
        let mut metrics = Collector::new();
        if sink {
            metrics.attach_sink(TraceSink::new());
        }
        if reference {
            reference_run_faulted(&mut sys, trace.clone(), &events, horizon, &mut metrics);
        } else {
            run_faulted(&mut sys, trace.clone(), &events, horizon, &mut metrics, false);
        }
        let log: Vec<TraceEvent> =
            metrics.take_sink().map(|s| s.events().to_vec()).unwrap_or_default();
        (metrics.records_in_window(warmup, duration), log)
    };
    let (heap_off, none) = run(false, false);
    let (heap_on, heap_log) = run(false, true);
    let (ref_off, _) = run(true, false);
    let (ref_on, ref_log) = run(true, true);
    assert!(none.is_empty());
    assert!(!heap_off.is_empty());
    assert!(!heap_log.is_empty() && !ref_log.is_empty());

    for (label, a, b) in [
        ("heap on-vs-off", &heap_off, &heap_on),
        ("reference on-vs-off", &ref_off, &ref_on),
        ("heap-vs-reference traced", &heap_on, &ref_on),
    ] {
        assert_eq!(a.len(), b.len(), "{label}");
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.id, y.id, "{label}");
            assert_eq!(x.first_token.to_bits(), y.first_token.to_bits(), "{label} req {}", x.id);
            assert_eq!(x.completion.to_bits(), y.completion.to_bits(), "{label} req {}", x.id);
        }
    }
    // Same engine, same seed: the event log itself is reproducible.
    let (_, heap_log2) = run(false, true);
    assert_eq!(heap_log, heap_log2);
}

/// Contract 2: the rolling-activation gap bound, measured. EcoServe's
/// worst arrival→first-token gap stays strictly below vLLM's and both
/// FuDG systems' on bursty load at the fixed operating point.
#[test]
fn padg_bounds_the_prefill_gap_on_bursty_load() {
    let cfg = bursty_cfg();
    let scenario = by_name("bursty").unwrap();
    let outcome = run_scenario(&scenario, &cfg, &SystemKind::all());
    let gap = |kind: SystemKind| {
        let row = outcome.row(kind).expect("row");
        let s = &row.trace.as_ref().expect("traced row").summary;
        assert!(s.requests > 200, "{}: too few requests ({})", kind.label(), s.requests);
        s.max_prefill_gap_s
    };
    let eco = gap(SystemKind::EcoServe);
    for other in [SystemKind::Vllm, SystemKind::DistServe, SystemKind::MoonCake] {
        let theirs = gap(other);
        assert!(
            eco < theirs,
            "PaDG's max prefill gap ({eco:.3}s) must be strictly below {}'s ({theirs:.3}s)",
            other.label()
        );
    }
}

/// Contract 3: phase purity. PaDG never mixes phases in one batch;
/// Sarathi's chunked prefill exists to mix them.
#[test]
fn phase_overlap_is_zero_for_padg_and_positive_for_sarathi() {
    let scenario = by_name("steady").unwrap();
    let outcome = run_scenario(
        &scenario,
        &quick_cfg(true),
        &[SystemKind::EcoServe, SystemKind::Sarathi],
    );
    let frac = |kind: SystemKind| {
        let s = &outcome.row(kind).unwrap().trace.as_ref().unwrap().summary;
        assert!(s.phase_windows > 0, "{}: no phase windows", kind.label());
        s.phase_overlap_frac
    };
    assert_eq!(frac(SystemKind::EcoServe), 0.0, "PaDG ran a hybrid batch");
    assert!(frac(SystemKind::Sarathi) > 0.0, "Sarathi recorded no hybrid time");
}

/// Contract 4: same seed, same bytes — for the derived report and the
/// Perfetto export — and the Perfetto document parses.
#[test]
fn trace_artifacts_are_byte_identical_at_fixed_seed() {
    let cfg = quick_cfg(true);
    let scenario = by_name("bursty").unwrap();
    let systems = [SystemKind::EcoServe, SystemKind::Vllm];
    let render = || {
        let outcome = run_scenario(&scenario, &cfg, &systems);
        let report = trace_suite_to_json(std::slice::from_ref(&outcome), &cfg).to_string();
        let tracks: Vec<(String, Vec<TraceEvent>)> = outcome
            .rows
            .iter()
            .map(|r| {
                let label = format!("{} / {}", outcome.scenario.name, r.system.label());
                (label, r.trace.as_ref().unwrap().events.clone())
            })
            .collect();
        let borrowed: Vec<(String, &[TraceEvent])> =
            tracks.iter().map(|(l, e)| (l.clone(), e.as_slice())).collect();
        (report, to_perfetto(&borrowed).to_string())
    };
    let (report_a, perfetto_a) = render();
    let (report_b, perfetto_b) = render();
    assert_eq!(report_a, report_b, "BENCH_trace.json must be seed-deterministic");
    assert_eq!(perfetto_a, perfetto_b, "Perfetto export must be seed-deterministic");

    let doc = Json::parse(&perfetto_a).expect("Perfetto export must be valid JSON");
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(events.len() > 100, "suspiciously sparse export: {}", events.len());
    let report = Json::parse(&report_a).expect("trace report must be valid JSON");
    assert_eq!(report.get("bench").unwrap().as_str(), Some("ecoserve-trace"));
}
