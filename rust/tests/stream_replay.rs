//! Production-scale streaming replay: the trace zoo's acceptance gates.
//!
//! * A million-request multi-day log replays through the streaming path
//!   with buffering bounded by the reorder window, never the log length.
//! * The streamed and materialized import paths produce bit-identical
//!   per-request records and scores for all five systems on the
//!   committed fixtures.
//! * A streamed multi-day diurnal log drives the mitosis autoscaler up
//!   at the day peaks and back down through the night troughs.
//! * The goodput frontier consumes a streamed scenario and stamps the
//!   full import provenance into its BENCH JSON.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::time::Duration;

use ecoserve::config::{ExperimentConfig, SystemKind};
use ecoserve::frontier::{frontier_to_json, run_frontier, FrontierConfig};
use ecoserve::harness::build_system;
use ecoserve::metrics::{Attainment, Collector};
use ecoserve::scenarios::{run_system_variant, RunSpec, Scenario, ScenarioConfig};
use ecoserve::sim::{run_abandonable, run_source_faulted};
use ecoserve::util::json::Json;
use ecoserve::workload::{StreamedTrace, TraceFormat};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn temp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("ecoserve-stream-tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

const BURSTGPT_HEADER: &str =
    "Timestamp,Model,Request tokens,Response tokens,Total tokens,Log Type";

/// The headline scale gate: a 10^6-request log spanning ~2 days streams
/// end to end while the reorder buffer stays window-sized. Materializing
/// this log would hold a million `Request`s; the streaming path may only
/// ever hold the records inside the reorder window.
#[test]
fn million_request_multiday_log_replays_with_window_bounded_buffering() {
    const MILLION: usize = 1_000_000;
    const CHUNK: usize = 8; // written locally reversed to exercise the window
    const DT: f64 = 0.1728; // 10^6 arrivals span just under 48 hours

    let path = temp_path("multiday_million.csv");
    {
        let f = std::fs::File::create(&path).unwrap();
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "{BURSTGPT_HEADER}").unwrap();
        for chunk in 0..(MILLION / CHUNK) {
            for j in (0..CHUNK).rev() {
                let i = chunk * CHUNK + j;
                let (inp, out) = (60 + i % 37, 8 + i % 11);
                let kind = if i % 3 == 0 { "API log" } else { "Conversation log" };
                writeln!(w, "{:.4},ChatGPT,{inp},{out},{},{kind}", i as f64 * DT, inp + out)
                    .unwrap();
            }
        }
        w.flush().unwrap();
    }

    let st = StreamedTrace::open(&path, TraceFormat::BurstGpt, 5.0).unwrap();
    assert_eq!(st.len(), MILLION);
    assert!(st.duration() > 170_000.0, "spans {}s, wanted ~2 days", st.duration());
    assert_eq!(st.classes().len(), 2);

    // Drain the exact iterator the engine consumes, at native rate over
    // the full span.
    let mut arr = st.arrivals_at(st.native_rate(), st.duration()).unwrap();
    let mut n = 0usize;
    let mut last = f64::NEG_INFINITY;
    for req in &mut arr {
        assert!(req.arrival >= last, "request {} left the stream out of order", req.id);
        last = req.arrival;
        n += 1;
    }
    assert_eq!(n, MILLION, "every record must replay");
    let peak = arr.peak_buffered();
    // ~window x rate + one reversed chunk; a leaky implementation that
    // buffers the log shows up as 10^6 here.
    assert!(
        peak >= CHUNK && peak <= 64,
        "peak buffered {peak}: must track the reorder window, not the {MILLION}-record log"
    );
    std::fs::remove_file(&path).ok();
}

/// Streamed vs materialized, full stack: identical scores for all five
/// systems on the committed fixture, at the native rate and under a 4x
/// time-warp compression.
#[test]
fn streamed_and_materialized_replay_score_identically_for_every_system() {
    let st = StreamedTrace::open(&fixture("burstgpt_small.csv"), TraceFormat::BurstGpt, 5.0)
        .unwrap();
    let mat_scenario = Scenario::from_replay(st.materialize().unwrap());
    let str_scenario = Scenario::from_stream(st);

    for rate in [None, Some(1.6)] {
        let mut cfg = ScenarioConfig::default_l20();
        cfg.deployment.gpus_used = 16;
        cfg.rate = rate;
        for kind in SystemKind::all() {
            let spec = RunSpec::new(kind);
            let a = run_system_variant(&mat_scenario, &cfg, &spec);
            let b = run_system_variant(&str_scenario, &cfg, &spec);
            let tag = format!("{kind:?} at rate {rate:?}");
            assert_eq!(a.arrived, b.arrived, "{tag}: arrived");
            assert_eq!(a.completed, b.completed, "{tag}: completed");
            assert_eq!(a.met, b.met, "{tag}: met");
            assert_eq!(a.attainment.to_bits(), b.attainment.to_bits(), "{tag}: attainment");
            assert_eq!(a.goodput_rps.to_bits(), b.goodput_rps.to_bits(), "{tag}: goodput");
            assert_eq!(a.events, b.events, "{tag}: events");
            assert_eq!(a.events_saved, b.events_saved, "{tag}: events_saved");
            assert_eq!(a.abandoned, b.abandoned, "{tag}: abandoned");
            assert_eq!(a.classes.len(), b.classes.len(), "{tag}: class count");
            for (ca, cb) in a.classes.iter().zip(&b.classes) {
                assert_eq!(ca.class, cb.class, "{tag}");
                assert_eq!(ca.arrived, cb.arrived, "{tag}: class '{}' arrived", ca.class);
                assert_eq!(ca.met, cb.met, "{tag}: class '{}' met", ca.class);
                assert_eq!(
                    ca.attainment.to_bits(),
                    cb.attainment.to_bits(),
                    "{tag}: class '{}' attainment",
                    ca.class
                );
            }
        }
    }
}

/// Streamed vs materialized, engine level: the per-request completion
/// records — ids, lengths, first-token and completion times — are equal
/// float-for-float for every system on the Azure fixture.
#[test]
fn streamed_and_materialized_replay_produce_identical_request_records() {
    let st = StreamedTrace::open(&fixture("azure_small.csv"), TraceFormat::Azure, 5.0).unwrap();
    let scenario = Scenario::from_stream(st.clone());
    let mat = st.materialize().unwrap();
    let rate = scenario.default_rate;
    let (duration, warmup) = scenario.horizon_at(rate);
    let horizon = duration + 240.0;

    let mut cfg = ScenarioConfig::default_l20();
    cfg.deployment.gpus_used = 16;
    for kind in SystemKind::all() {
        let mut exp = ExperimentConfig::new(cfg.deployment.clone(), scenario.scheduler_dataset());
        exp.seed = cfg.seed;
        exp.duration = duration;
        exp.warmup = warmup;

        let mut sys_a = build_system(kind, &exp, None);
        let mut m_a = Collector::new();
        run_abandonable(sys_a.as_mut(), mat.requests_at(rate, duration), horizon, &mut m_a, false);

        let mut sys_b = build_system(kind, &exp, None);
        let mut m_b = Collector::new();
        let mut arr = st.arrivals_at(rate, duration).unwrap();
        run_source_faulted(sys_b.as_mut(), &mut arr, &[], horizon, &mut m_b, false);

        assert_eq!(
            m_a.completed().len(),
            m_b.completed().len(),
            "{kind:?}: completion counts diverged"
        );
        for (ra, rb) in m_a.completed().iter().zip(m_b.completed()) {
            assert_eq!(ra, rb, "{kind:?}: per-request record diverged");
        }
        assert!(!m_b.completed().is_empty(), "{kind:?}: nothing completed");
    }
}

/// A streamed two-day diurnal log (arrival gaps modulated 0.1x..2.0x
/// around the mean) replayed compressed with mitosis on: the day peaks
/// force scale-ups past the N_l start and the night troughs idle the
/// fleet back down.
#[test]
fn streamed_multiday_diurnal_log_drives_mitosis_up_and_down() {
    let path = temp_path("diurnal_2day.csv");
    {
        let f = std::fs::File::create(&path).unwrap();
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "{BURSTGPT_HEADER}").unwrap();
        let day = 86_400.0;
        let mut t = 0.0f64;
        let mut i = 0usize;
        while t < 2.0 * day {
            // Rate multiplier swings 0.1..2.0 over each day (trough at
            // the day boundaries, peak at midday), mean ~1.05.
            let mult = 1.05 - 0.95 * (2.0 * std::f64::consts::PI * t / day).cos();
            let (inp, out) = (80 + i % 61, 10 + i % 17);
            writeln!(w, "{t:.3},ChatGPT,{inp},{out},{},Conversation log", inp + out).unwrap();
            t += 120.0 / mult; // ~1 request per 2 minutes at the mean
            i += 1;
        }
        w.flush().unwrap();
    }

    let st = StreamedTrace::open(&path, TraceFormat::BurstGpt, 5.0).unwrap();
    assert!(st.len() > 1000, "generated only {} requests", st.len());
    let scenario = Scenario::from_stream(st);

    let mut cfg = ScenarioConfig::default_l20();
    cfg.deployment.gpus_used = 16; // 4 instances at TP=4; mitosis starts below that
    cfg.rate = Some(2.5); // compress ~2 days into ~10 min of sim time
    let row = run_system_variant(
        &scenario,
        &cfg,
        &RunSpec::new(SystemKind::EcoServe).autoscaled(),
    );
    assert!(row.arrived > 1000, "scored window saw only {} arrivals", row.arrived);
    let auto = row.autoscale.expect("autoscaled run reports telemetry");
    assert!(auto.scale_ups >= 1, "day peaks never scaled up: {auto:?}");
    assert!(auto.scale_downs >= 1, "night troughs never scaled down: {auto:?}");
    assert!(
        auto.peak_active >= 3 && auto.peak_active <= 4,
        "peak active outside [3, 4]: {auto:?}"
    );
    assert!(auto.final_active >= 1, "{auto:?}");
    std::fs::remove_file(&path).ok();
}

/// The frontier consumes a streamed scenario like any other and its
/// BENCH JSON carries the import provenance: source, format, lineage,
/// and the streamed flag.
#[test]
fn frontier_on_streamed_import_reports_full_provenance() {
    let st = StreamedTrace::open(&fixture("burstgpt_small.csv"), TraceFormat::BurstGpt, 5.0)
        .unwrap();
    let scenario = Scenario::from_stream(st);
    let mut base = ScenarioConfig::default_l20();
    base.deployment.gpus_used = 16;
    let mut cfg = FrontierConfig::new(base, Attainment::P90);
    cfg.quick = true;
    let fronts = run_frontier(&[scenario], &cfg, &[SystemKind::EcoServe], 2);
    assert_eq!(fronts.len(), 1);
    assert_eq!(fronts[0].rows.len(), 1);
    assert!(fronts[0].rows[0].probes >= 2);

    let wire = frontier_to_json(&fronts, &cfg, Duration::from_secs(1)).to_string();
    let parsed = Json::parse(&wire).expect("valid BENCH JSON");
    let sc = parsed.get("scenarios").unwrap().idx(0).unwrap();
    assert_eq!(sc.get("name").unwrap().as_str(), Some("replay:burstgpt_small.csv"));
    let replay = sc.get("replay").expect("replay provenance block");
    assert_eq!(replay.get("source").unwrap().as_str(), Some("burstgpt_small.csv"));
    assert_eq!(replay.get("streamed").unwrap().as_bool(), Some(true));
    assert_eq!(replay.get("format").unwrap().as_str(), Some("burstgpt"));
    assert_eq!(
        replay.get("lineage").unwrap().as_str(),
        Some("burstgpt import of 'burstgpt_small.csv' (24 requests)")
    );
    assert_eq!(replay.get("requests").unwrap().as_f64(), Some(24.0));
    assert_eq!(replay.get("recorded_duration_s").unwrap().as_f64(), Some(60.0));
}
