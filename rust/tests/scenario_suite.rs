//! Integration tests over the scenario suite: the registry is populated,
//! traces are deterministic, the JSON report honors its contract, and —
//! the headline claim — the PaDG coordinator beats at least one baseline
//! on the bursty scenario at a fixed offered rate.

use ecoserve::config::{ClusterSpec, Deployment, SystemKind};
use ecoserve::perfmodel::ModelSpec;
use ecoserve::scenarios::{
    by_name, registry, run_scenario, suite_to_json, ScenarioConfig,
};
use ecoserve::util::json::Json;

#[test]
fn registry_lists_at_least_five_scenarios() {
    let names: Vec<&str> = registry().iter().map(|s| s.name).collect();
    assert!(names.len() >= 5, "{names:?}");
    for required in ["steady", "bursty", "diurnal", "heavy-tail", "mixed-slo"] {
        assert!(names.contains(&required), "missing scenario '{required}'");
        assert!(by_name(required).is_some());
    }
}

#[test]
fn scenario_traces_are_bit_for_bit_deterministic() {
    for s in registry() {
        let a = s.build_trace(7, 3.0);
        let b = s.build_trace(7, 3.0);
        assert_eq!(a, b, "scenario '{}' trace not deterministic", s.name);
        assert!(!a.is_empty(), "scenario '{}' produced no requests", s.name);
    }
}

/// The paper's core claim transplanted to bursty load: temporal
/// disaggregation + rolling activation absorb 2.5x flash crowds that
/// break at least one baseline. Llama-30B's MHA KV (1.52 MiB/token)
/// makes the FuDG baselines transfer-bound over commodity Ethernet, and
/// the bursts squeeze the NoDG systems' prefill/decode interference, so
/// EcoServe must come out ahead of somebody at this operating point.
#[test]
fn padg_beats_a_baseline_on_bursty_load() {
    let mut cfg = ScenarioConfig::default_l20();
    cfg.deployment = Deployment::paper_default(
        ModelSpec::llama_30b(),
        ClusterSpec::l20_cluster(),
    );
    cfg.deployment.gpus_used = 32; // 8 instances at TP=4
    cfg.rate = Some(5.0);
    cfg.duration_override = Some(180.0);
    let bursty = by_name("bursty").expect("bursty scenario registered");
    let outcome = run_scenario(&bursty, &cfg, &SystemKind::all());
    assert_eq!(outcome.rows.len(), 5);

    let eco = outcome.row(SystemKind::EcoServe).expect("ecoserve row");
    assert!(
        eco.arrived > 200,
        "too few requests to be meaningful: {}",
        eco.arrived
    );
    let beaten: Vec<(SystemKind, f64)> = outcome
        .rows
        .iter()
        .filter(|r| r.system != SystemKind::EcoServe)
        .filter(|r| eco.attainment > r.attainment + 0.05)
        .map(|r| (r.system, r.attainment))
        .collect();
    assert!(
        !beaten.is_empty(),
        "EcoServe ({:.3}) beat no baseline: {:?}",
        eco.attainment,
        outcome
            .rows
            .iter()
            .map(|r| (r.system.label(), r.attainment))
            .collect::<Vec<_>>()
    );
    // Sanity on the winner itself: the bursts are sized to strain, not to
    // flatten, the PaDG coordinator.
    assert!(
        eco.attainment > 0.5,
        "EcoServe collapsed on bursty load: {:.3}",
        eco.attainment
    );
}

#[test]
fn mixed_slo_scenario_reports_per_class_attainment() {
    let mut cfg = ScenarioConfig::default_l20();
    cfg.deployment.gpus_used = 16;
    cfg.rate = Some(3.0);
    cfg.duration_override = Some(90.0);
    let mixed = by_name("mixed-slo").unwrap();
    let outcome = run_scenario(&mixed, &cfg, &[SystemKind::EcoServe]);
    let row = &outcome.rows[0];
    assert_eq!(row.classes.len(), 2);
    let names: Vec<&str> = row.classes.iter().map(|c| c.class).collect();
    assert_eq!(names, vec!["interactive", "batch"]);
    for c in &row.classes {
        assert!(c.arrived > 0, "class '{}' got no traffic", c.class);
        assert!(c.met <= c.arrived);
        assert!((0.0..=1.0).contains(&c.attainment));
    }
    assert_eq!(
        row.arrived,
        row.classes.iter().map(|c| c.arrived).sum::<usize>()
    );
}

#[test]
fn json_report_contract_holds_end_to_end() {
    let mut cfg = ScenarioConfig::default_l20();
    cfg.deployment.gpus_used = 16;
    cfg.rate = Some(2.0);
    cfg.duration_override = Some(60.0);
    let steady = by_name("steady").unwrap();
    let outcome = run_scenario(&steady, &cfg, &[SystemKind::EcoServe, SystemKind::Sarathi]);
    let wire = suite_to_json(&[outcome], &cfg).to_string();
    let parsed = Json::parse(&wire).expect("valid JSON");
    assert_eq!(parsed.path(&["suite"]).unwrap().as_str(), Some("ecoserve-scenarios"));
    let systems = parsed
        .path(&["scenarios"])
        .and_then(|s| s.idx(0))
        .and_then(|s| s.get("systems"))
        .and_then(|s| s.as_arr())
        .expect("scenarios[0].systems");
    assert_eq!(systems.len(), 2);
    for sys in systems {
        assert!(sys.path(&["ttft_s", "p50"]).is_some());
        assert!(sys.path(&["tpot_s", "p99"]).is_some());
        assert!(sys.get("goodput_rps").unwrap().as_f64().unwrap() >= 0.0);
        assert!(sys.get("attainment").unwrap().as_f64().unwrap() <= 1.0);
    }
}
