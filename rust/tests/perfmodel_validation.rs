//! Perfmodel calibration tests: the analytical substrate must reproduce
//! the paper's *measured* anchor points before any scheduling comparison
//! means anything (DESIGN.md §2).

use ecoserve::perfmodel::interconnect::{required_kv_bandwidth, LinkSpec};
use ecoserve::perfmodel::parallelism::ParallelCfg;
use ecoserve::perfmodel::{BatchTimer, GpuSpec, ModelSpec};

fn node_prefill_rate(model: ModelSpec, gpu: GpuSpec, tp: usize) -> f64 {
    let timer = BatchTimer::new(model, gpu, ParallelCfg::tp_only(tp, LinkSpec::pcie4()));
    timer.prefill_tokens_per_sec(1024) * (8 / tp) as f64
}

/// Paper Table 3 anchor points, within 20% (absolute testbed numbers
/// against an analytical model).
#[test]
fn table3_prefill_rates_within_20pct() {
    let cases = [
        (ModelSpec::llama_30b(), GpuSpec::l20(), 4, 6584.6),
        (ModelSpec::llama_30b(), GpuSpec::a800(), 2, 26189.2),
        (ModelSpec::codellama_34b(), GpuSpec::l20(), 4, 6838.92),
        (ModelSpec::codellama_34b(), GpuSpec::a800(), 2, 25978.88),
    ];
    for (model, gpu, tp, paper) in cases {
        let name = model.name;
        let got = node_prefill_rate(model, gpu.clone(), tp);
        let ratio = got / paper;
        assert!(
            (0.8..=1.25).contains(&ratio),
            "{name} on {}: {got:.0} tok/s vs paper {paper} (ratio {ratio:.2})",
            gpu.name
        );
    }
}

/// Paper Table 3 bandwidth column follows from rate × KV-per-token.
#[test]
fn table3_required_bandwidth_tracks_paper() {
    let cases = [
        (ModelSpec::llama_30b(), GpuSpec::l20(), 4, 9.796e9),
        (ModelSpec::codellama_34b(), GpuSpec::l20(), 4, 1.25e9),
    ];
    for (model, gpu, tp, paper_bw) in cases {
        let rate = node_prefill_rate(model.clone(), gpu, tp);
        let bw = required_kv_bandwidth(rate, model.kv_bytes_per_token());
        let ratio = bw / paper_bw;
        assert!((0.75..=1.3).contains(&ratio), "{}: {bw:.2e} vs {paper_bw:.2e}", model.name);
    }
}

/// §2.3 case study: "communication overhead accounts for nearly half of
/// the total execution time" for Llama-30B TP=4 on PCIe-only L20 decode.
#[test]
fn tp4_decode_comm_is_roughly_half_on_pcie() {
    let timer = BatchTimer::new(
        ModelSpec::llama_30b(),
        GpuSpec::l20(),
        ParallelCfg::tp_only(4, LinkSpec::pcie4()),
    );
    let batch = 48;
    let comm = timer.par.tp_comm_time(&timer.model, batch);
    let total = timer.decode_iter_time(batch, batch * 400);
    let frac = comm / total;
    assert!(
        (0.3..0.7).contains(&frac),
        "decode comm fraction {frac:.2} should be 'nearly half'"
    );
}

/// §2.1: prefill lands on the compute roof, decode on the memory roof.
#[test]
fn phase_regimes_match_table2() {
    for model in [ModelSpec::llama_30b(), ModelSpec::codellama_34b(), ModelSpec::qwen2_72b()] {
        for gpu in [GpuSpec::l20(), GpuSpec::a800()] {
            let balance = gpu.eff_flops() / gpu.eff_bw();
            let prefill_ai = model.prefill_flops(1024) / model.prefill_bytes(1024);
            let decode_ai = (32.0 * 2.0 * model.param_count())
                / model.decode_iter_bytes(32, 32 * 400);
            assert!(prefill_ai > balance, "{} prefill not compute-bound on {}",
                    model.name, gpu.name);
            assert!(decode_ai < balance, "{} decode not memory-bound on {}",
                    model.name, gpu.name);
        }
    }
}

/// Table 3's conclusion: MHA KV egress outruns 10GbE by ~an order of
/// magnitude; GQA fits in a 25G-RoCE-class link.
#[test]
fn fudg_feasibility_thresholds() {
    let mha_rate = node_prefill_rate(ModelSpec::llama_30b(), GpuSpec::l20(), 4);
    let mha_bw = required_kv_bandwidth(mha_rate, ModelSpec::llama_30b().kv_bytes_per_token());
    assert!(mha_bw > 5.0 * LinkSpec::eth_10g().bandwidth);

    let gqa_rate = node_prefill_rate(ModelSpec::codellama_34b(), GpuSpec::l20(), 4);
    let gqa_bw = required_kv_bandwidth(gqa_rate, ModelSpec::codellama_34b().kv_bytes_per_token());
    assert!(gqa_bw < 2.0 * LinkSpec::eth_10g().bandwidth);
    assert!(gqa_bw < LinkSpec::roce_25g().bandwidth);
}

/// A800 vs L20: compute scales faster (~3.3x) than the cluster's network
/// upgrade (2.5x), so FuDG gets *worse* on the better GPUs (§4.2,
/// "Comparison Across Clusters").
#[test]
fn a800_widen_the_fudg_gap() {
    let l20 = node_prefill_rate(ModelSpec::llama_30b(), GpuSpec::l20(), 4);
    let a800 = node_prefill_rate(ModelSpec::llama_30b(), GpuSpec::a800(), 2);
    let compute_scale = a800 / l20;
    let bw_scale = LinkSpec::roce_25g().bandwidth / LinkSpec::eth_10g().bandwidth;
    assert!(
        compute_scale > bw_scale,
        "compute scale {compute_scale:.2} must exceed network scale {bw_scale:.2}"
    );
}

/// PP hand-offs are orders cheaper than TP all-reduces over PCIe (§2.3).
#[test]
fn pp_comm_cheaper_than_tp() {
    let model = ModelSpec::codellama_34b();
    let tp = ParallelCfg::tp_only(4, LinkSpec::pcie4());
    let pp = ParallelCfg {
        tp: 1,
        pp: 4,
        tp_link: LinkSpec::pcie4(),
        pp_link: LinkSpec::pcie4(),
    };
    assert!(pp.pp_comm_time(&model, 64) < tp.tp_comm_time(&model, 64) / 10.0);
}
