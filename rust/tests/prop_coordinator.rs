//! Property-based tests over coordinator invariants (routing, batching,
//! mitosis, serialization) using the in-tree harness (`testing::prop`).

use ecoserve::config::{ClusterSpec, Deployment, SystemParams};
use ecoserve::coordinator::constraints::{check_constraints, ConstraintVerdict};
use ecoserve::coordinator::mitosis::MitosisState;
use ecoserve::coordinator::proxy::InstanceHandler;
use ecoserve::coordinator::routing::{route, RouteOutcome, RoutingState};
use ecoserve::coordinator::EcoServeSystem;
use ecoserve::metrics::{Collector, SloSpec};
use ecoserve::perfmodel::ModelSpec;
use ecoserve::prop_assert;
use ecoserve::sim::{run, SimInstance};
use ecoserve::testing::prop::{check, Gen};
use ecoserve::workload::{Dataset, Request, TraceGenerator};

fn deployment() -> Deployment {
    let mut d =
        Deployment::paper_default(ModelSpec::codellama_34b(), ClusterSpec::l20_cluster());
    d.gpus_used = 16;
    d
}

#[test]
fn prop_mitosis_invariants_under_random_ops() {
    check("mitosis-random-ops", 200, |g: &mut Gen| {
        let n_l = g.usize(1, 6);
        let n_u = g.usize(n_l, n_l + 12);
        let mut s = MitosisState::new(n_l, n_u);
        let mut next_id = 0usize;
        let mut live = 0usize;
        for _ in 0..g.usize(1, 60) {
            if live == 0 || g.bool() {
                s.add_instance(next_id);
                next_id += 1;
                live += 1;
            } else {
                let (_, _) = s.remove_instance().expect("non-empty");
                live -= 1;
            }
            s.check_invariants().map_err(|e| e)?;
            prop_assert!(
                s.total_instances() == live,
                "count {} != live {live}",
                s.total_instances()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_mitosis_split_only_at_upper_bound() {
    check("mitosis-split-bound", 100, |g: &mut Gen| {
        let n_l = g.usize(2, 4);
        let n_u = g.usize(n_l + 1, n_l + 8);
        let mut s = MitosisState::new(n_l, n_u);
        for id in 0..g.usize(1, 40) {
            let before_macros = s.macros.len();
            let ops = s.add_instance(id);
            let split = ops.iter().any(|o| {
                matches!(o, ecoserve::coordinator::mitosis::ScaleOp::Split { .. })
            });
            if split {
                prop_assert!(
                    s.macros.len() == before_macros + 1,
                    "split must create exactly one macro"
                );
                // A split-off macro holds exactly N_l members.
                prop_assert!(s.macros.last().unwrap().len() == n_l
                    || s.macros.iter().any(|m| m.len() == n_l));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_routing_admits_only_satisfying_instances() {
    let d = deployment();
    check("routing-admission-sound", 60, |g: &mut Gen| {
        let n = g.usize(1, 6);
        let mut instances: Vec<SimInstance> = (0..n)
            .map(|i| SimInstance::new(i, d.timer(), 0.1))
            .collect();
        // Random pre-load.
        for inst in &mut instances {
            inst.kv_used = g.usize(0, inst.kv_capacity);
        }
        let slo = SloSpec::new(g.f64(0.5, 10.0), 0.1);
        let req = Request {
            id: 1,
            arrival: 0.0,
            input_len: g.usize(1, 4096),
            output_len: g.usize(1, 512),
        };
        let members: Vec<usize> = (0..n).collect();
        let mut st = RoutingState { last: g.usize(0, n - 1), ..Default::default() };
        let budget = slo.ttft / n as f64;
        match route(&mut st, &members, &instances, &req, 0.0, &slo, 64) {
            RouteOutcome::Admitted(pos) => {
                let v = check_constraints(&instances[members[pos]], &req, 0.0, &slo, 64, budget);
                prop_assert!(v.ok(), "admitted instance fails Algorithm 2: {v:?}");
            }
            RouteOutcome::Deferred => {
                for &m in &members {
                    let v = check_constraints(&instances[m], &req, 0.0, &slo, 64, budget);
                    prop_assert!(
                        v != ConstraintVerdict::Satisfied,
                        "deferred although instance {m} satisfies constraints"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_simulation_conserves_kv_and_requests() {
    let d = deployment();
    check("padg-conservation", 15, |g: &mut Gen| {
        let rate = g.f64(0.5, 6.0);
        let seed = g.int(0, 1 << 30) as u64;
        let dataset = *g.pick(&[0usize, 1, 2]);
        let dataset = match dataset {
            0 => Dataset::alpaca(),
            1 => Dataset::sharegpt(),
            _ => Dataset::longbench(),
        };
        let slo = SloSpec::new(dataset.slo_ttft, dataset.slo_tpot);
        let mut sys = EcoServeSystem::new(&d, slo, SystemParams::default());
        let trace = TraceGenerator::new(dataset, seed).poisson(rate, 40.0);
        let n = trace.len();
        let mut m = Collector::new();
        run(&mut sys, trace, 5_000.0, &mut m);
        prop_assert!(m.completed().len() == n, "completed {} of {n}", m.completed().len());
        prop_assert!(m.in_flight() == 0, "{} stuck in flight", m.in_flight());
        for inst in &sys.instances {
            prop_assert!(
                inst.kv_used == 0,
                "instance {} leaked {} KV tokens",
                inst.id,
                inst.kv_used
            );
        }
        // Sanity on every record: first <= completion, ttft >= 0.
        for r in m.completed() {
            prop_assert!(r.first_token >= r.arrival, "token before arrival");
            prop_assert!(r.completion >= r.first_token, "completion before first");
        }
        Ok(())
    });
}

#[test]
fn prop_proxy_roundtrip_any_handler() {
    check("proxy-roundtrip", 200, |g: &mut Gen| {
        let h = InstanceHandler::new(
            g.int(0, i64::MAX - 1) as u64,
            format!("host-{}:{}", g.usize(0, 255), g.usize(1024, 65535)),
            g.usize(1, 8),
            g.usize(1, 4),
            g.usize(0, 10_000_000),
        );
        let wire = h.serialize();
        let back = InstanceHandler::deserialize(&wire)
            .map_err(|e| format!("deserialize failed: {e}"))?;
        prop_assert!(back == h, "roundtrip mismatch");
        Ok(())
    });
}

#[test]
fn prop_deterministic_simulation() {
    let d = deployment();
    check("sim-determinism", 8, |g: &mut Gen| {
        let seed = g.int(0, 1 << 30) as u64;
        let rate = g.f64(1.0, 8.0);
        let run_one = || {
            let dataset = Dataset::sharegpt();
            let slo = SloSpec::new(dataset.slo_ttft, dataset.slo_tpot);
            let mut sys = EcoServeSystem::new(&d, slo, SystemParams::default());
            let trace = TraceGenerator::new(dataset, seed).poisson(rate, 30.0);
            let mut m = Collector::new();
            run(&mut sys, trace, 2_000.0, &mut m);
            let mut recs = m.into_records();
            recs.sort_by_key(|r| r.id);
            recs
        };
        let a = run_one();
        let b = run_one();
        prop_assert!(a == b, "same seed produced different histories");
        Ok(())
    });
}
