//! Speculative-bisection equivalence, end to end: running the goodput
//! frontier with parallel probe speculation ON (the default) must produce
//! bit-for-bit the same answers as the serial search — identical max
//! rates, identical verdict at every consumed probe, identical per-class
//! scores, identical `BENCH_goodput.json` (up to wall-clock fields). Only
//! the *executed* probe count may grow: speculation trades discarded
//! probe work for wall time, never for answers.

use std::time::Duration;

use ecoserve::config::SystemKind;
use ecoserve::frontier::{frontier_to_json, run_frontier, FrontierConfig, ScenarioFrontier};
use ecoserve::metrics::Attainment;
use ecoserve::scenarios::{by_name, ScenarioConfig};
use ecoserve::util::json::Json;

fn quick_cfg(speculate: bool) -> FrontierConfig {
    let mut base = ScenarioConfig::default_l20();
    base.deployment.gpus_used = 16; // 4 instances — fast tests
    let mut cfg = FrontierConfig::new(base, Attainment::P90);
    cfg.quick = true;
    cfg.speculate = speculate;
    cfg
}

/// Strip every wall-clock field (the only legitimately nondeterministic
/// part of the BENCH report) so the rest can be compared as strings.
fn strip_walls(j: &mut Json) {
    match j {
        Json::Obj(m) => {
            m.remove("wall_s");
            for v in m.values_mut() {
                strip_walls(v);
            }
        }
        Json::Arr(v) => {
            for item in v.iter_mut() {
                strip_walls(item);
            }
        }
        _ => {}
    }
}

#[test]
fn frontier_answers_are_bit_identical_with_speculation_on_and_off() {
    let scenarios = vec![by_name("steady").unwrap(), by_name("bursty").unwrap()];
    let systems = [SystemKind::EcoServe, SystemKind::Vllm];
    let spec_cfg = quick_cfg(true);
    let serial_cfg = quick_cfg(false);
    let spec: Vec<ScenarioFrontier> = run_frontier(&scenarios, &spec_cfg, &systems, 4);
    let serial: Vec<ScenarioFrontier> = run_frontier(&scenarios, &serial_cfg, &systems, 4);
    assert_eq!(spec.len(), 2);
    assert_eq!(serial.len(), 2);

    for (fa, fb) in spec.iter().zip(&serial) {
        assert_eq!(fa.scenario.name, fb.scenario.name);
        assert_eq!(fa.rows.len(), fb.rows.len());
        for (a, b) in fa.rows.iter().zip(&fb.rows) {
            let tag = format!("{} / {}", fa.scenario.name, a.system.label());
            assert_eq!(a.system, b.system, "{tag}");
            // The answers: max rate, saturation, probe-by-probe curve.
            assert_eq!(a.max_rate.to_bits(), b.max_rate.to_bits(), "{tag}");
            assert_eq!(a.saturated, b.saturated, "{tag}");
            assert_eq!(a.truncated, b.truncated, "{tag}");
            // Consumed probes (the search trajectory) are identical; only
            // executed probes (perf) may differ.
            assert_eq!(a.probes, b.probes, "{tag}");
            assert_eq!(a.goodput_rps.to_bits(), b.goodput_rps.to_bits(), "{tag}");
            assert_eq!(a.attainment.to_bits(), b.attainment.to_bits(), "{tag}");
            assert_eq!(a.curve.len(), b.curve.len(), "{tag}");
            for (pa, pb) in a.curve.iter().zip(&b.curve) {
                assert_eq!(pa.rate.to_bits(), pb.rate.to_bits(), "{tag}");
                assert_eq!(pa.attainment.to_bits(), pb.attainment.to_bits(), "{tag}");
                assert_eq!(pa.goodput_rps.to_bits(), pb.goodput_rps.to_bits(), "{tag}");
                // Same verdict at every consumed rate.
                assert_eq!(
                    pa.attainment >= 0.90 - 1e-12,
                    pb.attainment >= 0.90 - 1e-12,
                    "{tag} verdict flipped at {} req/s",
                    pa.rate
                );
            }
            assert_eq!(a.classes.len(), b.classes.len(), "{tag}");
            for (ca, cb) in a.classes.iter().zip(&b.classes) {
                assert_eq!(ca.class, cb.class, "{tag}");
                assert_eq!(ca.arrived, cb.arrived, "{tag}");
                assert_eq!(ca.met, cb.met, "{tag}");
                assert_eq!(ca.attainment.to_bits(), cb.attainment.to_bits(), "{tag}");
            }
            // The cost: speculation only ever *adds* discarded probe work.
            assert_eq!(b.perf.probes, b.probes, "{tag}: serial executes = consumes");
            assert!(a.perf.probes >= a.probes, "{tag}");
            assert!(a.perf.probes >= b.perf.probes, "{tag}");
            assert!(a.perf.events >= b.perf.events, "{tag}");
        }
    }

    // BENCH_goodput.json, the shipped artifact, is identical up to wall
    // clocks (it reports consumed probes, not executed ones).
    let mut ja = frontier_to_json(&spec, &spec_cfg, Duration::from_secs(1));
    let mut jb = frontier_to_json(&serial, &serial_cfg, Duration::from_secs(1));
    strip_walls(&mut ja);
    strip_walls(&mut jb);
    assert_eq!(ja.to_string(), jb.to_string());
}
