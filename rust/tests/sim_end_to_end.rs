//! Cross-system integration tests over the simulator: the paper's
//! qualitative comparisons must hold at fixed operating points (fast,
//! deterministic versions of the Figure-8 claims).

use ecoserve::config::{ClusterSpec, Deployment, ExperimentConfig, SystemKind};
use ecoserve::harness::{pick_fudg_ratio, run_once};
use ecoserve::metrics::Attainment;
use ecoserve::perfmodel::ModelSpec;
use ecoserve::workload::Dataset;

fn cfg(model: ModelSpec, dataset: Dataset, gpus: usize) -> ExperimentConfig {
    let mut d = Deployment::paper_default(model, ClusterSpec::l20_cluster());
    d.gpus_used = gpus;
    let mut cfg = ExperimentConfig::new(d, dataset);
    cfg.duration = 120.0;
    cfg.warmup = 20.0;
    cfg
}

#[test]
fn all_systems_complete_a_light_trace() {
    let cfg = cfg(ModelSpec::codellama_34b(), Dataset::sharegpt(), 16);
    for kind in SystemKind::all() {
        let r = run_once(kind, &cfg, 1.0, None);
        assert!(r.arrived > 0);
        assert!(
            r.summary.count >= (r.arrived * 95) / 100,
            "{}: only {}/{} completed",
            kind.label(),
            r.summary.count,
            r.arrived
        );
    }
}

#[test]
fn ecoserve_beats_vllm_at_interference_load() {
    // ShareGPT at a rate where prefill-decode interference bites vLLM's
    // TPOT but EcoServe still holds P90 (the core Figure-8 claim).
    let cfg = cfg(ModelSpec::llama_30b(), Dataset::sharegpt(), 32);
    let eco = run_once(SystemKind::EcoServe, &cfg, 13.0, None);
    let vllm = run_once(SystemKind::Vllm, &cfg, 13.0, None);
    assert!(
        eco.attainment > vllm.attainment,
        "EcoServe {:.3} should beat vLLM {:.3}",
        eco.attainment,
        vllm.attainment
    );
    assert!(eco.meets(Attainment::P90), "{:.3}", eco.attainment);
}

#[test]
fn ecoserve_dominates_on_longbench() {
    // Long prompts maximize interference: paper reports +202% over NoDG.
    // Operating point sits between the NoDG baselines' P90 goodput (~3.6 /
    // ~2.9, see bench_results_fig8.txt) and EcoServe's (~5.5).
    let cfg = cfg(ModelSpec::llama_30b(), Dataset::longbench(), 32);
    let eco = run_once(SystemKind::EcoServe, &cfg, 4.4, None);
    let vllm = run_once(SystemKind::Vllm, &cfg, 4.4, None);
    let sarathi = run_once(SystemKind::Sarathi, &cfg, 4.4, None);
    assert!(eco.meets(Attainment::P90), "EcoServe {:.3}", eco.attainment);
    assert!(!vllm.meets(Attainment::P90), "vLLM should fail here: {:.3}",
            vllm.attainment);
    assert!(!sarathi.meets(Attainment::P90), "Sarathi should fail here: {:.3}",
            sarathi.attainment);
}

#[test]
fn mooncake_collapses_on_mha_over_ethernet() {
    // 1.52 MiB/token KV over 10GbE: the FuDG failure mode (Table 3 / §4.2;
    // the paper's MoonCake cannot meet SLOs for Llama-30B + LongBench).
    let cfg = cfg(ModelSpec::llama_30b(), Dataset::sharegpt(), 32);
    let moon = run_once(SystemKind::MoonCake, &cfg, 4.0, Some(3));
    assert!(
        moon.attainment < 0.5,
        "MoonCake should collapse at this load: {:.3}",
        moon.attainment
    );
    let eco = run_once(SystemKind::EcoServe, &cfg, 4.0, None);
    assert!(eco.meets(Attainment::P90));
}

#[test]
fn fudg_recovers_with_gqa_kv() {
    // CodeLlama's GQA shrinks KV 8x: FuDG becomes workable at moderate
    // rates (the paper's "FuDG can match NoDG on GQA models" observation).
    let cfg = cfg(ModelSpec::codellama_34b(), Dataset::sharegpt(), 32);
    let p = pick_fudg_ratio(SystemKind::MoonCake, &cfg, 2.0);
    let moon = run_once(SystemKind::MoonCake, &cfg, 5.0, Some(p));
    assert!(
        moon.attainment > 0.8,
        "MoonCake with GQA KV should mostly hold: {:.3}",
        moon.attainment
    );
}

#[test]
fn alpaca_gap_is_small() {
    // Short prompts = little interference: NoDG ~ EcoServe (paper: +10.4%).
    let cfg = cfg(ModelSpec::codellama_34b(), Dataset::alpaca(), 16);
    let eco = run_once(SystemKind::EcoServe, &cfg, 20.0, None);
    let vllm = run_once(SystemKind::Vllm, &cfg, 20.0, None);
    assert!(eco.meets(Attainment::P90));
    assert!(vllm.meets(Attainment::P90));
}

#[test]
fn distserve_beats_mooncake_intra_node() {
    // DistServe's intra-node PCIe hops beat MoonCake's double NIC hops.
    let cfg = cfg(ModelSpec::codellama_34b(), Dataset::sharegpt(), 32);
    let dist = run_once(SystemKind::DistServe, &cfg, 6.0, Some(4));
    let moon = run_once(SystemKind::MoonCake, &cfg, 6.0, Some(4));
    assert!(
        dist.attainment >= moon.attainment,
        "DistServe {:.3} vs MoonCake {:.3}",
        dist.attainment,
        moon.attainment
    );
}

/// The arrival-cursor engine must reproduce the seed (preload-everything)
/// engine bit for bit on a golden trace, for every serving system — the
/// heap rewrite changes memory behavior, never event order.
#[test]
fn cursor_engine_reproduces_reference_engine_on_every_system() {
    use ecoserve::harness::build_system;
    use ecoserve::metrics::Collector;
    use ecoserve::sim::{reference_run, run};
    use ecoserve::workload::TraceGenerator;

    let cfg = cfg(ModelSpec::codellama_34b(), Dataset::sharegpt(), 16);
    let trace = TraceGenerator::new(cfg.dataset.clone(), 1234).poisson(6.0, 60.0);
    for kind in SystemKind::all() {
        let mut sys_a = build_system(kind, &cfg, Some(1));
        let mut sys_b = build_system(kind, &cfg, Some(1));
        let mut m_a = Collector::new();
        let mut m_b = Collector::new();
        let a = run(sys_a.as_mut(), trace.clone(), 300.0, &mut m_a);
        let b = reference_run(sys_b.as_mut(), trace.clone(), 300.0, &mut m_b);
        assert_eq!(a.events, b.events, "{}", kind.label());
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits(), "{}", kind.label());
        assert_eq!(
            m_a.completed().len(),
            m_b.completed().len(),
            "{}",
            kind.label()
        );
        for (ra, rb) in m_a.completed().iter().zip(m_b.completed()) {
            assert_eq!(ra.id, rb.id, "{}", kind.label());
            assert_eq!(
                ra.first_token.to_bits(),
                rb.first_token.to_bits(),
                "{} request {}",
                kind.label(),
                ra.id
            );
            assert_eq!(
                ra.completion.to_bits(),
                rb.completion.to_bits(),
                "{} request {}",
                kind.label(),
                ra.id
            );
            assert_eq!(ra.output_len, rb.output_len, "{}", kind.label());
        }
    }
}

#[test]
fn phase_switch_counts_padg_below_nodg() {
    use ecoserve::baselines::VllmSystem;
    use ecoserve::config::SystemParams;
    use ecoserve::coordinator::EcoServeSystem;
    use ecoserve::metrics::{Collector, SloSpec};
    use ecoserve::sim::run;
    use ecoserve::workload::TraceGenerator;

    let mut d =
        Deployment::paper_default(ModelSpec::codellama_34b(), ClusterSpec::l20_cluster());
    d.gpus_used = 16;
    let dataset = Dataset::sharegpt();
    let slo = SloSpec::new(dataset.slo_ttft, dataset.slo_tpot);
    let trace = TraceGenerator::new(dataset, 77).poisson(8.0, 120.0);

    let mut eco = EcoServeSystem::new(&d, slo, SystemParams::default());
    let mut m1 = Collector::new();
    run(&mut eco, trace.clone(), 5_000.0, &mut m1);
    let eco_switches = eco.total_switches();

    let mut vllm = VllmSystem::new(&d, SystemParams::default());
    let mut m2 = Collector::new();
    run(&mut vllm, trace, 5_000.0, &mut m2);
    let vllm_switches: u64 = vllm.instances.iter().map(|i| i.switches).sum();

    assert!(
        eco_switches < vllm_switches,
        "PaDG switches {eco_switches} should undercut NoDG {vllm_switches}"
    );
}
