//! Integration tests over the trace zoo's committed fixtures: both
//! import formats parse from disk with the documented class/SLO mapping
//! and provenance, the streamed handle mirrors the materialized trace,
//! an imported log plugs into the scenario machinery end to end, and
//! re-recording an import preserves its lineage.

use std::path::{Path, PathBuf};

use ecoserve::scenarios::Scenario;
use ecoserve::workload::import::import_trace;
use ecoserve::workload::{ReplayTrace, StreamedTrace, TraceFormat};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

#[test]
fn burstgpt_fixture_imports_with_class_mapping_and_provenance() {
    let t = import_trace(&fixture("burstgpt_small.csv"), TraceFormat::BurstGpt, 5.0).unwrap();
    assert_eq!(t.len(), 24);
    assert_eq!(t.duration(), 60.0);
    assert_eq!(t.source(), "burstgpt_small.csv");
    assert_eq!(t.lineage(), Some("burstgpt import of 'burstgpt_small.csv' (24 requests)"));
    // Log types map to classes with the documented SLO datasets.
    let names: Vec<&str> = t.classes().iter().map(|c| c.name).collect();
    assert_eq!(names, vec!["conversation", "api"]);
    assert_eq!(t.classes()[0].dataset.name, "ShareGPT");
    assert_eq!(t.classes()[1].dataset.name, "Alpaca-gpt4");
    assert_eq!(t.class_counts(), vec![16, 8]);
    // The fixture's deliberate near-miss ordering (4.2 logged after 5.1,
    // inside the 5 s window) lands sorted in the materialized records.
    let arrivals: Vec<f64> = t.records().iter().map(|r| r.arrival).collect();
    for w in arrivals.windows(2) {
        assert!(w[0] <= w[1], "{arrivals:?}");
    }
    assert_eq!(t.records()[2].arrival, 4.2);
    assert_eq!(t.records()[2].input_len, 60);
    assert_eq!(t.records()[3].arrival, 5.1);
    assert_eq!(t.records()[3].class, 1, "the 5.1s row is an API log line");
}

#[test]
fn azure_fixture_imports_single_class_with_datetime_timestamps() {
    let t = import_trace(&fixture("azure_small.csv"), TraceFormat::Azure, 5.0).unwrap();
    assert_eq!(t.len(), 16);
    assert!((t.duration() - 45.0).abs() < 1e-6, "{}", t.duration());
    let names: Vec<&str> = t.classes().iter().map(|c| c.name).collect();
    assert_eq!(names, vec!["azure-llm"]);
    assert_eq!(t.classes()[0].dataset.name, "ShareGPT");
    assert_eq!(t.class_counts(), vec![16]);
    assert_eq!(t.lineage(), Some("azure import of 'azure_small.csv' (16 requests)"));
    // 18:13:04.10 was logged after 18:13:05 — inside the window, so it
    // sorts back into place after rebasing.
    let arrivals: Vec<f64> = t.records().iter().map(|r| r.arrival).collect();
    for w in arrivals.windows(2) {
        assert!(w[0] <= w[1], "{arrivals:?}");
    }
    assert!((t.records()[2].arrival - 4.1).abs() < 1e-4, "{}", t.records()[2].arrival);
    assert_eq!(t.records()[2].input_len, 1002);
    assert_eq!(t.records()[2].output_len, 14);
}

#[test]
fn streamed_fixture_handles_mirror_the_materialized_traces() {
    for (name, format) in [
        ("burstgpt_small.csv", TraceFormat::BurstGpt),
        ("azure_small.csv", TraceFormat::Azure),
    ] {
        let st = StreamedTrace::open(&fixture(name), format, 5.0).unwrap();
        let mat = st.materialize().unwrap();
        assert_eq!(st.len(), mat.len(), "{name}");
        assert_eq!(st.duration().to_bits(), mat.duration().to_bits(), "{name}");
        assert_eq!(st.warmup().to_bits(), mat.warmup().to_bits(), "{name}");
        assert_eq!(st.native_rate().to_bits(), mat.native_rate().to_bits(), "{name}");
        assert_eq!(st.source(), mat.source(), "{name}");
        assert_eq!(Some(st.lineage()), mat.lineage(), "{name}");
        assert_eq!(st.class_counts(), mat.class_counts(), "{name}");
        for id in 0..st.len() as u64 {
            assert_eq!(st.class_of(id), mat.class_of(id), "{name} id {id}");
        }
    }
}

#[test]
fn imported_stream_becomes_a_replay_scenario() {
    let st = StreamedTrace::open(&fixture("burstgpt_small.csv"), TraceFormat::BurstGpt, 5.0)
        .unwrap();
    let s = Scenario::from_stream(st);
    assert_eq!(s.name, "replay:burstgpt_small.csv");
    assert!(s.is_replay());
    assert!(s.stream().is_some() && s.replay().is_none());
    assert_eq!(s.classes.len(), 2);
    assert!((s.classes[0].share - 16.0 / 24.0).abs() < 1e-12);
    assert!((s.classes[1].share - 8.0 / 24.0).abs() < 1e-12);
    // The API class's tighter Alpaca TTFT drives the scheduler.
    assert_eq!(s.scheduler_dataset().name, "Alpaca-gpt4");
    assert!((s.default_rate - 0.4).abs() < 1e-12);
    // Native-rate horizon: the recorded span with the /8-capped warmup.
    assert_eq!(s.horizon_at(s.default_rate), (60.0, 7.5));
    // build_trace materializes the same arrivals the stream yields —
    // seeds don't matter, the log is the randomness.
    let a = s.build_trace(1, s.default_rate);
    let b = s.build_trace(99, s.default_rate);
    assert_eq!(a, b);
    assert_eq!(a.len(), 24);
    for w in a.windows(2) {
        assert!(w[0].arrival <= w[1].arrival && w[0].id < w[1].id);
    }
}

#[test]
fn rerecording_an_imported_stream_preserves_its_lineage() {
    let st = StreamedTrace::open(&fixture("azure_small.csv"), TraceFormat::Azure, 5.0).unwrap();
    let lineage = st.lineage().to_string();
    let s = Scenario::from_stream(st);
    // `ecoserve record` on the imported scenario stamps the import
    // provenance, not a fresh "scenario ..." line.
    let log = s.record_log(0, s.default_rate);
    let header = log.lines().next().unwrap();
    assert!(header.contains("azure import of 'azure_small.csv' (16 requests)"), "{header}");
    // record → import → record: the chain never loses where the arrivals
    // actually came from.
    let t = ReplayTrace::parse_named(&log, "rerecorded.jsonl").unwrap();
    assert_eq!(t.lineage(), Some(lineage.as_str()));
    assert_eq!(t.len(), 16);
    let s2 = Scenario::from_replay(t);
    let log2 = s2.record_log(7, s2.default_rate);
    let t2 = ReplayTrace::parse_named(&log2, "again.jsonl").unwrap();
    assert_eq!(t2.lineage(), Some(lineage.as_str()));
}

#[test]
fn gzipped_fixture_imports_bit_identical_to_the_plain_file() {
    // burstgpt_small.csv.gz is the committed gzip of burstgpt_small.csv:
    // the transport must be invisible — records, classes, and span all
    // match the plain import bit for bit (only the source label keeps
    // the .gz name).
    let plain = import_trace(&fixture("burstgpt_small.csv"), TraceFormat::BurstGpt, 5.0).unwrap();
    let gz = import_trace(&fixture("burstgpt_small.csv.gz"), TraceFormat::BurstGpt, 5.0).unwrap();
    assert_eq!(gz.len(), plain.len());
    assert_eq!(gz.duration().to_bits(), plain.duration().to_bits());
    assert_eq!(gz.warmup().to_bits(), plain.warmup().to_bits());
    assert_eq!(gz.class_counts(), plain.class_counts());
    for (g, p) in gz.records().iter().zip(plain.records()) {
        assert_eq!(g.arrival.to_bits(), p.arrival.to_bits());
        assert_eq!((g.input_len, g.output_len, g.class), (p.input_len, p.output_len, p.class));
    }
    assert_eq!(gz.source(), "burstgpt_small.csv.gz");
    assert_eq!(
        gz.lineage(),
        Some("burstgpt import of 'burstgpt_small.csv.gz' (24 requests)")
    );
}

#[test]
fn gzipped_fixture_streams_bit_identical_to_its_materialized_import() {
    let st =
        StreamedTrace::open(&fixture("burstgpt_small.csv.gz"), TraceFormat::BurstGpt, 5.0)
            .unwrap();
    let mat = st.materialize().unwrap();
    assert_eq!(st.len(), mat.len());
    assert_eq!(st.duration().to_bits(), mat.duration().to_bits());
    assert_eq!(st.class_counts(), mat.class_counts());
    let rate = st.native_rate();
    let want = mat.requests_at(rate, f64::INFINITY);
    let mut arr = st.arrivals_at(rate, f64::INFINITY).unwrap();
    let got: Vec<_> = (&mut arr).collect();
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.id, w.id);
        assert_eq!(g.arrival.to_bits(), w.arrival.to_bits());
        assert_eq!((g.input_len, g.output_len), (w.input_len, w.output_len));
    }
}

#[test]
fn corrupt_gzip_fails_loudly_on_both_paths() {
    let dir = std::env::temp_dir().join("ecoserve-import-integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mangled.csv.gz");
    let mut bytes = std::fs::read(fixture("burstgpt_small.csv.gz")).unwrap();
    let n = bytes.len();
    bytes[n / 2] ^= 0xff; // flip a payload byte mid-stream
    std::fs::write(&path, &bytes).unwrap();
    let e = format!("{:#}", import_trace(&path, TraceFormat::BurstGpt, 5.0).unwrap_err());
    assert!(e.contains("mangled.csv.gz"), "{e}");
    let e = format!(
        "{:#}",
        StreamedTrace::open(&path, TraceFormat::BurstGpt, 5.0).unwrap_err()
    );
    assert!(e.contains("mangled.csv.gz"), "{e}");
}

#[test]
fn corrupt_files_fail_with_file_and_line() {
    let dir = std::env::temp_dir().join("ecoserve-import-integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("truncated.csv");
    std::fs::write(
        &path,
        "Timestamp,Model,Request tokens,Response tokens,Total tokens,Log Type\n\
         1.0,ChatGPT,100,50,150,Conversation log\n\
         2.0,ChatGPT,oops,50,150,Conversation log\n",
    )
    .unwrap();
    // Both consumption paths reject the same row with the same location.
    let e = format!("{:#}", import_trace(&path, TraceFormat::BurstGpt, 5.0).unwrap_err());
    assert!(e.contains("truncated.csv:3"), "{e}");
    let e = format!(
        "{:#}",
        StreamedTrace::open(&path, TraceFormat::BurstGpt, 5.0).unwrap_err()
    );
    assert!(e.contains("truncated.csv:3"), "{e}");
    // A format mismatch fails on line 1, before any rows are consumed.
    let e = format!(
        "{:#}",
        import_trace(&fixture("burstgpt_small.csv"), TraceFormat::Azure, 5.0).unwrap_err()
    );
    assert!(e.contains("burstgpt_small.csv:1"), "{e}");
}
