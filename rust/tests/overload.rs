//! Overload acceptance locks: the closed loop must make undefended
//! systems collapse, the defenses must pay for themselves, and with the
//! client switched off the whole machinery must vanish without a trace.
//!
//! Three contracts are pinned here, all on fixed seeds:
//!
//! 1. **Undefended collapse** (`retry-storm`, 4 instances): the vLLM
//!    baseline with a closed-loop client but no defenses delivers
//!    strictly *less* goodput at 2× saturation than at 1× — retries
//!    amplify the offered load and servers burn capacity on attempts
//!    whose clients already gave up.
//! 2. **Shedding earns its keep**: at 2× saturation the defended PaDG
//!    coordinator delivers strictly more SLO-meeting work than its own
//!    `ablate_no_shedding` ablation on the exact same trace and client.
//! 3. **Defenses-off invariance**: with no client and no defenses, every
//!    system's per-request records are bit-identical across the plain
//!    engine, the client-capable engine, and the reference engine — and
//!    scenario rows carry no overload telemetry block at all.

use ecoserve::config::{DefenseConfig, ExperimentConfig, SystemKind};
use ecoserve::harness::build_system;
use ecoserve::metrics::{AbandonPolicy, Collector, RequestRecord};
use ecoserve::scenarios::{
    by_name, run_overload_suite, run_system, run_system_variant, RunSpec, ScenarioConfig,
};
use ecoserve::sim::{reference_run_faulted_client, run_abandonable, run_faulted_client};

/// 4 instances (16 L20 GPUs): small enough for test wall time, with a
/// base rate near the knee so the overload multipliers sweep past it.
fn overload_cfg() -> ScenarioConfig {
    let mut cfg = ScenarioConfig::default_l20();
    cfg.deployment.gpus_used = 16;
    cfg.duration_override = Some(60.0);
    cfg.rate = Some(3.0);
    cfg
}

/// ISSUE acceptance (a): undefended goodput strictly *falls* as offered
/// load rises past saturation — the closed loop's retry amplification
/// turns congestion into collapse when nothing sheds.
#[test]
fn undefended_vllm_goodput_collapses_past_saturation() {
    let s = by_name("retry-storm").unwrap();
    let cfg = overload_cfg();
    let outcomes = run_overload_suite(&[s], &cfg, &[SystemKind::Vllm], 4);
    let row = &outcomes[0].rows[0];
    let curve = row.undefended_goodputs();
    assert!(curve.len() >= 2, "{curve:?}");
    for w in curve.windows(2) {
        assert!(
            w[1] < w[0],
            "undefended goodput must strictly fall past saturation: {curve:?}"
        );
    }
    assert!(row.undefended_retained_at_peak() < 1.0);

    // The storm actually fired: timeouts and retries are nonzero at the
    // heaviest point, and the defended half sheds rather than queueing.
    let top = row.cells.last().unwrap();
    let ct = top.undefended.overload.unwrap().client;
    assert!(ct.timeouts > 0 && ct.retries > 0, "{ct:?}");
    let dt = top.defended.overload.unwrap().defense.unwrap();
    assert!(dt.sheds() > 0, "{dt:?}");
    // Shedding hopeless work can only help at the peak: the defended
    // half never does worse than the undefended one on the same cell.
    assert!(
        top.defended.goodput_rps >= top.undefended.goodput_rps,
        "defended {} vs undefended {}",
        top.defended.goodput_rps,
        top.undefended.goodput_rps
    );
}

/// ISSUE acceptance (b): at 2× saturation, defended PaDG strictly beats
/// its own no-shedding ablation on SLO-met count — same trace, same
/// client, one knob.
#[test]
fn defended_padg_beats_its_own_no_shedding_ablation() {
    let s = by_name("retry-storm").unwrap();
    let mut cfg = overload_cfg();
    cfg.rate = Some(6.0); // 2× the saturation-knee base rate
    let client = s.overload.unwrap().client;
    let defended = run_system_variant(
        &s,
        &cfg,
        &RunSpec::new(SystemKind::EcoServe)
            .with_client(client)
            .with_defense(DefenseConfig::default()),
    );
    let ablated = run_system_variant(
        &s,
        &cfg,
        &RunSpec::new(SystemKind::EcoServe)
            .with_client(client)
            .with_defense(DefenseConfig::default())
            .without_shedding(),
    );
    assert!(
        defended.met > ablated.met,
        "shedding must strictly beat the ablation on SLO-met work: {} vs {}",
        defended.met,
        ablated.met
    );
    // The defended run reports its defenses; the ablation nulls them
    // (same code path as an undefended run, telemetry and all).
    let dt = defended.overload.unwrap().defense.expect("defended run reports telemetry");
    assert!(dt.sheds() > 0, "{dt:?}");
    assert!(ablated.overload.unwrap().defense.is_none());
}

/// ISSUE acceptance (c): with the client disabled, the client-capable
/// engine entry points are bit-identical to the plain engine — for every
/// system, across both the heap and reference engines.
#[test]
fn client_disabled_runs_are_bit_identical_across_engines() {
    let s = by_name("overload-sustained").unwrap();
    let cfg = overload_cfg();
    let (duration, _) = cfg.horizon(&s);
    let trace = s.build_trace_for(cfg.seed, cfg.rate.unwrap(), duration);
    let horizon = duration + 240.0;

    let sched = s.scheduler_dataset();
    let mut exp = ExperimentConfig::new(cfg.deployment.clone(), sched);
    exp.seed = cfg.seed;
    exp.duration = duration;

    for kind in SystemKind::all() {
        let run = |mode: usize| -> Vec<RequestRecord> {
            let mut sys = build_system(kind, &exp, None);
            let mut m = Collector::new();
            match mode {
                0 => {
                    run_abandonable(sys.as_mut(), trace.clone(), horizon, &mut m, false);
                }
                1 => {
                    run_faulted_client(
                        sys.as_mut(),
                        trace.clone(),
                        &[],
                        None,
                        horizon,
                        &mut m,
                        false,
                    );
                }
                _ => {
                    reference_run_faulted_client(
                        sys.as_mut(),
                        trace.clone(),
                        &[],
                        None,
                        horizon,
                        &mut m,
                    );
                }
            }
            m.completed().to_vec()
        };
        let plain = run(0);
        assert!(!plain.is_empty(), "{kind:?}");
        for mode in [1, 2] {
            let got = run(mode);
            assert_eq!(plain.len(), got.len(), "{kind:?} mode {mode}");
            for (a, b) in plain.iter().zip(&got) {
                assert_eq!(a.id, b.id, "{kind:?} mode {mode}");
                assert_eq!(
                    a.first_token.to_bits(),
                    b.first_token.to_bits(),
                    "{kind:?} mode {mode} req {}",
                    a.id
                );
                assert_eq!(
                    a.completion.to_bits(),
                    b.completion.to_bits(),
                    "{kind:?} mode {mode} req {}",
                    a.id
                );
                assert_eq!((a.input_len, a.output_len), (b.input_len, b.output_len));
            }
        }
    }

    // The scenario surface stays clean too: a default cell (no client,
    // no defenses) carries no overload telemetry block, so existing
    // BENCH artifacts are untouched by this machinery.
    let row = run_system(&s, &cfg, SystemKind::Vllm);
    assert!(row.overload.is_none());
}

/// The online SLO monitor's early-abandon verdict stays correct with
/// timeouts and retries in play: an abandoned run really was doomed (the
/// full run misses the target), and an undecided run scores identically
/// to the full one.
#[test]
fn slo_monitor_verdicts_stay_correct_with_client_attached() {
    let s = by_name("retry-storm").unwrap();
    let mut cfg = overload_cfg();
    cfg.rate = Some(6.0); // 2× saturation: the verdict should be doom
    let client = s.overload.unwrap().client;
    let full =
        run_system_variant(&s, &cfg, &RunSpec::new(SystemKind::Vllm).with_client(client));
    let armed = run_system_variant(
        &s,
        &cfg,
        &RunSpec::new(SystemKind::Vllm)
            .with_client(client)
            .with_abandon(AbandonPolicy::stop_at(0.9)),
    );
    let ct = full.overload.unwrap().client;
    assert!(ct.timeouts > 0 && ct.retries > 0, "the client must be live: {ct:?}");
    if armed.abandoned {
        // Retries must never fake the verdict: the full run confirms the
        // target really was unreachable, and stopping early saved work.
        assert!(
            full.attainment < 0.9,
            "monitor declared doom but the full run met the target: {}",
            full.attainment
        );
        assert!(armed.events <= full.events);
    } else {
        assert_eq!(armed.met, full.met);
        assert_eq!(armed.attainment.to_bits(), full.attainment.to_bits());
    }
    assert!(
        armed.abandoned,
        "a 2×-saturation cell must be decided early (attainment {})",
        full.attainment
    );
}
