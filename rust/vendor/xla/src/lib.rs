//! API-compatible **stub** for the `xla` crate (PJRT C-API bindings).
//!
//! The live serving path (`ecoserve`'s `pjrt` feature) is written against
//! the real `xla` crate, which needs an XLA/PJRT shared library that the
//! offline CI image does not carry. This stub keeps `--features pjrt`
//! *compiling* everywhere while failing fast — and cleanly — at runtime:
//! [`PjRtClient::cpu`] returns an error, which the engine/coordinator
//! layers surface as a normal startup failure ("XLA PJRT runtime
//! unavailable ...").
//!
//! To serve live, replace this path dependency in `rust/Cargo.toml` with a
//! real binding (e.g. a local `xla-rs` checkout built against
//! `xla_extension`):
//!
//! ```toml
//! xla = { path = "/path/to/xla-rs", optional = true }
//! ```
//!
//! The surface below mirrors exactly what `rust/src/runtime/{pjrt,engine}`
//! calls — nothing more.

use std::fmt;

/// Error type filling the real crate's `xla::Error` role.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real crate.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "XLA PJRT runtime unavailable ({what}): this build links the in-tree \
         stub `xla` crate (rust/vendor/xla); point Cargo at a real xla binding \
         to serve live"
    ))
}

/// Element types accepted by host-buffer upload / literal download.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// PJRT client handle (CPU plugin in the real crate).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Stands up the PJRT CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_buffer"))
    }
}

/// Parsed HLO module (the real crate reparses HLO text through
/// `HloModuleProto`).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed argument buffers; the real crate returns one
    /// `Vec<PjRtBuffer>` per device.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute_b"))
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// A host-side literal value.
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("decompose_tuple"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_a_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must not start");
        let msg = err.to_string();
        assert!(msg.contains("stub"), "{msg}");
        assert!(msg.contains("PjRtClient::cpu"), "{msg}");
    }

    #[test]
    fn surface_typechecks_like_the_real_crate() {
        // The types compose the way runtime/pjrt.rs uses them even though
        // every runtime call errors.
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let comp = XlaComputation::from_proto(&HloModuleProto { _priv: () });
        let _ = &comp;
    }
}
