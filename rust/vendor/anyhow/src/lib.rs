//! In-tree substrate for the `anyhow` crate, API-compatible with the
//! subset this workspace uses (the offline image has no crates.io access;
//! see `rust/src/util/mod.rs` for the same pattern applied to `rand`,
//! `serde_json`, `clap`, and `tokio`).
//!
//! Provided surface:
//! * [`Error`] — a context-chain error; `{e}` prints the outermost
//!   message, `{e:#}` the whole chain joined with `": "` (matching real
//!   anyhow's alternate Display).
//! * [`Result<T>`] with the `E = Error` default parameter.
//! * [`Context`] — `.context(msg)` / `.with_context(|| msg)` on both
//!   `Result` and `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, so the blanket `From<E: std::error::Error>`
//! impl (which powers `?` on foreign errors) stays coherent.

use std::fmt;

/// A context-chain error. `chain[0]` is the outermost context, the last
/// element the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context(mut self, message: impl fmt::Display) -> Error {
        self.chain.insert(0, message.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Real anyhow's Debug prints the message plus a "Caused by" list;
        // the joined chain carries the same information on one line.
        f.write_str(&self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with [`Error`] as the
/// default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Result` and `Option` values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/definitely/missing")
            .context("read config")?;
        Ok(s)
    }

    #[test]
    fn context_chain_formats() {
        let err = fails_io().unwrap_err();
        let plain = format!("{err}");
        let alt = format!("{err:#}");
        assert_eq!(plain, "read config");
        assert!(alt.starts_with("read config: "), "{alt}");
        assert!(alt.len() > plain.len());
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let err = x.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(format!("{err}"), "missing field");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts_foreign_errors() {
        fn parse() -> Result<u32> {
            let n: u32 = "not a number".parse()?;
            Ok(n)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn macros() {
        fn inner(flag: bool) -> Result<()> {
            ensure!(flag, "flag was {flag}");
            bail!("reached the end: {}", 42);
        }
        assert_eq!(format!("{}", inner(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", inner(true).unwrap_err()), "reached the end: 42");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn root_cause_is_innermost() {
        let err = fails_io().unwrap_err();
        assert_ne!(err.root_cause(), "read config");
        assert!(err.chain().count() >= 2);
    }
}
