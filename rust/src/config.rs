//! Configuration: cluster topologies, deployments, and experiment specs.
//!
//! Cluster presets mirror the paper's two testbeds (§4.1):
//! * `l20_cluster()` — 8 nodes × 8 NVIDIA L20-48GB, PCIe-only intra-node,
//!   10 Gbps Ethernet inter-node (the "production-level" commodity cluster;
//!   the end-to-end grid uses 32 of the 64 GPUs, as §4.2 does).
//! * `a800_cluster()` — 2 nodes × 8 NVIDIA A800-80GB, PCIe intra-node,
//!   25 Gbps RoCE inter-node.

use crate::perfmodel::interconnect::LinkSpec;
use crate::perfmodel::parallelism::ParallelCfg;
use crate::perfmodel::{BatchTimer, GpuSpec, ModelSpec};
use crate::workload::Dataset;

/// Physical cluster description.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: &'static str,
    pub gpu: GpuSpec,
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// Intra-node fabric (TP all-reduces, DistServe KV hops).
    pub intra_link: LinkSpec,
    /// Inter-node network (MoonCake KV pool traffic).
    pub inter_link: LinkSpec,
    /// Host overhead, USD per occupied node per hour (CPUs, DRAM, chassis,
    /// power) — the capacity planner's per-node term on top of GPU rental.
    pub node_overhead_per_hour: f64,
}

impl ClusterSpec {
    pub fn l20_cluster() -> Self {
        ClusterSpec {
            name: "L20-cluster",
            gpu: GpuSpec::l20(),
            nodes: 8,
            gpus_per_node: 8,
            intra_link: LinkSpec::pcie4(),
            inter_link: LinkSpec::eth_10g(),
            node_overhead_per_hour: 0.55,
        }
    }

    pub fn a800_cluster() -> Self {
        ClusterSpec {
            name: "A800-cluster",
            gpu: GpuSpec::a800(),
            nodes: 2,
            gpus_per_node: 8,
            intra_link: LinkSpec::pcie4(),
            inter_link: LinkSpec::roce_25g(),
            node_overhead_per_hour: 0.75,
        }
    }

    pub fn by_name(name: &str) -> Option<ClusterSpec> {
        match name.to_ascii_lowercase().as_str() {
            "l20" | "l20-cluster" => Some(Self::l20_cluster()),
            "a800" | "a800-cluster" => Some(Self::a800_cluster()),
            _ => None,
        }
    }

    pub fn total_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }
}

/// A model deployed on a cluster with a parallelism layout.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub model: ModelSpec,
    pub cluster: ClusterSpec,
    pub tp: usize,
    pub pp: usize,
    /// Total GPUs used (defines the instance count).
    pub gpus_used: usize,
    /// Fraction of GPU memory held back from KV (activations etc.).
    pub kv_reserve_frac: f64,
}

impl Deployment {
    /// The paper's §4.2 layouts: on L20, 32 GPUs with TP=4 (30B/34B) or
    /// TP=8 (72B); on A800, 16 GPUs with TP=2 / TP=4.
    pub fn paper_default(model: ModelSpec, cluster: ClusterSpec) -> Self {
        let (tp, gpus_used) = match (cluster.name, model.name) {
            ("L20-cluster", "Qwen2-72B") => (8, 32),
            ("L20-cluster", _) => (4, 32),
            ("A800-cluster", "Qwen2-72B") => (4, 16),
            _ => (2, 16),
        };
        Deployment {
            model,
            cluster,
            tp,
            pp: 1,
            gpus_used,
            kv_reserve_frac: 0.10,
        }
    }

    pub fn gpus_per_instance(&self) -> usize {
        self.tp * self.pp
    }

    pub fn num_instances(&self) -> usize {
        self.gpus_used / self.gpus_per_instance()
    }

    /// Node hosting instance `i` (instances fill nodes in order).
    pub fn node_of_instance(&self, i: usize) -> usize {
        i * self.gpus_per_instance() / self.cluster.gpus_per_node
    }

    /// Parallelism config for one instance: TP over the intra-node link,
    /// PP hand-offs intra-node too (instances never span nodes in the
    /// paper's setups).
    pub fn parallel_cfg(&self) -> ParallelCfg {
        ParallelCfg {
            tp: self.tp,
            pp: self.pp,
            tp_link: self.cluster.intra_link.clone(),
            pp_link: self.cluster.intra_link.clone(),
        }
    }

    /// Batch timer for one instance.
    pub fn timer(&self) -> BatchTimer {
        BatchTimer::new(self.model.clone(), self.cluster.gpu.clone(), self.parallel_cfg())
    }

    /// Nodes this deployment occupies (instances fill nodes in order, so
    /// partial nodes at the tail still count — you rent whole hosts).
    pub fn nodes_used(&self) -> usize {
        self.gpus_used.div_ceil(self.cluster.gpus_per_node)
    }
}

/// Smallest KV capacity (tokens) a deployment must retain after weights to
/// count as servable in [`enumerate_deployments`]: one max-length prompt
/// (4096) plus decode headroom. Anything tighter thrashes admission before
/// the first batch forms.
pub const MIN_PLANNABLE_KV_TOKENS: usize = 8192;

/// Enumerate the feasible deployments of `model` on `cluster` for the
/// capacity planner ([`crate::planner`]): every (TP × PP × instance count)
/// shape that (a) keeps each instance inside one node — the paper's
/// placement invariant, so `tp·pp` must divide `gpus_per_node` — (b) fits
/// the GPU budget `max_gpus` (clamped to the cluster), and (c) leaves at
/// least [`MIN_PLANNABLE_KV_TOKENS`] of KV room after weights. Order is
/// deterministic: tp-major, then pp, then instance count.
pub fn enumerate_deployments(
    model: &ModelSpec,
    cluster: &ClusterSpec,
    tp_options: &[usize],
    pp_options: &[usize],
    instance_options: &[usize],
    max_gpus: usize,
) -> Vec<Deployment> {
    let cap = max_gpus.min(cluster.total_gpus());
    let mut out = Vec::new();
    for &tp in tp_options {
        for &pp in pp_options {
            let per_instance = tp * pp;
            if per_instance == 0
                || per_instance > cluster.gpus_per_node
                || cluster.gpus_per_node % per_instance != 0
            {
                continue;
            }
            for &instances in instance_options {
                if instances == 0 {
                    continue;
                }
                let gpus_used = per_instance * instances;
                if gpus_used > cap {
                    continue;
                }
                let d = Deployment {
                    model: model.clone(),
                    cluster: cluster.clone(),
                    tp,
                    pp,
                    gpus_used,
                    kv_reserve_frac: 0.10,
                };
                if d.timer().kv_capacity_tokens(d.kv_reserve_frac) < MIN_PLANNABLE_KV_TOKENS {
                    continue; // weights (nearly) fill memory: not servable
                }
                out.push(d);
            }
        }
    }
    out
}

/// Which serving system to run (paper §4.1 baselines + EcoServe).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    /// PaDG (this paper).
    EcoServe,
    /// NoDG, separate batching, prefill-priority (vLLM).
    Vllm,
    /// NoDG, hybrid batching + chunked prefill (Sarathi-Serve).
    Sarathi,
    /// Intra-node FuDG (DistServe).
    DistServe,
    /// Inter-node FuDG with a central KV pool (MoonCake).
    MoonCake,
}

impl SystemKind {
    pub fn label(&self) -> &'static str {
        match self {
            SystemKind::EcoServe => "EcoServe",
            SystemKind::Vllm => "vLLM",
            SystemKind::Sarathi => "Sarathi",
            SystemKind::DistServe => "DistServe",
            SystemKind::MoonCake => "MoonCake",
        }
    }

    pub fn by_name(name: &str) -> Option<SystemKind> {
        match name.to_ascii_lowercase().as_str() {
            "ecoserve" | "padg" => Some(SystemKind::EcoServe),
            "vllm" => Some(SystemKind::Vllm),
            "sarathi" => Some(SystemKind::Sarathi),
            "distserve" => Some(SystemKind::DistServe),
            "mooncake" => Some(SystemKind::MoonCake),
            _ => None,
        }
    }

    pub fn all() -> [SystemKind; 5] {
        [
            SystemKind::EcoServe,
            SystemKind::Vllm,
            SystemKind::Sarathi,
            SystemKind::DistServe,
            SystemKind::MoonCake,
        ]
    }
}

/// Coordinator-side overload defenses (PR 9). Carried inside
/// [`SystemParams`] so it reaches every system constructor through the
/// existing `build_system` path; `None` (the default) means no defenses
/// and leaves every system bit-identical to its pre-defense behavior.
///
/// PaDG consumes the full set (deadline-aware admission, per-class
/// priority shedding, decode brownout); the NoDG/FuDG baselines get only
/// the native weak form — a hard backlog cap — mirroring what their real
/// counterparts ship.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DefenseConfig {
    /// Deadline-aware admission: reject a new arrival when the backlog's
    /// oldest entry has already waited longer than this multiple of the
    /// tightest TTFT SLO — the queue-implied TTFT for a newcomer is
    /// provably blown, so failing fast beats queueing it to die.
    pub admission_slack: f64,
    /// Backlog length beyond which low-priority classes are shed at
    /// arrival (PaDG) or all arrivals are rejected (baselines' native
    /// cap). Priority classes ride until `2 ×` this cap.
    pub backlog_cap: usize,
    /// Mean decode-occupancy fraction across active instances above which
    /// brownout engages (decode lengths are capped)…
    pub brownout_hi: f64,
    /// …and below which it disengages (hysteresis so the mode doesn't
    /// flap on every batch boundary).
    pub brownout_lo: f64,
    /// Decode-length cap applied to admissions while browned out.
    pub brownout_decode_cap: usize,
}

impl Default for DefenseConfig {
    fn default() -> Self {
        DefenseConfig {
            admission_slack: 1.0,
            backlog_cap: 64,
            brownout_hi: 0.90,
            brownout_lo: 0.75,
            brownout_decode_cap: 64,
        }
    }
}

/// Knobs for the individual systems (paper-faithful defaults).
#[derive(Debug, Clone)]
pub struct SystemParams {
    /// Sarathi chunk budget (tokens per hybrid iteration).
    pub sarathi_chunk: usize,
    /// FuDG prefill:decode instance split — prefill count out of
    /// `num_instances`; `None` = auto-sweep (the paper picks the best
    /// ratio for MoonCake).
    pub fudg_prefill_instances: Option<usize>,
    /// EcoServe mitosis bounds (paper §3.5 / Figure 10: N_l=4, N_u=16).
    pub n_lower: usize,
    pub n_upper: usize,
    /// KV margin (expected output tokens) reserved at admission.
    pub admission_margin: usize,
    /// EcoServe: cap on prefill tokens admitted into one instance's
    /// pending window per routing decision.
    pub max_window_prefill_tokens: usize,
    /// Ablations (benches/ablation_padg.rs; defaults = full EcoServe):
    /// gate constraint 2 on the paper's *mean* saved TPOT instead of the
    /// minimum (DESIGN.md §8 deviation).
    pub ablate_mean_slack: bool,
    /// Disable the rolling-activation window cap (SLO_TTFT / members).
    pub ablate_no_window_cap: bool,
    /// Disable sticky routing: restart every Algorithm-1 scan at member 0.
    pub ablate_no_sticky: bool,
    /// Disable intra-instance window hysteresis (flip to prefill for any
    /// lone arrival).
    pub ablate_no_hysteresis: bool,
    /// Disable EcoServe's coordinator recovery under injected faults
    /// ([`crate::sim::faults`]): a crashed instance's work is dropped
    /// instead of re-routed, lost capacity is not backfilled, and the
    /// router keeps cycling through dead members. Fault-free behavior is
    /// unchanged.
    pub ablate_no_recovery: bool,
    /// Disable EcoServe's overload defenses even when [`Self::defense`]
    /// is set: PaDG falls back to force-admitting hopeless requests while
    /// baselines keep their native backlog cap — isolating how much of
    /// the graceful-degradation story the shedding layer buys.
    pub ablate_no_shedding: bool,
    /// Overload defenses; `None` (the default) disables them everywhere
    /// and keeps every system bit-identical to the defense-free build.
    pub defense: Option<DefenseConfig>,
}

impl Default for SystemParams {
    fn default() -> Self {
        SystemParams {
            sarathi_chunk: 512,
            fudg_prefill_instances: None,
            n_lower: 4,
            n_upper: 16,
            admission_margin: 128,
            max_window_prefill_tokens: 16384,
            ablate_mean_slack: false,
            ablate_no_window_cap: false,
            ablate_no_sticky: false,
            ablate_no_hysteresis: false,
            ablate_no_recovery: false,
            ablate_no_shedding: false,
            defense: None,
        }
    }
}

/// A full experiment: deployment × dataset × workload × system knobs.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub deployment: Deployment,
    pub dataset: Dataset,
    pub params: SystemParams,
    pub seed: u64,
    /// Trace duration, seconds.
    pub duration: f64,
    /// Warm-up prefix excluded from metrics, seconds.
    pub warmup: f64,
}

impl ExperimentConfig {
    pub fn new(deployment: Deployment, dataset: Dataset) -> Self {
        ExperimentConfig {
            deployment,
            dataset,
            params: SystemParams::default(),
            seed: 42,
            duration: 240.0,
            warmup: 30.0,
        }
    }

    /// Default L20 / CodeLlama / ShareGPT experiment (used by docs + smoke).
    pub fn default_l20() -> Self {
        Self::new(
            Deployment::paper_default(ModelSpec::codellama_34b(), ClusterSpec::l20_cluster()),
            Dataset::sharegpt(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_layouts() {
        let l20 = ClusterSpec::l20_cluster();
        let d = Deployment::paper_default(ModelSpec::llama_30b(), l20.clone());
        assert_eq!(d.tp, 4);
        assert_eq!(d.num_instances(), 8);
        assert_eq!(d.node_of_instance(0), 0);
        assert_eq!(d.node_of_instance(1), 0);
        assert_eq!(d.node_of_instance(2), 1);

        let dq = Deployment::paper_default(ModelSpec::qwen2_72b(), l20);
        assert_eq!(dq.tp, 8);
        assert_eq!(dq.num_instances(), 4);
        assert_eq!(dq.node_of_instance(3), 3);

        let a800 = ClusterSpec::a800_cluster();
        let da = Deployment::paper_default(ModelSpec::codellama_34b(), a800);
        assert_eq!(da.tp, 2);
        assert_eq!(da.num_instances(), 8);
    }

    #[test]
    fn kv_capacity_positive_for_all_paper_deployments() {
        for cluster in [ClusterSpec::l20_cluster(), ClusterSpec::a800_cluster()] {
            for model in [
                ModelSpec::llama_30b(),
                ModelSpec::codellama_34b(),
                ModelSpec::qwen2_72b(),
            ] {
                let d = Deployment::paper_default(model.clone(), cluster.clone());
                let cap = d.timer().kv_capacity_tokens(d.kv_reserve_frac);
                assert!(
                    cap > 10_000,
                    "{} on {}: kv capacity {cap}",
                    model.name,
                    cluster.name
                );
            }
        }
    }

    #[test]
    fn system_kind_lookup() {
        assert_eq!(SystemKind::by_name("vllm"), Some(SystemKind::Vllm));
        assert_eq!(SystemKind::by_name("PaDG"), Some(SystemKind::EcoServe));
        assert!(SystemKind::by_name("triton").is_none());
        assert_eq!(SystemKind::all().len(), 5);
    }

    #[test]
    fn cluster_lookup() {
        assert!(ClusterSpec::by_name("l20").is_some());
        assert!(ClusterSpec::by_name("tpu").is_none());
        assert_eq!(ClusterSpec::l20_cluster().total_gpus(), 64);
    }

    #[test]
    fn nodes_used_counts_partial_tail_nodes() {
        let mut d = Deployment::paper_default(
            ModelSpec::codellama_34b(),
            ClusterSpec::l20_cluster(),
        );
        d.gpus_used = 32;
        assert_eq!(d.nodes_used(), 4);
        d.gpus_used = 12; // one and a half nodes: rent two hosts
        assert_eq!(d.nodes_used(), 2);
        d.gpus_used = 4;
        assert_eq!(d.nodes_used(), 1);
    }

    #[test]
    fn enumeration_respects_placement_budget_and_memory() {
        let l20 = ClusterSpec::l20_cluster();
        let model = ModelSpec::llama_30b();
        let all = enumerate_deployments(
            &model,
            &l20,
            &[1, 2, 4, 8],
            &[1, 2],
            &[1, 2, 4, 8, 16],
            32,
        );
        assert!(!all.is_empty());
        for d in &all {
            // Instances never span nodes and the budget is a hard cap.
            assert_eq!(l20.gpus_per_node % d.gpus_per_instance(), 0, "{d:?}");
            assert!(d.gpus_used <= 32, "{d:?}");
            assert!(d.num_instances() >= 1);
            // Every emitted deployment is actually servable.
            assert!(
                d.timer().kv_capacity_tokens(d.kv_reserve_frac) >= MIN_PLANNABLE_KV_TOKENS,
                "{d:?}"
            );
        }
        // The paper's 8x TP=4 layout is in the space.
        assert!(all
            .iter()
            .any(|d| d.tp == 4 && d.pp == 1 && d.num_instances() == 8));
        // TP=1 on a 48GB card cannot hold 30B of bf16 weights: excluded.
        assert!(all.iter().all(|d| d.gpus_per_instance() >= 2));
        // Deterministic order: tp-major, then pp, then instance count.
        let again = enumerate_deployments(
            &model,
            &l20,
            &[1, 2, 4, 8],
            &[1, 2],
            &[1, 2, 4, 8, 16],
            32,
        );
        let shape = |d: &Deployment| (d.tp, d.pp, d.gpus_used);
        assert_eq!(
            all.iter().map(shape).collect::<Vec<_>>(),
            again.iter().map(shape).collect::<Vec<_>>()
        );
    }

    #[test]
    fn enumeration_excludes_node_spanning_shapes() {
        let l20 = ClusterSpec::l20_cluster();
        let model = ModelSpec::llama_30b();
        // tp*pp = 16 > 8 GPUs/node: nothing may be emitted.
        let spanning = enumerate_deployments(&model, &l20, &[8], &[2], &[1, 2], 64);
        assert!(spanning.is_empty());
        // A zero budget yields an empty space, not a panic.
        assert!(enumerate_deployments(&model, &l20, &[2], &[1], &[1], 0).is_empty());
    }
}
