//! Minimal JSON parser + serializer (substrate for `serde_json`, which is
//! unavailable in the offline image).
//!
//! Used for: reading `artifacts/manifest.json`, dumping experiment results,
//! and the wire format of the serializable [`crate::coordinator::proxy`]
//! `InstanceHandler` (the paper uses pickle; we use JSON for the same
//! logical-migration semantics with a readable wire form).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for debugging malformed input.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ---------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }

    /// Optional numeric report field: `Some(x)` → number, `None` → null.
    pub fn opt_num<T: Into<f64>>(x: Option<T>) -> Json {
        match x {
            Some(v) => Json::num(v),
            None => Json::Null,
        }
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `obj.get(key)` chain helper: `j.path(&["config", "vocab"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // ---- serialization --------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if !x.is_finite() {
                    // NaN/±Inf have no JSON spelling; `null` keeps the
                    // artifact parseable (the round-trip loses only the
                    // distinction between the three non-finite values).
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing --------------------------------------------------------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: consume one codepoint.
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::str("hi\nthere"));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_i64(), Some(2));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("name", Json::str("macro-0")),
            ("instances", Json::arr((0..3).map(|i| Json::num(i as f64)))),
            ("active", Json::Bool(true)),
            ("frac", Json::num(0.25)),
        ]);
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // NaN/Inf would otherwise print as bare `NaN`/`inf` — invalid
        // JSON that breaks every downstream parser of a BENCH artifact.
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(bad).to_string(), "null");
        }
        let j = Json::obj(vec![
            ("mean_recovery_s", Json::num(f64::NAN)),
            ("ok", Json::num(1.5)),
        ]);
        let s = j.to_string();
        assert_eq!(s, r#"{"mean_recovery_s":null,"ok":1.5}"#);
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::str("quote\" slash\\ tab\t nl\n");
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::str("A"));
    }

    #[test]
    fn parses_real_manifest() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = Json::parse(&text).unwrap();
            assert_eq!(j.path(&["model"]).unwrap().as_str(), Some("tinylm"));
            assert!(j.path(&["config", "vocab"]).unwrap().as_i64().unwrap() > 0);
        }
    }

    #[test]
    fn path_helper() {
        let j = Json::parse(r#"{"a":{"b":{"c":7}}}"#).unwrap();
        assert_eq!(j.path(&["a", "b", "c"]).unwrap().as_i64(), Some(7));
        assert!(j.path(&["a", "x"]).is_none());
    }
}
