//! Thread/actor helpers (substrate for `tokio`/Ray, unavailable offline).
//!
//! The live serving path runs each inference instance as an OS-thread actor
//! with an mpsc mailbox — the same master/slave control structure the paper
//! builds with Ray RPC + ZeroMQ. The macro-instance scheduler owns handles
//! to its instances' mailboxes and receives status updates on a shared
//! channel; the overall scheduler moves those handles between macro
//! schedulers during mitosis migration.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// A spawned actor: a worker thread plus its command mailbox.
pub struct Actor<Cmd> {
    pub name: String,
    tx: Sender<Cmd>,
    handle: Option<JoinHandle<()>>,
}

impl<Cmd: Send + 'static> Actor<Cmd> {
    /// Spawn an actor. `body` receives the mailbox receiver and runs until
    /// it returns (usually on a Shutdown command or channel disconnect).
    pub fn spawn<F>(name: impl Into<String>, body: F) -> Self
    where
        F: FnOnce(Receiver<Cmd>) + Send + 'static,
    {
        let name = name.into();
        let (tx, rx) = channel();
        let handle = std::thread::Builder::new()
            .name(name.clone())
            .spawn(move || body(rx))
            .expect("spawn actor thread");
        Actor {
            name,
            tx,
            handle: Some(handle),
        }
    }

    /// Send a command; returns false if the actor is gone.
    pub fn send(&self, cmd: Cmd) -> bool {
        self.tx.send(cmd).is_ok()
    }

    /// A clonable sender for this actor's mailbox.
    pub fn sender(&self) -> Sender<Cmd> {
        self.tx.clone()
    }

    /// Wait for the actor thread to finish (consumes the join handle).
    pub fn join(&mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Fan-in helper: one receiver, many senders — instance status updates flow
/// into the macro-instance scheduler through one of these.
pub struct Inbox<T> {
    pub tx: Sender<T>,
    pub rx: Receiver<T>,
}

impl<T> Inbox<T> {
    pub fn new() -> Self {
        let (tx, rx) = channel();
        Inbox { tx, rx }
    }

    /// Drain everything currently queued without blocking.
    pub fn drain(&self) -> Vec<T> {
        let mut out = Vec::new();
        while let Ok(item) = self.rx.try_recv() {
            out.push(item);
        }
        out
    }
}

impl<T> Default for Inbox<T> {
    fn default() -> Self {
        Self::new()
    }
}

/// Run `f` over `items` on up to `workers` scoped threads, preserving input
/// order in the output. Used by the benchmark harness to sweep request
/// rates / systems in parallel.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let jobs: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = std::sync::Mutex::new(jobs);
    let results_mx = std::sync::Mutex::new(&mut results);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let job = queue.lock().unwrap().pop();
                match job {
                    Some((i, item)) => {
                        let r = f(item);
                        results_mx.lock().unwrap()[i] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    results.into_iter().map(|r| r.expect("worker completed")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    enum Cmd {
        Add(usize),
        Stop,
    }

    #[test]
    fn actor_processes_commands() {
        let total = Arc::new(AtomicUsize::new(0));
        let t2 = total.clone();
        let mut actor = Actor::spawn("adder", move |rx| {
            for cmd in rx {
                match cmd {
                    Cmd::Add(x) => {
                        t2.fetch_add(x, Ordering::SeqCst);
                    }
                    Cmd::Stop => break,
                }
            }
        });
        for i in 1..=10 {
            assert!(actor.send(Cmd::Add(i)));
        }
        actor.send(Cmd::Stop);
        actor.join();
        assert_eq!(total.load(Ordering::SeqCst), 55);
    }

    #[test]
    fn inbox_drains() {
        let inbox = Inbox::new();
        for i in 0..5 {
            inbox.tx.send(i).unwrap();
        }
        assert_eq!(inbox.drain(), vec![0, 1, 2, 3, 4]);
        assert!(inbox.drain().is_empty());
    }

    #[test]
    fn parallel_map_preserves_order() {
        let xs: Vec<usize> = (0..100).collect();
        let ys = parallel_map(xs, 8, |x| x * x);
        assert_eq!(ys, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let ys: Vec<usize> = parallel_map(Vec::<usize>::new(), 4, |x| x);
        assert!(ys.is_empty());
    }
}
