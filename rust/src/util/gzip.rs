//! Minimal pure-Rust gzip decoder (RFC 1952 framing over RFC 1951
//! DEFLATE) — substrate for the `flate2` crate, unavailable in the
//! offline image. Whole-buffer decompression only: the import paths
//! that consume it materialize the decompressed text before scanning,
//! so a `.csv.gz` trace costs one decompressed copy in memory (gunzip
//! first if a log's *text* is too large to hold — the compressed file
//! itself never is the constraint).
//!
//! Supported: stored, fixed-Huffman, and dynamic-Huffman blocks; all
//! optional header fields (FEXTRA/FNAME/FCOMMENT/FHCRC); concatenated
//! multi-member files (valid gzip — members decode back to back). The
//! CRC32 and ISIZE trailer of every member are verified, so silent
//! corruption fails loudly instead of replaying a mangled trace.
//!
//! The decoder is the canonical bit-at-a-time scheme (the same shape as
//! zlib's reference `puff.c`): slow next to a table-driven inflate, but
//! small enough to audit line by line, and import parsing dominates the
//! wall clock anyway.

/// Decompress a complete gzip file: every member, concatenated.
pub fn gunzip(data: &[u8]) -> Result<Vec<u8>, String> {
    if data.is_empty() {
        return Err("empty gzip input".to_string());
    }
    let mut out = Vec::new();
    let mut pos = 0;
    while pos < data.len() {
        pos = member(data, pos, &mut out)?;
    }
    Ok(out)
}

/// CRC-32 (reflected, polynomial 0xEDB88320) — the gzip trailer checksum.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Decode one gzip member starting at `pos`; append its payload to
/// `out` and return the offset just past its trailer.
fn member(data: &[u8], mut pos: usize, out: &mut Vec<u8>) -> Result<usize, String> {
    let need = |p: usize, n: usize| -> Result<(), String> {
        if p + n > data.len() {
            Err(format!("truncated gzip stream at byte {p}"))
        } else {
            Ok(())
        }
    };
    need(pos, 10)?;
    if data[pos] != 0x1f || data[pos + 1] != 0x8b {
        return Err("not a gzip stream (bad magic bytes)".to_string());
    }
    if data[pos + 2] != 8 {
        return Err(format!("unsupported gzip compression method {}", data[pos + 2]));
    }
    let flg = data[pos + 3];
    if flg & 0xe0 != 0 {
        return Err("reserved gzip FLG bits set".to_string());
    }
    pos += 10; // MTIME(4), XFL, OS: informational, skipped
    if flg & 0x04 != 0 {
        // FEXTRA: little-endian length prefix.
        need(pos, 2)?;
        let xlen = u16::from_le_bytes([data[pos], data[pos + 1]]) as usize;
        pos += 2;
        need(pos, xlen)?;
        pos += xlen;
    }
    for name_or_comment in [0x08u8, 0x10] {
        // FNAME / FCOMMENT: NUL-terminated strings.
        if flg & name_or_comment != 0 {
            loop {
                need(pos, 1)?;
                pos += 1;
                if data[pos - 1] == 0 {
                    break;
                }
            }
        }
    }
    if flg & 0x02 != 0 {
        // FHCRC: header checksum, not verified (the payload CRC is).
        need(pos, 2)?;
        pos += 2;
    }

    let start = out.len();
    let mut br = BitReader { data, byte: pos, bit: 0 };
    inflate(&mut br, out)?;
    br.align();
    pos = br.byte;

    need(pos, 8)?;
    let crc = u32::from_le_bytes([data[pos], data[pos + 1], data[pos + 2], data[pos + 3]]);
    let isize_mod = u32::from_le_bytes([
        data[pos + 4],
        data[pos + 5],
        data[pos + 6],
        data[pos + 7],
    ]);
    let payload = &out[start..];
    if payload.len() as u32 != isize_mod {
        return Err(format!(
            "gzip length mismatch: trailer says {isize_mod} bytes (mod 2^32), got {}",
            payload.len()
        ));
    }
    if crc32(payload) != crc {
        return Err("gzip CRC mismatch — corrupt stream".to_string());
    }
    Ok(pos + 8)
}

/// LSB-first bit cursor over the deflate byte stream.
struct BitReader<'a> {
    data: &'a [u8],
    byte: usize,
    bit: u32,
}

impl BitReader<'_> {
    fn bit(&mut self) -> Result<u32, String> {
        if self.byte >= self.data.len() {
            return Err("truncated deflate stream".to_string());
        }
        let b = (self.data[self.byte] >> self.bit) & 1;
        self.bit += 1;
        if self.bit == 8 {
            self.bit = 0;
            self.byte += 1;
        }
        Ok(b as u32)
    }

    fn bits(&mut self, n: u32) -> Result<u32, String> {
        let mut v = 0u32;
        for i in 0..n {
            v |= self.bit()? << i;
        }
        Ok(v)
    }

    /// Discard any partial byte (stored-block alignment, trailer seek).
    fn align(&mut self) {
        if self.bit != 0 {
            self.bit = 0;
            self.byte += 1;
        }
    }
}

/// A canonical Huffman decoder: `count[n]` codes of length n, symbols
/// in canonical order. Decoding walks one bit at a time through the
/// code-length bands — the reference algorithm from RFC 1951 §3.2.2.
struct Huffman {
    count: [u16; 16],
    symbol: Vec<u16>,
}

impl Huffman {
    fn build(lengths: &[u16]) -> Result<Huffman, String> {
        let mut count = [0u16; 16];
        for &l in lengths {
            if l > 15 {
                return Err(format!("huffman code length {l} out of range"));
            }
            count[l as usize] += 1;
        }
        if count[0] as usize == lengths.len() {
            // No codes at all — legal for a distance table in an
            // all-literal block; decoding against it errors if used.
            return Ok(Huffman { count, symbol: Vec::new() });
        }
        // Reject over-subscribed length sets (incomplete ones are
        // allowed: the fixed distance table is incomplete by spec).
        let mut left: i32 = 1;
        for len in 1..=15 {
            left <<= 1;
            left -= count[len] as i32;
            if left < 0 {
                return Err("over-subscribed huffman code".to_string());
            }
        }
        let mut offs = [0u16; 16];
        for len in 1..15 {
            offs[len + 1] = offs[len] + count[len];
        }
        let mut symbol = vec![0u16; lengths.iter().filter(|&&l| l != 0).count()];
        for (sym, &l) in lengths.iter().enumerate() {
            if l != 0 {
                symbol[offs[l as usize] as usize] = sym as u16;
                offs[l as usize] += 1;
            }
        }
        Ok(Huffman { count, symbol })
    }

    fn decode(&self, br: &mut BitReader) -> Result<u16, String> {
        let mut code: i32 = 0;
        let mut first: i32 = 0;
        let mut index: i32 = 0;
        for len in 1..=15 {
            code |= br.bit()? as i32;
            let count = self.count[len] as i32;
            if code - count < first {
                return Ok(self.symbol[(index + (code - first)) as usize]);
            }
            index += count;
            first = (first + count) << 1;
            code <<= 1;
        }
        Err("invalid huffman code".to_string())
    }
}

fn inflate(br: &mut BitReader, out: &mut Vec<u8>) -> Result<(), String> {
    loop {
        let bfinal = br.bits(1)?;
        match br.bits(2)? {
            0 => stored(br, out)?,
            1 => {
                let (lit, dist) = fixed_tables()?;
                block(br, out, &lit, &dist)?;
            }
            2 => {
                let (lit, dist) = dynamic_tables(br)?;
                block(br, out, &lit, &dist)?;
            }
            _ => return Err("reserved deflate block type 3".to_string()),
        }
        if bfinal == 1 {
            return Ok(());
        }
    }
}

fn stored(br: &mut BitReader, out: &mut Vec<u8>) -> Result<(), String> {
    br.align();
    if br.byte + 4 > br.data.len() {
        return Err("truncated stored-block header".to_string());
    }
    let len = u16::from_le_bytes([br.data[br.byte], br.data[br.byte + 1]]) as usize;
    let nlen = u16::from_le_bytes([br.data[br.byte + 2], br.data[br.byte + 3]]);
    if nlen != !(len as u16) {
        return Err("stored block length complement check failed".to_string());
    }
    br.byte += 4;
    if br.byte + len > br.data.len() {
        return Err("truncated stored block".to_string());
    }
    out.extend_from_slice(&br.data[br.byte..br.byte + len]);
    br.byte += len;
    Ok(())
}

// RFC 1951 §3.2.5: length/distance symbol expansion tables.
const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u32; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u32; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];

fn block(
    br: &mut BitReader,
    out: &mut Vec<u8>,
    lit: &Huffman,
    dist: &Huffman,
) -> Result<(), String> {
    loop {
        let sym = lit.decode(br)?;
        if sym < 256 {
            out.push(sym as u8);
        } else if sym == 256 {
            return Ok(());
        } else {
            let i = (sym - 257) as usize;
            if i >= LEN_BASE.len() {
                return Err(format!("invalid length symbol {sym}"));
            }
            let len = LEN_BASE[i] as usize + br.bits(LEN_EXTRA[i])? as usize;
            let d = dist.decode(br)? as usize;
            if d >= DIST_BASE.len() {
                return Err(format!("invalid distance symbol {d}"));
            }
            let distance = DIST_BASE[d] as usize + br.bits(DIST_EXTRA[d])? as usize;
            if distance > out.len() {
                return Err("back-reference before output start".to_string());
            }
            // Byte-by-byte on purpose: distance < len means the copy
            // overlaps itself (run-length encoding), which a slice copy
            // would get wrong.
            let from = out.len() - distance;
            for k in 0..len {
                let b = out[from + k];
                out.push(b);
            }
        }
    }
}

/// The fixed (btype=1) code tables from RFC 1951 §3.2.6.
fn fixed_tables() -> Result<(Huffman, Huffman), String> {
    let mut lens = [0u16; 288];
    for (i, l) in lens.iter_mut().enumerate() {
        *l = match i {
            0..=143 => 8,
            144..=255 => 9,
            256..=279 => 7,
            _ => 8,
        };
    }
    Ok((Huffman::build(&lens)?, Huffman::build(&[5u16; 30])?))
}

// The permuted order code-length-code lengths arrive in (RFC 1951 §3.2.7).
const CLC_ORDER: [usize; 19] = [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];

/// Read a dynamic (btype=2) block header: the code-length code, then the
/// run-length-encoded literal/length and distance code lengths.
fn dynamic_tables(br: &mut BitReader) -> Result<(Huffman, Huffman), String> {
    let hlit = br.bits(5)? as usize + 257;
    let hdist = br.bits(5)? as usize + 1;
    let hclen = br.bits(4)? as usize + 4;
    if hlit > 286 || hdist > 30 {
        return Err("dynamic block header counts out of range".to_string());
    }
    let mut clc = [0u16; 19];
    for &slot in CLC_ORDER.iter().take(hclen) {
        clc[slot] = br.bits(3)? as u16;
    }
    let cl = Huffman::build(&clc)?;
    let mut lens = vec![0u16; hlit + hdist];
    let mut i = 0;
    while i < lens.len() {
        let sym = cl.decode(br)?;
        match sym {
            0..=15 => {
                lens[i] = sym;
                i += 1;
            }
            16 => {
                if i == 0 {
                    return Err("length repeat with no previous length".to_string());
                }
                let prev = lens[i - 1];
                let n = 3 + br.bits(2)? as usize;
                if i + n > lens.len() {
                    return Err("code-length repeat overruns the table".to_string());
                }
                for _ in 0..n {
                    lens[i] = prev;
                    i += 1;
                }
            }
            17 | 18 => {
                let n = if sym == 17 {
                    3 + br.bits(3)? as usize
                } else {
                    11 + br.bits(7)? as usize
                };
                if i + n > lens.len() {
                    return Err("code-length zero run overruns the table".to_string());
                }
                i += n; // already zero-initialized
            }
            _ => return Err(format!("invalid code-length symbol {sym}")),
        }
    }
    if lens[256] == 0 {
        return Err("dynamic block defines no end-of-block code".to_string());
    }
    Ok((Huffman::build(&lens[..hlit])?, Huffman::build(&lens[hlit..])?))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Fixtures produced by CPython's gzip module (mtime pinned to 0).
    const HELLO_GZ: &[u8] = &[
        31, 139, 8, 0, 0, 0, 0, 0, 2, 255, 203, 72, 205, 201, 201, 215, 81, 72, 73, 77, 203, 73,
        44, 73, 85, 40, 207, 47, 202, 73, 225, 2, 0, 144, 67, 179, 77, 21, 0, 0, 0,
    ];
    const STORED_GZ: &[u8] = &[
        31, 139, 8, 0, 0, 0, 0, 0, 0, 255, 1, 32, 0, 223, 255, 115, 116, 111, 114, 101, 100, 45,
        98, 108, 111, 99, 107, 32, 112, 97, 121, 108, 111, 97, 100, 32, 49, 50, 51, 52, 53, 54,
        55, 56, 57, 48, 10, 60, 109, 13, 153, 32, 0, 0, 0,
    ];
    const DYN_GZ: &[u8] = &[
        31, 139, 8, 0, 0, 0, 0, 0, 2, 255, 237, 203, 199, 17, 128, 48, 12, 68, 209, 86, 182, 15,
        170, 33, 8, 91, 4, 11, 28, 177, 171, 71, 67, 13, 220, 224, 184, 243, 223, 70, 75, 56, 19,
        143, 43, 6, 47, 197, 97, 150, 11, 75, 218, 143, 0, 201, 228, 17, 53, 111, 125, 171, 152,
        196, 116, 207, 250, 241, 103, 240, 209, 171, 219, 43, 6, 69, 133, 163, 197, 204, 153, 52,
        53, 114, 216, 248, 76, 226, 245, 107, 194, 15, 223, 130, 55, 147, 189, 124, 99, 141, 3,
        0, 0,
    ];
    const MULTI_GZ: &[u8] = &[
        31, 139, 8, 0, 0, 0, 0, 0, 2, 255, 75, 203, 44, 42, 46, 81, 200, 77, 205, 77, 74, 45,
        226, 2, 0, 167, 244, 133, 10, 13, 0, 0, 0, 31, 139, 8, 0, 0, 0, 0, 0, 2, 255, 43, 78, 77,
        206, 207, 75, 81, 200, 77, 205, 77, 74, 45, 226, 2, 0, 54, 24, 75, 14, 14, 0, 0, 0,
    ];
    const NAMED_GZ: &[u8] = &[
        31, 139, 8, 8, 0, 0, 0, 0, 2, 255, 110, 97, 109, 101, 100, 46, 116, 120, 116, 0, 203, 75,
        204, 77, 77, 81, 40, 72, 172, 204, 201, 79, 76, 225, 2, 0, 251, 192, 113, 178, 14, 0, 0,
        0,
    ];

    #[test]
    fn fixed_huffman_member_roundtrips() {
        assert_eq!(gunzip(HELLO_GZ).unwrap(), b"hello, deflate world\n");
    }

    #[test]
    fn stored_block_member_roundtrips() {
        assert_eq!(gunzip(STORED_GZ).unwrap(), b"stored-block payload 1234567890\n");
    }

    #[test]
    fn dynamic_huffman_member_roundtrips() {
        let mut want = Vec::new();
        for _ in 0..12 {
            want.extend_from_slice(b"the quick brown fox jumps over the lazy dog; ");
        }
        for _ in 0..9 {
            want.extend_from_slice(b"pack my box with five dozen liquor jugs; ");
        }
        assert_eq!(gunzip(DYN_GZ).unwrap(), want);
    }

    #[test]
    fn concatenated_members_decode_back_to_back() {
        assert_eq!(gunzip(MULTI_GZ).unwrap(), b"first member\nsecond member\n");
    }

    #[test]
    fn optional_fname_header_is_skipped() {
        assert_eq!(gunzip(NAMED_GZ).unwrap(), b"named payload\n");
    }

    #[test]
    fn corruption_fails_loudly() {
        // Bad magic.
        let e = gunzip(b"not gzip at all").unwrap_err();
        assert!(e.contains("magic"), "{e}");
        // Empty input.
        assert!(gunzip(&[]).unwrap_err().contains("empty"));
        // Truncated mid-stream.
        let e = gunzip(&HELLO_GZ[..HELLO_GZ.len() - 12]).unwrap_err();
        assert!(e.contains("truncated"), "{e}");
        // Flipped payload bit: the CRC catches it. (Flip inside the
        // stored block's literal bytes so the deflate layer still parses.)
        let mut bad = STORED_GZ.to_vec();
        bad[20] ^= 0x01;
        let e = gunzip(&bad).unwrap_err();
        assert!(e.contains("CRC"), "{e}");
        // Mangled trailer length.
        let mut bad = HELLO_GZ.to_vec();
        let n = bad.len();
        bad[n - 1] ^= 0x7f; // ISIZE high byte
        let e = gunzip(&bad).unwrap_err();
        assert!(e.contains("length mismatch"), "{e}");
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // The canonical check value from the CRC catalogue.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
