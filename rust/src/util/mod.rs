//! In-tree substrates replacing crates unavailable in the offline image
//! (`rand`, `serde`/`serde_json`, `clap`, `tokio`, `flate2`): a
//! counter-based PRNG with the distribution samplers the workload
//! generator needs, a JSON parser/serializer, a CLI flag parser, a gzip
//! decoder for compressed trace imports, and small thread/channel
//! helpers.

pub mod alloc;
pub mod cli;
pub mod gzip;
pub mod json;
pub mod rng;
pub mod threads;

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// p-th percentile (0..=100) by linear interpolation over a *sorted copy*.
///
/// Well-defined on degenerate input — callers feed it raw latency vectors
/// and must never get a panic or NaN back:
/// * empty (and all-NaN) input returns 0.0;
/// * a single sample returns that sample at any `p`;
/// * NaN samples are dropped before ranking;
/// * `p` outside [0, 100] is clamped; a NaN `p` reads as 100 (the
///   conservative upper tail for latency metrics).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut sorted: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    sorted.sort_by(f64::total_cmp);
    percentile_sorted(&sorted, p)
}

/// p-th percentile over an already-sorted slice (same edge-case contract
/// as [`percentile`], except NaN samples must already be absent).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let p = if p.is_nan() { 100.0 } else { p };
    let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 90.0) - 4.6).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn percentile_empty_is_zero_at_any_p() {
        for p in [0.0, 50.0, 99.0, 100.0, f64::NAN, f64::INFINITY] {
            assert_eq!(percentile(&[], p), 0.0);
        }
    }

    #[test]
    fn percentile_clamps_out_of_range_p() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, -50.0), 1.0);
        assert_eq!(percentile(&xs, 250.0), 3.0);
        assert_eq!(percentile(&xs, f64::NEG_INFINITY), 1.0);
        assert_eq!(percentile(&xs, f64::INFINITY), 3.0);
    }

    #[test]
    fn percentile_nan_p_reads_as_upper_tail() {
        let xs = [1.0, 2.0, 3.0];
        let v = percentile(&xs, f64::NAN);
        assert!(!v.is_nan());
        assert_eq!(v, 3.0);
        // Single-sample path is NaN-p safe too.
        assert_eq!(percentile(&[7.0], f64::NAN), 7.0);
    }

    #[test]
    fn percentile_ignores_nan_samples() {
        // Previously this panicked in sort_by(partial_cmp().unwrap()).
        let xs = [3.0, f64::NAN, 1.0, f64::NAN, 2.0];
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert_eq!(percentile(&xs, 100.0), 3.0);
        let all_nan = [f64::NAN, f64::NAN];
        assert_eq!(percentile(&all_nan, 90.0), 0.0);
    }
}
