//! In-tree substrates replacing crates unavailable in the offline image
//! (`rand`, `serde`/`serde_json`, `clap`, `tokio`): a counter-based PRNG
//! with the distribution samplers the workload generator needs, a JSON
//! parser/serializer, a CLI flag parser, and small thread/channel helpers.

pub mod cli;
pub mod json;
pub mod rng;
pub mod threads;

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// p-th percentile (0..=100) by linear interpolation over a *sorted copy*.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, p)
}

/// p-th percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0).clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 90.0) - 4.6).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }
}
