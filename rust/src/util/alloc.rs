//! Thread-local heap-allocation accounting.
//!
//! The simulator's headline contract (ISSUE 8 / ROADMAP "raw simulator
//! speed") is that the engine's event hot loop performs **zero heap
//! allocations after warmup**: the scheduler heap, the collector's
//! request columns, and the completed-record log are all pooled and
//! recycled between runs. Contracts that aren't measured rot, so the
//! crate installs [`CountingAlloc`] as the global allocator and the
//! engine reports per-run allocation counts in
//! [`RunStats::allocs`](crate::sim::RunStats) — asserted to be exactly
//! zero for a warm run in `sim::engine` tests and surfaced per frontier
//! cell in `BENCH_simperf.json`.
//!
//! The counter is **thread-local**, not a global atomic: a simulation
//! run executes on one thread, and frontier cells (plus speculative
//! probes) run concurrently on sibling threads whose allocations must
//! not pollute each other's deltas. Counting is a single thread-local
//! increment per allocation, cheap enough to leave on unconditionally.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    /// Allocations performed by this thread since it started.
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Number of heap allocations this thread has performed so far.
/// Monotonic per thread; take a delta around a region to count its
/// allocations (frees are not counted — the contract is about *new*
/// heap traffic, and a free implies an earlier counted allocation).
pub fn thread_allocs() -> u64 {
    // `try_with`: during thread teardown the TLS slot may already be
    // destroyed while destructors still allocate/deallocate; report 0
    // rather than aborting the process from inside the allocator.
    ALLOCS.try_with(|c| c.get()).unwrap_or(0)
}

fn bump() {
    let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
}

/// A [`System`] allocator wrapper that counts allocations per thread.
/// Installed once as `#[global_allocator]` in `lib.rs`, so binaries,
/// integration tests, and benches all get the same accounting.
pub struct CountingAlloc;

// SAFETY: delegates every operation unchanged to `System`; the only
// addition is a thread-local counter bump, which does not allocate.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_increments_on_allocation() {
        let before = thread_allocs();
        let v: Vec<u64> = Vec::with_capacity(32);
        let after = thread_allocs();
        assert!(after > before, "Vec::with_capacity must count as an allocation");
        drop(v);
        // Frees are not counted.
        assert_eq!(thread_allocs(), after);
    }

    #[test]
    fn pure_stack_work_is_free() {
        // Pre-touch TLS, then a stack-only region must count zero.
        let _ = thread_allocs();
        let before = thread_allocs();
        let mut acc = 0u64;
        for i in 0..1000u64 {
            acc = acc.wrapping_add(i * i);
        }
        assert!(acc > 0);
        assert_eq!(thread_allocs(), before);
    }

    #[test]
    fn counts_are_per_thread() {
        let before = thread_allocs();
        std::thread::scope(|s| {
            s.spawn(|| {
                // Allocate heavily on a sibling thread.
                let mut v = Vec::new();
                for i in 0..100u64 {
                    v.push(vec![i; 16]);
                }
            });
        });
        // Joining the scope allocates nothing on *this* thread beyond
        // the spawn bookkeeping that happened before the region — the
        // sibling's 100+ allocations must not leak into our counter.
        let delta = thread_allocs() - before;
        assert!(delta < 50, "sibling-thread allocations leaked: {delta}");
    }
}
