//! Deterministic PRNG + distribution samplers (substrate for the `rand`
//! crate, unavailable offline — see DESIGN.md §2).
//!
//! The generator is PCG64 (O'Neill 2014, `pcg_xsl_rr_128_64`): a 128-bit
//! LCG with an output permutation — small state, solid statistical quality,
//! and cheap `fork()` for deterministic per-component streams. Everything
//! in the simulator and workload generator draws from this, so every
//! experiment in EXPERIMENTS.md is reproducible bit-for-bit from its seed.

/// PCG64 pseudo-random number generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different stream ids
    /// yield statistically independent sequences for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent generator (new stream) from this one — used to
    /// give each simulator component its own deterministic stream.
    pub fn fork(&mut self, salt: u64) -> Pcg64 {
        let seed = self.next_u64();
        Pcg64::new(seed, salt.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1)
    }

    /// Next raw 64-bit output (XSL-RR permutation of the 128-bit state).
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a log() argument.
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Marsaglia polar (no trig, fast enough for traces).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean / stddev.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate lambda (mean 1/lambda) — Poisson inter-arrivals.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64_open().ln() / lambda
    }

    /// Log-normal with *underlying* normal parameters mu, sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson-distributed count with mean `lambda` (Knuth below 30, normal
    /// approximation above — we only use it for per-tick arrival counts).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal_with(lambda, lambda.sqrt());
            x.max(0.0).round() as u64
        }
    }

    /// Zipf-distributed rank in [1, n] with exponent `s` (rejection-free
    /// inverse-CDF over precomputed weights is overkill; we use the
    /// rejection sampler of Devroye). Used by multi-tenant workload mixes.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        assert!(n >= 1);
        if n == 1 {
            return 1;
        }
        // Devroye's rejection method for the Zipf distribution.
        let b = 2f64.powf(s - 1.0);
        loop {
            let u = self.f64_open();
            let v = self.f64();
            let x = (u.powf(-1.0 / (s - 1.0))).floor();
            if x < 1.0 || x > n as f64 {
                continue;
            }
            let t = (1.0 + 1.0 / x).powf(s - 1.0);
            if v * x * (t - 1.0) / (b - 1.0) <= t / b {
                return x as u64;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(1, 0);
        let mut b = Pcg64::new(1, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Pcg64::seeded(5);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::seeded(11);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.exponential(2.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_variance() {
        let mut r = Pcg64::seeded(13);
        for lambda in [0.5, 5.0, 80.0] {
            let n = 20_000;
            let xs: Vec<f64> = (0..n).map(|_| r.poisson(lambda) as f64).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var =
                xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
            assert!((mean - lambda).abs() < lambda.max(1.0) * 0.05, "{lambda} {mean}");
            assert!((var - lambda).abs() < lambda.max(1.0) * 0.12, "{lambda} {var}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Pcg64::seeded(19);
        let n = 50_000;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(3.0, 0.8)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        // median of lognormal = exp(mu)
        assert!((median - 3.0f64.exp()).abs() / 3.0f64.exp() < 0.05, "{median}");
    }

    #[test]
    fn zipf_rank_one_most_common() {
        let mut r = Pcg64::seeded(23);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..20_000 {
            *counts.entry(r.zipf(10, 1.5)).or_insert(0u32) += 1;
        }
        let c1 = counts[&1];
        let c2 = *counts.get(&2).unwrap_or(&0);
        assert!(c1 > c2, "{counts:?}");
        assert!(counts.keys().all(|&k| (1..=10).contains(&k)));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(29);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_independent() {
        let mut root = Pcg64::seeded(31);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
