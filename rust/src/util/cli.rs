//! Tiny CLI argument parser (substrate for `clap`, unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and trailing
//! positional arguments. The launcher (`rust/src/main.rs`) and the examples
//! use it for subcommand-style interfaces.

use std::collections::BTreeMap;

/// Parsed command line: positionals + `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// True when the boolean switch `--name` is enabled — as a bare flag
    /// (`--name`) or with a truthy value (`--name=1`, `--name true`).
    /// Explicitly falsy values (`0`/`false`/`no`/`off`) disable it, so
    /// `--quick=false` means what it says instead of silently enabling
    /// quick mode. Switches may need the `=value` form when followed by a
    /// non-flag token, since `--name foo` parses as an option.
    pub fn has(&self, name: &str) -> bool {
        if self.has_flag(name) {
            return true;
        }
        match self.options.get(name) {
            Some(v) => !matches!(
                v.to_ascii_lowercase().as_str(),
                "0" | "false" | "no" | "off"
            ),
            None => false,
        }
    }

    /// A filesystem-path option (`--log trace.jsonl`). Distinguishes a
    /// missing value from a missing flag so callers can error usefully:
    /// `--log` followed by another `--flag` (or nothing) parses as a bare
    /// flag, and `Err` names the switch that lost its value.
    pub fn get_path(&self, key: &str) -> Result<Option<std::path::PathBuf>, String> {
        if let Some(v) = self.get(key) {
            return Ok(Some(std::path::PathBuf::from(v)));
        }
        if self.has_flag(key) {
            return Err(format!("--{key} needs a value (e.g. --{key} <path>)"));
        }
        Ok(None)
    }

    /// First positional (the subcommand), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("simulate trailing --system ecoserve --rate 3.5 --verbose");
        assert_eq!(a.command(), Some("simulate"));
        assert_eq!(a.get("system"), Some("ecoserve"));
        assert_eq!(a.get_f64("rate", 0.0), 3.5);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["simulate", "trailing"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("--n=4 --name=macro-1");
        assert_eq!(a.get_usize("n", 0), 4);
        assert_eq!(a.get("name"), Some("macro-1"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --dry-run");
        assert!(a.has_flag("dry-run"));
    }

    #[test]
    fn has_accepts_flag_or_option_form() {
        let a = parse("frontier --quick --autoscale=1 --level p90");
        assert!(a.has("quick"));
        assert!(a.has("autoscale"));
        assert!(a.has("level"));
        assert!(!a.has("out"));
        // A switch followed by another --flag parses as a bare flag.
        let b = parse("frontier --autoscale --quick");
        assert!(b.has("autoscale") && b.has("quick"));
    }

    #[test]
    fn has_rejects_explicitly_falsy_values() {
        let a = parse("frontier --quick=false --autoscale=0 --verbose=off --x=no");
        assert!(!a.has("quick"));
        assert!(!a.has("autoscale"));
        assert!(!a.has("verbose"));
        assert!(!a.has("x"));
        let b = parse("frontier --quick=true --autoscale=yes");
        assert!(b.has("quick") && b.has("autoscale"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_u64("seed", 42), 42);
        assert!(a.command().is_none());
    }

    #[test]
    fn get_path_distinguishes_missing_value_from_missing_flag() {
        let a = parse("scenarios --replay logs/trace.jsonl");
        let p = a.get_path("replay").unwrap().unwrap();
        assert_eq!(p, std::path::PathBuf::from("logs/trace.jsonl"));
        assert_eq!(a.get_path("out"), Ok(None));
        // Value swallowed by the next switch: error, not silent None.
        let b = parse("frontier --replay --quick");
        let err = b.get_path("replay").unwrap_err();
        assert!(err.contains("--replay"), "{err}");
    }
}
