//! Tiny CLI argument parser (substrate for `clap`, unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and trailing
//! positional arguments, plus a declarative flag-spec layer: each launcher
//! subcommand declares its flags once in [`COMMANDS`], and
//! [`Args::check`] rejects unknown flags and value-less value-taking
//! flags uniformly, while [`CommandSpec::help_text`] generates the
//! per-subcommand `--help` text from the same table.

use std::collections::BTreeMap;

/// Parsed command line: positionals + `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Single-value flags supplied more than once, with both values —
    /// `(--key, first, second)`. The map keeps the last value, but
    /// [`Args::check`] turns any entry here into an up-front error
    /// instead of letting the earlier value vanish silently.
    pub duplicates: Vec<(String, String, String)>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.note_option(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.note_option(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Record a `--key value` occurrence (either `=` or space form),
    /// remembering repeats so [`Args::check`] can reject them.
    fn note_option(&mut self, key: String, value: String) {
        if let Some(prev) = self.options.insert(key.clone(), value.clone()) {
            self.duplicates.push((key, prev, value));
        }
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// True when the boolean switch `--name` is enabled — as a bare flag
    /// (`--name`) or with a truthy value (`--name=1`, `--name true`).
    /// Explicitly falsy values (`0`/`false`/`no`/`off`) disable it, so
    /// `--quick=false` means what it says instead of silently enabling
    /// quick mode. Switches may need the `=value` form when followed by a
    /// non-flag token, since `--name foo` parses as an option.
    pub fn has(&self, name: &str) -> bool {
        if self.has_flag(name) {
            return true;
        }
        match self.options.get(name) {
            Some(v) => !matches!(
                v.to_ascii_lowercase().as_str(),
                "0" | "false" | "no" | "off"
            ),
            None => false,
        }
    }

    /// A filesystem-path option (`--log trace.jsonl`). Distinguishes a
    /// missing value from a missing flag so callers can error usefully:
    /// `--log` followed by another `--flag` (or nothing) parses as a bare
    /// flag, and `Err` names the switch that lost its value.
    pub fn get_path(&self, key: &str) -> Result<Option<std::path::PathBuf>, String> {
        if let Some(v) = self.get(key) {
            return Ok(Some(std::path::PathBuf::from(v)));
        }
        if self.has_flag(key) {
            return Err(format!("--{key} needs a value (e.g. --{key} <path>)"));
        }
        Ok(None)
    }

    /// An optional numeric flag that errors loudly on a typo — or on a
    /// value-less `--flag` (which the parser files as a boolean switch) —
    /// instead of silently falling back to a default: `--loop` without a
    /// horizon must not quietly run the un-tiled replay.
    pub fn f64_flag(&self, key: &str) -> Result<Option<f64>, String> {
        match self.get(key) {
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{key} expects a number, got '{v}'")),
            None if self.has_flag(key) => {
                Err(format!("--{key} needs a numeric value (e.g. --{key}=30)"))
            }
            None => Ok(None),
        }
    }

    /// [`Args::f64_flag`] for unsigned integers (`--gpus 32`).
    pub fn usize_flag(&self, key: &str) -> Result<Option<usize>, String> {
        match self.get(key) {
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
            None if self.has_flag(key) => {
                Err(format!("--{key} needs an integer value (e.g. --{key}=4)"))
            }
            None => Ok(None),
        }
    }

    /// [`Args::f64_flag`] for u64 values (`--seed 7`).
    pub fn u64_flag(&self, key: &str) -> Result<Option<u64>, String> {
        match self.get(key) {
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
            None if self.has_flag(key) => {
                Err(format!("--{key} needs an integer value (e.g. --{key}=7)"))
            }
            None => Ok(None),
        }
    }

    /// Validate every supplied flag against a subcommand's [`CommandSpec`]:
    /// unknown flags error (typos must not silently fall back to
    /// defaults), and a value-taking flag supplied bare (`--rate` followed
    /// by another `--flag` or the end of the line) errors too —
    /// generalizing the `--loop`/`--budget-s` fix to every flag in the
    /// table. A single-value flag supplied more than once (any mix of
    /// `--k v` and `--k=v` forms) errors naming the flag and both values
    /// — the earlier one must not lose silently. `--help` is always
    /// accepted.
    pub fn check(&self, spec: &CommandSpec) -> Result<(), String> {
        if let Some((key, first, second)) = self.duplicates.first() {
            return Err(format!(
                "--{key} given more than once ('{first}', then '{second}') \
                 for '{}'; supply it exactly once",
                spec.name
            ));
        }
        for key in self.options.keys() {
            if key == "help" {
                continue;
            }
            if spec.flag(key).is_none() {
                return Err(format!(
                    "unknown flag --{key} for '{}' (see `ecoserve {} --help`)",
                    spec.name, spec.name
                ));
            }
        }
        for name in &self.flags {
            if name == "help" {
                continue;
            }
            match spec.flag(name) {
                None => {
                    return Err(format!(
                        "unknown flag --{name} for '{}' (see `ecoserve {} --help`)",
                        spec.name, spec.name
                    ));
                }
                Some(f) => {
                    if let Some(metavar) = f.value {
                        return Err(format!(
                            "--{name} needs a value (e.g. --{name} <{metavar}>)"
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// First positional (the subcommand), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

/// One flag a subcommand accepts: a value-taking option (`value` is the
/// metavar shown in help) or a boolean switch (`value: None`).
#[derive(Debug, Clone, Copy)]
pub struct FlagSpec {
    pub name: &'static str,
    pub value: Option<&'static str>,
    pub help: &'static str,
}

impl FlagSpec {
    /// A value-taking flag (`--name <METAVAR>`).
    pub const fn opt(
        name: &'static str,
        value: &'static str,
        help: &'static str,
    ) -> FlagSpec {
        FlagSpec { name, value: Some(value), help }
    }

    /// A boolean switch (`--name`).
    pub const fn switch(name: &'static str, help: &'static str) -> FlagSpec {
        FlagSpec { name, value: None, help }
    }
}

/// One launcher subcommand: its summary plus the full flag table the
/// generated `--help` and [`Args::check`] are driven by.
#[derive(Debug, Clone, Copy)]
pub struct CommandSpec {
    pub name: &'static str,
    pub summary: &'static str,
    pub flags: &'static [FlagSpec],
}

impl CommandSpec {
    pub fn flag(&self, name: &str) -> Option<&FlagSpec> {
        self.flags.iter().find(|f| f.name == name)
    }

    /// Generated per-subcommand help text (locked by a golden test).
    pub fn help_text(&self) -> String {
        let mut out = format!(
            "usage: ecoserve {} [flags]\n\n  {}\n\nflags:\n",
            self.name, self.summary
        );
        for f in self.flags {
            let left = match f.value {
                Some(mv) => format!("--{} <{}>", f.name, mv),
                None => format!("--{}", f.name),
            };
            out.push_str(&format!("  {:<22} {}\n", left, f.help));
        }
        out.push_str(&format!("  {:<22} {}\n", "--help", "show this help"));
        out
    }
}

// ---- shared flag literals ---------------------------------------------

const MODEL: FlagSpec =
    FlagSpec::opt("model", "NAME", "model preset (codellama-34b|llama-30b|qwen2-72b)");
const CLUSTER: FlagSpec = FlagSpec::opt("cluster", "NAME", "cluster preset (l20|a800)");
const TP: FlagSpec = FlagSpec::opt("tp", "N", "tensor-parallel degree override");
const PP: FlagSpec = FlagSpec::opt("pp", "N", "pipeline-parallel degree override");
const GPUS: FlagSpec = FlagSpec::opt("gpus", "N", "total GPUs used (sets instance count)");
const DATASET: FlagSpec =
    FlagSpec::opt("dataset", "NAME", "workload dataset (sharegpt|alpaca|longbench)");
const SEED: FlagSpec = FlagSpec::opt("seed", "N", "trace RNG seed");
const SYSTEM: FlagSpec =
    FlagSpec::opt("system", "NAME", "serving system (ecoserve|vllm|sarathi|distserve|mooncake)");
const LEVEL: FlagSpec = FlagSpec::opt("level", "PCT", "attainment level (p50|p90|p99)");
const SCENARIO: FlagSpec = FlagSpec::opt("scenario", "NAME", "one named scenario");
const REPLAY: FlagSpec =
    FlagSpec::opt("replay", "LOG", "replay a recorded arrival log (JSONL)");
const LOOP: FlagSpec =
    FlagSpec::opt("loop", "SECS", "tile the --replay log to at least this horizon");
const IMPORT: FlagSpec = FlagSpec::opt(
    "import",
    "FILE",
    "stream-replay an external trace (CSV, gzip ok; see --format)",
);
const FORMAT: FlagSpec =
    FlagSpec::opt("format", "NAME", "external trace format for --import (burstgpt|azure)");
const WINDOW: FlagSpec = FlagSpec::opt(
    "window",
    "SECS",
    "reorder tolerance for --import timestamps (default 5)",
);
const DURATION: FlagSpec = FlagSpec::opt("duration", "SECS", "trace duration override");
const OUT: FlagSpec = FlagSpec::opt("out", "PATH", "write the JSON report here");
const BUDGET_S: FlagSpec =
    FlagSpec::opt("budget-s", "SECS", "wall-clock budget per search cell");
const FAULT_SEED: FlagSpec = FlagSpec::opt(
    "fault-seed",
    "N",
    "fault-schedule RNG seed for churn scenarios (default: --seed)",
);
const TRACE_OUT: FlagSpec = FlagSpec::opt(
    "trace-out",
    "PATH",
    "write BENCH_trace.json here (+ Perfetto sibling *.perfetto.json)",
);

/// Every launcher subcommand, declared once: the dispatch table,
/// [`Args::check`], and the generated `--help` all read from here.
pub static COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "serve",
        summary: "live serving on PJRT-CPU instances (needs the `pjrt` feature)",
        flags: &[
            FlagSpec::opt("instances", "N", "live instance count"),
            FlagSpec::opt("rate", "RPS", "Poisson arrival rate"),
            DURATION,
            SEED,
            FlagSpec::opt("artifacts", "DIR", "TinyLM artifact directory"),
        ],
    },
    CommandSpec {
        name: "simulate",
        summary: "one simulated run of a system at a fixed request rate",
        flags: &[
            SYSTEM,
            MODEL,
            CLUSTER,
            TP,
            PP,
            GPUS,
            DATASET,
            SEED,
            FlagSpec::opt("rate", "RPS", "Poisson arrival rate"),
            DURATION,
            FlagSpec::opt("warmup", "SECS", "scoring warm-up prefix"),
        ],
    },
    CommandSpec {
        name: "goodput",
        summary: "goodput search (paper \u{a7}4.1) for one system",
        flags: &[
            SYSTEM,
            MODEL,
            CLUSTER,
            TP,
            PP,
            GPUS,
            DATASET,
            SEED,
            LEVEL,
            DURATION,
            FlagSpec::opt("warmup", "SECS", "scoring warm-up prefix"),
            FlagSpec::switch("curve", "print every probed operating point"),
        ],
    },
    CommandSpec {
        name: "scenarios",
        summary: "the multi-scenario evaluation suite",
        flags: &[
            FlagSpec::switch("list", "list the scenario registry and exit"),
            SCENARIO,
            REPLAY,
            LOOP,
            IMPORT,
            FORMAT,
            WINDOW,
            SYSTEM,
            MODEL,
            CLUSTER,
            TP,
            PP,
            GPUS,
            SEED,
            FAULT_SEED,
            FlagSpec::opt("rate", "RPS", "offered rate override"),
            DURATION,
            OUT,
            FlagSpec::opt(
                "churn-out",
                "PATH",
                "write BENCH_churn.json (clean-vs-faulted pairs) here",
            ),
            FlagSpec::opt(
                "overload-out",
                "PATH",
                "write BENCH_overload.json (undefended-vs-defended load sweep) here",
            ),
            TRACE_OUT,
        ],
    },
    CommandSpec {
        name: "frontier",
        summary: "goodput-frontier sweep per scenario x system",
        flags: &[
            SCENARIO,
            REPLAY,
            LOOP,
            IMPORT,
            FORMAT,
            WINDOW,
            SYSTEM,
            LEVEL,
            MODEL,
            CLUSTER,
            TP,
            PP,
            GPUS,
            SEED,
            FAULT_SEED,
            DURATION,
            FlagSpec::switch("autoscale", "add a mitosis-on PaDG variant"),
            FlagSpec::switch("quick", "coarse search for CI smoke runs"),
            FlagSpec::switch("no-abandon", "run doomed probes to completion"),
            FlagSpec::switch("no-speculate", "probe bisection rates serially"),
            BUDGET_S,
            OUT,
            FlagSpec::opt("perf-out", "PATH", "write BENCH_simperf.json here"),
            TRACE_OUT,
        ],
    },
    CommandSpec {
        name: "plan",
        summary: "capacity planner: goodput-per-dollar over deployments",
        flags: &[
            SCENARIO,
            REPLAY,
            LOOP,
            IMPORT,
            FORMAT,
            WINDOW,
            MODEL,
            CLUSTER,
            GPUS,
            SYSTEM,
            LEVEL,
            SEED,
            FAULT_SEED,
            FlagSpec::switch("quick", "coarse search for CI smoke runs"),
            FlagSpec::switch("spot", "also price spot-GPU twins (discount + reclaim churn)"),
            FlagSpec::opt("target-rate", "RPS", "also report the cheapest config meeting this"),
            BUDGET_S,
            DURATION,
            OUT,
        ],
    },
    CommandSpec {
        name: "record",
        summary: "export a scenario's trace as a replay log (JSONL)",
        flags: &[
            SCENARIO,
            REPLAY,
            LOOP,
            IMPORT,
            FORMAT,
            WINDOW,
            DURATION,
            SEED,
            FlagSpec::opt("rate", "RPS", "offered rate override"),
            OUT,
        ],
    },
    CommandSpec {
        name: "table2",
        summary: "print the arithmetic-intensity table",
        flags: &[
            FlagSpec::opt("batch", "B", "batch size"),
            FlagSpec::opt("seq", "S", "sequence length"),
            FlagSpec::opt("hidden", "H", "hidden size"),
            FlagSpec::opt("heads", "M", "attention heads"),
        ],
    },
    CommandSpec {
        name: "table3",
        summary: "print the KV-bandwidth table",
        flags: &[],
    },
];

/// Look up a subcommand's spec by name.
pub fn command_spec(name: &str) -> Option<&'static CommandSpec> {
    COMMANDS.iter().find(|c| c.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("simulate trailing --system ecoserve --rate 3.5 --verbose");
        assert_eq!(a.command(), Some("simulate"));
        assert_eq!(a.get("system"), Some("ecoserve"));
        assert_eq!(a.get_f64("rate", 0.0), 3.5);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["simulate", "trailing"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("--n=4 --name=macro-1");
        assert_eq!(a.get_usize("n", 0), 4);
        assert_eq!(a.get("name"), Some("macro-1"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --dry-run");
        assert!(a.has_flag("dry-run"));
    }

    #[test]
    fn has_accepts_flag_or_option_form() {
        let a = parse("frontier --quick --autoscale=1 --level p90");
        assert!(a.has("quick"));
        assert!(a.has("autoscale"));
        assert!(a.has("level"));
        assert!(!a.has("out"));
        // A switch followed by another --flag parses as a bare flag.
        let b = parse("frontier --autoscale --quick");
        assert!(b.has("autoscale") && b.has("quick"));
    }

    #[test]
    fn has_rejects_explicitly_falsy_values() {
        let a = parse("frontier --quick=false --autoscale=0 --verbose=off --x=no");
        assert!(!a.has("quick"));
        assert!(!a.has("autoscale"));
        assert!(!a.has("verbose"));
        assert!(!a.has("x"));
        let b = parse("frontier --quick=true --autoscale=yes");
        assert!(b.has("quick") && b.has("autoscale"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_u64("seed", 42), 42);
        assert!(a.command().is_none());
    }

    #[test]
    fn get_path_distinguishes_missing_value_from_missing_flag() {
        let a = parse("scenarios --replay logs/trace.jsonl");
        let p = a.get_path("replay").unwrap().unwrap();
        assert_eq!(p, std::path::PathBuf::from("logs/trace.jsonl"));
        assert_eq!(a.get_path("out"), Ok(None));
        // Value swallowed by the next switch: error, not silent None.
        let b = parse("frontier --replay --quick");
        let err = b.get_path("replay").unwrap_err();
        assert!(err.contains("--replay"), "{err}");
    }

    #[test]
    fn typed_flags_error_on_bare_and_garbage_values() {
        let a = parse("scenarios --rate fast --seed 7");
        assert!(a.f64_flag("rate").unwrap_err().contains("--rate"));
        assert_eq!(a.u64_flag("seed"), Ok(Some(7)));
        assert_eq!(a.f64_flag("duration"), Ok(None));
        // A value-less value flag parses as a boolean switch: error.
        let b = parse("scenarios --rate --out x.json");
        assert!(b.f64_flag("rate").unwrap_err().contains("numeric"));
        let c = parse("plan --gpus");
        assert!(c.usize_flag("gpus").unwrap_err().contains("--gpus"));
    }

    #[test]
    fn check_rejects_unknown_flags_and_bare_value_flags() {
        let spec = command_spec("scenarios").unwrap();
        assert!(parse("scenarios --scenario bursty --seed 7").check(spec).is_ok());
        // Unknown option and unknown switch both error, naming the command.
        let err = parse("scenarios --senario bursty").check(spec).unwrap_err();
        assert!(err.contains("--senario") && err.contains("scenarios"), "{err}");
        let err = parse("scenarios --frobnicate").check(spec).unwrap_err();
        assert!(err.contains("--frobnicate"), "{err}");
        // A value-taking flag supplied bare errors up front (the PR 5
        // --loop/--budget-s fix, generalized to the whole table).
        let err = parse("scenarios --scenario --out x.json").check(spec).unwrap_err();
        assert!(err.contains("--scenario") && err.contains("value"), "{err}");
        // --help is always accepted, and switches stay valid bare or =v.
        assert!(parse("scenarios --list --help").check(spec).is_ok());
        let fr = command_spec("frontier").unwrap();
        assert!(parse("frontier --quick --autoscale=1 --no-abandon").check(fr).is_ok());
        let err = parse("frontier --budget-s").check(fr).unwrap_err();
        assert!(err.contains("--budget-s"), "{err}");
    }

    #[test]
    fn check_rejects_duplicate_value_flags() {
        let spec = command_spec("scenarios").unwrap();
        // Space form twice, = form twice, and a mix: all error, naming
        // the flag, both values, and the command.
        for line in [
            "scenarios --rate 3 --rate 4",
            "scenarios --rate=3 --rate=4",
            "scenarios --rate 3 --rate=4",
        ] {
            let err = parse(line).check(spec).unwrap_err();
            assert!(err.contains("--rate"), "{line}: {err}");
            assert!(err.contains("'3'") && err.contains("'4'"), "{line}: {err}");
            assert!(err.contains("scenarios"), "{line}: {err}");
        }
        // Repeating the same value is still a duplicate (the intent is
        // ambiguous), and unrelated singles stay fine.
        assert!(parse("scenarios --seed 7 --seed 7").check(spec).is_err());
        assert!(parse("scenarios --rate 3 --seed 7").check(spec).is_ok());
        // Parse itself stays infallible: the map keeps the last value.
        let a = parse("scenarios --rate 3 --rate 4");
        assert_eq!(a.get("rate"), Some("4"));
        assert_eq!(a.duplicates.len(), 1);
    }

    #[test]
    fn every_subcommand_has_a_spec_with_unique_flags() {
        for cmd in ["serve", "simulate", "goodput", "scenarios", "frontier",
                    "plan", "record", "table2", "table3"] {
            let spec = command_spec(cmd).expect(cmd);
            let mut names: Vec<&str> = spec.flags.iter().map(|f| f.name).collect();
            names.sort_unstable();
            names.dedup();
            assert_eq!(names.len(), spec.flags.len(), "{cmd}: duplicate flag");
        }
        assert!(command_spec("frobnicate").is_none());
    }

    /// The generated help text is part of the CLI surface: lock it.
    #[test]
    fn golden_help_text_for_record() {
        let spec = command_spec("record").unwrap();
        let expected = "\
usage: ecoserve record [flags]

  export a scenario's trace as a replay log (JSONL)

flags:
  --scenario <NAME>      one named scenario
  --replay <LOG>         replay a recorded arrival log (JSONL)
  --loop <SECS>          tile the --replay log to at least this horizon
  --import <FILE>        stream-replay an external trace (CSV, gzip ok; see --format)
  --format <NAME>        external trace format for --import (burstgpt|azure)
  --window <SECS>        reorder tolerance for --import timestamps (default 5)
  --duration <SECS>      trace duration override
  --seed <N>             trace RNG seed
  --rate <RPS>           offered rate override
  --out <PATH>           write the JSON report here
  --help                 show this help
";
        assert_eq!(spec.help_text(), expected);
    }

    #[test]
    fn help_text_lists_every_flag() {
        for spec in COMMANDS {
            let help = spec.help_text();
            assert!(help.starts_with(&format!("usage: ecoserve {} [flags]", spec.name)));
            for f in spec.flags {
                assert!(help.contains(&format!("--{}", f.name)), "{}: {}", spec.name, f.name);
            }
            assert!(help.contains("--help"));
        }
    }
}
