//! Tiny CLI argument parser (substrate for `clap`, unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and trailing
//! positional arguments. The launcher (`rust/src/main.rs`) and the examples
//! use it for subcommand-style interfaces.

use std::collections::BTreeMap;

/// Parsed command line: positionals + `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// True when the boolean switch `--name` is enabled — as a bare flag
    /// (`--name`) or with a truthy value (`--name=1`, `--name true`).
    /// Explicitly falsy values (`0`/`false`/`no`/`off`) disable it, so
    /// `--quick=false` means what it says instead of silently enabling
    /// quick mode. Switches may need the `=value` form when followed by a
    /// non-flag token, since `--name foo` parses as an option.
    pub fn has(&self, name: &str) -> bool {
        if self.has_flag(name) {
            return true;
        }
        match self.options.get(name) {
            Some(v) => !matches!(
                v.to_ascii_lowercase().as_str(),
                "0" | "false" | "no" | "off"
            ),
            None => false,
        }
    }

    /// First positional (the subcommand), if any.
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positionals_and_options() {
        let a = parse("simulate trailing --system ecoserve --rate 3.5 --verbose");
        assert_eq!(a.command(), Some("simulate"));
        assert_eq!(a.get("system"), Some("ecoserve"));
        assert_eq!(a.get_f64("rate", 0.0), 3.5);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["simulate", "trailing"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("--n=4 --name=macro-1");
        assert_eq!(a.get_usize("n", 0), 4);
        assert_eq!(a.get("name"), Some("macro-1"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --dry-run");
        assert!(a.has_flag("dry-run"));
    }

    #[test]
    fn has_accepts_flag_or_option_form() {
        let a = parse("frontier --quick --autoscale=1 --level p90");
        assert!(a.has("quick"));
        assert!(a.has("autoscale"));
        assert!(a.has("level"));
        assert!(!a.has("out"));
        // A switch followed by another --flag parses as a bare flag.
        let b = parse("frontier --autoscale --quick");
        assert!(b.has("autoscale") && b.has("quick"));
    }

    #[test]
    fn has_rejects_explicitly_falsy_values() {
        let a = parse("frontier --quick=false --autoscale=0 --verbose=off --x=no");
        assert!(!a.has("quick"));
        assert!(!a.has("autoscale"));
        assert!(!a.has("verbose"));
        assert!(!a.has("x"));
        let b = parse("frontier --quick=true --autoscale=yes");
        assert!(b.has("quick") && b.has("autoscale"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_u64("seed", 42), 42);
        assert!(a.command().is_none());
    }
}
