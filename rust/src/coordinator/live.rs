//! The live PaDG coordinator: EcoServe's scheduling hierarchy driving
//! *real* PJRT-backed instances (runtime::Engine) on wall-clock time.
//!
//! Mirrors the paper's implementation shape — instance workers as actors
//! with an RPC-like mailbox (the Ray analogue, util::threads), a
//! macro-instance scheduler routing with Algorithms 1+2 over reported
//! status, and strict §3.3 timing measured by the metrics collector. The
//! constraint inputs that the simulator computes analytically are here
//! *measured*: per-token prefill time as an EMA, saved-TPOT slack from real
//! first-token timestamps.

use std::collections::VecDeque;
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::metrics::{Collector, SloSpec};
use crate::runtime::engine::{argmax, Engine};
use crate::runtime::tokenizer::EOS;
use crate::util::threads::{Actor, Inbox};
use crate::workload::Request;

/// A request on the live path.
#[derive(Debug, Clone)]
pub struct LiveRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
}

/// Commands into an instance worker (the RPC surface of the paper's
/// `InstanceHandler`: "prefill"/"decode_step" are implicit in Admit).
pub enum InstCmd {
    Admit(LiveRequest),
    Shutdown,
}

/// Status an instance reports upward after every step (paper §3.2.2:
/// "instances require to constantly update their statuses").
#[derive(Debug, Clone)]
pub struct InstanceStatus {
    pub instance: usize,
    pub pending_prefill_tokens: usize,
    pub running: usize,
    /// Mean saved-TPOT slack of in-flight decodes, seconds (Algorithm 2).
    pub mean_saved_tpot: f64,
    pub kv_free_tokens: usize,
    /// Measured seconds per prefilled token (EMA).
    pub prefill_secs_per_token: f64,
}

/// Events out of instance workers.
pub enum WorkerEvent {
    First { id: u64, at: Instant },
    Token { id: u64, at: Instant },
    Done { id: u64, at: Instant },
    Status(InstanceStatus),
    Fatal { instance: usize, error: String },
}

struct RunningReq {
    id: u64,
    next_token: u32,
    generated: usize,
    max_new: usize,
    first_at: Instant,
}

/// Instance worker main loop: temporal disaggregation on real hardware —
/// drain admitted prefills first (a contiguous prefill window), otherwise
/// run batched decode steps.
fn worker_loop(
    instance: usize,
    artifacts: std::path::PathBuf,
    kv_capacity: usize,
    slo_tpot: f64,
    rx: std::sync::mpsc::Receiver<InstCmd>,
    events: std::sync::mpsc::Sender<WorkerEvent>,
) {
    let mut engine = match Engine::load(&artifacts, Some(kv_capacity)) {
        Ok(e) => e,
        Err(e) => {
            let _ = events.send(WorkerEvent::Fatal {
                instance,
                error: format!("{e:#}"),
            });
            return;
        }
    };
    // Readiness: executables are compiled; report before accepting work so
    // the coordinator can hold traffic until the fleet is warm.
    let _ = events.send(WorkerEvent::Status(InstanceStatus {
        instance,
        pending_prefill_tokens: 0,
        running: 0,
        mean_saved_tpot: f64::INFINITY,
        kv_free_tokens: engine.kv.free_blocks() * engine.kv.cfg.block_tokens,
        prefill_secs_per_token: 2e-3,
    }));
    let mut queue: VecDeque<LiveRequest> = VecDeque::new();
    let mut running: Vec<RunningReq> = Vec::new();
    let mut shutdown = false;
    let mut prefill_ema = 2e-3f64; // seconds/token prior; refined by measurement

    let send_status = |engine: &Engine,
                       queue: &VecDeque<LiveRequest>,
                       running: &Vec<RunningReq>,
                       ema: f64| {
        let now = Instant::now();
        let slack = if running.is_empty() {
            f64::INFINITY
        } else {
            running
                .iter()
                .map(|r| r.generated as f64 * slo_tpot
                    - now.duration_since(r.first_at).as_secs_f64())
                .sum::<f64>()
                / running.len() as f64
        };
        let _ = events.send(WorkerEvent::Status(InstanceStatus {
            instance,
            pending_prefill_tokens: queue.iter().map(|r| r.prompt.len()).sum(),
            running: running.len(),
            mean_saved_tpot: slack,
            kv_free_tokens: engine.kv.free_blocks() * engine.kv.cfg.block_tokens,
            prefill_secs_per_token: ema,
        }));
    };

    loop {
        // Drain the mailbox without blocking.
        while let Ok(cmd) = rx.try_recv() {
            match cmd {
                InstCmd::Admit(r) => queue.push_back(r),
                InstCmd::Shutdown => shutdown = true,
            }
        }
        if shutdown && queue.is_empty() && running.is_empty() {
            send_status(&engine, &queue, &running, prefill_ema);
            return;
        }

        if let Some(req) = queue.pop_front() {
            // Prefill window: prompts drain back-to-back before any decode.
            let t0 = Instant::now();
            match engine.prefill(req.id, &req.prompt) {
                Ok(out) => {
                    let dt = t0.elapsed().as_secs_f64();
                    prefill_ema = 0.7 * prefill_ema + 0.3 * dt / req.prompt.len() as f64;
                    let at = Instant::now();
                    let _ = events.send(WorkerEvent::First { id: req.id, at });
                    let next = argmax(&out.logits);
                    if req.max_new_tokens <= 1 || next == EOS {
                        engine.release(req.id);
                        let _ = events.send(WorkerEvent::Done { id: req.id, at });
                    } else {
                        running.push(RunningReq {
                            id: req.id,
                            next_token: next,
                            generated: 1,
                            max_new: req.max_new_tokens,
                            first_at: at,
                        });
                    }
                }
                Err(e) => {
                    let _ = events.send(WorkerEvent::Fatal {
                        instance,
                        error: format!("prefill {}: {e:#}", req.id),
                    });
                }
            }
            send_status(&engine, &queue, &running, prefill_ema);
            continue;
        }

        if !running.is_empty() {
            let batch = running.len().min(engine.max_decode_batch());
            let ids: Vec<u64> = running[..batch].iter().map(|r| r.id).collect();
            let toks: Vec<u32> = running[..batch].iter().map(|r| r.next_token).collect();
            match engine.decode(&ids, &toks) {
                Ok(rows) => {
                    let at = Instant::now();
                    let mut i = 0;
                    for row_logits in rows {
                        let r = &mut running[i];
                        r.generated += 1;
                        let _ = events.send(WorkerEvent::Token { id: r.id, at });
                        let next = argmax(&row_logits);
                        let kv_full = r.generated + 1 >= engine.config.max_seq
                            || engine.kv.len_of(r.id).unwrap_or(0) + 1
                                >= engine.config.max_seq;
                        if next == EOS || r.generated >= r.max_new || kv_full {
                            engine.release(r.id);
                            let _ = events.send(WorkerEvent::Done { id: r.id, at });
                            running.swap_remove(i);
                        } else {
                            running[i].next_token = next;
                            i += 1;
                        }
                    }
                }
                Err(e) => {
                    let _ = events.send(WorkerEvent::Fatal {
                        instance,
                        error: format!("decode: {e:#}"),
                    });
                    for r in running.drain(..) {
                        engine.release(r.id);
                        let _ = events.send(WorkerEvent::Done { id: r.id, at: Instant::now() });
                    }
                }
            }
            send_status(&engine, &queue, &running, prefill_ema);
            continue;
        }

        // Idle: block briefly for new work.
        match rx.recv_timeout(std::time::Duration::from_millis(2)) {
            Ok(InstCmd::Admit(r)) => queue.push_back(r),
            Ok(InstCmd::Shutdown) => shutdown = true,
            Err(_) => {}
        }
    }
}

/// The live macro-instance scheduler over `n` PJRT-backed instances.
pub struct LiveCoordinator {
    actors: Vec<Actor<InstCmd>>,
    events: Inbox<WorkerEvent>,
    status: Vec<InstanceStatus>,
    /// Optimistic pending-token estimates updated at admit time (status
    /// messages lag; the scheduler must not over-admit in the gap).
    optimistic_pending: Vec<usize>,
    cursor: usize,
    slo: SloSpec,
    pub collector: Collector,
    backlog: VecDeque<(Request, LiveRequest)>,
    t0: Instant,
    pub fatal_errors: Vec<String>,
    ready: Vec<bool>,
}

impl LiveCoordinator {
    /// Spawn `n` instance workers, each with its own engine compiled from
    /// `artifacts`. Blocks until all workers report their first status.
    pub fn start(
        n: usize,
        artifacts: &Path,
        slo: SloSpec,
        kv_capacity_tokens: usize,
    ) -> Result<Self> {
        let events: Inbox<WorkerEvent> = Inbox::new();
        let mut actors = Vec::with_capacity(n);
        for i in 0..n {
            let tx = events.tx.clone();
            let dir = artifacts.to_path_buf();
            let tpot = slo.tpot;
            actors.push(Actor::spawn(format!("instance-{i}"), move |rx| {
                worker_loop(i, dir, kv_capacity_tokens, tpot, rx, tx)
            }));
        }
        let mut coord = LiveCoordinator {
            actors,
            events,
            status: (0..n)
                .map(|i| InstanceStatus {
                    instance: i,
                    pending_prefill_tokens: 0,
                    running: 0,
                    mean_saved_tpot: f64::INFINITY,
                    kv_free_tokens: kv_capacity_tokens,
                    prefill_secs_per_token: 2e-3,
                })
                .collect(),
            optimistic_pending: vec![0; n],
            cursor: 0,
            slo,
            collector: Collector::new(),
            backlog: VecDeque::new(),
            t0: Instant::now(),
            fatal_errors: Vec::new(),
            ready: vec![false; n],
        };
        // Block until every worker has compiled its executables and
        // reported ready — the arrival clock must not run against cold
        // instances (each engine compiles ~10 AOT buckets at startup).
        let deadline = Instant::now() + std::time::Duration::from_secs(600);
        while !coord.ready.iter().all(|r| *r) {
            coord.pump();
            if !coord.fatal_errors.is_empty() {
                anyhow::bail!("worker failed at startup: {:?}", coord.fatal_errors);
            }
            if Instant::now() > deadline {
                anyhow::bail!("workers failed to become ready within 600s");
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        coord.t0 = Instant::now(); // serving clock starts warm
        Ok(coord)
    }

    pub fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn secs(&self, at: Instant) -> f64 {
        at.duration_since(self.t0).as_secs_f64()
    }

    /// Algorithm 2 over *reported* status (live analogue of
    /// constraints::check_constraints).
    fn admissible(&self, i: usize, prompt_len: usize, waited: f64) -> bool {
        let s = &self.status[i];
        let pending = s.pending_prefill_tokens.max(self.optimistic_pending[i]);
        let t_total = (pending + prompt_len) as f64 * s.prefill_secs_per_token;
        if waited + t_total > self.slo.ttft {
            return false;
        }
        if s.mean_saved_tpot < t_total {
            return false;
        }
        s.kv_free_tokens >= prompt_len + 32
    }

    /// Algorithm 1: sticky-cyclic routing across instance workers.
    fn try_route(&mut self, req: &Request, live: &LiveRequest) -> bool {
        let n = self.actors.len();
        for step in 0..n {
            let i = (self.cursor + step) % n;
            if self.admissible(i, live.prompt.len(), self.now() - req.arrival) {
                self.actors[i].send(InstCmd::Admit(live.clone()));
                self.optimistic_pending[i] += live.prompt.len();
                self.cursor = i;
                return true;
            }
        }
        false
    }

    /// Submit a request (arrival time = now).
    pub fn submit(&mut self, prompt: Vec<u32>, max_new_tokens: usize) -> u64 {
        static NEXT: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);
        let id = NEXT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let req = Request {
            id,
            arrival: self.now(),
            input_len: prompt.len(),
            output_len: max_new_tokens,
        };
        self.collector.on_arrival(&req);
        let live = LiveRequest { id, prompt, max_new_tokens };
        if !self.try_route(&req, &live) {
            self.backlog.push_back((req, live));
        }
        id
    }

    /// Drain worker events into metrics/status and retry the backlog.
    pub fn pump(&mut self) {
        for ev in self.events.drain() {
            match ev {
                WorkerEvent::First { id, at } => {
                    let t = self.secs(at);
                    self.collector.on_first_token(id, t);
                }
                WorkerEvent::Token { id, at } => {
                    let t = self.secs(at);
                    self.collector.on_token(id, t);
                }
                WorkerEvent::Done { id, at } => {
                    let t = self.secs(at);
                    self.collector.on_complete(id, t);
                }
                WorkerEvent::Status(s) => {
                    let i = s.instance;
                    // Status reflects reality; clear the optimistic bump.
                    self.optimistic_pending[i] = s.pending_prefill_tokens;
                    self.ready[i] = true;
                    self.status[i] = s;
                }
                WorkerEvent::Fatal { instance, error } => {
                    self.fatal_errors.push(format!("instance {instance}: {error}"));
                }
            }
        }
        // Retry backlog FIFO.
        while let Some((req, live)) = self.backlog.front().cloned() {
            let hopeless = self.now() - req.arrival > self.slo.ttft;
            let routed = if hopeless {
                // Serve late on the emptiest instance with room.
                let n = self.actors.len();
                let pick = (0..n)
                    .filter(|&i| self.status[i].kv_free_tokens >= live.prompt.len() + 32)
                    .min_by_key(|&i| self.status[i].pending_prefill_tokens
                        + self.optimistic_pending[i]);
                match pick {
                    Some(i) => {
                        self.actors[i].send(InstCmd::Admit(live.clone()));
                        self.optimistic_pending[i] += live.prompt.len();
                        true
                    }
                    None => false,
                }
            } else {
                self.try_route(&req, &live)
            };
            if routed {
                self.backlog.pop_front();
            } else {
                break;
            }
        }
    }

    pub fn in_flight(&self) -> usize {
        self.collector.in_flight() + self.backlog.len()
    }

    /// Block until everything submitted has completed (or `timeout`).
    pub fn drain(&mut self, timeout: std::time::Duration) -> bool {
        let deadline = Instant::now() + timeout;
        while self.in_flight() > 0 {
            if Instant::now() > deadline {
                return false;
            }
            self.pump();
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        true
    }

    /// Shut all workers down and join them.
    pub fn shutdown(&mut self) {
        for a in &self.actors {
            a.send(InstCmd::Shutdown);
        }
        for a in &mut self.actors {
            a.join();
        }
        self.pump();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn live_two_instance_round_trip() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let slo = SloSpec::new(5.0, 1.0);
        let mut coord = LiveCoordinator::start(2, &dir, slo, 4096).unwrap();
        for k in 0..6 {
            let prompt: Vec<u32> = (1..6 + k % 3).map(|x| x as u32 * 3 % 500).collect();
            coord.submit(prompt, 6);
        }
        assert!(coord.drain(std::time::Duration::from_secs(120)), "drain timed out");
        coord.shutdown();
        assert!(coord.fatal_errors.is_empty(), "{:?}", coord.fatal_errors);
        let records = coord.collector.completed();
        assert_eq!(records.len(), 6);
        for r in records {
            assert!(r.ttft() > 0.0);
            assert!(r.completion >= r.first_token);
            assert!(r.output_len >= 1);
        }
    }
}
