//! Algorithm 1 — Inter-Instance Scheduling (sticky-cyclic routing).
//!
//! For an incoming request the macro-instance scheduler first tries the
//! instance that received the *previous* request (stickiness keeps one
//! instance's prefill window filling while the others run long decode
//! phases), then walks the remaining instances cyclically. The first
//! instance whose Algorithm-2 check passes wins. If none qualifies the
//! request stays in the macro-level backlog and is retried at the next
//! scheduling point — rolling activation *emerges* from this loop plus the
//! saved-TPOT constraint: as one instance's slack is consumed, the cursor
//! advances to the next, staggering prefill windows around the ring.

use super::constraints::ConstraintVerdict;
use crate::metrics::SloSpec;
use crate::sim::SimInstance;
use crate::workload::Request;

/// Routing cursor for one macro instance.
#[derive(Debug, Clone, Default)]
pub struct RoutingState {
    /// Position (index into the macro's member list) of the instance that
    /// admitted the previous request.
    pub last: usize,
    /// Verdict counters for observability / tests.
    pub admitted: u64,
    pub deferred: u64,
}

/// Outcome of one routing attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteOutcome {
    /// Admitted by the member at this position (index into `members`).
    Admitted(usize),
    /// No member satisfied Algorithm 2; caller should backlog the request.
    Deferred,
}

/// Route `req` over the macro's `members` (indices into `instances`),
/// starting at the sticky cursor. Does not mutate the instances; the caller
/// performs the actual admission on `Admitted`.
pub fn route(
    state: &mut RoutingState,
    members: &[usize],
    instances: &[SimInstance],
    req: &Request,
    now: f64,
    slo: &SloSpec,
    admission_margin: usize,
) -> RouteOutcome {
    route_with(
        state,
        members,
        instances,
        req,
        now,
        slo,
        admission_margin,
        RouteOpts::default(),
    )
}

/// Ablation switches for [`route_with`] (benches/ablation_padg.rs).
#[derive(Debug, Clone, Copy)]
pub struct RouteOpts {
    /// false: restart every scan at member 0 (no stickiness).
    pub sticky: bool,
    /// false: window budget = whole TTFT (no rolling-activation cap).
    pub window_cap: bool,
    /// true: gate on mean saved-TPOT (paper-literal Algorithm 2).
    pub mean_slack: bool,
    /// false: route onto dead/degraded members anyway (the no-recovery
    /// ablation — the coordinator never learns about the fault).
    pub health_gate: bool,
}

impl Default for RouteOpts {
    fn default() -> Self {
        RouteOpts { sticky: true, window_cap: true, mean_slack: false, health_gate: true }
    }
}

/// [`route`] with ablation switches.
#[allow(clippy::too_many_arguments)]
pub fn route_with(
    state: &mut RoutingState,
    members: &[usize],
    instances: &[SimInstance],
    req: &Request,
    now: f64,
    slo: &SloSpec,
    admission_margin: usize,
    opts: RouteOpts,
) -> RouteOutcome {
    if members.is_empty() {
        state.deferred += 1;
        return RouteOutcome::Deferred;
    }
    let n = members.len();
    // Stagger the ring's prefill windows so together they cover the TTFT
    // budget (see constraints::check_constraints on window_budget).
    let window_budget = if opts.window_cap {
        slo.ttft / n as f64
    } else {
        slo.ttft
    };
    let start = if opts.sticky { state.last.min(n - 1) } else { 0 };
    for step in 0..n {
        let pos = (start + step) % n;
        let inst = &instances[members[pos]];
        if opts.health_gate && inst.health != crate::sim::Health::Up {
            continue; // dead or draining-for-preemption member
        }
        if super::constraints::check_constraints_opt(
            inst, req, now, slo, admission_margin, window_budget, opts.mean_slack,
        ) == ConstraintVerdict::Satisfied
        {
            state.last = pos;
            state.admitted += 1;
            return RouteOutcome::Admitted(pos);
        }
    }
    state.deferred += 1;
    RouteOutcome::Deferred
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::interconnect::LinkSpec;
    use crate::perfmodel::parallelism::ParallelCfg;
    use crate::perfmodel::{BatchTimer, GpuSpec, ModelSpec};

    fn instances(n: usize) -> Vec<SimInstance> {
        (0..n)
            .map(|i| {
                let timer = BatchTimer::new(
                    ModelSpec::llama_30b(),
                    GpuSpec::l20(),
                    ParallelCfg::tp_only(4, LinkSpec::pcie4()),
                );
                SimInstance::new(i, timer, 0.1)
            })
            .collect()
    }

    fn req(id: u64, input: usize) -> Request {
        Request { id, arrival: 0.0, input_len: input, output_len: 50 }
    }

    fn slo() -> SloSpec {
        SloSpec::new(5.0, 0.1)
    }

    #[test]
    fn sticky_prefers_last_instance() {
        let insts = instances(4);
        let mut st = RoutingState { last: 2, ..Default::default() };
        let out = route(&mut st, &[0, 1, 2, 3], &insts, &req(1, 100), 0.0, &slo(), 64);
        assert_eq!(out, RouteOutcome::Admitted(2));
        assert_eq!(st.last, 2);
    }

    #[test]
    fn advances_cyclically_on_violation() {
        let mut insts = instances(3);
        // Fill instance 1 (the sticky target) past its KV capacity.
        insts[1].kv_used = insts[1].kv_capacity;
        let mut st = RoutingState { last: 1, ..Default::default() };
        let out = route(&mut st, &[0, 1, 2], &insts, &req(1, 100), 0.0, &slo(), 64);
        assert_eq!(out, RouteOutcome::Admitted(2)); // 1 -> 2 (next in cycle)
        assert_eq!(st.last, 2);
    }

    #[test]
    fn wraps_around_ring() {
        let mut insts = instances(3);
        insts[2].kv_used = insts[2].kv_capacity;
        let mut st = RoutingState { last: 2, ..Default::default() };
        let out = route(&mut st, &[0, 1, 2], &insts, &req(1, 100), 0.0, &slo(), 64);
        assert_eq!(out, RouteOutcome::Admitted(0));
    }

    #[test]
    fn defers_when_all_full() {
        let mut insts = instances(2);
        for i in &mut insts {
            i.kv_used = i.kv_capacity;
        }
        let mut st = RoutingState::default();
        let out = route(&mut st, &[0, 1], &insts, &req(1, 100), 0.0, &slo(), 64);
        assert_eq!(out, RouteOutcome::Deferred);
        assert_eq!(st.deferred, 1);
    }

    #[test]
    fn empty_macro_defers() {
        let insts = instances(1);
        let mut st = RoutingState::default();
        let out = route(&mut st, &[], &insts, &req(1, 100), 0.0, &slo(), 64);
        assert_eq!(out, RouteOutcome::Deferred);
    }

    #[test]
    fn health_gate_skips_down_members() {
        let mut insts = instances(3);
        insts[1].health = crate::sim::Health::Down;
        let mut st = RoutingState { last: 1, ..Default::default() };
        let out = route(&mut st, &[0, 1, 2], &insts, &req(1, 100), 0.0, &slo(), 64);
        assert_eq!(out, RouteOutcome::Admitted(2), "sticky target is down; cursor advances");
        // With the gate ablated the dead member is routable again.
        insts[1].kv_used = 0;
        let mut st = RoutingState { last: 1, ..Default::default() };
        let opts = RouteOpts { health_gate: false, ..Default::default() };
        let out = route_with(&mut st, &[0, 1, 2], &insts, &req(1, 100), 0.0, &slo(), 64, opts);
        assert_eq!(out, RouteOutcome::Admitted(1));
    }

    #[test]
    fn members_subset_respected() {
        // Macro owns only instances {1}; instance 0 must never be chosen.
        let mut insts = instances(2);
        insts[1].kv_used = insts[1].kv_capacity;
        let mut st = RoutingState::default();
        let out = route(&mut st, &[1], &insts, &req(1, 100), 0.0, &slo(), 64);
        assert_eq!(out, RouteOutcome::Deferred);
    }
}
