//! Algorithm 2 — Constraint Checking.
//!
//! Before the macro-instance scheduler routes a request to an instance it
//! verifies three conditions (paper §3.4):
//!
//! 1. **TTFT**: the summed predicted prefill durations of the instance's
//!    pending prefills, plus the candidate, plus the time the candidate has
//!    already waited, must fit inside `SLO_TTFT` (the §3.3 strict TTFT that
//!    folds in phase-switching wait).
//! 2. **TPOT**: the instance's in-flight decodes have accumulated
//!    *saved TPOT* — `L·SLO_TPOT − (now − first_token_time)` per request —
//!    and the mean slack must cover the prefill window `t_total` that would
//!    interrupt them.
//! 3. **KV capacity**: the prompt (plus an expected-output margin) must fit
//!    in the instance's remaining KV budget.

use crate::metrics::SloSpec;
use crate::sim::SimInstance;
use crate::workload::Request;

/// Why an instance was (or wasn't) admissible.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConstraintVerdict {
    Satisfied,
    TtftViolated,
    TpotViolated,
    KvExhausted,
}

impl ConstraintVerdict {
    pub fn ok(&self) -> bool {
        *self == ConstraintVerdict::Satisfied
    }
}

/// Algorithm 2, line-for-line against the paper (see module docs).
///
/// `admission_margin` is the expected-output KV reserve per request;
/// `now` is the scheduling instant; `window_budget` caps one instance's
/// pending prefill-window duration (the macro scheduler passes
/// `SLO_TTFT / members` so the ring's staggered windows jointly cover the
/// TTFT budget — an unbounded sticky window would hoard the whole macro's
/// arrivals on one instance while the rest idle).
pub fn check_constraints(
    instance: &SimInstance,
    req: &Request,
    now: f64,
    slo: &SloSpec,
    admission_margin: usize,
    window_budget: f64,
) -> ConstraintVerdict {
    check_constraints_opt(instance, req, now, slo, admission_margin, window_budget, false)
}

/// [`check_constraints`] with the mean-slack ablation switch exposed
/// (`use_mean_slack = true` reproduces the paper's literal Algorithm 2
/// line 16; see benches/ablation_padg.rs for why the default tightens it).
pub fn check_constraints_opt(
    instance: &SimInstance,
    req: &Request,
    now: f64,
    slo: &SloSpec,
    admission_margin: usize,
    window_budget: f64,
    use_mean_slack: bool,
) -> ConstraintVerdict {
    // ---- Constraint 1: TTFT --------------------------------------------
    // pending prefills of this window + the candidate request.
    let candidate_prefill = instance.prefill_cost(req.input_len);
    let already_waited = (now - req.arrival).max(0.0);
    // If a batch is mid-flight the switch happens at its boundary; include
    // the residual as part of the wait.
    let residual = instance
        .in_flight
        .as_ref()
        .map(|(_, done)| (done - now).max(0.0))
        .unwrap_or(0.0);
    let t_total = instance.pending_prefill_time() + candidate_prefill;
    if already_waited + residual + t_total > slo.ttft {
        return ConstraintVerdict::TtftViolated;
    }
    // Rolling-activation window cap (always letting at least one prompt in).
    if t_total > window_budget.max(candidate_prefill * 1.5) {
        return ConstraintVerdict::TtftViolated;
    }
    // The window must also fit inside the TTFT budget of the requests
    // already waiting in it (§3.3: their reported TTFT runs until their
    // decode phase starts, so admitting one more prompt extends every
    // waiter's TTFT by the candidate's prefill time).
    if let Some(oldest) = instance.oldest_unserved_arrival() {
        if (now - oldest).max(0.0) + residual + t_total > slo.ttft {
            return ConstraintVerdict::TtftViolated;
        }
    }

    // ---- Constraint 2: TPOT --------------------------------------------
    // Existing decodes must hold enough saved-TPOT slack to absorb the
    // whole prefill window without violating their own SLO. The paper
    // gates on the *mean* slack; we gate on the *minimum* so that no
    // below-mean request is driven negative by the window (DESIGN.md §8) —
    // the mean check admits windows that individually violate short
    // requests.
    let saved = if use_mean_slack {
        instance.mean_saved_tpot(now, slo.tpot)
    } else {
        instance.min_saved_tpot(now, slo.tpot)
    };
    if saved < t_total {
        return ConstraintVerdict::TpotViolated;
    }
    // Capacity guard: admitting this request must leave the steady-state
    // decode iteration itself under the TPOT SLO (a batch whose single
    // iteration exceeds SLO_TPOT can never meet the SLO regardless of
    // scheduling).
    let predicted_iter = instance.predicted_decode_iter(1, req.input_len + 64);
    if predicted_iter > slo.tpot {
        return ConstraintVerdict::TpotViolated;
    }

    // ---- Constraint 3: KV capacity -------------------------------------
    if !instance.kv_room_for(req.input_len, admission_margin) {
        return ConstraintVerdict::KvExhausted;
    }

    ConstraintVerdict::Satisfied
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Collector;
    use crate::perfmodel::interconnect::LinkSpec;
    use crate::perfmodel::parallelism::ParallelCfg;
    use crate::perfmodel::{BatchTimer, GpuSpec, ModelSpec};

    fn inst() -> SimInstance {
        let timer = BatchTimer::new(
            ModelSpec::llama_30b(),
            GpuSpec::l20(),
            ParallelCfg::tp_only(4, LinkSpec::pcie4()),
        );
        SimInstance::new(0, timer, 0.1)
    }

    fn req(id: u64, arrival: f64, input: usize) -> Request {
        Request { id, arrival, input_len: input, output_len: 100 }
    }

    fn slo() -> SloSpec {
        SloSpec::new(5.0, 0.1)
    }

    #[test]
    fn empty_instance_admits() {
        let ins = inst();
        let v = check_constraints(&ins, &req(1, 0.0, 500), 0.0, &slo(), 128, slo().ttft);
        assert!(v.ok());
    }

    #[test]
    fn ttft_violated_when_queue_deep() {
        let mut ins = inst();
        // Queue enough 4k prefills that the window exceeds 5 s.
        for i in 0..40 {
            ins.admit(req(i, 0.0, 4096));
        }
        let v = check_constraints(&ins, &req(99, 0.0, 4096), 0.0, &slo(), 128, slo().ttft);
        assert_eq!(v, ConstraintVerdict::TtftViolated);
    }

    #[test]
    fn ttft_accounts_for_time_already_waited() {
        let ins = inst();
        let old = req(1, 0.0, 500);
        // Request has been waiting 4.9s of its 5s budget.
        let v = check_constraints(&ins, &old, 4.9, &slo(), 128, slo().ttft);
        assert_eq!(v, ConstraintVerdict::TtftViolated);
    }

    #[test]
    fn tpot_violated_when_no_slack() {
        let mut ins = inst();
        let mut m = Collector::new();
        // A decode whose slack is nearly exhausted: first token long ago.
        let r = req(1, 0.0, 100);
        m.on_arrival(&r);
        ins.admit(r);
        let d = ins.start_prefill(1, 0.0);
        ins.complete_batch(d, &mut m);
        // One decode iteration starts the TPOT clock (§3.3 semantics).
        let d2 = ins.start_decode(d);
        ins.complete_batch(d2, &mut m);
        // now = first_token + generated*slo + epsilon => slack < 0
        let now = d + 2.0 * 0.1 + 0.05;
        let v = check_constraints(&ins, &req(2, now, 2000), now, &slo(), 128, slo().ttft);
        assert_eq!(v, ConstraintVerdict::TpotViolated);
    }

    #[test]
    fn tpot_ok_when_slack_accumulated() {
        let mut ins = inst();
        let mut m = Collector::new();
        let r = req(1, 0.0, 100);
        m.on_arrival(&r);
        ins.admit(r);
        let mut now = ins.start_prefill(1, 0.0);
        ins.complete_batch(now, &mut m);
        // Fast decodes (iter << slo) accumulate slack.
        for _ in 0..30 {
            let d = ins.start_decode(now);
            ins.complete_batch(d, &mut m);
            now = d;
        }
        let v = check_constraints(&ins, &req(2, now, 500), now, &slo(), 128, slo().ttft);
        assert!(v.ok(), "{v:?}");
    }

    #[test]
    fn kv_exhaustion_detected() {
        let mut ins = inst();
        ins.kv_used = ins.kv_capacity - 100;
        let v = check_constraints(&ins, &req(1, 0.0, 500), 0.0, &slo(), 128, slo().ttft);
        assert_eq!(v, ConstraintVerdict::KvExhausted);
    }

    #[test]
    fn residual_batch_time_counts_toward_ttft() {
        let mut ins = inst();
        // Fake an in-flight batch ending 4.9s from now.
        ins.in_flight = Some((crate::sim::BatchKind::Decode, 4.9));
        let v = check_constraints(&ins, &req(1, 0.0, 2000), 0.0, &slo(), 128, slo().ttft);
        assert_eq!(v, ConstraintVerdict::TtftViolated);
    }
}
