//! §3.5.2 — the serializable `InstanceHandler` proxy.
//!
//! To split or merge macro instances without re-initializing workers, the
//! paper serializes a proxy object (actor id, worker address, callable
//! surface) and ships it to the target macro-instance scheduler, which
//! reconstructs a fully functional handle — the worker never stops
//! decoding. The paper uses pickle over Ray; we serialize to JSON (the
//! in-tree [`crate::util::json`]) with identical semantics: migration is
//! *logical* (a metadata move), costing well under the paper's 100 ms
//! budget (measured in benches/microbench_coordinator.rs).

use crate::util::json::{Json, JsonError};

/// Metadata that fully describes a live instance worker, sufficient to
/// rebuild a calling proxy in another scheduler process.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceHandler {
    /// Stable actor id of the worker.
    pub actor_id: u64,
    /// Worker mailbox address ("host:port" in a distributed deployment;
    /// thread-actor name on the live path).
    pub address: String,
    /// Parallelism layout, for placement decisions after migration.
    pub tp: usize,
    pub pp: usize,
    /// Remote-callable surface (the RPC-like system dispatches by name).
    pub methods: Vec<String>,
    /// Scheduler bookkeeping carried across the move.
    pub kv_capacity_tokens: usize,
}

impl InstanceHandler {
    /// The callable surface every instance worker exposes.
    pub fn standard_methods() -> Vec<String> {
        ["prefill", "decode_step", "status", "pause", "resume"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    }

    pub fn new(
        actor_id: u64,
        address: impl Into<String>,
        tp: usize,
        pp: usize,
        kv_capacity_tokens: usize,
    ) -> Self {
        InstanceHandler {
            actor_id,
            address: address.into(),
            tp,
            pp,
            methods: Self::standard_methods(),
            kv_capacity_tokens,
        }
    }

    /// Serialize for migration (the pickle analogue). `actor_id` travels
    /// as a string: JSON numbers are f64 and would corrupt ids above 2^53
    /// (caught by prop_proxy_roundtrip_any_handler).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("actor_id", Json::str(self.actor_id.to_string())),
            ("address", Json::str(self.address.clone())),
            ("tp", Json::num(self.tp as f64)),
            ("pp", Json::num(self.pp as f64)),
            ("methods", Json::arr(self.methods.iter().map(|m| Json::str(m.clone())))),
            ("kv_capacity_tokens", Json::num(self.kv_capacity_tokens as f64)),
        ])
    }

    pub fn serialize(&self) -> String {
        self.to_json().to_string()
    }

    /// Reconstruct a proxy on the receiving scheduler.
    pub fn deserialize(wire: &str) -> Result<Self, JsonError> {
        let j = Json::parse(wire)?;
        let field = |k: &str| -> Result<&Json, JsonError> {
            j.get(k).ok_or(JsonError { msg: format!("missing field {k}"), offset: 0 })
        };
        Ok(InstanceHandler {
            actor_id: field("actor_id")?
                .as_str()
                .and_then(|s| s.parse().ok())
                .unwrap_or(0),
            address: field("address")?.as_str().unwrap_or("").to_string(),
            tp: field("tp")?.as_usize().unwrap_or(1),
            pp: field("pp")?.as_usize().unwrap_or(1),
            methods: field("methods")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|m| m.as_str().map(|s| s.to_string()))
                .collect(),
            kv_capacity_tokens: field("kv_capacity_tokens")?.as_usize().unwrap_or(0),
        })
    }

    /// Can the proxy issue this call?
    pub fn supports(&self, method: &str) -> bool {
        self.methods.iter().any(|m| m == method)
    }
}

/// A macro-instance scheduler's handler table; migration moves handlers
/// between tables without touching the workers themselves.
#[derive(Debug, Default)]
pub struct HandlerTable {
    pub handlers: Vec<InstanceHandler>,
}

impl HandlerTable {
    /// Remove the handler for `actor_id`, serializing it for transport.
    /// Returns the wire string (None if unknown).
    pub fn export(&mut self, actor_id: u64) -> Option<String> {
        let pos = self.handlers.iter().position(|h| h.actor_id == actor_id)?;
        let h = self.handlers.remove(pos);
        Some(h.serialize())
    }

    /// Install a handler received from another scheduler.
    pub fn import(&mut self, wire: &str) -> Result<&InstanceHandler, JsonError> {
        let h = InstanceHandler::deserialize(wire)?;
        self.handlers.push(h);
        Ok(self.handlers.last().unwrap())
    }

    pub fn len(&self) -> usize {
        self.handlers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handlers.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn handler(id: u64) -> InstanceHandler {
        InstanceHandler::new(id, format!("10.0.0.{id}:5005"), 4, 1, 120_000)
    }

    #[test]
    fn serialize_roundtrip_exact() {
        let h = handler(7);
        let wire = h.serialize();
        let back = InstanceHandler::deserialize(&wire).unwrap();
        assert_eq!(h, back);
    }

    #[test]
    fn migration_moves_handler_between_tables() {
        let mut a = HandlerTable::default();
        let mut b = HandlerTable::default();
        a.handlers.push(handler(1));
        a.handlers.push(handler(2));
        let wire = a.export(1).expect("exists");
        let imported = b.import(&wire).unwrap();
        assert_eq!(imported.actor_id, 1);
        assert_eq!(a.len(), 1);
        assert_eq!(b.len(), 1);
        // Unknown id exports nothing.
        assert!(a.export(99).is_none());
    }

    #[test]
    fn supports_standard_surface() {
        let h = handler(3);
        assert!(h.supports("prefill"));
        assert!(h.supports("decode_step"));
        assert!(!h.supports("train_step"));
    }

    #[test]
    fn deserialize_rejects_garbage() {
        assert!(InstanceHandler::deserialize("not json").is_err());
        assert!(InstanceHandler::deserialize("{}").is_err());
    }

    #[test]
    fn migration_preserves_capacity_bookkeeping() {
        let mut a = HandlerTable::default();
        a.handlers.push(handler(9));
        let wire = a.export(9).unwrap();
        let mut b = HandlerTable::default();
        let h = b.import(&wire).unwrap();
        assert_eq!(h.kv_capacity_tokens, 120_000);
        assert_eq!(h.tp, 4);
    }
}
