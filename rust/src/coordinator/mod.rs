//! The EcoServe coordinator — the paper's system contribution.
//!
//! * [`constraints`] — Algorithm 2: can instance X admit request R without
//!   violating TTFT, saved-TPOT slack, or KV capacity?
//! * [`routing`] — Algorithm 1: sticky-cyclic inter-instance routing inside
//!   a macro instance (the mechanism behind rolling activation).
//! * [`padg`] — the PaDG serving system wired into the simulator: temporal
//!   disaggregation inside each instance + rolling activation across them.
//! * [`mitosis`] — §3.5 expansion/contraction with split at `N_u` and merge
//!   at `N_l`.
//! * [`proxy`] — the serializable `InstanceHandler` enabling logical
//!   instance migration between macro-instance schedulers without
//!   re-initialization (§3.5.2).
//! * [`live`] — the same coordinator logic driving *real* PJRT-backed
//!   instances on the live path (examples/serve_model.rs).

pub mod constraints;
#[cfg(feature = "pjrt")]
pub mod live;
pub mod mitosis;
pub mod padg;
pub mod proxy;
pub mod routing;

pub use constraints::{check_constraints, ConstraintVerdict};
pub use padg::{AutoScalePolicy, EcoServeSystem, ScaleEvent};
