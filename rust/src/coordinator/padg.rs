//! The PaDG serving system: EcoServe's hierarchical coordinator wired into
//! the discrete-event simulator (the identical decision logic drives the
//! live PJRT path in [`super::live`]).
//!
//! Three scheduler levels (paper Figure 5):
//! * **overall scheduler** — dispatches arrivals across macro instances
//!   (cyclic, capability-checked) and runs the mitosis controller;
//! * **macro-instance scheduler** — Algorithm 1 sticky-cyclic routing over
//!   its members, gated by Algorithm 2's constraint check;
//! * **instance scheduler** — temporal disaggregation: drains its admitted
//!   prefill queue as one contiguous window (prefill priority), otherwise
//!   decodes; each batch completion is an `InstanceWake` event.
//!
//! Rolling activation is emergent: stickiness concentrates arrivals into
//! one member's prefill window until its saved-TPOT slack or TTFT budget is
//! spent, then the cursor advances — staggering prefill windows around the
//! ring so new requests almost always find an instance able to prefill.

use std::collections::VecDeque;

use super::mitosis::MitosisState;
use super::routing::{RouteOutcome, RoutingState};
use crate::config::{DefenseConfig, Deployment, SystemParams};
use crate::metrics::{attainment_fraction, Collector, SloSpec};
use crate::sim::{
    ChurnTelemetry, ClassRanker, DefenseTelemetry, Event, EventScheduler, FaultEvent, Health,
    SimInstance, SimReq, System,
};
use crate::trace::{RejectCause, TraceEvent, TraceKind, NO_INSTANCE, NO_REQ};
use crate::workload::Request;

const EPS: f64 = 1e-9;

/// Autoscaling policy for the mitosis controller (Figure 10).
#[derive(Debug, Clone)]
pub struct AutoScalePolicy {
    /// Attainment target; scale up when the trailing window drops below it.
    pub target_attainment: f64,
    /// Trailing window length, seconds.
    pub window: f64,
    /// Controller tick period, seconds.
    pub interval: f64,
    /// Minimum spacing between scale operations, seconds.
    pub cooldown: f64,
    /// Scale down when mean instance busy-fraction falls below this.
    pub idle_threshold: f64,
}

impl Default for AutoScalePolicy {
    fn default() -> Self {
        AutoScalePolicy {
            target_attainment: 0.90,
            window: 30.0,
            interval: 10.0,
            cooldown: 20.0,
            idle_threshold: 0.35,
        }
    }
}

/// A scale event for the Figure 10 report.
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleEvent {
    pub time: f64,
    pub active_instances: usize,
    pub kind: &'static str, // "up" | "down"
}

/// EcoServe under simulation.
pub struct EcoServeSystem {
    /// All provisioned instances; `active` gates which ones serve.
    pub instances: Vec<SimInstance>,
    active: Vec<bool>,
    draining: Vec<bool>,
    /// Macro-instance membership (mitosis state machine).
    pub mitosis: MitosisState,
    /// Sticky routing cursor per macro (rebuilt on structural changes).
    routing: Vec<RoutingState>,
    /// Overall-scheduler cursor over macros.
    overall_cursor: usize,
    pub slo: SloSpec,
    pub params: SystemParams,
    /// Requests no member could admit yet (retried at every wake).
    pub backlog: VecDeque<Request>,
    /// Autoscaler (None = fixed capacity, the Figure 8 setting).
    pub autoscale: Option<AutoScalePolicy>,
    last_scale_at: f64,
    prev_busy: Vec<f64>,
    pub scale_log: Vec<ScaleEvent>,
    /// Force-admissions of TTFT-hopeless backlog (observability).
    pub forced_admissions: u64,
    /// Fault-injection counters (zero in fault-free runs).
    pub churn: ChurnTelemetry,
    /// Crash times whose recovery (backlog drained again) is still open.
    pending_recovery: Vec<f64>,
    /// Overload defenses: `Some` when [`SystemParams::defense`] is set
    /// and `ablate_no_shedding` is off. `None` leaves every path below
    /// bit-identical to the defense-free coordinator.
    defense: Option<DefenseConfig>,
    /// What the defenses did (all-zero until they act).
    defense_stats: DefenseTelemetry,
    /// Request id → priority rank for per-class shedding (0 sheds last);
    /// installed by the scenario driver from the scenario's class map.
    class_ranker: Option<ClassRanker>,
    /// Brownout engagement time; re-stamped as brownout seconds accrue.
    brownout_since: Option<f64>,
}

impl EcoServeSystem {
    /// Build from a deployment with `initial` active instances out of
    /// `max_instances` provisioned (equal when autoscaling is off).
    pub fn with_capacity(
        deployment: &Deployment,
        slo: SloSpec,
        params: SystemParams,
        initial: usize,
        max_instances: usize,
    ) -> Self {
        assert!(initial >= 1 && initial <= max_instances);
        let instances: Vec<SimInstance> = (0..max_instances)
            .map(|i| SimInstance::new(i, deployment.timer(), deployment.kv_reserve_frac))
            .collect();
        let mut mitosis = MitosisState::new(params.n_lower, params.n_upper);
        for i in 0..initial {
            mitosis.add_instance(i);
        }
        let routing = (0..mitosis.macros.len()).map(|_| RoutingState::default()).collect();
        let prev_busy = vec![0.0; max_instances];
        let mut active = vec![false; max_instances];
        for a in active.iter_mut().take(initial) {
            *a = true;
        }
        let defense = if params.ablate_no_shedding { None } else { params.defense };
        EcoServeSystem {
            instances,
            active,
            draining: vec![false; max_instances],
            mitosis,
            routing,
            overall_cursor: 0,
            slo,
            params,
            backlog: VecDeque::new(),
            autoscale: None,
            last_scale_at: f64::NEG_INFINITY,
            prev_busy,
            scale_log: Vec::new(),
            forced_admissions: 0,
            churn: ChurnTelemetry::default(),
            pending_recovery: Vec::new(),
            defense,
            defense_stats: DefenseTelemetry::default(),
            class_ranker: None,
            brownout_since: None,
        }
    }

    /// Fixed-capacity constructor (Figure 8).
    pub fn new(deployment: &Deployment, slo: SloSpec, params: SystemParams) -> Self {
        let n = deployment.num_instances();
        Self::with_capacity(deployment, slo, params, n, n)
    }

    /// Mitosis-on constructor (Figure 10 / the frontier's autoscale
    /// variant): start from `N_l` active instances (clamped to the fleet)
    /// and let the controller grow toward the full deployment under
    /// `policy`. With `num_instances <= N_l` the variant degenerates to
    /// fixed capacity — the controller then only ever sheds idle
    /// instances.
    pub fn with_autoscale(
        deployment: &Deployment,
        slo: SloSpec,
        params: SystemParams,
        policy: AutoScalePolicy,
    ) -> Self {
        let n = deployment.num_instances();
        assert!(n >= 1, "deployment has zero instances (gpus < tp*pp)");
        let initial = params.n_lower.clamp(1, n);
        let mut sys = Self::with_capacity(deployment, slo, params, initial, n);
        sys.autoscale = Some(policy);
        sys
    }

    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|a| **a).count()
    }

    pub fn total_switches(&self) -> u64 {
        self.instances.iter().map(|i| i.switches).sum()
    }

    fn sync_routing(&mut self) {
        self.routing
            .resize_with(self.mitosis.macros.len(), RoutingState::default);
        self.routing.truncate(self.mitosis.macros.len());
    }

    /// Overall scheduler: offer the request to macros cyclically; each
    /// macro runs Algorithm 1 internally.
    fn try_route(&mut self, req: &Request, now: f64, sched: &mut EventScheduler) -> bool {
        let opts = super::routing::RouteOpts {
            sticky: !self.params.ablate_no_sticky,
            window_cap: !self.params.ablate_no_window_cap,
            mean_slack: self.params.ablate_mean_slack,
            health_gate: !self.params.ablate_no_recovery,
        };
        let n_macros = self.mitosis.macros.len();
        for k in 0..n_macros {
            let mi = (self.overall_cursor + k) % n_macros;
            let members = &self.mitosis.macros[mi];
            match super::routing::route_with(
                &mut self.routing[mi],
                members,
                &self.instances,
                req,
                now,
                &self.slo,
                self.params.admission_margin,
                opts,
            ) {
                RouteOutcome::Admitted(pos) => {
                    let idx = self.mitosis.macros[mi][pos];
                    self.instances[idx].admit(req.clone());
                    self.overall_cursor = mi;
                    if self.instances[idx].idle() {
                        sched.at(now, Event::InstanceWake { instance: idx });
                    }
                    return true;
                }
                RouteOutcome::Deferred => continue,
            }
        }
        false
    }

    /// Deadline-pressure admission: when strict Algorithm-2 routing keeps
    /// deferring a request but its TTFT budget is running out, place it on
    /// the member that (a) can still make its TTFT and (b) has the most
    /// saved-TPOT slack — trading the least TPOT damage for TTFT rescue.
    /// This is the "rescue" half of rolling activation under pressure.
    fn relaxed_admit(&mut self, req: &Request, now: f64, sched: &mut EventScheduler) -> bool {
        let margin = self.params.admission_margin;
        let gate = !self.params.ablate_no_recovery;
        let waited = (now - req.arrival).max(0.0);
        let mut best: Option<(f64, usize)> = None;
        for m in &self.mitosis.macros {
            for &idx in m {
                let inst = &self.instances[idx];
                if gate && inst.health != Health::Up {
                    continue;
                }
                if !inst.kv_room_for(req.input_len, margin) {
                    continue;
                }
                let residual = inst
                    .in_flight
                    .as_ref()
                    .map(|(_, done)| (done - now).max(0.0))
                    .unwrap_or(0.0);
                let t_total = inst.pending_prefill_time()
                    + inst.prefill_cost(req.input_len);
                if waited + residual + t_total > self.slo.ttft {
                    continue; // would still miss TTFT — no point
                }
                if let Some(oldest) = inst.oldest_unserved_arrival() {
                    if (now - oldest).max(0.0) + residual + t_total > self.slo.ttft {
                        continue; // would doom an already-waiting member
                    }
                }
                let slack = inst.min_saved_tpot(now, self.slo.tpot);
                if best.map(|(s, _)| slack > s).unwrap_or(true) {
                    best = Some((slack, idx));
                }
            }
        }
        if let Some((_, idx)) = best {
            self.instances[idx].admit(req.clone());
            if self.instances[idx].idle() {
                sched.at(now, Event::InstanceWake { instance: idx });
            }
            true
        } else {
            false
        }
    }

    /// Hopeless-TTFT fallback: a backlogged request whose wait already
    /// exceeds the TTFT SLO can never pass constraint 1; serve it anyway on
    /// the least-loaded member with KV room (it records as a violation —
    /// shedding it silently would fake better attainment).
    fn force_admit(&mut self, req: &Request, now: f64, sched: &mut EventScheduler) -> bool {
        let margin = self.params.admission_margin;
        let gate = !self.params.ablate_no_recovery;
        let mut best: Option<(usize, usize)> = None; // (kv_used, idx)
        for m in &self.mitosis.macros {
            for &idx in m {
                let inst = &self.instances[idx];
                if gate && inst.health != Health::Up {
                    continue;
                }
                if inst.kv_room_for(req.input_len, margin) {
                    let key = inst.kv_used + inst.prefill_queue.len() * 1000;
                    if best.map(|(b, _)| key < b).unwrap_or(true) {
                        best = Some((key, idx));
                    }
                }
            }
        }
        if let Some((_, idx)) = best {
            self.instances[idx].admit(req.clone());
            self.forced_admissions += 1;
            if self.instances[idx].idle() {
                sched.at(now, Event::InstanceWake { instance: idx });
            }
            true
        } else {
            false
        }
    }

    /// Arrival-time triage (defenses on): deadline-aware admission
    /// control plus per-class priority shedding. Returns the shed cause
    /// when the request should be rejected instead of queued — the caller
    /// records the cause-tagged rejection, which both counts as a
    /// guaranteed SLO violation (sheds can't fake attainment) and gives
    /// closed-loop clients fast feedback to back off on.
    fn shed_at_arrival(
        &mut self,
        req: &Request,
        now: f64,
        d: &DefenseConfig,
    ) -> Option<RejectCause> {
        // Deadline-aware admission: the backlog is FIFO, so a newcomer
        // waits at least as long as the head already has. Head wait past
        // `admission_slack x TTFT` means the queue-implied TTFT for this
        // arrival is provably blown — fail fast.
        if let Some(head) = self.backlog.front() {
            if now - head.arrival > d.admission_slack * self.slo.ttft {
                self.defense_stats.deadline_rejects += 1;
                return Some(RejectCause::Deadline);
            }
        }
        // Priority triage under backlog pressure: low-priority classes
        // (rank > 0 — retries rank last, see the driver's ranker) shed
        // once the backlog passes the cap; even priority traffic sheds
        // past twice the cap.
        let rank = self.class_ranker.as_ref().map(|r| r(req.id)).unwrap_or(0);
        let len = self.backlog.len();
        if (len > d.backlog_cap && rank > 0) || len > 2 * d.backlog_cap {
            self.defense_stats.priority_sheds += 1;
            return Some(RejectCause::Priority);
        }
        None
    }

    /// Track decode-occupancy brownout (defenses on): engage when mean
    /// KV occupancy across healthy active instances crosses the high
    /// watermark, disengage below the low one (hysteresis). Brownout
    /// seconds accrue incrementally so telemetry is current even if the
    /// run ends browned out.
    fn update_brownout(&mut self, now: f64, d: &DefenseConfig) {
        let (mut used, mut cap) = (0usize, 0usize);
        for (i, inst) in self.instances.iter().enumerate() {
            if self.active[i] && inst.health == Health::Up {
                used += inst.kv_used;
                cap += inst.kv_capacity;
            }
        }
        let occ = if cap == 0 { 1.0 } else { used as f64 / cap as f64 };
        match self.brownout_since {
            None if occ >= d.brownout_hi => self.brownout_since = Some(now),
            Some(t0) if occ <= d.brownout_lo => {
                self.defense_stats.brownout_s += now - t0;
                self.brownout_since = None;
            }
            Some(t0) => {
                self.defense_stats.brownout_s += now - t0;
                self.brownout_since = Some(now);
            }
            None => {}
        }
    }

    fn drain_backlog(&mut self, now: f64, sched: &mut EventScheduler, metrics: &mut Collector) {
        while let Some(req) = self.backlog.front().cloned() {
            let waited = now - req.arrival;
            let admitted = if waited > self.slo.ttft {
                if self.defense.is_some() {
                    // Defenses on: a TTFT-hopeless request is shed (an
                    // honest, monitored rejection) instead of being
                    // force-admitted to die on an instance — the freed
                    // capacity serves requests that can still meet SLO.
                    self.backlog.pop_front();
                    self.defense_stats.hopeless_sheds += 1;
                    metrics.on_reject_as(req.id, RejectCause::Hopeless);
                    continue;
                }
                // Already doomed: serve late rather than shed.
                self.force_admit(&req, now, sched)
            } else if waited > 0.35 * self.slo.ttft {
                // Budget draining: strict first, then deadline-pressure.
                self.try_route(&req, now, sched)
                    || self.relaxed_admit(&req, now, sched)
            } else {
                self.try_route(&req, now, sched)
            };
            if admitted {
                self.backlog.pop_front();
            } else {
                break; // FIFO: don't starve the head
            }
        }
        // A crash's recovery closes when the coordinator's backlog next
        // drains: every displaced (and congestion-displaced) request has
        // been placed again. Congestion that predates the fault is charged
        // to the recovery — the coordinator really was that far behind.
        if self.backlog.is_empty() && !self.pending_recovery.is_empty() {
            for t0 in self.pending_recovery.drain(..) {
                self.churn.recovery_s_sum += now - t0;
                self.churn.recoveries += 1;
            }
        }
    }

    /// Re-route evacuated requests after a fault. Requests that never
    /// reached their decode phase restart prefill from the backlog (the
    /// restart is honestly charged to TTFT — the arrival time is kept);
    /// mid-decode requests died with the KV cache and are lost. The backlog
    /// is re-sorted by (arrival, id) so displaced requests keep FIFO order
    /// relative to already-backlogged ones. Returns the re-routed count.
    fn requeue(&mut self, evacuated: Vec<SimReq>, now: f64, metrics: &mut Collector) -> u64 {
        let mut rerouted = 0u64;
        for r in evacuated {
            if r.first_token_at.is_none() {
                metrics.trace(TraceEvent::instant(
                    TraceKind::Reroute,
                    r.req.id,
                    NO_INSTANCE,
                    now,
                ));
                self.backlog.push_back(r.req);
                rerouted += 1;
            } else {
                self.churn.lost += 1;
            }
        }
        if rerouted > 0 {
            let mut v: Vec<Request> = self.backlog.drain(..).collect();
            v.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
            self.backlog = v.into();
        }
        rerouted
    }

    /// Intra-instance scheduling (temporal disaggregation, paper §3.4):
    /// the instance "executes decodes while accumulating sufficient slack
    /// to safely admit new requests" — a queued prefill runs as soon as the
    /// running decodes' saved-TPOT slack covers it (or nothing is
    /// decoding); otherwise one more decode iteration accrues slack first.
    /// One prompt per prefill batch — prefill saturates the GPU at batch 1
    /// (paper §2.2) and per-prompt completion gives each request its true
    /// TTFT.
    fn dispatch(
        &mut self,
        idx: usize,
        now: f64,
        sched: &mut EventScheduler,
        metrics: &mut Collector,
    ) {
        let slo_tpot = self.slo.tpot;
        let slo_ttft = self.slo.ttft;
        // Window hysteresis ("each phase lasting longer to reduce switching
        // overhead", §1): don't flip to prefill for every lone arrival —
        // switch when the queued window is worth the transition, when the
        // oldest queued request's TTFT budget demands it, or when nothing
        // is decoding anyway.
        let macro_size = self
            .mitosis
            .macro_of(idx)
            .map(|m| self.mitosis.macros[m].len())
            .unwrap_or(1)
            .max(1);
        let window_budget = slo_ttft / macro_size as f64;
        let inst = &mut self.instances[idx];
        if inst.health == Health::Down {
            return; // dead hardware runs nothing (work waits for restore)
        }
        if !inst.idle() {
            return;
        }
        let next_prefill = inst
            .prefill_queue
            .front()
            .map(|r| inst.prefill_cost(r.req.input_len - r.prefilled));
        let window_ready = {
            let oldest_wait = inst
                .prefill_queue
                .front()
                .map(|r| now - r.req.arrival)
                .unwrap_or(0.0);
            // Mid-window (already prefilling): keep going — switching away
            // and back would pay the PP fill/drain twice.
            self.params.ablate_no_hysteresis
                || inst.last_phase == Some(crate::perfmodel::Phase::Prefill)
                || oldest_wait > 0.25 * slo_ttft
                || inst.pending_prefill_time() >= 0.5 * window_budget
        };
        match next_prefill {
            Some(cost)
                if inst.running.is_empty()
                    || (window_ready
                        && inst.min_saved_tpot(now, slo_tpot) >= cost) =>
            {
                // Batch short prompts into one prefill: prefill saturates
                // the GPU around ~512 tokens (paper §2.2 — "batch size of
                // just one" refers to *long* prompts); below that, weight
                // streaming dominates and per-prompt batches waste it.
                let mut count = 1;
                let mut tokens = inst.prefill_queue[0].req.input_len
                    - inst.prefill_queue[0].prefilled;
                while count < inst.prefill_queue.len() && count < 16 {
                    let next = inst.prefill_queue[count].req.input_len
                        - inst.prefill_queue[count].prefilled;
                    if tokens + next > 512 {
                        break;
                    }
                    tokens += next;
                    count += 1;
                }
                let done = inst.start_prefill(count, now);
                sched.at(done, Event::InstanceWake { instance: idx });
            }
            _ if !inst.running.is_empty() => {
                let done = inst.start_decode(now);
                sched.at(done, Event::InstanceWake { instance: idx });
            }
            Some(_) => {
                // Slack shortfall with nothing to decode cannot happen
                // (running is empty => first arm matched); defensive kick.
                let done = inst.start_prefill(1, now);
                sched.at(done, Event::InstanceWake { instance: idx });
            }
            None => {
                if self.draining[idx] {
                    // Drained: release the instance.
                    self.active[idx] = false;
                    self.draining[idx] = false;
                    metrics.trace(TraceEvent::instant(
                        TraceKind::Drained,
                        NO_REQ,
                        idx as u32,
                        now,
                    ));
                }
            }
        }
    }

    fn scale_up(&mut self, now: f64, metrics: &mut Collector) -> bool {
        // First free provisioned-but-inactive instance that is healthy.
        let Some(idx) = (0..self.instances.len())
            .find(|&i| {
                !self.active[i] && !self.draining[i] && self.instances[i].health == Health::Up
            })
        else {
            return false;
        };
        self.active[idx] = true;
        self.instances[idx].kv_used = 0;
        let ops = self.mitosis.add_instance(idx);
        debug_assert!(self.mitosis.check_invariants().is_ok(), "{ops:?}");
        self.sync_routing();
        metrics.trace(TraceEvent::instant(TraceKind::ScaleUp, NO_REQ, idx as u32, now));
        self.scale_log.push(ScaleEvent {
            time: now,
            active_instances: self.active_count(),
            kind: "up",
        });
        true
    }

    fn scale_down(&mut self, now: f64, metrics: &mut Collector) -> bool {
        if self.mitosis.total_instances() <= self.params.n_lower {
            return false;
        }
        let Some((idx, ops)) = self.mitosis.remove_instance() else {
            return false;
        };
        debug_assert!(self.mitosis.check_invariants().is_ok(), "{ops:?}");
        self.sync_routing();
        // Instance drains: finishes admitted work, admits nothing new.
        self.draining[idx] = true;
        metrics.trace(TraceEvent::instant(TraceKind::ScaleDown, NO_REQ, idx as u32, now));
        self.scale_log.push(ScaleEvent {
            time: now,
            active_instances: self.active_count().saturating_sub(1),
            kind: "down",
        });
        true
    }
}

impl System for EcoServeSystem {
    fn on_arrival(
        &mut self,
        mut req: Request,
        now: f64,
        sched: &mut EventScheduler,
        metrics: &mut Collector,
    ) {
        // Seed the controller tick lazily on the first arrival.
        if self.autoscale.is_some() && self.last_scale_at == f64::NEG_INFINITY {
            self.last_scale_at = now;
            let interval = self.autoscale.as_ref().unwrap().interval;
            sched.at(now + interval, Event::ControlTick);
        }
        if let Some(d) = self.defense {
            if let Some(cause) = self.shed_at_arrival(&req, now, &d) {
                metrics.on_reject_as(req.id, cause);
                return;
            }
            // Brownout: when decode occupancy saturates, cap this
            // admission's generation length (models a reduced max_tokens
            // under graceful degradation).
            self.update_brownout(now, &d);
            if self.brownout_since.is_some() && req.output_len > d.brownout_decode_cap {
                req.output_len = d.brownout_decode_cap;
                self.defense_stats.brownout_truncations += 1;
                metrics.trace(TraceEvent::instant(
                    TraceKind::Brownout,
                    req.id,
                    NO_INSTANCE,
                    now,
                ));
            }
        }
        if !self.backlog.is_empty() || !self.try_route(&req, now, sched) {
            self.backlog.push_back(req);
        }
    }

    fn on_instance_wake(
        &mut self,
        idx: usize,
        now: f64,
        sched: &mut EventScheduler,
        metrics: &mut Collector,
    ) {
        if let Some((_, done)) = self.instances[idx].in_flight {
            if now + EPS < done {
                return; // spurious kick; the completion wake is scheduled
            }
            self.instances[idx].complete_batch(now, metrics);
        }
        if let Some(d) = self.defense {
            self.update_brownout(now, &d);
        }
        self.drain_backlog(now, sched, metrics);
        self.dispatch(idx, now, sched, metrics);
        // Backlog drain may have fed other idle instances; their kick wakes
        // were scheduled by try_route/force_admit.
    }

    /// Coordinator recovery (the fault-injection tentpole): a dead
    /// instance's queued work re-routes through the macro backlog (prefill
    /// restarts elsewhere, charged to TTFT), mid-decode work is lost with
    /// its KV cache, membership shrinks via [`MitosisState::remove_specific`]
    /// so rolling activation re-derives over the survivors, and spare
    /// provisioned capacity backfills immediately. A preemption notice
    /// drains the victim proactively. With
    /// [`SystemParams::ablate_no_recovery`] the coordinator never learns:
    /// crashed work is dropped, the router keeps cycling dead members, and
    /// work routed to them waits out the outage.
    fn on_fault(
        &mut self,
        fault: FaultEvent,
        now: f64,
        sched: &mut EventScheduler,
        metrics: &mut Collector,
    ) {
        self.churn.faults += 1;
        let recover = !self.params.ablate_no_recovery;
        match fault {
            FaultEvent::InstanceDown { instance } => {
                self.churn.downs += 1;
                if instance >= self.instances.len()
                    || self.instances[instance].health == Health::Down
                {
                    return;
                }
                let evacuated = self.instances[instance].crash();
                if recover {
                    let n = self.requeue(evacuated, now, metrics);
                    self.churn.rerouted += n;
                    self.active[instance] = false;
                    self.draining[instance] = false;
                    if self.mitosis.remove_specific(instance).is_some() {
                        debug_assert!(self.mitosis.check_invariants().is_ok());
                        self.sync_routing();
                    }
                    if self.scale_up(now, metrics) {
                        self.churn.backfills += 1; // spare capacity steps in
                    }
                    self.pending_recovery.push(now);
                    self.drain_backlog(now, sched, metrics);
                } else {
                    self.churn.lost += evacuated.len() as u64;
                }
            }
            FaultEvent::InstanceUp { instance } => {
                if instance >= self.instances.len()
                    || self.instances[instance].health != Health::Down
                {
                    return;
                }
                self.instances[instance].restore();
                if recover {
                    if self.mitosis.macro_of(instance).is_none() && !self.draining[instance] {
                        self.active[instance] = true;
                        let ops = self.mitosis.add_instance(instance);
                        debug_assert!(self.mitosis.check_invariants().is_ok(), "{ops:?}");
                        self.sync_routing();
                        self.churn.backfills += 1;
                    }
                    self.drain_backlog(now, sched, metrics);
                }
                sched.at(now, Event::InstanceWake { instance });
            }
            FaultEvent::PreemptNotice { instance } => {
                self.churn.notices += 1;
                if instance >= self.instances.len() {
                    return;
                }
                if recover && self.instances[instance].health == Health::Up {
                    // Stop placing work here and re-route what hasn't
                    // started; running decodes finish what they can before
                    // the reclaim lands.
                    self.instances[instance].health = Health::Degraded;
                    let evacuated = self.instances[instance].evacuate_queue();
                    let n = self.requeue(evacuated, now, metrics);
                    self.churn.rerouted += n;
                    self.drain_backlog(now, sched, metrics);
                }
            }
            // PaDG never migrates KV between instances: interconnect
            // degradation is invisible to it (the FuDG baselines pay).
            FaultEvent::LinkDegrade { .. } | FaultEvent::LinkRestore => {}
        }
    }

    fn churn_telemetry(&self) -> Option<ChurnTelemetry> {
        if self.churn.any() {
            Some(self.churn.clone())
        } else {
            None
        }
    }

    fn defense_telemetry(&self) -> Option<DefenseTelemetry> {
        self.defense.map(|_| self.defense_stats)
    }

    fn set_class_ranker(&mut self, ranker: ClassRanker) {
        self.class_ranker = Some(ranker);
    }

    fn on_control_tick(&mut self, now: f64, sched: &mut EventScheduler, metrics: &mut Collector) {
        let Some(policy) = self.autoscale.clone() else { return };
        let recs = metrics.records_in_window((now - policy.window).max(0.0), now);
        let attainment = attainment_fraction(&recs, &self.slo);
        let can_scale = now - self.last_scale_at >= policy.cooldown;
        if can_scale && !recs.is_empty() && attainment < policy.target_attainment {
            if self.scale_up(now, metrics) {
                self.last_scale_at = now;
            }
        } else if can_scale && !recs.is_empty() {
            // Mean busy fraction since the previous tick.
            let mut busy = 0.0;
            let mut n = 0.0;
            for (i, inst) in self.instances.iter().enumerate() {
                if self.active[i] {
                    busy += (inst.busy_time - self.prev_busy[i]) / policy.interval;
                    n += 1.0;
                }
            }
            if n > 0.0 && busy / n < policy.idle_threshold
                && attainment >= policy.target_attainment
                && self.scale_down(now, metrics)
            {
                self.last_scale_at = now;
            }
        }
        for (i, inst) in self.instances.iter().enumerate() {
            self.prev_busy[i] = inst.busy_time;
        }
        sched.at(now + policy.interval, Event::ControlTick);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, Deployment};
    use crate::perfmodel::ModelSpec;
    use crate::sim::run;
    use crate::workload::{Dataset, TraceGenerator};

    fn small_deployment() -> Deployment {
        let mut d = Deployment::paper_default(
            ModelSpec::codellama_34b(),
            ClusterSpec::l20_cluster(),
        );
        d.gpus_used = 16; // 4 instances at TP=4
        d
    }

    fn system(d: &Deployment) -> EcoServeSystem {
        EcoServeSystem::new(d, SloSpec::new(5.0, 0.1), SystemParams::default())
    }

    #[test]
    fn serves_light_load_within_slo() {
        let d = small_deployment();
        let mut sys = system(&d);
        let trace = TraceGenerator::new(Dataset::sharegpt(), 1).poisson(2.0, 60.0);
        let n = trace.len();
        let mut metrics = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut metrics);
        assert_eq!(metrics.completed().len(), n, "all requests complete");
        let frac = attainment_fraction(metrics.completed(), &sys.slo);
        assert!(frac > 0.95, "light load attainment {frac}");
    }

    #[test]
    fn rolling_activation_spreads_prefills() {
        let d = small_deployment();
        let mut sys = system(&d);
        let trace = TraceGenerator::new(Dataset::sharegpt(), 2).poisson(6.0, 60.0);
        let mut metrics = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut metrics);
        // Every instance must have served prefills (the ring rotates).
        for inst in &sys.instances[..4] {
            assert!(inst.busy_time > 0.0, "instance {} never used", inst.id);
        }
    }

    #[test]
    fn temporal_disaggregation_limits_switches() {
        // Phase switches should be far fewer than completed requests —
        // each prefill window covers a burst of requests.
        let d = small_deployment();
        let mut sys = system(&d);
        let trace = TraceGenerator::new(Dataset::sharegpt(), 3).poisson(6.0, 120.0);
        let n = trace.len() as u64;
        let mut metrics = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut metrics);
        let switches = sys.total_switches();
        assert!(
            switches < n,
            "switches {switches} should be below request count {n}"
        );
    }

    #[test]
    fn overload_degrades_gracefully() {
        let d = small_deployment();
        let mut sys = system(&d);
        // Far beyond capacity: attainment collapses but nothing panics and
        // throughput stays positive.
        let trace = TraceGenerator::new(Dataset::sharegpt(), 4).poisson(60.0, 30.0);
        let mut metrics = Collector::new();
        run(&mut sys, trace, 600.0, &mut metrics);
        assert!(!metrics.completed().is_empty());
        let frac = attainment_fraction(metrics.completed(), &sys.slo);
        assert!(frac < 0.9, "overload should break SLOs, got {frac}");
    }

    #[test]
    fn autoscaler_adds_instances_under_ramp() {
        let d = small_deployment();
        let mut sys = EcoServeSystem::with_capacity(
            &d,
            SloSpec::new(5.0, 0.1),
            SystemParams::default(),
            2,
            8,
        );
        sys.autoscale = Some(AutoScalePolicy::default());
        let gen = TraceGenerator::new(Dataset::sharegpt(), 5);
        let trace = gen.ramp(&[(2.0, 60.0), (8.0, 60.0), (14.0, 120.0)]);
        let mut metrics = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut metrics);
        assert!(
            sys.active_count() > 2,
            "scaler should have grown: log {:?}",
            sys.scale_log
        );
        assert!(sys.scale_log.iter().any(|e| e.kind == "up"));
        sys.mitosis.check_invariants().unwrap();
    }

    #[test]
    fn with_autoscale_starts_at_n_lower_and_grows() {
        let mut d = small_deployment();
        d.gpus_used = 32; // 8 instances at TP=4
        let mut sys = EcoServeSystem::with_autoscale(
            &d,
            SloSpec::new(5.0, 0.1),
            SystemParams::default(),
            AutoScalePolicy::default(),
        );
        assert_eq!(sys.active_count(), 4, "starts at N_l");
        let gen = TraceGenerator::new(Dataset::sharegpt(), 9);
        let trace = gen.ramp(&[(2.0, 60.0), (10.0, 60.0), (16.0, 120.0)]);
        let mut metrics = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut metrics);
        assert!(
            sys.active_count() > 4,
            "autoscale variant should grow: {:?}",
            sys.scale_log
        );
        sys.mitosis.check_invariants().unwrap();
        assert_eq!(
            sys.mitosis.macro_sizes().iter().sum::<usize>(),
            sys.mitosis.total_instances()
        );
    }

    #[test]
    fn fault_recovery_restores_membership_and_conserves_requests() {
        let d = small_deployment();
        let mut sys = system(&d);
        let trace = TraceGenerator::new(Dataset::sharegpt(), 11).poisson(5.0, 60.0);
        let n = trace.len();
        let faults = crate::sim::FaultSchedule::new(vec![
            crate::sim::Fault {
                at: 15.0,
                kind: crate::sim::FaultKind::Crash { instance: 1, down_s: 10.0 },
            },
            crate::sim::Fault {
                at: 40.0,
                kind: crate::sim::FaultKind::Preempt {
                    instance: 2,
                    notice_s: 2.0,
                    down_s: 8.0,
                },
            },
        ])
        .unwrap();
        let mut metrics = Collector::new();
        crate::sim::run_faulted(
            &mut sys,
            trace,
            &faults.events(&d),
            10_000.0,
            &mut metrics,
            false,
        );
        assert_eq!(sys.churn.downs, 2);
        assert_eq!(sys.churn.notices, 1);
        assert_eq!(sys.mitosis.total_instances(), 4, "both victims rejoined");
        sys.mitosis.check_invariants().unwrap();
        // Conservation: every arrival either completed or was honestly
        // counted lost (mid-decode at a crash); lost requests are exactly
        // the collector's never-completed entries.
        assert_eq!(metrics.completed().len() + sys.churn.lost as usize, n);
        assert_eq!(metrics.in_flight(), sys.churn.lost as usize);
        for inst in &sys.instances {
            assert_eq!(inst.health, crate::sim::Health::Up);
            assert_eq!(inst.kv_used, 0, "instance {} leaked KV across faults", inst.id);
        }
        assert!(sys.churn_telemetry().is_some());
    }

    #[test]
    fn no_recovery_ablation_drops_crashed_work() {
        let d = small_deployment();
        let params = SystemParams { ablate_no_recovery: true, ..SystemParams::default() };
        let mut sys = EcoServeSystem::new(&d, SloSpec::new(5.0, 0.1), params);
        let trace = TraceGenerator::new(Dataset::sharegpt(), 11).poisson(5.0, 60.0);
        let n = trace.len();
        let faults = crate::sim::FaultSchedule::new(vec![crate::sim::Fault {
            at: 15.0,
            kind: crate::sim::FaultKind::Crash { instance: 1, down_s: 10.0 },
        }])
        .unwrap();
        let mut metrics = Collector::new();
        crate::sim::run_faulted(
            &mut sys,
            trace,
            &faults.events(&d),
            10_000.0,
            &mut metrics,
            false,
        );
        // The coordinator never re-routes: whatever the victim held is gone
        // (queued work included), membership never shrank, nothing rerouted.
        assert_eq!(sys.churn.rerouted, 0);
        assert_eq!(sys.churn.backfills, 0);
        assert_eq!(sys.mitosis.total_instances(), 4);
        assert_eq!(metrics.completed().len() + sys.churn.lost as usize, n);
    }

    #[test]
    fn defenses_shed_under_deep_overload() {
        let d = small_deployment();
        let params = SystemParams {
            defense: Some(DefenseConfig::default()),
            ..SystemParams::default()
        };
        let mut sys = EcoServeSystem::new(&d, SloSpec::new(5.0, 0.1), params);
        // Far beyond capacity: the defended coordinator must shed rather
        // than let the backlog grow without bound.
        let trace = TraceGenerator::new(Dataset::sharegpt(), 4).poisson(60.0, 30.0);
        let mut metrics = Collector::new();
        run(&mut sys, trace, 600.0, &mut metrics);
        let t = sys.defense_telemetry().expect("defenses were configured");
        assert!(t.sheds() > 0, "deep overload must shed: {t:?}");
        assert_eq!(metrics.rejected as u64, t.sheds(), "every shed is a monitored reject");
        assert_eq!(
            sys.forced_admissions, 0,
            "defended PaDG sheds hopeless requests instead of force-admitting"
        );
        // The backlog stays bounded near the configured cap.
        assert!(sys.backlog.len() <= 2 * DefenseConfig::default().backlog_cap + 1);
    }

    #[test]
    fn ablate_no_shedding_reproduces_the_undefended_run_bit_for_bit() {
        let d = small_deployment();
        let trace = TraceGenerator::new(Dataset::sharegpt(), 4).poisson(60.0, 30.0);
        let run_with = |params: SystemParams| {
            let mut sys = EcoServeSystem::new(&d, SloSpec::new(5.0, 0.1), params);
            let mut metrics = Collector::new();
            run(&mut sys, trace.clone(), 600.0, &mut metrics);
            (metrics.completed().to_vec(), sys.defense_telemetry().is_some())
        };
        let (base, base_t) = run_with(SystemParams::default());
        let (ablated, ablated_t) = run_with(SystemParams {
            defense: Some(DefenseConfig::default()),
            ablate_no_shedding: true,
            ..SystemParams::default()
        });
        assert!(!base_t && !ablated_t, "ablation must silence defense telemetry");
        assert_eq!(base.len(), ablated.len());
        for (a, b) in base.iter().zip(&ablated) {
            assert_eq!(a, b, "ablated run diverged from the undefended baseline");
            assert_eq!(a.first_token.to_bits(), b.first_token.to_bits());
            assert_eq!(a.completion.to_bits(), b.completion.to_bits());
        }
    }

    #[test]
    fn kv_accounting_balances_at_quiescence() {
        let d = small_deployment();
        let mut sys = system(&d);
        let trace = TraceGenerator::new(Dataset::alpaca(), 6).poisson(4.0, 30.0);
        let mut metrics = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut metrics);
        assert_eq!(metrics.in_flight(), 0);
        for inst in &sys.instances {
            assert_eq!(inst.kv_used, 0, "instance {} leaked KV", inst.id);
            assert!(inst.prefill_queue.is_empty());
            assert!(inst.running.is_empty());
        }
    }
}
