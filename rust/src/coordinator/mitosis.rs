//! §3.5 — Mitosis scaling: instance-granular expansion/contraction of
//! macro instances with split/merge at the `N_u`/`N_l` thresholds.
//!
//! Expansion (paper Figure 7, steps 1-4): new instances are added to the
//! *growing* macro until its size would exceed `N_u`, at which point a new
//! macro of `N_l` instances splits off; further instances fill the original
//! back to `N_u`, then start filling the new macro.
//!
//! Contraction (steps 5-8): instances are removed from the *smallest*
//! macro until it reaches `N_l`, then from a full macro; when the two
//! smallest macros together hold fewer than `N_u` instances they merge
//! (after one more removal at exactly `N_u`, per the paper).
//!
//! The state machine is pure (no scheduling side effects) so its invariants
//! are property-tested in isolation; `EcoServeSystem` applies the returned
//! [`ScaleOp`]s to live scheduling state, and instance moves between macros
//! travel as serialized [`super::proxy::InstanceHandler`]s.

/// Membership state: which instances belong to which macro instance.
#[derive(Debug, Clone, PartialEq)]
pub struct MitosisState {
    /// macro -> member instance ids. Invariant: non-empty macros only
    /// (except transiently inside operations), no duplicate ids.
    pub macros: Vec<Vec<usize>>,
    pub n_lower: usize,
    pub n_upper: usize,
}

/// A structural change the controller performed (for logs/tests; the
/// scheduler re-reads `macros` afterwards).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScaleOp {
    /// Instance added to macro `m`.
    Added { instance: usize, to_macro: usize },
    /// Macro `from` split; the listed instances migrated to new macro `to`.
    Split { from: usize, to: usize, moved: Vec<usize> },
    /// Instance removed from macro `m`.
    Removed { instance: usize, from_macro: usize },
    /// Macro `from` merged into macro `into`.
    Merged { from: usize, into: usize, moved: Vec<usize> },
}

impl MitosisState {
    pub fn new(n_lower: usize, n_upper: usize) -> Self {
        assert!(n_lower >= 1 && n_upper >= n_lower);
        MitosisState { macros: vec![], n_lower, n_upper }
    }

    /// Start with one macro holding `instances`.
    pub fn with_initial(instances: Vec<usize>, n_lower: usize, n_upper: usize) -> Self {
        let mut s = Self::new(n_lower, n_upper);
        if !instances.is_empty() {
            s.macros.push(instances);
        }
        s
    }

    pub fn total_instances(&self) -> usize {
        self.macros.iter().map(|m| m.len()).sum()
    }

    pub fn macro_of(&self, instance: usize) -> Option<usize> {
        self.macros.iter().position(|m| m.contains(&instance))
    }

    /// Member count per macro instance — the shape the frontier's
    /// mitosis-on telemetry reports after a run (e.g. `[6, 4]`).
    pub fn macro_sizes(&self) -> Vec<usize> {
        self.macros.iter().map(|m| m.len()).collect()
    }

    /// Expansion: add `instance`, splitting if the growing macro would
    /// exceed `N_u`. Returns the ops performed.
    pub fn add_instance(&mut self, instance: usize) -> Vec<ScaleOp> {
        debug_assert!(self.macro_of(instance).is_none(), "instance already placed");
        let mut ops = Vec::new();
        if self.macros.is_empty() {
            self.macros.push(vec![instance]);
            ops.push(ScaleOp::Added { instance, to_macro: 0 });
            return ops;
        }
        // Growing macro: the fullest macro that is not yet at N_u; if all
        // are full, the smallest (a fresh split target).
        let grow = self
            .macros
            .iter()
            .enumerate()
            .filter(|(_, m)| m.len() < self.n_upper)
            .max_by_key(|(_, m)| m.len())
            .map(|(i, _)| i);
        match grow {
            Some(g) => {
                self.macros[g].push(instance);
                ops.push(ScaleOp::Added { instance, to_macro: g });
            }
            None => {
                // Every macro is at N_u: adding one more exceeds the bound,
                // so split N_l instances off the first full macro into a new
                // macro, then place the newcomer in the donor.
                let donor = 0;
                let moved: Vec<usize> = {
                    let m = &mut self.macros[donor];
                    let keep = m.len() - self.n_lower;
                    m.split_off(keep)
                };
                self.macros.push(moved.clone());
                let new_idx = self.macros.len() - 1;
                ops.push(ScaleOp::Split { from: donor, to: new_idx, moved });
                self.macros[donor].push(instance);
                ops.push(ScaleOp::Added { instance, to_macro: donor });
            }
        }
        ops
    }

    /// Contraction: remove one instance (the controller's choice of which
    /// physical instance to release), merging macros when the two smallest
    /// jointly fall under `N_u`. Returns (released instance id, ops).
    pub fn remove_instance(&mut self) -> Option<(usize, Vec<ScaleOp>)> {
        if self.macros.is_empty() {
            return None;
        }
        let mut ops = Vec::new();
        // Remove from the smallest macro, unless it is already at N_l and
        // another macro can spare one (paper steps 5-6).
        let smallest = (0..self.macros.len())
            .min_by_key(|&i| self.macros[i].len())
            .unwrap();
        let victim_macro = if self.macros[smallest].len() > self.n_lower
            || self.macros.len() == 1
        {
            smallest
        } else {
            // Take from a full (or fullest) macro instead.
            (0..self.macros.len())
                .max_by_key(|&i| self.macros[i].len())
                .unwrap()
        };
        let instance = self.macros[victim_macro].pop()?;
        ops.push(ScaleOp::Removed { instance, from_macro: victim_macro });
        if self.macros[victim_macro].is_empty() {
            self.macros.remove(victim_macro);
        }
        // Merge check (paper steps 7-8): if the two smallest macros sum to
        // fewer than N_u instances, merge them.
        if self.macros.len() >= 2 {
            let mut idx: Vec<usize> = (0..self.macros.len()).collect();
            idx.sort_by_key(|&i| self.macros[i].len());
            let (a, b) = (idx[0], idx[1]);
            if self.macros[a].len() + self.macros[b].len() < self.n_upper {
                let (from, into) = if a > b { (a, b) } else { (b, a) };
                let moved = self.macros[from].clone();
                let moved_clone = moved.clone();
                self.macros[into].extend(moved);
                self.macros.remove(from);
                ops.push(ScaleOp::Merged { from, into, moved: moved_clone });
            }
        }
        Some((instance, ops))
    }

    /// Fault path: remove a *specific* instance (one that just died) from
    /// whatever macro holds it, applying the same merge rule as
    /// [`MitosisState::remove_instance`]. Unlike planned contraction the
    /// controller does not get to pick the victim — the fault did. Returns
    /// `None` when the instance is not a member (already removed, or was
    /// never activated).
    pub fn remove_specific(&mut self, instance: usize) -> Option<Vec<ScaleOp>> {
        let mi = self.macro_of(instance)?;
        let pos = self.macros[mi].iter().position(|&x| x == instance)?;
        self.macros[mi].remove(pos);
        let mut ops = vec![ScaleOp::Removed { instance, from_macro: mi }];
        if self.macros[mi].is_empty() {
            self.macros.remove(mi);
        }
        // Same merge check as planned contraction (paper steps 7-8).
        if self.macros.len() >= 2 {
            let mut idx: Vec<usize> = (0..self.macros.len()).collect();
            idx.sort_by_key(|&i| self.macros[i].len());
            let (a, b) = (idx[0], idx[1]);
            if self.macros[a].len() + self.macros[b].len() < self.n_upper {
                let (from, into) = if a > b { (a, b) } else { (b, a) };
                let moved = self.macros[from].clone();
                let moved_clone = moved.clone();
                self.macros[into].extend(moved);
                self.macros.remove(from);
                ops.push(ScaleOp::Merged { from, into, moved: moved_clone });
            }
        }
        Some(ops)
    }

    /// Structural invariants (asserted by property tests):
    /// no duplicates, no empty macros, every macro within [1, N_u].
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = std::collections::HashSet::new();
        for (i, m) in self.macros.iter().enumerate() {
            if m.is_empty() {
                return Err(format!("macro {i} is empty"));
            }
            if m.len() > self.n_upper {
                return Err(format!("macro {i} has {} > N_u={}", m.len(), self.n_upper));
            }
            for &id in m {
                if !seen.insert(id) {
                    return Err(format!("instance {id} in two macros"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Walk the paper's Figure 7 (N_l=3, N_u=6) expansion narrative.
    #[test]
    fn figure7_expansion() {
        let mut s = MitosisState::with_initial((0..6).collect(), 3, 6);
        // Step 2: adding a 7th instance exceeds N_u=6 -> split off N_l=3.
        let ops = s.add_instance(6);
        assert!(matches!(ops[0], ScaleOp::Split { .. }), "{ops:?}");
        assert_eq!(s.macros.len(), 2);
        assert_eq!(s.macros[0].len(), 4); // 3 kept + newcomer
        assert_eq!(s.macros[1].len(), 3); // split-off N_l
        s.check_invariants().unwrap();
        // Step 3: next instances refill the original toward N_u.
        for id in 7..9 {
            s.add_instance(id);
        }
        assert_eq!(s.macros[0].len(), 6);
        // Step 4: subsequent additions land in the new macro.
        let ops = s.add_instance(9);
        assert_eq!(ops, vec![ScaleOp::Added { instance: 9, to_macro: 1 }]);
        assert_eq!(s.macros[1].len(), 4);
        s.check_invariants().unwrap();
    }

    /// Walk the contraction narrative (steps 5-8).
    #[test]
    fn figure7_contraction() {
        let mut s = MitosisState {
            macros: vec![(0..6).collect(), (6..10).collect()],
            n_lower: 3,
            n_upper: 6,
        };
        // Step 5: remove from the smallest macro until N_l.
        let (_, _) = s.remove_instance().unwrap();
        assert_eq!(s.macros[1].len(), 3);
        s.check_invariants().unwrap();
        // Step 6-8: next removal takes from the full macro; 6+3-1 = 8 >= 6
        // no merge yet. Keep removing until total hits N_u - 1 => merge.
        let mut merged = false;
        while let Some((_, ops)) = s.remove_instance() {
            s.check_invariants().unwrap();
            if ops.iter().any(|o| matches!(o, ScaleOp::Merged { .. })) {
                merged = true;
                break;
            }
        }
        assert!(merged, "macros should merge when jointly under N_u");
        assert_eq!(s.macros.len(), 1);
        assert!(s.total_instances() < 6);
    }

    #[test]
    fn add_from_empty() {
        let mut s = MitosisState::new(2, 4);
        let ops = s.add_instance(0);
        assert_eq!(ops, vec![ScaleOp::Added { instance: 0, to_macro: 0 }]);
        assert_eq!(s.total_instances(), 1);
    }

    #[test]
    fn grow_shrink_roundtrip_preserves_invariants() {
        let mut s = MitosisState::new(4, 16);
        for id in 0..40 {
            s.add_instance(id);
            s.check_invariants().unwrap();
        }
        assert_eq!(s.total_instances(), 40);
        for _ in 0..40 {
            s.remove_instance();
            s.check_invariants().unwrap();
        }
        assert_eq!(s.total_instances(), 0);
        assert!(s.remove_instance().is_none());
    }

    #[test]
    fn remove_specific_takes_the_named_instance() {
        let mut s = MitosisState {
            macros: vec![(0..6).collect(), (6..10).collect()],
            n_lower: 3,
            n_upper: 6,
        };
        // Kill instance 2 out of the first macro: membership shrinks by
        // exactly that id, invariants hold, 5 + 4 >= 6 so no merge.
        let ops = s.remove_specific(2).unwrap();
        assert_eq!(ops[0], ScaleOp::Removed { instance: 2, from_macro: 0 });
        assert_eq!(s.macro_of(2), None);
        assert_eq!(s.total_instances(), 9);
        s.check_invariants().unwrap();
        // A non-member is a no-op.
        assert!(s.remove_specific(2).is_none());
        assert_eq!(s.total_instances(), 9);
    }

    #[test]
    fn remove_specific_merges_when_jointly_small() {
        let mut s = MitosisState {
            macros: vec![(0..3).collect(), (3..6).collect()],
            n_lower: 3,
            n_upper: 6,
        };
        // 2 + 3 < 6 after the removal: the macros must merge.
        let ops = s.remove_specific(1).unwrap();
        assert!(ops.iter().any(|o| matches!(o, ScaleOp::Merged { .. })), "{ops:?}");
        assert_eq!(s.macros.len(), 1);
        assert_eq!(s.total_instances(), 5);
        s.check_invariants().unwrap();
    }

    #[test]
    fn macro_of_lookup() {
        let s = MitosisState::with_initial(vec![3, 5, 9], 2, 6);
        assert_eq!(s.macro_of(5), Some(0));
        assert_eq!(s.macro_of(7), None);
    }

    #[test]
    fn macro_sizes_reports_membership_shape() {
        let s = MitosisState {
            macros: vec![(0..6).collect(), (6..10).collect()],
            n_lower: 3,
            n_upper: 6,
        };
        assert_eq!(s.macro_sizes(), vec![6, 4]);
        assert_eq!(s.macro_sizes().iter().sum::<usize>(), s.total_instances());
        assert!(MitosisState::new(2, 4).macro_sizes().is_empty());
    }
}
