//! Link-contention network model for FuDG KV-cache migration.
//!
//! Each named link (a node NIC, a PCIe fabric, an NVLink domain) serializes
//! transfers FIFO: a transfer starts at `max(now, link.busy_until)` and
//! occupies the link for `latency + bytes/bandwidth`. This is the
//! first-order contention model behind the paper's Table 3 argument — when
//! offered KV traffic exceeds link bandwidth, transfer queues grow without
//! bound and decode admission stalls.

use std::collections::HashMap;

use crate::perfmodel::interconnect::LinkSpec;

pub type TransferId = u64;

/// One queued/in-flight transfer.
#[derive(Debug, Clone)]
pub struct Transfer {
    pub id: TransferId,
    pub bytes: f64,
    /// Scheduler-defined payload (request id, destination instance, ...).
    pub tag: u64,
    pub start: f64,
    pub done: f64,
}

/// A set of FIFO links indexed by id.
#[derive(Debug, Default)]
pub struct Network {
    links: Vec<Link>,
    next_id: TransferId,
    in_flight: HashMap<TransferId, Transfer>,
    /// Total bytes ever enqueued, per link (Table-3 style accounting).
    pub bytes_enqueued: Vec<f64>,
}

#[derive(Debug)]
struct Link {
    spec: LinkSpec,
    busy_until: f64,
}

impl Network {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a link; returns its id.
    pub fn add_link(&mut self, spec: LinkSpec) -> usize {
        self.links.push(Link { spec, busy_until: 0.0 });
        self.bytes_enqueued.push(0.0);
        self.links.len() - 1
    }

    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    /// Enqueue a transfer of `bytes` on `link` at time `now`; the returned
    /// transfer carries its completion time — schedule a TransferDone there.
    pub fn enqueue(&mut self, link: usize, bytes: f64, tag: u64, now: f64) -> Transfer {
        let l = &mut self.links[link];
        let start = now.max(l.busy_until);
        let done = start + l.spec.latency + bytes / l.spec.bandwidth;
        l.busy_until = done;
        self.bytes_enqueued[link] += bytes;
        self.next_id += 1;
        let t = Transfer { id: self.next_id, bytes, tag, start, done };
        self.in_flight.insert(t.id, t.clone());
        t
    }

    /// Enqueue a two-hop transfer (MoonCake: prefill node -> pool -> decode
    /// node). The second hop starts when the first completes.
    pub fn enqueue_two_hop(
        &mut self,
        first: usize,
        second: usize,
        bytes: f64,
        tag: u64,
        now: f64,
    ) -> Transfer {
        let hop1 = self.enqueue(first, bytes, tag, now);
        // remove hop1 from in_flight; only the final hop is awaited
        self.in_flight.remove(&hop1.id);
        let l = &mut self.links[second];
        let start = hop1.done.max(l.busy_until);
        let done = start + l.spec.latency + bytes / l.spec.bandwidth;
        l.busy_until = done;
        self.bytes_enqueued[second] += bytes;
        self.next_id += 1;
        let t = Transfer { id: self.next_id, bytes, tag, start, done };
        self.in_flight.insert(t.id, t.clone());
        t
    }

    /// Complete (and remove) a transfer by id.
    pub fn complete(&mut self, id: TransferId) -> Option<Transfer> {
        self.in_flight.remove(&id)
    }

    /// Current queueing delay on a link: how far its FIFO extends past now.
    pub fn backlog(&self, link: usize, now: f64) -> f64 {
        (self.links[link].busy_until - now).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_serializes_transfers() {
        let mut net = Network::new();
        let l = net.add_link(LinkSpec::eth_10g()); // 1.1 GB/s
        let t1 = net.enqueue(l, 1.1e9, 0, 0.0);
        let t2 = net.enqueue(l, 1.1e9, 1, 0.0);
        assert!((t1.done - 1.0).abs() < 0.01);
        assert!((t2.start - t1.done).abs() < 1e-9, "t2 waits for t1");
        assert!((t2.done - 2.0).abs() < 0.02);
    }

    #[test]
    fn idle_link_starts_immediately() {
        let mut net = Network::new();
        let l = net.add_link(LinkSpec::roce_25g());
        let t = net.enqueue(l, 2.9e9, 0, 5.0);
        assert!((t.start - 5.0).abs() < 1e-9);
        assert!((t.done - 6.0).abs() < 0.01);
        assert!(net.backlog(l, 5.5) > 0.4);
        assert_eq!(net.backlog(l, 10.0), 0.0);
    }

    #[test]
    fn two_hop_chains() {
        let mut net = Network::new();
        let a = net.add_link(LinkSpec::eth_10g());
        let b = net.add_link(LinkSpec::eth_10g());
        let t = net.enqueue_two_hop(a, b, 1.1e9, 7, 0.0);
        // hop1 ~1s, hop2 ~1s
        assert!((t.done - 2.0).abs() < 0.02, "done={}", t.done);
        assert_eq!(t.tag, 7);
    }

    #[test]
    fn independent_links_do_not_contend() {
        let mut net = Network::new();
        let a = net.add_link(LinkSpec::eth_10g());
        let b = net.add_link(LinkSpec::eth_10g());
        let t1 = net.enqueue(a, 1.1e9, 0, 0.0);
        let t2 = net.enqueue(b, 1.1e9, 1, 0.0);
        assert!((t1.done - t2.done).abs() < 1e-9);
    }

    #[test]
    fn complete_removes() {
        let mut net = Network::new();
        let l = net.add_link(LinkSpec::pcie4());
        let t = net.enqueue(l, 1e6, 3, 0.0);
        assert!(net.complete(t.id).is_some());
        assert!(net.complete(t.id).is_none());
    }
}
