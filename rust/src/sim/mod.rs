//! Discrete-event cluster simulator.
//!
//! The paper evaluates on 32 L20 / 16 A800 GPUs we do not have; every
//! Figure-8/9/10/11 experiment instead runs here, driven by the analytical
//! [`crate::perfmodel`] (DESIGN.md §2 explains why this substitution
//! preserves the comparison's shape: all five systems share one cost
//! model, and scheduling policy — the paper's contribution — is what
//! differs between them).
//!
//! Architecture: a binary-heap event [`engine`], a GPU-instance state
//! machine ([`instance::SimInstance`]) shared by every scheduler, and a
//! FIFO-contention [`network`] used by the FuDG baselines for KV-cache
//! migration. Schedulers implement [`System`] and plug into
//! [`engine::run`].

pub mod engine;
pub mod faults;
pub mod instance;
pub mod network;

pub use engine::{
    reference_run, reference_run_faulted, reference_run_faulted_client, run,
    run_abandonable, run_faulted, run_faulted_client, run_source_faulted,
    run_source_faulted_client, run_source_until_faulted, run_until, run_until_faulted,
    ClassRanker, DefenseTelemetry, Event, EventScheduler, RunStats, StopReason, System,
};
pub use faults::{ChurnProfile, ChurnTelemetry, Fault, FaultEvent, FaultKind, FaultSchedule};
pub use instance::{BatchKind, Health, SimInstance, SimReq};
pub use network::{Network, TransferId};
