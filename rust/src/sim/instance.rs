//! The simulated GPU inference instance: a state machine every scheduler
//! (EcoServe and the four baselines) drives.
//!
//! An instance owns a [`BatchTimer`] (its hardware/parallelism profile), a
//! KV-token budget, a prefill queue, and a running decode set. Schedulers
//! decide *what* to run next (`BatchKind`); the instance computes how long
//! it takes and applies the effects at completion. Phase switches are
//! counted — temporal disaggregation's whole point is minimizing them.

use std::collections::VecDeque;

use crate::metrics::Collector;
use crate::perfmodel::{BatchTimer, Phase};
use crate::trace::{TraceEvent, TraceKind};
use crate::workload::Request;

/// Scheduler-visible per-request state.
#[derive(Debug, Clone)]
pub struct SimReq {
    pub req: Request,
    /// Prompt tokens prefilled so far (== input_len once prefill is done;
    /// intermediate values only under Sarathi's chunked prefill).
    pub prefilled: usize,
    /// Output tokens generated so far.
    pub generated: usize,
    /// When the first token was emitted.
    pub first_token_at: Option<f64>,
}

impl SimReq {
    pub fn new(req: Request) -> Self {
        SimReq { req, prefilled: 0, generated: 0, first_token_at: None }
    }

    pub fn prefill_done(&self) -> bool {
        self.prefilled >= self.req.input_len
    }

    pub fn decode_done(&self) -> bool {
        self.generated >= self.req.output_len
    }

    /// Current KV-cache footprint in tokens.
    pub fn kv_tokens(&self) -> usize {
        self.prefilled + self.generated
    }

    /// Context length seen by the next decode step.
    pub fn context(&self) -> usize {
        self.req.input_len + self.generated
    }
}

/// Hardware health of an instance under fault injection
/// ([`crate::sim::faults`]). Fault-free runs never leave [`Health::Up`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Health {
    /// Serving normally.
    #[default]
    Up,
    /// Preemption notice received: still running, but draining — the
    /// coordinator should stop placing new work here.
    Degraded,
    /// Dead. Holds no state and can run nothing until restored.
    Down,
}

/// What the instance is executing right now.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchKind {
    /// Whole-prompt prefills for the given queue positions (separate
    /// batching). Each request's KV was reserved at enqueue time.
    Prefill { count: usize },
    /// One decode iteration over the whole running set.
    Decode,
    /// Sarathi hybrid iteration: all running decodes + `chunk` prompt
    /// tokens of the head-of-queue prefill.
    Hybrid { chunk: usize },
}

/// A simulated instance (one model replica over tp×pp GPUs).
#[derive(Debug)]
pub struct SimInstance {
    pub id: usize,
    pub timer: BatchTimer,
    /// KV capacity in tokens (from GPU memory minus weights).
    pub kv_capacity: usize,
    /// KV tokens currently reserved (admitted prompts + generated tokens).
    pub kv_used: usize,
    /// Admitted requests waiting for (or mid-way through) prefill.
    pub prefill_queue: VecDeque<SimReq>,
    /// Requests in the decode phase.
    pub running: Vec<SimReq>,
    /// In-flight batch: kind + completion time (None = idle).
    pub in_flight: Option<(BatchKind, f64)>,
    /// Start time of the in-flight batch (first-decode-token timestamps
    /// use the iteration *start*, per the paper's §3.3 convention that
    /// TPOT measurement begins after the phase-switching delay).
    batch_started: f64,
    /// Current phase for switch accounting.
    pub last_phase: Option<Phase>,
    /// Number of prefill<->decode transitions (paper: PaDG minimizes these).
    pub switches: u64,
    /// Total busy seconds (utilization accounting).
    pub busy_time: f64,
    /// Max decode batch size (vLLM-style cap).
    pub max_decode_batch: usize,
    /// Fault-injection health. Always [`Health::Up`] in fault-free runs.
    pub health: Health,
    /// Single-prompt latency of the most recent prefill (PP drain cost
    /// when the pipeline switches prefill -> decode).
    last_prefill_single: f64,
}

impl SimInstance {
    pub fn new(id: usize, timer: BatchTimer, kv_reserve_frac: f64) -> Self {
        let kv_capacity = timer.kv_capacity_tokens(kv_reserve_frac);
        SimInstance {
            id,
            timer,
            kv_capacity,
            kv_used: 0,
            prefill_queue: VecDeque::new(),
            running: Vec::new(),
            in_flight: None,
            batch_started: 0.0,
            last_phase: None,
            switches: 0,
            busy_time: 0.0,
            max_decode_batch: 256,
            health: Health::Up,
            last_prefill_single: 0.0,
        }
    }

    /// The instance dies: the in-flight batch evaporates, all resident
    /// state (queued prefills + running decodes) is evacuated to the
    /// caller, and the KV cache is wiped. The caller decides each
    /// evacuated request's fate — re-route (prefill restarts elsewhere,
    /// honestly charged to TTFT) or drop (mid-decode state is gone).
    pub fn crash(&mut self) -> Vec<SimReq> {
        self.health = Health::Down;
        self.in_flight = None;
        self.last_phase = None;
        let mut evacuated: Vec<SimReq> = self.prefill_queue.drain(..).collect();
        evacuated.extend(self.running.drain(..));
        self.kv_used = 0;
        evacuated
    }

    /// The instance comes back empty after an outage (weights reloaded,
    /// KV cold). [`Self::crash`] already zeroed the resident state.
    pub fn restore(&mut self) {
        self.health = Health::Up;
    }

    /// Proactive drain on a preemption notice: hand back the *queued*
    /// (not yet prefilled) requests so the coordinator can place them
    /// elsewhere before the instance dies, releasing their admission
    /// reservations. Running decodes stay — their KV exists only here.
    pub fn evacuate_queue(&mut self) -> Vec<SimReq> {
        // Requests inside the in-flight batch must stay queued:
        // complete_batch pops exactly those heads when the batch lands.
        let keep = match &self.in_flight {
            Some((BatchKind::Prefill { count }, _)) => *count,
            Some((BatchKind::Hybrid { chunk }, _)) if *chunk > 0 => 1,
            _ => 0,
        };
        let keep = keep.min(self.prefill_queue.len());
        let evacuated: Vec<SimReq> = self.prefill_queue.split_off(keep).into_iter().collect();
        for r in &evacuated {
            // Queued requests hold exactly their admission reservation
            // (the prompt); chunked-prefill progress reuses it.
            self.kv_used = self.kv_used.saturating_sub(r.req.input_len);
        }
        evacuated
    }

    pub fn idle(&self) -> bool {
        self.in_flight.is_none()
    }

    pub fn has_work(&self) -> bool {
        !self.prefill_queue.is_empty() || !self.running.is_empty()
    }

    /// KV tokens a request needs end-to-end is unknown (output length is
    /// stochastic); admission reserves the prompt plus a safety margin of
    /// expected output tokens.
    pub fn kv_room_for(&self, input_len: usize, margin: usize) -> bool {
        self.kv_used + input_len + margin <= self.kv_capacity
    }

    /// Admit a request into the prefill queue, reserving prompt KV.
    pub fn admit(&mut self, req: Request) {
        self.kv_used += req.input_len;
        self.prefill_queue.push_back(SimReq::new(req));
    }

    /// Incremental cost of prefilling `len` tokens inside a window:
    /// under PP, consecutive window prompts pipeline at one per stage-time.
    pub fn prefill_cost(&self, len: usize) -> f64 {
        self.timer.prefill_time(&[len]) / self.timer.par.pp as f64
    }

    /// Sum of predicted prefill durations for queued (unprefilled) work —
    /// Algorithm 2's `t_total` input.
    pub fn pending_prefill_time(&self) -> f64 {
        self.prefill_queue
            .iter()
            .map(|r| self.prefill_cost(r.req.input_len - r.prefilled))
            .sum()
    }

    /// Cost of one prefill<->decode transition even without PP: kernel-set
    /// swap, CUDA-graph switch, batch re-formation, allocator churn. Small
    /// per event but the term the paper's temporal disaggregation
    /// amortizes ("each phase lasting longer to reduce switching
    /// overhead", §1) — NoDG systems pay it every alternation.
    pub const PHASE_SWITCH_OVERHEAD_S: f64 = 3e-3;

    /// Note the phase of the starting batch; returns the switch overhead
    /// to add to its duration (0 when the phase is unchanged).
    fn note_phase(&mut self, phase: Phase) -> f64 {
        if self.last_phase.is_some() && self.last_phase != Some(phase) {
            self.switches += 1;
            self.last_phase = Some(phase);
            Self::PHASE_SWITCH_OVERHEAD_S
        } else {
            self.last_phase = Some(phase);
            0.0
        }
    }

    /// Pipeline fill/drain bubble incurred when a PP instance changes
    /// phase: the pipeline drains the old phase's sub-batches and refills
    /// with the new phase's — ~(pp−1)/pp of one iteration (paper Figure 4).
    /// PaDG pays this rarely (long same-phase windows); NoDG constantly.
    fn pp_switch_bubble(&self, phase: Phase, dur: f64) -> f64 {
        let pp = self.timer.par.pp;
        if pp > 1 && self.last_phase.is_some() && self.last_phase != Some(phase) {
            dur * (pp - 1) as f64 / pp as f64
        } else {
            0.0
        }
    }

    /// Start a prefill batch over the first `count` queued requests.
    /// Returns the completion time to schedule a wake at.
    pub fn start_prefill(&mut self, count: usize, now: f64) -> f64 {
        debug_assert!(self.idle());
        let count = count.min(self.prefill_queue.len());
        debug_assert!(count > 0);
        let lens: Vec<usize> = self
            .prefill_queue
            .iter()
            .take(count)
            .map(|r| r.req.input_len - r.prefilled)
            .collect();
        let dur = {
            let base = self.timer.prefill_time(&lens);
            let pp = self.timer.par.pp;
            if pp > 1 {
                // Consecutive same-phase prefills stream through the
                // pipeline at one prompt per stage-time (the uniform
                // microbatches of a PaDG prefill window — paper Figure 4's
                // bubble-free case); a phase switch pays the pipeline fill.
                if self.last_phase == Some(Phase::Prefill) {
                    base / pp as f64
                } else {
                    let fill = self.timer.prefill_time(&lens[..1])
                        * (pp - 1) as f64 / pp as f64;
                    base / pp as f64 + fill
                }
            } else {
                base
            }
        };
        self.last_prefill_single = self.timer.prefill_time(&lens[..1])
            / self.timer.par.pp as f64;
        let dur = dur + self.note_phase(Phase::Prefill);
        self.busy_time += dur;
        let done = now + dur;
        self.batch_started = now;
        self.in_flight = Some((BatchKind::Prefill { count }, done));
        done
    }

    /// Start one decode iteration over the running set (capped).
    pub fn start_decode(&mut self, now: f64) -> f64 {
        debug_assert!(self.idle());
        debug_assert!(!self.running.is_empty());
        let batch = self.running.len().min(self.max_decode_batch);
        let ctx: usize = self.running.iter().take(batch).map(|r| r.context()).sum();
        // Under PP the running set is split into pp interleaved sub-batches
        // that keep every stage busy; each request sees one token per
        // sub-batch full-model latency (see perfmodel::roofline on why a
        // single batch gets no PP latency speedup).
        let pp = self.timer.par.pp;
        let dur = {
            let (b, c) = if pp > 1 { (batch.div_ceil(pp), ctx.div_ceil(pp)) } else { (batch, ctx) };
            let base = self.timer.decode_iter_time(b, c);
            // Switching prefill -> decode drains the prefill microbatches
            // still in the pipe (one per stage) before decode can refill:
            // a prefill-scale bubble, not a decode-scale one (Figure 4).
            let drain = if pp > 1 && self.last_phase == Some(Phase::Prefill) {
                self.last_prefill_single * (pp - 1) as f64
            } else {
                0.0
            };
            base + self.pp_switch_bubble(Phase::Decode, base) + drain
        };
        let dur = dur + self.note_phase(Phase::Decode);
        self.busy_time += dur;
        let done = now + dur;
        self.batch_started = now;
        self.in_flight = Some((BatchKind::Decode, done));
        done
    }

    /// Start a Sarathi hybrid iteration: decodes + up to `budget` prompt
    /// tokens from the head of the prefill queue.
    pub fn start_hybrid(&mut self, budget: usize, now: f64) -> f64 {
        debug_assert!(self.idle());
        let decode_batch = self.running.len().min(self.max_decode_batch);
        let decode_ctx: usize =
            self.running.iter().take(decode_batch).map(|r| r.context()).sum();
        let (chunk, chunk_ctx) = match self.prefill_queue.front() {
            Some(head) => {
                let remaining = head.req.input_len - head.prefilled;
                let chunk = remaining.min(budget);
                // Attention context for this chunk spans already-prefilled
                // tokens (re-read from KV — the chunked-prefill overhead).
                (chunk, head.prefilled + chunk)
            }
            None => (0, 0),
        };
        debug_assert!(decode_batch > 0 || chunk > 0);
        let dur = self
            .timer
            .hybrid_iter_time(decode_batch, decode_ctx, chunk, chunk_ctx);
        // Hybrid batching blurs phases; count a switch only from pure
        // states. Treat hybrid as decode-phase for switch accounting.
        let dur = dur + self.note_phase(Phase::Decode);
        self.busy_time += dur;
        let done = now + dur;
        self.batch_started = now;
        self.in_flight = Some((BatchKind::Hybrid { chunk }, done));
        done
    }

    /// Apply the in-flight batch's effects at its completion time.
    /// Returns requests that finished decoding (already removed, KV freed).
    pub fn complete_batch(&mut self, now: f64, metrics: &mut Collector) -> Vec<SimReq> {
        let (kind, done_at) = self.in_flight.take().expect("no batch in flight");
        debug_assert!((done_at - now).abs() < 1e-6, "wake at wrong time");
        let phase = match kind {
            BatchKind::Prefill { .. } => TraceKind::PhasePrefill,
            BatchKind::Decode => TraceKind::PhaseDecode,
            BatchKind::Hybrid { .. } => TraceKind::PhaseHybrid,
        };
        metrics.trace_phase(phase, self.id as u32, self.batch_started, now);
        let mut finished = Vec::new();
        match kind {
            BatchKind::Prefill { count } => {
                for _ in 0..count {
                    let r = self.prefill_queue.pop_front().expect("queued prefill");
                    self.finish_prefill(r, now, metrics, &mut finished);
                }
            }
            BatchKind::Decode => {
                self.apply_decode_step(now, metrics, &mut finished);
            }
            BatchKind::Hybrid { chunk } => {
                self.apply_decode_step(now, metrics, &mut finished);
                if chunk > 0 {
                    let head_done = {
                        let head = self.prefill_queue.front_mut().expect("chunked head");
                        head.prefilled += chunk;
                        head.prefill_done()
                    };
                    if head_done {
                        let r = self.prefill_queue.pop_front().unwrap();
                        self.finish_prefill(r, now, metrics, &mut finished);
                    }
                }
            }
        }
        finished
    }

    /// A request's prompt finished prefilling. Its first token exists now,
    /// but per §3.3 the *reported* first-token timestamp is deferred to the
    /// start of its first decode iteration — the gap is the phase-switching
    /// wait, charged to TTFT, with TPOT measured after it. (Requests whose
    /// entire output is the prefill token complete immediately.)
    fn finish_prefill(
        &mut self,
        mut r: SimReq,
        now: f64,
        metrics: &mut Collector,
        finished: &mut Vec<SimReq>,
    ) {
        metrics.trace(TraceEvent::span(
            TraceKind::ReqPrefill,
            r.req.id,
            self.id as u32,
            self.batch_started,
            now,
        ));
        r.prefilled = r.req.input_len;
        r.generated = 1; // the prefill's token; rendered at decode start
        self.kv_used += 1;
        if r.decode_done() {
            r.first_token_at = Some(now);
            metrics.on_first_token(r.req.id, now);
            metrics.on_complete(r.req.id, now);
            self.kv_used -= r.kv_tokens();
            finished.push(r);
        } else {
            self.running.push(r); // first_token_at stays None until decode
        }
    }

    fn apply_decode_step(&mut self, now: f64, metrics: &mut Collector, finished: &mut Vec<SimReq>) {
        let started = self.batch_started;
        let batch = self.running.len().min(self.max_decode_batch);
        let mut i = 0;
        let mut seen = 0;
        while i < self.running.len() && seen < batch {
            seen += 1;
            let r = &mut self.running[i];
            if r.first_token_at.is_none() {
                // §3.3: TTFT_reported ends (and the TPOT clock starts) when
                // the request's decode phase begins.
                r.first_token_at = Some(started);
                metrics.on_first_token(r.req.id, started);
            }
            r.generated += 1;
            self.kv_used += 1;
            metrics.on_token(r.req.id, now);
            if r.decode_done() {
                metrics.on_complete(r.req.id, now);
                let r = self.running.swap_remove(i);
                self.kv_used -= r.kv_tokens();
                finished.push(r);
            } else {
                i += 1;
            }
        }
    }

    /// Saved-TPOT slack of the running decodes (Algorithm 2, constraint 2):
    /// per request `L·SLO_tpot − (now − first_token_time)`; returns the
    /// mean, or +inf when nothing is decoding.
    /// (Requests still waiting for their decode phase to begin have no
    /// TPOT clock yet — §3.3 — and do not constrain the slack.)
    pub fn mean_saved_tpot(&self, now: f64, slo_tpot: f64) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for r in &self.running {
            if let Some(first) = r.first_token_at {
                sum += r.generated as f64 * slo_tpot - (now - first);
                n += 1;
            }
        }
        if n == 0 {
            f64::INFINITY
        } else {
            sum / n as f64
        }
    }

    /// Minimum saved-TPOT slack across running decodes. Gating prefill
    /// windows on the *minimum* (rather than the paper's mean) guarantees
    /// no individual request is driven past its TPOT SLO by an absorbed
    /// window — see DESIGN.md §8 for why we tighten this.
    pub fn min_saved_tpot(&self, now: f64, slo_tpot: f64) -> f64 {
        self.running
            .iter()
            .filter_map(|r| {
                r.first_token_at
                    .map(|first| r.generated as f64 * slo_tpot - (now - first))
            })
            .fold(f64::INFINITY, f64::min)
    }

    /// Earliest arrival among requests that have not yet reached their
    /// decode phase (queued prefills + prefilled-but-waiting). Constraint
    /// 1 uses this to bound the prefill window by its members' TTFT
    /// budgets.
    pub fn oldest_unserved_arrival(&self) -> Option<f64> {
        let q = self.prefill_queue.iter().map(|r| r.req.arrival);
        let w = self
            .running
            .iter()
            .filter(|r| r.first_token_at.is_none())
            .map(|r| r.req.arrival);
        q.chain(w).fold(None, |acc, a| match acc {
            None => Some(a),
            Some(b) => Some(b.min(a)),
        })
    }

    /// Start time of the in-flight (or most recent) batch — FuDG-style
    /// coordinators that drive prefill against a scratch collector use it
    /// to re-emit phase spans into the real one.
    pub fn batch_started(&self) -> f64 {
        self.batch_started
    }

    /// Predicted duration of the next decode iteration if `extra` requests
    /// with `extra_ctx` total context joined the running set — Algorithm
    /// 2's capacity guard against over-batching past the TPOT SLO.
    pub fn predicted_decode_iter(&self, extra: usize, extra_ctx: usize) -> f64 {
        let batch = (self.running.len() + self.prefill_queue.len() + extra)
            .min(self.max_decode_batch);
        let ctx: usize = self.running.iter().map(|r| r.context()).sum::<usize>()
            + self.prefill_queue.iter().map(|r| r.req.input_len).sum::<usize>()
            + extra_ctx;
        let pp = self.timer.par.pp;
        if pp > 1 {
            self.timer
                .decode_iter_time(batch.div_ceil(pp), ctx.div_ceil(pp))
        } else {
            self.timer.decode_iter_time(batch, ctx)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::interconnect::LinkSpec;
    use crate::perfmodel::parallelism::ParallelCfg;
    use crate::perfmodel::{GpuSpec, ModelSpec};

    fn inst() -> SimInstance {
        let timer = BatchTimer::new(
            ModelSpec::llama_30b(),
            GpuSpec::l20(),
            ParallelCfg::tp_only(4, LinkSpec::pcie4()),
        );
        SimInstance::new(0, timer, 0.1)
    }

    fn req(id: u64, input: usize, output: usize) -> Request {
        Request { id, arrival: 0.0, input_len: input, output_len: output }
    }

    #[test]
    fn prefill_emits_first_token_and_moves_to_running() {
        let mut ins = inst();
        let mut m = Collector::new();
        let r = req(1, 100, 10);
        m.on_arrival(&r);
        ins.admit(r);
        assert_eq!(ins.kv_used, 100);
        let done = ins.start_prefill(1, 0.0);
        assert!(done > 0.0);
        let finished = ins.complete_batch(done, &mut m);
        assert!(finished.is_empty());
        assert_eq!(ins.running.len(), 1);
        assert_eq!(ins.kv_used, 101);
        assert_eq!(m.in_flight(), 1);
    }

    #[test]
    fn decode_iterations_finish_request_and_free_kv() {
        let mut ins = inst();
        let mut m = Collector::new();
        let r = req(1, 50, 3);
        m.on_arrival(&r);
        ins.admit(r);
        let t = ins.complete_and_wake(&mut m, 0.0);
        // run decode until done
        let mut now = t;
        while !ins.running.is_empty() {
            let done = ins.start_decode(now);
            ins.complete_batch(done, &mut m);
            now = done;
        }
        assert_eq!(ins.kv_used, 0);
        let rec = &m.completed()[0];
        assert_eq!(rec.output_len, 3);
        assert!(rec.tpot() > 0.0);
    }

    impl SimInstance {
        /// test helper: run the admitted prefill to completion
        fn complete_and_wake(&mut self, m: &mut Collector, now: f64) -> f64 {
            let done = self.start_prefill(1, now);
            self.complete_batch(done, m);
            done
        }
    }

    #[test]
    fn single_output_request_completes_at_prefill() {
        let mut ins = inst();
        let mut m = Collector::new();
        let r = req(9, 40, 1);
        m.on_arrival(&r);
        ins.admit(r);
        let done = ins.start_prefill(1, 0.0);
        let fin = ins.complete_batch(done, &mut m);
        assert_eq!(fin.len(), 1);
        assert_eq!(ins.kv_used, 0);
        assert!(ins.running.is_empty());
    }

    #[test]
    fn hybrid_chunks_prefill_progressively() {
        let mut ins = inst();
        let mut m = Collector::new();
        let r = req(2, 1000, 5);
        m.on_arrival(&r);
        ins.admit(r);
        // 512-token chunks: two iterations to finish prefill
        let d1 = ins.start_hybrid(512, 0.0);
        ins.complete_batch(d1, &mut m);
        assert_eq!(ins.prefill_queue.front().unwrap().prefilled, 512);
        assert!(ins.running.is_empty());
        let d2 = ins.start_hybrid(512, d1);
        ins.complete_batch(d2, &mut m);
        assert!(ins.prefill_queue.is_empty());
        assert_eq!(ins.running.len(), 1);
        assert_eq!(m.in_flight(), 1);
    }

    #[test]
    fn phase_switches_counted() {
        let mut ins = inst();
        let mut m = Collector::new();
        for i in 0..2 {
            let r = req(i, 100, 4);
            m.on_arrival(&r);
            ins.admit(r);
        }
        let d = ins.start_prefill(2, 0.0);
        ins.complete_batch(d, &mut m);
        assert_eq!(ins.switches, 0);
        let d2 = ins.start_decode(d);
        ins.complete_batch(d2, &mut m);
        assert_eq!(ins.switches, 1); // prefill -> decode
        // admit another and go back to prefill
        let r = req(7, 60, 2);
        m.on_arrival(&r);
        ins.admit(r);
        let d3 = ins.start_prefill(1, d2);
        ins.complete_batch(d3, &mut m);
        assert_eq!(ins.switches, 2);
    }

    #[test]
    fn saved_tpot_slack_accumulates() {
        let mut ins = inst();
        let mut m = Collector::new();
        let r = req(3, 100, 50);
        m.on_arrival(&r);
        ins.admit(r);
        let d = ins.start_prefill(1, 0.0);
        ins.complete_batch(d, &mut m);
        // §3.3: the TPOT clock has not started yet — slack is unbounded
        // until the first decode iteration begins.
        assert!(ins.mean_saved_tpot(d, 0.1).is_infinite());
        // Decode a few fast iterations: slack grows if iter < slo. Context
        // grows by one token per iteration (101, 102, ... at start). The
        // clock starts at the *start* of the first decode iteration (= d).
        let mut now = d;
        let mut iter_sum = 0.0;
        for i in 0..5 {
            iter_sum += ins.timer.decode_iter_time(1, 101 + i);
            let done = ins.start_decode(now);
            ins.complete_batch(done, &mut m);
            now = done;
        }
        // The first decode iteration also pays the phase-switch overhead.
        let expected = 6.0 * 0.1 - iter_sum - SimInstance::PHASE_SWITCH_OVERHEAD_S;
        assert!(
            (ins.mean_saved_tpot(now, 0.1) - expected).abs() < 1e-6,
            "{} vs {expected}",
            ins.mean_saved_tpot(now, 0.1)
        );
    }

    #[test]
    fn empty_instance_has_infinite_slack() {
        let ins = inst();
        assert!(ins.mean_saved_tpot(0.0, 0.1).is_infinite());
    }

    #[test]
    fn crash_wipes_state_and_returns_residents() {
        let mut ins = inst();
        let mut m = Collector::new();
        for i in 0..3 {
            let r = req(i, 100, 10);
            m.on_arrival(&r);
            ins.admit(r);
        }
        // Prefill one into the running set, leave two queued, then die
        // mid-decode.
        let d = ins.start_prefill(1, 0.0);
        ins.complete_batch(d, &mut m);
        let d2 = ins.start_decode(d);
        assert!(!ins.idle());
        let evacuated = ins.crash();
        assert_eq!(ins.health, Health::Down);
        assert_eq!(evacuated.len(), 3);
        assert_eq!(ins.kv_used, 0);
        assert!(ins.idle() && !ins.has_work());
        // The decode-stage request is distinguishable by its progress.
        assert_eq!(evacuated.iter().filter(|r| r.prefill_done()).count(), 1);
        // The stale completion wake must now be a no-op for the caller.
        assert!(ins.in_flight.is_none());
        let _ = d2;
        ins.restore();
        assert_eq!(ins.health, Health::Up);
    }

    #[test]
    fn evacuate_queue_spares_the_in_flight_batch() {
        let mut ins = inst();
        let mut m = Collector::new();
        for i in 0..3 {
            let r = req(i, 100, 10);
            m.on_arrival(&r);
            ins.admit(r);
        }
        let d = ins.start_prefill(2, 0.0);
        // Two queued requests belong to the running batch; only the third
        // may leave, releasing exactly its prompt reservation.
        let evacuated = ins.evacuate_queue();
        assert_eq!(evacuated.len(), 1);
        assert_eq!(evacuated[0].req.id, 2);
        assert_eq!(ins.kv_used, 200);
        ins.complete_batch(d, &mut m); // must not panic: batch heads intact
        assert_eq!(ins.running.len(), 2);
    }

    #[test]
    fn kv_room_respects_capacity() {
        let mut ins = inst();
        assert!(ins.kv_room_for(1000, 0));
        ins.kv_used = ins.kv_capacity - 500;
        assert!(ins.kv_room_for(400, 0));
        assert!(!ins.kv_room_for(400, 200));
        assert!(!ins.kv_room_for(600, 0));
    }
}
