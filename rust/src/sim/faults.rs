//! Fault injection: deterministic, seedable hardware-churn timelines.
//!
//! EcoServe's cost argument lives on commodity clusters where nodes die,
//! links degrade, and spot GPUs get reclaimed mid-decode. This module
//! turns that churn into data: a [`FaultSchedule`] is a validated list of
//! [`Fault`]s (instance crash/restart, whole-node loss, link-tier
//! degradation, spot preemption with a reclaim notice) that expands —
//! against a concrete [`Deployment`] — into the [`FaultEvent`] timeline
//! the engine feeds through its dynamic-event heap
//! ([`crate::sim::run_faulted`]). Schedules come from two places:
//!
//! * [`FaultSchedule::generate`] — derived from a scenario's
//!   [`ChurnProfile`] and a seed (PCG64), so `steady+churn`-style
//!   scenarios are reproducible bit-for-bit from `--fault-seed`;
//! * [`FaultSchedule::parse_named`] — a JSONL description, strict like
//!   the replay parser: malformed, out-of-order, or overlapping lines
//!   fail with the offending line number.
//!
//! Expansion merges overlapping down-windows per instance (a node loss
//! that swallows an already-crashed instance extends its outage instead
//! of double-firing), so every `InstanceDown` is paired with exactly one
//! `InstanceUp`.
//!
//! ## JSONL format
//!
//! One fault per line:
//!
//! ```text
//! {"at_s":40,"kind":"crash","instance":2,"down_s":20}
//! {"at_s":90,"kind":"node-loss","node":0,"down_s":30}
//! {"at_s":150,"kind":"preempt","instance":1,"notice_s":5,"down_s":60}
//! {"at_s":200,"kind":"link-degrade","factor":4,"for_s":30}
//! ```

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::config::Deployment;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// One fault to inject, in schedule (deployment-independent) form.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fault {
    /// Absolute simulation time, seconds.
    pub at: f64,
    pub kind: FaultKind,
}

/// The fault taxonomy the simulator understands.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// One instance dies, restarting `down_s` seconds later.
    Crash { instance: usize, down_s: f64 },
    /// Every instance on `node` dies, restarting `down_s` later.
    NodeLoss { node: usize, down_s: f64 },
    /// Spot reclaim: a notice fires at `at`, the instance dies
    /// `notice_s` later, and the capacity returns after `down_s`.
    Preempt { instance: usize, notice_s: f64, down_s: f64 },
    /// Inter-instance transfers slow down by `factor` for `for_s`
    /// seconds (FuDG KV migration; PaDG moves no KV and shrugs).
    LinkDegrade { factor: f64, for_s: f64 },
}

/// A fault delivered to a running system (deployment-resolved).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultEvent {
    InstanceDown { instance: usize },
    InstanceUp { instance: usize },
    PreemptNotice { instance: usize },
    LinkDegrade { factor: f64 },
    LinkRestore,
}

/// Per-scenario churn shape ([`crate::scenarios::Scenario::churn`]):
/// mean spacings between faults, expanded into a concrete
/// [`FaultSchedule`] by [`FaultSchedule::generate`].
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnProfile {
    /// Mean seconds between instance crashes (`None` = no crashes).
    pub crash_every_s: Option<f64>,
    /// Outage length per crash, seconds.
    pub crash_down_s: f64,
    /// Mean seconds between spot preemptions (`None` = none).
    pub preempt_every_s: Option<f64>,
    /// Reclaim notice before a preempted instance dies, seconds.
    pub preempt_notice_s: f64,
    /// Outage length per preemption, seconds.
    pub preempt_down_s: f64,
}

impl ChurnProfile {
    /// Crash-only churn.
    pub fn crashes(every_s: f64, down_s: f64) -> Self {
        ChurnProfile {
            crash_every_s: Some(every_s),
            crash_down_s: down_s,
            preempt_every_s: None,
            preempt_notice_s: 0.0,
            preempt_down_s: 0.0,
        }
    }

    /// Preemption-only churn.
    pub fn preemptions(every_s: f64, notice_s: f64, down_s: f64) -> Self {
        ChurnProfile {
            crash_every_s: None,
            crash_down_s: 0.0,
            preempt_every_s: Some(every_s),
            preempt_notice_s: notice_s,
            preempt_down_s: down_s,
        }
    }
}

/// Churn bookkeeping a system accumulates in
/// [`crate::sim::System::on_fault`] and reports through
/// [`crate::sim::System::churn_telemetry`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChurnTelemetry {
    /// Fault events delivered to the system.
    pub faults: u64,
    /// Instance-down events observed.
    pub downs: u64,
    /// Preemption notices observed.
    pub notices: u64,
    /// Evacuated requests re-queued for another instance.
    pub rerouted: u64,
    /// Evacuated requests dropped (mid-decode state is unrecoverable).
    pub lost: u64,
    /// Instances restored into the serving set after an outage.
    pub backfills: u64,
    /// Sum of recovery latencies, seconds (see `recoveries`).
    pub recovery_s_sum: f64,
    /// Closed recovery episodes: outage start → evacuated work
    /// re-admitted (coordinator recovery) or instance restart (native).
    pub recoveries: u64,
}

impl ChurnTelemetry {
    /// Mean recovery latency over the closed episodes, seconds.
    pub fn mean_recovery_s(&self) -> f64 {
        if self.recoveries == 0 {
            0.0
        } else {
            self.recovery_s_sum / self.recoveries as f64
        }
    }

    /// Did this run see any fault at all?
    pub fn any(&self) -> bool {
        self.faults > 0
    }
}

/// Same-target overlap key for validation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Target {
    Instance(usize),
    Node(usize),
    Link,
}

impl Fault {
    /// Validation window `[start, end)` during which the target is
    /// affected, plus the target itself.
    fn window(&self) -> (Target, f64, f64) {
        match self.kind {
            FaultKind::Crash { instance, down_s } => {
                (Target::Instance(instance), self.at, self.at + down_s)
            }
            FaultKind::NodeLoss { node, down_s } => {
                (Target::Node(node), self.at, self.at + down_s)
            }
            FaultKind::Preempt { instance, notice_s, down_s } => (
                Target::Instance(instance),
                self.at + notice_s,
                self.at + notice_s + down_s,
            ),
            FaultKind::LinkDegrade { factor: _, for_s } => {
                (Target::Link, self.at, self.at + for_s)
            }
        }
    }
}

/// A validated fault timeline: times non-decreasing, every fault
/// well-formed, and no two faults against the *same* target overlapping
/// (a node loss may still swallow an instance crash — expansion merges
/// those windows).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSchedule {
    faults: Vec<Fault>,
}

/// Shared validator; `where_` renders the error location ("fault[3]" for
/// programmatic lists, "faults.jsonl:4" for parsed ones).
fn validate(faults: &[Fault], where_: impl Fn(usize) -> String) -> Result<()> {
    let mut last_at = f64::NEG_INFINITY;
    let mut busy_until: BTreeMap<Target, f64> = BTreeMap::new();
    for (i, f) in faults.iter().enumerate() {
        let at = f.at;
        if !at.is_finite() || at < 0.0 {
            bail!("{}: fault time must be finite and >= 0, got {at}", where_(i));
        }
        if at < last_at {
            bail!(
                "{}: fault times must be non-decreasing ({at} after {last_at})",
                where_(i)
            );
        }
        last_at = at;
        match f.kind {
            FaultKind::Crash { down_s, .. } | FaultKind::NodeLoss { down_s, .. } => {
                if !down_s.is_finite() || down_s <= 0.0 {
                    bail!("{}: 'down_s' must be positive and finite, got {down_s}", where_(i));
                }
            }
            FaultKind::Preempt { notice_s, down_s, .. } => {
                if !notice_s.is_finite() || notice_s < 0.0 {
                    bail!("{}: 'notice_s' must be finite and >= 0, got {notice_s}", where_(i));
                }
                if !down_s.is_finite() || down_s <= 0.0 {
                    bail!("{}: 'down_s' must be positive and finite, got {down_s}", where_(i));
                }
            }
            FaultKind::LinkDegrade { factor, for_s } => {
                if !factor.is_finite() || factor < 1.0 {
                    bail!(
                        "{}: 'factor' must be a slowdown >= 1, got {factor}",
                        where_(i)
                    );
                }
                if !for_s.is_finite() || for_s <= 0.0 {
                    bail!("{}: 'for_s' must be positive and finite, got {for_s}", where_(i));
                }
            }
        }
        let (target, start, end) = f.window();
        if let Some(&until) = busy_until.get(&target) {
            if start < until {
                bail!(
                    "{}: fault overlaps the previous {} window (starts {start}, \
                     previous runs to {until})",
                    where_(i),
                    match target {
                        Target::Instance(k) => format!("instance-{k}"),
                        Target::Node(k) => format!("node-{k}"),
                        Target::Link => "link-degrade".to_string(),
                    }
                );
            }
        }
        let slot = busy_until.entry(target).or_insert(f64::NEG_INFINITY);
        *slot = slot.max(end);
    }
    Ok(())
}

impl FaultSchedule {
    /// An empty (fault-free) schedule.
    pub fn none() -> Self {
        FaultSchedule::default()
    }

    /// Validate and wrap an explicit fault list.
    pub fn new(faults: Vec<Fault>) -> Result<Self> {
        validate(&faults, |i| format!("fault[{i}]"))?;
        Ok(FaultSchedule { faults })
    }

    /// Parse a JSONL fault description. `src` labels errors (file name);
    /// every malformed, out-of-order, or overlapping line fails with its
    /// line number, exactly like the replay-log parser.
    pub fn parse_named(text: &str, src: &str) -> Result<FaultSchedule> {
        let mut faults = Vec::new();
        let mut lines = Vec::new();
        for (idx, line) in text.lines().enumerate() {
            let n = idx + 1;
            if line.trim().is_empty() {
                bail!("{src}:{n}: blank line (faults are one JSON object per line)");
            }
            let j = Json::parse(line)
                .map_err(|e| anyhow::anyhow!("{src}:{n}: {e}"))?;
            let at = j
                .get("at_s")
                .and_then(|v| v.as_f64())
                .with_context(|| format!("{src}:{n}: 'at_s' must be a number"))?;
            let kind = j
                .get("kind")
                .and_then(|v| v.as_str())
                .with_context(|| format!("{src}:{n}: 'kind' must be a string"))?;
            let num = |key: &str| -> Result<f64> {
                j.get(key)
                    .and_then(|v| v.as_f64())
                    .with_context(|| format!("{src}:{n}: '{key}' must be a number"))
            };
            let index = |key: &str| -> Result<usize> {
                let x = num(key)?;
                if x < 0.0 || x.fract() != 0.0 {
                    bail!("{src}:{n}: '{key}' must be a non-negative integer, got {x}");
                }
                Ok(x as usize)
            };
            let kind = match kind {
                "crash" => FaultKind::Crash { instance: index("instance")?, down_s: num("down_s")? },
                "node-loss" => FaultKind::NodeLoss { node: index("node")?, down_s: num("down_s")? },
                "preempt" => FaultKind::Preempt {
                    instance: index("instance")?,
                    notice_s: match j.get("notice_s") {
                        Some(_) => num("notice_s")?,
                        None => 0.0,
                    },
                    down_s: num("down_s")?,
                },
                "link-degrade" => {
                    FaultKind::LinkDegrade { factor: num("factor")?, for_s: num("for_s")? }
                }
                other => bail!(
                    "{src}:{n}: unknown fault kind '{other}' \
                     (crash, node-loss, preempt, link-degrade)"
                ),
            };
            faults.push(Fault { at, kind });
            lines.push(n);
        }
        validate(&faults, |i| format!("{src}:{}", lines[i]))?;
        Ok(FaultSchedule { faults })
    }

    /// Derive a schedule from a churn profile: faults land in
    /// `[warmup, duration)` with PCG64-jittered spacing and victims, so
    /// the same `(profile, seed, duration, warmup, instances)` tuple
    /// always yields the identical timeline.
    pub fn generate(
        profile: &ChurnProfile,
        seed: u64,
        duration: f64,
        warmup: f64,
        instances: usize,
    ) -> FaultSchedule {
        let mut faults = Vec::new();
        if duration <= warmup || instances == 0 {
            return FaultSchedule { faults };
        }
        let mut rng = Pcg64::new(seed, 0xFA17);
        let mut victim = rng.below(instances as u64) as usize;
        if let Some(every) = profile.crash_every_s {
            let mut t = warmup + every * 0.5;
            while t < duration {
                faults.push(Fault {
                    at: t,
                    kind: FaultKind::Crash {
                        instance: victim % instances,
                        down_s: profile.crash_down_s,
                    },
                });
                victim += 1 + rng.below(instances as u64) as usize;
                t += every * rng.uniform(0.75, 1.25);
            }
        }
        if let Some(every) = profile.preempt_every_s {
            let mut t = warmup + every * 0.65;
            while t < duration {
                faults.push(Fault {
                    at: t,
                    kind: FaultKind::Preempt {
                        instance: victim % instances,
                        notice_s: profile.preempt_notice_s,
                        down_s: profile.preempt_down_s,
                    },
                });
                victim += 1 + rng.below(instances as u64) as usize;
                t += every * rng.uniform(0.75, 1.25);
            }
        }
        faults.sort_by(|a, b| a.at.total_cmp(&b.at));
        // No validation: generated streams may overlap on an instance;
        // expansion merges those windows into one longer outage.
        FaultSchedule { faults }
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn len(&self) -> usize {
        self.faults.len()
    }

    /// Expand against a concrete deployment into the engine's event
    /// timeline, sorted by time (ties keep a deterministic build order).
    /// Instance indices wrap the deployment size so a schedule written
    /// for a larger fleet still injects; per-instance down-windows are
    /// merged so every `InstanceDown` pairs with exactly one
    /// `InstanceUp`.
    pub fn events(&self, d: &Deployment) -> Vec<(f64, FaultEvent)> {
        let n = d.num_instances();
        if n == 0 || self.faults.is_empty() {
            return Vec::new();
        }
        let mut intervals: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n];
        let mut notices: Vec<(f64, usize)> = Vec::new();
        let mut link: Vec<(f64, f64, f64)> = Vec::new();
        for f in &self.faults {
            match f.kind {
                FaultKind::Crash { instance, down_s } => {
                    intervals[instance % n].push((f.at, f.at + down_s));
                }
                FaultKind::NodeLoss { node, down_s } => {
                    for i in 0..n {
                        if d.node_of_instance(i) == node {
                            intervals[i].push((f.at, f.at + down_s));
                        }
                    }
                }
                FaultKind::Preempt { instance, notice_s, down_s } => {
                    let i = instance % n;
                    notices.push((f.at, i));
                    intervals[i].push((f.at + notice_s, f.at + notice_s + down_s));
                }
                FaultKind::LinkDegrade { factor, for_s } => {
                    link.push((f.at, f.at + for_s, factor));
                }
            }
        }
        let mut out: Vec<(f64, FaultEvent)> = Vec::new();
        for (t, i) in notices {
            out.push((t, FaultEvent::PreemptNotice { instance: i }));
        }
        for (i, mut iv) in intervals.into_iter().enumerate() {
            iv.sort_by(|a, b| a.0.total_cmp(&b.0));
            let mut merged: Vec<(f64, f64)> = Vec::new();
            for (s, e) in iv {
                match merged.last_mut() {
                    Some(last) if s <= last.1 => last.1 = last.1.max(e),
                    _ => merged.push((s, e)),
                }
            }
            for (s, e) in merged {
                out.push((s, FaultEvent::InstanceDown { instance: i }));
                out.push((e, FaultEvent::InstanceUp { instance: i }));
            }
        }
        for (s, e, factor) in link {
            out.push((s, FaultEvent::LinkDegrade { factor }));
            out.push((e, FaultEvent::LinkRestore));
        }
        // Stable by time: same-time ties fire in build order (notices
        // first, then instance windows by index, then link windows).
        out.sort_by(|a, b| a.0.total_cmp(&b.0));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::perfmodel::ModelSpec;

    fn deployment(gpus: usize) -> Deployment {
        let mut d =
            Deployment::paper_default(ModelSpec::codellama_34b(), ClusterSpec::l20_cluster());
        d.gpus_used = gpus;
        d
    }

    #[test]
    fn crash_expands_to_paired_down_up() {
        let s = FaultSchedule::new(vec![Fault {
            at: 40.0,
            kind: FaultKind::Crash { instance: 2, down_s: 20.0 },
        }])
        .unwrap();
        let ev = s.events(&deployment(16));
        assert_eq!(
            ev,
            vec![
                (40.0, FaultEvent::InstanceDown { instance: 2 }),
                (60.0, FaultEvent::InstanceUp { instance: 2 }),
            ]
        );
    }

    #[test]
    fn node_loss_takes_every_instance_on_the_node() {
        // 16 GPUs, TP=4 -> 4 instances, 2 per 8-GPU node.
        let d = deployment(16);
        let s = FaultSchedule::new(vec![Fault {
            at: 10.0,
            kind: FaultKind::NodeLoss { node: 0, down_s: 5.0 },
        }])
        .unwrap();
        let ev = s.events(&d);
        let downs: Vec<usize> = ev
            .iter()
            .filter_map(|(_, e)| match e {
                FaultEvent::InstanceDown { instance } => Some(*instance),
                _ => None,
            })
            .collect();
        assert_eq!(downs, vec![0, 1]);
    }

    #[test]
    fn preempt_notice_precedes_the_outage() {
        let s = FaultSchedule::new(vec![Fault {
            at: 100.0,
            kind: FaultKind::Preempt { instance: 1, notice_s: 5.0, down_s: 60.0 },
        }])
        .unwrap();
        let ev = s.events(&deployment(16));
        assert_eq!(
            ev,
            vec![
                (100.0, FaultEvent::PreemptNotice { instance: 1 }),
                (105.0, FaultEvent::InstanceDown { instance: 1 }),
                (165.0, FaultEvent::InstanceUp { instance: 1 }),
            ]
        );
    }

    #[test]
    fn overlapping_windows_merge_into_one_outage() {
        // Crash on instance 0, then a node loss swallowing it mid-outage:
        // one Down at 10, one Up at the later end (30).
        let s = FaultSchedule::new(vec![
            Fault { at: 10.0, kind: FaultKind::Crash { instance: 0, down_s: 10.0 } },
            Fault { at: 15.0, kind: FaultKind::NodeLoss { node: 0, down_s: 15.0 } },
        ])
        .unwrap();
        let ev = s.events(&deployment(16));
        let inst0: Vec<(f64, FaultEvent)> = ev
            .into_iter()
            .filter(|(_, e)| {
                matches!(
                    e,
                    FaultEvent::InstanceDown { instance: 0 }
                        | FaultEvent::InstanceUp { instance: 0 }
                )
            })
            .collect();
        assert_eq!(
            inst0,
            vec![
                (10.0, FaultEvent::InstanceDown { instance: 0 }),
                (30.0, FaultEvent::InstanceUp { instance: 0 }),
            ]
        );
    }

    #[test]
    fn out_of_order_schedule_rejected_with_line_number() {
        let text = "{\"at_s\":50,\"kind\":\"crash\",\"instance\":0,\"down_s\":5}\n\
                    {\"at_s\":20,\"kind\":\"crash\",\"instance\":1,\"down_s\":5}";
        let err = FaultSchedule::parse_named(text, "faults.jsonl").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("faults.jsonl:2"), "{msg}");
        assert!(msg.contains("non-decreasing"), "{msg}");
    }

    #[test]
    fn overlapping_same_instance_schedule_rejected_with_line_number() {
        let text = "{\"at_s\":10,\"kind\":\"crash\",\"instance\":3,\"down_s\":30}\n\
                    {\"at_s\":25,\"kind\":\"preempt\",\"instance\":3,\"notice_s\":0,\"down_s\":10}";
        let err = FaultSchedule::parse_named(text, "faults.jsonl").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("faults.jsonl:2"), "{msg}");
        assert!(msg.contains("overlaps"), "{msg}");
    }

    #[test]
    fn malformed_lines_rejected_with_line_number() {
        for (text, needle) in [
            ("{\"kind\":\"crash\",\"instance\":0,\"down_s\":5}", "'at_s'"),
            ("{\"at_s\":1,\"kind\":\"meteor\"}", "unknown fault kind"),
            ("{\"at_s\":1,\"kind\":\"crash\",\"instance\":0,\"down_s\":0}", "'down_s'"),
            ("{\"at_s\":1,\"kind\":\"link-degrade\",\"factor\":0.5,\"for_s\":5}", "'factor'"),
            ("{\"at_s\":1,\"kind\":\"crash\",\"instance\":1.5,\"down_s\":5}", "'instance'"),
            ("not json", "faults.jsonl:1"),
        ] {
            let err = FaultSchedule::parse_named(text, "faults.jsonl").unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("faults.jsonl:1"), "{text} -> {msg}");
            assert!(msg.contains(needle), "{text} -> {msg}");
        }
    }

    #[test]
    fn generate_is_deterministic_in_the_seed() {
        let p = ChurnProfile::crashes(40.0, 20.0);
        let a = FaultSchedule::generate(&p, 7, 240.0, 30.0, 8);
        let b = FaultSchedule::generate(&p, 7, 240.0, 30.0, 8);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        let c = FaultSchedule::generate(&p, 8, 240.0, 30.0, 8);
        assert_ne!(a, c, "different seeds should move the timeline");
        for f in a.faults() {
            assert!(f.at >= 30.0 && f.at < 240.0, "{f:?} outside [warmup, duration)");
        }
    }

    #[test]
    fn generate_handles_degenerate_spans() {
        let p = ChurnProfile::preemptions(50.0, 5.0, 30.0);
        assert!(FaultSchedule::generate(&p, 1, 10.0, 30.0, 8).is_empty());
        assert!(FaultSchedule::generate(&p, 1, 240.0, 30.0, 0).is_empty());
    }
}
