//! Event queue + simulation loop.
//!
//! Events are totally ordered by (time, sequence number) so simultaneous
//! events fire in insertion order and runs are deterministic bit-for-bit.
//!
//! The run loop does *not* preload the trace into the heap: arrivals are
//! merged from a cursor over the (already time-sorted) trace, so the heap
//! only ever holds the dynamic events currently in flight — its size
//! tracks active work, not total trace length. A pluggable stop condition
//! lets callers abandon a run the moment its outcome is decided (see
//! [`crate::metrics::SloMonitor`]); [`reference_run`] keeps the original
//! preload-everything engine as a differential-testing oracle.

use std::cell::Cell;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::metrics::Collector;
use crate::sim::faults::{ChurnTelemetry, FaultEvent};
use crate::workload::client::ClientLoop;
use crate::workload::Request;

/// Events a serving system reacts to.
#[derive(Debug, Clone)]
pub enum Event {
    /// A request reaches the overall scheduler.
    Arrival(Request),
    /// An instance's in-flight batch completes (or a deferred kick).
    InstanceWake { instance: usize },
    /// A network transfer completes (FuDG KV migration).
    TransferDone { transfer: u64 },
    /// Periodic controller tick (mitosis scaling, Figure 10).
    ControlTick,
    /// An injected fault fires (crash, restart, preemption notice, link
    /// degradation) — see [`crate::sim::faults`].
    Fault(FaultEvent),
    /// A closed-loop client's TTFT timer fires ([`crate::workload::client`]).
    /// Engine-internal: never dispatched to a [`System`], and never
    /// scheduled unless a client loop is attached to the run.
    ClientCheck { id: u64 },
}

/// Total order wrapper: min-heap on (time, seq).
#[derive(Debug)]
struct Entry {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // total_cmp, not partial_cmp-or-Equal: a NaN event time must not
        // be able to corrupt the heap's ordering invariant in release
        // builds (the debug_assert in `at` only guards debug runs).
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// The future-event queue handed to systems so they can schedule work.
#[derive(Debug, Default)]
pub struct EventScheduler {
    heap: BinaryHeap<Reverse<Entry>>,
    seq: u64,
}

impl EventScheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `event` at absolute time `time`.
    pub fn at(&mut self, time: f64, event: Event) {
        debug_assert!(time.is_finite(), "non-finite event time");
        self.seq += 1;
        self.heap.push(Reverse(Entry { time, seq: self.seq, event }));
    }

    fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|Reverse(e)| (e.time, e.event))
    }

    /// Fire time of the earliest queued dynamic event.
    fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(e)| e.time)
    }

    /// Queued events that would still fire at or before `horizon` — the
    /// ones a full run would actually have dispatched.
    fn len_within(&self, horizon: f64) -> usize {
        self.heap.iter().filter(|Reverse(e)| e.time <= horizon).count()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Reset for reuse, retaining the heap's capacity. Both halves of
    /// the reset are load-bearing for pooled reuse:
    /// * the heap is cleared, so entries left queued by a previous run
    ///   (an abandoned probe always leaves some) can never resurface;
    /// * the sequence counter restarts at 0, so tie-breaking in the next
    ///   run is bit-identical to a freshly constructed scheduler — stale
    ///   sequence numbers must not leak across runs.
    pub fn recycle(&mut self) {
        self.heap.clear();
        self.seq = 0;
    }
}

thread_local! {
    /// One spare scheduler per thread: the rate search runs thousands of
    /// probes back to back on the same worker thread, and reusing the
    /// heap's allocation across runs is what makes the merge loop
    /// allocation-free after the first (warmup) run. A `Cell<Option<_>>`
    /// (not `RefCell`) so take/put can never panic on re-entrancy.
    static SCHED_POOL: std::cell::Cell<Option<EventScheduler>> =
        const { std::cell::Cell::new(None) };
}

/// This thread's pooled scheduler (fresh if the pool is empty), recycled
/// to the exact observable state of `EventScheduler::new()` — only heap
/// capacity survives from previous runs.
fn pooled_scheduler() -> EventScheduler {
    let mut sched = SCHED_POOL.with(Cell::take).unwrap_or_default();
    sched.recycle();
    sched
}

/// Return a scheduler to this thread's pool for the next run.
fn repool_scheduler(sched: EventScheduler) {
    SCHED_POOL.with(|p| p.set(Some(sched)));
}

/// A serving system under simulation: the five schedulers implement this.
pub trait System {
    fn on_arrival(
        &mut self,
        req: Request,
        now: f64,
        sched: &mut EventScheduler,
        metrics: &mut Collector,
    );
    fn on_instance_wake(
        &mut self,
        instance: usize,
        now: f64,
        sched: &mut EventScheduler,
        metrics: &mut Collector,
    );
    fn on_transfer_done(
        &mut self,
        _transfer: u64,
        _now: f64,
        _sched: &mut EventScheduler,
        _metrics: &mut Collector,
    ) {
    }
    fn on_control_tick(
        &mut self,
        _now: f64,
        _sched: &mut EventScheduler,
        _metrics: &mut Collector,
    ) {
    }
    /// React to an injected fault. The default ignores faults entirely —
    /// a system that opts out simply keeps scheduling onto hardware that
    /// no longer exists, which is exactly the recovery-off ablation.
    fn on_fault(
        &mut self,
        _fault: FaultEvent,
        _now: f64,
        _sched: &mut EventScheduler,
        _metrics: &mut Collector,
    ) {
    }
    /// Churn bookkeeping accumulated by [`Self::on_fault`]; `None` when
    /// the run saw no faults (keeps fault-free reports byte-identical).
    fn churn_telemetry(&self) -> Option<ChurnTelemetry> {
        None
    }
    /// Overload-defense bookkeeping (sheds, brownout time); `None` when
    /// the system ran without defenses, so defense-free reports stay
    /// byte-identical.
    fn defense_telemetry(&self) -> Option<DefenseTelemetry> {
        None
    }
    /// Install the per-class priority ranker (request id → priority rank,
    /// 0 = most latency-critical) used by priority shedding. Systems
    /// without class-aware defenses ignore it.
    fn set_class_ranker(&mut self, _ranker: ClassRanker) {}
}

/// Request id → priority rank for per-class shedding (0 sheds last).
/// Built by the scenario driver from the scenario's class map.
pub type ClassRanker = std::sync::Arc<dyn Fn(u64) -> usize + Send + Sync>;

/// What a system's overload defenses did during a run; assembled into the
/// report's `overload` block next to client telemetry.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DefenseTelemetry {
    /// Arrivals rejected because the queue-implied TTFT already blew the
    /// SLO (deadline-aware admission control).
    pub deadline_rejects: u64,
    /// Arrivals shed because their class rank lost the priority triage.
    pub priority_sheds: u64,
    /// Backlogged requests shed after their own TTFT deadline passed
    /// (instead of being force-admitted to die on an instance).
    pub hopeless_sheds: u64,
    /// Arrivals bounced by a plain bounded waiting queue — the only
    /// defense the baseline stacks have natively.
    pub queue_full_rejects: u64,
    /// Simulated seconds spent in decode brownout.
    pub brownout_s: f64,
    /// Admissions whose decode length was capped by brownout.
    pub brownout_truncations: u64,
}

impl DefenseTelemetry {
    /// Total requests turned away by any defense.
    pub fn sheds(&self) -> u64 {
        self.deadline_rejects + self.priority_sheds + self.hopeless_sheds + self.queue_full_rejects
    }
}

/// Why a simulation run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// Every event drained before the horizon.
    Drained,
    /// The horizon cut the run off with events still queued.
    Horizon,
    /// The stop condition fired (e.g. the SLO verdict became decided).
    Abandoned,
}

/// Outcome of a simulation run.
#[derive(Debug)]
pub struct RunStats {
    pub sim_time: f64,
    /// Events dispatched to the system (arrivals included).
    pub events: u64,
    /// Events still queued *within the horizon* (remaining trace
    /// arrivals + dynamic heap) when the stop condition fired — a lower
    /// bound on the work abandonment avoided, since a full run would
    /// also have scheduled follow-on events. 0 unless
    /// `stop == StopReason::Abandoned`.
    pub events_saved: u64,
    pub stop: StopReason,
    /// Heap allocations performed by this thread during the run (counted
    /// by [`crate::util::alloc`]). Exactly 0 for a warm run — pooled
    /// scheduler, recycled collector, capacity-retaining system — which
    /// is the zero-alloc hot-loop contract asserted in tests and
    /// tracked per frontier cell in `BENCH_simperf.json`.
    pub allocs: u64,
    pub wall_time: std::time::Duration,
}

/// Drive `system` over `trace` until all events drain or `horizon` is hit.
/// Returns run statistics; completed requests land in `metrics`.
pub fn run(
    system: &mut dyn System,
    trace: Vec<Request>,
    horizon: f64,
    metrics: &mut Collector,
) -> RunStats {
    run_until(system, trace, horizon, metrics, |_, _| false)
}

/// [`run`] with a pluggable stop condition, checked once per event after
/// the clock (and any armed [`crate::metrics::SloMonitor`]) advances to
/// the event's time but *before* the event is dispatched. Returning true
/// ends the run with [`StopReason::Abandoned`]; the popped event is not
/// dispatched and counts toward `events_saved`, not `events`.
pub fn run_until(
    system: &mut dyn System,
    trace: Vec<Request>,
    horizon: f64,
    metrics: &mut Collector,
    stop: impl FnMut(f64, &Collector) -> bool,
) -> RunStats {
    run_until_faulted(system, trace, &[], horizon, metrics, stop)
}

/// [`run_until`] with an injected fault timeline. The `(time, event)`
/// pairs (see [`crate::sim::faults::FaultSchedule::events`]) are seeded
/// into the dynamic heap before the first arrival, so faults interleave
/// deterministically with the trace; with an empty fault list the
/// scheduler's sequence numbering is untouched and the run is
/// bit-identical to [`run_until`].
pub fn run_until_faulted(
    system: &mut dyn System,
    mut trace: Vec<Request>,
    faults: &[(f64, FaultEvent)],
    horizon: f64,
    metrics: &mut Collector,
    stop: impl FnMut(f64, &Collector) -> bool,
) -> RunStats {
    // The cursor merge needs a time-sorted trace. Generators emit sorted
    // traces; an unsorted one is stable-sorted, which reproduces exactly
    // the (time, insertion seq) order the preload heap used to impose.
    if !trace.windows(2).all(|w| w[0].arrival <= w[1].arrival) {
        trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    }
    run_source_until_faulted(system, trace.into_iter(), faults, horizon, metrics, stop)
}

/// The merge loop itself, generic over the arrival source: everything
/// [`run_until_faulted`] does after its sort check, for any time-ordered
/// iterator of requests. This is the streaming entry point — a
/// multi-day recorded log can be fed through
/// [`crate::workload::StreamedArrivals`] without ever materializing a
/// `Vec` of the whole trace; the engine's memory stays O(active events).
/// The iterator MUST yield requests in nondecreasing arrival order (the
/// Vec wrapper guarantees it by sorting; streaming sources enforce it
/// with a bounded reorder window).
pub fn run_source_until_faulted(
    system: &mut dyn System,
    arrivals: impl Iterator<Item = Request>,
    faults: &[(f64, FaultEvent)],
    horizon: f64,
    metrics: &mut Collector,
    stop: impl FnMut(f64, &Collector) -> bool,
) -> RunStats {
    run_core(system, arrivals, faults, None, horizon, metrics, stop)
}

/// The merge loop with an optional closed-loop client
/// ([`crate::workload::client::ClientLoop`]) attached. With `client ==
/// None` this *is* [`run_source_until_faulted`] — no extra events are
/// scheduled, no reject tracking is armed, and the run is bit-identical
/// to the clientless engine. With a client, every arrival arms a TTFT
/// timer ([`Event::ClientCheck`]), timeouts and admission rejections
/// feed retry re-arrivals back through the dynamic heap, and the
/// client's telemetry accumulates in place.
fn run_core(
    system: &mut dyn System,
    arrivals: impl Iterator<Item = Request>,
    faults: &[(f64, FaultEvent)],
    mut client: Option<&mut ClientLoop>,
    horizon: f64,
    metrics: &mut Collector,
    mut stop: impl FnMut(f64, &Collector) -> bool,
) -> RunStats {
    let wall_start = std::time::Instant::now();
    if client.is_some() {
        metrics.enable_reject_tracking();
    }
    let allocs_start = crate::util::alloc::thread_allocs();
    let mut arrivals = arrivals.peekable();
    // Pooled: same observable state as `EventScheduler::new()`, but the
    // heap allocation is reused across the thousands of runs a rate
    // search performs on this thread.
    let mut sched = pooled_scheduler();
    for &(t, fault) in faults {
        sched.at(t, Event::Fault(fault));
    }
    let mut now = 0.0;
    let mut dispatched: u64 = 0;
    let mut events_saved: u64 = 0;
    let mut reason = StopReason::Drained;
    loop {
        // Merge: next trace arrival vs. earliest dynamic event. Arrivals
        // win ties, matching the preloaded engine where every arrival
        // held a smaller sequence number than any dynamic event.
        let take_arrival = match (arrivals.peek(), sched.peek_time()) {
            (Some(req), Some(t)) => req.arrival <= t,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => break,
        };
        let (t, event) = if take_arrival {
            let req = arrivals.next().expect("peeked arrival");
            (req.arrival, Event::Arrival(req))
        } else {
            sched.pop().expect("peeked dynamic event")
        };
        if t > horizon {
            reason = StopReason::Horizon;
            break;
        }
        debug_assert!(t >= now - 1e-9, "time went backwards: {t} < {now}");
        now = t;
        metrics.observe_time(now);
        if stop(now, metrics) {
            reason = StopReason::Abandoned;
            // Count only work a full run would actually have dispatched:
            // the popped event (t <= horizon, checked above) plus every
            // queued event firing at or before the horizon.
            let arrivals_left =
                arrivals.by_ref().filter(|r| r.arrival <= horizon).count() as u64;
            events_saved = 1 + arrivals_left + sched.len_within(horizon) as u64;
            break;
        }
        dispatched += 1;
        match event {
            Event::Arrival(req) => {
                metrics.on_arrival(&req);
                if let Some(c) = client.as_deref_mut() {
                    c.on_arrival(&req, &mut sched);
                }
                system.on_arrival(req, now, &mut sched, metrics);
            }
            Event::InstanceWake { instance } => {
                system.on_instance_wake(instance, now, &mut sched, metrics);
            }
            Event::TransferDone { transfer } => {
                system.on_transfer_done(transfer, now, &mut sched, metrics);
            }
            Event::ControlTick => {
                system.on_control_tick(now, &mut sched, metrics);
            }
            Event::Fault(fault) => {
                metrics.trace_fault(&fault, now);
                system.on_fault(fault, now, &mut sched, metrics);
            }
            Event::ClientCheck { id } => {
                if let Some(c) = client.as_deref_mut() {
                    c.on_check(id, now, &mut sched, metrics);
                }
            }
        }
        // Fast rejection feedback: hand freshly rejected ids to the
        // client so it can back off and retry. Clientless runs never arm
        // the queue, so this drains nothing there.
        if let Some(c) = client.as_deref_mut() {
            while let Some(id) = metrics.pop_client_reject() {
                c.on_reject(id, now, &mut sched);
            }
        }
    }
    let allocs = crate::util::alloc::thread_allocs() - allocs_start;
    repool_scheduler(sched);
    RunStats {
        sim_time: now,
        events: dispatched,
        events_saved,
        stop: reason,
        allocs,
        wall_time: wall_start.elapsed(),
    }
}

/// Probe-run chooser shared by the harness and the scenario driver:
/// abort the moment the collector's armed SLO monitor decides the
/// verdict (`stop_early`), or drive the run to completion. Both modes
/// score identically — see [`crate::metrics::SloMonitor`].
pub fn run_abandonable(
    system: &mut dyn System,
    trace: Vec<Request>,
    horizon: f64,
    metrics: &mut Collector,
    stop_early: bool,
) -> RunStats {
    if stop_early {
        run_until(system, trace, horizon, metrics, |_, m: &Collector| m.decided())
    } else {
        run(system, trace, horizon, metrics)
    }
}

/// [`run_abandonable`] with an injected fault timeline.
pub fn run_faulted(
    system: &mut dyn System,
    trace: Vec<Request>,
    faults: &[(f64, FaultEvent)],
    horizon: f64,
    metrics: &mut Collector,
    stop_early: bool,
) -> RunStats {
    if stop_early {
        run_until_faulted(system, trace, faults, horizon, metrics, |_, m: &Collector| {
            m.decided()
        })
    } else {
        run_until_faulted(system, trace, faults, horizon, metrics, |_, _| false)
    }
}

/// [`run_faulted`] over a streaming arrival source ([`run_abandonable`]'s
/// chooser semantics, [`run_source_until_faulted`]'s memory profile).
/// The iterator must be time-ordered; see [`run_source_until_faulted`].
pub fn run_source_faulted(
    system: &mut dyn System,
    arrivals: impl Iterator<Item = Request>,
    faults: &[(f64, FaultEvent)],
    horizon: f64,
    metrics: &mut Collector,
    stop_early: bool,
) -> RunStats {
    if stop_early {
        run_source_until_faulted(system, arrivals, faults, horizon, metrics, |_, m: &Collector| {
            m.decided()
        })
    } else {
        run_source_until_faulted(system, arrivals, faults, horizon, metrics, |_, _| false)
    }
}

/// [`run_source_faulted`] with a closed-loop client attached: the
/// overload suite's engine entry point. `client = None` degrades to the
/// clientless engine bit-for-bit.
pub fn run_source_faulted_client(
    system: &mut dyn System,
    arrivals: impl Iterator<Item = Request>,
    faults: &[(f64, FaultEvent)],
    client: Option<&mut ClientLoop>,
    horizon: f64,
    metrics: &mut Collector,
    stop_early: bool,
) -> RunStats {
    if stop_early {
        run_core(system, arrivals, faults, client, horizon, metrics, |_, m: &Collector| {
            m.decided()
        })
    } else {
        run_core(system, arrivals, faults, client, horizon, metrics, |_, _| false)
    }
}

/// [`run_faulted`] with a closed-loop client attached (Vec-trace
/// convenience over [`run_source_faulted_client`], with the same
/// sort-check as [`run_until_faulted`]).
pub fn run_faulted_client(
    system: &mut dyn System,
    mut trace: Vec<Request>,
    faults: &[(f64, FaultEvent)],
    client: Option<&mut ClientLoop>,
    horizon: f64,
    metrics: &mut Collector,
    stop_early: bool,
) -> RunStats {
    if !trace.windows(2).all(|w| w[0].arrival <= w[1].arrival) {
        trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    }
    run_source_faulted_client(system, trace.into_iter(), faults, client, horizon, metrics, stop_early)
}

/// The original engine: preloads every trace arrival into the heap, so
/// heap size starts at the full trace length. Retained purely as a
/// differential-testing oracle for the cursor engine — tests pin that
/// both produce bit-identical completed records on the same trace.
#[doc(hidden)]
pub fn reference_run(
    system: &mut dyn System,
    trace: Vec<Request>,
    horizon: f64,
    metrics: &mut Collector,
) -> RunStats {
    reference_run_faulted(system, trace, &[], horizon, metrics)
}

/// [`reference_run`] with an injected fault timeline. Arrivals are
/// preloaded *before* faults so every arrival holds a smaller sequence
/// number than any fault at the same instant — matching the cursor
/// engine, where arrivals win ties against the dynamic heap.
#[doc(hidden)]
pub fn reference_run_faulted(
    system: &mut dyn System,
    trace: Vec<Request>,
    faults: &[(f64, FaultEvent)],
    horizon: f64,
    metrics: &mut Collector,
) -> RunStats {
    reference_run_faulted_client(system, trace, faults, None, horizon, metrics)
}

/// [`reference_run_faulted`] with an optional closed-loop client — the
/// differential oracle for the cursor engine's client path. Arrivals
/// preloaded before faults keeps arrival-wins-ties intact; client timers
/// and retries join the heap dynamically exactly as in the cursor engine.
#[doc(hidden)]
pub fn reference_run_faulted_client(
    system: &mut dyn System,
    trace: Vec<Request>,
    faults: &[(f64, FaultEvent)],
    mut client: Option<&mut ClientLoop>,
    horizon: f64,
    metrics: &mut Collector,
) -> RunStats {
    let wall_start = std::time::Instant::now();
    let allocs_start = crate::util::alloc::thread_allocs();
    if client.is_some() {
        metrics.enable_reject_tracking();
    }
    // Deliberately unpooled: the oracle must stay the naive engine the
    // cursor engine is differentially tested against.
    let mut sched = EventScheduler::new();
    for req in trace {
        sched.at(req.arrival, Event::Arrival(req));
    }
    for &(t, fault) in faults {
        sched.at(t, Event::Fault(fault));
    }
    let mut now = 0.0;
    let mut dispatched: u64 = 0;
    let mut reason = StopReason::Drained;
    while let Some((t, event)) = sched.pop() {
        if t > horizon {
            reason = StopReason::Horizon;
            break;
        }
        debug_assert!(t >= now - 1e-9, "time went backwards: {t} < {now}");
        now = t;
        metrics.observe_time(now);
        dispatched += 1;
        match event {
            Event::Arrival(req) => {
                metrics.on_arrival(&req);
                if let Some(c) = client.as_deref_mut() {
                    c.on_arrival(&req, &mut sched);
                }
                system.on_arrival(req, now, &mut sched, metrics);
            }
            Event::InstanceWake { instance } => {
                system.on_instance_wake(instance, now, &mut sched, metrics);
            }
            Event::TransferDone { transfer } => {
                system.on_transfer_done(transfer, now, &mut sched, metrics);
            }
            Event::ControlTick => {
                system.on_control_tick(now, &mut sched, metrics);
            }
            Event::Fault(fault) => {
                metrics.trace_fault(&fault, now);
                system.on_fault(fault, now, &mut sched, metrics);
            }
            Event::ClientCheck { id } => {
                if let Some(c) = client.as_deref_mut() {
                    c.on_check(id, now, &mut sched, metrics);
                }
            }
        }
        if let Some(c) = client.as_deref_mut() {
            while let Some(id) = metrics.pop_client_reject() {
                c.on_reject(id, now, &mut sched);
            }
        }
    }
    RunStats {
        sim_time: now,
        events: dispatched,
        events_saved: 0,
        stop: reason,
        allocs: crate::util::alloc::thread_allocs() - allocs_start,
        wall_time: wall_start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo system: completes each request after a fixed service time.
    struct Echo {
        service: f64,
        pending: Vec<(u64, f64)>, // (id, done_at)
    }

    impl System for Echo {
        fn on_arrival(
            &mut self,
            req: Request,
            now: f64,
            sched: &mut EventScheduler,
            metrics: &mut Collector,
        ) {
            metrics.on_first_token(req.id, now + self.service);
            self.pending.push((req.id, now + self.service));
            sched.at(now + self.service, Event::InstanceWake { instance: 0 });
        }

        fn on_instance_wake(
            &mut self,
            _i: usize,
            now: f64,
            _s: &mut EventScheduler,
            metrics: &mut Collector,
        ) {
            let done: Vec<u64> = self
                .pending
                .iter()
                .filter(|(_, t)| *t <= now + 1e-12)
                .map(|(id, _)| *id)
                .collect();
            self.pending.retain(|(_, t)| *t > now + 1e-12);
            for id in done {
                metrics.on_complete(id, now);
            }
        }
    }

    fn req(id: u64, arrival: f64) -> Request {
        Request { id, arrival, input_len: 8, output_len: 1 }
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut sched = EventScheduler::new();
        sched.at(3.0, Event::ControlTick);
        sched.at(1.0, Event::InstanceWake { instance: 7 });
        sched.at(2.0, Event::ControlTick);
        assert_eq!(sched.peek_time(), Some(1.0));
        let t1 = sched.pop().unwrap().0;
        let t2 = sched.pop().unwrap().0;
        let t3 = sched.pop().unwrap().0;
        assert_eq!((t1, t2, t3), (1.0, 2.0, 3.0));
        assert_eq!(sched.peek_time(), None);
    }

    #[test]
    fn ties_fire_in_insertion_order() {
        let mut sched = EventScheduler::new();
        sched.at(1.0, Event::InstanceWake { instance: 1 });
        sched.at(1.0, Event::InstanceWake { instance: 2 });
        match (sched.pop().unwrap().1, sched.pop().unwrap().1) {
            (Event::InstanceWake { instance: a }, Event::InstanceWake { instance: b }) => {
                assert_eq!((a, b), (1, 2));
            }
            _ => panic!("wrong events"),
        }
    }

    #[test]
    fn run_completes_all_requests() {
        let mut system = Echo { service: 0.25, pending: vec![] };
        let trace: Vec<Request> = (0..10).map(|i| req(i, i as f64 * 0.1)).collect();
        let mut metrics = Collector::new();
        let stats = run(&mut system, trace, 100.0, &mut metrics);
        assert_eq!(metrics.completed().len(), 10);
        assert!(stats.events >= 20);
        assert_eq!(stats.stop, StopReason::Drained);
        assert_eq!(stats.events_saved, 0);
        for r in metrics.completed() {
            assert!((r.ttft() - 0.25).abs() < 1e-9);
        }
    }

    #[test]
    fn horizon_cuts_off() {
        let mut system = Echo { service: 10.0, pending: vec![] };
        let trace = vec![req(0, 0.0), req(1, 50.0)];
        let mut metrics = Collector::new();
        let stats = run(&mut system, trace, 5.0, &mut metrics);
        assert!(metrics.completed().is_empty());
        assert_eq!(metrics.in_flight(), 1); // only the first arrived
        assert_eq!(stats.stop, StopReason::Horizon);
    }

    /// The cursor engine must reproduce the preload oracle bit for bit on
    /// a golden trace with same-time ties and interleaved dynamic events.
    #[test]
    fn cursor_engine_matches_reference_engine_bit_for_bit() {
        let golden: Vec<Request> = (0..200)
            .map(|i| {
                // Clustered arrivals with exact ties every third request,
                // so arrival-vs-arrival and arrival-vs-wake tie-breaking
                // are both exercised.
                let t = (i / 3) as f64 * 0.25;
                req(i, t)
            })
            .collect();
        let mut sys_a = Echo { service: 0.25, pending: vec![] };
        let mut sys_b = Echo { service: 0.25, pending: vec![] };
        let mut m_a = Collector::new();
        let mut m_b = Collector::new();
        let a = run(&mut sys_a, golden.clone(), 1_000.0, &mut m_a);
        let b = reference_run(&mut sys_b, golden, 1_000.0, &mut m_b);
        assert_eq!(a.events, b.events);
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        assert_eq!(m_a.completed().len(), m_b.completed().len());
        for (ra, rb) in m_a.completed().iter().zip(m_b.completed()) {
            assert_eq!(ra, rb, "records diverged");
            assert_eq!(ra.first_token.to_bits(), rb.first_token.to_bits());
            assert_eq!(ra.completion.to_bits(), rb.completion.to_bits());
        }
    }

    /// An unsorted trace must behave as if it had been preloaded into the
    /// ordering heap (stable time order).
    #[test]
    fn unsorted_trace_matches_reference_engine() {
        let mut shuffled: Vec<Request> =
            (0..50).map(|i| req(i, ((i * 7) % 50) as f64 * 0.1)).collect();
        shuffled.reverse();
        let mut sys_a = Echo { service: 0.1, pending: vec![] };
        let mut sys_b = Echo { service: 0.1, pending: vec![] };
        let mut m_a = Collector::new();
        let mut m_b = Collector::new();
        run(&mut sys_a, shuffled.clone(), 1_000.0, &mut m_a);
        reference_run(&mut sys_b, shuffled, 1_000.0, &mut m_b);
        assert_eq!(m_a.completed().len(), 50);
        let mut a: Vec<_> = m_a.completed().to_vec();
        let mut b: Vec<_> = m_b.completed().to_vec();
        a.sort_by_key(|r| r.id);
        b.sort_by_key(|r| r.id);
        assert_eq!(a, b);
    }

    #[test]
    fn stop_condition_abandons_and_reports_saved_events() {
        let mut system = Echo { service: 0.25, pending: vec![] };
        let trace: Vec<Request> = (0..100).map(|i| req(i, i as f64 * 0.1)).collect();
        let mut metrics = Collector::new();
        let stats = run_until(&mut system, trace, 1_000.0, &mut metrics, |now, _| now >= 2.0);
        assert_eq!(stats.stop, StopReason::Abandoned);
        assert!(stats.events_saved > 0, "{stats:?}");
        assert!(stats.events < 200, "{stats:?}");
        // The run stopped around t=2.0: roughly 20 of 100 arrivals seen.
        assert!(metrics.completed().len() < 30);
    }

    /// Feeding the same sorted trace through the iterator entry point
    /// must be indistinguishable from the Vec wrapper, bit for bit —
    /// this is the contract the streaming replay path leans on.
    #[test]
    fn source_engine_matches_vec_engine_bit_for_bit() {
        let golden: Vec<Request> =
            (0..300).map(|i| req(i, (i / 3) as f64 * 0.2)).collect();
        let mut sys_a = Echo { service: 0.3, pending: vec![] };
        let mut sys_b = Echo { service: 0.3, pending: vec![] };
        let mut m_a = Collector::new();
        let mut m_b = Collector::new();
        let a = run_source_faulted(&mut sys_a, golden.clone().into_iter(), &[], 1_000.0, &mut m_a, false);
        let b = run(&mut sys_b, golden, 1_000.0, &mut m_b);
        assert_eq!(a.events, b.events);
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        assert_eq!(m_a.completed().len(), m_b.completed().len());
        for (ra, rb) in m_a.completed().iter().zip(m_b.completed()) {
            assert_eq!(ra, rb, "records diverged");
            assert_eq!(ra.first_token.to_bits(), rb.first_token.to_bits());
            assert_eq!(ra.completion.to_bits(), rb.completion.to_bits());
        }
    }

    #[test]
    fn heap_tracks_active_events_not_trace_length() {
        // 10_000 arrivals, but Echo keeps at most one pending wake per
        // arrival in flight; the dynamic heap must stay tiny. Probed via
        // the scheduler a system sees mid-run.
        struct Probe {
            inner: Echo,
            max_heap: usize,
        }
        impl System for Probe {
            fn on_arrival(
                &mut self,
                req: Request,
                now: f64,
                sched: &mut EventScheduler,
                metrics: &mut Collector,
            ) {
                self.inner.on_arrival(req, now, sched, metrics);
                self.max_heap = self.max_heap.max(sched.len());
            }
            fn on_instance_wake(
                &mut self,
                i: usize,
                now: f64,
                sched: &mut EventScheduler,
                metrics: &mut Collector,
            ) {
                self.inner.on_instance_wake(i, now, sched, metrics);
                self.max_heap = self.max_heap.max(sched.len());
            }
        }
        let mut probe = Probe { inner: Echo { service: 0.01, pending: vec![] }, max_heap: 0 };
        let trace: Vec<Request> = (0..10_000).map(|i| req(i, i as f64 * 0.1)).collect();
        let mut metrics = Collector::new();
        run(&mut probe, trace, 2_000.0, &mut metrics);
        assert_eq!(metrics.completed().len(), 10_000);
        assert!(probe.max_heap < 64, "heap grew to {}", probe.max_heap);
    }

    /// Pool-reuse hazard #1, unit level: recycling must drop queued
    /// entries *and* restart the sequence counter, so a refilled
    /// scheduler breaks ties by the new insertion order — never by stale
    /// sequence numbers from the previous run.
    #[test]
    fn recycling_resets_sequence_numbers_and_drops_stale_entries() {
        let mut sched = EventScheduler::new();
        sched.at(1.0, Event::InstanceWake { instance: 1 });
        sched.at(1.0, Event::InstanceWake { instance: 2 });
        assert!(sched.pop().is_some());
        // Drain abandoned midway: one stale entry still queued.
        assert!(!sched.is_empty());
        sched.recycle();
        assert!(sched.is_empty(), "stale entries must not survive recycling");
        assert_eq!(sched.seq, 0, "sequence numbers must restart at 0");
        // Refill: ties fire in the *new* insertion order, exactly as on
        // a freshly constructed scheduler.
        sched.at(2.0, Event::InstanceWake { instance: 7 });
        sched.at(2.0, Event::InstanceWake { instance: 8 });
        match (sched.pop().unwrap().1, sched.pop().unwrap().1) {
            (Event::InstanceWake { instance: a }, Event::InstanceWake { instance: b }) => {
                assert_eq!((a, b), (7, 8));
            }
            _ => panic!("wrong events"),
        }
        assert!(sched.is_empty());
    }

    /// Pool-reuse hazard #1, engine level: an abandoned run repools its
    /// scheduler with events still queued; the next run on this thread
    /// takes that scheduler from the pool and must be bit-identical to
    /// the never-pooled reference engine — same tie order (the golden
    /// trace ties every third arrival), no resurrected entries.
    #[test]
    fn pooled_run_after_abandoned_run_matches_reference_bit_for_bit() {
        let golden: Vec<Request> =
            (0..200).map(|i| req(i, (i / 3) as f64 * 0.25)).collect();
        let mut warm_sys = Echo { service: 0.25, pending: vec![] };
        let mut warm_m = Collector::new();
        let w = run_until(&mut warm_sys, golden.clone(), 1_000.0, &mut warm_m, |now, _| {
            now >= 4.0
        });
        assert_eq!(w.stop, StopReason::Abandoned);
        assert!(w.events_saved > 0, "abandoned run must leave queued events");
        let mut sys_a = Echo { service: 0.25, pending: vec![] };
        let mut sys_b = Echo { service: 0.25, pending: vec![] };
        let mut m_a = Collector::new();
        let mut m_b = Collector::new();
        let a = run(&mut sys_a, golden.clone(), 1_000.0, &mut m_a);
        let b = reference_run(&mut sys_b, golden, 1_000.0, &mut m_b);
        assert_eq!(a.events, b.events);
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        assert_eq!(m_a.completed().len(), m_b.completed().len());
        for (ra, rb) in m_a.completed().iter().zip(m_b.completed()) {
            assert_eq!(ra, rb, "records diverged after pool reuse");
            assert_eq!(ra.first_token.to_bits(), rb.first_token.to_bits());
            assert_eq!(ra.completion.to_bits(), rb.completion.to_bits());
        }
    }

    /// Echo variant whose own handlers never allocate (completions via
    /// `swap_remove`, not a collected Vec) — the probe for the
    /// zero-alloc hot-loop contract.
    struct LeanEcho {
        service: f64,
        pending: Vec<(u64, f64)>, // (id, done_at)
    }

    impl System for LeanEcho {
        fn on_arrival(
            &mut self,
            req: Request,
            now: f64,
            sched: &mut EventScheduler,
            metrics: &mut Collector,
        ) {
            metrics.on_first_token(req.id, now + self.service);
            self.pending.push((req.id, now + self.service));
            sched.at(now + self.service, Event::InstanceWake { instance: 0 });
        }

        fn on_instance_wake(
            &mut self,
            _i: usize,
            now: f64,
            _s: &mut EventScheduler,
            metrics: &mut Collector,
        ) {
            let mut i = 0;
            while i < self.pending.len() {
                if self.pending[i].1 <= now + 1e-12 {
                    let (id, _) = self.pending.swap_remove(i);
                    metrics.on_complete(id, now);
                } else {
                    i += 1;
                }
            }
        }
    }

    /// Single-server FIFO queue with a bounded waiting room: requests
    /// queue for service, overflow is rejected at admission. Under
    /// sustained overload TTFT grows without bound, so client timers and
    /// rejection feedback both fire — the test rig for the client path.
    struct QueueServer {
        service: f64,
        cap: usize,
        queue: std::collections::VecDeque<u64>,
        busy: bool,
    }

    impl QueueServer {
        fn new(service: f64, cap: usize) -> Self {
            QueueServer { service, cap, queue: Default::default(), busy: false }
        }
    }

    impl System for QueueServer {
        fn on_arrival(
            &mut self,
            req: Request,
            now: f64,
            sched: &mut EventScheduler,
            metrics: &mut Collector,
        ) {
            if self.queue.len() >= self.cap {
                metrics.on_reject(req.id);
                return;
            }
            self.queue.push_back(req.id);
            if !self.busy {
                self.busy = true;
                sched.at(now + self.service, Event::InstanceWake { instance: 0 });
            }
        }

        fn on_instance_wake(
            &mut self,
            _i: usize,
            now: f64,
            sched: &mut EventScheduler,
            metrics: &mut Collector,
        ) {
            if let Some(id) = self.queue.pop_front() {
                metrics.on_first_token(id, now);
                metrics.on_complete(id, now);
            }
            if self.queue.is_empty() {
                self.busy = false;
            } else {
                sched.at(now + self.service, Event::InstanceWake { instance: 0 });
            }
        }
    }

    /// The client-in-the-loop cursor engine must reproduce the preload
    /// oracle bit for bit — timers, retries, and rejection feedback all
    /// ride the same (time, seq) order in both engines.
    #[test]
    fn client_engines_match_bit_for_bit_under_overload() {
        use crate::workload::client::{ClientLoop, ClientPolicy};
        // 2x overload: service 0.2s, arrivals every 0.1s, room for 8.
        let trace: Vec<Request> = (0..150).map(|i| req(i, i as f64 * 0.1)).collect();
        let policy = ClientPolicy {
            timeout_s: 1.0,
            max_retries: 2,
            backoff_base_s: 0.3,
            backoff_cap_s: 1.2,
            jitter_frac: 0.25,
            seed: 11,
        };
        let mut ca = ClientLoop::new(policy);
        let mut cb = ClientLoop::new(policy);
        let mut sys_a = QueueServer::new(0.2, 8);
        let mut sys_b = QueueServer::new(0.2, 8);
        let mut m_a = Collector::new();
        let mut m_b = Collector::new();
        let a = run_faulted_client(
            &mut sys_a, trace.clone(), &[], Some(&mut ca), 1_000.0, &mut m_a, false,
        );
        let b = reference_run_faulted_client(
            &mut sys_b, trace, &[], Some(&mut cb), 1_000.0, &mut m_b,
        );
        assert_eq!(a.events, b.events);
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        assert_eq!(ca.telemetry(), cb.telemetry());
        let t = ca.telemetry();
        assert!(t.timeouts > 0, "overloaded queue must time clients out: {t:?}");
        assert!(t.rejected > 0, "bounded waiting room must reject: {t:?}");
        assert!(t.retries > 0, "{t:?}");
        assert_eq!(m_a.completed().len(), m_b.completed().len());
        for (ra, rb) in m_a.completed().iter().zip(m_b.completed()) {
            assert_eq!(ra, rb, "records diverged");
            assert_eq!(ra.first_token.to_bits(), rb.first_token.to_bits());
            assert_eq!(ra.completion.to_bits(), rb.completion.to_bits());
        }
        assert_eq!(m_a.rejected, m_b.rejected);
    }

    /// `client = None` through the client entry point must be the
    /// clientless engine, bit for bit — the defenses-off invariant at
    /// the engine layer.
    #[test]
    fn disabled_client_is_bit_identical_to_clientless_engine() {
        let trace: Vec<Request> = (0..150).map(|i| req(i, i as f64 * 0.1)).collect();
        let mut sys_a = QueueServer::new(0.2, 8);
        let mut sys_b = QueueServer::new(0.2, 8);
        let mut m_a = Collector::new();
        let mut m_b = Collector::new();
        let a = run_faulted_client(&mut sys_a, trace.clone(), &[], None, 1_000.0, &mut m_a, false);
        let b = run(&mut sys_b, trace, 1_000.0, &mut m_b);
        assert_eq!(a.events, b.events);
        assert_eq!(a.sim_time.to_bits(), b.sim_time.to_bits());
        assert_eq!(m_a.completed().len(), m_b.completed().len());
        for (ra, rb) in m_a.completed().iter().zip(m_b.completed()) {
            assert_eq!(ra, rb);
            assert_eq!(ra.first_token.to_bits(), rb.first_token.to_bits());
        }
        assert_eq!(m_a.rejected, m_b.rejected);
    }

    /// Retries amplify load: the same overloaded trace dispatches
    /// strictly more arrivals with a client loop than without, and the
    /// extra arrivals all carry retry-range ids.
    #[test]
    fn retry_storm_amplifies_offered_load() {
        use crate::workload::client::{ClientLoop, ClientPolicy, RETRY_ID_BASE};
        let trace: Vec<Request> = (0..150).map(|i| req(i, i as f64 * 0.1)).collect();
        let mut client = ClientLoop::new(ClientPolicy {
            timeout_s: 0.8,
            max_retries: 3,
            backoff_base_s: 0.2,
            backoff_cap_s: 1.0,
            jitter_frac: 0.2,
            seed: 5,
        });
        let mut sys = QueueServer::new(0.2, 8);
        let mut m = Collector::new();
        run_faulted_client(&mut sys, trace.clone(), &[], Some(&mut client), 1_000.0, &mut m, false);
        let retry_completions =
            m.completed().iter().filter(|r| r.id >= RETRY_ID_BASE).count();
        assert!(client.telemetry().retries > 0);
        assert!(
            retry_completions > 0,
            "some retries must make it through the queue"
        );
        // First-attempt records stay identifiable for scoring.
        assert!(m.completed().iter().any(|r| r.id < RETRY_ID_BASE));
    }

    /// The tentpole contract: after a warmup run has grown the pooled
    /// scheduler heap, the collector's request columns, the completed
    /// record log, and the system's own buffers to steady-state
    /// capacity, an identical second run performs exactly zero heap
    /// allocations in the merge loop.
    #[test]
    fn hot_loop_is_allocation_free_after_warmup() {
        let trace: Vec<Request> = (0..2_000).map(|i| req(i, i as f64 * 0.01)).collect();
        let mut sys = LeanEcho { service: 0.005, pending: Vec::new() };
        let mut metrics = Collector::new();
        // Warmup: grows every buffer (and seeds this thread's pool).
        let warm = run(&mut sys, trace.clone(), 1_000.0, &mut metrics);
        assert_eq!(metrics.completed().len(), 2_000);
        assert!(warm.allocs > 0, "cold run must have allocated");
        // Warm run: recycled collector, pooled scheduler, retained
        // system capacity — the loop itself must allocate nothing.
        metrics.recycle(None);
        sys.pending.clear();
        let stats = run(&mut sys, trace.clone(), 1_000.0, &mut metrics);
        assert_eq!(metrics.completed().len(), 2_000);
        assert_eq!(stats.events, warm.events);
        assert_eq!(stats.allocs, 0, "hot loop allocated after warmup: {stats:?}");
        // Recorder attached: the first traced run may allocate (the sink's
        // event vec grows to steady state), but a *warmed* sink cleared
        // and re-attached appends the same events with zero allocations —
        // the recorder adds no per-event heap traffic.
        metrics.recycle(None);
        metrics.attach_sink(crate::trace::TraceSink::new());
        sys.pending.clear();
        run(&mut sys, trace.clone(), 1_000.0, &mut metrics);
        let mut sink = metrics.take_sink().expect("sink survives the run");
        let traced_events = sink.len();
        assert!(traced_events >= 2_000, "lifecycle events recorded");
        sink.clear();
        metrics.recycle(None);
        metrics.attach_sink(sink);
        sys.pending.clear();
        let traced = run(&mut sys, trace.clone(), 1_000.0, &mut metrics);
        assert_eq!(metrics.completed().len(), 2_000);
        assert_eq!(traced.events, warm.events);
        let sink = metrics.take_sink().expect("sink still attached");
        assert_eq!(sink.len(), traced_events, "traced rerun records identically");
        assert_eq!(traced.allocs, 0, "warmed recorder allocated: {traced:?}");
        // Recorder detached again: back to the strict zero-alloc contract.
        metrics.recycle(None);
        sys.pending.clear();
        let off = run(&mut sys, trace, 1_000.0, &mut metrics);
        assert_eq!(metrics.completed().len(), 2_000);
        assert_eq!(off.allocs, 0, "recorder-off run allocated: {off:?}");
    }
}
