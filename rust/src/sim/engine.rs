//! Event queue + simulation loop.
//!
//! Events are totally ordered by (time, sequence number) so simultaneous
//! events fire in insertion order and runs are deterministic bit-for-bit.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::metrics::Collector;
use crate::workload::Request;

/// Events a serving system reacts to.
#[derive(Debug, Clone)]
pub enum Event {
    /// A request reaches the overall scheduler.
    Arrival(Request),
    /// An instance's in-flight batch completes (or a deferred kick).
    InstanceWake { instance: usize },
    /// A network transfer completes (FuDG KV migration).
    TransferDone { transfer: u64 },
    /// Periodic controller tick (mitosis scaling, Figure 10).
    ControlTick,
}

/// Total order wrapper: min-heap on (time, seq).
#[derive(Debug)]
struct Entry {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .partial_cmp(&other.time)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(self.seq.cmp(&other.seq))
    }
}

/// The future-event queue handed to systems so they can schedule work.
#[derive(Debug, Default)]
pub struct EventScheduler {
    heap: BinaryHeap<Reverse<Entry>>,
    seq: u64,
    /// Events processed so far (simulator §Perf metric).
    pub processed: u64,
}

impl EventScheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `event` at absolute time `time`.
    pub fn at(&mut self, time: f64, event: Event) {
        debug_assert!(time.is_finite(), "non-finite event time");
        self.seq += 1;
        self.heap.push(Reverse(Entry { time, seq: self.seq, event }));
    }

    fn pop(&mut self) -> Option<(f64, Event)> {
        self.heap.pop().map(|Reverse(e)| {
            self.processed += 1;
            (e.time, e.event)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// A serving system under simulation: the five schedulers implement this.
pub trait System {
    fn on_arrival(
        &mut self,
        req: Request,
        now: f64,
        sched: &mut EventScheduler,
        metrics: &mut Collector,
    );
    fn on_instance_wake(
        &mut self,
        instance: usize,
        now: f64,
        sched: &mut EventScheduler,
        metrics: &mut Collector,
    );
    fn on_transfer_done(
        &mut self,
        _transfer: u64,
        _now: f64,
        _sched: &mut EventScheduler,
        _metrics: &mut Collector,
    ) {
    }
    fn on_control_tick(
        &mut self,
        _now: f64,
        _sched: &mut EventScheduler,
        _metrics: &mut Collector,
    ) {
    }
}

/// Outcome of a simulation run.
#[derive(Debug)]
pub struct RunStats {
    pub sim_time: f64,
    pub events: u64,
    pub wall_time: std::time::Duration,
}

/// Drive `system` over `trace` until all events drain or `horizon` is hit.
/// Returns run statistics; completed requests land in `metrics`.
pub fn run(
    system: &mut dyn System,
    trace: Vec<Request>,
    horizon: f64,
    metrics: &mut Collector,
) -> RunStats {
    let wall_start = std::time::Instant::now();
    let mut sched = EventScheduler::new();
    for req in trace {
        sched.at(req.arrival, Event::Arrival(req));
    }
    let mut now = 0.0;
    while let Some((t, event)) = sched.pop() {
        if t > horizon {
            break;
        }
        debug_assert!(t >= now - 1e-9, "time went backwards: {t} < {now}");
        now = t;
        match event {
            Event::Arrival(req) => {
                metrics.on_arrival(&req);
                system.on_arrival(req, now, &mut sched, metrics);
            }
            Event::InstanceWake { instance } => {
                system.on_instance_wake(instance, now, &mut sched, metrics);
            }
            Event::TransferDone { transfer } => {
                system.on_transfer_done(transfer, now, &mut sched, metrics);
            }
            Event::ControlTick => {
                system.on_control_tick(now, &mut sched, metrics);
            }
        }
    }
    RunStats {
        sim_time: now,
        events: sched.processed,
        wall_time: wall_start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo system: completes each request after a fixed service time.
    struct Echo {
        service: f64,
        pending: Vec<(u64, f64)>, // (id, done_at)
    }

    impl System for Echo {
        fn on_arrival(
            &mut self,
            req: Request,
            now: f64,
            sched: &mut EventScheduler,
            metrics: &mut Collector,
        ) {
            metrics.on_first_token(req.id, now + self.service);
            self.pending.push((req.id, now + self.service));
            sched.at(now + self.service, Event::InstanceWake { instance: 0 });
        }

        fn on_instance_wake(&mut self, _i: usize, now: f64, _s: &mut EventScheduler,
                            metrics: &mut Collector) {
            let done: Vec<u64> = self
                .pending
                .iter()
                .filter(|(_, t)| *t <= now + 1e-12)
                .map(|(id, _)| *id)
                .collect();
            self.pending.retain(|(_, t)| *t > now + 1e-12);
            for id in done {
                metrics.on_complete(id, now);
            }
        }
    }

    fn req(id: u64, arrival: f64) -> Request {
        Request { id, arrival, input_len: 8, output_len: 1 }
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut sched = EventScheduler::new();
        sched.at(3.0, Event::ControlTick);
        sched.at(1.0, Event::InstanceWake { instance: 7 });
        sched.at(2.0, Event::ControlTick);
        let t1 = sched.pop().unwrap().0;
        let t2 = sched.pop().unwrap().0;
        let t3 = sched.pop().unwrap().0;
        assert_eq!((t1, t2, t3), (1.0, 2.0, 3.0));
    }

    #[test]
    fn ties_fire_in_insertion_order() {
        let mut sched = EventScheduler::new();
        sched.at(1.0, Event::InstanceWake { instance: 1 });
        sched.at(1.0, Event::InstanceWake { instance: 2 });
        match (sched.pop().unwrap().1, sched.pop().unwrap().1) {
            (Event::InstanceWake { instance: a }, Event::InstanceWake { instance: b }) => {
                assert_eq!((a, b), (1, 2));
            }
            _ => panic!("wrong events"),
        }
    }

    #[test]
    fn run_completes_all_requests() {
        let mut system = Echo { service: 0.25, pending: vec![] };
        let trace: Vec<Request> = (0..10).map(|i| req(i, i as f64 * 0.1)).collect();
        let mut metrics = Collector::new();
        let stats = run(&mut system, trace, 100.0, &mut metrics);
        assert_eq!(metrics.completed().len(), 10);
        assert!(stats.events >= 20);
        for r in metrics.completed() {
            assert!((r.ttft() - 0.25).abs() < 1e-9);
        }
    }

    #[test]
    fn horizon_cuts_off() {
        let mut system = Echo { service: 10.0, pending: vec![] };
        let trace = vec![req(0, 0.0), req(1, 50.0)];
        let mut metrics = Collector::new();
        run(&mut system, trace, 5.0, &mut metrics);
        assert!(metrics.completed().is_empty());
        assert_eq!(metrics.in_flight(), 1); // only the first arrived
    }
}
