//! # EcoServe
//!
//! A from-scratch reproduction of *EcoServe: Enabling Cost-effective LLM
//! Serving with Proactive Intra- and Inter-Instance Orchestration* (cs.DC
//! 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper's contribution — the **PaDG (partially disaggregated)
//! strategy** — lives in [`coordinator`]: prefill and decode phases are
//! disaggregated *in time* within each instance (temporal disaggregation),
//! and instances inside a *macro instance* stagger their prefill windows
//! (rolling activation) so some instance is always accepting new requests.
//!
//! Layer map (see `DESIGN.md`):
//! * **L3 (this crate)** — coordinator, schedulers, KV management, metrics,
//!   the discrete-event cluster simulator, and the analytical GPU
//!   performance model used to reproduce the paper's evaluation.
//! * **L2 (`python/compile/model.py`)** — TinyLM JAX graphs, AOT-lowered to
//!   HLO text once at build time (`make artifacts`).
//! * **L1 (`python/compile/kernels/`)** — Pallas flash-attention kernels.
//!
//! Python never runs on the request path: [`runtime`] loads the HLO
//! artifacts through the PJRT C API (`xla` crate) and executes them from
//! the Rust hot loop.
//!
//! The live-serving layers (`runtime::{engine,pjrt}`, `coordinator::live`,
//! `server`) sit behind the `pjrt` cargo feature (default **off**) so the
//! simulator, harness, and scenario suite build and test on machines with
//! no XLA shared library.

// The tree is hand-formatted (~80 cols, aligned tables) and predates
// rustfmt/clippy adoption; style/complexity/perf lint groups are advisory
// here while the correctness and suspicious groups — plus all rustc
// warnings — stay enforced for the library and CLI by CI's
// `clippy -- -D warnings` (both feature edges; see .github/workflows).
#![allow(clippy::style, clippy::complexity, clippy::perf)]

/// Count heap allocations per thread so the simulator's zero-alloc
/// hot-loop contract is measurable (see [`util::alloc`] and
/// [`sim::RunStats::allocs`]): one global allocator for the library,
/// the CLI, and every integration test.
#[global_allocator]
static GLOBAL_ALLOC: util::alloc::CountingAlloc = util::alloc::CountingAlloc;

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod frontier;
pub mod harness;
pub mod metrics;
pub mod perfmodel;
pub mod planner;
pub mod runtime;
pub mod scenarios;
#[cfg(feature = "pjrt")]
pub mod server;
pub mod sim;
pub mod testing;
pub mod trace;
pub mod util;
pub mod workload;
