//! Test-support code compiled into the crate (so unit tests, integration
//! tests, and benches can share it). The property-test harness substitutes
//! for `proptest`, which is unavailable in the offline image.

pub mod prop;
