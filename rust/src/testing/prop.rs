//! Minimal property-based testing harness (substrate for `proptest`).
//!
//! `check(name, cases, |g| ...)` runs a closure against `cases` random
//! inputs drawn through a [`Gen`]. On failure it reruns the recorded draw
//! trace with progressively simpler values (halving shrink) and reports the
//! seed so the exact case can be replayed with `PROP_SEED=<n>`.
//!
//! Coordinator invariants (routing, batching, mitosis state) are verified
//! with this harness — see `rust/tests/prop_coordinator.rs`.

use crate::util::rng::Pcg64;

/// Random input source handed to property bodies.
pub struct Gen {
    rng: Pcg64,
    /// Trace of raw draws, kept so a failing case can be reported.
    pub trace: Vec<u64>,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            rng: Pcg64::seeded(seed),
            trace: Vec::new(),
        }
    }

    fn record(&mut self, x: u64) -> u64 {
        self.trace.push(x);
        x
    }

    /// Integer in [lo, hi] inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        let span = (hi - lo) as u64;
        let x = if span == u64::MAX {
            self.rng.next_u64()
        } else {
            self.rng.below(span + 1)
        };
        lo + self.record(x) as i64
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    /// f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        let x = self.rng.next_u64();
        self.record(x);
        let unit = (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        lo + (hi - lo) * unit
    }

    pub fn bool(&mut self) -> bool {
        self.int(0, 1) == 1
    }

    /// Vector with random length in [min_len, max_len].
    pub fn vec<T>(
        &mut self,
        min_len: usize,
        max_len: usize,
        mut item: impl FnMut(&mut Gen) -> T,
    ) -> Vec<T> {
        let n = self.usize(min_len, max_len);
        (0..n).map(|_| item(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.usize(0, xs.len() - 1);
        &xs[i]
    }
}

/// Outcome of a property body. Use `prop_assert!` or return `Err(msg)`.
pub type PropResult = Result<(), String>;

/// Run `body` against `cases` random generators. Panics (test failure) with
/// the seed and message of the first failing case.
pub fn check(name: &str, cases: u64, body: impl Fn(&mut Gen) -> PropResult) {
    let base_seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok());
    if let Some(seed) = base_seed {
        let mut g = Gen::new(seed);
        if let Err(msg) = body(&mut g) {
            panic!("property '{name}' failed (replay seed {seed}): {msg}");
        }
        return;
    }
    for case in 0..cases {
        // Seeds are deterministic per (name, case) so CI failures replay.
        let seed = fnv1a(name) ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = body(&mut g) {
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !($cond) {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        // count via interior mutability through a Cell
        let counter = std::cell::Cell::new(0u64);
        check("sum-commutes", 50, |g| {
            counter.set(counter.get() + 1);
            let a = g.int(-1000, 1000);
            let b = g.int(-1000, 1000);
            prop_assert!(a + b == b + a);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 10, |g| {
            let x = g.int(0, 100);
            prop_assert!(x > 1000, "x={x} not > 1000");
            Ok(())
        });
    }

    #[test]
    fn gen_ranges_respected() {
        check("gen-ranges", 100, |g| {
            let x = g.int(-5, 5);
            prop_assert!((-5..=5).contains(&x));
            let u = g.usize(2, 4);
            prop_assert!((2..=4).contains(&u));
            let f = g.f64(1.0, 2.0);
            prop_assert!((1.0..2.0).contains(&f));
            let v = g.vec(1, 8, |g| g.bool());
            prop_assert!((1..=8).contains(&v.len()));
            let p = *g.pick(&[10, 20, 30]);
            prop_assert!([10, 20, 30].contains(&p));
            Ok(())
        });
    }
}
