//! Byte-level tokenizer for the TinyLM live path.
//!
//! Vocabulary (512 ids, matching TinyLMConfig.vocab):
//!   0        PAD
//!   1        BOS
//!   2        EOS
//!   3..=258  raw bytes 0..=255 (byte value + BYTE_BASE)
//!   259..511 merged digraphs of common ASCII pairs (greedy longest-match),
//!            trained statically over English text — enough compression to
//!            exercise multi-token prompts without a learned BPE.

use std::collections::HashMap;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
const BYTE_BASE: u32 = 3;
const PAIR_BASE: u32 = 259;

/// Static digraph table (common English bigrams; order = token id offset).
const PAIRS: &[&str] = &[
    "th", "he", "in", "er", "an", "re", "on", "at", "en", "nd", "ti", "es",
    "or", "te", "of", "ed", "is", "it", "al", "ar", "st", "to", "nt", "ng",
    "se", "ha", "as", "ou", "io", "le", "ve", "co", "me", "de", "hi", "ri",
    "ro", "ic", "ne", "ea", "ra", "ce", "li", "ch", "ll", "be", "ma", "si",
    "om", "ur", "e ", " t", " a", "s ", "d ", "t ", " s", " w", "w ", "o ",
];

/// Byte-level tokenizer with a static digraph merge table.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    pair_ids: HashMap<[u8; 2], u32>,
    pairs_by_id: Vec<[u8; 2]>,
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tokenizer {
    pub fn new() -> Self {
        let mut pair_ids = HashMap::new();
        let mut pairs_by_id = Vec::new();
        for (i, p) in PAIRS.iter().enumerate() {
            let b = p.as_bytes();
            let key = [b[0], b[1]];
            pair_ids.insert(key, PAIR_BASE + i as u32);
            pairs_by_id.push(key);
        }
        Tokenizer { pair_ids, pairs_by_id }
    }

    pub fn vocab_size(&self) -> usize {
        512
    }

    /// Encode text: BOS + greedy digraph/byte tokens. No EOS — generation
    /// appends it.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let bytes = text.as_bytes();
        let mut out = vec![BOS];
        let mut i = 0;
        while i < bytes.len() {
            if i + 1 < bytes.len() {
                if let Some(&id) = self.pair_ids.get(&[bytes[i], bytes[i + 1]]) {
                    out.push(id);
                    i += 2;
                    continue;
                }
            }
            out.push(BYTE_BASE + bytes[i] as u32);
            i += 1;
        }
        out
    }

    /// Decode ids back to text (PAD/BOS/EOS skipped; invalid ids become
    /// U+FFFD via lossy UTF-8).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::with_capacity(ids.len() * 2);
        for &id in ids {
            match id {
                PAD | BOS | EOS => {}
                id if id >= PAIR_BASE => {
                    let idx = (id - PAIR_BASE) as usize;
                    if idx < self.pairs_by_id.len() {
                        bytes.extend_from_slice(&self.pairs_by_id[idx]);
                    }
                }
                id if id >= BYTE_BASE => bytes.push((id - BYTE_BASE) as u8),
                _ => {}
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = Tokenizer::new();
        for s in ["the rain in spain", "hello, world!", "EcoServe PaDG 123"] {
            let ids = t.encode(s);
            assert_eq!(t.decode(&ids), s);
            assert_eq!(ids[0], BOS);
        }
    }

    #[test]
    fn roundtrip_unicode() {
        let t = Tokenizer::new();
        let s = "naïve — 東京";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn digraphs_compress() {
        let t = Tokenizer::new();
        let s = "the theatre there";
        let ids = t.encode(s);
        assert!(ids.len() - 1 < s.len(), "{} !< {}", ids.len() - 1, s.len());
    }

    #[test]
    fn all_ids_in_vocab() {
        let t = Tokenizer::new();
        let ids = t.encode("every token id must be < 512 \u{00e9}\u{4e2d}");
        assert!(ids.iter().all(|&i| (i as usize) < t.vocab_size()));
    }

    #[test]
    fn specials_skipped_in_decode() {
        let t = Tokenizer::new();
        let mut ids = t.encode("ok");
        ids.push(EOS);
        ids.insert(0, PAD);
        assert_eq!(t.decode(&ids), "ok");
    }
}
