//! `artifacts/weights.bin` + `manifest.json` loading.
//!
//! The manifest's `weights` index is the same `param_spec` order the AOT
//! executables expect positionally; the Rust engine must feed buffers in
//! exactly this order after (tokens, prompt_len) / (tokens, positions,
//! k_cache, v_cache).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// TinyLM architecture constants, read from the manifest (must match
/// python/compile/model.py's TinyLMConfig).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TinyConfig {
    pub vocab: usize,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub head_dim: usize,
}

/// One weight array, host-resident.
#[derive(Debug, Clone)]
pub struct WeightArray {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// The full weight bundle plus bucket lists.
#[derive(Debug)]
pub struct WeightBundle {
    pub config: TinyConfig,
    pub arrays: Vec<WeightArray>,
    pub prefill_buckets: Vec<usize>,
    pub decode_buckets: Vec<usize>,
}

fn usize_field(j: &Json, keys: &[&str]) -> Result<usize> {
    j.path(keys)
        .and_then(|v| v.as_usize())
        .with_context(|| format!("manifest missing {keys:?}"))
}

/// Load manifest.json + weights.bin from `dir`.
pub fn load_weights(dir: &Path) -> Result<WeightBundle> {
    let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
        .context("read manifest.json — run `make artifacts` first")?;
    let manifest = Json::parse(&manifest_text).context("parse manifest.json")?;
    let config = TinyConfig {
        vocab: usize_field(&manifest, &["config", "vocab"])?,
        layers: usize_field(&manifest, &["config", "layers"])?,
        hidden: usize_field(&manifest, &["config", "hidden"])?,
        heads: usize_field(&manifest, &["config", "heads"])?,
        kv_heads: usize_field(&manifest, &["config", "kv_heads"])?,
        ffn: usize_field(&manifest, &["config", "ffn"])?,
        max_seq: usize_field(&manifest, &["config", "max_seq"])?,
        head_dim: usize_field(&manifest, &["config", "head_dim"])?,
    };
    let buckets = |key: &str| -> Result<Vec<usize>> {
        Ok(manifest
            .get(key)
            .and_then(|v| v.as_arr())
            .with_context(|| format!("manifest missing {key}"))?
            .iter()
            .filter_map(|x| x.as_usize())
            .collect())
    };
    let prefill_buckets = buckets("prefill_buckets")?;
    let decode_buckets = buckets("decode_buckets")?;

    let raw = std::fs::read(dir.join("weights.bin")).context("read weights.bin")?;
    if raw.len() % 4 != 0 {
        bail!("weights.bin length {} not a multiple of 4", raw.len());
    }
    let floats: Vec<f32> = raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    let index = manifest
        .get("weights")
        .and_then(|v| v.as_arr())
        .context("manifest missing weights index")?;
    let mut arrays = Vec::with_capacity(index.len());
    for entry in index {
        let name = entry
            .get("name")
            .and_then(|v| v.as_str())
            .context("weight entry missing name")?
            .to_string();
        let shape: Vec<usize> = entry
            .get("shape")
            .and_then(|v| v.as_arr())
            .context("weight entry missing shape")?
            .iter()
            .filter_map(|x| x.as_usize())
            .collect();
        let offset = entry
            .get("offset")
            .and_then(|v| v.as_usize())
            .context("weight entry missing offset")?;
        let numel: usize = shape.iter().product();
        if offset + numel > floats.len() {
            bail!("weight {name} spans past weights.bin ({offset}+{numel})");
        }
        arrays.push(WeightArray {
            name,
            shape,
            data: floats[offset..offset + numel].to_vec(),
        });
    }
    Ok(WeightBundle { config, arrays, prefill_buckets, decode_buckets })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_bundle() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let b = load_weights(&dir).unwrap();
        assert_eq!(b.config.vocab, 512);
        assert_eq!(b.config.layers, 4);
        assert_eq!(b.arrays.len(), 1 + 7 * b.config.layers + 2);
        assert_eq!(b.arrays[0].name, "embed");
        assert_eq!(b.arrays[0].shape, vec![512, 256]);
        assert!(!b.prefill_buckets.is_empty());
        assert!(!b.decode_buckets.is_empty());
        // Every array's data length matches its shape.
        for a in &b.arrays {
            assert_eq!(a.data.len(), a.shape.iter().product::<usize>(), "{}", a.name);
        }
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = load_weights(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
