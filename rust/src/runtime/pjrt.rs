//! PJRT client/executable wrappers.
//!
//! Interchange is HLO **text**: `HloModuleProto::from_text_file` reparses
//! and reassigns instruction ids, sidestepping the 64-bit-id protos that
//! jax >= 0.5 emits and xla_extension 0.5.1 rejects (see aot.py and
//! /opt/xla-example/README.md).

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT device runtime (CPU in this image; the same wrapper would take
/// `PjRtClient::gpu`/`tpu` on real hardware).
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo_text(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))
    }

    /// Upload an f32 tensor as a device-resident buffer (weights are
    /// uploaded once at engine startup — never on the request path).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("upload f32 buffer")
    }

    /// Upload an i32 tensor.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .context("upload i32 buffer")
    }
}

/// Execute with device buffers and decompose the 1-tuple output into its
/// elements, copied back to host literals.
pub fn execute_tuple(
    exe: &xla::PjRtLoadedExecutable,
    args: &[&xla::PjRtBuffer],
) -> Result<Vec<xla::Literal>> {
    let out = exe.execute_b(args).context("execute")?;
    let mut lit = out[0][0].to_literal_sync().context("fetch output")?;
    // aot.py lowers with return_tuple=True: outputs arrive as one tuple.
    lit.decompose_tuple().context("decompose output tuple")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn cpu_client_comes_up() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu"));
    }

    #[test]
    fn loads_and_runs_prefill_artifact() {
        let dir = artifacts_dir();
        let path = dir.join("tiny_prefill_s16.hlo.txt");
        if !path.exists() {
            eprintln!("artifacts not built; skipping");
            return;
        }
        let rt = PjrtRuntime::cpu().unwrap();
        let exe = rt.load_hlo_text(&path).unwrap();
        // Inputs: tokens [1,16] i32, prompt_len i32, then 31 weights.
        let weights = crate::runtime::weights::load_weights(&dir).unwrap();
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::new();
        let tokens: Vec<i32> = (0..16).map(|i| (i % 7) as i32 + 1).collect();
        bufs.push(rt.upload_i32(&tokens, &[1, 16]).unwrap());
        bufs.push(rt.upload_i32(&[10], &[]).unwrap());
        for w in &weights.arrays {
            bufs.push(rt.upload_f32(&w.data, &w.shape).unwrap());
        }
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let out = execute_tuple(&exe, &refs).unwrap();
        assert_eq!(out.len(), 3); // logits, k_cache, v_cache
        let logits = out[0].to_vec::<f32>().unwrap();
        assert_eq!(logits.len(), weights.config.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
    }
}
