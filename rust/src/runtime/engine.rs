//! Shape-bucketed execution engine: the live-path equivalent of one
//! EcoServe *instance*.
//!
//! At startup the engine compiles every prefill/decode artifact bucket and
//! uploads the weights to device buffers **once**; each request-path call
//! uploads only its small dynamic inputs (tokens, positions, gathered KV)
//! and picks the smallest bucket that fits — the standard shape-bucketed
//! AOT serving pattern.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::kv::{KvConfig, KvStore};
use super::pjrt::{execute_tuple, PjrtRuntime};
use super::weights::{load_weights, TinyConfig};

/// Outcome of one prefill: last-position logits.
pub struct PrefillOut {
    pub logits: Vec<f32>,
}

/// One live inference engine (model replica).
pub struct Engine {
    rt: PjrtRuntime,
    pub config: TinyConfig,
    prefill_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    weight_bufs: Vec<xla::PjRtBuffer>,
    pub kv: KvStore,
    /// Wall-clock spent inside PJRT execute calls (perf accounting).
    pub exec_seconds: f64,
    pub prefill_calls: u64,
    pub decode_calls: u64,
}

impl Engine {
    /// Load artifacts from `dir` and stand the engine up.
    /// `kv_capacity_tokens` bounds the paged KV pool (defaults to
    /// 64 concurrent max-length requests when None).
    pub fn load(dir: &Path, kv_capacity_tokens: Option<usize>) -> Result<Engine> {
        let rt = PjrtRuntime::cpu()?;
        let bundle = load_weights(dir)?;
        let config = bundle.config.clone();

        let mut prefill_exes = BTreeMap::new();
        for &s in &bundle.prefill_buckets {
            let path = dir.join(format!("tiny_prefill_s{s}.hlo.txt"));
            prefill_exes.insert(s, rt.load_hlo_text(&path)?);
        }
        let mut decode_exes = BTreeMap::new();
        for &b in &bundle.decode_buckets {
            let path = dir.join(format!("tiny_decode_b{b}.hlo.txt"));
            decode_exes.insert(b, rt.load_hlo_text(&path)?);
        }
        if prefill_exes.is_empty() || decode_exes.is_empty() {
            bail!("no executables found in {}", dir.display());
        }

        // Weights go to the device once; the request path never re-uploads.
        let mut weight_bufs = Vec::with_capacity(bundle.arrays.len());
        for w in &bundle.arrays {
            weight_bufs.push(rt.upload_f32(&w.data, &w.shape)?);
        }

        let kv_cfg = KvConfig {
            layers: config.layers,
            kv_heads: config.kv_heads,
            head_dim: config.head_dim,
            max_seq: config.max_seq,
            block_tokens: 16,
        };
        let capacity = kv_capacity_tokens.unwrap_or(64 * config.max_seq);
        let kv = KvStore::new(kv_cfg, capacity);
        Ok(Engine {
            rt,
            config,
            prefill_exes,
            decode_exes,
            weight_bufs,
            kv,
            exec_seconds: 0.0,
            prefill_calls: 0,
            decode_calls: 0,
        })
    }

    /// Smallest prefill bucket that fits `len` tokens.
    pub fn prefill_bucket(&self, len: usize) -> Result<usize> {
        self.prefill_exes
            .keys()
            .copied()
            .find(|&b| b >= len)
            .with_context(|| format!("prompt of {len} tokens exceeds largest bucket"))
    }

    /// Smallest decode bucket that fits `batch` rows.
    pub fn decode_bucket(&self, batch: usize) -> Result<usize> {
        self.decode_exes
            .keys()
            .copied()
            .find(|&b| b >= batch)
            .with_context(|| format!("decode batch {batch} exceeds largest bucket"))
    }

    /// Max decode batch the engine supports.
    pub fn max_decode_batch(&self) -> usize {
        *self.decode_exes.keys().last().unwrap()
    }

    /// Run prefill for request `id`; installs its KV and returns logits.
    pub fn prefill(&mut self, id: u64, tokens: &[u32]) -> Result<PrefillOut> {
        let len = tokens.len();
        if len == 0 {
            bail!("empty prompt");
        }
        if !self.kv.has_room(len) {
            bail!("KV pool full (prompt {len} tokens)");
        }
        let bucket = self.prefill_bucket(len)?;
        let exe = &self.prefill_exes[&bucket];
        let mut padded = vec![0i32; bucket];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let tok_buf = self.rt.upload_i32(&padded, &[1, bucket])?;
        let len_buf = self.rt.upload_i32(&[len as i32], &[])?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &len_buf];
        args.extend(self.weight_bufs.iter());
        let t0 = std::time::Instant::now();
        let out = execute_tuple(exe, &args)?;
        self.exec_seconds += t0.elapsed().as_secs_f64();
        self.prefill_calls += 1;
        let logits = out[0].to_vec::<f32>()?;
        let k = out[1].to_vec::<f32>()?;
        let v = out[2].to_vec::<f32>()?;
        self.kv.insert_prefill(id, &k, &v, bucket, len)?;
        Ok(PrefillOut { logits })
    }

    /// One decode step for `ids` (each paired with its current token).
    /// Returns one logits row per request and appends the new KV.
    pub fn decode(&mut self, ids: &[u64], tokens: &[u32]) -> Result<Vec<Vec<f32>>> {
        if ids.is_empty() || ids.len() != tokens.len() {
            bail!("decode batch shape mismatch");
        }
        let batch = ids.len();
        let bucket = self.decode_bucket(batch)?;
        let exe = &self.decode_exes[&bucket];
        let (k_host, v_host, positions) = self.kv.gather_batch(ids, bucket)?;
        let mut toks = vec![0i32; bucket];
        for (i, &t) in tokens.iter().enumerate() {
            toks[i] = t as i32;
        }
        let c = &self.config;
        let kv_dims = [c.layers, bucket, c.kv_heads, c.max_seq, c.head_dim];
        let tok_buf = self.rt.upload_i32(&toks, &[bucket])?;
        let pos_buf = self.rt.upload_i32(&positions, &[bucket])?;
        let k_buf = self.rt.upload_f32(&k_host, &kv_dims)?;
        let v_buf = self.rt.upload_f32(&v_host, &kv_dims)?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&tok_buf, &pos_buf, &k_buf, &v_buf];
        args.extend(self.weight_bufs.iter());
        let t0 = std::time::Instant::now();
        let out = execute_tuple(exe, &args)?;
        self.exec_seconds += t0.elapsed().as_secs_f64();
        self.decode_calls += 1;
        let logits = out[0].to_vec::<f32>()?;
        let new_k = out[1].to_vec::<f32>()?;
        let new_v = out[2].to_vec::<f32>()?;
        let vocab = self.config.vocab;
        let mut rows = Vec::with_capacity(batch);
        for (row, &id) in ids.iter().enumerate() {
            self.kv.append_token(id, &new_k, &new_v, row, bucket)?;
            rows.push(logits[row * vocab..(row + 1) * vocab].to_vec());
        }
        Ok(rows)
    }

    /// Release a finished request's KV.
    pub fn release(&mut self, id: u64) {
        self.kv.release(id);
    }
}

/// Greedy sampler.
pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in logits.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn engine() -> Option<Engine> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts not built; skipping");
            return None;
        }
        Some(Engine::load(&dir, Some(4096)).unwrap())
    }

    #[test]
    fn bucket_selection() {
        let Some(e) = engine() else { return };
        assert_eq!(e.prefill_bucket(10).unwrap(), 16);
        assert_eq!(e.prefill_bucket(16).unwrap(), 16);
        assert_eq!(e.prefill_bucket(17).unwrap(), 32);
        assert!(e.prefill_bucket(4096).is_err());
        assert_eq!(e.decode_bucket(3).unwrap(), 4);
        assert_eq!(e.max_decode_batch(), 32);
    }

    #[test]
    fn prefill_decode_generates_deterministically() {
        let Some(mut e) = engine() else { return };
        let prompt: Vec<u32> = vec![1, 5, 9, 13, 21];
        let p = e.prefill(7, &prompt).unwrap();
        assert_eq!(p.logits.len(), e.config.vocab);
        let t1 = argmax(&p.logits);
        let rows = e.decode(&[7], &[t1]).unwrap();
        assert_eq!(rows.len(), 1);
        let t2 = argmax(&rows[0]);
        e.release(7);

        // Re-run: identical tokens (deterministic AOT graphs).
        let p2 = e.prefill(8, &prompt).unwrap();
        assert_eq!(argmax(&p2.logits), t1);
        let rows2 = e.decode(&[8], &[t1]).unwrap();
        assert_eq!(argmax(&rows2[0]), t2);
        e.release(8);
    }

    #[test]
    fn batched_decode_matches_solo() {
        let Some(mut e) = engine() else { return };
        let pa: Vec<u32> = vec![3, 1, 4, 1, 5];
        let pb: Vec<u32> = vec![2, 7, 1, 8, 2, 8, 1, 8];
        let la = e.prefill(1, &pa).unwrap();
        let lb = e.prefill(2, &pb).unwrap();
        let (ta, tb) = (argmax(&la.logits), argmax(&lb.logits));
        // batched
        let rows = e.decode(&[1, 2], &[ta, tb]).unwrap();
        let batched: Vec<u32> = rows.iter().map(|r| argmax(r)).collect();
        e.release(1);
        e.release(2);
        // solo
        let la2 = e.prefill(11, &pa).unwrap();
        let r1 = e.decode(&[11], &[argmax(&la2.logits)]).unwrap();
        let lb2 = e.prefill(12, &pb).unwrap();
        let r2 = e.decode(&[12], &[argmax(&lb2.logits)]).unwrap();
        assert_eq!(batched, vec![argmax(&r1[0]), argmax(&r2[0])]);
        e.release(11);
        e.release(12);
    }

    #[test]
    fn kv_room_enforced() {
        let Some(mut e) = engine() else { return };
        // capacity 4096 tokens, block 16 -> 256 blocks.
        let prompt: Vec<u32> = (0..100).map(|i| (i % 500) as u32).collect();
        let mut admitted = 0;
        for id in 0..100 {
            match e.prefill(id, &prompt) {
                Ok(_) => admitted += 1,
                Err(_) => break,
            }
        }
        assert!(admitted >= 30 && admitted < 50, "admitted {admitted}");
    }
}
