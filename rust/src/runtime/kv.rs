//! Paged KV-cache store (the PagedAttention-style substrate, S12 in
//! DESIGN.md).
//!
//! GPU-resident KV in real deployments is block-allocated to avoid
//! fragmentation (vLLM); here the store is host-resident f32 (the CPU PJRT
//! path) but keeps the same structure: fixed-size token blocks in a slab,
//! per-request block tables, gather into contiguous `[L, B, Hkv, Smax, D]`
//! batch buffers for the decode executable, scatter of the per-step KV
//! delta back into the right block.
//!
//! Layout within a block: `[layers][2 (k/v)][kv_heads][block_tokens][head_dim]`.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Store geometry (matches the TinyLM manifest on the live path).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KvConfig {
    pub layers: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    /// Tokens per block (vLLM default is 16).
    pub block_tokens: usize,
}

impl KvConfig {
    /// f32 elements one token occupies (K+V, all layers).
    pub fn elems_per_token(&self) -> usize {
        2 * self.layers * self.kv_heads * self.head_dim
    }

    pub fn elems_per_block(&self) -> usize {
        self.elems_per_token() * self.block_tokens
    }

    pub fn blocks_per_request(&self) -> usize {
        self.max_seq.div_ceil(self.block_tokens)
    }
}

#[derive(Debug)]
struct Entry {
    blocks: Vec<usize>,
    len: usize,
}

/// Block-allocated KV store for a set of in-flight requests.
#[derive(Debug)]
pub struct KvStore {
    pub cfg: KvConfig,
    pool: Vec<f32>,
    free: Vec<usize>,
    entries: HashMap<u64, Entry>,
    pub capacity_blocks: usize,
    /// Reusable gather buffers (§Perf L3: zeroing 2x4 MB per decode step
    /// dominated the gather; the decode kernel masks positions >= length,
    /// so stale bytes in the padding are never read into results).
    scratch_k: Vec<f32>,
    scratch_v: Vec<f32>,
}

impl KvStore {
    pub fn new(cfg: KvConfig, capacity_tokens: usize) -> Self {
        let capacity_blocks = capacity_tokens.div_ceil(cfg.block_tokens);
        let pool = vec![0.0; capacity_blocks * cfg.elems_per_block()];
        let free = (0..capacity_blocks).rev().collect();
        KvStore {
            cfg,
            pool,
            free,
            entries: HashMap::new(),
            capacity_blocks,
            scratch_k: Vec::new(),
            scratch_v: Vec::new(),
        }
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_tokens(&self) -> usize {
        self.entries.values().map(|e| e.len).sum()
    }

    pub fn len_of(&self, id: u64) -> Option<usize> {
        self.entries.get(&id).map(|e| e.len)
    }

    pub fn contains(&self, id: u64) -> bool {
        self.entries.contains_key(&id)
    }

    /// Can a new request of `tokens` prompt tokens be allocated right now?
    pub fn has_room(&self, tokens: usize) -> bool {
        self.free.len() >= tokens.div_ceil(self.cfg.block_tokens)
    }

    fn ensure_blocks(&mut self, id: u64, len: usize) -> Result<()> {
        let need = len.div_ceil(self.cfg.block_tokens);
        let entry = self.entries.get_mut(&id).expect("entry exists");
        while entry.blocks.len() < need {
            match self.free.pop() {
                Some(b) => entry.blocks.push(b),
                None => bail!("KV pool exhausted (request {id}, len {len})"),
            }
        }
        Ok(())
    }

    /// Element offset of (layer, k_or_v, head, token) inside the pool for
    /// request `id`'s token index `t`.
    fn offset(&self, blocks: &[usize], l: usize, kv: usize, h: usize, t: usize) -> usize {
        let c = &self.cfg;
        let block = blocks[t / c.block_tokens];
        let t_in = t % c.block_tokens;
        (((block * c.layers + l) * 2 + kv) * c.kv_heads + h) * c.block_tokens * c.head_dim
            + t_in * c.head_dim
    }

    /// Install a freshly prefilled request. `k`/`v` are the prefill
    /// executable's outputs laid out `[L, 1, Hkv, S_bucket, D]`; only the
    /// first `len` positions are valid.
    pub fn insert_prefill(
        &mut self,
        id: u64,
        k: &[f32],
        v: &[f32],
        bucket: usize,
        len: usize,
    ) -> Result<()> {
        let c = self.cfg.clone();
        if self.entries.contains_key(&id) {
            bail!("request {id} already in KV store");
        }
        self.entries.insert(id, Entry { blocks: vec![], len: 0 });
        self.ensure_blocks(id, len)?;
        let blocks = self.entries[&id].blocks.clone();
        for l in 0..c.layers {
            for h in 0..c.kv_heads {
                for t in 0..len {
                    let src = ((l * c.kv_heads + h) * bucket + t) * c.head_dim;
                    let dk = self.offset(&blocks, l, 0, h, t);
                    let dv = self.offset(&blocks, l, 1, h, t);
                    self.pool[dk..dk + c.head_dim]
                        .copy_from_slice(&k[src..src + c.head_dim]);
                    self.pool[dv..dv + c.head_dim]
                        .copy_from_slice(&v[src..src + c.head_dim]);
                }
            }
        }
        self.entries.get_mut(&id).unwrap().len = len;
        Ok(())
    }

    /// Append one decode step's KV rows. `new_k`/`new_v` are the decode
    /// executable's outputs `[L, B, Hkv, D]`; `row` selects this request's
    /// batch row; the token lands at the current length.
    pub fn append_token(&mut self, id: u64, new_k: &[f32], new_v: &[f32],
                        row: usize, batch: usize) -> Result<()> {
        let c = self.cfg.clone();
        let len = match self.entries.get(&id) {
            Some(e) => e.len,
            None => bail!("append to unknown request {id}"),
        };
        if len >= c.max_seq {
            bail!("request {id} exceeded max_seq {}", c.max_seq);
        }
        self.ensure_blocks(id, len + 1)?;
        let blocks = self.entries[&id].blocks.clone();
        for l in 0..c.layers {
            for h in 0..c.kv_heads {
                let src = ((l * batch + row) * c.kv_heads + h) * c.head_dim;
                let dk = self.offset(&blocks, l, 0, h, len);
                let dv = self.offset(&blocks, l, 1, h, len);
                self.pool[dk..dk + c.head_dim]
                    .copy_from_slice(&new_k[src..src + c.head_dim]);
                self.pool[dv..dv + c.head_dim]
                    .copy_from_slice(&new_v[src..src + c.head_dim]);
            }
        }
        self.entries.get_mut(&id).unwrap().len = len + 1;
        Ok(())
    }

    /// Gather a decode batch's caches into contiguous buffers shaped
    /// `[L, bucket, Hkv, max_seq, D]`, plus the per-row positions (current
    /// lengths). Padding (rows beyond `ids.len()` and positions beyond a
    /// request's length) carries stale bytes: the decode kernel masks by
    /// `lengths`, so they are never observable (asserted by the python
    /// test `test_decode_padding_is_ignored`).
    pub fn gather_batch(&mut self, ids: &[u64], bucket: usize)
                        -> Result<(&[f32], &[f32], Vec<i32>)> {
        let c = self.cfg.clone();
        assert!(ids.len() <= bucket);
        let row_elems = c.kv_heads * c.max_seq * c.head_dim;
        let total = c.layers * bucket * row_elems;
        if self.scratch_k.len() < total {
            self.scratch_k.resize(total, 0.0);
            self.scratch_v.resize(total, 0.0);
        }
        let mut positions = vec![0i32; bucket];
        for (row, &id) in ids.iter().enumerate() {
            let entry = match self.entries.get(&id) {
                Some(e) => e,
                None => bail!("gather of unknown request {id}"),
            };
            positions[row] = entry.len as i32;
            // Hot path (§Perf L3): tokens are contiguous within a block
            // for fixed (layer, k/v, head), so copy whole block-token runs
            // instead of per-token head_dim slivers (~block_tokens x fewer
            // memcpy calls; see EXPERIMENTS.md §Perf for before/after).
            for l in 0..c.layers {
                for h in 0..c.kv_heads {
                    let dst_base = ((l * bucket + row) * c.kv_heads + h)
                        * c.max_seq
                        * c.head_dim;
                    let mut t = 0;
                    while t < entry.len {
                        let run = (c.block_tokens - t % c.block_tokens)
                            .min(entry.len - t);
                        let n = run * c.head_dim;
                        let sk = self.offset(&entry.blocks, l, 0, h, t);
                        let sv = self.offset(&entry.blocks, l, 1, h, t);
                        let dst = dst_base + t * c.head_dim;
                        self.scratch_k[dst..dst + n]
                            .copy_from_slice(&self.pool[sk..sk + n]);
                        self.scratch_v[dst..dst + n]
                            .copy_from_slice(&self.pool[sv..sv + n]);
                        t += run;
                    }
                }
            }
        }
        Ok((&self.scratch_k[..total], &self.scratch_v[..total], positions))
    }

    /// Release a request's blocks.
    pub fn release(&mut self, id: u64) {
        if let Some(e) = self.entries.remove(&id) {
            self.free.extend(e.blocks);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> KvConfig {
        KvConfig { layers: 2, kv_heads: 2, head_dim: 4, max_seq: 32, block_tokens: 8 }
    }

    fn fill_pattern(l: usize, h: usize, t: usize, d: usize, tag: f32) -> f32 {
        tag + (l * 1000 + h * 100 + t * 10 + d) as f32
    }

    /// Build fake prefill output [L,1,Hkv,bucket,D].
    fn prefill_kv(c: &KvConfig, bucket: usize, len: usize, tag: f32) -> Vec<f32> {
        let mut out = vec![0.0; c.layers * c.kv_heads * bucket * c.head_dim];
        for l in 0..c.layers {
            for h in 0..c.kv_heads {
                for t in 0..len {
                    for d in 0..c.head_dim {
                        out[((l * c.kv_heads + h) * bucket + t) * c.head_dim + d] =
                            fill_pattern(l, h, t, d, tag);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn prefill_then_gather_roundtrip() {
        let c = cfg();
        let mut store = KvStore::new(c.clone(), 256);
        let k = prefill_kv(&c, 16, 10, 1.0);
        let v = prefill_kv(&c, 16, 10, 2.0);
        store.insert_prefill(7, &k, &v, 16, 10).unwrap();
        let (gk, gv, pos) = store.gather_batch(&[7], 2).unwrap();
        assert_eq!(pos, vec![10, 0]);
        // spot check: layer 1, head 0, token 9, dim 3
        let (l, h, t, d) = (1, 0, 9, 3);
        let bucket = 2;
        let idx = ((l * bucket + 0) * c.kv_heads + h) * c.max_seq * c.head_dim
            + t * c.head_dim + d;
        assert_eq!(gk[idx], fill_pattern(l, h, t, d, 1.0));
        assert_eq!(gv[idx], fill_pattern(l, h, t, d, 2.0));
        // padded row stays zero
        let pad = ((0 * bucket + 1) * c.kv_heads) * c.max_seq * c.head_dim;
        assert_eq!(gk[pad], 0.0);
    }

    #[test]
    fn append_token_lands_at_length() {
        let c = cfg();
        let mut store = KvStore::new(c.clone(), 256);
        let k = prefill_kv(&c, 16, 5, 1.0);
        store.insert_prefill(1, &k, &k, 16, 5).unwrap();
        // decode delta [L,B,Hkv,D], batch 1, row 0
        let mut nk = vec![0.0; c.layers * c.kv_heads * c.head_dim];
        for (i, x) in nk.iter_mut().enumerate() {
            *x = 500.0 + i as f32;
        }
        store.append_token(1, &nk, &nk, 0, 1).unwrap();
        assert_eq!(store.len_of(1), Some(6));
        let (gk, _, pos) = store.gather_batch(&[1], 1).unwrap();
        assert_eq!(pos, vec![6]);
        // token 5, layer 0, head 1, dim 2 => source index (0*1+0)*2+1)*4+2
        let src = ((0 * c.kv_heads) + 1) * c.head_dim + 2;
        let dst = ((0 + 0) * c.kv_heads + 1) * c.max_seq * c.head_dim + 5 * c.head_dim + 2;
        assert_eq!(gk[dst], nk[src]);
    }

    #[test]
    fn blocks_allocated_lazily_and_released() {
        let c = cfg(); // 8 tokens per block
        let mut store = KvStore::new(c.clone(), 64); // 8 blocks
        assert_eq!(store.free_blocks(), 8);
        let k = prefill_kv(&c, 16, 9, 0.0);
        store.insert_prefill(1, &k, &k, 16, 9).unwrap(); // 9 tokens -> 2 blocks
        assert_eq!(store.free_blocks(), 6);
        store.release(1);
        assert_eq!(store.free_blocks(), 8);
        assert!(!store.contains(1));
    }

    #[test]
    fn exhaustion_errors() {
        let c = cfg();
        let mut store = KvStore::new(c.clone(), 16); // 2 blocks
        assert!(store.has_room(16));
        assert!(!store.has_room(17));
        let k = prefill_kv(&c, 16, 16, 0.0);
        store.insert_prefill(1, &k, &k, 16, 16).unwrap();
        let k2 = prefill_kv(&c, 16, 1, 0.0);
        assert!(store.insert_prefill(2, &k2, &k2, 16, 1).is_err());
        store.release(2); // cleanup of failed entry is safe
    }

    #[test]
    fn max_seq_guard() {
        let c = cfg();
        let mut store = KvStore::new(c.clone(), 1024);
        let k = prefill_kv(&c, 32, 32, 0.0);
        store.insert_prefill(1, &k, &k, 32, 32).unwrap();
        let nk = vec![0.0; c.layers * c.kv_heads * c.head_dim];
        assert!(store.append_token(1, &nk, &nk, 0, 1).is_err());
    }

    #[test]
    fn duplicate_insert_rejected() {
        let c = cfg();
        let mut store = KvStore::new(c.clone(), 256);
        let k = prefill_kv(&c, 16, 4, 0.0);
        store.insert_prefill(1, &k, &k, 16, 4).unwrap();
        assert!(store.insert_prefill(1, &k, &k, 16, 4).is_err());
    }
}
