//! The PJRT execution runtime — the live serving path.
//!
//! Loads the HLO-text artifacts produced by `make artifacts`
//! (python/compile/aot.py), compiles them on the PJRT CPU client through
//! the `xla` crate, and executes prefill/decode steps from the Rust hot
//! loop. Python never runs here.
//!
//! * [`pjrt`] — client + executable wrappers (HLO text → compiled exe).
//! * [`weights`] — `weights.bin`/`manifest.json` loading.
//! * [`kv`] — the paged KV-cache store (PagedAttention-style block
//!   allocator; gathers per-request blocks into batch buffers).
//! * [`engine`] — shape-bucketed prefill/decode execution over the store.
//! * [`tokenizer`] — byte-level tokenizer matching TinyLM's vocab.

//! `kv`, `weights`, and `tokenizer` are pure host-side code and always
//! compile; `engine` and `pjrt` call into the `xla` crate and sit behind
//! the `pjrt` feature.

#[cfg(feature = "pjrt")]
pub mod engine;
pub mod kv;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod tokenizer;
pub mod weights;

#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use kv::KvStore;
pub use tokenizer::Tokenizer;
