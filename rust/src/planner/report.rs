//! Renderings of a finished plan: the human table and the
//! schema-versioned `BENCH_plan.json` CI uploads next to the other BENCH
//! artifacts. The JSON shares [`crate::scenarios::SCHEMA_VERSION`] with
//! the scenario and frontier reports; keep changes additive.

use std::time::Duration;

use crate::scenarios::{replay_to_json, SCHEMA_VERSION};
use crate::util::json::Json;

use super::search::{PlanCell, PlanConfig, PlanOutcome};

fn cell_to_json(cell: &PlanCell) -> Json {
    let cand = &cell.candidate;
    let d = &cand.deployment;
    let mut fields = vec![
        ("system", Json::str(cand.system.label())),
        ("gpu", Json::str(d.cluster.gpu.name)),
        ("cluster", Json::str(d.cluster.name)),
        ("intra_link", Json::str(d.cluster.intra_link.name)),
        ("inter_link", Json::str(d.cluster.inter_link.name)),
        ("tp", Json::num(d.tp as f64)),
        ("pp", Json::num(d.pp as f64)),
        ("instances", Json::num(d.num_instances() as f64)),
        ("gpus", Json::num(d.gpus_used as f64)),
        ("nodes", Json::num(d.nodes_used() as f64)),
        ("price_per_hour", Json::num(cand.price.total)),
        ("price_tier", Json::str(cand.tier.label())),
        (
            "price",
            Json::obj(vec![
                ("gpu", Json::num(cand.price.gpu)),
                ("interconnect", Json::num(cand.price.interconnect)),
                ("nodes", Json::num(cand.price.nodes)),
            ]),
        ),
        ("roofline_ub_rps", Json::num(cand.roofline_ub)),
        ("pruned", Json::Bool(cell.pruned())),
        ("pruned_by", Json::opt_num(cell.pruned_by.map(|i| i as f64))),
    ];
    if !cell.pruned() {
        fields.extend([
            ("max_rate_rps", Json::num(cell.max_rate)),
            ("goodput_rps", Json::num(cell.goodput_rps)),
            ("goodput_per_dollar", Json::num(cell.value())),
            ("attainment_at_max", Json::num(cell.attainment)),
            ("saturated", Json::Bool(cell.saturated)),
            ("budget_truncated", Json::Bool(cell.truncated)),
            ("probes", Json::num(cell.probes as f64)),
            ("sim_events", Json::num(cell.events as f64)),
            ("wall_s", Json::num(cell.wall.as_secs_f64())),
        ]);
    }
    Json::obj(fields)
}

/// The full `BENCH_plan.json` document.
pub fn plan_to_json(outcome: &PlanOutcome, cfg: &PlanConfig, wall: Duration) -> Json {
    let idx = |i: Option<usize>| Json::opt_num(i.map(|v| v as f64));
    let mut scenario_fields = vec![
        ("name", Json::str(outcome.scenario.name)),
        ("summary", Json::str(outcome.scenario.summary)),
    ];
    if let Some(block) = replay_to_json(&outcome.scenario) {
        scenario_fields.push(block);
    }
    Json::obj(vec![
        ("bench", Json::str("ecoserve-plan")),
        ("schema_version", Json::num(SCHEMA_VERSION)),
        ("level", Json::str(outcome.level.label())),
        ("quick", Json::Bool(cfg.quick)),
        ("seed", Json::num(cfg.seed as f64)),
        ("model", Json::str(cfg.model.name)),
        ("scenario", Json::obj(scenario_fields)),
        ("target_rate_rps", Json::opt_num(outcome.target_rate)),
        ("budget_s", Json::opt_num(cfg.budget_s)),
        ("candidates", Json::arr(outcome.cells.iter().map(cell_to_json))),
        (
            "pareto",
            Json::arr(outcome.pareto.iter().map(|&i| Json::num(i as f64))),
        ),
        ("best_value", idx(outcome.best_value)),
        ("cheapest_meeting_target", idx(outcome.cheapest_meeting_target)),
        ("wall_s", Json::num(wall.as_secs_f64())),
    ])
}

/// Human-readable plan table, cheapest row first.
pub fn render_plan_table(outcome: &PlanOutcome) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "--- capacity plan: '{}' @ {} per-class attainment{} ---\n",
        outcome.scenario.name,
        outcome.level.label(),
        match outcome.target_rate {
            Some(t) => format!(" (target {t:.2} req/s)"),
            None => String::new(),
        },
    ));
    out.push_str(&format!(
        "{:<10} {:<6} {:>10} {:<22} {:>8} {:>8} {:>10} {:>9}  {}\n",
        "system", "gpu", "shape", "links", "$/hr", "ub r/s", "goodput/s", "good/$", "note"
    ));
    let pareto: std::collections::BTreeSet<usize> = outcome.pareto.iter().copied().collect();
    for (i, cell) in outcome.cells.iter().enumerate() {
        let cand = &cell.candidate;
        let d = &cand.deployment;
        let mut note = String::new();
        if pareto.contains(&i) {
            note.push_str("pareto ");
        }
        if outcome.best_value == Some(i) {
            note.push_str("best-$ ");
        }
        if outcome.cheapest_meeting_target == Some(i) {
            note.push_str("target ");
        }
        if cell.candidate.tier == crate::planner::PriceTier::Spot {
            note.push_str("spot ");
        }
        if cell.saturated {
            note.push('+');
        }
        if cell.truncated {
            note.push('~');
        }
        let (goodput, value) = if cell.pruned() {
            ("--".to_string(), format!("pruned<-{}", cell.pruned_by.unwrap()))
        } else {
            (format!("{:.2}", cell.goodput_rps), format!("{:.4}", cell.value()))
        };
        out.push_str(&format!(
            "{:<10} {:<6} {:>10} {:<22} {:>8.2} {:>8.1} {:>10} {:>9}  {}\n",
            cand.system.label(),
            d.cluster.gpu.name,
            cand.shape(),
            format!("{}/{}", d.cluster.intra_link.name, d.cluster.inter_link.name),
            cand.price.total,
            cand.roofline_ub,
            goodput,
            value,
            note.trim_end(),
        ));
    }
    if let Some(i) = outcome.best_value {
        let c = &outcome.cells[i];
        out.push_str(&format!(
            "  best goodput/$: {} {} on {} — {:.2} req/s at ${:.2}/hr ({:.4} (req/s)/($/hr))\n",
            c.candidate.system.label(),
            c.candidate.shape(),
            c.candidate.deployment.cluster.name,
            c.goodput_rps,
            c.candidate.price.total,
            c.value(),
        ));
    }
    match (outcome.target_rate, outcome.cheapest_meeting_target) {
        (Some(t), Some(i)) => {
            let c = &outcome.cells[i];
            out.push_str(&format!(
                "  cheapest >= {t:.2} req/s: {} {} on {} at ${:.2}/hr (sustains {:.2})\n",
                c.candidate.system.label(),
                c.candidate.shape(),
                c.candidate.deployment.cluster.name,
                c.candidate.price.total,
                c.max_rate,
            ));
        }
        (Some(t), None) => {
            out.push_str(&format!(
                "  no measured config sustains {t:.2} req/s — raise --gpus or relax --level\n"
            ));
        }
        (None, _) => {}
    }
    let pruned = outcome.cells.iter().filter(|c| c.pruned()).count();
    if pruned > 0 {
        out.push_str(&format!(
            "  ({pruned} candidate(s) pruned by price x roofline dominance, never simulated)\n"
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, Deployment, SystemKind};
    use crate::metrics::Attainment;
    use crate::perfmodel::ModelSpec;
    use crate::planner::candidates::Candidate;
    use crate::planner::cost::CostModel;
    use crate::planner::search::pareto_indices;
    use crate::scenarios::by_name;

    /// Synthetic plan — report tests must not pay for simulation.
    fn synthetic() -> (PlanOutcome, PlanConfig) {
        let scenario = by_name("bursty").unwrap();
        let cost = CostModel::default();
        let cand = |system: SystemKind, gpus: usize| {
            let mut d = Deployment::paper_default(
                ModelSpec::llama_30b(),
                ClusterSpec::l20_cluster(),
            );
            d.gpus_used = gpus;
            Candidate::new(system, d, &cost, &scenario)
        };
        let measured = |c: Candidate, goodput: f64| PlanCell {
            candidate: c,
            pruned_by: None,
            max_rate: goodput / 0.9,
            goodput_rps: goodput,
            attainment: 0.91,
            saturated: false,
            truncated: false,
            probes: 7,
            events: 120_000,
            wall: Duration::from_millis(900),
        };
        let cells = vec![
            measured(cand(SystemKind::Vllm, 8), 1.2),
            measured(cand(SystemKind::EcoServe, 8), 2.0),
            PlanCell::skipped(cand(SystemKind::DistServe, 16), 1),
            measured(cand(SystemKind::EcoServe, 32), 6.5),
        ];
        let pareto = pareto_indices(&cells);
        let mut cfg = PlanConfig::quick(scenario.clone(), ModelSpec::llama_30b());
        cfg.target_rate = Some(2.0);
        let outcome = PlanOutcome {
            scenario,
            level: Attainment::P90,
            target_rate: cfg.target_rate,
            cells,
            pareto,
            best_value: Some(1),
            cheapest_meeting_target: Some(1),
            wall: Duration::from_secs(30),
        };
        (outcome, cfg)
    }

    #[test]
    fn bench_plan_json_honors_the_contract() {
        let (outcome, cfg) = synthetic();
        let text = plan_to_json(&outcome, &cfg, Duration::from_secs(31)).to_string();
        let back = Json::parse(&text).expect("BENCH_plan must be valid JSON");
        assert_eq!(back.get("bench").unwrap().as_str(), Some("ecoserve-plan"));
        assert_eq!(
            back.get("schema_version").unwrap().as_f64(),
            Some(SCHEMA_VERSION)
        );
        assert_eq!(back.get("level").unwrap().as_str(), Some("P90"));
        assert_eq!(back.get("quick").unwrap().as_bool(), Some(true));
        assert_eq!(back.get("model").unwrap().as_str(), Some("Llama-30B"));
        assert_eq!(
            back.path(&["scenario", "name"]).unwrap().as_str(),
            Some("bursty")
        );
        assert_eq!(back.get("target_rate_rps").unwrap().as_f64(), Some(2.0));
        assert_eq!(back.get("budget_s"), Some(&Json::Null));
        assert_eq!(back.get("best_value").unwrap().as_i64(), Some(1));
        assert_eq!(
            back.get("cheapest_meeting_target").unwrap().as_i64(),
            Some(1)
        );

        let cands = back.get("candidates").unwrap().as_arr().unwrap();
        assert_eq!(cands.len(), 4);
        for c in cands {
            for key in [
                "system", "gpu", "cluster", "intra_link", "inter_link", "tp", "pp",
                "instances", "gpus", "nodes", "price_per_hour", "price_tier", "price",
                "roofline_ub_rps", "pruned", "pruned_by",
            ] {
                assert!(c.get(key).is_some(), "missing {key}");
            }
            assert_eq!(c.get("price_tier").unwrap().as_str(), Some("on-demand"));
            let b = c.get("price").unwrap();
            let total = c.get("price_per_hour").unwrap().as_f64().unwrap();
            let sum = b.get("gpu").unwrap().as_f64().unwrap()
                + b.get("interconnect").unwrap().as_f64().unwrap()
                + b.get("nodes").unwrap().as_f64().unwrap();
            assert!((sum - total).abs() < 1e-9, "breakdown must sum to total");
        }
        // Measured cells carry the measurement block; pruned cells don't.
        let measured = &cands[1];
        for key in [
            "max_rate_rps", "goodput_rps", "goodput_per_dollar", "attainment_at_max",
            "saturated", "budget_truncated", "probes", "sim_events", "wall_s",
        ] {
            assert!(measured.get(key).is_some(), "missing {key}");
        }
        let pruned = &cands[2];
        assert_eq!(pruned.get("pruned").unwrap().as_bool(), Some(true));
        assert_eq!(pruned.get("pruned_by").unwrap().as_i64(), Some(1));
        assert!(pruned.get("goodput_rps").is_none());

        // The Pareto set indexes measured cells in ascending price with
        // strictly rising goodput.
        let front: Vec<usize> = back
            .get("pareto")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_usize().unwrap())
            .collect();
        assert_eq!(front, vec![1, 3], "vLLM at equal price is dominated");
    }

    #[test]
    fn plan_table_flags_winners_and_pruned_rows() {
        let (outcome, _) = synthetic();
        let table = render_plan_table(&outcome);
        assert!(table.contains("EcoServe"));
        assert!(table.contains("vLLM"));
        assert!(table.contains("pruned<-1"));
        assert!(table.contains("best-$"));
        assert!(table.contains("pareto"));
        assert!(table.contains("target"));
        assert!(table.contains("best goodput/$"));
        assert!(table.contains("cheapest >= 2.00 req/s"));
        assert!(table.contains("1 candidate(s) pruned"));
    }

    #[test]
    fn unmet_target_is_called_out() {
        let (mut outcome, _) = synthetic();
        outcome.target_rate = Some(50.0);
        outcome.cheapest_meeting_target = None;
        let table = render_plan_table(&outcome);
        assert!(table.contains("no measured config sustains 50.00"));
    }
}
