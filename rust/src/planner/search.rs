//! The plan driver: price-ordered, dominance-pruned goodput search over
//! the candidate space, reusing the frontier cell machinery probe for
//! probe.
//!
//! Candidates run cheapest-first in fixed-width waves. Before a wave is
//! simulated, each candidate is tested against everything already
//! measured: if some no-more-expensive cell's measured goodput already
//! reaches the candidate's roofline ceiling, the candidate is pruned
//! without simulation. The rule is sound for every answer the plan
//! reports — Pareto membership, cheapest-meeting-target, and best
//! goodput-per-dollar — because the roofline is a ceiling on anything
//! the simulator can measure: a pruned config, simulated anyway, can
//! never beat the cell that dominated it (locked by
//! rust/tests/planner.rs). The wave width is a constant so the pruning
//! decisions — and therefore `BENCH_plan.json` — do not depend on the
//! host's core count.

use std::time::{Duration, Instant};

use crate::config::{ClusterSpec, SystemKind};
use crate::frontier::{run_cell, FrontierConfig};
use crate::metrics::Attainment;
use crate::perfmodel::ModelSpec;
use crate::scenarios::{Scenario, ScenarioConfig, SweepBounds};
use crate::util::threads::parallel_map;

use super::candidates::{enumerate_candidates, Candidate};
use super::cost::PriceTier;

/// Candidates simulated concurrently per wave. Fixed (not core-count
/// derived) so pruning sees an identical measured set on every machine.
const WAVE: usize = 4;

/// What `ecoserve plan` was asked to do.
#[derive(Debug, Clone)]
pub struct PlanConfig {
    /// Workload the plan is for (synthetic scenario or replayed log).
    pub scenario: Scenario,
    pub model: ModelSpec,
    /// Base clusters whose link tiers and shapes are enumerated.
    pub clusters: Vec<ClusterSpec>,
    pub systems: Vec<SystemKind>,
    pub level: Attainment,
    pub seed: u64,
    /// Coarse searches, short horizons, native link tier only.
    pub quick: bool,
    /// Cap on GPUs per candidate (None = each cluster's total).
    pub max_gpus: Option<usize>,
    /// Report the cheapest config sustaining at least this rate.
    pub target_rate: Option<f64>,
    /// Per-candidate wall-clock search budget, seconds (`--budget-s`).
    pub budget_s: Option<f64>,
    /// Probe horizon override, seconds (tests / quick CLI runs).
    pub duration_override: Option<f64>,
    /// Fault-schedule seed: churn scenarios plan under their fault
    /// timeline when set (fault-free otherwise).
    pub fault_seed: Option<u64>,
    /// Also enumerate a spot-priced twin of every candidate: GPU bill
    /// discounted, goodput measured under the spot reclaim churn.
    pub spot: bool,
}

impl PlanConfig {
    /// A plan over the L20 cluster with the full system roster.
    pub fn new(scenario: Scenario, model: ModelSpec) -> Self {
        PlanConfig {
            scenario,
            model,
            clusters: vec![ClusterSpec::l20_cluster()],
            systems: SystemKind::all().to_vec(),
            level: Attainment::P90,
            seed: 42,
            quick: false,
            max_gpus: None,
            target_rate: None,
            budget_s: None,
            duration_override: None,
            fault_seed: None,
            spot: false,
        }
    }

    /// The quick (CI smoke) profile: PaDG vs. one NoDG and one FuDG
    /// representative over a trimmed shape grid.
    pub fn quick(scenario: Scenario, model: ModelSpec) -> Self {
        let mut cfg = Self::new(scenario, model);
        cfg.quick = true;
        cfg.systems = vec![SystemKind::EcoServe, SystemKind::Vllm, SystemKind::DistServe];
        cfg
    }

    pub fn tp_options(&self) -> Vec<usize> {
        if self.quick { vec![2, 4] } else { vec![1, 2, 4, 8] }
    }

    pub fn pp_options(&self) -> Vec<usize> {
        if self.quick { vec![1] } else { vec![1, 2] }
    }

    pub fn instance_options(&self) -> Vec<usize> {
        if self.quick { vec![2, 4, 8] } else { vec![1, 2, 4, 8, 16] }
    }
}

/// One candidate's planned outcome. Pruned cells carry the dominator's
/// index instead of measurements.
#[derive(Debug, Clone)]
pub struct PlanCell {
    pub candidate: Candidate,
    /// Index (into the plan's price-ordered cells) of the measured cell
    /// that dominated this one; `None` when this cell was simulated.
    pub pruned_by: Option<usize>,
    /// Max offered rate sustaining the target attainment (0 when pruned
    /// or nothing sustained).
    pub max_rate: f64,
    /// Delivered SLO-meeting completions/s at `max_rate`.
    pub goodput_rps: f64,
    /// Min per-class attainment at `max_rate`.
    pub attainment: f64,
    pub saturated: bool,
    /// Per-cell `--budget-s` cut the search short.
    pub truncated: bool,
    pub probes: usize,
    pub events: u64,
    pub wall: Duration,
}

impl PlanCell {
    pub fn pruned(&self) -> bool {
        self.pruned_by.is_some()
    }

    /// The plan's objective: goodput per hardware dollar, (req/s)/($/hr).
    pub fn value(&self) -> f64 {
        self.goodput_rps / self.candidate.price.total.max(1e-9)
    }

    pub(crate) fn skipped(candidate: Candidate, dominator: usize) -> Self {
        PlanCell {
            candidate,
            pruned_by: Some(dominator),
            max_rate: 0.0,
            goodput_rps: 0.0,
            attainment: 0.0,
            saturated: false,
            truncated: false,
            probes: 0,
            events: 0,
            wall: Duration::ZERO,
        }
    }
}

/// The finished plan: price-ordered cells plus the three answers a
/// capacity question needs — the Pareto frontier of $/hr vs. goodput,
/// the best goodput-per-dollar config, and the cheapest config meeting
/// the target rate (when one was asked for).
#[derive(Debug)]
pub struct PlanOutcome {
    pub scenario: Scenario,
    pub level: Attainment,
    pub target_rate: Option<f64>,
    /// Cells sorted by ascending price (deterministic tie-break).
    pub cells: Vec<PlanCell>,
    /// Indices of the measured cells on the (price, goodput) Pareto
    /// frontier, ascending price and strictly ascending goodput.
    pub pareto: Vec<usize>,
    /// Index of the measured cell with the best goodput-per-dollar.
    pub best_value: Option<usize>,
    /// Index of the cheapest measured cell with `max_rate >= target_rate`.
    pub cheapest_meeting_target: Option<usize>,
    pub wall: Duration,
}

impl PlanOutcome {
    pub fn cell(&self, i: usize) -> &PlanCell {
        &self.cells[i]
    }
}

/// The sound dominance test: can `c` be skipped given the measured cells
/// so far? Returns the first dominator's index. `b` dominates `c` when
/// it costs no more and its *measured* goodput already reaches `c`'s
/// roofline ceiling: anything `c` could sustain, the cheaper `b`
/// provably sustains too, so `c` can join neither the Pareto frontier
/// nor improve the cheapest-meeting-target or best-value answers. (A
/// weaker "b's goodput-per-dollar beats c's ceiling value" rule would
/// protect only the best-value answer while silently dropping Pareto /
/// target candidates — deliberately not used.)
pub fn dominated_by(cells: &[PlanCell], c: &Candidate) -> Option<usize> {
    const EPS: f64 = 1e-9;
    cells.iter().position(|b| {
        !b.pruned()
            && b.goodput_rps > 0.0
            && b.candidate.price.total <= c.price.total + EPS
            && b.goodput_rps >= c.roofline_ub - EPS
    })
}

/// The (price, goodput) Pareto frontier over the measured cells: walk
/// prices upward and keep every cell that strictly raises the best
/// goodput seen. Equal-price groups contribute at most their best row.
pub fn pareto_indices(cells: &[PlanCell]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..cells.len())
        .filter(|&i| !cells[i].pruned() && cells[i].goodput_rps > 0.0)
        .collect();
    order.sort_by(|&a, &b| {
        let (ca, cb) = (&cells[a], &cells[b]);
        ca.candidate
            .price
            .total
            .partial_cmp(&cb.candidate.price.total)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(
                cb.goodput_rps
                    .partial_cmp(&ca.goodput_rps)
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
            .then(a.cmp(&b))
    });
    let mut out = Vec::new();
    let mut best = 0.0;
    for i in order {
        if cells[i].goodput_rps > best + 1e-12 {
            out.push(i);
            best = cells[i].goodput_rps;
        }
    }
    out
}

/// Probe one candidate: a frontier cell search on the plan's scenario,
/// with the sweep re-bracketed around the candidate's roofline ceiling
/// (the registry bounds are tuned for the default 8-instance layout; a
/// 2-instance candidate would waste its bracket far above its ceiling).
fn measure(cfg: &PlanConfig, cand: &Candidate) -> PlanCell {
    let mut scenario = cfg.scenario.clone();
    let mut sweep = SweepBounds::around((cand.roofline_ub * 0.5).max(0.2));
    // The ceiling-derived bracket must not raise the crumb with it: a
    // config whose SLO-attaining rate sits far below its hardware
    // roofline (tight-TTFT bursty traffic does this) still deserves a
    // low last-resort probe instead of a spurious max_rate of 0.
    sweep.floor = 0.05;
    scenario.sweep = sweep;
    // Spot candidates are probed under the spot reclaim churn: the tier
    // maps to a ChurnProfile layered over the scenario's own, expanded
    // through the same fault-seed plumbing churn scenarios already use.
    // The plan's seed stands in when no --fault-seed was given, so spot
    // twins are never accidentally measured fault-free.
    let mut fault_seed = cfg.fault_seed;
    if cand.tier == PriceTier::Spot {
        scenario.churn = cand.tier.churn_profile(scenario.churn.as_ref());
        fault_seed = Some(fault_seed.unwrap_or(cfg.seed));
    }
    let base = ScenarioConfig {
        deployment: cand.deployment.clone(),
        seed: cfg.seed,
        rate: None, // the search owns the rate
        duration_override: cfg.duration_override,
        fault_seed,
        trace: false,
    };
    let mut fc = FrontierConfig::new(base, cfg.level);
    fc.quick = cfg.quick;
    fc.budget_s = cfg.budget_s;
    let cell = run_cell(&scenario, &fc, cand.system, false);
    PlanCell {
        candidate: cand.clone(),
        pruned_by: None,
        max_rate: cell.max_rate,
        goodput_rps: cell.goodput_rps,
        attainment: cell.attainment,
        saturated: cell.saturated,
        truncated: cell.truncated,
        probes: cell.probes,
        events: cell.perf.events,
        wall: cell.wall,
    }
}

/// Run the plan over an explicit candidate list (the enumeration is
/// [`enumerate_candidates`]; tests inject handcrafted lists to pin the
/// pruning rules). Candidates are price-sorted, then measured
/// cheapest-first in [`WAVE`]-wide parallel waves with dominance pruning
/// between waves.
pub fn run_plan_on(cfg: &PlanConfig, mut candidates: Vec<Candidate>) -> PlanOutcome {
    let t0 = Instant::now();
    candidates.sort_by(|a, b| {
        a.price
            .total
            .partial_cmp(&b.price.total)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| {
                let key = |c: &Candidate| {
                    (
                        c.system.label(),
                        c.deployment.cluster.name,
                        c.deployment.tp,
                        c.deployment.pp,
                        c.deployment.gpus_used,
                        c.tier.label(),
                    )
                };
                key(a).cmp(&key(b))
            })
    });
    let mut cells: Vec<PlanCell> = Vec::with_capacity(candidates.len());
    let mut queue = candidates.into_iter().peekable();
    while queue.peek().is_some() {
        let wave: Vec<Candidate> = queue.by_ref().take(WAVE).collect();
        // Pruning consults only cells measured in *earlier* waves, so the
        // decision set is deterministic regardless of intra-wave timing.
        let decisions: Vec<Option<usize>> = wave.iter().map(|c| dominated_by(&cells, c)).collect();
        let jobs: Vec<(usize, Candidate)> = wave
            .iter()
            .zip(&decisions)
            .enumerate()
            .filter(|(_, (_, d))| d.is_none())
            .map(|(k, (c, _))| (k, c.clone()))
            .collect();
        let measured = parallel_map(jobs, WAVE, |(k, cand)| (k, measure(cfg, &cand)));
        let mut slots: Vec<Option<PlanCell>> = vec![None; wave.len()];
        for (k, cell) in measured {
            slots[k] = Some(cell);
        }
        for (k, cand) in wave.into_iter().enumerate() {
            cells.push(match slots[k].take() {
                Some(cell) => cell,
                None => PlanCell::skipped(cand, decisions[k].expect("pruned")),
            });
        }
    }

    let pareto = pareto_indices(&cells);
    let best_value = cells
        .iter()
        .enumerate()
        .filter(|(_, c)| !c.pruned() && c.goodput_rps > 0.0)
        .max_by(|(ia, a), (ib, b)| {
            a.value()
                .partial_cmp(&b.value())
                .unwrap_or(std::cmp::Ordering::Equal)
                // Ties: prefer the cheaper, then the earlier (stable) cell.
                .then_with(|| {
                    b.candidate
                        .price
                        .total
                        .partial_cmp(&a.candidate.price.total)
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .then(ib.cmp(ia))
        })
        .map(|(i, _)| i);
    let cheapest_meeting_target = cfg.target_rate.and_then(|target| {
        cells
            .iter()
            .position(|c| !c.pruned() && c.max_rate >= target - 1e-9)
    });
    PlanOutcome {
        scenario: cfg.scenario.clone(),
        level: cfg.level,
        target_rate: cfg.target_rate,
        cells,
        pareto,
        best_value,
        cheapest_meeting_target,
        wall: t0.elapsed(),
    }
}

/// Enumerate and run the full plan for `cfg`.
pub fn run_plan(cfg: &PlanConfig) -> PlanOutcome {
    run_plan_on(cfg, enumerate_candidates(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Deployment;
    use crate::planner::cost::CostModel;
    use crate::scenarios::by_name;

    fn candidate(system: SystemKind, gpus: usize) -> Candidate {
        let mut d = Deployment::paper_default(
            ModelSpec::llama_30b(),
            ClusterSpec::l20_cluster(),
        );
        d.gpus_used = gpus;
        Candidate::new(system, d, &CostModel::default(), &by_name("steady").unwrap())
    }

    fn measured(c: Candidate, goodput: f64) -> PlanCell {
        PlanCell {
            candidate: c,
            pruned_by: None,
            max_rate: goodput / 0.9,
            goodput_rps: goodput,
            attainment: 0.9,
            saturated: false,
            truncated: false,
            probes: 5,
            events: 1000,
            wall: Duration::from_millis(10),
        }
    }

    #[test]
    fn dominance_requires_cheaper_and_ceiling_beaten() {
        let cheap = measured(candidate(SystemKind::EcoServe, 8), 3.0);
        // An honest bigger config: ceiling far above 3 req/s — no prune.
        let big = candidate(SystemKind::EcoServe, 32);
        assert!(big.roofline_ub > 3.0);
        assert!(dominated_by(&[cheap.clone()], &big).is_none());
        // A config whose ceiling the cheap cell already delivers: pruned.
        let mut weak = candidate(SystemKind::EcoServe, 32);
        weak.roofline_ub = 2.5;
        assert_eq!(dominated_by(&[cheap.clone()], &weak), Some(0));
        // Same ceiling but *cheaper* than the measured cell: not pruned.
        let mut cheaper_weak = candidate(SystemKind::EcoServe, 4);
        cheaper_weak.roofline_ub = 2.5;
        assert!(cheaper_weak.price.total < cheap.candidate.price.total);
        assert!(dominated_by(&[cheap.clone()], &cheaper_weak).is_none());
        // An overpriced twin with an honest (high) ceiling is NOT pruned:
        // it might still raise the Pareto frontier or meet a target no
        // cheaper cell meets, so only its measurement can rule it out.
        let mut overpriced = candidate(SystemKind::EcoServe, 8);
        overpriced.price.total *= 100.0;
        assert!(overpriced.roofline_ub > cheap.goodput_rps);
        assert!(dominated_by(&[cheap.clone()], &overpriced).is_none());
        // Pruned or zero-goodput cells never dominate anyone.
        let ghost = PlanCell::skipped(candidate(SystemKind::EcoServe, 8), 0);
        assert!(dominated_by(&[ghost], &weak).is_none());
    }

    #[test]
    fn pareto_keeps_strict_goodput_increases_only() {
        let cells = vec![
            measured(candidate(SystemKind::EcoServe, 8), 3.0),
            measured(candidate(SystemKind::Vllm, 8), 2.0), // same price, worse
            measured(candidate(SystemKind::EcoServe, 16), 5.0),
            measured(candidate(SystemKind::Vllm, 16), 5.0), // no strict gain
            measured(candidate(SystemKind::EcoServe, 32), 9.0),
        ];
        let front = pareto_indices(&cells);
        assert_eq!(front, vec![0, 2, 4]);
        // A dominated expensive cell never enters the frontier.
        let mut cells2 = cells;
        cells2.push(measured(candidate(SystemKind::Sarathi, 32), 1.0));
        assert_eq!(pareto_indices(&cells2), vec![0, 2, 4]);
    }

    #[test]
    fn pareto_ignores_pruned_and_zero_cells() {
        let cells = vec![
            PlanCell::skipped(candidate(SystemKind::EcoServe, 8), 0),
            measured(candidate(SystemKind::Vllm, 8), 0.0),
            measured(candidate(SystemKind::EcoServe, 16), 4.0),
        ];
        assert_eq!(pareto_indices(&cells), vec![2]);
    }
}
