//! Capacity planner — goodput-per-dollar search over deployments.
//!
//! The measurement stack answers "how fast is this deployment"
//! ([`crate::scenarios`] at a fixed rate, [`crate::frontier`] at the max
//! sustainable rate). This subsystem closes the loop the paper's
//! cost-effectiveness claim actually needs: *given my traffic and SLO,
//! what cluster should I buy and how should I shape it?* DistServe
//! (arXiv:2401.09670) shows the placement/parallelism search is where
//! disaggregated systems win or lose; DynaServe (arXiv:2504.09285) argues
//! unit sizing must be chosen per workload. `ecoserve plan` runs that
//! search end to end:
//!
//! ```text
//! ecoserve plan --scenario bursty --model llama-30b --target-rate 5
//! ecoserve plan --quick --scenario bursty --gpus 32 --out BENCH_plan.json
//! ecoserve plan --replay trace.jsonl --loop 600 --cluster all --level p99
//! ecoserve plan --scenario steady --budget-s 30   # cap each cell's search
//! ```
//!
//! * [`cost`] — the `CostModel`: USD/hr per candidate from the hardware
//!   catalog's rates (GPU rental + fabric premium + host overhead).
//! * [`candidates`] — the search space: GPU type × TP/PP × instance
//!   count × inter-node link tier × serving system, each with a cheap
//!   roofline ceiling on sustainable rate
//!   ([`candidates::roofline_rate_ub`]).
//! * [`search`] — cheapest-first waves through [`crate::frontier`]'s
//!   cell search (one shared bracket+bisect core), with sound dominance
//!   pruning: a candidate whose roofline ceiling is already delivered by
//!   a no-more-expensive measured cell is never simulated.
//! * [`report`] — the plan table and the schema-versioned
//!   `BENCH_plan.json` CI uploads next to `BENCH_goodput.json`.
//!
//! The answers: the Pareto frontier of $/hr vs. goodput, the best
//! goodput-per-dollar config, and (with `--target-rate`) the cheapest
//! config sustaining the target.

pub mod candidates;
pub mod cost;
pub mod report;
pub mod search;

pub use candidates::{enumerate_candidates, link_tiers, roofline_rate_ub, Candidate};
pub use cost::{CostBreakdown, CostModel, PriceTier};
pub use report::{plan_to_json, render_plan_table};
pub use search::{
    dominated_by, pareto_indices, run_plan, run_plan_on, PlanCell, PlanConfig, PlanOutcome,
};
