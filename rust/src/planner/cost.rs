//! The deployment cost model: what a candidate cluster shape rents for,
//! USD per hour, decomposed the way a capacity bill actually reads —
//! GPUs, interconnect premium, host overhead.
//!
//! Rates live on the hardware catalog so the planner and any future
//! consumer price identically: [`crate::perfmodel::GpuSpec::price_per_hour`]
//! per GPU, [`crate::perfmodel::LinkSpec::price_per_gpu_hour`] per
//! attached GPU for each fabric (intra-node switch + inter-node NIC/spine
//! share), and [`crate::config::ClusterSpec::node_overhead_per_hour`] per
//! occupied host. This is the denominator of the paper's headline metric:
//! goodput per dollar on commodity clusters vs. FuDG hyper-clusters.

use crate::config::Deployment;
use crate::sim::ChurnProfile;

/// Spot GPUs rent at this fraction of the on-demand rate (a typical
/// cloud spot discount of ~60%). Only the GPU component is discounted:
/// the fabric share and host overhead bill the same either way.
pub const SPOT_GPU_PRICE_MULT: f64 = 0.4;

/// Expected spot-market reclaim cadence priced into spot candidates:
/// mean seconds between preemptions, the reclaim notice, and the outage
/// until a replacement instance joins. These feed
/// [`PriceTier::churn_profile`], which the planner expands into a
/// deterministic fault timeline per probe.
pub const SPOT_PREEMPT_EVERY_S: f64 = 45.0;
pub const SPOT_PREEMPT_NOTICE_S: f64 = 5.0;
pub const SPOT_PREEMPT_DOWN_S: f64 = 25.0;

/// How a candidate's GPUs are procured. On-demand is the catalog rate;
/// spot trades a deep GPU discount for preemption churn, and the planner
/// prices *both* sides of that trade: the discount in the bill, the
/// churn in the measured goodput.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PriceTier {
    #[default]
    OnDemand,
    Spot,
}

impl PriceTier {
    pub fn label(&self) -> &'static str {
        match self {
            PriceTier::OnDemand => "on-demand",
            PriceTier::Spot => "spot",
        }
    }

    /// Multiplier on the GPU price component.
    pub fn gpu_price_mult(&self) -> f64 {
        match self {
            PriceTier::OnDemand => 1.0,
            PriceTier::Spot => SPOT_GPU_PRICE_MULT,
        }
    }

    /// The churn this tier's probes must run under, layered on top of the
    /// scenario's own profile (spot reclaim replaces any milder
    /// preemption cadence the scenario carries; crashes pass through).
    pub fn churn_profile(&self, base: Option<&ChurnProfile>) -> Option<ChurnProfile> {
        match self {
            PriceTier::OnDemand => base.cloned(),
            PriceTier::Spot => {
                let mut p = base.cloned().unwrap_or(ChurnProfile {
                    crash_every_s: None,
                    crash_down_s: 0.0,
                    preempt_every_s: None,
                    preempt_notice_s: 0.0,
                    preempt_down_s: 0.0,
                });
                p.preempt_every_s = Some(SPOT_PREEMPT_EVERY_S);
                p.preempt_notice_s = SPOT_PREEMPT_NOTICE_S;
                p.preempt_down_s = SPOT_PREEMPT_DOWN_S;
                Some(p)
            }
        }
    }
}

/// One deployment's hourly price, split by component. `total` is the sum
/// of the parts; keep them additive so reports can show the bill.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostBreakdown {
    /// GPU rental: `gpus_used × gpu.price_per_hour`.
    pub gpu: f64,
    /// Fabric premium: `gpus_used × (intra + inter).price_per_gpu_hour`.
    pub interconnect: f64,
    /// Host overhead: `nodes_used × node_overhead_per_hour`.
    pub nodes: f64,
    pub total: f64,
}

/// Prices deployments. A plain markup knob is the only state: the catalog
/// rates are list prices, and a fleet with negotiated discounts (or a
/// different margin model) scales every component uniformly.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Uniform multiplier on every component (1.0 = catalog rates).
    pub markup: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { markup: 1.0 }
    }
}

impl CostModel {
    /// Hourly bill for `d`, component by component.
    pub fn breakdown(&self, d: &Deployment) -> CostBreakdown {
        self.breakdown_tier(d, PriceTier::OnDemand)
    }

    /// Hourly bill for `d` under a procurement tier: spot discounts the
    /// GPU component only.
    pub fn breakdown_tier(&self, d: &Deployment, tier: PriceTier) -> CostBreakdown {
        let gpus = d.gpus_used as f64;
        let gpu = gpus * d.cluster.gpu.price_per_hour * self.markup * tier.gpu_price_mult();
        let interconnect = gpus
            * (d.cluster.intra_link.price_per_gpu_hour
                + d.cluster.inter_link.price_per_gpu_hour)
            * self.markup;
        let nodes = d.nodes_used() as f64 * d.cluster.node_overhead_per_hour * self.markup;
        CostBreakdown { gpu, interconnect, nodes, total: gpu + interconnect + nodes }
    }

    /// Hourly bill for `d`, total only.
    pub fn price_per_hour(&self, d: &Deployment) -> f64 {
        self.breakdown(d).total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, Deployment};
    use crate::perfmodel::{LinkSpec, ModelSpec};

    fn l20_deployment(gpus_used: usize) -> Deployment {
        let mut d = Deployment::paper_default(
            ModelSpec::codellama_34b(),
            ClusterSpec::l20_cluster(),
        );
        d.gpus_used = gpus_used;
        d
    }

    #[test]
    fn breakdown_components_sum_and_scale() {
        let cost = CostModel::default();
        let d32 = l20_deployment(32);
        let b = cost.breakdown(&d32);
        assert!((b.gpu + b.interconnect + b.nodes - b.total).abs() < 1e-12);
        // 32 L20s at $1.05, 10GbE at $0.03/GPU, 4 hosts at $0.55.
        assert!((b.gpu - 32.0 * 1.05).abs() < 1e-9);
        assert!((b.interconnect - 32.0 * 0.03).abs() < 1e-9);
        assert!((b.nodes - 4.0 * 0.55).abs() < 1e-9);
        // Half the GPUs on half the hosts: strictly cheaper, and the GPU
        // component halves exactly.
        let b16 = cost.breakdown(&l20_deployment(16));
        assert!(b16.total < b.total);
        assert!((b16.gpu * 2.0 - b.gpu).abs() < 1e-9);
    }

    #[test]
    fn premium_fabric_costs_more_on_identical_hardware() {
        let cost = CostModel::default();
        let commodity = l20_deployment(32);
        let mut upgraded = commodity.clone();
        upgraded.cluster.inter_link = LinkSpec::ib_400g();
        let delta = cost.price_per_hour(&upgraded) - cost.price_per_hour(&commodity);
        // The IB premium over 10GbE, per GPU, across 32 GPUs.
        let want = 32.0 * (0.45 - 0.03);
        assert!((delta - want).abs() < 1e-9, "delta {delta} want {want}");
    }

    #[test]
    fn a800_nodes_price_above_l20_nodes() {
        let cost = CostModel::default();
        let l20 = l20_deployment(16);
        let mut a800 = Deployment::paper_default(
            ModelSpec::codellama_34b(),
            ClusterSpec::a800_cluster(),
        );
        a800.gpus_used = 16;
        assert!(cost.price_per_hour(&a800) > 2.0 * cost.price_per_hour(&l20));
    }

    #[test]
    fn spot_tier_discounts_gpus_only() {
        let cost = CostModel::default();
        let d = l20_deployment(32);
        let od = cost.breakdown(&d);
        let spot = cost.breakdown_tier(&d, PriceTier::Spot);
        assert!((spot.gpu - od.gpu * SPOT_GPU_PRICE_MULT).abs() < 1e-9);
        assert_eq!(spot.interconnect, od.interconnect);
        assert_eq!(spot.nodes, od.nodes);
        assert!((spot.total - (spot.gpu + spot.interconnect + spot.nodes)).abs() < 1e-12);
        assert!(spot.total < od.total);
        // On-demand via the tier API matches the plain breakdown exactly.
        assert_eq!(cost.breakdown_tier(&d, PriceTier::OnDemand), od);
    }

    #[test]
    fn spot_churn_layers_preemptions_over_the_base_profile() {
        use crate::sim::ChurnProfile;
        // No base churn: pure reclaim cadence.
        let p = PriceTier::Spot.churn_profile(None).unwrap();
        assert_eq!(p.preempt_every_s, Some(SPOT_PREEMPT_EVERY_S));
        assert_eq!(p.crash_every_s, None);
        // Base crashes survive; base preemptions are replaced by the
        // market cadence.
        let base = ChurnProfile {
            crash_every_s: Some(120.0),
            crash_down_s: 15.0,
            preempt_every_s: Some(600.0),
            preempt_notice_s: 30.0,
            preempt_down_s: 10.0,
        };
        let p = PriceTier::Spot.churn_profile(Some(&base)).unwrap();
        assert_eq!(p.crash_every_s, Some(120.0));
        assert_eq!(p.preempt_every_s, Some(SPOT_PREEMPT_EVERY_S));
        assert_eq!(p.preempt_notice_s, SPOT_PREEMPT_NOTICE_S);
        // On-demand passes the base through untouched.
        assert_eq!(PriceTier::OnDemand.churn_profile(Some(&base)), Some(base));
        assert_eq!(PriceTier::OnDemand.churn_profile(None), None);
    }

    #[test]
    fn markup_scales_every_component() {
        let list = CostModel::default();
        let discounted = CostModel { markup: 0.8 };
        let d = l20_deployment(32);
        let a = list.breakdown(&d);
        let b = discounted.breakdown(&d);
        assert!((b.total - 0.8 * a.total).abs() < 1e-9);
        assert!((b.gpu - 0.8 * a.gpu).abs() < 1e-9);
        assert!((b.nodes - 0.8 * a.nodes).abs() < 1e-9);
    }
}
