//! Candidate enumeration and the cheap roofline ceiling.
//!
//! A candidate is (serving system × deployment), where the deployment
//! space is GPU type × TP/PP degree × instance count × inter-node
//! interconnect tier ([`crate::config::enumerate_deployments`] supplies
//! the shapes; [`link_tiers`] the fabric upgrades). Each candidate
//! carries its hourly price ([`crate::planner::cost::CostModel`]) and a
//! roofline upper bound on the rate it could possibly sustain — the two
//! numbers dominance pruning compares before paying for a simulation.

use crate::config::{enumerate_deployments, ClusterSpec, Deployment, SystemKind};
use crate::perfmodel::LinkSpec;
use crate::scenarios::Scenario;
use crate::workload::replay::leak;

use super::cost::{CostBreakdown, CostModel, PriceTier};
use super::PlanConfig;

/// Safety factor on the roofline ceiling. The bound below is already
/// optimistic everywhere (perfect batching, zero queueing, no SLO or
/// burst penalty); the slack absorbs the residual modeling gap so the
/// bound stays a *sound* ceiling on anything the simulator measures —
/// pruning soundness (rust/tests/planner.rs) leans on exactly this.
pub const ROOFLINE_SLACK: f64 = 1.5;

/// Optimistic ceiling on the SLO-attaining request rate of `d` under
/// `scenario`'s traffic mix, req/s: expected per-request service demand
/// with every favorable assumption — prefill amortized over a batch of 4,
/// decode amortized over a 512-deep batch at decode-start context, phases
/// perfectly overlapped across the fleet — then scaled by instance count
/// and [`ROOFLINE_SLACK`]. System-independent by construction: no
/// scheduler can beat the hardware's roofline.
pub fn roofline_rate_ub(d: &Deployment, scenario: &Scenario) -> f64 {
    let timer = d.timer();
    let mut t_per_req = 0.0;
    for class in &scenario.classes {
        let mean_in = class.dataset.input.untruncated_mean().round().max(1.0) as usize;
        let mean_out = class.dataset.output.untruncated_mean().round().max(1.0);
        // Prefill: batch-4 amortizes the weight stream (prefill is
        // compute-bound, so deeper batches barely improve on this).
        let t_prefill = timer.prefill_time(&[mean_in; 4]) / 4.0;
        // Decode: per-token occupancy at the efficient asymptote, charged
        // at decode-*start* context (the cheapest any token gets).
        let batch = 512;
        let t_decode_tok = timer.decode_iter_time(batch, batch * mean_in) / batch as f64;
        let t_req = t_prefill + (mean_out - 1.0).max(0.0) * t_decode_tok;
        t_per_req += class.share * t_req;
    }
    d.num_instances() as f64 / t_per_req.max(1e-9) * ROOFLINE_SLACK
}

/// Inter-node fabric tiers to price for `cluster`: the native network
/// plus purchasable upgrades (25G RoCE, 400G InfiniBand). Quick mode
/// sticks to the native tier. Intra-node fabric is fixed — it ships with
/// the node (the paper's L20 boxes are PCIe-only by construction).
pub fn link_tiers(cluster: &ClusterSpec, quick: bool) -> Vec<ClusterSpec> {
    let mut out = vec![cluster.clone()];
    if quick {
        return out;
    }
    for link in [LinkSpec::roce_25g(), LinkSpec::ib_400g()] {
        if link.name == cluster.inter_link.name {
            continue;
        }
        let mut c = cluster.clone();
        c.name = leak(format!("{}+{}", cluster.name, link.name));
        c.inter_link = link;
        out.push(c);
    }
    out
}

/// One point of the plan's search space: a serving system on a priced
/// deployment, with its roofline ceiling under the plan's scenario.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub system: SystemKind,
    pub deployment: Deployment,
    pub price: CostBreakdown,
    /// Roofline ceiling on sustainable rate, req/s ([`roofline_rate_ub`]).
    pub roofline_ub: f64,
    /// GPU procurement tier: spot candidates carry a discounted bill and
    /// are measured under the spot reclaim churn.
    pub tier: PriceTier,
}

impl Candidate {
    pub fn new(
        system: SystemKind,
        deployment: Deployment,
        cost: &CostModel,
        scenario: &Scenario,
    ) -> Self {
        Self::with_tier(system, deployment, cost, scenario, PriceTier::OnDemand)
    }

    /// A candidate priced at a specific procurement tier. The roofline is
    /// tier-independent (hardware is hardware); the dominance prune stays
    /// sound because churn only *lowers* measured goodput below it.
    pub fn with_tier(
        system: SystemKind,
        deployment: Deployment,
        cost: &CostModel,
        scenario: &Scenario,
        tier: PriceTier,
    ) -> Self {
        let price = cost.breakdown_tier(&deployment, tier);
        let roofline_ub = roofline_rate_ub(&deployment, scenario);
        Candidate { system, deployment, price, roofline_ub, tier }
    }

    /// Compact shape label: `tp4x1 x8` = TP4, PP1, 8 instances.
    pub fn shape(&self) -> String {
        let d = &self.deployment;
        format!("tp{}x{} x{}", d.tp, d.pp, d.num_instances())
    }
}

/// The full candidate list for a plan, in enumeration order (clusters ×
/// link tiers × deployment shapes × systems). Price-sorting happens in
/// the search, which needs it for wave-ordered dominance pruning.
pub fn enumerate_candidates(cfg: &PlanConfig) -> Vec<Candidate> {
    let cost = CostModel::default();
    let tp = cfg.tp_options();
    let pp = cfg.pp_options();
    let instances = cfg.instance_options();
    let mut out = Vec::new();
    for cluster in &cfg.clusters {
        let cap = cfg.max_gpus.unwrap_or(cluster.total_gpus());
        for tier in link_tiers(cluster, cfg.quick) {
            for d in enumerate_deployments(&cfg.model, &tier, &tp, &pp, &instances, cap) {
                for &system in &cfg.systems {
                    out.push(Candidate::new(system, d.clone(), &cost, &cfg.scenario));
                    if cfg.spot {
                        out.push(Candidate::with_tier(
                            system,
                            d.clone(),
                            &cost,
                            &cfg.scenario,
                            PriceTier::Spot,
                        ));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::perfmodel::ModelSpec;
    use crate::scenarios::by_name;

    fn deployment(tp: usize, pp: usize, gpus: usize) -> Deployment {
        let mut d = Deployment::paper_default(
            ModelSpec::llama_30b(),
            ClusterSpec::l20_cluster(),
        );
        d.tp = tp;
        d.pp = pp;
        d.gpus_used = gpus;
        d
    }

    #[test]
    fn roofline_ub_is_positive_and_scales_with_instances() {
        let s = by_name("steady").unwrap();
        let two = roofline_rate_ub(&deployment(4, 1, 8), &s);
        let eight = roofline_rate_ub(&deployment(4, 1, 32), &s);
        assert!(two > 0.0);
        assert!((eight / two - 4.0).abs() < 1e-9, "{eight} vs {two}");
    }

    #[test]
    fn roofline_ub_ranks_hardware_sanely() {
        let s = by_name("steady").unwrap();
        // Same shape on A800 beats L20 (≈2.6x the compute).
        let mut a800 = deployment(4, 1, 16);
        a800.cluster = ClusterSpec::a800_cluster();
        assert!(roofline_rate_ub(&a800, &s) > roofline_rate_ub(&deployment(4, 1, 16), &s));
        // PP taxes the bound: same GPUs, fewer (slower-per-batch)
        // instances.
        let pp2 = deployment(4, 2, 16); // 2 instances of 8 GPUs
        let pp1 = deployment(4, 1, 16); // 4 instances of 4 GPUs
        assert!(roofline_rate_ub(&pp2, &s) < roofline_rate_ub(&pp1, &s));
        // Long-context traffic (heavy-tail) lowers the ceiling.
        let heavy = by_name("heavy-tail").unwrap();
        let d = deployment(4, 1, 32);
        assert!(roofline_rate_ub(&d, &heavy) < roofline_rate_ub(&d, &s));
    }

    #[test]
    fn link_tiers_native_plus_upgrades() {
        let l20 = ClusterSpec::l20_cluster();
        let quick = link_tiers(&l20, true);
        assert_eq!(quick.len(), 1);
        assert_eq!(quick[0].inter_link.name, "10GbE");
        let full = link_tiers(&l20, false);
        assert_eq!(full.len(), 3);
        assert_eq!(full[0].inter_link.name, "10GbE");
        assert!(full.iter().any(|c| c.inter_link.name == "400G-IB"));
        assert!(full[1].name.contains('+'));
        // The A800 cluster is natively RoCE: the RoCE tier dedups away.
        let a800_tiers = link_tiers(&ClusterSpec::a800_cluster(), false);
        assert_eq!(a800_tiers.len(), 2);
    }

    #[test]
    fn candidate_carries_price_ceiling_and_shape() {
        let s = by_name("steady").unwrap();
        let cost = CostModel::default();
        let c = Candidate::new(SystemKind::EcoServe, deployment(4, 1, 32), &cost, &s);
        assert_eq!(c.shape(), "tp4x1 x8");
        assert_eq!(c.tier, PriceTier::OnDemand);
        assert!(c.roofline_ub > 0.0);
        assert!((c.price.total - cost.price_per_hour(&c.deployment)).abs() < 1e-12);
        assert!(c.price.total > 30.0, "32 L20s cost real money: {:?}", c.price);
    }

    #[test]
    fn spot_enumeration_emits_discounted_twins() {
        let mut cfg = PlanConfig::quick(by_name("steady").unwrap(), ModelSpec::llama_30b());
        cfg.max_gpus = Some(16);
        let on_demand = enumerate_candidates(&cfg);
        assert!(on_demand.iter().all(|c| c.tier == PriceTier::OnDemand));
        cfg.spot = true;
        let both = enumerate_candidates(&cfg);
        assert_eq!(both.len(), 2 * on_demand.len());
        let spots: Vec<&Candidate> =
            both.iter().filter(|c| c.tier == PriceTier::Spot).collect();
        assert_eq!(spots.len(), on_demand.len());
        // Each spot twin shares its sibling's hardware and ceiling but
        // bills strictly less (the GPU discount), never more.
        for (od, spot) in both.chunks(2).map(|w| (&w[0], &w[1])) {
            assert_eq!(od.tier, PriceTier::OnDemand);
            assert_eq!(spot.tier, PriceTier::Spot);
            assert_eq!(od.deployment.gpus_used, spot.deployment.gpus_used);
            assert_eq!(od.roofline_ub, spot.roofline_ub);
            assert!(spot.price.total < od.price.total);
            assert!(spot.price.gpu < od.price.gpu);
            assert_eq!(spot.price.nodes, od.price.nodes);
        }
    }
}
