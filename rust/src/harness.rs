//! Experiment harness: builds a system, drives a trace through the
//! simulator, computes attainment the strict way, and searches for goodput
//! — "the throughput collected by incrementally increasing the request
//! rate until the system fails to reach the attainment" (paper §4.1).
//!
//! Attainment here is computed over requests that *arrived* in the
//! measurement window, counting never-completed requests as violations —
//! a system cannot improve its score by silently falling behind.

use crate::baselines::{FudgMode, FudgSystem, SarathiSystem, VllmSystem};
use crate::config::{ExperimentConfig, SystemKind};
use crate::coordinator::EcoServeSystem;
use crate::frontier::search::{rate_search, Probe, SearchParams, SearchPoint};
use crate::metrics::{
    summarize_from, AbandonPolicy, Attainment, Collector, SloMonitor, SloSpec, Summary,
};
use crate::sim::{run_abandonable, StopReason, System};
use crate::util::threads::parallel_map;
use crate::workload::TraceGenerator;

/// How long past the trace end the simulator may run to drain in-flight
/// requests before attainment is assessed.
const DRAIN_SECS: f64 = 240.0;

/// One simulation run's outcome.
#[derive(Debug)]
pub struct RunResult {
    pub summary: Summary,
    /// Requests that arrived in the measurement window.
    pub arrived: usize,
    /// Of those, completed AND meeting both SLOs.
    pub met: usize,
    /// Strict attainment = met / arrived.
    pub attainment: f64,
    pub events: u64,
    /// Events still queued when the SLO monitor aborted the run (0 on
    /// full runs) — a lower bound on the work abandonment avoided.
    pub events_saved: u64,
    /// True when the run was cut short because the attainment target
    /// became mathematically unreachable.
    pub abandoned: bool,
    pub wall: std::time::Duration,
}

impl RunResult {
    pub fn meets(&self, level: Attainment) -> bool {
        self.attainment >= level.fraction()
    }
}

/// Instantiate a system for one run. FuDG systems need a prefill:decode
/// split; `fudg_prefill` overrides the config (used by the ratio sweep).
pub fn build_system(
    kind: SystemKind,
    cfg: &ExperimentConfig,
    fudg_prefill: Option<usize>,
) -> Box<dyn System> {
    let slo = SloSpec::new(cfg.dataset.slo_ttft, cfg.dataset.slo_tpot);
    let d = &cfg.deployment;
    match kind {
        SystemKind::EcoServe => {
            Box::new(EcoServeSystem::new(d, slo, cfg.params.clone()))
        }
        SystemKind::Vllm => Box::new(VllmSystem::new(d, cfg.params.clone())),
        SystemKind::Sarathi => Box::new(SarathiSystem::new(d, cfg.params.clone())),
        SystemKind::DistServe | SystemKind::MoonCake => {
            let n = d.num_instances();
            let p = fudg_prefill
                .or(cfg.params.fudg_prefill_instances)
                .unwrap_or_else(|| (n / 3).max(1));
            let mode = if kind == SystemKind::DistServe {
                FudgMode::DistServe
            } else {
                FudgMode::MoonCake
            };
            Box::new(FudgSystem::new(d, mode, p.clamp(1, n - 1), cfg.params.clone()))
        }
    }
}

/// Run `kind` at `rate` req/s and measure strict attainment (full
/// simulation, no online monitor).
pub fn run_once(kind: SystemKind, cfg: &ExperimentConfig, rate: f64,
                fudg_prefill: Option<usize>) -> RunResult {
    run_probe(kind, cfg, rate, fudg_prefill, None)
}

/// [`run_once`] with an optional [`AbandonPolicy`]: when set, an online
/// [`SloMonitor`] watches every measurement-window arrival and the run is
/// scored through the monitor's decision snapshot; with
/// `policy.stop_early` the simulation also aborts the moment the target
/// becomes unreachable. Verdicts and reported numbers are bit-identical
/// across `stop_early` on/off — only `events`/`wall` change.
pub fn run_probe(
    kind: SystemKind,
    cfg: &ExperimentConfig,
    rate: f64,
    fudg_prefill: Option<usize>,
    abandon: Option<AbandonPolicy>,
) -> RunResult {
    let slo = SloSpec::new(cfg.dataset.slo_ttft, cfg.dataset.slo_tpot);
    let gen = TraceGenerator::new(cfg.dataset.clone(), cfg.seed);
    let trace = gen.poisson(rate, cfg.duration);
    let window = (cfg.warmup, cfg.duration);
    let arrived = trace
        .iter()
        .filter(|r| r.arrival >= window.0 && r.arrival < window.1)
        .count();
    let mut system = build_system(kind, cfg, fudg_prefill);
    let monitor = abandon.map(|policy| {
        let mut monitor = SloMonitor::new(policy.target, 1);
        for req in &trace {
            if req.arrival >= window.0 && req.arrival < window.1 {
                monitor.track(req.id, req.arrival, slo, 0, req.output_len);
            }
        }
        monitor
    });
    // Pooled: rate searches fire many probes per worker thread, and the
    // collector's maps/vecs are the largest per-probe allocations.
    let mut metrics = Collector::pooled(monitor);
    let horizon = cfg.duration + DRAIN_SECS;
    let stop_early = abandon.is_some_and(|p| p.stop_early);
    let stats = run_abandonable(system.as_mut(), trace, horizon, &mut metrics, stop_early);
    let met = metrics
        .window_records(window.0, window.1)
        .filter(|r| r.meets(&slo))
        .count();
    let attainment = if arrived == 0 { 1.0 } else { met as f64 / arrived as f64 };
    let result = RunResult {
        summary: summarize_from(
            metrics.window_records(window.0, window.1),
            &slo,
            window.1 - window.0,
        ),
        arrived,
        met,
        attainment,
        events: stats.events,
        events_saved: stats.events_saved,
        abandoned: stats.stop == StopReason::Abandoned,
        wall: stats.wall_time,
    };
    metrics.release();
    result
}

/// Pick the best FuDG prefill:decode split at a calibration rate — the
/// paper "perform[s] different P/D ratio and select[s] the optimal one"
/// for MoonCake; we extend the same courtesy to DistServe.
pub fn pick_fudg_ratio(kind: SystemKind, cfg: &ExperimentConfig, probe_rate: f64) -> usize {
    let n = cfg.deployment.num_instances();
    if n <= 2 {
        return 1;
    }
    let candidates: Vec<usize> = [n / 4, n / 3, n / 2, (2 * n) / 3]
        .into_iter()
        .map(|p| p.clamp(1, n - 1))
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    let scored = parallel_map(candidates.clone(), candidates.len(), |p| {
        let r = run_once(kind, cfg, probe_rate, Some(p));
        (p, r.attainment, r.summary.throughput_rps)
    });
    scored
        .into_iter()
        .max_by(|a, b| {
            (a.1, a.2)
                .partial_cmp(&(b.1, b.2))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .map(|(p, _, _)| p)
        .unwrap_or(1)
}

/// Goodput search result.
#[derive(Debug)]
pub struct Goodput {
    pub system: SystemKind,
    pub level: Attainment,
    /// Max sustainable request rate (req/s) meeting the attainment.
    pub rate: f64,
    /// Summary at the found rate.
    pub summary: Summary,
    /// FuDG split used (None for NoDG/PaDG).
    pub fudg_prefill: Option<usize>,
    /// Every probed (rate, attainment) point, sorted by rate.
    pub curve: Vec<SearchPoint>,
}

/// Find the maximum Poisson rate at which `kind` sustains `level`
/// attainment (paper §4.1's "incrementally increasing the request
/// rate"). Thin wrapper over the shared frontier search core
/// ([`crate::frontier::search::rate_search`]) — the bracketing/bisection
/// loop lives there, and only there.
pub fn goodput_search(kind: SystemKind, cfg: &ExperimentConfig, level: Attainment) -> Goodput {
    let fudg_prefill = match kind {
        SystemKind::DistServe | SystemKind::MoonCake => Some(
            cfg.params
                .fudg_prefill_instances
                .unwrap_or_else(|| pick_fudg_ratio(kind, cfg, 2.0)),
        ),
        _ => None,
    };
    let params = SearchParams::paper_default(level.fraction());
    // Every probe runs under the online SLO monitor: doomed rates abort
    // the moment the target is provably unreachable, with the same
    // verdict (and reported numbers) a full run would produce.
    let abandon = AbandonPolicy::stop_at(level.fraction());
    let outcome = rate_search(&params, |rate| {
        let r = run_probe(kind, cfg, rate, fudg_prefill, Some(abandon));
        Probe {
            attainment: r.attainment,
            goodput_rps: r.met as f64 / (cfg.duration - cfg.warmup).max(1e-9),
            result: r,
        }
    });
    let summary = match outcome.best {
        Some(r) => r.summary,
        None => {
            run_once(kind, cfg, outcome.max_rate.max(0.05), fudg_prefill).summary
        }
    };
    Goodput {
        system: kind,
        level,
        rate: outcome.max_rate,
        summary,
        fudg_prefill,
        curve: outcome.curve,
    }
}

/// Convenience used by the crate docs and the quickstart example.
pub struct GoodputReport {
    pub rows: Vec<Goodput>,
}

impl GoodputReport {
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for g in &self.rows {
            out.push_str(&format!(
                "{:<10} {}: goodput {:.2} req/s (p90 ttft {:.2}s, p90 tpot {:.0}ms)\n",
                g.system.label(),
                g.level.label(),
                g.rate,
                g.summary.ttft_p90,
                g.summary.tpot_p90 * 1e3,
            ));
        }
        out
    }
}

/// Run a goodput search for several systems in parallel (used by benches).
pub fn run_goodput_search(cfg: &ExperimentConfig) -> GoodputReport {
    let kinds: Vec<SystemKind> = SystemKind::all().to_vec();
    // One worker per system — a hardcoded width would silently serialize
    // the moment a sixth system joins the registry.
    let workers = kinds.len();
    let rows = parallel_map(kinds, workers, |kind| {
        goodput_search(kind, cfg, Attainment::P90)
    });
    GoodputReport { rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterSpec, Deployment};
    use crate::perfmodel::ModelSpec;
    use crate::workload::Dataset;

    fn small_cfg() -> ExperimentConfig {
        let mut d = Deployment::paper_default(
            ModelSpec::codellama_34b(),
            ClusterSpec::l20_cluster(),
        );
        d.gpus_used = 16; // 4 instances — fast tests
        let mut cfg = ExperimentConfig::new(d, Dataset::sharegpt());
        cfg.duration = 90.0;
        cfg.warmup = 15.0;
        cfg
    }

    #[test]
    fn run_once_light_load_meets_p90() {
        let cfg = small_cfg();
        let r = run_once(SystemKind::EcoServe, &cfg, 2.0, None);
        assert!(r.arrived > 50);
        assert!(r.meets(Attainment::P90), "attainment {}", r.attainment);
    }

    #[test]
    fn run_once_overload_fails_p90() {
        let cfg = small_cfg();
        let r = run_once(SystemKind::EcoServe, &cfg, 80.0, None);
        assert!(!r.meets(Attainment::P90), "attainment {}", r.attainment);
    }

    #[test]
    fn goodput_search_brackets_a_positive_rate() {
        let mut cfg = small_cfg();
        cfg.duration = 60.0;
        cfg.warmup = 10.0;
        let g = goodput_search(SystemKind::EcoServe, &cfg, Attainment::P90);
        assert!(g.rate > 0.5, "goodput {}", g.rate);
        assert!(g.rate < 200.0);
        // The shared search core records the full attainment curve.
        assert!(g.curve.len() >= 3, "{:?}", g.curve);
        for w in g.curve.windows(2) {
            assert!(w[0].rate < w[1].rate, "curve must be rate-sorted");
        }
        assert!(g.curve.iter().any(|p| (p.rate - g.rate).abs() < 1e-9));
    }

    #[test]
    fn fudg_ratio_sweep_returns_valid_split() {
        let mut cfg = small_cfg();
        cfg.duration = 40.0;
        cfg.warmup = 10.0;
        let p = pick_fudg_ratio(SystemKind::MoonCake, &cfg, 1.0);
        let n = cfg.deployment.num_instances();
        assert!(p >= 1 && p < n);
    }

    /// Early abandonment must change cost, never answers: an overload
    /// probe stopped at decision time and the same probe driven to
    /// completion report bit-identical verdict fields.
    #[test]
    fn early_abandon_matches_full_run_bit_for_bit_on_overload() {
        let cfg = small_cfg();
        let on = run_probe(
            SystemKind::Vllm,
            &cfg,
            80.0,
            None,
            Some(AbandonPolicy::stop_at(0.90)),
        );
        let off = run_probe(
            SystemKind::Vllm,
            &cfg,
            80.0,
            None,
            Some(AbandonPolicy::monitor_only(0.90)),
        );
        assert!(on.abandoned, "an 80 req/s probe on 4 instances must abandon");
        assert!(!off.abandoned);
        assert_eq!(on.arrived, off.arrived);
        assert_eq!(on.met, off.met);
        assert_eq!(on.attainment.to_bits(), off.attainment.to_bits());
        assert_eq!(on.summary.count, off.summary.count);
        assert_eq!(on.summary.ttft_p90.to_bits(), off.summary.ttft_p90.to_bits());
        assert_eq!(on.summary.tpot_p99.to_bits(), off.summary.tpot_p99.to_bits());
        // The whole point: the abandoned run simulated far less.
        assert!(
            on.events * 2 <= off.events,
            "expected >=2x fewer events: {} vs {}",
            on.events,
            off.events
        );
        assert!(on.events_saved > 0);
        assert_eq!(off.events_saved, 0);
        // And both agree with the legacy full run's verdict.
        let legacy = run_once(SystemKind::Vllm, &cfg, 80.0, None);
        assert!(!legacy.meets(Attainment::P90));
        assert!(on.attainment < 0.90 - 1e-12);
    }

    /// On a healthy (passing) probe the monitor never decides, so the
    /// monitored run is the legacy run, bit for bit.
    #[test]
    fn monitored_passing_probe_equals_the_legacy_run() {
        let cfg = small_cfg();
        let probe = run_probe(
            SystemKind::EcoServe,
            &cfg,
            2.0,
            None,
            Some(AbandonPolicy::stop_at(0.90)),
        );
        let legacy = run_once(SystemKind::EcoServe, &cfg, 2.0, None);
        assert!(!probe.abandoned);
        assert_eq!(probe.arrived, legacy.arrived);
        assert_eq!(probe.met, legacy.met);
        assert_eq!(probe.attainment.to_bits(), legacy.attainment.to_bits());
        assert_eq!(probe.events, legacy.events);
        assert_eq!(probe.summary.ttft_p99.to_bits(), legacy.summary.ttft_p99.to_bits());
    }

    #[test]
    fn strict_attainment_counts_missing_completions() {
        // At absurd overload, many arrivals never complete; strict
        // attainment must reflect that.
        let cfg = small_cfg();
        let r = run_once(SystemKind::Vllm, &cfg, 100.0, None);
        assert!(r.met <= r.arrived);
        assert!(r.attainment < 0.9);
    }
}
