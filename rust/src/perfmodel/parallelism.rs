//! Parallelism timing: tensor-parallel communication and pipeline-parallel
//! staging (paper §2.3, Figures 3–4, and the Figure 11 experiment).
//!
//! TP partitions every layer across `tp` GPUs — two ring all-reduces per
//! layer over the intra-node link (PCIe on the paper's L20/A800 nodes; the
//! paper measures "communication overhead accounts for nearly half of the
//! total execution time" for Llama-30B TP=4 over PCIe — validated in
//! rust/tests/perfmodel_validation.rs).
//!
//! PP partitions layer-wise into `pp` stages with one point-to-point
//! activation hand-off between consecutive stages. Its efficiency depends
//! on workload balance: the paper's Figure 4 bubbles come from inter-batch
//! imbalance and prefill/decode imbalance, which the simulator reproduces
//! by running stages sequentially per batch and interleaving up to `pp`
//! batches.

use super::interconnect::LinkSpec;
use super::llm::ModelSpec;

/// Parallel execution configuration of one inference instance.
#[derive(Debug, Clone, PartialEq)]
pub struct ParallelCfg {
    /// Tensor-parallel degree (GPUs per stage).
    pub tp: usize,
    /// Pipeline-parallel degree (stages).
    pub pp: usize,
    /// Link carrying TP all-reduces (intra-node: PCIe or NVLink).
    pub tp_link: LinkSpec,
    /// Link carrying PP activations (PCIe intra-node, NIC across nodes).
    pub pp_link: LinkSpec,
}

impl ParallelCfg {
    pub fn tp_only(tp: usize, link: LinkSpec) -> Self {
        ParallelCfg { tp, pp: 1, tp_link: link.clone(), pp_link: link }
    }

    pub fn gpus(&self) -> usize {
        self.tp * self.pp
    }

    /// TP all-reduce time for processing `tokens` tokens through the whole
    /// model: 2 all-reduces per layer of (tokens · H) activations.
    pub fn tp_comm_time(&self, model: &ModelSpec, tokens: usize) -> f64 {
        let (bw, lat) = self.tp_comm_parts(model, tokens);
        bw + lat
    }

    /// TP all-reduce cost split into (bandwidth, latency) totals across all
    /// layers — phases with compute to spare can hide the bandwidth part.
    pub fn tp_comm_parts(&self, model: &ModelSpec, tokens: usize) -> (f64, f64) {
        if self.tp <= 1 {
            return (0.0, 0.0);
        }
        let bytes = (tokens * model.hidden * model.elem_bytes) as f64;
        let (bw, lat) = self.tp_link.allreduce_parts(bytes, self.tp);
        let layers = model.layers as f64;
        (2.0 * bw * layers, 2.0 * lat * layers)
    }

    /// PP hand-off time for `tokens` tokens crossing all stage boundaries.
    pub fn pp_comm_time(&self, model: &ModelSpec, tokens: usize) -> f64 {
        if self.pp <= 1 {
            return 0.0;
        }
        let bytes = (tokens * model.hidden * model.elem_bytes) as f64;
        (self.pp - 1) as f64 * self.pp_link.p2p_time(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(tp: usize, pp: usize) -> ParallelCfg {
        ParallelCfg {
            tp,
            pp,
            tp_link: LinkSpec::pcie4(),
            pp_link: LinkSpec::pcie4(),
        }
    }

    #[test]
    fn tp1_has_no_comm() {
        let m = ModelSpec::llama_30b();
        assert_eq!(cfg(1, 1).tp_comm_time(&m, 512), 0.0);
    }

    #[test]
    fn tp_comm_grows_with_degree_and_tokens() {
        let m = ModelSpec::llama_30b();
        assert!(cfg(4, 1).tp_comm_time(&m, 512) > cfg(2, 1).tp_comm_time(&m, 512));
        assert!(cfg(4, 1).tp_comm_time(&m, 1024) > cfg(4, 1).tp_comm_time(&m, 512));
    }

    #[test]
    fn pp_comm_much_cheaper_than_tp() {
        // Paper §2.3: PP needs one small p2p every few layers vs TP's two
        // all-reduces per layer.
        let m = ModelSpec::llama_30b();
        let tp = cfg(4, 1).tp_comm_time(&m, 512);
        let pp = cfg(1, 4).pp_comm_time(&m, 512);
        assert!(pp < tp / 10.0, "pp={pp} tp={tp}");
    }
}
