//! GPU device catalog: the two SKUs of the paper's clusters plus a CPU
//! pseudo-device for the live path.
//!
//! `flops_eff` / `bw_eff` are the achievable fractions of peak that
//! calibrate the roofline to the paper's measured Table 3 throughputs
//! (validated in rust/tests/perfmodel_validation.rs). They absorb kernel
//! inefficiency, scheduling gaps, and framework overhead — a standard
//! simulator technique when the physical testbed is unavailable.
//!
//! `price_per_hour` is the rental rate the capacity planner
//! ([`crate::planner`]) charges per GPU: representative cloud/colo rates
//! for the paper's cost-effectiveness argument (commodity L20s vs.
//! A800-class accelerators), not a quote. Node-level overhead and
//! interconnect premiums live on [`crate::config::ClusterSpec`] and
//! [`crate::perfmodel::interconnect::LinkSpec`].

/// A GPU (or pseudo-GPU) device model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Peak dense bf16 throughput, FLOP/s.
    pub peak_flops: f64,
    /// Peak HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Device memory, bytes.
    pub mem_bytes: f64,
    /// Achievable fraction of peak FLOPs in compute-bound phases.
    pub flops_eff: f64,
    /// Achievable fraction of peak HBM bandwidth in memory-bound phases.
    pub bw_eff: f64,
    /// Rental rate, USD per GPU-hour (capacity-planner cost model).
    pub price_per_hour: f64,
}

impl GpuSpec {
    /// NVIDIA L20-48GB: 119.5 TFLOP/s bf16, 864 GB/s GDDR6, PCIe only.
    pub fn l20() -> Self {
        GpuSpec {
            name: "L20",
            peak_flops: 119.5e12,
            hbm_bw: 864.0e9,
            mem_bytes: 48.0 * 1e9,
            flops_eff: 0.55,
            bw_eff: 0.80,
            price_per_hour: 1.05,
        }
    }

    /// NVIDIA A800-80GB: 312 TFLOP/s bf16, 2039 GB/s HBM2e.
    pub fn a800() -> Self {
        GpuSpec {
            name: "A800",
            peak_flops: 312.0e12,
            hbm_bw: 2039.0e9,
            mem_bytes: 80.0 * 1e9,
            flops_eff: 0.72,
            bw_eff: 0.85,
            price_per_hour: 3.40,
        }
    }

    pub fn by_name(name: &str) -> Option<GpuSpec> {
        match name {
            "l20" | "L20" => Some(Self::l20()),
            "a800" | "A800" => Some(Self::a800()),
            _ => None,
        }
    }

    /// Effective compute throughput (FLOP/s).
    pub fn eff_flops(&self) -> f64 {
        self.peak_flops * self.flops_eff
    }

    /// Effective memory bandwidth (bytes/s).
    pub fn eff_bw(&self) -> f64 {
        self.hbm_bw * self.bw_eff
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_sane() {
        let l20 = GpuSpec::l20();
        let a800 = GpuSpec::a800();
        assert!(a800.peak_flops > 2.0 * l20.peak_flops);
        assert!(a800.hbm_bw > 2.0 * l20.hbm_bw);
        assert!(a800.mem_bytes > l20.mem_bytes);
        assert!(l20.flops_eff > 0.0 && l20.flops_eff <= 1.0);
        // The commodity card is the cheap one — the paper's premise.
        assert!(l20.price_per_hour > 0.0);
        assert!(a800.price_per_hour > 2.0 * l20.price_per_hour);
    }

    #[test]
    fn lookup() {
        assert_eq!(GpuSpec::by_name("L20").unwrap().name, "L20");
        assert!(GpuSpec::by_name("h100").is_none());
    }
}
