//! Interconnect catalog + collective-time arithmetic.
//!
//! The paper's cost-effectiveness argument hinges on interconnects: FuDG
//! needs NVLink/InfiniBand-class links to move KV cache, while PaDG runs on
//! "commodity" PCIe + 10 Gbps Ethernet. These link models feed both the
//! TP/PP communication costs (perfmodel::parallelism) and the simulator's
//! KV-transfer events (sim::network).

/// A point-to-point (or bus) link model: bandwidth + fixed per-message
/// latency + a collective-efficiency derate.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSpec {
    pub name: &'static str,
    /// Usable point-to-point bandwidth, bytes/s (derated from line rate).
    pub bandwidth: f64,
    /// Per-message latency, seconds.
    pub latency: f64,
    /// Fraction of `bandwidth` achievable inside ring collectives. PCIe
    /// without P2P/GPU-direct routes all-reduce traffic through host
    /// memory, cutting effective collective bandwidth to ~a third — this is
    /// what makes TP "account for nearly half of the total execution time"
    /// on the paper's L20 nodes (§2.3), validated in
    /// rust/tests/perfmodel_validation.rs.
    pub collective_eff: f64,
    /// Fabric premium, USD per attached GPU per hour, charged by the
    /// capacity planner ([`crate::planner`]) on top of the GPU rental
    /// rate. Commodity PCIe is free (it ships with the node); NVLink
    /// switches and InfiniBand HCAs+spines are what make FuDG-class
    /// hyper-clusters expensive — the cost axis of the paper's argument.
    pub price_per_gpu_hour: f64,
}

impl LinkSpec {
    /// PCIe 4.0 x16: ~32 GB/s line, ~25 GB/s usable p2p; host-routed
    /// collectives reach ~8-9 GB/s with ~20 us sync latency.
    pub fn pcie4() -> Self {
        LinkSpec {
            name: "PCIe4x16",
            bandwidth: 25.0e9,
            latency: 20e-6,
            collective_eff: 0.35,
            price_per_gpu_hour: 0.0,
        }
    }

    /// NVLink (A100/A800-class NVSwitch): ~400 GB/s per GPU usable ~300.
    pub fn nvlink() -> Self {
        LinkSpec {
            name: "NVLink",
            bandwidth: 300.0e9,
            latency: 2e-6,
            collective_eff: 0.85,
            price_per_gpu_hour: 0.60,
        }
    }

    /// 10 Gbps datacenter Ethernet: ~1.1 GB/s usable after TCP overheads.
    pub fn eth_10g() -> Self {
        LinkSpec {
            name: "10GbE",
            bandwidth: 1.1e9,
            latency: 50e-6,
            collective_eff: 0.7,
            price_per_gpu_hour: 0.03,
        }
    }

    /// 25 Gbps RoCE: ~2.9 GB/s usable.
    pub fn roce_25g() -> Self {
        LinkSpec {
            name: "25G-RoCE",
            bandwidth: 2.9e9,
            latency: 10e-6,
            collective_eff: 0.8,
            price_per_gpu_hour: 0.10,
        }
    }

    /// 400 Gbps InfiniBand (the class of link FuDG hyper-clusters assume).
    pub fn ib_400g() -> Self {
        LinkSpec {
            name: "400G-IB",
            bandwidth: 45.0e9,
            latency: 3e-6,
            collective_eff: 0.85,
            price_per_gpu_hour: 0.45,
        }
    }

    pub fn by_name(name: &str) -> Option<LinkSpec> {
        match name {
            "pcie4" => Some(Self::pcie4()),
            "nvlink" => Some(Self::nvlink()),
            "eth10g" | "10gbe" => Some(Self::eth_10g()),
            "roce25g" => Some(Self::roce_25g()),
            "ib400g" => Some(Self::ib_400g()),
            _ => None,
        }
    }

    /// Time to move `bytes` point-to-point.
    pub fn p2p_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.bandwidth
    }

    /// Ring all-reduce across `n` workers of a `bytes`-sized buffer:
    /// 2·(n-1)/n · bytes over the slowest link + 2(n-1) latency hops.
    pub fn allreduce_time(&self, bytes: f64, n: usize) -> f64 {
        let (bw, lat) = self.allreduce_parts(bytes, n);
        bw + lat
    }

    /// The all-reduce split into (bandwidth term, latency term). Compute
    /// overlap can hide the bandwidth term under GEMMs, but the hop
    /// latency serializes with kernel boundaries — the roofline model
    /// discounts only the part a given phase can actually hide.
    pub fn allreduce_parts(&self, bytes: f64, n: usize) -> (f64, f64) {
        if n <= 1 {
            return (0.0, 0.0);
        }
        let nf = n as f64;
        (
            2.0 * (nf - 1.0) / nf * bytes / (self.bandwidth * self.collective_eff),
            2.0 * (nf - 1.0) * self.latency,
        )
    }

    /// All-gather of `bytes` total across `n` workers.
    pub fn allgather_time(&self, bytes: f64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let nf = n as f64;
        (nf - 1.0) / nf * bytes / (self.bandwidth * self.collective_eff)
            + (nf - 1.0) * self.latency
    }
}

/// Required KV egress bandwidth for an all-prefill node producing
/// `tokens_per_sec`, for a model with `kv_bytes_per_token` — the paper's
/// Table 3 arithmetic.
pub fn required_kv_bandwidth(tokens_per_sec: f64, kv_bytes_per_token: f64) -> f64 {
    tokens_per_sec * kv_bytes_per_token
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_dominated_by_bandwidth_for_big_transfers() {
        let l = LinkSpec::eth_10g();
        let t = l.p2p_time(1.1e9);
        assert!((t - 1.0).abs() < 0.01, "{t}");
    }

    #[test]
    fn allreduce_scales_with_workers() {
        let l = LinkSpec::pcie4();
        let t2 = l.allreduce_time(1e9, 2);
        let t4 = l.allreduce_time(1e9, 4);
        let t8 = l.allreduce_time(1e9, 8);
        assert!(t2 < t4 && t4 < t8);
        assert_eq!(l.allreduce_time(1e9, 1), 0.0);
        // asymptote: 2*bytes/(bw*collective_eff)
        assert!(t8 < 2.0 * 1e9 / (l.bandwidth * l.collective_eff) * 1.01);
    }

    #[test]
    fn table3_bandwidth_arithmetic() {
        // Paper Table 3 row 1: Llama-30B on L20, 6584.6 tok/s -> 9.796 GB/s.
        let kv = crate::perfmodel::llm::ModelSpec::llama_30b().kv_bytes_per_token();
        let bw = required_kv_bandwidth(6584.6, kv);
        assert!((bw / 1e9 - 9.796).abs() < 0.75, "got {} GB/s", bw / 1e9);
    }

    #[test]
    fn link_ordering_matches_cost_tiers() {
        assert!(LinkSpec::nvlink().bandwidth > LinkSpec::pcie4().bandwidth);
        assert!(LinkSpec::pcie4().bandwidth > LinkSpec::roce_25g().bandwidth);
        assert!(LinkSpec::roce_25g().bandwidth > LinkSpec::eth_10g().bandwidth);
        // Faster fabrics carry higher planner premiums; commodity PCIe and
        // 10GbE stay (near-)free — the paper's cost axis.
        assert_eq!(LinkSpec::pcie4().price_per_gpu_hour, 0.0);
        assert!(LinkSpec::nvlink().price_per_gpu_hour > LinkSpec::roce_25g().price_per_gpu_hour);
        assert!(LinkSpec::ib_400g().price_per_gpu_hour > LinkSpec::roce_25g().price_per_gpu_hour);
        assert!(LinkSpec::roce_25g().price_per_gpu_hour > LinkSpec::eth_10g().price_per_gpu_hour);
    }
}
