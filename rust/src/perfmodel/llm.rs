//! LLM architecture catalog: parameter counts, FLOPs, memory traffic and
//! KV-cache footprints for the paper's three evaluation models plus the
//! TinyLM used on the live path.
//!
//! The KV-per-token numbers reproduce the paper's §2.1 and Table 3
//! arithmetic exactly: Llama-30B (MHA) 1.52 MiB/token in bf16;
//! CodeLlama2-34B (GQA, 8 KV heads) 187.5 KiB/token.

/// Attention flavour — determines KV cache size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Attention {
    /// Multi-head attention: one KV head per query head (Llama-30B).
    Mha,
    /// Grouped-query attention with the given number of KV heads.
    Gqa(usize),
}

/// Transformer architecture description (paper Table 1 notation in docs:
/// L = layers, H = hidden, M = heads, D = head dim).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub layers: usize,      // L
    pub hidden: usize,      // H
    pub heads: usize,       // M
    pub attention: Attention,
    pub ffn: usize,         // FFN inner dim (SwiGLU counts both matrices)
    pub vocab: usize,
    /// Bytes per weight/activation element (2 = bf16, the paper's setting).
    pub elem_bytes: usize,
}

impl ModelSpec {
    /// Llama-30B (actually 32.5B): 60 layers, hidden 6656, 52 MHA heads.
    pub fn llama_30b() -> Self {
        ModelSpec {
            name: "Llama-30B",
            layers: 60,
            hidden: 6656,
            heads: 52,
            attention: Attention::Mha,
            ffn: 17920,
            vocab: 32000,
            elem_bytes: 2,
        }
    }

    /// CodeLlama2-34B: 48 layers, hidden 8192, 64 heads, GQA with 8 KV heads.
    pub fn codellama_34b() -> Self {
        ModelSpec {
            name: "CodeLlama2-34B",
            layers: 48,
            hidden: 8192,
            heads: 64,
            attention: Attention::Gqa(8),
            ffn: 22016,
            vocab: 32000,
            elem_bytes: 2,
        }
    }

    /// Qwen2-72B: 80 layers, hidden 8192, 64 heads, GQA with 8 KV heads.
    pub fn qwen2_72b() -> Self {
        ModelSpec {
            name: "Qwen2-72B",
            layers: 80,
            hidden: 8192,
            heads: 64,
            attention: Attention::Gqa(8),
            ffn: 29568,
            vocab: 152064,
            elem_bytes: 2,
        }
    }

    /// The live-path model served through PJRT (python/compile/model.py).
    pub fn tinylm() -> Self {
        ModelSpec {
            name: "TinyLM",
            layers: 4,
            hidden: 256,
            heads: 8,
            attention: Attention::Gqa(2),
            ffn: 1024,
            vocab: 512,
            elem_bytes: 4, // live path runs f32 on CPU
        }
    }

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name {
            "llama-30b" | "Llama-30B" => Some(Self::llama_30b()),
            "codellama-34b" | "CodeLlama2-34B" => Some(Self::codellama_34b()),
            "qwen2-72b" | "Qwen2-72B" => Some(Self::qwen2_72b()),
            "tinylm" | "TinyLM" => Some(Self::tinylm()),
            _ => None,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    pub fn kv_heads(&self) -> usize {
        match self.attention {
            Attention::Mha => self.heads,
            Attention::Gqa(k) => k,
        }
    }

    /// Total parameter count (weights only; embeddings included once).
    pub fn param_count(&self) -> f64 {
        let h = self.hidden as f64;
        let kv = (self.kv_heads() * self.head_dim()) as f64;
        let per_layer = h * (h + 2.0 * kv)        // QKV projection
            + h * h                               // output projection
            + 3.0 * h * self.ffn as f64;          // SwiGLU gate/up/down
        self.layers as f64 * per_layer + 2.0 * h * self.vocab as f64
    }

    /// Weight bytes (per full model, before TP sharding).
    pub fn weight_bytes(&self) -> f64 {
        self.param_count() * self.elem_bytes as f64
    }

    /// KV-cache bytes for one token (K and V, all layers) — the paper's
    /// 2 · L · Hkv · D · elem_bytes.
    pub fn kv_bytes_per_token(&self) -> f64 {
        (2 * self.layers * self.kv_heads() * self.head_dim() * self.elem_bytes) as f64
    }

    /// FLOPs to prefill a prompt of `s` tokens (dense causal attention):
    /// 2·params per token for the matmuls + 4·s²·H·L/2 ≈ 2·s²·H·L for
    /// score+value attention (causal halves it).
    pub fn prefill_flops(&self, s: usize) -> f64 {
        let s = s as f64;
        let linear = 2.0 * self.param_count() * s;
        let attn = 2.0 * s * s * self.hidden as f64 * self.layers as f64;
        linear + attn
    }

    /// FLOPs for one decode step of one request with `context` tokens in
    /// cache: 2·params + 4·context·H·L for attention.
    pub fn decode_flops(&self, context: usize) -> f64 {
        2.0 * self.param_count()
            + 4.0 * context as f64 * self.hidden as f64 * self.layers as f64
    }

    /// HBM bytes moved for a prefill of `s` tokens: weights once + KV write
    /// + activations (approximated as 12·s·H·L elements).
    pub fn prefill_bytes(&self, s: usize) -> f64 {
        let act = 12.0 * s as f64 * self.hidden as f64 * self.layers as f64
            * self.elem_bytes as f64;
        self.weight_bytes() + self.kv_bytes_per_token() * s as f64 + act
    }

    /// HBM bytes for one decode iteration of a batch: weights once, plus
    /// each request's KV cache read + written token.
    pub fn decode_iter_bytes(&self, batch: usize, total_context: usize) -> f64 {
        let act = 12.0 * batch as f64 * self.hidden as f64 * self.layers as f64
            * self.elem_bytes as f64;
        self.weight_bytes() + self.kv_bytes_per_token() * total_context as f64 + act
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama30b_kv_matches_paper() {
        // Paper §2.1: "in Llama-30B, the KV cache for a single token
        // requires 1.52 MB".
        let m = ModelSpec::llama_30b();
        let mib = m.kv_bytes_per_token() / (1024.0 * 1024.0);
        assert!((mib - 1.52).abs() < 0.01, "got {mib} MiB");
    }

    #[test]
    fn codellama_kv_matches_table3_ratio() {
        // Architecture: 2 (K+V) * 48 layers * 8 KV heads * 128 head-dim * 2
        // bytes = 192 KiB/token. Table 3's implied 1.25e9 / 6838.9 tok/s =
        // 178.5 KiB is within 8% (the paper's rate includes sampling gaps).
        let m = ModelSpec::codellama_34b();
        let kib = m.kv_bytes_per_token() / 1024.0;
        assert!((kib - 192.0).abs() < 0.1, "got {kib} KiB");
        let paper_implied = 1.25e9 / 6838.92 / 1024.0;
        assert!((kib - paper_implied).abs() / paper_implied < 0.1);
    }

    #[test]
    fn param_counts_roughly_right() {
        let l = ModelSpec::llama_30b().param_count() / 1e9;
        assert!((30.0..36.0).contains(&l), "llama {l}B");
        let c = ModelSpec::codellama_34b().param_count() / 1e9;
        assert!((31.0..37.0).contains(&c), "codellama {c}B");
        let q = ModelSpec::qwen2_72b().param_count() / 1e9;
        assert!((65.0..78.0).contains(&q), "qwen {q}B");
    }

    #[test]
    fn gqa_shrinks_kv_only() {
        let mha = ModelSpec::llama_30b();
        let gqa = ModelSpec::codellama_34b();
        // GQA model is bigger in params yet much smaller in KV per token.
        assert!(gqa.param_count() > 0.9 * mha.param_count());
        assert!(gqa.kv_bytes_per_token() < mha.kv_bytes_per_token() / 4.0);
    }

    #[test]
    fn prefill_flops_superlinear_in_s() {
        let m = ModelSpec::llama_30b();
        let f1 = m.prefill_flops(1024);
        let f2 = m.prefill_flops(2048);
        assert!(f2 > 2.0 * f1); // attention term is quadratic
    }

    #[test]
    fn decode_flops_grow_with_context() {
        let m = ModelSpec::codellama_34b();
        assert!(m.decode_flops(4096) > m.decode_flops(16));
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(ModelSpec::by_name("llama-30b").unwrap().name, "Llama-30B");
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }
}
