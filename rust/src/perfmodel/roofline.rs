//! Roofline batch-duration model: the single source of truth for how long
//! a prefill batch / decode iteration / hybrid (Sarathi) batch takes on a
//! given (model, GPU, parallelism) triple.
//!
//! time = max(FLOPs / effective-FLOP/s, bytes / effective-bandwidth)
//!        + TP communication + PP hand-off + fixed kernel-launch overhead
//!
//! Per Table 2 the prefill phase lands on the compute roof and the decode
//! phase on the memory roof; the max() reproduces that without hand-coding
//! the regime per phase (asserted in tests below).

use super::llm::ModelSpec;
use super::parallelism::ParallelCfg;
use super::GpuSpec;

/// Which phase a batch belongs to (paper Table 2's P/D column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// Fixed per-iteration overhead (kernel launches, python-free scheduling,
/// sampler). Measured values for vLLM-class systems are 1–3 ms on CUDA.
pub const ITER_OVERHEAD_S: f64 = 1.5e-3;

/// Fraction of TP all-reduce time hidden under prefill compute. Prefill's
/// large matmuls let frameworks overlap collectives with the next layer's
/// GEMMs; decode's small kernels cannot (which is why the paper measures
/// comm as ~half of decode execution on PCIe — validated in
/// rust/tests/perfmodel_validation.rs). Calibrated so Table 3's measured
/// prefill rates reproduce within ~15%.
pub const PREFILL_COMM_OVERLAP: f64 = 0.8;

/// Hybrid (Sarathi) iterations overlap partially: the fused chunk+decode
/// batch launches larger kernels than pure decode but smaller than pure
/// prefill.
pub const HYBRID_COMM_OVERLAP: f64 = 0.5;

/// Batch-duration calculator for one inference instance.
#[derive(Debug, Clone)]
pub struct BatchTimer {
    pub model: ModelSpec,
    pub gpu: GpuSpec,
    pub par: ParallelCfg,
}

impl BatchTimer {
    pub fn new(model: ModelSpec, gpu: GpuSpec, par: ParallelCfg) -> Self {
        BatchTimer { model, gpu, par }
    }

    /// Number of GPUs this instance occupies.
    pub fn gpus(&self) -> usize {
        self.par.gpus()
    }

    fn roofline(&self, flops: f64, bytes: f64) -> f64 {
        // Only TP shortens a single batch's latency. PP shards layers
        // across stages, but one batch still traverses every stage
        // sequentially — summed over stages the work is the full model's,
        // executed tp-wide (paper §2.3: "PP does not improve the latency of
        // a single batch"). PP's throughput benefit comes from interleaving
        // sub-batches, modeled in sim::instance (and its memory benefit via
        // kv_capacity_tokens, which uses all tp×pp GPUs).
        let shards = self.par.tp as f64;
        let t_compute = flops / (self.gpu.eff_flops() * shards);
        let t_memory = bytes / (self.gpu.eff_bw() * shards);
        t_compute.max(t_memory)
    }

    /// Duration of a prefill batch over prompts of the given lengths
    /// (separate batching: prefill-only batch, paper §2.2).
    pub fn prefill_time(&self, seq_lens: &[usize]) -> f64 {
        if seq_lens.is_empty() {
            return 0.0;
        }
        let total_tokens: usize = seq_lens.iter().sum();
        let flops: f64 = seq_lens.iter().map(|&s| self.model.prefill_flops(s)).sum();
        // Weights stream once per batch; per-prompt KV writes + activations.
        let bytes: f64 = self.model.weight_bytes()
            + seq_lens
                .iter()
                .map(|&s| self.model.prefill_bytes(s) - self.model.weight_bytes())
                .sum::<f64>();
        self.roofline(flops, bytes)
            + self.par.tp_comm_time(&self.model, total_tokens) * (1.0 - PREFILL_COMM_OVERLAP)
            + self.par.pp_comm_time(&self.model, total_tokens)
            + ITER_OVERHEAD_S
    }

    /// Duration of one decode iteration for a batch of `batch` requests
    /// whose cached contexts sum to `total_context` tokens.
    pub fn decode_iter_time(&self, batch: usize, total_context: usize) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let flops: f64 = batch as f64 * 2.0 * self.model.param_count()
            + 4.0 * total_context as f64 * self.model.hidden as f64
                * self.model.layers as f64;
        let bytes = self.model.decode_iter_bytes(batch, total_context);
        self.roofline(flops, bytes)
            + self.par.tp_comm_time(&self.model, batch)
            + self.par.pp_comm_time(&self.model, batch)
            + ITER_OVERHEAD_S
    }

    /// Duration of a Sarathi-style hybrid iteration: `decode_batch` decode
    /// tokens (context sum `decode_context`) plus `chunk_tokens` of prefill
    /// work whose attention spans `chunk_context` cached tokens (chunked
    /// prefill re-reads the prompt KV produced by earlier chunks — the
    /// "repeated KV cache access" overhead of paper §2.4.1).
    pub fn hybrid_iter_time(
        &self,
        decode_batch: usize,
        decode_context: usize,
        chunk_tokens: usize,
        chunk_context: usize,
    ) -> f64 {
        if decode_batch == 0 && chunk_tokens == 0 {
            return 0.0;
        }
        let m = &self.model;
        // Component decomposition (a single global roofline would let the
        // chunk's GEMMs hide the decode KV reads and vice versa, which the
        // per-layer kernel sequence does not permit):
        //  (1) linear layers — genuinely fused: decode + chunk tokens share
        //      one weight stream (the real hybrid-batching win);
        //  (2) decode attention — memory-bound paged KV reads;
        //  (3) chunk attention — compute over the growing prompt context,
        //      re-reading the KV earlier chunks produced (the §2.4.1
        //      chunked-prefill overhead).
        let tokens = decode_batch + chunk_tokens;
        let act = 12.0 * tokens as f64 * m.hidden as f64 * m.layers as f64
            * m.elem_bytes as f64;
        let linear = self.roofline(
            2.0 * m.param_count() * tokens as f64,
            m.weight_bytes() + act,
        );
        let dec_attn = self.roofline(
            4.0 * decode_context as f64 * m.hidden as f64 * m.layers as f64,
            m.kv_bytes_per_token() * decode_context as f64,
        );
        let chunk_attn = if chunk_tokens > 0 {
            self.roofline(
                4.0 * chunk_tokens as f64 * chunk_context as f64 * m.hidden as f64
                    * m.layers as f64
                    / 2.0,
                m.kv_bytes_per_token() * chunk_context as f64,
            )
        } else {
            0.0
        };
        // Hybrid batches hide part of the all-reduce *bandwidth* under the
        // chunk's GEMMs, but the per-hop latency serializes with kernel
        // boundaries exactly as in pure decode.
        let (comm_bw, comm_lat) = self.par.tp_comm_parts(m, tokens);
        linear
            + dec_attn
            + chunk_attn
            + comm_bw * (1.0 - HYBRID_COMM_OVERLAP)
            + comm_lat
            + self.par.pp_comm_time(m, tokens)
            + ITER_OVERHEAD_S
    }

    /// Steady-state prefill throughput (tokens/s) at prompt length `s`,
    /// batch size 1 — the quantity behind the paper's Table 3.
    pub fn prefill_tokens_per_sec(&self, s: usize) -> f64 {
        s as f64 / self.prefill_time(&[s])
    }

    /// KV-cache capacity (tokens) of this instance: memory left after
    /// weights, divided by per-token KV. `reserve_frac` holds back room for
    /// activations/fragmentation (vLLM's gpu_memory_utilization analogue).
    pub fn kv_capacity_tokens(&self, reserve_frac: f64) -> usize {
        let total = self.gpu.mem_bytes * self.gpus() as f64;
        let avail = (total * (1.0 - reserve_frac) - self.model.weight_bytes()).max(0.0);
        (avail / self.model.kv_bytes_per_token()) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::interconnect::LinkSpec;

    fn timer(tp: usize) -> BatchTimer {
        BatchTimer::new(
            ModelSpec::llama_30b(),
            GpuSpec::l20(),
            ParallelCfg::tp_only(tp, LinkSpec::pcie4()),
        )
    }

    #[test]
    fn prefill_is_compute_bound_decode_memory_bound() {
        let t = timer(4);
        let m = &t.model;
        // Prefill at S=512: flops/bytes ratio far above the machine balance.
        let s = 512;
        let ai = m.prefill_flops(s) / m.prefill_bytes(s);
        let balance = t.gpu.eff_flops() / t.gpu.eff_bw();
        assert!(ai > balance, "prefill AI {ai} vs balance {balance}");
        // Decode at B=32: below machine balance.
        let ai_d = (32.0 * 2.0 * m.param_count()) / m.decode_iter_bytes(32, 32 * 512);
        assert!(ai_d < balance, "decode AI {ai_d} vs balance {balance}");
    }

    #[test]
    fn decode_iter_in_tens_of_ms() {
        // Llama-30B TP=4 on L20, batch 64 with 300-token contexts: the
        // decode iteration should land in the 10–100 ms band the paper's
        // 100 ms TPOT SLO implies.
        let t = timer(4);
        let d = t.decode_iter_time(64, 64 * 300);
        assert!(d > 0.01 && d < 0.1, "decode iter {d}s");
    }

    #[test]
    fn prefill_time_grows_with_length() {
        let t = timer(4);
        assert!(t.prefill_time(&[2048]) > t.prefill_time(&[256]));
        let batch = t.prefill_time(&[256, 256, 256, 256]);
        let single = t.prefill_time(&[256]);
        // Batched prefill amortizes weight streaming but adds flops.
        assert!(batch > single && batch < 4.5 * single);
    }

    #[test]
    fn bigger_batch_decodes_more_efficiently() {
        let t = timer(4);
        let per_tok_small = t.decode_iter_time(8, 8 * 300) / 8.0;
        let per_tok_big = t.decode_iter_time(128, 128 * 300) / 128.0;
        assert!(per_tok_big < per_tok_small / 2.0);
    }

    #[test]
    fn hybrid_iter_between_pure_costs() {
        let t = timer(4);
        let pure_decode = t.decode_iter_time(32, 32 * 200);
        let hybrid = t.hybrid_iter_time(32, 32 * 200, 256, 256);
        assert!(hybrid > pure_decode);
    }

    #[test]
    fn kv_capacity_positive_and_scales_with_tp() {
        let t2 = BatchTimer::new(
            ModelSpec::llama_30b(),
            GpuSpec::l20(),
            ParallelCfg::tp_only(2, LinkSpec::pcie4()),
        );
        let t4 = timer(4);
        let c2 = t2.kv_capacity_tokens(0.1);
        let c4 = t4.kv_capacity_tokens(0.1);
        assert!(c2 > 0);
        assert!(c4 > c2, "more GPUs, more KV room: {c4} vs {c2}");
    }

    #[test]
    fn tp_overhead_significant_on_pcie() {
        // Paper §2.3 case study: Llama-30B TP=4 over PCIe — comm is a large
        // fraction (they report ~half) of execution time for decode.
        let t = timer(4);
        let comm = t.par.tp_comm_time(&t.model, 32);
        let total = t.decode_iter_time(32, 32 * 300);
        let frac = comm / total;
        assert!(frac > 0.2, "comm fraction {frac}");
    }
}
