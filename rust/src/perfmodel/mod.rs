//! Analytical GPU performance model (substrate for the paper's testbed,
//! which we do not have — see DESIGN.md §2).
//!
//! Everything the schedulers observe about hardware — prefill/decode batch
//! durations, KV-cache sizes, transfer times, TP/PP communication costs —
//! is produced here from first principles: the arithmetic-intensity
//! formulas of the paper's **Table 2**, a roofline over device specs
//! (**§2.1**), and the interconnect arithmetic of **Table 3**.
//!
//! Calibration: two scalar efficiency factors per GPU (achievable fraction
//! of peak FLOPs for compute-bound phases, achievable fraction of peak HBM
//! bandwidth for memory-bound phases) are set so the model reproduces the
//! paper's Table 3 throughput numbers within a few percent (validated in
//! `rust/tests/perfmodel_validation.rs`).

pub mod gpu;
pub mod interconnect;
pub mod llm;
pub mod parallelism;
pub mod roofline;

pub use gpu::GpuSpec;
pub use interconnect::LinkSpec;
pub use llm::ModelSpec;
pub use roofline::{BatchTimer, Phase};

/// One row of the paper's Table 2: FLOPs, memory traffic and arithmetic
/// intensity of a primary LLM operation, per phase.
#[derive(Debug, Clone, PartialEq)]
pub struct OpCost {
    pub name: &'static str,
    pub phase: Phase,
    pub flops: f64,
    pub bytes: f64,
}

impl OpCost {
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops / self.bytes
    }
}

/// Reproduce the paper's Table 2 for hyper-parameters (B, S, H, M, D) —
/// element counts; `bytes` assumes `elem_bytes` per element (2 for bf16).
///
/// The six ops are QKV projection, attention QK^T, attention (QK^T)V,
/// output projection, FFN dim expansion, FFN dim reduction; each appears
/// in a prefill and a decode variant. Negligible terms (softmax, layernorm,
/// 1/H factors) are omitted exactly as the paper does.
pub fn table2_ops(b: f64, s: f64, h: f64, m: f64, elem_bytes: f64) -> Vec<OpCost> {
    let e = elem_bytes;
    vec![
        OpCost {
            name: "QKV Projection",
            phase: Phase::Prefill,
            flops: 6.0 * b * s * h * h,
            bytes: (6.0 * b * s * h + 3.0 * h * h) * e,
        },
        OpCost {
            name: "QKV Projection",
            phase: Phase::Decode,
            flops: 6.0 * b * h * h,
            bytes: (6.0 * b * h + 3.0 * h * h) * e,
        },
        OpCost {
            name: "Attention QK^T",
            phase: Phase::Prefill,
            flops: 2.0 * b * s * s * h,
            bytes: (2.0 * b * s * h + b * s * s * m) * e,
        },
        OpCost {
            name: "Attention QK^T",
            phase: Phase::Decode,
            flops: 2.0 * b * s * h,
            bytes: (2.0 * b * s * m + b * h * (s + 1.0)) * e,
        },
        OpCost {
            name: "Attention (QK^T)V",
            phase: Phase::Prefill,
            flops: 2.0 * b * s * s * h,
            bytes: (2.0 * b * s * h + b * s * s * m) * e,
        },
        OpCost {
            name: "Attention (QK^T)V",
            phase: Phase::Decode,
            flops: 2.0 * b * s * h,
            bytes: (2.0 * b * s * m + b * h * (s + 1.0)) * e,
        },
        OpCost {
            name: "Output Projection",
            phase: Phase::Prefill,
            flops: 2.0 * b * s * h * h,
            bytes: (2.0 * b * s * h + h * h) * e,
        },
        OpCost {
            name: "Output Projection",
            phase: Phase::Decode,
            flops: 2.0 * b * h * h,
            bytes: (2.0 * b * h + h * h) * e,
        },
        OpCost {
            name: "Dim Expansion",
            phase: Phase::Prefill,
            flops: 8.0 * b * s * h * h,
            bytes: (2.0 * b * s * h + 4.0 * h * h) * e,
        },
        OpCost {
            name: "Dim Expansion",
            phase: Phase::Decode,
            flops: 8.0 * b * h * h,
            bytes: (2.0 * b * h + 4.0 * h * h) * e,
        },
        OpCost {
            name: "Dim Reduction",
            phase: Phase::Prefill,
            flops: 8.0 * b * s * h * h,
            bytes: (2.0 * b * s * h + 4.0 * h * h) * e,
        },
        OpCost {
            name: "Dim Reduction",
            phase: Phase::Decode,
            flops: 8.0 * b * h * h,
            bytes: (2.0 * b * h + 4.0 * h * h) * e,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's approximate-AI column: prefill projections ~ Θ(BS),
    /// decode projections ~ Θ(B), prefill attention ~ Θ(S), decode
    /// attention ~ Θ(1). (The exact limit of the Table 2 formulas is 2·BS
    /// etc. as H → ∞; the paper's column is order-of notation.)
    #[test]
    fn table2_approximate_ai_matches_paper() {
        let (b, s, h, m) = (2.0, 64.0, 8192.0, 64.0);
        let ops = table2_ops(b, s, h, m, 1.0); // elem_bytes=1: AI in elements
        let find = |name: &str, phase: Phase| {
            ops.iter()
                .find(|o| o.name == name && o.phase == phase)
                .unwrap()
                .arithmetic_intensity()
        };
        // Projections: Θ(BS) prefill, Θ(B) decode (asymptote 2·BS / 2·B).
        let ai = find("QKV Projection", Phase::Prefill);
        assert!(ai > 0.5 * b * s && ai <= 2.5 * b * s, "{ai}");
        let ai = find("QKV Projection", Phase::Decode);
        assert!(ai > 0.5 * b && ai <= 2.5 * b, "{ai}");
        // Attention: Θ(S) prefill, Θ(1) decode.
        let ai = find("Attention QK^T", Phase::Prefill);
        assert!(ai <= s && ai > s / 20.0, "{ai}");
        let ai = find("Attention QK^T", Phase::Decode);
        assert!(ai < 2.5, "{ai}");
        // Scaling check: doubling B doubles projection AI in this regime.
        let ops2 = table2_ops(2.0 * b, s, h, m, 1.0);
        let ai1 = find("QKV Projection", Phase::Prefill);
        let ai2 = ops2
            .iter()
            .find(|o| o.name == "QKV Projection" && o.phase == Phase::Prefill)
            .unwrap()
            .arithmetic_intensity();
        assert!((ai2 / ai1 - 2.0).abs() < 0.2, "{ai2} / {ai1}");
    }

    #[test]
    fn prefill_ai_dominates_decode() {
        let ops = table2_ops(16.0, 256.0, 4096.0, 32.0, 2.0);
        for name in [
            "QKV Projection",
            "Attention QK^T",
            "Output Projection",
            "Dim Expansion",
            "Dim Reduction",
        ] {
            let p = ops.iter().find(|o| o.name == name && o.phase == Phase::Prefill).unwrap();
            let d = ops.iter().find(|o| o.name == name && o.phase == Phase::Decode).unwrap();
            assert!(
                p.arithmetic_intensity() > d.arithmetic_intensity(),
                "{name}: prefill AI should exceed decode AI"
            );
        }
    }
}
