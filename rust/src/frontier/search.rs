//! The one rate-search implementation: exponential bracketing followed by
//! bisection, generic over the probe (paper §4.1's "incrementally
//! increasing the request rate until the system fails to reach the
//! attainment"; DistServe arXiv:2401.09670 calls the same procedure the
//! goodput frontier).
//!
//! Both consumers go through here so their semantics cannot drift:
//! * [`crate::harness::goodput_search`] probes fixed-rate Poisson traces
//!   (the paper's Figure-8 setting);
//! * [`crate::frontier::driver`] probes whole scenarios — multi-class
//!   traces with bursty/diurnal/ramp load shapes — and scores strict
//!   per-class attainment, optionally with mitosis autoscaling on.
//!
//! Every probe is recorded, so a search yields not just the max
//! sustainable rate but the sampled rate→attainment curve that
//! `BENCH_goodput.json` ships to CI.

/// One probed operating point on the rate→attainment curve.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchPoint {
    /// Offered time-averaged rate, req/s.
    pub rate: f64,
    /// Score the probe reported at this rate (strict attainment).
    pub attainment: f64,
    /// Delivered SLO-meeting completions per second at this rate.
    pub goodput_rps: f64,
}

/// What a probe hands back: an opaque payload plus the two scores the
/// search needs. The payload at the found rate is returned untouched.
#[derive(Debug)]
pub struct Probe<R> {
    pub result: R,
    pub attainment: f64,
    pub goodput_rps: f64,
}

/// Search knobs. `target` is the attainment fraction a rate must reach to
/// count as sustained; the bracket runs `start, 2·start, …` capped at
/// `ceiling`, with a final `floor` "crumb" probe when even `start` fails.
#[derive(Debug, Clone)]
pub struct SearchParams {
    pub target: f64,
    pub floor: f64,
    pub start: f64,
    pub ceiling: f64,
    /// Max doubling steps in the bracket phase.
    pub max_doublings: usize,
    /// Bisection refinement steps after bracketing.
    pub bisections: usize,
    /// Wall-clock budget for the whole search, seconds (`--budget-s`).
    /// Checked between probes — the first probe always runs, so a search
    /// always has an answer. Truncation only forgoes *refinement*: the
    /// reported max rate is whatever the probes already confirmed, so a
    /// bigger budget can never report a lower rate on a monotone probe.
    pub budget_s: Option<f64>,
}

impl SearchParams {
    /// The harness's historical settings (Figure 8): bracket from 0.5
    /// req/s, crumb at 0.1, 12 doublings, 6 bisections.
    pub fn paper_default(target: f64) -> Self {
        SearchParams {
            target,
            floor: 0.1,
            start: 0.5,
            ceiling: 2048.0,
            max_doublings: 12,
            bisections: 6,
            budget_s: None,
        }
    }

    /// Coarse, wall-clock-bounded settings for CI smoke runs.
    pub fn quick(mut self) -> Self {
        self.max_doublings = self.max_doublings.min(6);
        self.bisections = self.bisections.min(3);
        self
    }
}

/// Search outcome: the max sustained rate, the probe payload there, and
/// the full sampled curve (sorted by rate).
#[derive(Debug)]
pub struct SearchOutcome<R> {
    /// Max rate meeting `target` attainment (0.0 when even `floor` fails).
    pub max_rate: f64,
    /// Probe payload at `max_rate` (`None` when nothing sustained).
    pub best: Option<R>,
    /// Probed points sorted by rate — the attainment curve. Equal-rate
    /// re-probes (a bisection mid landing on the floor) are collapsed, so
    /// rates are strictly increasing.
    pub curve: Vec<SearchPoint>,
    /// Number of probes spent (>= `curve.len()`; equal only when no rate
    /// was probed twice).
    pub probes: usize,
    /// True when the search stopped while the top probe still sustained
    /// the target (ceiling hit or doubling budget exhausted): `max_rate`
    /// is then a lower bound set by the bracket, not the system.
    pub saturated: bool,
    /// True when the wall-clock budget (`SearchParams::budget_s`) cut the
    /// search short: `max_rate` is confirmed but unrefined (bisections
    /// and/or bracket steps were skipped).
    pub truncated: bool,
}

/// Find the maximum rate at which `probe` reports at least
/// `params.target` attainment. Monotonicity is assumed statistically, not
/// structurally: a non-monotone probe simply lands the search on *a*
/// sustained rate inside the final bracket.
pub fn rate_search<R>(
    params: &SearchParams,
    mut probe: impl FnMut(f64) -> Probe<R>,
) -> SearchOutcome<R> {
    fn finish<R>(
        max_rate: f64,
        best: Option<R>,
        mut curve: Vec<SearchPoint>,
        saturated: bool,
        truncated: bool,
    ) -> SearchOutcome<R> {
        curve.sort_by(|a, b| {
            a.rate.partial_cmp(&b.rate).unwrap_or(std::cmp::Ordering::Equal)
        });
        let probes = curve.len();
        // A bisection mid can land exactly on the already-probed floor
        // (e.g. floor = start/4 bit-exactly); probes are deterministic, so
        // collapsing equal-rate samples loses nothing and keeps the curve
        // strictly increasing.
        curve.dedup_by(|a, b| a.rate == b.rate);
        SearchOutcome { max_rate, best, curve, probes, saturated, truncated }
    }

    let wall_start = std::time::Instant::now();
    let over_budget = || params.budget_s.is_some_and(|b| wall_start.elapsed().as_secs_f64() >= b);

    let mut curve: Vec<SearchPoint> = Vec::new();
    let mut sample = |rate: f64, curve: &mut Vec<SearchPoint>| {
        let p = probe(rate);
        curve.push(SearchPoint {
            rate,
            attainment: p.attainment,
            goodput_rps: p.goodput_rps,
        });
        p
    };
    let meets = |p: &Probe<R>| p.attainment >= params.target - 1e-12;

    // Exponential bracket: double until the target breaks, the ceiling
    // caps the climb, or the doubling budget runs out. In the latter two
    // cases the top probe still sustains the target, so `hi` is a lower
    // bound on capacity and the result is flagged saturated — treating
    // it as the failing bisection bound would under-report max rate.
    let mut lo = 0.0;
    let mut lo_probe: Option<Probe<R>> = None;
    let mut hi = params.start.max(params.floor).min(params.ceiling);
    let mut hi_probe = sample(hi, &mut curve);
    let mut guard = 0;
    while meets(&hi_probe) {
        if hi >= params.ceiling || guard >= params.max_doublings {
            return finish(hi, Some(hi_probe.result), curve, true, false);
        }
        if over_budget() {
            // The top probe still sustains the target, so `hi` is a
            // confirmed (bracket-limited) lower bound — report it rather
            // than bisecting down from an unconfirmed rate.
            return finish(hi, Some(hi_probe.result), curve, true, true);
        }
        lo = hi;
        lo_probe = Some(hi_probe);
        hi = (hi * 2.0).min(params.ceiling);
        hi_probe = sample(hi, &mut curve);
        guard += 1;
    }
    let mut truncated = false;
    if lo == 0.0 && !meets(&hi_probe) && params.floor < hi {
        if over_budget() {
            truncated = true;
        } else {
            // Cannot sustain even the first probe: try a crumb, else zero.
            let crumb = sample(params.floor, &mut curve);
            if meets(&crumb) {
                lo = params.floor;
                lo_probe = Some(crumb);
            }
        }
    }

    // Bisect [lo, hi].
    for _ in 0..params.bisections {
        if hi - lo < 1e-9 {
            break;
        }
        if over_budget() {
            truncated = true;
            break;
        }
        let mid = 0.5 * (lo + hi);
        if mid <= 0.0 {
            break;
        }
        let p = sample(mid, &mut curve);
        if meets(&p) {
            lo = mid;
            lo_probe = Some(p);
        } else {
            hi = mid;
        }
    }
    finish(lo, lo_probe.map(|p| p.result), curve, false, truncated)
}

/// How many probes a speculative search launches per batch: the rate the
/// serial search asked for plus up to two lookahead candidates.
pub const SPECULATION_WIDTH: usize = 3;

/// [`rate_search`] with speculative probe execution: identical control
/// flow (it *wraps* the serial search — there is still exactly one
/// rate-search implementation), but each time the search asks for an
/// unseen rate, the next 1–2 candidate rates it could ask for — known in
/// advance because bracket/crumb/bisection steps are predictable from
/// the probe verdicts so far — are launched concurrently on the
/// [`crate::util::threads::parallel_map`] pool and cached. When the
/// serial search then asks for one of them, the cached result is
/// consumed instead of re-probing; mispredicted candidates are simply
/// discarded.
///
/// The outcome is **bit-identical to the serial search by construction**
/// (same `max_rate`, same curve, same flags; locked by tests here and by
/// `tests/speculative_equivalence.rs`): the serial search never sees the
/// speculation, it just gets its deterministic probe results faster. The
/// only caveat is `params.budget_s` — wall-clock truncation points
/// depend on timing in both modes, so exact equivalence is only
/// guaranteed for budget-free searches. Requires a deterministic,
/// thread-safe probe; `workers <= 1` degenerates to the serial search.
pub fn rate_search_speculative<R: Send>(
    params: &SearchParams,
    probe: impl Fn(f64) -> Probe<R> + Sync,
    workers: usize,
) -> SearchOutcome<R> {
    use std::collections::HashMap;

    if workers <= 1 {
        return rate_search(params, &probe);
    }
    // Keyed by bit pattern: speculated rates must match the serial
    // search's future requests *exactly*, not within an epsilon.
    let mut cache: HashMap<u64, Probe<R>> = HashMap::new();
    let mut shadow = Shadow::new(params);
    rate_search(params, |rate| {
        let p = match cache.remove(&rate.to_bits()) {
            Some(hit) => hit,
            None => {
                let mut batch = vec![rate];
                for c in shadow.lookahead(rate).into_iter().flatten() {
                    if batch.len() >= workers {
                        break;
                    }
                    if c.is_finite()
                        && c > 0.0
                        && !cache.contains_key(&c.to_bits())
                        && !batch.contains(&c)
                    {
                        batch.push(c);
                    }
                }
                if batch.len() == 1 {
                    probe(rate)
                } else {
                    let rates = batch.clone();
                    let mut results =
                        crate::util::threads::parallel_map(batch, workers, &probe);
                    let wanted = results.remove(0);
                    for (r, speculated) in rates[1..].iter().zip(results) {
                        cache.insert(r.to_bits(), speculated);
                    }
                    wanted
                }
            }
        };
        shadow.observe(rate, p.attainment);
        p
    })
}

/// Which step of [`rate_search`] the [`Shadow`] believes is next.
enum ShadowPhase {
    Bracket,
    Crumb,
    Bisect,
    Done,
}

/// A shadow of [`rate_search`]'s control flow, advanced probe by probe,
/// so [`rate_search_speculative`] can guess the serial search's next
/// rate(s) before the current probe's verdict is known. Pure lookahead:
/// a wrong guess wastes one discarded probe and can never change the
/// search outcome, so this does not need to model budget truncation or
/// degenerate-interval exits — only the rate arithmetic, which mirrors
/// the serial implementation line for line.
struct Shadow {
    target: f64,
    floor: f64,
    ceiling: f64,
    max_doublings: usize,
    bisections_left: usize,
    lo: f64,
    hi: f64,
    guard: usize,
    phase: ShadowPhase,
}

impl Shadow {
    fn new(params: &SearchParams) -> Shadow {
        Shadow {
            target: params.target,
            floor: params.floor,
            ceiling: params.ceiling,
            max_doublings: params.max_doublings,
            bisections_left: params.bisections,
            lo: 0.0,
            hi: params.start.max(params.floor).min(params.ceiling),
            guard: 0,
            phase: ShadowPhase::Bracket,
        }
    }

    /// Rates the serial search may ask for right after probing `rate`,
    /// best guess first (at most 2; [`rate_search_speculative`] caps the
    /// batch at its worker count).
    fn lookahead(&self, rate: f64) -> [Option<f64>; 2] {
        match self.phase {
            ShadowPhase::Bracket => {
                // Sustained → the bracket doubles (unless capped)…
                let up = if rate < self.ceiling && self.guard < self.max_doublings {
                    Some((rate * 2.0).min(self.ceiling))
                } else {
                    None
                };
                // …failed → the crumb probe, or the first bisection mid.
                let down = if self.lo == 0.0 && self.floor < rate {
                    Some(self.floor)
                } else if self.bisections_left > 0 {
                    Some(0.5 * (self.lo + rate))
                } else {
                    None
                };
                [up, down]
            }
            ShadowPhase::Crumb => {
                // Crumb sustained → bisect [floor, hi]; failed → [0, hi].
                if self.bisections_left > 0 {
                    [Some(0.5 * (self.floor + self.hi)), Some(0.5 * self.hi)]
                } else {
                    [None, None]
                }
            }
            ShadowPhase::Bisect => {
                // `rate` is the current mid: the next mid is the midpoint
                // of whichever half-interval the verdict selects.
                if self.bisections_left > 1 {
                    [Some(0.5 * (rate + self.hi)), Some(0.5 * (self.lo + rate))]
                } else {
                    [None, None]
                }
            }
            ShadowPhase::Done => [None, None],
        }
    }

    /// Advance the shadow past a probe the serial search consumed.
    fn observe(&mut self, rate: f64, attainment: f64) {
        let meets = attainment >= self.target - 1e-12;
        match self.phase {
            ShadowPhase::Bracket => {
                if meets {
                    if rate >= self.ceiling || self.guard >= self.max_doublings {
                        self.phase = ShadowPhase::Done;
                    } else {
                        self.lo = rate;
                        self.hi = (rate * 2.0).min(self.ceiling);
                        self.guard += 1;
                    }
                } else {
                    self.hi = rate;
                    if self.lo == 0.0 && self.floor < rate {
                        self.phase = ShadowPhase::Crumb;
                    } else {
                        self.phase = ShadowPhase::Bisect;
                    }
                }
            }
            ShadowPhase::Crumb => {
                if meets {
                    self.lo = self.floor;
                }
                self.phase = ShadowPhase::Bisect;
            }
            ShadowPhase::Bisect => {
                if meets {
                    self.lo = rate;
                } else {
                    self.hi = rate;
                }
                self.bisections_left = self.bisections_left.saturating_sub(1);
            }
            ShadowPhase::Done => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A sharp synthetic capacity cliff at `cap` req/s.
    fn cliff(cap: f64) -> impl FnMut(f64) -> Probe<f64> {
        move |rate| Probe {
            result: rate,
            attainment: if rate <= cap { 1.0 } else { 0.0 },
            goodput_rps: rate.min(cap),
        }
    }

    #[test]
    fn converges_to_the_cliff() {
        let params = SearchParams::paper_default(0.9);
        let out = rate_search(&params, cliff(7.3));
        assert!(out.max_rate > 6.0 && out.max_rate <= 7.3, "{}", out.max_rate);
        assert_eq!(out.best, Some(out.max_rate));
        assert!(!out.saturated, "a real cliff is not bracket-limited");
        assert_eq!(out.probes, out.curve.len());
        for w in out.curve.windows(2) {
            assert!(w[0].rate < w[1].rate, "curve must be rate-sorted");
        }
    }

    #[test]
    fn hopeless_probe_returns_zero() {
        // A system that sustains nothing at any rate.
        let params = SearchParams::paper_default(0.9);
        let out = rate_search(&params, cliff(0.0));
        assert_eq!(out.max_rate, 0.0);
        assert!(out.best.is_none());
        // start + crumb + bisections worth of probes, all recorded.
        assert!(out.probes >= 2);
    }

    #[test]
    fn curve_collapses_equal_rate_reprobes() {
        // floor = start/4 bit-exactly (the registry SweepBounds shape):
        // for a hopeless probe, bisection of [0, start] revisits the floor
        // (0.5 -> 0.25 -> 0.125), which must not produce a duplicate
        // curve point.
        let mut params = SearchParams::paper_default(0.9);
        params.floor = 0.125;
        params.start = 0.5;
        let out = rate_search(&params, cliff(0.0));
        assert_eq!(out.max_rate, 0.0);
        assert!(out.probes > out.curve.len(), "{} probes", out.probes);
        for w in out.curve.windows(2) {
            assert!(w[0].rate < w[1].rate, "duplicate rate in {:?}", out.curve);
        }
    }

    #[test]
    fn crumb_rescues_a_tiny_capacity() {
        let mut params = SearchParams::paper_default(0.9);
        params.floor = 0.1;
        params.start = 0.5;
        let out = rate_search(&params, cliff(0.2));
        assert!(out.max_rate >= 0.1, "{}", out.max_rate);
        assert!(out.max_rate <= 0.2);
        assert!(out.best.is_some());
    }

    #[test]
    fn ceiling_caps_the_search() {
        let mut params = SearchParams::paper_default(0.9);
        params.ceiling = 16.0;
        let out = rate_search(&params, cliff(1e9));
        assert_eq!(out.max_rate, 16.0);
        assert!(out.best.is_some());
        assert!(out.saturated, "ceiling hit must be flagged");
        assert!(out.curve.iter().all(|p| p.rate <= 16.0));
    }

    #[test]
    fn exhausted_doubling_budget_is_saturated_not_bisected_down() {
        // Capacity far above what the doubling budget can bracket: the
        // top probe still sustains the target, so it must be reported as
        // the (saturated) max, not treated as the failing bisection hi.
        let mut params = SearchParams::paper_default(0.9);
        params.ceiling = 1e9;
        params.max_doublings = 3;
        let out = rate_search(&params, cliff(1e9));
        assert_eq!(out.max_rate, 0.5 * 2f64.powi(3));
        assert!(out.saturated);
        assert_eq!(out.best, Some(out.max_rate));
    }

    /// "More budget never yields lower best goodput": a zero budget
    /// truncates after the mandatory first probe, and whatever it reports
    /// is a confirmed rate no larger than the unbudgeted search's.
    #[test]
    fn tighter_budget_never_reports_a_higher_rate() {
        let mut tight = SearchParams::paper_default(0.9);
        tight.budget_s = Some(0.0);
        let out = rate_search(&tight, cliff(7.3));
        assert!(out.truncated, "zero budget must truncate");
        assert_eq!(out.probes, 1, "only the mandatory first probe runs");
        assert!(out.saturated, "the sustained start probe is bracket-limited");
        assert_eq!(out.max_rate, tight.start);

        let full = rate_search(&SearchParams::paper_default(0.9), cliff(7.3));
        assert!(!full.truncated, "no budget, no truncation");
        assert!(out.max_rate <= full.max_rate, "{} vs {}", out.max_rate, full.max_rate);
        assert!(out.best.is_some());
    }

    #[test]
    fn zero_budget_on_a_hopeless_probe_reports_zero_truncated() {
        let mut tight = SearchParams::paper_default(0.9);
        tight.budget_s = Some(0.0);
        let out = rate_search(&tight, cliff(0.0));
        assert_eq!(out.max_rate, 0.0);
        assert!(out.best.is_none());
        assert!(out.truncated, "crumb and bisections were skipped");
        assert_eq!(out.probes, 1);
    }

    #[test]
    fn generous_budget_changes_nothing() {
        let mut roomy = SearchParams::paper_default(0.9);
        roomy.budget_s = Some(3600.0);
        let budgeted = rate_search(&roomy, cliff(7.3));
        let free = rate_search(&SearchParams::paper_default(0.9), cliff(7.3));
        assert!(!budgeted.truncated);
        assert_eq!(budgeted.max_rate, free.max_rate);
        assert_eq!(budgeted.probes, free.probes);
    }

    #[test]
    fn quick_params_spend_fewer_probes() {
        let full = rate_search(&SearchParams::paper_default(0.9), cliff(7.3));
        let quick = rate_search(&SearchParams::paper_default(0.9).quick(), cliff(7.3));
        assert!(quick.probes < full.probes, "{} vs {}", quick.probes, full.probes);
        assert!(quick.max_rate > 4.0);
    }

    #[test]
    fn target_is_respected() {
        // Attainment decays linearly: 1.0 at rate 0 down to 0.0 at 10.
        let probe = |rate: f64| Probe {
            result: (),
            attainment: (1.0 - rate / 10.0).max(0.0),
            goodput_rps: rate,
        };
        let strict = rate_search(&SearchParams::paper_default(0.99), probe);
        let loose = rate_search(&SearchParams::paper_default(0.50), probe);
        assert!(strict.max_rate < loose.max_rate);
        assert!(strict.max_rate <= 0.1 + 1e-9 || strict.max_rate < 1.0);
    }

    fn assert_outcomes_bit_identical(a: &SearchOutcome<f64>, b: &SearchOutcome<f64>) {
        assert_eq!(a.max_rate.to_bits(), b.max_rate.to_bits());
        assert_eq!(a.best.map(f64::to_bits), b.best.map(f64::to_bits));
        assert_eq!(a.probes, b.probes);
        assert_eq!(a.saturated, b.saturated);
        assert_eq!(a.truncated, b.truncated);
        assert_eq!(a.curve.len(), b.curve.len());
        for (pa, pb) in a.curve.iter().zip(&b.curve) {
            assert_eq!(pa.rate.to_bits(), pb.rate.to_bits());
            assert_eq!(pa.attainment.to_bits(), pb.attainment.to_bits());
            assert_eq!(pa.goodput_rps.to_bits(), pb.goodput_rps.to_bits());
        }
    }

    /// Speculation must be invisible in the outcome: same max rate, same
    /// curve, same flags, same *consumed* probe count — across cliffs
    /// that exercise every phase (hopeless/crumb/normal/saturated).
    #[test]
    fn speculative_search_is_bit_identical_to_serial() {
        for cap in [0.0, 0.05, 0.2, 7.3, 100.0, 1e9] {
            let params = SearchParams::paper_default(0.9);
            let probe = move |rate: f64| Probe {
                result: rate,
                attainment: if rate <= cap { 1.0 } else { 0.0 },
                goodput_rps: rate.min(cap),
            };
            let serial = rate_search(&params, probe);
            let spec = rate_search_speculative(&params, probe, SPECULATION_WIDTH);
            assert_outcomes_bit_identical(&serial, &spec);
        }
    }

    /// Same, for a gradual (non-cliff) attainment slope and a target
    /// landing mid-slope — bisection verdicts flip both ways.
    #[test]
    fn speculative_search_matches_serial_on_gradual_slopes() {
        for target in [0.5, 0.9, 0.99] {
            let params = SearchParams::paper_default(target);
            let probe = |rate: f64| Probe {
                result: rate,
                attainment: (1.0 - rate / 10.0).max(0.0),
                goodput_rps: rate,
            };
            let serial = rate_search(&params, probe);
            let spec = rate_search_speculative(&params, probe, SPECULATION_WIDTH);
            assert_outcomes_bit_identical(&serial, &spec);
        }
    }

    /// The lookahead must actually hit: the speculative search executes
    /// more probes than it consumes (losers are discarded), but far
    /// fewer batches than consumed probes — i.e. the cache serves real
    /// requests, this isn't serial execution with extra steps.
    #[test]
    fn speculation_serves_probes_from_the_cache() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let params = SearchParams::paper_default(0.9);
        let executed = AtomicUsize::new(0);
        let probe = |rate: f64| {
            executed.fetch_add(1, Ordering::Relaxed);
            Probe {
                result: rate,
                attainment: if rate <= 7.3 { 1.0 } else { 0.0 },
                goodput_rps: rate.min(7.3),
            }
        };
        let out = rate_search_speculative(&params, &probe, SPECULATION_WIDTH);
        let executed = executed.load(Ordering::Relaxed);
        let serial = rate_search(&params, &probe);
        assert_eq!(out.probes, serial.probes, "consumed probes must match serial");
        // Every serial probe ran (directly or speculatively), plus some
        // discarded losers — but a correct predictor converts most steps
        // into cache hits, so executed probes stay well under the
        // no-cache worst case of one full batch per consumed probe.
        assert!(executed >= out.probes, "{executed} < {}", out.probes);
        assert!(
            executed < out.probes * SPECULATION_WIDTH,
            "{executed} executed for {} consumed: cache never hit",
            out.probes
        );
    }

    /// `workers <= 1` must degenerate to the serial search exactly.
    #[test]
    fn single_worker_speculation_is_serial() {
        let params = SearchParams::paper_default(0.9).quick();
        let probe = |rate: f64| Probe {
            result: rate,
            attainment: if rate <= 3.7 { 1.0 } else { 0.0 },
            goodput_rps: rate.min(3.7),
        };
        let serial = rate_search(&params, probe);
        let spec = rate_search_speculative(&params, probe, 1);
        assert_outcomes_bit_identical(&serial, &spec);
    }
}
