//! Frontier execution: for every (scenario × system [× variant]) cell,
//! adaptively search for the maximum sustainable offered rate at a target
//! per-class attainment level, regenerating the scenario's trace at every
//! probed rate (traces are pure functions of (scenario, seed, rate), so
//! each probe is a fresh deterministic experiment, not a replay).
//!
//! The sustain criterion is *strict and per-class*: a rate counts only if
//! every traffic class holds the target attainment, with never-completed
//! arrivals scored as violations. The optional mitosis-on variant starts
//! PaDG at `N_l` active instances and lets the §3.5 controller grow the
//! fleet (DynaServe arXiv:2504.09285 motivates putting elastic
//! configurations on the same frontier as static ones).

use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::search::{
    rate_search, rate_search_speculative, Probe, SearchOutcome, SearchParams,
    SearchPoint, SPECULATION_WIDTH,
};
use crate::config::SystemKind;
use crate::coordinator::AutoScalePolicy;
use crate::metrics::{AbandonPolicy, Attainment};
use crate::scenarios::{
    run_system_variant, ClassScore, RunSpec, Scenario, ScenarioConfig, VariantSpec,
};
use crate::util::threads::parallel_map;

/// Shared knobs for a frontier run.
#[derive(Debug, Clone)]
pub struct FrontierConfig {
    /// Deployment / seed / horizon-override base. Its `rate` field is
    /// ignored — the search owns the rate.
    pub base: ScenarioConfig,
    /// Attainment level a rate must sustain (paper reports P90/P99).
    pub level: Attainment,
    /// Also run the mitosis-on PaDG variant per scenario.
    pub autoscale: bool,
    /// Coarse search + short horizons — the CI smoke setting.
    pub quick: bool,
    /// Abort doomed probes the moment the online SLO monitor proves the
    /// target unreachable (default). Off runs every probe to completion;
    /// results are bit-identical either way — only cost changes.
    pub early_abandon: bool,
    /// Wall-clock budget per cell's rate search, seconds (`--budget-s`).
    /// A truncated cell reports its confirmed-so-far max rate and is
    /// flagged in `BENCH_simperf.json` (`budget_truncated`).
    pub budget_s: Option<f64>,
    /// Launch the rate search's predictable next probes concurrently
    /// (default; `--no-speculate` turns it off). Answers are
    /// bit-identical either way — speculation only trades extra
    /// (discarded) probe work for wall-clock; the executed-probe count
    /// in `BENCH_simperf.json` is the only observable difference.
    pub speculate: bool,
}

/// Horizon used by `--quick` when the caller gave no explicit override.
const QUICK_HORIZON_SECS: f64 = 40.0;

impl FrontierConfig {
    pub fn new(base: ScenarioConfig, level: Attainment) -> Self {
        FrontierConfig {
            base,
            level,
            autoscale: false,
            quick: false,
            early_abandon: true,
            budget_s: None,
            speculate: true,
        }
    }

    /// Search bracket for one scenario: registry sweep bounds at this
    /// config's target, coarsened in quick mode.
    pub fn search_params(&self, scenario: &Scenario) -> SearchParams {
        let b = scenario.sweep;
        let params = SearchParams {
            target: self.level.fraction(),
            floor: b.floor,
            start: b.start,
            ceiling: b.ceiling,
            max_doublings: 10,
            bisections: 5,
            budget_s: self.budget_s,
        };
        if self.quick { params.quick() } else { params }
    }

    /// Per-probe scenario config (quick mode shortens the horizon unless
    /// the caller overrode it explicitly).
    fn probe_base(&self) -> ScenarioConfig {
        let mut base = self.base.clone();
        if self.quick && base.duration_override.is_none() {
            base.duration_override = Some(QUICK_HORIZON_SECS);
        }
        base
    }
}

/// Simulator-cost counters for one frontier cell, aggregated over all of
/// its rate probes — the raw material of `BENCH_simperf.json`. These
/// track *cost*, not answers: they are the only cell fields allowed to
/// differ between early-abandon on and off.
#[derive(Debug, Clone, Default)]
pub struct CellPerf {
    /// Rate probes *executed* for this cell. Equal to the cell's
    /// consumed-probe count with speculation off; with speculation on it
    /// also counts mispredicted (discarded) lookahead probes, so it can
    /// exceed `FrontierCell::probes`.
    pub probes: usize,
    /// Events simulated across all probes.
    pub events: u64,
    /// Of those, events simulated inside probes that were abandoned.
    pub abandoned_events: u64,
    /// Events still queued when abandoned probes stopped — a lower bound
    /// on the work abandonment avoided.
    pub events_saved: u64,
    /// Probes the SLO monitor cut short.
    pub abandoned_probes: usize,
    /// Heap allocations inside probe run loops, summed
    /// ([`crate::sim::RunStats::allocs`]). The engine's own structures
    /// are pooled and allocation-free when warm; what remains — and what
    /// this trajectory exists to drive down — is allocation by the
    /// simulated systems' handlers.
    pub allocs: u64,
    /// Simulation wall time summed over probes (excludes search overhead).
    pub sim_wall: Duration,
}

/// One system's (or variant's) point on a scenario's goodput frontier.
#[derive(Debug, Clone)]
pub struct FrontierCell {
    pub system: SystemKind,
    /// True for the mitosis-on PaDG variant.
    pub autoscale: bool,
    /// Max offered rate sustaining the target per-class attainment
    /// (0.0 when nothing was sustained).
    pub max_rate: f64,
    /// Delivered SLO-meeting completions per second at `max_rate`.
    pub goodput_rps: f64,
    /// Min per-class attainment at `max_rate`.
    pub attainment: f64,
    /// Per-class scores at `max_rate` (empty when nothing sustained).
    pub classes: Vec<ClassScore>,
    /// The sampled rate→attainment curve, sorted by rate.
    pub curve: Vec<SearchPoint>,
    /// True when the search stopped (sweep ceiling or doubling budget)
    /// while still sustaining the target — `max_rate` is then a lower
    /// bound set by the bracket, not the system.
    pub saturated: bool,
    /// True when the per-cell wall-clock budget (`--budget-s`) cut the
    /// rate search short: `max_rate` is confirmed but unrefined.
    pub truncated: bool,
    pub probes: usize,
    pub wall: Duration,
    /// Simulator-cost counters for the `BENCH_simperf.json` artifact.
    pub perf: CellPerf,
}

impl FrontierCell {
    /// Display label distinguishing the mitosis-on variant.
    pub fn variant_label(&self) -> &'static str {
        if self.autoscale { "mitosis" } else { "fixed" }
    }
}

/// One scenario's frontier across all requested systems/variants.
#[derive(Debug)]
pub struct ScenarioFrontier {
    pub scenario: Scenario,
    pub level: Attainment,
    pub rows: Vec<FrontierCell>,
}

impl ScenarioFrontier {
    /// The cell sustaining the highest rate (ties: higher goodput).
    pub fn best(&self) -> Option<&FrontierCell> {
        self.rows.iter().max_by(|a, b| {
            (a.max_rate, a.goodput_rps)
                .partial_cmp(&(b.max_rate, b.goodput_rps))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
    }

    pub fn row(&self, kind: SystemKind, autoscale: bool) -> Option<&FrontierCell> {
        self.rows
            .iter()
            .find(|r| r.system == kind && r.autoscale == autoscale)
    }
}

/// The fully-declarative spec for one probe of one frontier cell:
/// system × variant × armed SLO monitor × (for churn scenarios run with
/// a fault seed) the deterministic fault schedule at the probe's
/// horizon. `probe_cfg` must already carry the probe rate — replay
/// horizons and fault timelines are rate-dependent.
pub fn cell_spec(
    scenario: &Scenario,
    probe_cfg: &ScenarioConfig,
    cfg: &FrontierConfig,
    kind: SystemKind,
    autoscale: bool,
) -> RunSpec {
    let variant = if autoscale {
        // The controller must chase the same attainment the frontier
        // demands — a P99 sweep with a 0.90-satisfied controller would
        // under-scale and under-report elastic capacity.
        let mut policy = AutoScalePolicy::default();
        policy.target_attainment = cfg.level.fraction();
        VariantSpec { autoscale: Some(policy) }
    } else {
        VariantSpec::default()
    };
    RunSpec::for_cell(scenario, probe_cfg, kind)
        .with_variant(variant)
        .with_abandon(AbandonPolicy {
            target: cfg.level.fraction(),
            stop_early: cfg.early_abandon,
        })
}

/// Search one cell: adaptive rate probes, each a full deterministic
/// scenario run scored strictly per class.
pub fn run_cell(
    scenario: &Scenario,
    cfg: &FrontierConfig,
    kind: SystemKind,
    autoscale: bool,
) -> FrontierCell {
    let params = cfg.search_params(scenario);
    let base = cfg.probe_base();
    // Speculative lookahead runs probes concurrently, so the cost
    // counters accumulate through a mutex. Every update is a commutative
    // sum over a deterministic probe set, so the totals stay
    // deterministic even though completion order is not.
    let perf = Mutex::new(CellPerf::default());
    let t0 = Instant::now();
    let probe_fn = |rate: f64| {
        let mut probe_cfg = base.clone();
        probe_cfg.rate = Some(rate);
        let spec = cell_spec(scenario, &probe_cfg, cfg, kind, autoscale);
        let row = run_system_variant(scenario, &probe_cfg, &spec);
        {
            let mut p = perf.lock().unwrap();
            p.probes += 1;
            p.events += row.events;
            p.allocs += row.allocs;
            p.sim_wall += row.wall;
            if row.abandoned {
                p.abandoned_probes += 1;
                p.abandoned_events += row.events;
                p.events_saved += row.events_saved;
            }
        }
        Probe {
            attainment: row.min_class_attainment(),
            goodput_rps: row.goodput_rps,
            result: row,
        }
    };
    let outcome = if cfg.speculate {
        rate_search_speculative(&params, probe_fn, SPECULATION_WIDTH)
    } else {
        rate_search(&params, probe_fn)
    };
    let wall = t0.elapsed();
    let perf = perf.into_inner().unwrap();
    let SearchOutcome { max_rate, best, curve, probes, saturated, truncated } = outcome;
    let (goodput_rps, attainment, classes) = match best {
        Some(row) => (row.goodput_rps, row.min_class_attainment(), row.classes),
        None => (0.0, 0.0, Vec::new()),
    };
    FrontierCell {
        system: kind,
        autoscale,
        max_rate,
        goodput_rps,
        attainment,
        classes,
        curve,
        saturated,
        truncated,
        probes,
        wall,
        perf,
    }
}

/// Run the frontier for `scenarios` × `systems` (plus the mitosis-on PaDG
/// variant when configured) as one parallel job pool. Cell order within a
/// scenario follows `systems`, with the autoscale variant appended.
pub fn run_frontier(
    scenarios: &[Scenario],
    cfg: &FrontierConfig,
    systems: &[SystemKind],
    workers: usize,
) -> Vec<ScenarioFrontier> {
    let mut jobs: Vec<(usize, SystemKind, bool)> = Vec::new();
    for si in 0..scenarios.len() {
        for &kind in systems {
            jobs.push((si, kind, false));
        }
        if cfg.autoscale && systems.contains(&SystemKind::EcoServe) {
            jobs.push((si, SystemKind::EcoServe, true));
        }
    }
    let cells = parallel_map(jobs, workers.max(1), |(si, kind, auto)| {
        (si, run_cell(&scenarios[si], cfg, kind, auto))
    });
    let mut fronts: Vec<ScenarioFrontier> = scenarios
        .iter()
        .map(|s| ScenarioFrontier {
            scenario: s.clone(),
            level: cfg.level,
            rows: Vec::new(),
        })
        .collect();
    for (si, cell) in cells {
        fronts[si].rows.push(cell);
    }
    fronts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::by_name;

    fn quick_frontier_cfg() -> FrontierConfig {
        let mut base = ScenarioConfig::default_l20();
        base.deployment.gpus_used = 16; // 4 instances — fast tests
        let mut cfg = FrontierConfig::new(base, Attainment::P90);
        cfg.quick = true;
        cfg
    }

    #[test]
    fn cell_search_finds_a_positive_sustained_rate() {
        let s = by_name("steady").unwrap();
        let cfg = quick_frontier_cfg();
        let cell = run_cell(&s, &cfg, SystemKind::EcoServe, false);
        assert!(cell.max_rate > 0.0, "curve: {:?}", cell.curve);
        assert!(cell.max_rate <= s.sweep.ceiling);
        assert!(cell.attainment >= 0.90 - 1e-9, "{}", cell.attainment);
        assert!(cell.goodput_rps > 0.0);
        // The core only guarantees >= (equal-rate re-probes are deduped).
        assert!(cell.probes >= cell.curve.len());
        assert!(!cell.classes.is_empty());
        for w in cell.curve.windows(2) {
            assert!(w[0].rate < w[1].rate);
        }
    }

    /// The bracket phase always overshoots the capacity cliff (sweep
    /// ceilings sit at 8x nominal), so a cell search must both abandon
    /// doomed probes and account for the work it skipped.
    #[test]
    fn cell_perf_counters_track_abandoned_probes() {
        let s = by_name("steady").unwrap();
        let mut cfg = quick_frontier_cfg();
        cfg.speculate = false;
        assert!(cfg.early_abandon, "abandonment is the default");
        let cell = run_cell(&s, &cfg, SystemKind::EcoServe, false);
        // Speculation off: executed probes == consumed probes, exactly.
        assert_eq!(cell.perf.probes, cell.probes);
        assert!(cell.perf.events > 0);
        assert!(cell.perf.abandoned_probes > 0, "{:?}", cell.perf);
        assert!(cell.perf.abandoned_probes <= cell.perf.probes);
        assert!(cell.perf.abandoned_events > 0);
        assert!(cell.perf.events_saved > 0, "{:?}", cell.perf);
        assert!(cell.perf.abandoned_events <= cell.perf.events);
    }

    /// Speculation is on by default and must change cost counters only:
    /// same answer (rate, curve, classes), possibly more *executed*
    /// probes than the serial search *consumed*.
    #[test]
    fn speculative_cell_matches_serial_cell_bit_for_bit() {
        let s = by_name("steady").unwrap();
        let spec_cfg = quick_frontier_cfg();
        assert!(spec_cfg.speculate, "speculation is the default");
        let mut serial_cfg = quick_frontier_cfg();
        serial_cfg.speculate = false;
        let spec = run_cell(&s, &spec_cfg, SystemKind::EcoServe, false);
        let serial = run_cell(&s, &serial_cfg, SystemKind::EcoServe, false);
        assert_eq!(spec.max_rate.to_bits(), serial.max_rate.to_bits());
        assert_eq!(spec.goodput_rps.to_bits(), serial.goodput_rps.to_bits());
        assert_eq!(spec.attainment.to_bits(), serial.attainment.to_bits());
        assert_eq!(spec.probes, serial.probes, "consumed probes must match");
        assert_eq!(spec.curve.len(), serial.curve.len());
        for (a, b) in spec.curve.iter().zip(&serial.curve) {
            assert_eq!(a.rate.to_bits(), b.rate.to_bits());
            assert_eq!(a.attainment.to_bits(), b.attainment.to_bits());
            assert_eq!(a.goodput_rps.to_bits(), b.goodput_rps.to_bits());
        }
        assert!(spec.perf.probes >= spec.probes, "{:?}", spec.perf);
        assert!(spec.perf.probes >= serial.perf.probes);
    }

    /// `--budget-s 0`: the mandatory first probe still runs, the cell is
    /// flagged truncated, and its (confirmed) rate never exceeds what an
    /// unbudgeted search reports.
    #[test]
    fn zero_budget_truncates_a_cell_but_still_answers() {
        let s = by_name("steady").unwrap();
        let mut cfg = quick_frontier_cfg();
        cfg.budget_s = Some(0.0);
        let cell = run_cell(&s, &cfg, SystemKind::EcoServe, false);
        assert!(cell.truncated, "zero budget must truncate");
        assert_eq!(cell.probes, 1);
        let full = run_cell(&s, &quick_frontier_cfg(), SystemKind::EcoServe, false);
        assert!(!full.truncated);
        assert!(
            cell.max_rate <= full.max_rate,
            "{} vs {}",
            cell.max_rate,
            full.max_rate
        );
    }

    #[test]
    fn quick_mode_bounds_probe_count() {
        let s = by_name("steady").unwrap();
        let cfg = quick_frontier_cfg();
        let params = cfg.search_params(&s);
        assert!(params.bisections <= 3);
        assert!(params.max_doublings <= 6);
        // Worst case: bracket probes + crumb + bisections.
        let cell = run_cell(&s, &cfg, SystemKind::Vllm, false);
        assert!(
            cell.probes <= params.max_doublings + params.bisections + 2,
            "{}",
            cell.probes
        );
    }

    #[test]
    fn churn_scenario_flows_through_the_frontier() {
        let s = by_name("steady+churn").unwrap();
        let mut cfg = quick_frontier_cfg();
        cfg.base.fault_seed = Some(7);
        let churned = run_cell(&s, &cfg, SystemKind::EcoServe, false);
        // Per-probe specs carry the schedule (rate-dependent horizon).
        let mut probe_cfg = cfg.probe_base();
        probe_cfg.rate = Some(s.default_rate);
        let spec = cell_spec(&s, &probe_cfg, &cfg, SystemKind::EcoServe, false);
        assert!(spec.faults.is_some_and(|f| !f.is_empty()));
        assert!(spec.abandon.is_some());
        // Without a fault seed the same cell searches fault-free, and
        // injected outages never raise the sustainable rate.
        let clean_cfg = quick_frontier_cfg();
        let clean = run_cell(&s, &clean_cfg, SystemKind::EcoServe, false);
        let mut clean_probe = clean_cfg.probe_base();
        clean_probe.rate = Some(s.default_rate);
        let clean_spec =
            cell_spec(&s, &clean_probe, &clean_cfg, SystemKind::EcoServe, false);
        assert!(clean_spec.faults.is_none());
        assert!(clean.max_rate > 0.0);
        assert!(
            churned.max_rate <= clean.max_rate + 1e-9,
            "churned {} vs clean {}",
            churned.max_rate,
            clean.max_rate
        );
    }

    #[test]
    fn frontier_groups_rows_and_appends_autoscale_variant() {
        let scenarios = vec![by_name("steady").unwrap()];
        let mut cfg = quick_frontier_cfg();
        cfg.autoscale = true;
        // 8 instances so the mitosis variant (initial N_l=4) has headroom.
        cfg.base.deployment.gpus_used = 32;
        let systems = [SystemKind::EcoServe, SystemKind::Vllm];
        let fronts = run_frontier(&scenarios, &cfg, &systems, 4);
        assert_eq!(fronts.len(), 1);
        let f = &fronts[0];
        assert_eq!(f.rows.len(), 3);
        assert_eq!(f.rows[0].system, SystemKind::EcoServe);
        assert!(!f.rows[0].autoscale);
        assert_eq!(f.rows[1].system, SystemKind::Vllm);
        assert_eq!(f.rows[2].system, SystemKind::EcoServe);
        assert!(f.rows[2].autoscale);
        assert_eq!(f.rows[2].variant_label(), "mitosis");
        assert!(f.best().is_some());
        assert!(f.row(SystemKind::EcoServe, true).is_some());
        assert!(f.row(SystemKind::Vllm, true).is_none());
        // The elastic variant must still sustain something.
        assert!(f.rows[2].max_rate > 0.0);
    }
}
