//! Renderings of frontier results: the human table and the
//! machine-readable `BENCH_goodput.json` that CI uploads as a build
//! artifact so successive PRs can track the performance trajectory.
//! The JSON shares its `schema_version` with the scenario-suite report
//! ([`crate::scenarios::SCHEMA_VERSION`]); keep changes additive.

use std::time::Duration;

use super::driver::{CellPerf, FrontierCell, FrontierConfig, ScenarioFrontier};
use crate::scenarios::{class_to_json, deployment_to_json, SCHEMA_VERSION};
use crate::util::json::Json;

fn cell_to_json(cell: &FrontierCell) -> Json {
    Json::obj(vec![
        ("system", Json::str(cell.system.label())),
        ("autoscale", Json::Bool(cell.autoscale)),
        ("max_rate_rps", Json::num(cell.max_rate)),
        ("saturated", Json::Bool(cell.saturated)),
        ("goodput_rps", Json::num(cell.goodput_rps)),
        ("attainment_at_max", Json::num(cell.attainment)),
        ("classes", Json::arr(cell.classes.iter().map(class_to_json))),
        (
            "curve",
            Json::arr(cell.curve.iter().map(|p| {
                Json::obj(vec![
                    ("rate_rps", Json::num(p.rate)),
                    ("attainment", Json::num(p.attainment)),
                    ("goodput_rps", Json::num(p.goodput_rps)),
                ])
            })),
        ),
        ("probes", Json::num(cell.probes as f64)),
        ("wall_s", Json::num(cell.wall.as_secs_f64())),
    ])
}

fn frontier_to_json_one(f: &ScenarioFrontier) -> Json {
    let mut fields = vec![
        ("name", Json::str(f.scenario.name)),
        ("summary", Json::str(f.scenario.summary)),
        (
            "sweep",
            Json::obj(vec![
                ("floor_rps", Json::num(f.scenario.sweep.floor)),
                ("start_rps", Json::num(f.scenario.sweep.start)),
                ("ceiling_rps", Json::num(f.scenario.sweep.ceiling)),
            ]),
        ),
        (
            "best_system",
            match f.best() {
                Some(c) => Json::str(c.system.label()),
                None => Json::Null,
            },
        ),
        ("systems", Json::arr(f.rows.iter().map(cell_to_json))),
    ];
    if let Some(block) = crate::scenarios::report::replay_to_json(&f.scenario) {
        fields.push(block);
    }
    Json::obj(fields)
}

/// The full `BENCH_goodput.json` document.
pub fn frontier_to_json(
    fronts: &[ScenarioFrontier],
    cfg: &FrontierConfig,
    wall: Duration,
) -> Json {
    // Report what actually ran, not what was requested: run_frontier
    // skips the mitosis variant when PaDG is not among the systems, and
    // the flag must never contradict the rows.
    let variant_ran = fronts.iter().any(|f| f.rows.iter().any(|r| r.autoscale));
    Json::obj(vec![
        ("bench", Json::str("ecoserve-goodput-frontier")),
        ("schema_version", Json::num(SCHEMA_VERSION)),
        ("level", Json::str(cfg.level.label())),
        ("target_attainment", Json::num(cfg.level.fraction())),
        ("seed", Json::num(cfg.base.seed as f64)),
        ("quick", Json::Bool(cfg.quick)),
        ("autoscale_variant", Json::Bool(variant_ran)),
        ("deployment", deployment_to_json(&cfg.base.deployment)),
        ("wall_s", Json::num(wall.as_secs_f64())),
        ("scenarios", Json::arr(fronts.iter().map(frontier_to_json_one))),
    ])
}

fn perf_fields(p: &CellPerf) -> Vec<(&'static str, Json)> {
    let secs = p.sim_wall.as_secs_f64();
    vec![
        ("probes", Json::num(p.probes as f64)),
        ("events", Json::num(p.events as f64)),
        ("abandoned_probes", Json::num(p.abandoned_probes as f64)),
        ("abandoned_events", Json::num(p.abandoned_events as f64)),
        ("events_saved", Json::num(p.events_saved as f64)),
        ("allocs", Json::num(p.allocs as f64)),
        ("sim_wall_s", Json::num(secs)),
        (
            "events_per_sec",
            Json::num(if secs > 0.0 { p.events as f64 / secs } else { 0.0 }),
        ),
    ]
}

/// The full `BENCH_simperf.json` document: simulator *cost* per
/// (scenario × system × variant) cell — events simulated, events saved by
/// early abandonment, wall time — so the simulator's own throughput is a
/// tracked trajectory, separate from the answer-bearing
/// `BENCH_goodput.json` (whose cells must stay bit-identical whether or
/// not abandonment is on).
pub fn simperf_to_json(
    fronts: &[ScenarioFrontier],
    cfg: &FrontierConfig,
    wall: Duration,
) -> Json {
    let mut totals = CellPerf::default();
    let mut cells = Vec::new();
    for f in fronts {
        for cell in &f.rows {
            let p = &cell.perf;
            totals.probes += p.probes;
            totals.events += p.events;
            totals.abandoned_probes += p.abandoned_probes;
            totals.abandoned_events += p.abandoned_events;
            totals.events_saved += p.events_saved;
            totals.allocs += p.allocs;
            totals.sim_wall += p.sim_wall;
            let mut fields = vec![
                ("scenario", Json::str(f.scenario.name)),
                ("system", Json::str(cell.system.label())),
                ("variant", Json::str(cell.variant_label())),
                ("max_rate_rps", Json::num(cell.max_rate)),
                ("budget_truncated", Json::Bool(cell.truncated)),
            ];
            fields.extend(perf_fields(p));
            cells.push(Json::obj(fields));
        }
    }
    Json::obj(vec![
        ("bench", Json::str("ecoserve-simperf")),
        ("schema_version", Json::num(SCHEMA_VERSION)),
        ("level", Json::str(cfg.level.label())),
        ("quick", Json::Bool(cfg.quick)),
        ("seed", Json::num(cfg.base.seed as f64)),
        ("early_abandon", Json::Bool(cfg.early_abandon)),
        ("speculate", Json::Bool(cfg.speculate)),
        ("budget_s", Json::opt_num(cfg.budget_s)),
        ("deployment", deployment_to_json(&cfg.base.deployment)),
        ("wall_s", Json::num(wall.as_secs_f64())),
        ("totals", Json::obj(perf_fields(&totals))),
        ("cells", Json::arr(cells)),
    ])
}

/// Human-readable frontier table for one scenario.
pub fn render_frontier_table(f: &ScenarioFrontier) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "--- goodput frontier '{}' @ {} per-class attainment ---\n",
        f.scenario.name,
        f.level.label()
    ));
    out.push_str(&format!(
        "{:<10} {:>8} {:>11} {:>10} {:>11} {:>7} {:>8}\n",
        "system", "variant", "max rate/s", "goodput/s", "attain@max", "probes", "wall"
    ));
    for cell in &f.rows {
        let rate = format!(
            "{:.2}{}{}",
            cell.max_rate,
            if cell.saturated { "+" } else { "" },
            if cell.truncated { "~" } else { "" }
        );
        out.push_str(&format!(
            "{:<10} {:>8} {:>11} {:>10.2} {:>10.1}% {:>7} {:>7.1}s\n",
            cell.system.label(),
            cell.variant_label(),
            rate,
            cell.goodput_rps,
            cell.attainment * 100.0,
            cell.probes,
            cell.wall.as_secs_f64(),
        ));
    }
    if f.rows.iter().any(|c| c.saturated) {
        out.push_str("  (+ = hit the sweep ceiling; true max is at least this)\n");
    }
    if f.rows.iter().any(|c| c.truncated) {
        out.push_str("  (~ = wall-clock budget cut the search; rate is unrefined)\n");
    }
    if let Some(best) = f.best() {
        out.push_str(&format!(
            "  frontier: {} ({}) at {:.2} req/s\n",
            best.system.label(),
            best.variant_label(),
            best.max_rate
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemKind;
    use crate::frontier::search::SearchPoint;
    use crate::metrics::Attainment;
    use crate::scenarios::{by_name, ClassScore, ScenarioConfig};

    /// Synthetic frontier — report tests must not pay for simulation.
    fn synthetic() -> (Vec<ScenarioFrontier>, FrontierConfig) {
        let scenario = by_name("bursty").unwrap();
        let cell = |kind: SystemKind, auto: bool, rate: f64| FrontierCell {
            system: kind,
            autoscale: auto,
            max_rate: rate,
            goodput_rps: rate * 0.9,
            attainment: 0.92,
            classes: vec![ClassScore {
                class: "chat",
                arrived: 100,
                met: 92,
                attainment: 0.92,
            }],
            curve: vec![
                SearchPoint { rate: rate / 2.0, attainment: 1.0, goodput_rps: rate / 2.0 },
                SearchPoint { rate, attainment: 0.92, goodput_rps: rate * 0.9 },
                SearchPoint { rate: rate * 2.0, attainment: 0.4, goodput_rps: rate },
            ],
            saturated: false,
            truncated: false,
            probes: 3,
            wall: Duration::from_millis(1500),
            perf: CellPerf {
                probes: 3,
                events: 9000,
                abandoned_events: 1000,
                events_saved: 4000,
                abandoned_probes: 1,
                allocs: 500,
                sim_wall: Duration::from_millis(1200),
            },
        };
        let fronts = vec![ScenarioFrontier {
            scenario,
            level: Attainment::P90,
            rows: vec![
                cell(SystemKind::EcoServe, false, 6.0),
                cell(SystemKind::Vllm, false, 3.5),
                cell(SystemKind::EcoServe, true, 5.0),
            ],
        }];
        let mut base = ScenarioConfig::default_l20();
        base.deployment.gpus_used = 16;
        let mut cfg = FrontierConfig::new(base, Attainment::P90);
        cfg.quick = true;
        cfg.autoscale = true;
        (fronts, cfg)
    }

    #[test]
    fn bench_json_honors_the_contract() {
        let (fronts, cfg) = synthetic();
        let text = frontier_to_json(&fronts, &cfg, Duration::from_secs(9)).to_string();
        let back = Json::parse(&text).expect("BENCH report must be valid JSON");
        assert_eq!(
            back.get("bench").unwrap().as_str(),
            Some("ecoserve-goodput-frontier")
        );
        assert_eq!(
            back.get("schema_version").unwrap().as_f64(),
            Some(SCHEMA_VERSION)
        );
        assert_eq!(back.get("level").unwrap().as_str(), Some("P90"));
        assert_eq!(back.get("target_attainment").unwrap().as_f64(), Some(0.9));
        assert!(back.path(&["deployment", "instances"]).is_some());
        let sc = back.get("scenarios").unwrap().idx(0).unwrap();
        assert_eq!(sc.get("name").unwrap().as_str(), Some("bursty"));
        assert!(sc.path(&["sweep", "ceiling_rps"]).is_some());
        assert_eq!(sc.get("best_system").unwrap().as_str(), Some("EcoServe"));
        let systems = sc.get("systems").unwrap().as_arr().unwrap();
        assert_eq!(systems.len(), 3);
        for sys in systems {
            for key in [
                "system", "autoscale", "max_rate_rps", "saturated", "goodput_rps",
                "attainment_at_max", "classes", "curve", "probes", "wall_s",
            ] {
                assert!(sys.get(key).is_some(), "missing {key}");
            }
            let curve = sys.get("curve").unwrap().as_arr().unwrap();
            assert_eq!(curve.len(), 3);
            assert!(curve[0].get("rate_rps").unwrap().as_f64().is_some());
        }
        // The mitosis variant is distinguishable in the wire format, and
        // the top-level flag reflects the rows that actually ran.
        assert_eq!(systems[2].get("autoscale").unwrap().as_bool(), Some(true));
        assert_eq!(back.get("autoscale_variant").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn simperf_json_honors_the_contract() {
        let (fronts, cfg) = synthetic();
        let text = simperf_to_json(&fronts, &cfg, Duration::from_secs(4)).to_string();
        let back = Json::parse(&text).expect("simperf report must be valid JSON");
        assert_eq!(back.get("bench").unwrap().as_str(), Some("ecoserve-simperf"));
        assert_eq!(
            back.get("schema_version").unwrap().as_f64(),
            Some(SCHEMA_VERSION)
        );
        assert_eq!(back.get("level").unwrap().as_str(), Some("P90"));
        assert_eq!(back.get("early_abandon").unwrap().as_bool(), Some(true));
        assert_eq!(back.get("speculate").unwrap().as_bool(), Some(true));
        assert_eq!(back.get("budget_s"), Some(&Json::Null), "no budget set");
        assert!(back.path(&["deployment", "instances"]).is_some());
        // Totals aggregate the three synthetic cells.
        assert_eq!(back.path(&["totals", "probes"]).unwrap().as_i64(), Some(9));
        assert_eq!(
            back.path(&["totals", "events"]).unwrap().as_i64(),
            Some(27_000)
        );
        assert_eq!(
            back.path(&["totals", "events_saved"]).unwrap().as_i64(),
            Some(12_000)
        );
        assert_eq!(
            back.path(&["totals", "allocs"]).unwrap().as_i64(),
            Some(1_500)
        );
        let cells = back.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 3);
        for cell in cells {
            for key in [
                "scenario", "system", "variant", "max_rate_rps", "budget_truncated",
                "probes", "events", "abandoned_probes", "abandoned_events",
                "events_saved", "allocs", "sim_wall_s", "events_per_sec",
            ] {
                assert!(cell.get(key).is_some(), "missing {key}");
            }
            assert_eq!(cell.get("budget_truncated").unwrap().as_bool(), Some(false));
            // events_per_sec = events / sim_wall (synthetic: 9000 / 1.2s).
            let eps = cell.get("events_per_sec").unwrap().as_f64().unwrap();
            assert!((eps - 7500.0).abs() < 1e-6, "{eps}");
        }
        assert_eq!(cells[0].get("scenario").unwrap().as_str(), Some("bursty"));
        assert_eq!(cells[2].get("variant").unwrap().as_str(), Some("mitosis"));
    }

    #[test]
    fn autoscale_flag_reflects_rows_not_the_request() {
        let (mut fronts, cfg) = synthetic();
        // Drop the mitosis row: the flag must follow the data even though
        // cfg.autoscale is still true.
        fronts[0].rows.retain(|r| !r.autoscale);
        assert!(cfg.autoscale);
        let text = frontier_to_json(&fronts, &cfg, Duration::from_secs(1)).to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("autoscale_variant").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn frontier_table_lists_variants_and_winner() {
        let (fronts, _) = synthetic();
        let table = render_frontier_table(&fronts[0]);
        assert!(table.contains("EcoServe"));
        assert!(table.contains("vLLM"));
        assert!(table.contains("mitosis"));
        assert!(table.contains("fixed"));
        assert!(table.contains("frontier: EcoServe"));
    }
}
