//! The goodput frontier — capacity planning over the scenario suite.
//!
//! The paper's headline comparison is not "who wins at rate X" but "what
//! is the maximum rate each system can sustain at the target SLO
//! attainment" (§4.1; DistServe arXiv:2401.09670 formalizes the same
//! goodput-frontier methodology). PR 1's scenario suite scores systems at
//! fixed rates; this subsystem runs, for every scenario × system pair, an
//! adaptive rate search — coarse doubling then bisection, via the single
//! shared [`search`] core that [`crate::harness::goodput_search`] also
//! uses — to find that maximum, optionally with mitosis autoscaling
//! enabled for PaDG:
//!
//! ```text
//! ecoserve frontier --scenario bursty --level p90 --out BENCH_goodput.json
//! ecoserve frontier --quick --autoscale          # CI smoke setting
//! ecoserve frontier --system vllm --gpus 16
//! ecoserve frontier --replay trace.jsonl --quick # recorded arrival log
//! ```
//!
//! `--replay` sweeps a recorded arrival log instead of a synthetic
//! shape: every probe time-warps the log so the offered rate matches the
//! probed rate while the recorded burst structure is preserved
//! ([`crate::workload::ReplayTrace::requests_at`]).
//!
//! * [`search`] — the one rate-search implementation (bracket + bisect),
//!   generic over the probe; every probe is recorded so searches yield
//!   full rate→attainment curves.
//! * [`driver`] — (scenario × system × variant) cells: each probe
//!   regenerates the scenario trace at the probed rate and scores strict
//!   per-class attainment; the mitosis-on variant starts PaDG at `N_l`
//!   instances under the §3.5 controller.
//! * [`report`] — the frontier table and the schema-versioned
//!   `BENCH_goodput.json` CI uploads so future PRs track the trajectory.

pub mod driver;
pub mod report;
pub mod search;

pub use driver::{
    cell_spec, run_cell, run_frontier, CellPerf, FrontierCell, FrontierConfig, ScenarioFrontier,
};
pub use report::{frontier_to_json, render_frontier_table, simperf_to_json};
pub use search::{
    rate_search, rate_search_speculative, Probe, SearchOutcome, SearchParams,
    SearchPoint, SPECULATION_WIDTH,
};
