//! Fully-disaggregated (FuDG) baselines: DistServe and MoonCake (§2.4.2).
//!
//! Both split instances into prefill and decode roles; the KV cache
//! migrates after prefill. They differ in where the bytes travel:
//!
//! * **DistServe** (intra-node FuDG): prefill/decode instances colocate in
//!   one node when the layout allows; KV hops over the node's intra-node
//!   fabric (PCIe on the paper's clusters — no NVLink). When a model needs
//!   a whole node per instance (Qwen2-72B TP=8), colocating is impossible
//!   and KV crosses the inter-node network.
//! * **MoonCake** (inter-node FuDG): one instance per node; every KV
//!   transfer goes through the central pool — two NIC hops (src NIC →
//!   pool → dst NIC) *even when src == dst node*, as the paper notes.
//!
//! Strict §3.3 timing: the first token is recorded when the request is
//! admitted on the decode side — the reported TTFT therefore folds in the
//! transfer ("phase-switching") wait, exactly the metric the paper argues
//! is usually misrepresented.

use std::collections::{HashMap, VecDeque};

use super::{BaselineChurn, QueueGuard};
use crate::config::{Deployment, SystemParams};
use crate::metrics::Collector;
use crate::sim::{
    ChurnTelemetry, DefenseTelemetry, Event, EventScheduler, FaultEvent, Health, Network,
    SimInstance, SimReq, System,
};
use crate::trace::{RejectCause, TraceEvent, TraceKind};
use crate::workload::Request;

const EPS: f64 = 1e-9;

/// Which FuDG flavour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FudgMode {
    DistServe,
    MoonCake,
}

/// A request whose KV is in flight between instances.
#[derive(Debug, Clone)]
struct InTransit {
    req: Request,
    dest: usize,
    /// Transfer enqueue time (the flight recorder's `Transfer` span start).
    started: f64,
}

/// DistServe / MoonCake under simulation.
pub struct FudgSystem {
    pub mode: FudgMode,
    pub instances: Vec<SimInstance>,
    /// Instance index -> node (for link selection).
    node_of: Vec<usize>,
    /// Role split: indices of prefill / decode instances.
    pub prefill_ids: Vec<usize>,
    pub decode_ids: Vec<usize>,
    /// Shared FCFS prompt queue feeding the prefill fleet.
    pub prefill_backlog: VecDeque<Request>,
    /// KV finished prefill but its transfer has not been enqueued because
    /// no decode instance had room.
    pub staged: VecDeque<Request>,
    pub network: Network,
    /// node -> intra-node link id; node -> NIC link id.
    intra_links: Vec<usize>,
    nic_links: Vec<usize>,
    transfers: HashMap<u64, InTransit>,
    pub params: SystemParams,
    kv_bytes_per_token: f64,
    /// Count of cross-node DistServe transfers (layout diagnostics).
    pub cross_node_transfers: u64,
    /// Scratch collector for prefill-side bookkeeping (first token is
    /// recorded on the decode side per §3.3).
    scratch: Collector,
    /// Native fault handling (crashes lose resident work).
    pub churn: BaselineChurn,
    /// Native overload handling (bounded prompt queue).
    pub guard: QueueGuard,
    /// Interconnect slowdown under an active link-degrade fault (1.0 =
    /// healthy). FuDG pays this on every KV migration; the co-located
    /// systems do not — the fragility the churn scenarios expose.
    link_factor: f64,
}

impl FudgSystem {
    /// `prefill_count`: how many of the deployment's instances take the
    /// prefill role (the paper sweeps this ratio for MoonCake; the harness
    /// exposes the same sweep).
    pub fn new(
        deployment: &Deployment,
        mode: FudgMode,
        prefill_count: usize,
        params: SystemParams,
    ) -> Self {
        let n = deployment.num_instances();
        assert!(
            prefill_count >= 1 && prefill_count < n,
            "need at least one prefill and one decode instance"
        );
        let instances: Vec<SimInstance> = (0..n)
            .map(|i| SimInstance::new(i, deployment.timer(), deployment.kv_reserve_frac))
            .collect();
        // MoonCake deploys one instance per node (paper §4.2); DistServe
        // packs instances densely so P/D pairs share nodes when possible.
        let node_of: Vec<usize> = (0..n)
            .map(|i| match mode {
                FudgMode::MoonCake => i % deployment.cluster.nodes,
                FudgMode::DistServe => deployment.node_of_instance(i),
            })
            .collect();
        // Interleave roles so DistServe colocates one prefill with one
        // decode instance per node when there are 2+ instances per node.
        let mut prefill_ids = Vec::new();
        let mut decode_ids = Vec::new();
        for i in 0..n {
            if prefill_ids.len() < prefill_count && i % 2 == 0 {
                prefill_ids.push(i);
            } else {
                decode_ids.push(i);
            }
        }
        while prefill_ids.len() < prefill_count {
            prefill_ids.push(decode_ids.pop().expect("enough instances"));
        }
        let mut network = Network::new();
        let nodes = deployment.cluster.nodes;
        let intra_links: Vec<usize> = (0..nodes)
            .map(|_| network.add_link(deployment.cluster.intra_link.clone()))
            .collect();
        let nic_links: Vec<usize> = (0..nodes)
            .map(|_| network.add_link(deployment.cluster.inter_link.clone()))
            .collect();
        let guard = QueueGuard::new(&params);
        FudgSystem {
            mode,
            instances,
            node_of,
            prefill_ids,
            decode_ids,
            prefill_backlog: VecDeque::new(),
            staged: VecDeque::new(),
            network,
            intra_links,
            nic_links,
            transfers: HashMap::new(),
            params,
            kv_bytes_per_token: deployment.model.kv_bytes_per_token(),
            cross_node_transfers: 0,
            scratch: Collector::new(),
            churn: BaselineChurn::new(n),
            guard,
            link_factor: 1.0,
        }
    }

    fn is_prefill_instance(&self, idx: usize) -> bool {
        self.prefill_ids.contains(&idx)
    }

    /// Pick the decode instance for a finished prefill: least-loaded with
    /// room, preferring the same node under DistServe.
    fn pick_decode_dest(&self, req: &Request, src: usize) -> Option<usize> {
        let margin = self.params.admission_margin;
        let candidates = self.decode_ids.iter().copied().filter(|&d| {
            self.instances[d].health == Health::Up
                && self.instances[d].kv_room_for(req.input_len, margin)
        });
        match self.mode {
            FudgMode::DistServe => {
                let src_node = self.node_of[src];
                candidates.min_by_key(|&d| {
                    let same_node = (self.node_of[d] != src_node) as usize;
                    (same_node, self.instances[d].kv_used)
                })
            }
            FudgMode::MoonCake => candidates.min_by_key(|&d| self.instances[d].kv_used),
        }
    }

    /// Enqueue the KV transfer for `req` from prefill instance `src`.
    fn start_transfer(
        &mut self,
        req: Request,
        src: usize,
        now: f64,
        sched: &mut EventScheduler,
    ) -> bool {
        let Some(dest) = self.pick_decode_dest(&req, src) else {
            self.staged.push_back(req);
            return false;
        };
        // Reserve decode-side KV at transfer start so the room is there on
        // arrival (prompt + margin).
        self.instances[dest].kv_used += req.input_len;
        // A degraded interconnect stretches the transfer: under the FIFO
        // link model, scaling bytes is scaling time.
        let bytes = self.kv_bytes_per_token * req.input_len as f64 * self.link_factor;
        let (src_node, dst_node) = (self.node_of[src], self.node_of[dest]);
        let transfer = match self.mode {
            FudgMode::MoonCake => {
                // Always through the pool: src NIC then dst NIC.
                self.network.enqueue_two_hop(
                    self.nic_links[src_node],
                    self.nic_links[dst_node],
                    bytes,
                    req.id,
                    now,
                )
            }
            FudgMode::DistServe => {
                if src_node == dst_node {
                    self.network.enqueue(self.intra_links[src_node], bytes, req.id, now)
                } else {
                    self.cross_node_transfers += 1;
                    self.network.enqueue_two_hop(
                        self.nic_links[src_node],
                        self.nic_links[dst_node],
                        bytes,
                        req.id,
                        now,
                    )
                }
            }
        };
        sched.at(transfer.done, Event::TransferDone { transfer: transfer.id });
        self.transfers.insert(transfer.id, InTransit { req, dest, started: now });
        true
    }

    fn kick_prefill_fleet(&mut self, now: f64, sched: &mut EventScheduler) {
        // Feed idle prefill instances from the shared backlog, FCFS,
        // batching short prompts up to the ~512-token saturation point.
        for pi in self.prefill_ids.clone() {
            if self.prefill_backlog.is_empty() {
                break;
            }
            let inst = &mut self.instances[pi];
            if inst.health == Health::Up && inst.idle() && inst.prefill_queue.is_empty() {
                let mut count = 0;
                let mut tokens = 0;
                while let Some(req) = self.prefill_backlog.front() {
                    if count > 0 && (count >= 16 || tokens + req.input_len > 512) {
                        break;
                    }
                    tokens += req.input_len;
                    count += 1;
                    let req = self.prefill_backlog.pop_front().unwrap();
                    inst.admit(req);
                }
                let done = inst.start_prefill(count, now);
                sched.at(done, Event::InstanceWake { instance: pi });
            }
        }
    }

    fn retry_staged(&mut self, now: f64, sched: &mut EventScheduler) {
        let mut remaining = VecDeque::new();
        while let Some(req) = self.staged.pop_front() {
            // Source node unknown after staging; approximate with the
            // least-backlogged prefill node (transfer already produced).
            let src = self.prefill_ids[0];
            if !self.start_transfer(req.clone(), src, now, sched) {
                remaining.push_back(req);
                break;
            }
        }
        while let Some(r) = self.staged.pop_front() {
            remaining.push_back(r);
        }
        self.staged = remaining;
    }
}

impl System for FudgSystem {
    fn on_arrival(
        &mut self,
        req: Request,
        now: f64,
        sched: &mut EventScheduler,
        metrics: &mut Collector,
    ) {
        if self.guard.reject(self.prefill_backlog.len()) {
            metrics.on_reject_as(req.id, RejectCause::QueueFull);
            return;
        }
        self.prefill_backlog.push_back(req);
        self.kick_prefill_fleet(now, sched);
    }

    fn on_instance_wake(&mut self, idx: usize, now: f64, sched: &mut EventScheduler,
                        metrics: &mut Collector) {
        if let Some((_, done)) = self.instances[idx].in_flight {
            if now + EPS < done {
                return;
            }
            if self.is_prefill_instance(idx) {
                // Prefill-side completion is internal bookkeeping: the
                // request's public first token happens on the decode side.
                // The scratch collector swallows the instance's trace
                // emissions too, so the flight-recorder spans are re-emitted
                // into the real collector below.
                let finished = {
                    let inst = &mut self.instances[idx];
                    inst.complete_batch(now, &mut self.scratch);
                    // Pull everything out of `running`: prefill instances
                    // never decode; KV leaves with the transfer.
                    let drained: Vec<SimReq> = inst.running.drain(..).collect();
                    for r in &drained {
                        inst.kv_used -= r.kv_tokens();
                    }
                    drained
                };
                let started = self.instances[idx].batch_started();
                metrics.trace_phase(TraceKind::PhasePrefill, idx as u32, started, now);
                for r in finished {
                    metrics.trace(TraceEvent::span(
                        TraceKind::ReqPrefill,
                        r.req.id,
                        idx as u32,
                        started,
                        now,
                    ));
                    self.start_transfer(r.req, idx, now, sched);
                }
            } else {
                self.instances[idx].complete_batch(now, metrics);
                self.retry_staged(now, sched);
            }
        }
        // Dispatch next work for this instance.
        if self.is_prefill_instance(idx) {
            self.kick_prefill_fleet(now, sched);
        } else {
            let inst = &mut self.instances[idx];
            if inst.idle() && !inst.running.is_empty() {
                let done = inst.start_decode(now);
                sched.at(done, Event::InstanceWake { instance: idx });
            }
        }
    }

    fn on_fault(
        &mut self,
        fault: FaultEvent,
        now: f64,
        sched: &mut EventScheduler,
        _metrics: &mut Collector,
    ) {
        match fault {
            FaultEvent::LinkDegrade { factor } => {
                self.churn.telemetry.faults += 1;
                self.link_factor = factor;
                return;
            }
            FaultEvent::LinkRestore => {
                self.churn.telemetry.faults += 1;
                self.link_factor = 1.0;
                return;
            }
            _ => {}
        }
        let wake = self.churn.on_fault(&mut self.instances, fault, now);
        if let FaultEvent::InstanceDown { instance } = fault {
            // KV already in flight toward the dead decode instance has
            // nowhere to land: its reservation died with the KV cache, so
            // the transfer is dropped (the stale TransferDone is ignored).
            let doomed: Vec<u64> = self
                .transfers
                .iter()
                .filter(|(_, t)| t.dest == instance)
                .map(|(id, _)| *id)
                .collect();
            for id in doomed {
                self.transfers.remove(&id);
                self.churn.telemetry.lost += 1;
            }
        }
        if let Some(instance) = wake {
            sched.at(now, Event::InstanceWake { instance });
            // A restored decode instance has fresh KV room: staged
            // transfers can move again (a wake alone only re-dispatches).
            if !self.is_prefill_instance(instance) {
                self.retry_staged(now, sched);
            }
        }
    }

    fn churn_telemetry(&self) -> Option<ChurnTelemetry> {
        self.churn.telemetry()
    }

    fn defense_telemetry(&self) -> Option<DefenseTelemetry> {
        self.guard.telemetry()
    }

    fn on_transfer_done(&mut self, transfer: u64, now: f64, sched: &mut EventScheduler,
                        metrics: &mut Collector) {
        self.network.complete(transfer);
        let Some(InTransit { req, dest, started }) = self.transfers.remove(&transfer) else {
            return;
        };
        // Decode-side admission: §3.3 first token (includes the transfer
        // wait). KV for the prompt was reserved at transfer start.
        metrics.trace(TraceEvent::span(
            TraceKind::Transfer,
            req.id,
            dest as u32,
            started,
            now,
        ));
        let inst = &mut self.instances[dest];
        let id = req.id;
        let done_already = req.output_len <= 1;
        let mut sr = SimReq::new(req);
        sr.prefilled = sr.req.input_len;
        sr.generated = 1;
        sr.first_token_at = Some(now);
        inst.kv_used += 1;
        metrics.on_first_token(id, now);
        if done_already {
            metrics.on_complete(id, now);
            inst.kv_used -= sr.kv_tokens();
        } else {
            inst.running.push(sr);
            if inst.idle() {
                sched.at(now, Event::InstanceWake { instance: dest });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::metrics::{attainment_fraction, SloSpec};
    use crate::perfmodel::ModelSpec;
    use crate::sim::run;
    use crate::workload::{Dataset, TraceGenerator};

    fn deployment(model: ModelSpec) -> Deployment {
        let mut d = Deployment::paper_default(model, ClusterSpec::l20_cluster());
        d.gpus_used = 32;
        d
    }

    #[test]
    fn mooncake_completes_light_load() {
        let d = deployment(ModelSpec::codellama_34b());
        let mut sys = FudgSystem::new(&d, FudgMode::MoonCake, 3, SystemParams::default());
        let trace = TraceGenerator::new(Dataset::sharegpt(), 1).poisson(2.0, 60.0);
        let n = trace.len();
        let mut m = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut m);
        assert_eq!(m.completed().len(), n);
        let frac = attainment_fraction(m.completed(), &SloSpec::new(5.0, 0.1));
        assert!(frac > 0.8, "{frac}");
    }

    #[test]
    fn distserve_prefers_same_node() {
        let d = deployment(ModelSpec::codellama_34b());
        // 8 instances, 2 per node: alternate P/D -> same-node pairs exist.
        let mut sys = FudgSystem::new(&d, FudgMode::DistServe, 4, SystemParams::default());
        let trace = TraceGenerator::new(Dataset::sharegpt(), 2).poisson(3.0, 60.0);
        let mut m = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut m);
        assert_eq!(
            sys.cross_node_transfers, 0,
            "balanced colocated layout should never cross nodes"
        );
    }

    #[test]
    fn mooncake_mha_kv_congests_ethernet() {
        // Llama-30B (MHA, 1.52 MiB/token) over 10 GbE: at moderate load the
        // transfer backlog should inflate TTFT well past the prefill time —
        // the paper's core FuDG-on-commodity-network failure mode.
        let d = deployment(ModelSpec::llama_30b());
        let mut sys = FudgSystem::new(&d, FudgMode::MoonCake, 3, SystemParams::default());
        let trace = TraceGenerator::new(Dataset::sharegpt(), 3).poisson(6.0, 90.0);
        let mut m = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut m);
        let slo = SloSpec::new(5.0, 0.1);
        let frac = attainment_fraction(m.completed(), &slo);
        assert!(frac < 0.9, "MHA KV over 10GbE should break SLOs, got {frac}");
    }

    #[test]
    fn gqa_transfers_far_cheaper_than_mha() {
        let d_mha = deployment(ModelSpec::llama_30b());
        let d_gqa = deployment(ModelSpec::codellama_34b());
        assert!(d_mha.model.kv_bytes_per_token() > 8.0 * d_gqa.model.kv_bytes_per_token());
    }

    #[test]
    fn decode_side_first_token_includes_transfer() {
        // A single request: TTFT must exceed prefill + transfer time.
        let d = deployment(ModelSpec::llama_30b());
        let mut sys = FudgSystem::new(&d, FudgMode::MoonCake, 1, SystemParams::default());
        let trace = vec![Request { id: 0, arrival: 0.0, input_len: 2048, output_len: 4 }];
        let mut m = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut m);
        assert_eq!(m.completed().len(), 1);
        let rec = &m.completed()[0];
        let prefill = sys.instances[sys.prefill_ids[0]].timer.prefill_time(&[2048]);
        let transfer = 2.0 * (2048.0 * d.model.kv_bytes_per_token()) / 1.1e9;
        assert!(
            rec.ttft() > prefill + transfer * 0.9,
            "ttft {} should include ~{}s transfer",
            rec.ttft(),
            transfer
        );
    }

    #[test]
    fn decode_crash_loses_in_flight_work_but_conserves_accounting() {
        use crate::sim::{run_faulted, Fault, FaultKind, FaultSchedule};
        let d = deployment(ModelSpec::codellama_34b());
        let mut sys = FudgSystem::new(&d, FudgMode::MoonCake, 3, SystemParams::default());
        let victim = sys.decode_ids[0];
        let trace = TraceGenerator::new(Dataset::sharegpt(), 5).poisson(4.0, 60.0);
        let n = trace.len();
        let faults = FaultSchedule::new(vec![Fault {
            at: 20.0,
            kind: FaultKind::Crash { instance: victim, down_s: 15.0 },
        }])
        .unwrap();
        let mut m = Collector::new();
        run_faulted(&mut sys, trace, &faults.events(&d), 10_000.0, &mut m, false);
        assert_eq!(sys.churn.telemetry.downs, 1);
        assert_eq!(sys.instances[victim].health, Health::Up, "restored");
        // No re-routing: everything resident (or in flight toward) the
        // victim is lost, and nothing else leaks.
        assert_eq!(m.completed().len() + sys.churn.telemetry.lost as usize, n);
        assert_eq!(m.in_flight(), sys.churn.telemetry.lost as usize);
    }

    #[test]
    fn link_degrade_inflates_mooncake_ttft() {
        use crate::sim::{run_faulted, Fault, FaultKind, FaultSchedule};
        use crate::util::percentile;
        let d = deployment(ModelSpec::llama_30b());
        let trace = TraceGenerator::new(Dataset::sharegpt(), 6).poisson(3.0, 60.0);

        let mut base = FudgSystem::new(&d, FudgMode::MoonCake, 3, SystemParams::default());
        let mut m0 = Collector::new();
        run(&mut base, trace.clone(), 10_000.0, &mut m0);

        let mut sys = FudgSystem::new(&d, FudgMode::MoonCake, 3, SystemParams::default());
        let faults = FaultSchedule::new(vec![Fault {
            at: 0.0,
            kind: FaultKind::LinkDegrade { factor: 8.0, for_s: 600.0 },
        }])
        .unwrap();
        let mut m1 = Collector::new();
        run_faulted(&mut sys, trace, &faults.events(&d), 10_000.0, &mut m1, false);

        let p90 = |m: &Collector| {
            let v: Vec<f64> = m.completed().iter().map(|r| r.ttft()).collect();
            percentile(&v, 90.0)
        };
        assert!(
            p90(&m1) > p90(&m0),
            "8x slower interconnect must hurt TTFT: {} vs {}",
            p90(&m1),
            p90(&m0)
        );
    }

    #[test]
    fn invalid_split_rejected() {
        let d = deployment(ModelSpec::codellama_34b());
        let r = std::panic::catch_unwind(|| {
            FudgSystem::new(&d, FudgMode::MoonCake, 8, SystemParams::default())
        });
        assert!(r.is_err(), "all-prefill split must be rejected");
    }
}
