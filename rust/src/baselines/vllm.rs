//! vLLM baseline: NoDG strategy, separate batching, prefill-priority
//! continuous batching (paper §2.2, §2.4.1, §4.1).
//!
//! Each instance owns the full request lifecycle. At every scheduling
//! point, waiting prefills run first (batched up to a token budget);
//! decodes only proceed when no prefill is waiting. This is the
//! interference the paper targets: arriving prefills continually delay
//! in-flight decodes (TPOT suffers), while decode batches stay small under
//! SLO pressure (throughput suffers).

use std::collections::VecDeque;

use super::{least_loaded_with_room, BaselineChurn, QueueGuard};
use crate::config::{Deployment, SystemParams};
use crate::metrics::Collector;
use crate::sim::{
    ChurnTelemetry, DefenseTelemetry, Event, EventScheduler, FaultEvent, Health, SimInstance,
    System,
};
use crate::trace::RejectCause;
use crate::workload::Request;

const EPS: f64 = 1e-9;

/// vLLM under simulation.
pub struct VllmSystem {
    pub instances: Vec<SimInstance>,
    pub backlog: VecDeque<Request>,
    pub params: SystemParams,
    /// Token budget per prefill batch (vLLM's max_num_batched_tokens).
    pub max_prefill_tokens: usize,
    /// Max prompts per prefill batch (vLLM's max_num_seqs for the waiting
    /// queue slice).
    pub max_prefill_reqs: usize,
    /// Native fault handling (crashes lose resident work).
    pub churn: BaselineChurn,
    /// Native overload handling (bounded waiting queue).
    pub guard: QueueGuard,
}

impl VllmSystem {
    pub fn new(deployment: &Deployment, params: SystemParams) -> Self {
        let n = deployment.num_instances();
        let instances = (0..n)
            .map(|i| SimInstance::new(i, deployment.timer(), deployment.kv_reserve_frac))
            .collect();
        let guard = QueueGuard::new(&params);
        VllmSystem {
            instances,
            backlog: VecDeque::new(),
            params,
            max_prefill_tokens: 8192,
            max_prefill_reqs: 16,
            churn: BaselineChurn::new(n),
            guard,
        }
    }

    fn try_admit(&mut self, req: &Request, now: f64, sched: &mut EventScheduler) -> bool {
        match least_loaded_with_room(&self.instances, req, self.params.admission_margin) {
            Some(idx) => {
                self.instances[idx].admit(req.clone());
                if self.instances[idx].idle() {
                    sched.at(now, Event::InstanceWake { instance: idx });
                }
                true
            }
            None => false,
        }
    }

    fn drain_backlog(&mut self, now: f64, sched: &mut EventScheduler) {
        while let Some(req) = self.backlog.front().cloned() {
            if self.try_admit(&req, now, sched) {
                self.backlog.pop_front();
            } else {
                break;
            }
        }
    }

    fn dispatch(&mut self, idx: usize, now: f64, sched: &mut EventScheduler) {
        let max_tokens = self.max_prefill_tokens;
        let max_reqs = self.max_prefill_reqs;
        let inst = &mut self.instances[idx];
        if inst.health == Health::Down || !inst.idle() {
            return;
        }
        if !inst.prefill_queue.is_empty() {
            // Prefill priority: batch waiting prompts up to the budget.
            let mut count = 0;
            let mut tokens = 0;
            for r in inst.prefill_queue.iter() {
                if count >= max_reqs || tokens + r.req.input_len > max_tokens {
                    break;
                }
                count += 1;
                tokens += r.req.input_len;
            }
            let count = count.max(1);
            let done = inst.start_prefill(count, now);
            sched.at(done, Event::InstanceWake { instance: idx });
        } else if !inst.running.is_empty() {
            let done = inst.start_decode(now);
            sched.at(done, Event::InstanceWake { instance: idx });
        }
    }
}

impl System for VllmSystem {
    fn on_arrival(
        &mut self,
        req: Request,
        now: f64,
        sched: &mut EventScheduler,
        metrics: &mut Collector,
    ) {
        if self.guard.reject(self.backlog.len()) {
            metrics.on_reject_as(req.id, RejectCause::QueueFull);
            return;
        }
        if !self.backlog.is_empty() || !self.try_admit(&req, now, sched) {
            self.backlog.push_back(req);
        }
    }

    fn on_instance_wake(&mut self, idx: usize, now: f64, sched: &mut EventScheduler,
                        metrics: &mut Collector) {
        if let Some((_, done)) = self.instances[idx].in_flight {
            if now + EPS < done {
                return;
            }
            self.instances[idx].complete_batch(now, metrics);
        }
        self.drain_backlog(now, sched);
        self.dispatch(idx, now, sched);
    }

    fn on_fault(
        &mut self,
        fault: FaultEvent,
        now: f64,
        sched: &mut EventScheduler,
        _metrics: &mut Collector,
    ) {
        if let Some(wake) = self.churn.on_fault(&mut self.instances, fault, now) {
            sched.at(now, Event::InstanceWake { instance: wake });
        }
    }

    fn churn_telemetry(&self) -> Option<ChurnTelemetry> {
        self.churn.telemetry()
    }

    fn defense_telemetry(&self) -> Option<DefenseTelemetry> {
        self.guard.telemetry()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::metrics::{attainment_fraction, SloSpec};
    use crate::perfmodel::ModelSpec;
    use crate::sim::run;
    use crate::workload::{Dataset, TraceGenerator};

    fn deployment() -> Deployment {
        let mut d = Deployment::paper_default(
            ModelSpec::codellama_34b(),
            ClusterSpec::l20_cluster(),
        );
        d.gpus_used = 16;
        d
    }

    #[test]
    fn completes_light_load() {
        let d = deployment();
        let mut sys = VllmSystem::new(&d, SystemParams::default());
        let trace = TraceGenerator::new(Dataset::sharegpt(), 1).poisson(2.0, 60.0);
        let n = trace.len();
        let mut m = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut m);
        assert_eq!(m.completed().len(), n);
        let frac = attainment_fraction(m.completed(), &SloSpec::new(5.0, 0.1));
        assert!(frac > 0.9, "light-load attainment {frac}");
    }

    #[test]
    fn prefill_priority_hurts_tpot_under_load() {
        // At meaningful load, vLLM's prefill-priority scheduling should
        // produce TPOT violations (the interference PaDG removes).
        let d = deployment();
        let mut sys = VllmSystem::new(&d, SystemParams::default());
        let trace = TraceGenerator::new(Dataset::sharegpt(), 2).poisson(14.0, 120.0);
        let mut m = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut m);
        let slo = SloSpec::new(5.0, 0.1);
        let tpot_violations = m
            .completed()
            .iter()
            .filter(|r| r.output_len > 1 && r.tpot() > slo.tpot)
            .count();
        assert!(
            tpot_violations > 0,
            "expected prefill-decode interference at load"
        );
    }

    #[test]
    fn many_phase_switches_under_mixed_load() {
        // NoDG alternates phases constantly compared to PaDG.
        let d = deployment();
        let mut sys = VllmSystem::new(&d, SystemParams::default());
        let trace = TraceGenerator::new(Dataset::sharegpt(), 3).poisson(8.0, 60.0);
        let n = trace.len() as u64;
        let mut m = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut m);
        let switches: u64 = sys.instances.iter().map(|i| i.switches).sum();
        assert!(switches > n / 2, "switches {switches} vs requests {n}");
    }

    #[test]
    fn kv_quiescence() {
        let d = deployment();
        let mut sys = VllmSystem::new(&d, SystemParams::default());
        let trace = TraceGenerator::new(Dataset::alpaca(), 4).poisson(3.0, 30.0);
        let mut m = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut m);
        for inst in &sys.instances {
            assert_eq!(inst.kv_used, 0);
        }
        assert_eq!(m.in_flight(), 0);
    }
}
