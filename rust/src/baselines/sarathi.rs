//! Sarathi-Serve baseline: NoDG strategy with hybrid batching, chunked
//! prefill, and decode priority (paper §2.4.1, §4.1).
//!
//! Every iteration packs all running decodes plus up to `chunk` tokens of
//! the head-of-queue prompt into one hybrid batch. Decodes are never
//! stalled behind whole prompts (better TPOT than vLLM), but chunked
//! prefill re-reads the growing prompt KV every chunk — the overhead whose
//! "effectiveness heavily depends on the input-to-output length ratio".

use std::collections::VecDeque;

use super::{least_loaded_with_room, BaselineChurn, QueueGuard};
use crate::config::{Deployment, SystemParams};
use crate::metrics::Collector;
use crate::sim::{
    ChurnTelemetry, DefenseTelemetry, Event, EventScheduler, FaultEvent, Health, SimInstance,
    System,
};
use crate::trace::RejectCause;
use crate::workload::Request;

const EPS: f64 = 1e-9;

/// Sarathi under simulation.
pub struct SarathiSystem {
    pub instances: Vec<SimInstance>,
    pub backlog: VecDeque<Request>,
    pub params: SystemParams,
    /// Native fault handling (crashes lose resident work).
    pub churn: BaselineChurn,
    /// Native overload handling (bounded waiting queue).
    pub guard: QueueGuard,
}

impl SarathiSystem {
    pub fn new(deployment: &Deployment, params: SystemParams) -> Self {
        let n = deployment.num_instances();
        let instances = (0..n)
            .map(|i| SimInstance::new(i, deployment.timer(), deployment.kv_reserve_frac))
            .collect();
        let guard = QueueGuard::new(&params);
        SarathiSystem {
            instances,
            backlog: VecDeque::new(),
            params,
            churn: BaselineChurn::new(n),
            guard,
        }
    }

    fn try_admit(&mut self, req: &Request, now: f64, sched: &mut EventScheduler) -> bool {
        match least_loaded_with_room(&self.instances, req, self.params.admission_margin) {
            Some(idx) => {
                self.instances[idx].admit(req.clone());
                if self.instances[idx].idle() {
                    sched.at(now, Event::InstanceWake { instance: idx });
                }
                true
            }
            None => false,
        }
    }

    fn drain_backlog(&mut self, now: f64, sched: &mut EventScheduler) {
        while let Some(req) = self.backlog.front().cloned() {
            if self.try_admit(&req, now, sched) {
                self.backlog.pop_front();
            } else {
                break;
            }
        }
    }

    fn dispatch(&mut self, idx: usize, now: f64, sched: &mut EventScheduler) {
        let chunk = self.params.sarathi_chunk;
        let inst = &mut self.instances[idx];
        if inst.health == Health::Down || !inst.idle() || !inst.has_work() {
            return;
        }
        let done = inst.start_hybrid(chunk, now);
        sched.at(done, Event::InstanceWake { instance: idx });
    }
}

impl System for SarathiSystem {
    fn on_arrival(
        &mut self,
        req: Request,
        now: f64,
        sched: &mut EventScheduler,
        metrics: &mut Collector,
    ) {
        if self.guard.reject(self.backlog.len()) {
            metrics.on_reject_as(req.id, RejectCause::QueueFull);
            return;
        }
        if !self.backlog.is_empty() || !self.try_admit(&req, now, sched) {
            self.backlog.push_back(req);
        }
    }

    fn on_instance_wake(&mut self, idx: usize, now: f64, sched: &mut EventScheduler,
                        metrics: &mut Collector) {
        if let Some((_, done)) = self.instances[idx].in_flight {
            if now + EPS < done {
                return;
            }
            self.instances[idx].complete_batch(now, metrics);
        }
        self.drain_backlog(now, sched);
        self.dispatch(idx, now, sched);
    }

    fn on_fault(
        &mut self,
        fault: FaultEvent,
        now: f64,
        sched: &mut EventScheduler,
        _metrics: &mut Collector,
    ) {
        if let Some(wake) = self.churn.on_fault(&mut self.instances, fault, now) {
            sched.at(now, Event::InstanceWake { instance: wake });
        }
    }

    fn churn_telemetry(&self) -> Option<ChurnTelemetry> {
        self.churn.telemetry()
    }

    fn defense_telemetry(&self) -> Option<DefenseTelemetry> {
        self.guard.telemetry()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterSpec;
    use crate::metrics::{attainment_fraction, SloSpec};
    use crate::perfmodel::ModelSpec;
    use crate::sim::run;
    use crate::workload::{Dataset, TraceGenerator};

    fn deployment() -> Deployment {
        let mut d = Deployment::paper_default(
            ModelSpec::codellama_34b(),
            ClusterSpec::l20_cluster(),
        );
        d.gpus_used = 16;
        d
    }

    #[test]
    fn completes_light_load() {
        let d = deployment();
        let mut sys = SarathiSystem::new(&d, SystemParams::default());
        let trace = TraceGenerator::new(Dataset::sharegpt(), 1).poisson(2.0, 60.0);
        let n = trace.len();
        let mut m = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut m);
        assert_eq!(m.completed().len(), n);
        let frac = attainment_fraction(m.completed(), &SloSpec::new(5.0, 0.1));
        assert!(frac > 0.9, "{frac}");
    }

    #[test]
    fn better_tpot_than_vllm_under_load() {
        // Decode-priority hybrid batching should beat vLLM's prefill
        // priority on p90 TPOT at the same offered load.
        use crate::baselines::vllm::VllmSystem;
        use crate::util::percentile;
        let d = deployment();
        let trace = TraceGenerator::new(Dataset::sharegpt(), 2).poisson(10.0, 120.0);

        let mut sarathi = SarathiSystem::new(&d, SystemParams::default());
        let mut m1 = Collector::new();
        run(&mut sarathi, trace.clone(), 10_000.0, &mut m1);
        let tp1: Vec<f64> = m1.completed().iter().map(|r| r.tpot()).collect();

        let mut vllm = VllmSystem::new(&d, SystemParams::default());
        let mut m2 = Collector::new();
        run(&mut vllm, trace, 10_000.0, &mut m2);
        let tp2: Vec<f64> = m2.completed().iter().map(|r| r.tpot()).collect();

        assert!(
            percentile(&tp1, 90.0) < percentile(&tp2, 90.0),
            "sarathi p90 tpot {} should beat vllm {}",
            percentile(&tp1, 90.0),
            percentile(&tp2, 90.0)
        );
    }

    #[test]
    fn chunked_prefill_slows_long_prompts() {
        // A LongBench-style prompt takes longer to first token under
        // chunking than under whole-prompt prefill (the KV re-read tax),
        // holding hardware fixed.
        let d = deployment();
        let inst_timer = d.timer();
        let whole = inst_timer.prefill_time(&[4096]);
        let mut chunked = 0.0;
        let chunk = 512;
        let mut done = 0;
        while done < 4096 {
            chunked += inst_timer.hybrid_iter_time(0, 0, chunk, done + chunk);
            done += chunk;
        }
        assert!(chunked > whole, "chunked {chunked} vs whole {whole}");
    }

    #[test]
    fn kv_quiescence() {
        let d = deployment();
        let mut sys = SarathiSystem::new(&d, SystemParams::default());
        let trace = TraceGenerator::new(Dataset::longbench(), 4).poisson(1.0, 30.0);
        let mut m = Collector::new();
        run(&mut sys, trace, 10_000.0, &mut m);
        for inst in &sys.instances {
            assert_eq!(inst.kv_used, 0);
        }
        assert_eq!(m.in_flight(), 0);
    }
}
