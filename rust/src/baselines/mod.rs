//! The four comparison systems of the paper's evaluation (§4.1):
//!
//! * [`vllm`] — NoDG, separate batching, prefill-priority continuous
//!   batching (vLLM's default scheduler).
//! * [`sarathi`] — NoDG, hybrid batching with chunked prefill,
//!   decode-priority (Sarathi-Serve).
//! * [`fudg`] — the two fully-disaggregated systems: DistServe (intra-node
//!   KV hops) and MoonCake (inter-node hops through a central KV pool).
//!
//! All share the same [`crate::sim::SimInstance`] hardware model as
//! EcoServe — only the scheduling policy differs, which is exactly the
//! comparison the paper makes.

pub mod fudg;
pub mod sarathi;
pub mod vllm;

pub use fudg::{FudgMode, FudgSystem};
pub use sarathi::SarathiSystem;
pub use vllm::VllmSystem;

use crate::sim::SimInstance;
use crate::workload::Request;

/// Least-outstanding-load routing used by both NoDG baselines: pick the
/// instance with the smallest (KV in use + queued prompt tokens) that has
/// KV room; `None` when every instance is at capacity.
pub fn least_loaded_with_room(
    instances: &[SimInstance],
    req: &Request,
    margin: usize,
) -> Option<usize> {
    instances
        .iter()
        .filter(|i| i.kv_room_for(req.input_len, margin))
        .min_by_key(|i| {
            i.kv_used + i.prefill_queue.iter().map(|r| r.req.input_len).sum::<usize>()
        })
        .map(|i| i.id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::interconnect::LinkSpec;
    use crate::perfmodel::parallelism::ParallelCfg;
    use crate::perfmodel::{BatchTimer, GpuSpec, ModelSpec};

    fn instances(n: usize) -> Vec<SimInstance> {
        (0..n)
            .map(|i| {
                let timer = BatchTimer::new(
                    ModelSpec::codellama_34b(),
                    GpuSpec::l20(),
                    ParallelCfg::tp_only(4, LinkSpec::pcie4()),
                );
                SimInstance::new(i, timer, 0.1)
            })
            .collect()
    }

    fn req(input: usize) -> Request {
        Request { id: 1, arrival: 0.0, input_len: input, output_len: 10 }
    }

    #[test]
    fn picks_least_loaded() {
        let mut insts = instances(3);
        insts[0].kv_used = 5000;
        insts[1].kv_used = 3000;
        insts[2].kv_used = 100;
        assert_eq!(least_loaded_with_room(&insts, &req(64), 0), Some(2));
    }

    #[test]
    fn skips_full_instances() {
        let mut insts = instances(2);
        insts[0].kv_used = insts[0].kv_capacity;
        assert_eq!(least_loaded_with_room(&insts, &req(64), 0), Some(1));
        insts[1].kv_used = insts[1].kv_capacity;
        assert_eq!(least_loaded_with_room(&insts, &req(64), 0), None);
    }
}
