//! The four comparison systems of the paper's evaluation (§4.1):
//!
//! * [`vllm`] — NoDG, separate batching, prefill-priority continuous
//!   batching (vLLM's default scheduler).
//! * [`sarathi`] — NoDG, hybrid batching with chunked prefill,
//!   decode-priority (Sarathi-Serve).
//! * [`fudg`] — the two fully-disaggregated systems: DistServe (intra-node
//!   KV hops) and MoonCake (inter-node hops through a central KV pool).
//!
//! All share the same [`crate::sim::SimInstance`] hardware model as
//! EcoServe — only the scheduling policy differs, which is exactly the
//! comparison the paper makes.

pub mod fudg;
pub mod sarathi;
pub mod vllm;

pub use fudg::{FudgMode, FudgSystem};
pub use sarathi::SarathiSystem;
pub use vllm::VllmSystem;

use crate::config::SystemParams;
use crate::sim::{ChurnTelemetry, DefenseTelemetry, FaultEvent, Health, SimInstance};
use crate::workload::Request;

/// Least-outstanding-load routing used by both NoDG baselines: pick the
/// healthy instance with the smallest (KV in use + queued prompt tokens)
/// that has KV room; `None` when every instance is at capacity. The health
/// filter models the load balancer's liveness probe — even baseline stacks
/// stop sending traffic to a dead replica.
pub fn least_loaded_with_room(
    instances: &[SimInstance],
    req: &Request,
    margin: usize,
) -> Option<usize> {
    instances
        .iter()
        .filter(|i| i.health == Health::Up && i.kv_room_for(req.input_len, margin))
        .min_by_key(|i| {
            i.kv_used + i.prefill_queue.iter().map(|r| r.req.input_len).sum::<usize>()
        })
        .map(|i| i.id)
}

/// Native overload handling shared by the baselines: a bounded waiting
/// queue, nothing more. When a run enables coordinator defenses
/// ([`SystemParams::defense`]), each baseline bounces new arrivals once
/// its global backlog reaches the configured cap — the serving-stack
/// equivalent of an HTTP 503 from a full admission queue. No deadline
/// awareness, no priority classes, no brownout: that is the (weaker)
/// native handling real NoDG/FuDG stacks ship with, so overload
/// scenarios stay a fair fight the same way [`BaselineChurn`] keeps
/// churn scenarios fair.
#[derive(Debug, Default)]
pub struct QueueGuard {
    cap: Option<usize>,
    pub stats: DefenseTelemetry,
}

impl QueueGuard {
    pub fn new(params: &SystemParams) -> Self {
        let cap = if params.ablate_no_shedding {
            None
        } else {
            params.defense.map(|d| d.backlog_cap)
        };
        QueueGuard { cap, stats: DefenseTelemetry::default() }
    }

    /// True when the arrival must be bounced (backlog at or past the cap).
    pub fn reject(&mut self, backlog_len: usize) -> bool {
        match self.cap {
            Some(cap) if backlog_len >= cap => {
                self.stats.queue_full_rejects += 1;
                true
            }
            _ => false,
        }
    }

    /// `Some` whenever a cap was configured, so defended-but-quiet runs
    /// still report a zeroed block (mirrors PaDG's defense telemetry).
    pub fn telemetry(&self) -> Option<DefenseTelemetry> {
        self.cap.map(|_| self.stats)
    }
}

/// Native fault handling shared by the baselines: no coordinator-level
/// re-routing — everything resident on a crashed replica is lost, the
/// restored replica simply rejoins the pool, preemption notices are
/// ignored, and recovery latency is the raw outage duration. This is the
/// (weaker) recovery the paper's comparison systems get so churn scenarios
/// stay a fair fight.
#[derive(Debug, Default)]
pub struct BaselineChurn {
    pub telemetry: ChurnTelemetry,
    down_since: Vec<Option<f64>>,
}

impl BaselineChurn {
    pub fn new(n: usize) -> Self {
        BaselineChurn { telemetry: ChurnTelemetry::default(), down_since: vec![None; n] }
    }

    /// Apply one fault event. Returns the instance to wake, if the event
    /// restored one.
    pub fn on_fault(
        &mut self,
        instances: &mut [SimInstance],
        fault: FaultEvent,
        now: f64,
    ) -> Option<usize> {
        self.telemetry.faults += 1;
        match fault {
            FaultEvent::InstanceDown { instance } => {
                self.telemetry.downs += 1;
                if instance >= instances.len() || instances[instance].health == Health::Down {
                    return None;
                }
                let lost = instances[instance].crash();
                self.telemetry.lost += lost.len() as u64;
                self.down_since[instance] = Some(now);
                None
            }
            FaultEvent::InstanceUp { instance } => {
                if instance >= instances.len() || instances[instance].health != Health::Down {
                    return None;
                }
                instances[instance].restore();
                if let Some(t0) = self.down_since[instance].take() {
                    self.telemetry.recovery_s_sum += now - t0;
                    self.telemetry.recoveries += 1;
                }
                Some(instance)
            }
            FaultEvent::PreemptNotice { .. } => {
                self.telemetry.notices += 1;
                None
            }
            FaultEvent::LinkDegrade { .. } | FaultEvent::LinkRestore => None,
        }
    }

    pub fn telemetry(&self) -> Option<ChurnTelemetry> {
        if self.telemetry.any() {
            Some(self.telemetry.clone())
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::interconnect::LinkSpec;
    use crate::perfmodel::parallelism::ParallelCfg;
    use crate::perfmodel::{BatchTimer, GpuSpec, ModelSpec};

    fn instances(n: usize) -> Vec<SimInstance> {
        (0..n)
            .map(|i| {
                let timer = BatchTimer::new(
                    ModelSpec::codellama_34b(),
                    GpuSpec::l20(),
                    ParallelCfg::tp_only(4, LinkSpec::pcie4()),
                );
                SimInstance::new(i, timer, 0.1)
            })
            .collect()
    }

    fn req(input: usize) -> Request {
        Request { id: 1, arrival: 0.0, input_len: input, output_len: 10 }
    }

    #[test]
    fn picks_least_loaded() {
        let mut insts = instances(3);
        insts[0].kv_used = 5000;
        insts[1].kv_used = 3000;
        insts[2].kv_used = 100;
        assert_eq!(least_loaded_with_room(&insts, &req(64), 0), Some(2));
    }

    #[test]
    fn skips_full_instances() {
        let mut insts = instances(2);
        insts[0].kv_used = insts[0].kv_capacity;
        assert_eq!(least_loaded_with_room(&insts, &req(64), 0), Some(1));
        insts[1].kv_used = insts[1].kv_capacity;
        assert_eq!(least_loaded_with_room(&insts, &req(64), 0), None);
    }

    #[test]
    fn skips_down_instances() {
        let mut insts = instances(2);
        insts[0].health = Health::Down;
        assert_eq!(least_loaded_with_room(&insts, &req(64), 0), Some(1));
    }

    #[test]
    fn queue_guard_is_inert_until_defenses_are_configured() {
        use crate::config::DefenseConfig;
        let mut off = QueueGuard::new(&SystemParams::default());
        assert!(!off.reject(usize::MAX / 2), "no cap configured: never rejects");
        assert!(off.telemetry().is_none());

        let defended = SystemParams {
            defense: Some(DefenseConfig { backlog_cap: 2, ..DefenseConfig::default() }),
            ..SystemParams::default()
        };
        let mut on = QueueGuard::new(&defended);
        assert!(!on.reject(1));
        assert!(on.reject(2), "at cap: bounce");
        assert!(on.reject(3));
        assert_eq!(on.telemetry().unwrap().queue_full_rejects, 2);

        let ablated = SystemParams { ablate_no_shedding: true, ..defended };
        let mut ab = QueueGuard::new(&ablated);
        assert!(!ab.reject(100), "ablation switches the native cap off too");
        assert!(ab.telemetry().is_none());
    }

    #[test]
    fn baseline_churn_loses_residents_and_times_the_outage() {
        let mut insts = instances(2);
        insts[1].admit(req(100));
        let mut churn = BaselineChurn::new(2);
        assert!(churn
            .on_fault(&mut insts, FaultEvent::InstanceDown { instance: 1 }, 10.0)
            .is_none());
        assert_eq!(insts[1].health, Health::Down);
        assert_eq!(churn.telemetry.lost, 1);
        assert_eq!(insts[1].kv_used, 0);
        // Duplicate Down (merged windows are defensive-guarded) is a no-op.
        churn.on_fault(&mut insts, FaultEvent::InstanceDown { instance: 1 }, 11.0);
        assert_eq!(churn.telemetry.lost, 1);
        let wake = churn.on_fault(&mut insts, FaultEvent::InstanceUp { instance: 1 }, 25.0);
        assert_eq!(wake, Some(1));
        assert_eq!(insts[1].health, Health::Up);
        let t = churn.telemetry().unwrap();
        assert_eq!(t.recoveries, 1);
        assert!((t.mean_recovery_s() - 15.0).abs() < 1e-12);
    }
}
