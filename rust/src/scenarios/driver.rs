//! Scenario execution: drive every (scenario × system) cell through the
//! discrete-event simulator and score it the strict way the harness does —
//! attainment over requests that *arrived* in the measurement window, with
//! never-completed requests counted as violations — plus per-class scoring
//! against each traffic class's own SLO pair.

use std::time::Duration;

use super::registry::Scenario;
use super::spec::RunSpec;
use crate::config::{ClusterSpec, Deployment, ExperimentConfig, SystemKind};
use crate::coordinator::{AutoScalePolicy, EcoServeSystem};
use crate::harness::build_system;
use crate::metrics::{summarize_from, Collector, SloMonitor, SloSpec, Summary};
use crate::perfmodel::ModelSpec;
use crate::sim::{
    run_abandonable, run_faulted_client, run_source_faulted_client, ChurnTelemetry,
    ClassRanker, DefenseTelemetry, StopReason, System,
};
use crate::trace::{summarize, TraceCapture, TraceSink};
use crate::util::threads::parallel_map;
use crate::workload::{ClientLoop, ClientTelemetry, RETRY_ID_BASE};

/// How long past the trace end the simulator may drain in-flight requests
/// (mirrors the goodput harness).
pub const DRAIN_SECS: f64 = 240.0;

/// Shared knobs for a scenario run.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub deployment: Deployment,
    pub seed: u64,
    /// Time-averaged offered rate (req/s); `None` uses each scenario's
    /// `default_rate`.
    pub rate: Option<f64>,
    /// Override the scenario horizon (quick CLI runs / tests). The warmup
    /// is clamped to stay inside the shortened horizon.
    pub duration_override: Option<f64>,
    /// Seed for expanding a churn scenario's [`crate::sim::ChurnProfile`]
    /// into a concrete fault timeline (`--fault-seed`). `None` runs even
    /// churn scenarios fault-free.
    pub fault_seed: Option<u64>,
    /// Attach the flight recorder to every cell (`--trace-out`); `false`
    /// keeps every run on the recorder-off warm path.
    pub trace: bool,
}

impl ScenarioConfig {
    /// The paper's default evaluation deployment: 8 instances of
    /// CodeLlama2-34B at TP=4 on the L20 cluster.
    pub fn default_l20() -> Self {
        ScenarioConfig {
            deployment: Deployment::paper_default(
                ModelSpec::codellama_34b(),
                ClusterSpec::l20_cluster(),
            ),
            seed: 42,
            rate: None,
            duration_override: None,
            fault_seed: None,
            trace: false,
        }
    }

    /// (duration, warmup) actually used for `scenario` under this config
    /// — at the configured rate (replay horizons are rate-dependent; see
    /// [`ScenarioConfig::horizon_at`]).
    pub fn horizon(&self, scenario: &Scenario) -> (f64, f64) {
        self.horizon_at(scenario, self.rate.unwrap_or(scenario.default_rate))
    }

    /// (duration, warmup) for `scenario` probed at `rate`. Synthetic
    /// horizons are rate-independent; replayed logs scale with the time
    /// warp ([`Scenario::horizon_at`]). A `duration_override` truncates,
    /// but for replay never extends past the warped span — a longer
    /// window would trail a dead tail and dilute the offered rate below
    /// the probe rate.
    pub fn horizon_at(&self, scenario: &Scenario, rate: f64) -> (f64, f64) {
        let (native_d, native_w) = scenario.horizon_at(rate);
        match self.duration_override {
            Some(d) => {
                let d = if scenario.is_replay() { d.min(native_d) } else { d };
                (d, native_w.min(d / 4.0))
            }
            None => (native_d, native_w),
        }
    }
}

/// Per-traffic-class strict score.
#[derive(Debug, Clone)]
pub struct ClassScore {
    pub class: &'static str,
    pub arrived: usize,
    pub met: usize,
    pub attainment: f64,
}

/// How to instantiate the serving system for one cell. The default is the
/// fixed-capacity paper configuration every suite run used so far.
#[derive(Debug, Clone, Default)]
pub struct VariantSpec {
    /// PaDG only (ignored by the baselines): run with the mitosis
    /// autoscaler on, starting from `N_l` active instances that may grow
    /// to the full deployment (paper Figure 10).
    pub autoscale: Option<AutoScalePolicy>,
}

impl VariantSpec {
    /// The mitosis-on variant with the Figure-10 default policy.
    pub fn autoscaled() -> Self {
        VariantSpec { autoscale: Some(AutoScalePolicy::default()) }
    }
}

/// What the mitosis controller actually did during an autoscaled run.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleTelemetry {
    pub scale_ups: usize,
    pub scale_downs: usize,
    /// Highest concurrently-active instance count observed.
    pub peak_active: usize,
    /// Active instances when the run ended.
    pub final_active: usize,
    /// Macro-instance membership shape at the end (e.g. `[6, 4]`).
    pub final_macros: Vec<usize>,
}

/// What the closed loop and the coordinator defenses did during an
/// overload cell — assembled from the client's counters and the system's
/// [`DefenseTelemetry`] after the run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OverloadTelemetry {
    /// Client-side counters (timeouts, rejections observed, retries,
    /// give-ups, confirmed first tokens).
    pub client: ClientTelemetry,
    /// Coordinator-side defense counters; `None` when the system ran
    /// undefended (or the ablation nulled its defense set).
    pub defense: Option<DefenseTelemetry>,
}

/// One system's outcome on one scenario.
#[derive(Debug)]
pub struct SystemRow {
    pub system: SystemKind,
    /// Requests arriving inside the measurement window.
    pub arrived: usize,
    /// Of those, completed before the drain horizon.
    pub completed: usize,
    /// Of those, completed AND meeting their class's SLO pair.
    pub met: usize,
    /// Strict attainment = met / arrived.
    pub attainment: f64,
    /// SLO-meeting completions per second of measurement window — the
    /// goodput actually delivered at this operating point.
    pub goodput_rps: f64,
    pub summary: Summary,
    pub classes: Vec<ClassScore>,
    pub events: u64,
    /// Events still queued when the SLO monitor aborted the run (0 on
    /// full runs) — a lower bound on the work abandonment avoided.
    pub events_saved: u64,
    /// True when the run was cut short because the attainment target
    /// became mathematically unreachable for some traffic class.
    pub abandoned: bool,
    /// Heap allocations on the simulation thread during the run (engine
    /// structures are pooled, so warm reruns spend these only in the
    /// simulated systems' own handlers).
    pub allocs: u64,
    /// Simulation wall time for this run.
    pub wall: Duration,
    /// Present on mitosis-on (autoscaled) runs only.
    pub autoscale: Option<AutoscaleTelemetry>,
    /// Present when the run saw injected faults (churn scenarios run
    /// with a fault seed): what the system's recovery machinery did.
    pub churn: Option<ChurnTelemetry>,
    /// Present when the spec attached a closed-loop client or armed the
    /// coordinator defenses: what the loop and the defenses did.
    pub overload: Option<OverloadTelemetry>,
    /// Present when the spec attached the flight recorder: the raw event
    /// log plus the derived diagnostics ([`crate::trace::TraceSummary`]).
    pub trace: Option<TraceCapture>,
}

impl SystemRow {
    /// The frontier's sustain criterion: the *weakest* class must hold the
    /// target — a system cannot buy batch goodput with interactive misses.
    pub fn min_class_attainment(&self) -> f64 {
        self.classes
            .iter()
            .map(|c| c.attainment)
            .fold(self.attainment, f64::min)
    }
}

/// All systems' outcomes on one scenario.
#[derive(Debug)]
pub struct ScenarioOutcome {
    pub scenario: Scenario,
    /// Offered time-averaged rate used for this run.
    pub rate: f64,
    pub duration: f64,
    pub warmup: f64,
    pub rows: Vec<SystemRow>,
}

impl ScenarioOutcome {
    /// The row with the highest strict attainment (ties: higher goodput).
    pub fn best(&self) -> Option<&SystemRow> {
        self.rows.iter().max_by(|a, b| {
            (a.attainment, a.goodput_rps)
                .partial_cmp(&(b.attainment, b.goodput_rps))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
    }

    pub fn row(&self, kind: SystemKind) -> Option<&SystemRow> {
        self.rows.iter().find(|r| r.system == kind)
    }
}

/// Run one system through one scenario with the cell's default spec:
/// fixed capacity, monitor off, and — for churn scenarios under a
/// `fault_seed` — the scenario's deterministic fault schedule.
pub fn run_system(scenario: &Scenario, cfg: &ScenarioConfig, kind: SystemKind) -> SystemRow {
    run_system_variant(scenario, cfg, &RunSpec::for_cell(scenario, cfg, kind))
}

/// Run one fully-specified cell through one scenario. Deterministic: the
/// trace is a pure function of (scenario, seed, rate), the fault timeline
/// of (profile, fault seed), and the simulator is event-ordered.
pub fn run_system_variant(
    scenario: &Scenario,
    cfg: &ScenarioConfig,
    spec: &RunSpec,
) -> SystemRow {
    let kind = spec.system;
    let (duration, warmup) = cfg.horizon(scenario);
    let rate = cfg.rate.unwrap_or(scenario.default_rate);
    // Streamed scenarios never materialize the log: scoring prep walks
    // the arrival stream once, then the engine consumes a fresh stream.
    // Everything downstream — windowed per-class scoring, the SLO
    // monitor, fault injection, drain — is byte-identical between the
    // two feeds (the integration tests pin this per system).
    let streamed = scenario.stream();
    let trace: Vec<crate::workload::Request> = match streamed {
        Some(_) => Vec::new(),
        None => scenario.build_trace_for(cfg.seed, rate, duration),
    };

    // Scoring prep in one arrival-ordered pass: per-class arrived counts
    // over the measurement window and — when a frontier probe arms the
    // online SLO monitor — a watch on every window arrival against its
    // own class's SLO pair. The run is later scored through the monitor's
    // decision snapshot, identically whether or not the simulation is
    // actually cut short at that point.
    let n_classes = scenario.classes.len();
    let mut arrived_per_class = vec![0usize; n_classes];
    let mut monitor = spec.abandon.map(|policy| SloMonitor::new(policy.target, n_classes));
    {
        let mut prep = |req: &crate::workload::Request| {
            if req.arrival >= warmup && req.arrival < duration {
                let k = scenario.class_of(req.id);
                arrived_per_class[k] += 1;
                if let Some(mon) = monitor.as_mut() {
                    let d = &scenario.classes[k].dataset;
                    mon.track(
                        req.id,
                        req.arrival,
                        SloSpec::new(d.slo_ttft, d.slo_tpot),
                        k,
                        req.output_len,
                    );
                }
            }
        };
        match streamed {
            Some(stream) => {
                let arr = stream.arrivals_at(rate, duration).unwrap_or_else(|e| {
                    panic!("streamed trace '{}' unreadable: {e:#}", stream.source())
                });
                for req in arr {
                    prep(&req);
                }
            }
            None => {
                for req in &trace {
                    prep(req);
                }
            }
        }
    }

    // The scheduler sees the tightest class's SLO pair; scoring below is
    // per class against each class's own SLOs.
    let sched = scenario.scheduler_dataset();
    let sched_slo = SloSpec::new(sched.slo_ttft, sched.slo_tpot);
    let mut exp = ExperimentConfig::new(cfg.deployment.clone(), sched);
    exp.seed = cfg.seed;
    exp.duration = duration;
    exp.warmup = warmup;
    // Coordinator-side defenses ride the system params: PaDG builds its
    // full defense set from them, the baselines their native queue cap,
    // and the ablation nulls both without touching anything else.
    exp.params.defense = spec.defense;
    exp.params.ablate_no_shedding = spec.ablate_no_shedding;

    // The closed-loop client. Its timeout is clamped to the loosest
    // class TTFT SLO so every timed-out attempt is an SLO violation too
    // — scoring stays anchored on first attempts either way, but the
    // clamp keeps "timed out" and "missed SLO" from ever disagreeing.
    let mut client = spec.client.map(|mut policy| {
        let loosest = scenario
            .classes
            .iter()
            .map(|c| c.dataset.slo_ttft)
            .fold(0.0_f64, f64::max);
        policy.timeout_s = policy.timeout_s.max(loosest);
        ClientLoop::new(policy)
    });

    // Priority ranking for the defended coordinator's triage: tighter
    // TTFT classes rank higher (0 sheds last), retry attempts rank
    // strictly worst so the storm is shed before first-attempt traffic.
    // Synthetic traces tag classes as the id residue; replayed logs
    // carry a side table instead, but replay cells are single-class in
    // practice and a rank-0 miss only makes shedding less aggressive.
    let ranker: Option<ClassRanker> = spec.defense.map(|_| {
        let ttfts: Vec<f64> = scenario.classes.iter().map(|c| c.dataset.slo_ttft).collect();
        let rank_of_class: Vec<usize> = ttfts
            .iter()
            .map(|t| ttfts.iter().filter(|u| **u < *t).count())
            .collect();
        let worst = rank_of_class.len();
        let n = rank_of_class.len() as u64;
        std::sync::Arc::new(move |id: u64| {
            if id >= RETRY_ID_BASE {
                worst
            } else {
                rank_of_class[(id % n) as usize]
            }
        }) as ClassRanker
    });

    // Pooled: suite runs execute many cells per worker thread, and the
    // collector's maps/vecs are the largest per-run allocations.
    let mut metrics = Collector::pooled(monitor);
    if spec.trace {
        metrics.attach_sink(TraceSink::new());
    }
    let stop_early = spec.abandon.is_some_and(|p| p.stop_early);
    // Expanding the schedule against the deployment happens once per run;
    // `None` keeps the run on the exact fault-free code path (the engine's
    // sequence numbering is untouched by an absent fault timeline).
    let fault_events = spec.faults.as_ref().map(|s| s.events(&cfg.deployment));
    let horizon = duration + DRAIN_SECS;
    // Pass B: a fresh stream for the engine. The arrival cutoff matches
    // the materialized path's clip at `duration`; the engine still runs
    // to `horizon` so in-flight work drains. With an empty fault slice
    // `run_source_faulted` is bit-identical to `run_abandonable` on the
    // same arrivals, so one call site covers all four combinations.
    let mut source = streamed.map(|stream| {
        stream.arrivals_at(rate, duration).unwrap_or_else(|e| {
            panic!("streamed trace '{}' unreadable: {e:#}", stream.source())
        })
    });
    let (stats, autoscale, churn, defense_t) = match &spec.variant.autoscale {
        Some(policy) if kind == SystemKind::EcoServe => {
            let mut sys = EcoServeSystem::with_autoscale(
                &exp.deployment,
                sched_slo,
                exp.params.clone(),
                policy.clone(),
            );
            if let Some(r) = ranker {
                sys.set_class_ranker(r);
            }
            let initial = sys.active_count();
            let stats = match source.as_mut() {
                Some(arr) => run_source_faulted_client(
                    &mut sys,
                    arr,
                    fault_events.as_deref().unwrap_or(&[]),
                    client.as_mut(),
                    horizon,
                    &mut metrics,
                    stop_early,
                ),
                None => match &fault_events {
                    Some(ev) => run_faulted_client(
                        &mut sys,
                        trace,
                        ev,
                        client.as_mut(),
                        horizon,
                        &mut metrics,
                        stop_early,
                    ),
                    None if client.is_some() => run_faulted_client(
                        &mut sys,
                        trace,
                        &[],
                        client.as_mut(),
                        horizon,
                        &mut metrics,
                        stop_early,
                    ),
                    None => run_abandonable(&mut sys, trace, horizon, &mut metrics, stop_early),
                },
            };
            debug_assert!(sys.mitosis.check_invariants().is_ok());
            let ups = sys.scale_log.iter().filter(|e| e.kind == "up").count();
            let peak = sys
                .scale_log
                .iter()
                .map(|e| e.active_instances)
                .max()
                .unwrap_or(0)
                .max(initial);
            let telemetry = AutoscaleTelemetry {
                scale_ups: ups,
                scale_downs: sys.scale_log.len() - ups,
                peak_active: peak,
                final_active: sys.active_count(),
                final_macros: sys.mitosis.macro_sizes(),
            };
            let churn = sys.churn_telemetry();
            let defense_t = sys.defense_telemetry();
            (stats, Some(telemetry), churn, defense_t)
        }
        _ => {
            let mut system = build_system(kind, &exp, None);
            if let Some(r) = ranker {
                system.set_class_ranker(r);
            }
            let stats = match source.as_mut() {
                Some(arr) => run_source_faulted_client(
                    system.as_mut(),
                    arr,
                    fault_events.as_deref().unwrap_or(&[]),
                    client.as_mut(),
                    horizon,
                    &mut metrics,
                    stop_early,
                ),
                None => match &fault_events {
                    Some(ev) => run_faulted_client(
                        system.as_mut(),
                        trace,
                        ev,
                        client.as_mut(),
                        horizon,
                        &mut metrics,
                        stop_early,
                    ),
                    None if client.is_some() => run_faulted_client(
                        system.as_mut(),
                        trace,
                        &[],
                        client.as_mut(),
                        horizon,
                        &mut metrics,
                        stop_early,
                    ),
                    None => {
                        run_abandonable(system.as_mut(), trace, horizon, &mut metrics, stop_early)
                    }
                },
            };
            let churn = system.churn_telemetry();
            let defense_t = system.defense_telemetry();
            (stats, None, churn, defense_t)
        }
    };

    // Borrow-based windowed scoring: the collector's view respects the
    // monitor's decision snapshot and never clones the record log.
    // Goodput is anchored on FIRST attempts: retry re-arrivals carry
    // fresh ids past `RETRY_ID_BASE` and are excluded from scoring — a
    // retried request that eventually finishes was still a failure at
    // its original deadline, and counting retry completions would let a
    // collapsing system fake a flat goodput curve.
    let mut met_per_class = vec![0usize; n_classes];
    let mut completed = 0usize;
    for rec in metrics.window_records(warmup, duration) {
        if rec.id >= RETRY_ID_BASE {
            continue;
        }
        completed += 1;
        let k = scenario.class_of(rec.id);
        let d = &scenario.classes[k].dataset;
        if rec.meets(&SloSpec::new(d.slo_ttft, d.slo_tpot)) {
            met_per_class[k] += 1;
        }
    }

    let arrived: usize = arrived_per_class.iter().sum();
    let met: usize = met_per_class.iter().sum();
    let window = (duration - warmup).max(1e-9);
    let classes = scenario
        .classes
        .iter()
        .enumerate()
        .map(|(k, class)| ClassScore {
            class: class.name,
            arrived: arrived_per_class[k],
            met: met_per_class[k],
            attainment: if arrived_per_class[k] == 0 {
                1.0
            } else {
                met_per_class[k] as f64 / arrived_per_class[k] as f64
            },
        })
        .collect();

    // Harvest the flight recorder (if attached) before the collector goes
    // back to the pool. The derived diagnostics use the same scoring
    // window as the strict scorer above.
    let trace_cap = metrics.take_sink().map(|sink| {
        let class_slos: Vec<(String, SloSpec)> = scenario
            .classes
            .iter()
            .map(|c| {
                let d = &c.dataset;
                (c.name.to_string(), SloSpec::new(d.slo_ttft, d.slo_tpot))
            })
            .collect();
        let summary = summarize(
            sink.events(),
            &metrics,
            warmup,
            duration,
            horizon,
            &class_slos,
            &|id| scenario.class_of(id),
        );
        TraceCapture { events: sink.events().to_vec(), summary }
    });

    let row = SystemRow {
        system: kind,
        arrived,
        completed,
        met,
        attainment: if arrived == 0 { 1.0 } else { met as f64 / arrived as f64 },
        goodput_rps: met as f64 / window,
        summary: summarize_from(
            metrics
                .window_records(warmup, duration)
                .filter(|r| r.id < RETRY_ID_BASE),
            &sched_slo,
            window,
        ),
        classes,
        events: stats.events,
        events_saved: stats.events_saved,
        abandoned: stats.stop == StopReason::Abandoned,
        allocs: stats.allocs,
        wall: stats.wall_time,
        autoscale,
        churn,
        overload: (client.is_some() || defense_t.is_some()).then(|| OverloadTelemetry {
            client: client.as_ref().map(|c| c.telemetry()).unwrap_or_default(),
            defense: defense_t,
        }),
        trace: trace_cap,
    };
    metrics.release();
    row
}

/// Run one scenario across `systems`, in parallel.
pub fn run_scenario(
    scenario: &Scenario,
    cfg: &ScenarioConfig,
    systems: &[SystemKind],
) -> ScenarioOutcome {
    let kinds: Vec<SystemKind> = systems.to_vec();
    let rows = parallel_map(kinds, systems.len().max(1), |kind| {
        run_system(scenario, cfg, kind)
    });
    let (duration, warmup) = cfg.horizon(scenario);
    ScenarioOutcome {
        scenario: scenario.clone(),
        rate: cfg.rate.unwrap_or(scenario.default_rate),
        duration,
        warmup,
        rows,
    }
}

/// Run the whole suite: every (scenario × system) cell as one parallel
/// job pool (order of outcomes follows `scenarios`; rows follow
/// `systems`).
pub fn run_suite(
    scenarios: &[Scenario],
    cfg: &ScenarioConfig,
    systems: &[SystemKind],
    workers: usize,
) -> Vec<ScenarioOutcome> {
    let mut jobs: Vec<(usize, SystemKind)> = Vec::new();
    for si in 0..scenarios.len() {
        for &kind in systems {
            jobs.push((si, kind));
        }
    }
    let rows = parallel_map(jobs, workers.max(1), |(si, kind)| {
        (si, run_system(&scenarios[si], cfg, kind))
    });
    let mut outcomes: Vec<ScenarioOutcome> = scenarios
        .iter()
        .map(|s| {
            let (duration, warmup) = cfg.horizon(s);
            ScenarioOutcome {
                scenario: s.clone(),
                rate: cfg.rate.unwrap_or(s.default_rate),
                duration,
                warmup,
                rows: Vec::new(),
            }
        })
        .collect();
    for (si, row) in rows {
        outcomes[si].rows.push(row);
    }
    outcomes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::registry::by_name;

    fn quick_cfg() -> ScenarioConfig {
        let mut cfg = ScenarioConfig::default_l20();
        cfg.deployment.gpus_used = 16; // 4 instances — fast tests
        cfg.duration_override = Some(60.0);
        cfg.rate = Some(2.0);
        cfg
    }

    #[test]
    fn steady_light_load_scores_high_for_ecoserve() {
        let s = by_name("steady").unwrap();
        let row = run_system(&s, &quick_cfg(), SystemKind::EcoServe);
        assert!(row.arrived > 20, "{}", row.arrived);
        assert!(row.attainment > 0.9, "attainment {}", row.attainment);
        assert!(row.goodput_rps > 0.0);
        assert_eq!(row.classes.len(), 1);
    }

    #[test]
    fn mixed_slo_scores_each_class_separately() {
        let s = by_name("mixed-slo").unwrap();
        let mut cfg = quick_cfg();
        cfg.rate = Some(3.0);
        let row = run_system(&s, &cfg, SystemKind::EcoServe);
        assert_eq!(row.classes.len(), 2);
        let interactive = &row.classes[0];
        let batch = &row.classes[1];
        assert_eq!(interactive.class, "interactive");
        assert_eq!(batch.class, "batch");
        assert!(interactive.arrived > batch.arrived);
        assert_eq!(row.arrived, interactive.arrived + batch.arrived);
        assert_eq!(row.met, interactive.met + batch.met);
    }

    #[test]
    fn autoscaled_variant_reports_telemetry() {
        let s = by_name("surge").unwrap();
        let mut cfg = quick_cfg();
        cfg.deployment.gpus_used = 32; // 8 instances; autoscale starts at N_l=4
        cfg.rate = Some(6.0);
        let row = run_system_variant(&s, &cfg, &RunSpec::new(SystemKind::EcoServe).autoscaled());
        let t = row.autoscale.as_ref().expect("telemetry on autoscaled runs");
        assert!(t.peak_active >= 4 && t.peak_active <= 8, "{t:?}");
        assert!(t.final_active >= 1, "{t:?}");
        assert!(row.arrived > 0);
        // Baselines ignore the variant; fixed PaDG runs carry no telemetry.
        let vllm = run_system_variant(&s, &cfg, &RunSpec::new(SystemKind::Vllm).autoscaled());
        assert!(vllm.autoscale.is_none());
        assert!(run_system(&s, &cfg, SystemKind::EcoServe).autoscale.is_none());
    }

    #[test]
    fn min_class_attainment_takes_the_weakest_class() {
        let s = by_name("mixed-slo").unwrap();
        let mut cfg = quick_cfg();
        cfg.rate = Some(3.0);
        let row = run_system(&s, &cfg, SystemKind::EcoServe);
        let min = row.min_class_attainment();
        for c in &row.classes {
            assert!(min <= c.attainment + 1e-12);
        }
        assert!(min <= row.attainment + 1e-12);
    }

    /// End-to-end replay: a 2-class inline log whose class layout does
    /// not follow the synthetic id-tagging. Arrived counts per class must
    /// match the log exactly — this is the scoring-side guarantee of the
    /// `class_of` side table.
    #[test]
    fn replay_scenario_runs_and_attributes_classes_from_the_log() {
        use crate::workload::ReplayTrace;
        let mut log = String::from(
            "{\"ecoserve_trace\":1,\"duration_s\":40,\"warmup_s\":4,\"classes\":\
             [{\"name\":\"chat\",\"dataset\":\"sharegpt\"},\
              {\"name\":\"batch\",\"dataset\":\"longbench\"}]}\n",
        );
        for i in 0..80 {
            let arrival = 0.5 * i as f64; // 2 req/s native
            let (class, input) = if i % 3 == 0 { (1, 1500) } else { (0, 200) };
            log.push_str(&format!(
                "{{\"arrival_s\":{arrival},\"input_len\":{input},\
                 \"output_len\":20,\"class\":{class}}}\n"
            ));
        }
        let s = Scenario::from_replay(ReplayTrace::parse_named(&log, "inline").unwrap());
        let mut cfg = ScenarioConfig::default_l20();
        cfg.deployment.gpus_used = 16; // 4 instances — fast test
        let row = run_system(&s, &cfg, SystemKind::EcoServe);
        // Window [4, 40): i in 8..80 — 72 arrivals, 24 of them class 1.
        assert_eq!(row.arrived, 72);
        assert_eq!(row.classes.len(), 2);
        assert_eq!(row.classes[0].class, "chat");
        assert_eq!(row.classes[0].arrived, 48);
        assert_eq!(row.classes[1].class, "batch");
        assert_eq!(row.classes[1].arrived, 24);
        assert!(row.completed > 0);
        assert!((0.0..=1.0).contains(&row.attainment));
        // Deterministic across calls (no PRNG on the replay path).
        let again = run_system(&s, &cfg, SystemKind::EcoServe);
        assert_eq!(row.arrived, again.arrived);
        assert_eq!(row.met, again.met);
        assert_eq!(row.events, again.events);
    }

    /// Scenario-level early-abandon equivalence: an overloaded cell cut
    /// short by the monitor reports the same verdict fields as the same
    /// cell driven to completion — only the event count shrinks.
    #[test]
    fn abandoned_overload_cell_matches_the_monitored_full_run() {
        use crate::metrics::AbandonPolicy;
        let s = by_name("mixed-slo").unwrap();
        let mut cfg = quick_cfg();
        cfg.rate = Some(60.0); // far beyond 4 instances' capacity
        let stop = RunSpec::new(SystemKind::EcoServe).with_abandon(AbandonPolicy::stop_at(0.90));
        let fast = run_system_variant(&s, &cfg, &stop);
        let watch =
            RunSpec::new(SystemKind::EcoServe).with_abandon(AbandonPolicy::monitor_only(0.90));
        let full = run_system_variant(&s, &cfg, &watch);
        assert!(fast.abandoned, "overload must abandon");
        assert!(!full.abandoned);
        assert!(fast.events_saved > 0);
        assert!(fast.events < full.events, "{} vs {}", fast.events, full.events);
        assert_eq!(fast.arrived, full.arrived);
        assert_eq!(fast.met, full.met);
        assert_eq!(fast.completed, full.completed);
        assert_eq!(fast.attainment.to_bits(), full.attainment.to_bits());
        assert_eq!(
            fast.min_class_attainment().to_bits(),
            full.min_class_attainment().to_bits()
        );
        assert_eq!(fast.classes.len(), full.classes.len());
        for (a, b) in fast.classes.iter().zip(&full.classes) {
            assert_eq!(a.class, b.class);
            assert_eq!(a.arrived, b.arrived);
            assert_eq!(a.met, b.met);
            assert_eq!(a.attainment.to_bits(), b.attainment.to_bits());
        }
        assert_eq!(fast.summary.count, full.summary.count);
        assert_eq!(fast.summary.ttft_p99.to_bits(), full.summary.ttft_p99.to_bits());
        // Both verdicts are "fail" — and so says the legacy full run.
        assert!(fast.min_class_attainment() < 0.90 - 1e-12);
        let legacy = run_system(&s, &cfg, SystemKind::EcoServe);
        assert!(legacy.min_class_attainment() < 0.90 - 1e-12);
        assert!(!legacy.abandoned);
        assert_eq!(legacy.events_saved, 0);
    }

    #[test]
    fn overload_cell_reports_client_and_defense_telemetry() {
        use crate::config::DefenseConfig;
        let s = by_name("retry-storm").unwrap();
        let mut cfg = quick_cfg();
        cfg.rate = Some(12.0); // far past 4 instances' capacity
        let profile = s.overload.expect("retry-storm carries a profile");

        // Plain cell: no overload block — the pre-overload surface.
        let plain = run_system(&s, &cfg, SystemKind::EcoServe);
        assert!(plain.overload.is_none());

        // Client-on undefended: the loop must observe timeouts and retry.
        let spec = RunSpec::new(SystemKind::EcoServe).with_client(profile.client);
        let row = run_system_variant(&s, &cfg, &spec);
        let t = row.overload.expect("client => overload telemetry");
        assert!(t.defense.is_none(), "undefended run has no defense block");
        assert!(t.client.timeouts > 0, "deep overload must time clients out: {:?}", t.client);
        assert!(t.client.retries > 0, "{:?}", t.client);
        // First-attempt anchoring: the scored population never exceeds
        // the open-loop arrivals even though retries re-enter the system.
        assert_eq!(row.arrived, plain.arrived);

        // Defended PaDG: sheds show up in the defense block.
        let spec = RunSpec::new(SystemKind::EcoServe)
            .with_client(profile.client)
            .with_defense(DefenseConfig::default());
        let defended = run_system_variant(&s, &cfg, &spec);
        let d = defended
            .overload
            .and_then(|t| t.defense)
            .expect("defended run carries defense counters");
        assert!(d.sheds() > 0, "{d:?}");

        // The ablation nulls the defense block but keeps the client loop.
        let spec = RunSpec::new(SystemKind::EcoServe)
            .with_client(profile.client)
            .with_defense(DefenseConfig::default())
            .without_shedding();
        let ablated = run_system_variant(&s, &cfg, &spec);
        let t = ablated.overload.expect("client still attached");
        assert!(t.defense.is_none(), "ablation must silence the defense block");
        assert!(t.client.retries > 0);
    }

    #[test]
    fn churn_scenario_with_fault_seed_reports_telemetry() {
        let s = by_name("steady+churn").unwrap();
        let mut cfg = quick_cfg();
        // Without a fault seed the cell runs fault-free.
        let clean = run_system(&s, &cfg, SystemKind::EcoServe);
        assert!(clean.churn.is_none());
        cfg.fault_seed = Some(7);
        let faulted = run_system(&s, &cfg, SystemKind::EcoServe);
        let t = faulted.churn.as_ref().expect("fault seed => churn telemetry");
        assert!(t.downs >= 1, "{t:?}");
        // Faults cost goodput, never create it.
        assert!(faulted.met <= clean.met, "{} vs {}", faulted.met, clean.met);
        // Same fault seed, same timeline: rows agree exactly.
        let again = run_system(&s, &cfg, SystemKind::EcoServe);
        assert_eq!(faulted.events, again.events);
        assert_eq!(faulted.met, again.met);
        assert_eq!(Some(t), again.churn.as_ref());
        // Baselines see the same faults through their native handling.
        let vllm = run_system(&s, &cfg, SystemKind::Vllm);
        assert!(vllm.churn.is_some());
    }

    #[test]
    fn run_scenario_is_deterministic_across_calls() {
        let s = by_name("bursty").unwrap();
        let cfg = quick_cfg();
        let a = run_system(&s, &cfg, SystemKind::Vllm);
        let b = run_system(&s, &cfg, SystemKind::Vllm);
        assert_eq!(a.arrived, b.arrived);
        assert_eq!(a.met, b.met);
        assert_eq!(a.events, b.events);
        assert!((a.summary.ttft_p90 - b.summary.ttft_p90).abs() < 1e-12);
    }

    #[test]
    fn suite_groups_rows_per_scenario() {
        let scenarios: Vec<_> = ["steady", "bursty"]
            .iter()
            .map(|n| by_name(n).unwrap())
            .collect();
        let systems = [SystemKind::EcoServe, SystemKind::Vllm];
        let outcomes = run_suite(&scenarios, &quick_cfg(), &systems, 4);
        assert_eq!(outcomes.len(), 2);
        for (o, s) in outcomes.iter().zip(&scenarios) {
            assert_eq!(o.scenario.name, s.name);
            assert_eq!(o.rows.len(), 2);
            assert_eq!(o.rows[0].system, SystemKind::EcoServe);
            assert_eq!(o.rows[1].system, SystemKind::Vllm);
            assert!(o.best().is_some());
        }
    }
}
