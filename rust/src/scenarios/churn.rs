//! The churn evaluation: every system runs each churn scenario twice —
//! once fault-free (the control) and once with the scenario's
//! deterministic fault schedule injected — and is scored on the goodput
//! it *retains* under hardware churn. This is the paper's cost story
//! stress-tested: commodity clusters only stay cheap if the coordinator
//! survives the churn that comes with them.
//!
//! ```text
//! ecoserve scenarios --scenario steady+churn --fault-seed 7 \
//!     --churn-out BENCH_churn.json
//! ```
//!
//! The JSON artifact (`BENCH_churn.json`) embeds the full clean and
//! faulted system rows (the suite-report shape) plus the recovery
//! telemetry each system's fault handling accumulated, under the shared
//! [`super::report::SCHEMA_VERSION`].

use std::time::Duration;

use super::driver::{run_system_variant, ScenarioConfig, SystemRow};
use super::registry::Scenario;
use super::report::{deployment_to_json, row_to_json, SCHEMA_VERSION};
use super::spec::RunSpec;
use crate::config::SystemKind;
use crate::util::json::Json;
use crate::util::threads::parallel_map;

/// One system's clean-vs-faulted pairing on one churn scenario.
#[derive(Debug)]
pub struct ChurnRow {
    pub system: SystemKind,
    /// The fault-free control run (same trace, no fault timeline).
    pub clean: SystemRow,
    /// The identical cell with the scenario's fault schedule injected.
    pub faulted: SystemRow,
}

impl ChurnRow {
    /// Goodput retained under churn: faulted / clean delivered goodput
    /// (1.0 when the control delivered none — nothing was lost).
    pub fn goodput_retained(&self) -> f64 {
        if self.clean.goodput_rps <= 0.0 {
            1.0
        } else {
            self.faulted.goodput_rps / self.clean.goodput_rps
        }
    }
}

/// All systems' pairings on one churn scenario.
#[derive(Debug)]
pub struct ChurnOutcome {
    pub scenario: Scenario,
    pub rate: f64,
    pub duration: f64,
    pub warmup: f64,
    /// The seed the fault schedule was generated from.
    pub fault_seed: u64,
    pub rows: Vec<ChurnRow>,
}

impl ChurnOutcome {
    /// The row retaining the most goodput (ties: higher faulted goodput).
    pub fn best(&self) -> Option<&ChurnRow> {
        self.rows.iter().max_by(|a, b| {
            (a.goodput_retained(), a.faulted.goodput_rps)
                .partial_cmp(&(b.goodput_retained(), b.faulted.goodput_rps))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
    }
}

/// Run the clean-vs-faulted pairing for every (churn scenario × system)
/// cell as one parallel job pool. Scenarios without a churn profile are
/// skipped (they have no fault timeline to pair against). When the config
/// carries no `fault_seed`, the trace seed doubles as the fault seed so
/// the pairing stays reproducible from the command line alone.
pub fn run_churn_suite(
    scenarios: &[Scenario],
    cfg: &ScenarioConfig,
    systems: &[SystemKind],
    workers: usize,
) -> Vec<ChurnOutcome> {
    let fault_seed = cfg.fault_seed.unwrap_or(cfg.seed);
    let mut cfg = cfg.clone();
    cfg.fault_seed = Some(fault_seed);
    let list: Vec<&Scenario> = scenarios.iter().filter(|s| s.churn.is_some()).collect();

    // Clean/faulted are independent simulations: schedule them as
    // separate jobs (pushed adjacently, so they come back paired —
    // `parallel_map` preserves input order).
    let mut jobs: Vec<(usize, usize, bool)> = Vec::new();
    for si in 0..list.len() {
        for ki in 0..systems.len() {
            jobs.push((si, ki, false));
            jobs.push((si, ki, true));
        }
    }
    let rows = parallel_map(jobs, workers.max(1), |(si, ki, faulted)| {
        let spec = if faulted {
            RunSpec::for_cell(list[si], &cfg, systems[ki])
        } else {
            RunSpec::new(systems[ki])
        };
        run_system_variant(list[si], &cfg, &spec)
    });

    let mut outcomes: Vec<ChurnOutcome> = list
        .iter()
        .map(|s| {
            let (duration, warmup) = cfg.horizon(s);
            ChurnOutcome {
                scenario: (*s).clone(),
                rate: cfg.rate.unwrap_or(s.default_rate),
                duration,
                warmup,
                fault_seed,
                rows: Vec::new(),
            }
        })
        .collect();
    let mut rows = rows.into_iter();
    for outcome in &mut outcomes {
        for &kind in systems {
            let clean = rows.next().expect("one clean row per cell");
            let faulted = rows.next().expect("one faulted row per cell");
            outcome.rows.push(ChurnRow { system: kind, clean, faulted });
        }
    }
    outcomes
}

fn outcome_to_json(o: &ChurnOutcome) -> Json {
    Json::obj(vec![
        ("name", Json::str(o.scenario.name)),
        ("summary", Json::str(o.scenario.summary)),
        ("offered_rate_rps", Json::num(o.rate)),
        ("duration_s", Json::num(o.duration)),
        ("warmup_s", Json::num(o.warmup)),
        ("fault_seed", Json::num(o.fault_seed as f64)),
        (
            "best_system",
            match o.best() {
                Some(r) => Json::str(r.system.label()),
                None => Json::Null,
            },
        ),
        (
            "systems",
            Json::arr(o.rows.iter().map(|r| {
                Json::obj(vec![
                    ("system", Json::str(r.system.label())),
                    ("goodput_retained", Json::num(r.goodput_retained())),
                    ("clean", row_to_json(&r.clean)),
                    ("faulted", row_to_json(&r.faulted)),
                ])
            })),
        ),
    ])
}

/// The `BENCH_churn.json` artifact.
pub fn churn_to_json(outcomes: &[ChurnOutcome], cfg: &ScenarioConfig, wall: Duration) -> Json {
    Json::obj(vec![
        ("bench", Json::str("ecoserve-churn")),
        ("schema_version", Json::num(SCHEMA_VERSION)),
        ("seed", Json::num(cfg.seed as f64)),
        (
            "fault_seed",
            Json::num(cfg.fault_seed.unwrap_or(cfg.seed) as f64),
        ),
        ("deployment", deployment_to_json(&cfg.deployment)),
        ("wall_s", Json::num(wall.as_secs_f64())),
        ("scenarios", Json::arr(outcomes.iter().map(outcome_to_json))),
    ])
}

/// Human-readable table for one churn outcome.
pub fn render_churn_table(o: &ChurnOutcome) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "--- churn '{}' @ {:.2} req/s (fault seed {}, window {:.0}..{:.0}s) ---\n",
        o.scenario.name, o.rate, o.fault_seed, o.warmup, o.duration
    ));
    out.push_str(&format!(
        "{:<10} {:>9} {:>11} {:>10} {:>9} {:>6} {:>9} {:>9}\n",
        "system", "clean g/s", "faulted g/s", "retained %", "rerouted", "lost", "backfills", "recov s"
    ));
    for r in &o.rows {
        let t = r.faulted.churn.clone().unwrap_or_default();
        out.push_str(&format!(
            "{:<10} {:>9.2} {:>11.2} {:>10.1} {:>9} {:>6} {:>9} {:>9.2}\n",
            r.system.label(),
            r.clean.goodput_rps,
            r.faulted.goodput_rps,
            r.goodput_retained() * 100.0,
            t.rerouted,
            t.lost,
            t.backfills,
            t.mean_recovery_s(),
        ));
    }
    if let Some(best) = o.best() {
        out.push_str(&format!("  best under churn: {}\n", best.system.label()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::registry::by_name;

    fn quick_cfg() -> ScenarioConfig {
        let mut cfg = ScenarioConfig::default_l20();
        cfg.deployment.gpus_used = 16; // 4 instances — fast tests
        cfg.duration_override = Some(60.0);
        cfg.rate = Some(2.0);
        cfg.fault_seed = Some(7);
        cfg
    }

    #[test]
    fn suite_pairs_clean_and_faulted_runs() {
        let s = by_name("steady+churn").unwrap();
        let systems = [SystemKind::EcoServe, SystemKind::Vllm];
        let outcomes = run_churn_suite(&[s], &quick_cfg(), &systems, 4);
        assert_eq!(outcomes.len(), 1);
        let o = &outcomes[0];
        assert_eq!(o.fault_seed, 7);
        assert_eq!(o.rows.len(), 2);
        for (row, kind) in o.rows.iter().zip(systems) {
            assert_eq!(row.system, kind);
            assert!(row.clean.churn.is_none(), "control must be fault-free");
            let t = row.faulted.churn.as_ref().expect("faulted half sees faults");
            assert!(t.downs >= 1, "{t:?}");
            let retained = row.goodput_retained();
            assert!(retained > 0.0 && retained <= 1.0 + 1e-9, "{retained}");
        }
    }

    #[test]
    fn fault_free_scenarios_are_skipped() {
        let scenarios = vec![by_name("steady").unwrap(), by_name("steady+churn").unwrap()];
        let outcomes =
            run_churn_suite(&scenarios, &quick_cfg(), &[SystemKind::EcoServe], 2);
        assert_eq!(outcomes.len(), 1);
        assert_eq!(outcomes[0].scenario.name, "steady+churn");
    }

    #[test]
    fn churn_json_has_the_contract_fields_and_roundtrips() {
        let s = by_name("spot-decode-reclaim").unwrap();
        let cfg = quick_cfg();
        let outcomes = run_churn_suite(&[s], &cfg, &[SystemKind::EcoServe], 2);
        let j = churn_to_json(&outcomes, &cfg, Duration::from_secs(1));
        let text = j.to_string();
        let back = Json::parse(&text).expect("valid JSON");
        assert_eq!(back.get("bench").unwrap().as_str(), Some("ecoserve-churn"));
        assert_eq!(back.get("fault_seed").unwrap().as_i64(), Some(7));
        for key in ["schema_version", "seed", "deployment", "wall_s", "scenarios"] {
            assert!(back.get(key).is_some(), "missing {key}");
        }
        let sc = &back.get("scenarios").unwrap().as_arr().unwrap()[0];
        assert_eq!(sc.get("name").unwrap().as_str(), Some("spot-decode-reclaim"));
        let sys = &sc.get("systems").unwrap().as_arr().unwrap()[0];
        assert!(sys.get("goodput_retained").unwrap().as_f64().is_some());
        assert!(sys.path(&["clean", "goodput_rps"]).is_some());
        assert!(sys.path(&["faulted", "churn", "lost"]).is_some());
        assert!(sys.path(&["clean", "churn"]).is_none(), "control carries no churn block");
        // The table renders every system and the telemetry columns.
        let table = render_churn_table(&outcomes[0]);
        assert!(table.contains("EcoServe"));
        assert!(table.contains("retained %"));
    }
}
