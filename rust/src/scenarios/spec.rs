//! The declarative run specification: one value that says *how* a
//! (scenario × system) cell runs — which system, which capacity variant,
//! whether the online SLO monitor is armed, and which fault timeline (if
//! any) is injected. Both drivers consume it ([`super::driver`] for suite
//! rows, [`crate::frontier`] for search probes), so a new run dimension
//! is one new field here instead of another positional argument on every
//! call-site in between.
//!
//! A spec with `faults: None` runs the exact fault-free code path the
//! pre-fault driver ran — bit-identical, as pinned by the equivalence
//! tests — while [`RunSpec::for_cell`] derives the deterministic fault
//! schedule for churn scenarios from `(scenario.churn, cfg.fault_seed)`.

use crate::config::{DefenseConfig, SystemKind};
use crate::metrics::AbandonPolicy;
use crate::sim::FaultSchedule;
use crate::workload::ClientPolicy;

use super::driver::{ScenarioConfig, VariantSpec};
use super::registry::Scenario;

/// Everything that varies between two runs of the same scenario.
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Which serving system runs the cell.
    pub system: SystemKind,
    /// Fixed-capacity (default) vs mitosis-on instantiation.
    pub variant: VariantSpec,
    /// Arm the online SLO monitor at this policy (set per probe by the
    /// frontier search); `None` runs the legacy full simulation.
    pub abandon: Option<AbandonPolicy>,
    /// Inject this fault timeline; `None` keeps the run on the exact
    /// fault-free code path.
    pub faults: Option<FaultSchedule>,
    /// Closed-loop client model (per-request TTFT timeout, bounded
    /// retries, jittered backoff) driving the cell; `None` keeps the
    /// open-loop arrivals the pre-overload driver ran — bit-identical.
    pub client: Option<ClientPolicy>,
    /// Coordinator-side overload defenses for this cell. PaDG gets the
    /// full set (deadline-aware admission, priority shedding, brownout);
    /// baselines get only their native bounded waiting queue. `None`
    /// keeps every system on its pre-defense behaviour.
    pub defense: Option<DefenseConfig>,
    /// Ablation switch mirroring the autoscale ablations: keep `defense`
    /// configured but null the shedding machinery, so defended PaDG can
    /// be scored against its own defenseless twin on the same trace.
    pub ablate_no_shedding: bool,
    /// Attach the flight recorder ([`crate::trace::TraceSink`]) to this
    /// cell and harvest a [`crate::trace::TraceCapture`] into the row.
    /// `false` keeps the recorder-off warm path: bit-identical results,
    /// zero extra allocations (the PR 8/9 locks).
    pub trace: bool,
}

impl RunSpec {
    /// A plain fixed-capacity, monitor-off, fault-free run of `system`.
    pub fn new(system: SystemKind) -> Self {
        RunSpec {
            system,
            variant: VariantSpec::default(),
            abandon: None,
            faults: None,
            client: None,
            defense: None,
            ablate_no_shedding: false,
            trace: false,
        }
    }

    /// Builder: replace the capacity variant.
    pub fn with_variant(mut self, variant: VariantSpec) -> Self {
        self.variant = variant;
        self
    }

    /// Builder: the mitosis-on variant with the Figure-10 default policy.
    pub fn autoscaled(self) -> Self {
        self.with_variant(VariantSpec::autoscaled())
    }

    /// Builder: arm the online SLO monitor.
    pub fn with_abandon(mut self, policy: AbandonPolicy) -> Self {
        self.abandon = Some(policy);
        self
    }

    /// Builder: inject a fault timeline.
    pub fn with_faults(mut self, faults: FaultSchedule) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Builder: attach the closed-loop client model.
    pub fn with_client(mut self, policy: ClientPolicy) -> Self {
        self.client = Some(policy);
        self
    }

    /// Builder: arm the coordinator-side overload defenses.
    pub fn with_defense(mut self, defense: DefenseConfig) -> Self {
        self.defense = Some(defense);
        self
    }

    /// Builder: keep the defenses configured but switch shedding off.
    pub fn without_shedding(mut self) -> Self {
        self.ablate_no_shedding = true;
        self
    }

    /// Builder: attach the flight recorder to this cell.
    pub fn with_trace(mut self) -> Self {
        self.trace = true;
        self
    }

    /// The spec [`super::driver::run_system`] uses for one cell: plain
    /// run, plus the scenario's churn profile expanded into a concrete
    /// schedule when the config carries a fault seed. Deterministic — the
    /// schedule is a pure function of `(profile, fault_seed, horizon,
    /// instances)`, and the horizon already reflects the config's rate
    /// and duration override.
    pub fn for_cell(scenario: &Scenario, cfg: &ScenarioConfig, system: SystemKind) -> Self {
        let mut spec = RunSpec::new(system);
        spec.trace = cfg.trace;
        match (&scenario.churn, cfg.fault_seed) {
            (Some(profile), Some(fault_seed)) => {
                let (duration, warmup) = cfg.horizon(scenario);
                spec.with_faults(FaultSchedule::generate(
                    profile,
                    fault_seed,
                    duration,
                    warmup,
                    cfg.deployment.num_instances(),
                ))
            }
            _ => spec,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::registry::by_name;

    #[test]
    fn for_cell_attaches_faults_only_with_profile_and_seed() {
        let mut cfg = ScenarioConfig::default_l20();
        let churny = by_name("steady+churn").unwrap();
        let clean = by_name("steady").unwrap();

        // No fault seed: even churn scenarios run fault-free.
        assert!(RunSpec::for_cell(&churny, &cfg, SystemKind::EcoServe).faults.is_none());

        cfg.fault_seed = Some(7);
        let spec = RunSpec::for_cell(&churny, &cfg, SystemKind::EcoServe);
        let sched = spec.faults.expect("churn scenario + fault seed => schedule");
        assert!(!sched.is_empty());
        // A fault-free scenario never grows a schedule, seed or not.
        assert!(RunSpec::for_cell(&clean, &cfg, SystemKind::EcoServe).faults.is_none());

        // Deterministic in the seed, and the seed moves the timeline.
        let again = RunSpec::for_cell(&churny, &cfg, SystemKind::EcoServe);
        assert_eq!(Some(&sched), again.faults.as_ref());
        cfg.fault_seed = Some(8);
        assert_ne!(
            Some(&sched),
            RunSpec::for_cell(&churny, &cfg, SystemKind::EcoServe).faults.as_ref()
        );
    }

    #[test]
    fn builder_composes() {
        use crate::config::DefenseConfig;
        use crate::workload::ClientPolicy;
        let spec = RunSpec::new(SystemKind::EcoServe)
            .autoscaled()
            .with_abandon(AbandonPolicy::stop_at(0.9))
            .with_faults(FaultSchedule::none())
            .with_client(ClientPolicy::standard())
            .with_defense(DefenseConfig::default())
            .without_shedding()
            .with_trace();
        assert_eq!(spec.system, SystemKind::EcoServe);
        assert!(spec.variant.autoscale.is_some());
        assert!(spec.abandon.is_some_and(|p| p.stop_early));
        assert!(spec.faults.is_some());
        assert!(spec.client.is_some());
        assert!(spec.defense.is_some());
        assert!(spec.ablate_no_shedding);
        assert!(spec.trace);
        let plain = RunSpec::new(SystemKind::Vllm);
        assert!(plain.variant.autoscale.is_none());
        assert!(plain.abandon.is_none() && plain.faults.is_none());
        assert!(plain.client.is_none() && plain.defense.is_none());
        assert!(!plain.ablate_no_shedding);
        assert!(!plain.trace);
    }
}
