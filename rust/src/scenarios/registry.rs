//! The declarative scenario registry: each entry names a workload shape
//! the serving stack must survive, built from the same primitives as the
//! paper's evaluation (Table-4 datasets + Poisson/ramp arrival processes).
//!
//! A scenario is (traffic classes × load shape × horizon). Classes carry
//! their own dataset and therefore their own SLO pair (Table 4), which is
//! what lets `mixed-slo` score interactive and batch traffic separately;
//! the load shape modulates the *total* offered rate over time and is
//! normalized so `rate` is always the time-averaged offered rate.

use crate::workload::{Dataset, RampTrace, Request, TraceGenerator};

/// One class of traffic inside a scenario. `share` is this class's
/// fraction of the scenario's total offered rate; shares sum to 1.
#[derive(Debug, Clone)]
pub struct TrafficClass {
    pub name: &'static str,
    pub dataset: Dataset,
    pub share: f64,
}

/// How the total offered rate evolves over the scenario horizon. All
/// shapes are normalized so the time-averaged rate equals the nominal
/// `rate` handed to [`Scenario::build_trace`].
#[derive(Debug, Clone)]
pub enum LoadShape {
    /// Fixed-rate Poisson — the paper's §4.1 setting.
    Steady,
    /// On/off square wave: `duty` of each `period` runs at
    /// `peak_to_mean × rate`, the remainder at the complementary trough
    /// rate (DistServe-style burst resilience probe).
    OnOff { period: f64, duty: f64, peak_to_mean: f64 },
    /// Half-sine day curve from `trough_mult` up to `peak_mult` and back,
    /// discretized into `segments` constant-rate steps.
    Diurnal { trough_mult: f64, peak_mult: f64, segments: usize },
    /// Monotone escalation from `start_mult × rate` to `end_mult × rate`
    /// in `increments` equal steps (the Figure-10 [`RampTrace`] shape).
    Ramp { start_mult: f64, end_mult: f64, increments: usize },
}

impl LoadShape {
    /// Piecewise-constant (rate, duration) steps covering `duration`
    /// seconds at time-averaged rate `rate`.
    pub fn steps(&self, rate: f64, duration: f64) -> Vec<(f64, f64)> {
        // The arrival sampler needs strictly positive rates.
        const MIN_RATE: f64 = 0.05;
        match *self {
            LoadShape::Steady => vec![(rate.max(MIN_RATE), duration)],
            LoadShape::OnOff { period, duty, peak_to_mean } => {
                let duty = duty.clamp(0.05, 0.95);
                let peak = rate * peak_to_mean;
                // Trough chosen so duty·peak + (1−duty)·trough = rate.
                let trough = (rate * (1.0 - duty * peak_to_mean) / (1.0 - duty))
                    .max(MIN_RATE);
                let mut out = Vec::new();
                let mut t = 0.0;
                while t < duration {
                    let on = (period * duty).min(duration - t);
                    if on > 0.0 {
                        out.push((peak.max(MIN_RATE), on));
                        t += on;
                    }
                    let off = (period * (1.0 - duty)).min(duration - t);
                    if off > 0.0 {
                        out.push((trough, off));
                        t += off;
                    }
                }
                out
            }
            LoadShape::Diurnal { trough_mult, peak_mult, segments } => {
                let n = segments.max(2);
                let raw: Vec<f64> = (0..n)
                    .map(|i| {
                        let phase = std::f64::consts::PI * (i as f64 + 0.5) / n as f64;
                        trough_mult + (peak_mult - trough_mult) * phase.sin()
                    })
                    .collect();
                let mean = raw.iter().sum::<f64>() / n as f64;
                raw.into_iter()
                    .map(|m| ((rate * m / mean).max(MIN_RATE), duration / n as f64))
                    .collect()
            }
            LoadShape::Ramp { start_mult, end_mult, increments } => {
                let n = increments.max(2);
                let ramp = RampTrace {
                    start_rate: rate * start_mult,
                    end_rate: rate * end_mult,
                    increments: n,
                    step_secs: duration / n as f64,
                };
                // Normalize so the time mean equals `rate` (a linear ramp's
                // mean is (start+end)/2).
                let mean = rate * (start_mult + end_mult) / 2.0;
                ramp.steps()
                    .into_iter()
                    .map(|(r, d)| ((r * rate / mean.max(1e-9)).max(MIN_RATE), d))
                    .collect()
            }
        }
    }
}

/// Rate-sweep bracket for the goodput-frontier search
/// ([`crate::frontier`]): where to start probing this scenario and how
/// far the search may climb. Bounds keep the adaptive search's wall
/// clock predictable — they cap the doubling phase, they don't presume
/// the answer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepBounds {
    /// Smallest rate worth probing (the last-resort "crumb").
    pub floor: f64,
    /// First bracketing probe.
    pub start: f64,
    /// Hard cap on probed rates.
    pub ceiling: f64,
}

impl SweepBounds {
    /// Bracket derived from a scenario's nominal operating rate: crumb at
    /// 1/16th, first probe at a quarter, cap at 8x. Registry entries use
    /// this unless a scenario needs a bespoke bracket.
    pub fn around(nominal_rate: f64) -> Self {
        SweepBounds {
            floor: (nominal_rate / 16.0).max(0.05),
            start: (nominal_rate / 4.0).max(0.1),
            ceiling: nominal_rate * 8.0,
        }
    }
}

/// A named workload scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub summary: &'static str,
    pub classes: Vec<TrafficClass>,
    pub shape: LoadShape,
    /// Trace horizon, seconds.
    pub duration: f64,
    /// Warm-up prefix excluded from scoring, seconds.
    pub warmup: f64,
    /// Nominal time-averaged offered rate (req/s) when the caller gives
    /// none — tuned for the default 8-instance CodeLlama-34B/L20 layout.
    pub default_rate: f64,
    /// Frontier-search bracket for this scenario's rate sweep.
    pub sweep: SweepBounds,
}

impl Scenario {
    /// The dataset whose SLO pair drives the *scheduler* (admission and
    /// routing decisions): the tightest-TTFT class. Scoring remains
    /// per-class against each class's own SLOs.
    pub fn scheduler_dataset(&self) -> Dataset {
        self.classes
            .iter()
            .min_by(|a, b| {
                a.dataset
                    .slo_ttft
                    .partial_cmp(&b.dataset.slo_ttft)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .expect("scenario has at least one class")
            .dataset
            .clone()
    }

    /// Which traffic class a request id belongs to (ids are tagged
    /// `idx × n_classes + class` by [`Scenario::build_trace`]).
    pub fn class_of(&self, id: u64) -> usize {
        (id % self.classes.len() as u64) as usize
    }

    /// Deterministically generate the merged multi-class trace at
    /// time-averaged `rate` req/s: bit-for-bit reproducible from
    /// (scenario, seed, rate), matching the simulator's determinism
    /// contract (`sim::engine` orders ties by insertion).
    pub fn build_trace(&self, seed: u64, rate: f64) -> Vec<Request> {
        let n_classes = self.classes.len() as u64;
        let mut merged: Vec<Request> = Vec::new();
        for (k, class) in self.classes.iter().enumerate() {
            let steps = self.shape.steps(rate * class.share, self.duration);
            // Per-class stream: distinct seeds give independent arrivals.
            let gen = TraceGenerator::new(
                class.dataset.clone(),
                seed.wrapping_add(0x9E37_79B9u64.wrapping_mul(k as u64 + 1)),
            );
            for mut req in gen.ramp(&steps) {
                req.id = req.id * n_classes + k as u64;
                merged.push(req);
            }
        }
        merged.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        merged
    }
}

fn single(class_name: &'static str, dataset: Dataset) -> Vec<TrafficClass> {
    vec![TrafficClass { name: class_name, dataset, share: 1.0 }]
}

/// The built-in scenario registry (≥ 5 entries; `ecoserve scenarios
/// --list` prints this table).
pub fn registry() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "steady",
            summary: "fixed-rate Poisson on ShareGPT — the paper's §4.1 operating point",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::Steady,
            duration: 240.0,
            warmup: 30.0,
            default_rate: 8.0,
            sweep: SweepBounds::around(8.0),
        },
        Scenario {
            name: "bursty",
            summary: "on/off bursts at 2.5x the mean rate — flash-crowd resilience \
                      (rolling activation must absorb each front)",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::OnOff { period: 60.0, duty: 0.3, peak_to_mean: 2.5 },
            duration: 300.0,
            warmup: 30.0,
            default_rate: 6.0,
            sweep: SweepBounds::around(6.0),
        },
        Scenario {
            name: "diurnal",
            summary: "half-sine day curve, 0.4x..1.8x the mean rate in 12 steps",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::Diurnal { trough_mult: 0.4, peak_mult: 1.8, segments: 12 },
            duration: 360.0,
            warmup: 30.0,
            default_rate: 7.0,
            sweep: SweepBounds::around(7.0),
        },
        Scenario {
            name: "heavy-tail",
            summary: "LongBench long-context prompts (heavy-tailed inputs, short \
                      outputs) at steady rate — maximal prefill/decode interference",
            classes: single("summarize", Dataset::longbench()),
            shape: LoadShape::Steady,
            duration: 240.0,
            warmup: 30.0,
            default_rate: 2.5,
            sweep: SweepBounds::around(2.5),
        },
        Scenario {
            name: "mixed-slo",
            summary: "70% interactive (Alpaca, 1s TTFT SLO) + 30% batch (LongBench, \
                      15s TTFT SLO) sharing the fleet; scored per class",
            classes: vec![
                TrafficClass { name: "interactive", dataset: Dataset::alpaca(), share: 0.7 },
                TrafficClass { name: "batch", dataset: Dataset::longbench(), share: 0.3 },
            ],
            shape: LoadShape::Steady,
            duration: 240.0,
            warmup: 30.0,
            default_rate: 6.0,
            sweep: SweepBounds::around(6.0),
        },
        Scenario {
            name: "surge",
            summary: "monotone escalation 0.5x -> 1.5x of the mean rate in 6 steps \
                      (the Figure-10 ramp, without autoscaling)",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::Ramp { start_mult: 0.5, end_mult: 1.5, increments: 6 },
            duration: 300.0,
            warmup: 30.0,
            default_rate: 6.0,
            sweep: SweepBounds::around(6.0),
        },
    ]
}

/// Look a scenario up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<Scenario> {
    let lower = name.to_ascii_lowercase();
    registry().into_iter().find(|s| s.name == lower)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_at_least_five_unique_scenarios() {
        let all = registry();
        assert!(all.len() >= 5, "only {} scenarios", all.len());
        let mut names: Vec<&str> = all.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), all.len(), "duplicate scenario names");
        for s in &all {
            let share: f64 = s.classes.iter().map(|c| c.share).sum();
            assert!((share - 1.0).abs() < 1e-9, "{}: shares sum {share}", s.name);
            assert!(s.warmup < s.duration, "{}", s.name);
            assert!(s.default_rate > 0.0, "{}", s.name);
        }
    }

    #[test]
    fn sweep_bounds_bracket_the_default_rate() {
        for s in registry() {
            let b = s.sweep;
            assert!(b.floor > 0.0, "{}: floor {}", s.name, b.floor);
            assert!(b.floor < b.start, "{}: floor {} >= start {}", s.name, b.floor, b.start);
            assert!(b.start < b.ceiling, "{}: start {} >= ceiling {}", s.name, b.start, b.ceiling);
            assert!(
                b.floor <= s.default_rate && s.default_rate <= b.ceiling,
                "{}: default rate {} outside sweep [{}, {}]",
                s.name,
                s.default_rate,
                b.floor,
                b.ceiling
            );
        }
        let b = SweepBounds::around(8.0);
        assert_eq!(b.floor, 0.5);
        assert_eq!(b.start, 2.0);
        assert_eq!(b.ceiling, 64.0);
    }

    #[test]
    fn lookup_is_case_insensitive() {
        assert!(by_name("BURSTY").is_some());
        assert!(by_name("mixed-slo").is_some());
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn shapes_preserve_the_mean_rate() {
        for s in registry() {
            let rate = 6.0;
            let steps = s.shape.steps(rate, s.duration);
            let total_time: f64 = steps.iter().map(|(_, d)| d).sum();
            let weighted: f64 = steps.iter().map(|(r, d)| r * d).sum();
            assert!(
                (total_time - s.duration).abs() < 1e-6,
                "{}: steps cover {total_time}s of {}s",
                s.name,
                s.duration
            );
            let mean = weighted / total_time;
            assert!(
                (mean - rate).abs() / rate < 0.02,
                "{}: mean rate {mean} vs nominal {rate}",
                s.name
            );
            for (r, d) in steps {
                assert!(r > 0.0 && d > 0.0);
            }
        }
    }

    #[test]
    fn onoff_alternates_peak_and_trough() {
        let shape = LoadShape::OnOff { period: 60.0, duty: 0.3, peak_to_mean: 2.5 };
        let steps = shape.steps(6.0, 300.0);
        assert!(steps.len() >= 9, "{}", steps.len());
        assert!((steps[0].0 - 15.0).abs() < 1e-9, "peak {}", steps[0].0);
        assert!(steps[1].0 < 6.0, "trough {}", steps[1].0);
        assert!((steps[0].1 - 18.0).abs() < 1e-9);
    }

    #[test]
    fn trace_is_deterministic_and_class_tagged() {
        let s = by_name("mixed-slo").unwrap();
        let a = s.build_trace(42, 6.0);
        let b = s.build_trace(42, 6.0);
        assert_eq!(a, b, "same (scenario, seed, rate) must be bit-for-bit equal");
        assert_ne!(a, s.build_trace(43, 6.0));
        assert!(!a.is_empty());
        let interactive = a.iter().filter(|r| s.class_of(r.id) == 0).count();
        let batch = a.iter().filter(|r| s.class_of(r.id) == 1).count();
        assert!(interactive > batch, "{interactive} vs {batch}");
        assert!(batch > 0);
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "merged trace must be sorted");
        }
    }

    #[test]
    fn scheduler_dataset_is_tightest_ttft_class() {
        let s = by_name("mixed-slo").unwrap();
        assert_eq!(s.scheduler_dataset().name, "Alpaca-gpt4");
        let steady = by_name("steady").unwrap();
        assert_eq!(steady.scheduler_dataset().name, "ShareGPT");
    }
}
