//! The declarative scenario registry: each entry names a workload shape
//! the serving stack must survive, built from the same primitives as the
//! paper's evaluation (Table-4 datasets + Poisson/ramp arrival processes).
//!
//! A scenario is (traffic classes × load shape × horizon). Classes carry
//! their own dataset and therefore their own SLO pair (Table 4), which is
//! what lets `mixed-slo` score interactive and batch traffic separately;
//! the load shape modulates the *total* offered rate over time and is
//! normalized so `rate` is always the time-averaged offered rate.

use std::path::Path;

use anyhow::Result;

use crate::sim::ChurnProfile;
use crate::workload::import::StreamedTrace;
use crate::workload::replay::{leak, render_log, ReplayClass, ReplayRecord, ReplayTrace};
use crate::workload::{ClientPolicy, Dataset, RampTrace, Request, TraceGenerator};

/// One class of traffic inside a scenario. `share` is this class's
/// fraction of the scenario's total offered rate; shares sum to 1.
#[derive(Debug, Clone)]
pub struct TrafficClass {
    pub name: &'static str,
    pub dataset: Dataset,
    pub share: f64,
}

/// How the total offered rate evolves over the scenario horizon. All
/// shapes are normalized so the time-averaged rate equals the nominal
/// `rate` handed to [`Scenario::build_trace`].
#[derive(Debug, Clone)]
pub enum LoadShape {
    /// Fixed-rate Poisson — the paper's §4.1 setting.
    Steady,
    /// On/off square wave: `duty` of each `period` runs at
    /// `peak_to_mean × rate`, the remainder at the complementary trough
    /// rate (DistServe-style burst resilience probe).
    OnOff { period: f64, duty: f64, peak_to_mean: f64 },
    /// Half-sine day curve from `trough_mult` up to `peak_mult` and back,
    /// discretized into `segments` constant-rate steps.
    Diurnal { trough_mult: f64, peak_mult: f64, segments: usize },
    /// `cycles` back-to-back [`LoadShape::Diurnal`] day curves — the
    /// multi-day shape whose repeated day/night swing the mitosis
    /// autoscaler must track up *and* down.
    MultiDay { cycles: usize, trough_mult: f64, peak_mult: f64, segments: usize },
    /// Monotone escalation from `start_mult × rate` to `end_mult × rate`
    /// in `increments` equal steps (the Figure-10 [`RampTrace`] shape).
    Ramp { start_mult: f64, end_mult: f64, increments: usize },
    /// Replay of a recorded arrival log ([`ReplayTrace`]): arrivals come
    /// from the log, time-warped so the offered rate hits the nominal
    /// `rate` (see [`ReplayTrace::requests_at`]). The log — not a PRNG —
    /// is the randomness, so `seed` is unused on this path.
    Replay(ReplayTrace),
    /// Replay of an imported external trace consumed lazily from disk
    /// ([`StreamedTrace`]): same time-warp semantics as
    /// [`LoadShape::Replay`], but the driver feeds the engine a bounded-
    /// memory arrival iterator instead of a materialized vector, so
    /// multi-day multi-million-request logs replay in O(active requests)
    /// memory.
    Streamed(StreamedTrace),
}

impl LoadShape {
    /// Piecewise-constant (rate, duration) steps covering `duration`
    /// seconds at time-averaged rate `rate`. For [`LoadShape::Replay`]
    /// this is only the *nominal* profile (one flat step at the warped
    /// mean rate) — replay arrivals come straight from the log via
    /// [`Scenario::build_trace`], never from these steps.
    pub fn steps(&self, rate: f64, duration: f64) -> Vec<(f64, f64)> {
        // The arrival sampler needs strictly positive rates.
        const MIN_RATE: f64 = 0.05;
        match self {
            LoadShape::Steady | LoadShape::Replay(_) | LoadShape::Streamed(_) => {
                vec![(rate.max(MIN_RATE), duration)]
            }
            &LoadShape::OnOff { period, duty, peak_to_mean } => {
                let duty = duty.clamp(0.05, 0.95);
                let peak = rate * peak_to_mean;
                // Trough chosen so duty·peak + (1−duty)·trough = rate.
                let trough = (rate * (1.0 - duty * peak_to_mean) / (1.0 - duty))
                    .max(MIN_RATE);
                let mut out = Vec::new();
                let mut t = 0.0;
                while t < duration {
                    let on = (period * duty).min(duration - t);
                    if on > 0.0 {
                        out.push((peak.max(MIN_RATE), on));
                        t += on;
                    }
                    let off = (period * (1.0 - duty)).min(duration - t);
                    if off > 0.0 {
                        out.push((trough, off));
                        t += off;
                    }
                }
                out
            }
            &LoadShape::Diurnal { trough_mult, peak_mult, segments } => {
                let n = segments.max(2);
                let raw: Vec<f64> = (0..n)
                    .map(|i| {
                        let phase = std::f64::consts::PI * (i as f64 + 0.5) / n as f64;
                        trough_mult + (peak_mult - trough_mult) * phase.sin()
                    })
                    .collect();
                let mean = raw.iter().sum::<f64>() / n as f64;
                raw.into_iter()
                    .map(|m| ((rate * m / mean).max(MIN_RATE), duration / n as f64))
                    .collect()
            }
            &LoadShape::MultiDay { cycles, trough_mult, peak_mult, segments } => {
                let cycles = cycles.max(1);
                let day = LoadShape::Diurnal { trough_mult, peak_mult, segments };
                // Each cycle is one mean-normalized day curve, so the
                // multi-day mean equals `rate` too.
                let day_steps = day.steps(rate, duration / cycles as f64);
                let mut out = Vec::with_capacity(day_steps.len() * cycles);
                for _ in 0..cycles {
                    out.extend(day_steps.iter().copied());
                }
                out
            }
            &LoadShape::Ramp { start_mult, end_mult, increments } => {
                let n = increments.max(2);
                let ramp = RampTrace {
                    start_rate: rate * start_mult,
                    end_rate: rate * end_mult,
                    increments: n,
                    step_secs: duration / n as f64,
                };
                // Normalize so the time mean equals `rate` (a linear ramp's
                // mean is (start+end)/2).
                let mean = rate * (start_mult + end_mult) / 2.0;
                ramp.steps()
                    .into_iter()
                    .map(|(r, d)| ((r * rate / mean.max(1e-9)).max(MIN_RATE), d))
                    .collect()
            }
        }
    }
}

/// Rate-sweep bracket for the goodput-frontier search
/// ([`crate::frontier`]): where to start probing this scenario and how
/// far the search may climb. Bounds keep the adaptive search's wall
/// clock predictable — they cap the doubling phase, they don't presume
/// the answer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepBounds {
    /// Smallest rate worth probing (the last-resort "crumb").
    pub floor: f64,
    /// First bracketing probe.
    pub start: f64,
    /// Hard cap on probed rates.
    pub ceiling: f64,
}

impl SweepBounds {
    /// Bracket derived from a scenario's nominal operating rate: crumb at
    /// 1/16th, first probe at a quarter, cap at 8x. Registry entries use
    /// this unless a scenario needs a bespoke bracket.
    pub fn around(nominal_rate: f64) -> Self {
        SweepBounds {
            floor: (nominal_rate / 16.0).max(0.05),
            start: (nominal_rate / 4.0).max(0.1),
            ceiling: nominal_rate * 8.0,
        }
    }
}

/// Closed-loop overload probe attached to a scenario: which offered-load
/// multipliers the overload suite sweeps and how the clients behave
/// (TTFT timeout, bounded retries, jittered backoff) while sweeping
/// them. The suite reads the goodput-vs-offered-load curve across
/// `load_points`: past saturation an undefended system collapses —
/// timed-out work is still served and retries amplify the offered load —
/// while a defended coordinator sheds early and plateaus.
#[derive(Debug, Clone, Copy)]
pub struct OverloadProfile {
    /// Offered-load multipliers (× the probed base rate), ascending.
    pub load_points: &'static [f64],
    /// Closed-loop client behaviour at every load point.
    pub client: ClientPolicy,
}

/// A named workload scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub summary: &'static str,
    pub classes: Vec<TrafficClass>,
    pub shape: LoadShape,
    /// Trace horizon, seconds.
    pub duration: f64,
    /// Warm-up prefix excluded from scoring, seconds.
    pub warmup: f64,
    /// Nominal time-averaged offered rate (req/s) when the caller gives
    /// none — tuned for the default 8-instance CodeLlama-34B/L20 layout.
    pub default_rate: f64,
    /// Frontier-search bracket for this scenario's rate sweep.
    pub sweep: SweepBounds,
    /// Hardware-churn shape injected alongside the traffic (`None` =
    /// fault-free). Expanded into a concrete, deterministic
    /// [`crate::sim::FaultSchedule`] by the driver when a `--fault-seed`
    /// is supplied, so the same (scenario, fault seed) pair always
    /// replays the identical outage timeline.
    pub churn: Option<ChurnProfile>,
    /// Closed-loop overload probe (`None` = open loop only). The
    /// overload suite (`--overload-out`) runs each load point
    /// undefended-vs-defended with this profile's client model.
    pub overload: Option<OverloadProfile>,
}

impl Scenario {
    /// The dataset whose SLO pair drives the *scheduler* (admission and
    /// routing decisions): the tightest-TTFT class. Scoring remains
    /// per-class against each class's own SLOs.
    pub fn scheduler_dataset(&self) -> Dataset {
        self.classes
            .iter()
            .min_by(|a, b| {
                a.dataset
                    .slo_ttft
                    .partial_cmp(&b.dataset.slo_ttft)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .expect("scenario has at least one class")
            .dataset
            .clone()
    }

    /// Which traffic class a request id belongs to. Synthetic traces tag
    /// ids `idx × n_classes + class` and the class is the residue;
    /// replayed traffic carries *log-assigned* classes with no such
    /// structure, so attribution goes through the [`ReplayTrace`] side
    /// table instead — the modulo arithmetic would silently misattribute
    /// every replayed request whose class ≠ id mod n.
    pub fn class_of(&self, id: u64) -> usize {
        match &self.shape {
            LoadShape::Replay(trace) => trace.class_of(id),
            LoadShape::Streamed(stream) => stream.class_of(id),
            _ => (id % self.classes.len() as u64) as usize,
        }
    }

    /// True when this scenario replays a recorded log (materialized or
    /// streamed) — i.e. the arrivals come from a capture, not a PRNG.
    pub fn is_replay(&self) -> bool {
        matches!(self.shape, LoadShape::Replay(_) | LoadShape::Streamed(_))
    }

    /// The recorded log behind a materialized replay scenario.
    pub fn replay(&self) -> Option<&ReplayTrace> {
        match &self.shape {
            LoadShape::Replay(trace) => Some(trace),
            _ => None,
        }
    }

    /// The lazily-consumed trace behind a streamed replay scenario.
    pub fn stream(&self) -> Option<&StreamedTrace> {
        match &self.shape {
            LoadShape::Streamed(stream) => Some(stream),
            _ => None,
        }
    }

    /// (duration, warmup) at offered rate `rate`. Synthetic shapes have a
    /// rate-independent horizon. A replayed log's span *scales with the
    /// time warp*: compressing (rate above native) shortens it, and
    /// stretching is clipped at the recorded span — so the horizon never
    /// exceeds the native span and the scored window always carries the
    /// probe rate (a longer window would trail a dead, rate-diluting
    /// tail; see [`ReplayTrace::requests_at`]).
    pub fn horizon_at(&self, rate: f64) -> (f64, f64) {
        let native = match &self.shape {
            LoadShape::Replay(trace) => trace.native_rate(),
            LoadShape::Streamed(stream) => stream.native_rate(),
            _ => return (self.duration, self.warmup),
        };
        let warp = native / rate.max(1e-12);
        let duration = self.duration * warp.min(1.0);
        (duration, self.warmup.min(duration / 4.0))
    }

    /// Deterministically generate the merged multi-class trace at
    /// time-averaged `rate` req/s: bit-for-bit reproducible from
    /// (scenario, seed, rate), matching the simulator's determinism
    /// contract (`sim::engine` orders ties by insertion). Replay
    /// scenarios ignore `seed` — the recorded log is the randomness —
    /// and time-warp the log to `rate`, clipped at `self.duration`.
    pub fn build_trace(&self, seed: u64, rate: f64) -> Vec<Request> {
        self.build_trace_for(seed, rate, self.duration)
    }

    /// [`Scenario::build_trace`] with an explicit `horizon` (the driver's
    /// possibly-overridden duration), so callers shortening the window
    /// don't have to clone the scenario — a replay scenario carries the
    /// whole recorded log by value, and the frontier probes each cell
    /// many times.
    pub fn build_trace_for(&self, seed: u64, rate: f64, horizon: f64) -> Vec<Request> {
        match &self.shape {
            LoadShape::Replay(trace) => return trace.requests_at(rate, horizon),
            // Materializing a streamed trace defeats its purpose for huge
            // logs, but keeps every build_trace caller (record, tests)
            // working; the driver streams instead of calling this.
            LoadShape::Streamed(stream) => {
                return stream
                    .arrivals_at(rate, horizon)
                    .unwrap_or_else(|e| {
                        panic!("streamed trace '{}' unreadable: {e:#}", stream.source())
                    })
                    .collect();
            }
            _ => {}
        }
        let n_classes = self.classes.len() as u64;
        let mut merged: Vec<Request> = Vec::new();
        for (k, class) in self.classes.iter().enumerate() {
            let steps = self.shape.steps(rate * class.share, horizon);
            // Per-class stream: distinct seeds give independent arrivals.
            let gen = TraceGenerator::new(
                class.dataset.clone(),
                seed.wrapping_add(0x9E37_79B9u64.wrapping_mul(k as u64 + 1)),
            );
            for mut req in gen.ramp(&steps) {
                req.id = req.id * n_classes + k as u64;
                merged.push(req);
            }
        }
        merged.sort_by(|a, b| {
            a.arrival
                .partial_cmp(&b.arrival)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        merged
    }

    /// Wrap a parsed arrival log as a scenario: classes, horizon, and
    /// warm-up come from the log, the nominal rate is the log's native
    /// rate, and the frontier sweep brackets around it. Runs flow through
    /// the exact machinery synthetic scenarios use — per-class strict
    /// scoring, frontier probes, the mitosis-on variant.
    pub fn from_replay(trace: ReplayTrace) -> Scenario {
        let native_rate = trace.native_rate();
        let duration = trace.duration();
        let warmup = trace.warmup();
        let counts = trace.class_counts();
        let total = trace.len().max(1) as f64;
        let classes = trace
            .classes()
            .iter()
            .zip(&counts)
            .map(|(c, &n)| TrafficClass {
                name: c.name,
                dataset: c.dataset.clone(),
                share: n as f64 / total,
            })
            .collect();
        let name = leak(format!("replay:{}", trace.source()));
        let summary = leak(format!(
            "replayed arrival log '{}': {} requests over {:.0}s ({:.2} req/s native)",
            trace.source(),
            trace.len(),
            duration,
            native_rate,
        ));
        Scenario {
            name,
            summary,
            classes,
            shape: LoadShape::Replay(trace),
            duration,
            warmup,
            default_rate: native_rate,
            sweep: SweepBounds::around(native_rate),
            churn: None,
            overload: None,
        }
    }

    /// Load a recorded arrival log from disk as a replay scenario
    /// (`ecoserve scenarios --replay <log>` / `ecoserve frontier
    /// --replay <log>`).
    pub fn from_log(path: &Path) -> Result<Scenario> {
        Ok(Scenario::from_replay(ReplayTrace::from_file(path)?))
    }

    /// Wrap a streamed external trace as a scenario (`--import <file>
    /// --format <fmt>`): the [`Scenario::from_replay`] contract — classes,
    /// horizon, warm-up, native nominal rate, sweep around it — with the
    /// arrivals left on disk until the engine consumes them.
    pub fn from_stream(stream: StreamedTrace) -> Scenario {
        let native_rate = stream.native_rate();
        let duration = stream.duration();
        let warmup = stream.warmup();
        let counts = stream.class_counts();
        let total = stream.len().max(1) as f64;
        let classes = stream
            .classes()
            .iter()
            .zip(&counts)
            .map(|(c, &n)| TrafficClass {
                name: c.name,
                dataset: c.dataset.clone(),
                share: n as f64 / total,
            })
            .collect();
        let name = leak(format!("replay:{}", stream.source()));
        let summary = leak(format!(
            "streamed {} trace '{}': {} requests over {:.0}s ({:.2} req/s native)",
            stream.format().label(),
            stream.source(),
            stream.len(),
            duration,
            native_rate,
        ));
        Scenario {
            name,
            summary,
            classes,
            shape: LoadShape::Streamed(stream),
            duration,
            warmup,
            default_rate: native_rate,
            sweep: SweepBounds::around(native_rate),
            churn: None,
            overload: None,
        }
    }

    /// Export this scenario's trace at (seed, rate) in the recorded-log
    /// format (`ecoserve record`). Parsing the result back with
    /// [`Scenario::from_log`] reproduces the trace bit-for-bit modulo id
    /// retagging — the round-trip that keeps the wire format honest.
    pub fn record_log(&self, seed: u64, rate: f64) -> String {
        let classes: Vec<ReplayClass> = self
            .classes
            .iter()
            .map(|c| ReplayClass { name: c.name, dataset: c.dataset.clone() })
            .collect();
        // Full provenance for the header `source` field. Re-recording a
        // replayed trace keeps the *original* lineage instead of stamping
        // a new one, so record → import → record chains never lose where
        // the arrivals actually came from.
        let source = match &self.shape {
            LoadShape::Replay(trace) if trace.lineage().is_some() => {
                trace.lineage().unwrap_or_default().to_string()
            }
            LoadShape::Streamed(stream) => stream.lineage().to_string(),
            _ => format!(
                "scenario '{}' seed {} @ {} req/s (ecoserve v{})",
                self.name,
                seed,
                rate,
                env!("CARGO_PKG_VERSION")
            ),
        };
        let records = self.build_trace(seed, rate).into_iter().map(|req| ReplayRecord {
            arrival: req.arrival,
            input_len: req.input_len,
            output_len: req.output_len,
            class: self.class_of(req.id),
        });
        render_log(&classes, self.duration, self.warmup, &source, records)
    }
}

fn single(class_name: &'static str, dataset: Dataset) -> Vec<TrafficClass> {
    vec![TrafficClass { name: class_name, dataset, share: 1.0 }]
}

/// The built-in scenario registry (≥ 5 entries; `ecoserve scenarios
/// --list` prints this table).
pub fn registry() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "steady",
            summary: "fixed-rate Poisson on ShareGPT — the paper's §4.1 operating point",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::Steady,
            duration: 240.0,
            warmup: 30.0,
            default_rate: 8.0,
            sweep: SweepBounds::around(8.0),
            churn: None,
            overload: None,
        },
        Scenario {
            name: "bursty",
            summary: "on/off bursts at 2.5x the mean rate — flash-crowd resilience \
                      (rolling activation must absorb each front)",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::OnOff { period: 60.0, duty: 0.3, peak_to_mean: 2.5 },
            duration: 300.0,
            warmup: 30.0,
            default_rate: 6.0,
            sweep: SweepBounds::around(6.0),
            churn: None,
            overload: None,
        },
        Scenario {
            name: "diurnal",
            summary: "half-sine day curve, 0.4x..1.8x the mean rate in 12 steps",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::Diurnal { trough_mult: 0.4, peak_mult: 1.8, segments: 12 },
            duration: 360.0,
            warmup: 30.0,
            default_rate: 7.0,
            sweep: SweepBounds::around(7.0),
            churn: None,
            overload: None,
        },
        Scenario {
            name: "multiday",
            summary: "three compressed day/night cycles (0.3x..2.0x the mean rate) — \
                      the multi-day replay shape mitosis must track up and down",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::MultiDay {
                cycles: 3,
                trough_mult: 0.3,
                peak_mult: 2.0,
                segments: 10,
            },
            duration: 540.0,
            warmup: 30.0,
            default_rate: 6.0,
            sweep: SweepBounds::around(6.0),
            churn: None,
            overload: None,
        },
        Scenario {
            name: "heavy-tail",
            summary: "LongBench long-context prompts (heavy-tailed inputs, short \
                      outputs) at steady rate — maximal prefill/decode interference",
            classes: single("summarize", Dataset::longbench()),
            shape: LoadShape::Steady,
            duration: 240.0,
            warmup: 30.0,
            default_rate: 2.5,
            sweep: SweepBounds::around(2.5),
            churn: None,
            overload: None,
        },
        Scenario {
            name: "mixed-slo",
            summary: "70% interactive (Alpaca, 1s TTFT SLO) + 30% batch (LongBench, \
                      15s TTFT SLO) sharing the fleet; scored per class",
            classes: vec![
                TrafficClass { name: "interactive", dataset: Dataset::alpaca(), share: 0.7 },
                TrafficClass { name: "batch", dataset: Dataset::longbench(), share: 0.3 },
            ],
            shape: LoadShape::Steady,
            duration: 240.0,
            warmup: 30.0,
            default_rate: 6.0,
            sweep: SweepBounds::around(6.0),
            churn: None,
            overload: None,
        },
        Scenario {
            name: "surge",
            summary: "monotone escalation 0.5x -> 1.5x of the mean rate in 6 steps \
                      (the Figure-10 ramp, without autoscaling)",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::Ramp { start_mult: 0.5, end_mult: 1.5, increments: 6 },
            duration: 300.0,
            warmup: 30.0,
            default_rate: 6.0,
            sweep: SweepBounds::around(6.0),
            churn: None,
            overload: None,
        },
        Scenario {
            name: "steady+churn",
            summary: "the steady operating point with instance crashes every ~45s \
                      (20s outages) — goodput retained under hardware churn",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::Steady,
            duration: 240.0,
            warmup: 30.0,
            default_rate: 6.0,
            sweep: SweepBounds::around(6.0),
            churn: Some(ChurnProfile::crashes(45.0, 20.0)),
            overload: None,
        },
        Scenario {
            name: "surge+preemption",
            summary: "the Figure-10 ramp while spot capacity is reclaimed every \
                      ~60s (10s notice, 30s outages) — recovery under rising load",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::Ramp { start_mult: 0.5, end_mult: 1.5, increments: 6 },
            duration: 300.0,
            warmup: 30.0,
            default_rate: 5.0,
            sweep: SweepBounds::around(5.0),
            churn: Some(ChurnProfile::preemptions(60.0, 10.0, 30.0)),
            overload: None,
        },
        Scenario {
            name: "spot-decode-reclaim",
            summary: "steady traffic with near-zero-notice spot reclaims every ~50s \
                      (1s notice, 25s outages) — mid-decode state is on the line",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::Steady,
            duration: 240.0,
            warmup: 30.0,
            default_rate: 6.0,
            sweep: SweepBounds::around(6.0),
            churn: Some(ChurnProfile::preemptions(50.0, 1.0, 25.0)),
            overload: None,
        },
        Scenario {
            name: "overload-sustained",
            summary: "sustained 1x..3x saturation on ShareGPT with closed-loop \
                      clients (patient timeout/retry) — the goodput-vs-offered-load \
                      curve past the knee",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::Steady,
            duration: 240.0,
            warmup: 30.0,
            default_rate: 8.0,
            sweep: SweepBounds::around(8.0),
            churn: None,
            overload: Some(OverloadProfile {
                load_points: &[1.0, 1.5, 2.25, 3.0],
                client: ClientPolicy::standard(),
            }),
        },
        Scenario {
            name: "retry-storm",
            summary: "flash crowd with impatient clients (short timeout, 4 retries, \
                      short backoff) — rejected and timed-out attempts re-arrive and \
                      amplify the spike they are stuck behind",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::OnOff { period: 120.0, duty: 0.4, peak_to_mean: 2.2 },
            duration: 240.0,
            warmup: 30.0,
            default_rate: 7.0,
            sweep: SweepBounds::around(7.0),
            churn: None,
            overload: Some(OverloadProfile {
                load_points: &[1.0, 2.0],
                client: ClientPolicy::aggressive(),
            }),
        },
        Scenario {
            name: "slow-drain",
            summary: "one 2.5x burst then a long half-rate tail — does goodput \
                      recover once the storm passes, or does the retry backlog keep \
                      the fleet pinned",
            classes: single("chat", Dataset::sharegpt()),
            shape: LoadShape::OnOff { period: 300.0, duty: 0.25, peak_to_mean: 2.5 },
            duration: 300.0,
            warmup: 30.0,
            default_rate: 6.0,
            sweep: SweepBounds::around(6.0),
            churn: None,
            overload: Some(OverloadProfile {
                load_points: &[1.0, 1.75],
                client: ClientPolicy::standard(),
            }),
        },
    ]
}

/// Look a scenario up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<Scenario> {
    let lower = name.to_ascii_lowercase();
    registry().into_iter().find(|s| s.name == lower)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_at_least_five_unique_scenarios() {
        let all = registry();
        assert!(all.len() >= 5, "only {} scenarios", all.len());
        let mut names: Vec<&str> = all.iter().map(|s| s.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), all.len(), "duplicate scenario names");
        for s in &all {
            let share: f64 = s.classes.iter().map(|c| c.share).sum();
            assert!((share - 1.0).abs() < 1e-9, "{}: shares sum {share}", s.name);
            assert!(s.warmup < s.duration, "{}", s.name);
            assert!(s.default_rate > 0.0, "{}", s.name);
        }
    }

    #[test]
    fn sweep_bounds_bracket_the_default_rate() {
        for s in registry() {
            let b = s.sweep;
            assert!(b.floor > 0.0, "{}: floor {}", s.name, b.floor);
            assert!(b.floor < b.start, "{}: floor {} >= start {}", s.name, b.floor, b.start);
            assert!(b.start < b.ceiling, "{}: start {} >= ceiling {}", s.name, b.start, b.ceiling);
            assert!(
                b.floor <= s.default_rate && s.default_rate <= b.ceiling,
                "{}: default rate {} outside sweep [{}, {}]",
                s.name,
                s.default_rate,
                b.floor,
                b.ceiling
            );
        }
        let b = SweepBounds::around(8.0);
        assert_eq!(b.floor, 0.5);
        assert_eq!(b.start, 2.0);
        assert_eq!(b.ceiling, 64.0);
    }

    #[test]
    fn churn_scenarios_carry_profiles_and_fault_free_ones_do_not() {
        let churned: Vec<&str> = registry()
            .iter()
            .filter(|s| s.churn.is_some())
            .map(|s| s.name)
            .collect();
        assert_eq!(
            churned,
            vec!["steady+churn", "surge+preemption", "spot-decode-reclaim"]
        );
        assert!(by_name("steady").unwrap().churn.is_none());
        // The profiles must actually produce faults inside the scored
        // window at the registry horizons.
        for name in churned {
            let s = by_name(name).unwrap();
            let sched = crate::sim::FaultSchedule::generate(
                s.churn.as_ref().unwrap(),
                7,
                s.duration,
                s.warmup,
                8,
            );
            assert!(!sched.is_empty(), "{name}: empty generated schedule");
        }
    }

    #[test]
    fn overload_scenarios_carry_profiles_with_ascending_load_points() {
        let names: Vec<&str> = registry()
            .iter()
            .filter(|s| s.overload.is_some())
            .map(|s| s.name)
            .collect();
        assert_eq!(names, vec!["overload-sustained", "retry-storm", "slow-drain"]);
        for s in registry() {
            let Some(p) = s.overload else { continue };
            assert!(p.load_points.len() >= 2, "{}: need a curve, not a point", s.name);
            for w in p.load_points.windows(2) {
                assert!(w[0] < w[1], "{}: load points must ascend", s.name);
            }
            assert!(
                p.load_points[0] >= 1.0 - 1e-9,
                "{}: the sweep starts at the nominal operating point",
                s.name
            );
            assert!(p.client.max_retries > 0 && p.client.timeout_s > 0.0, "{}", s.name);
            assert!(s.churn.is_none(), "{}: overload scenarios run fault-free", s.name);
        }
    }

    #[test]
    fn lookup_is_case_insensitive() {
        assert!(by_name("BURSTY").is_some());
        assert!(by_name("mixed-slo").is_some());
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn shapes_preserve_the_mean_rate() {
        for s in registry() {
            let rate = 6.0;
            let steps = s.shape.steps(rate, s.duration);
            let total_time: f64 = steps.iter().map(|(_, d)| d).sum();
            let weighted: f64 = steps.iter().map(|(r, d)| r * d).sum();
            assert!(
                (total_time - s.duration).abs() < 1e-6,
                "{}: steps cover {total_time}s of {}s",
                s.name,
                s.duration
            );
            let mean = weighted / total_time;
            assert!(
                (mean - rate).abs() / rate < 0.02,
                "{}: mean rate {mean} vs nominal {rate}",
                s.name
            );
            for (r, d) in steps {
                assert!(r > 0.0 && d > 0.0);
            }
        }
    }

    #[test]
    fn multiday_repeats_the_normalized_day_curve() {
        let s = by_name("multiday").unwrap();
        let steps = s.shape.steps(6.0, s.duration);
        assert_eq!(steps.len(), 30, "3 cycles x 10 segments");
        // Every cycle is the first one repeated — the day/night swing the
        // autoscaler must ride multiple times.
        for k in 1..3 {
            for i in 0..10 {
                assert_eq!(steps[k * 10 + i], steps[i], "cycle {k} step {i}");
            }
        }
        let peak = steps.iter().map(|s| s.0).fold(f64::MIN, f64::max);
        let trough = steps.iter().map(|s| s.0).fold(f64::MAX, f64::min);
        assert!(trough < 3.0, "trough {trough} should sit well below the 6 req/s mean");
        assert!(peak > 8.0, "peak {peak} should sit well above the 6 req/s mean");
    }

    #[test]
    fn record_log_stamps_generator_provenance_and_preserves_lineage() {
        let s = by_name("bursty").unwrap();
        let log = s.record_log(7, 6.0);
        let header = log.lines().next().unwrap();
        assert!(
            header.contains("scenario 'bursty' seed 7 @ 6 req/s (ecoserve v"),
            "{header}"
        );
        let t = ReplayTrace::parse_named(&log, "rec.jsonl").unwrap();
        assert!(t.lineage().unwrap().contains("ecoserve v"));
        // Re-recording the replayed scenario must keep the original
        // provenance, not stamp a new "scenario 'replay:...'" line — the
        // record → import → record lineage chain.
        let s2 = Scenario::from_replay(t.clone());
        let log2 = s2.record_log(0, s2.default_rate);
        let t2 = ReplayTrace::parse_named(&log2, "rec2.jsonl").unwrap();
        assert_eq!(t2.lineage(), t.lineage());
    }

    #[test]
    fn onoff_alternates_peak_and_trough() {
        let shape = LoadShape::OnOff { period: 60.0, duty: 0.3, peak_to_mean: 2.5 };
        let steps = shape.steps(6.0, 300.0);
        assert!(steps.len() >= 9, "{}", steps.len());
        assert!((steps[0].0 - 15.0).abs() < 1e-9, "peak {}", steps[0].0);
        assert!(steps[1].0 < 6.0, "trough {}", steps[1].0);
        assert!((steps[0].1 - 18.0).abs() < 1e-9);
    }

    #[test]
    fn trace_is_deterministic_and_class_tagged() {
        let s = by_name("mixed-slo").unwrap();
        let a = s.build_trace(42, 6.0);
        let b = s.build_trace(42, 6.0);
        assert_eq!(a, b, "same (scenario, seed, rate) must be bit-for-bit equal");
        assert_ne!(a, s.build_trace(43, 6.0));
        assert!(!a.is_empty());
        let interactive = a.iter().filter(|r| s.class_of(r.id) == 0).count();
        let batch = a.iter().filter(|r| s.class_of(r.id) == 1).count();
        assert!(interactive > batch, "{interactive} vs {batch}");
        assert!(batch > 0);
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "merged trace must be sorted");
        }
    }

    #[test]
    fn scheduler_dataset_is_tightest_ttft_class() {
        let s = by_name("mixed-slo").unwrap();
        assert_eq!(s.scheduler_dataset().name, "Alpaca-gpt4");
        let steady = by_name("steady").unwrap();
        assert_eq!(steady.scheduler_dataset().name, "ShareGPT");
    }

    /// A log whose classes do NOT follow the synthetic `id % n` tagging:
    /// three consecutive class-1 records. The side table must attribute
    /// them correctly where the modulo arithmetic would not.
    #[test]
    fn replay_class_attribution_uses_the_log_not_modulo() {
        let text = "{\"ecoserve_trace\":1,\"duration_s\":8,\"warmup_s\":1,\"classes\":\
                    [{\"name\":\"a\",\"dataset\":\"alpaca\"},\
                     {\"name\":\"b\",\"dataset\":\"longbench\"}]}\n\
                    {\"arrival_s\":1,\"input_len\":10,\"output_len\":5,\"class\":1}\n\
                    {\"arrival_s\":2,\"input_len\":10,\"output_len\":5,\"class\":1}\n\
                    {\"arrival_s\":3,\"input_len\":10,\"output_len\":5,\"class\":1}\n\
                    {\"arrival_s\":4,\"input_len\":10,\"output_len\":5,\"class\":0}\n";
        let s = Scenario::from_replay(ReplayTrace::parse_named(text, "t").unwrap());
        assert!(s.is_replay());
        let trace = s.build_trace(0, s.default_rate);
        assert_eq!(trace.len(), 4);
        let classes: Vec<usize> = trace.iter().map(|r| s.class_of(r.id)).collect();
        assert_eq!(classes, vec![1, 1, 1, 0]);
        // The modulo rule (id % n over sequential replay ids 0..4) would
        // have produced [0, 1, 0, 1] here — every single one wrong.
        let modulo: Vec<usize> = trace.iter().map(|r| (r.id % 2) as usize).collect();
        assert_eq!(modulo, vec![0, 1, 0, 1]);
        assert_ne!(classes, modulo);
        // Shares follow the log's class mix.
        assert!((s.classes[0].share - 0.25).abs() < 1e-12);
        assert!((s.classes[1].share - 0.75).abs() < 1e-12);
        assert_eq!(s.scheduler_dataset().name, "Alpaca-gpt4");
    }

    #[test]
    fn replay_horizon_scales_with_the_time_warp() {
        let text = "{\"ecoserve_trace\":1,\"duration_s\":100,\"warmup_s\":10}\n\
                    {\"arrival_s\":10,\"input_len\":10,\"output_len\":5}\n\
                    {\"arrival_s\":60,\"input_len\":10,\"output_len\":5}\n";
        let s = Scenario::from_replay(ReplayTrace::parse_named(text, "t").unwrap());
        let native = s.default_rate; // 2 / 100 = 0.02 req/s
        assert!((native - 0.02).abs() < 1e-12);
        // Native rate: the recorded horizon and warmup.
        assert_eq!(s.horizon_at(native), (100.0, 10.0));
        // Compress 4x: horizon shrinks 4x, warmup clamps inside it.
        let (d, w) = s.horizon_at(native * 4.0);
        assert!((d - 25.0).abs() < 1e-9);
        assert!(w <= d / 4.0 + 1e-12);
        // Stretch: clipped at the recorded span, never longer.
        assert_eq!(s.horizon_at(native / 8.0), (100.0, 10.0));
        // Synthetic scenarios are rate-independent.
        let steady = by_name("steady").unwrap();
        assert_eq!(steady.horizon_at(1.0), steady.horizon_at(100.0));
        assert_eq!(steady.horizon_at(1.0), (steady.duration, steady.warmup));
    }

    #[test]
    fn replay_scenario_is_deterministic_and_sweeps_around_native() {
        let text = "{\"arrival_s\":0.5,\"input_len\":10,\"output_len\":5}\n\
                    {\"arrival_s\":1.25,\"input_len\":20,\"output_len\":5}\n\
                    {\"arrival_s\":2.5,\"input_len\":30,\"output_len\":5}\n";
        let s = Scenario::from_replay(ReplayTrace::parse_named(text, "t").unwrap());
        // Different seeds, same trace: the log is the randomness.
        assert_eq!(s.build_trace(1, s.default_rate), s.build_trace(99, s.default_rate));
        assert!(!s.build_trace(0, s.default_rate).is_empty());
        let b = s.sweep;
        assert!(b.floor < s.default_rate && s.default_rate < b.ceiling);
        assert!(s.name.starts_with("replay:"));
    }
}
