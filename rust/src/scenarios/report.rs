//! Machine-readable (JSON, via the in-tree `util::json`) and human
//! (table) renderings of scenario-suite outcomes. The JSON shape is the
//! contract consumed by CI artifacts and downstream tooling; keep it
//! stable and additive.

use super::driver::{ScenarioConfig, ScenarioOutcome, SystemRow};
use crate::util::json::Json;

fn pct_obj(p50: f64, p90: f64, p99: f64) -> Json {
    Json::obj(vec![
        ("p50", Json::num(p50)),
        ("p90", Json::num(p90)),
        ("p99", Json::num(p99)),
    ])
}

fn row_to_json(row: &SystemRow) -> Json {
    let s = &row.summary;
    Json::obj(vec![
        ("system", Json::str(row.system.label())),
        ("arrived", Json::num(row.arrived as f64)),
        ("completed", Json::num(row.completed as f64)),
        ("met_slo", Json::num(row.met as f64)),
        ("attainment", Json::num(row.attainment)),
        ("goodput_rps", Json::num(row.goodput_rps)),
        ("token_throughput", Json::num(s.token_throughput)),
        ("ttft_s", pct_obj(s.ttft_p50, s.ttft_p90, s.ttft_p99)),
        ("tpot_s", pct_obj(s.tpot_p50, s.tpot_p90, s.tpot_p99)),
        (
            "classes",
            Json::arr(row.classes.iter().map(|c| {
                Json::obj(vec![
                    ("class", Json::str(c.class)),
                    ("arrived", Json::num(c.arrived as f64)),
                    ("met_slo", Json::num(c.met as f64)),
                    ("attainment", Json::num(c.attainment)),
                ])
            })),
        ),
        ("sim_events", Json::num(row.events as f64)),
    ])
}

fn outcome_to_json(outcome: &ScenarioOutcome) -> Json {
    Json::obj(vec![
        ("name", Json::str(outcome.scenario.name)),
        ("summary", Json::str(outcome.scenario.summary)),
        ("offered_rate_rps", Json::num(outcome.rate)),
        ("duration_s", Json::num(outcome.duration)),
        ("warmup_s", Json::num(outcome.warmup)),
        (
            "best_system",
            match outcome.best() {
                Some(r) => Json::str(r.system.label()),
                None => Json::Null,
            },
        ),
        ("systems", Json::arr(outcome.rows.iter().map(row_to_json))),
    ])
}

/// The full suite report.
pub fn suite_to_json(outcomes: &[ScenarioOutcome], cfg: &ScenarioConfig) -> Json {
    let d = &cfg.deployment;
    Json::obj(vec![
        ("suite", Json::str("ecoserve-scenarios")),
        ("version", Json::num(1.0)),
        ("seed", Json::num(cfg.seed as f64)),
        (
            "deployment",
            Json::obj(vec![
                ("model", Json::str(d.model.name)),
                ("cluster", Json::str(d.cluster.name)),
                ("gpus_used", Json::num(d.gpus_used as f64)),
                ("tp", Json::num(d.tp as f64)),
                ("pp", Json::num(d.pp as f64)),
                ("instances", Json::num(d.num_instances() as f64)),
            ]),
        ),
        ("scenarios", Json::arr(outcomes.iter().map(outcome_to_json))),
    ])
}

/// Human-readable table for one scenario outcome.
pub fn render_table(outcome: &ScenarioOutcome) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "--- scenario '{}' @ {:.2} req/s (window {:.0}..{:.0}s) ---\n",
        outcome.scenario.name, outcome.rate, outcome.warmup, outcome.duration
    ));
    out.push_str(&format!(
        "{:<10} {:>8} {:>9} {:>10} {:>11} {:>11} {:>11}\n",
        "system", "arrived", "attain %", "goodput/s", "p99TTFT s", "p99TPOT ms", "tok/s"
    ));
    for row in &outcome.rows {
        let s = &row.summary;
        out.push_str(&format!(
            "{:<10} {:>8} {:>9.1} {:>10.2} {:>11.2} {:>11.1} {:>11.0}\n",
            row.system.label(),
            row.arrived,
            row.attainment * 100.0,
            row.goodput_rps,
            s.ttft_p99,
            s.tpot_p99 * 1e3,
            s.token_throughput,
        ));
        if row.classes.len() > 1 {
            for c in &row.classes {
                out.push_str(&format!(
                    "  {:<12} class '{}': {}/{} met ({:.1}%)\n",
                    "", c.class, c.met, c.arrived, c.attainment * 100.0
                ));
            }
        }
    }
    if let Some(best) = outcome.best() {
        out.push_str(&format!("  best: {}\n", best.system.label()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemKind;
    use crate::scenarios::driver::run_scenario;
    use crate::scenarios::registry::by_name;

    fn outcome() -> (ScenarioOutcome, ScenarioConfig) {
        let mut cfg = ScenarioConfig::default_l20();
        cfg.deployment.gpus_used = 16;
        cfg.duration_override = Some(45.0);
        cfg.rate = Some(2.0);
        let s = by_name("steady").unwrap();
        (
            run_scenario(&s, &cfg, &[SystemKind::EcoServe, SystemKind::Vllm]),
            cfg,
        )
    }

    #[test]
    fn json_roundtrips_and_has_the_contract_fields() {
        let (o, cfg) = outcome();
        let j = suite_to_json(&[o], &cfg);
        let text = j.to_string();
        let back = Json::parse(&text).expect("report must be valid JSON");
        assert_eq!(back.path(&["suite"]).unwrap().as_str(), Some("ecoserve-scenarios"));
        assert_eq!(
            back.path(&["deployment", "instances"]).unwrap().as_i64(),
            Some(4)
        );
        let scenarios = back.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(scenarios.len(), 1);
        let sc = &scenarios[0];
        assert_eq!(sc.get("name").unwrap().as_str(), Some("steady"));
        let systems = sc.get("systems").unwrap().as_arr().unwrap();
        assert_eq!(systems.len(), 2);
        for sys in systems {
            for key in [
                "system", "arrived", "attainment", "goodput_rps", "ttft_s",
                "tpot_s", "classes",
            ] {
                assert!(sys.get(key).is_some(), "missing {key}");
            }
            let a = sys.get("attainment").unwrap().as_f64().unwrap();
            assert!((0.0..=1.0).contains(&a));
            assert!(sys.path(&["ttft_s", "p99"]).unwrap().as_f64().is_some());
        }
        assert!(sc.get("best_system").unwrap().as_str().is_some());
    }

    #[test]
    fn table_renders_every_system() {
        let (o, _) = outcome();
        let table = render_table(&o);
        assert!(table.contains("EcoServe"));
        assert!(table.contains("vLLM"));
        assert!(table.contains("best:"));
    }
}
