//! Machine-readable (JSON, via the in-tree `util::json`) and human
//! (table) renderings of scenario-suite outcomes. The JSON shape is the
//! contract consumed by CI artifacts and downstream tooling; keep it
//! stable and additive.

use super::driver::{ClassScore, ScenarioConfig, ScenarioOutcome, SystemRow};
use crate::config::Deployment;
use crate::util::json::Json;

/// Version of the report contracts, shared by the scenario suite report
/// and the frontier's `BENCH_goodput.json` so downstream tooling checks
/// one number. Bump on any breaking (non-additive) change to either.
pub const SCHEMA_VERSION: f64 = 2.0;

/// The deployment block both report schemas embed.
pub fn deployment_to_json(d: &Deployment) -> Json {
    Json::obj(vec![
        ("model", Json::str(d.model.name)),
        ("cluster", Json::str(d.cluster.name)),
        ("gpus_used", Json::num(d.gpus_used as f64)),
        ("tp", Json::num(d.tp as f64)),
        ("pp", Json::num(d.pp as f64)),
        ("instances", Json::num(d.num_instances() as f64)),
    ])
}

/// The per-traffic-class score block both report schemas embed.
pub fn class_to_json(c: &ClassScore) -> Json {
    Json::obj(vec![
        ("class", Json::str(c.class)),
        ("arrived", Json::num(c.arrived as f64)),
        ("met_slo", Json::num(c.met as f64)),
        ("attainment", Json::num(c.attainment)),
    ])
}

fn pct_obj(p50: f64, p90: f64, p99: f64) -> Json {
    Json::obj(vec![
        ("p50", Json::num(p50)),
        ("p90", Json::num(p90)),
        ("p99", Json::num(p99)),
    ])
}

/// The per-system result block; shared with the churn report
/// ([`super::churn::churn_to_json`] embeds it for the clean and faulted
/// halves of each pairing).
pub fn row_to_json(row: &SystemRow) -> Json {
    let s = &row.summary;
    let mut fields = vec![
        ("system", Json::str(row.system.label())),
        ("arrived", Json::num(row.arrived as f64)),
        ("completed", Json::num(row.completed as f64)),
        ("met_slo", Json::num(row.met as f64)),
        ("attainment", Json::num(row.attainment)),
        ("goodput_rps", Json::num(row.goodput_rps)),
        ("token_throughput", Json::num(s.token_throughput)),
        ("ttft_s", pct_obj(s.ttft_p50, s.ttft_p90, s.ttft_p99)),
        ("tpot_s", pct_obj(s.tpot_p50, s.tpot_p90, s.tpot_p99)),
        ("classes", Json::arr(row.classes.iter().map(class_to_json))),
        ("sim_allocs", Json::num(row.allocs as f64)),
        ("sim_events", Json::num(row.events as f64)),
        ("sim_events_saved", Json::num(row.events_saved as f64)),
        ("abandoned", Json::Bool(row.abandoned)),
        ("wall_s", Json::num(row.wall.as_secs_f64())),
    ];
    if let Some(t) = &row.autoscale {
        fields.push((
            "autoscale",
            Json::obj(vec![
                ("scale_ups", Json::num(t.scale_ups as f64)),
                ("scale_downs", Json::num(t.scale_downs as f64)),
                ("peak_active", Json::num(t.peak_active as f64)),
                ("final_active", Json::num(t.final_active as f64)),
                (
                    "final_macros",
                    Json::arr(t.final_macros.iter().map(|&m| Json::num(m as f64))),
                ),
            ]),
        ));
    }
    if let Some(c) = &row.churn {
        fields.push(("churn", churn_telemetry_to_json(c)));
    }
    if let Some(o) = &row.overload {
        fields.push(("overload", overload_telemetry_to_json(o)));
    }
    Json::obj(fields)
}

/// The closed-loop/defense block attached to rows of overload cells
/// (absent on open-loop runs — additive, like the churn block). The
/// `defense` sub-object is itself absent when the system ran undefended
/// or the ablation nulled its defense set.
pub fn overload_telemetry_to_json(o: &super::driver::OverloadTelemetry) -> Json {
    let c = &o.client;
    let mut fields = vec![(
        "client",
        Json::obj(vec![
            ("timeouts", Json::num(c.timeouts as f64)),
            ("rejected", Json::num(c.rejected as f64)),
            ("retries", Json::num(c.retries as f64)),
            ("gave_up", Json::num(c.gave_up as f64)),
            ("succeeded", Json::num(c.succeeded as f64)),
        ]),
    )];
    if let Some(d) = &o.defense {
        fields.push((
            "defense",
            Json::obj(vec![
                ("deadline_rejects", Json::num(d.deadline_rejects as f64)),
                ("priority_sheds", Json::num(d.priority_sheds as f64)),
                ("hopeless_sheds", Json::num(d.hopeless_sheds as f64)),
                ("queue_full_rejects", Json::num(d.queue_full_rejects as f64)),
                ("sheds", Json::num(d.sheds() as f64)),
                ("brownout_s", Json::num(d.brownout_s)),
                ("brownout_truncations", Json::num(d.brownout_truncations as f64)),
            ]),
        ));
    }
    Json::obj(fields)
}

/// The recovery-telemetry block attached to rows of faulted runs (absent
/// on fault-free runs — additive, like the autoscale block).
pub fn churn_telemetry_to_json(c: &crate::sim::ChurnTelemetry) -> Json {
    Json::obj(vec![
        ("faults", Json::num(c.faults as f64)),
        ("downs", Json::num(c.downs as f64)),
        ("preempt_notices", Json::num(c.notices as f64)),
        ("rerouted", Json::num(c.rerouted as f64)),
        ("lost", Json::num(c.lost as f64)),
        ("backfills", Json::num(c.backfills as f64)),
        ("recoveries", Json::num(c.recoveries as f64)),
        ("mean_recovery_s", Json::num(c.mean_recovery_s())),
    ])
}

/// The replay-provenance block both report schemas embed for scenarios
/// backed by a recorded log (absent on synthetic scenarios — additive).
pub fn replay_to_json(scenario: &crate::scenarios::Scenario) -> Option<(&'static str, Json)> {
    if let Some(trace) = scenario.replay() {
        let mut fields = vec![
            ("source", Json::str(trace.source())),
            ("requests", Json::num(trace.len() as f64)),
            ("native_rate_rps", Json::num(trace.native_rate())),
            ("recorded_duration_s", Json::num(trace.duration())),
            ("streamed", Json::Bool(false)),
        ];
        if let Some(lineage) = trace.lineage() {
            fields.push(("lineage", Json::str(lineage)));
        }
        return Some(("replay", Json::obj(fields)));
    }
    scenario.stream().map(|stream| {
        (
            "replay",
            Json::obj(vec![
                ("source", Json::str(stream.source())),
                ("requests", Json::num(stream.len() as f64)),
                ("native_rate_rps", Json::num(stream.native_rate())),
                ("recorded_duration_s", Json::num(stream.duration())),
                ("streamed", Json::Bool(true)),
                ("format", Json::str(stream.format().label())),
                ("lineage", Json::str(stream.lineage())),
            ]),
        )
    })
}

fn outcome_to_json(outcome: &ScenarioOutcome) -> Json {
    let mut fields = vec![
        ("name", Json::str(outcome.scenario.name)),
        ("summary", Json::str(outcome.scenario.summary)),
        ("offered_rate_rps", Json::num(outcome.rate)),
        ("duration_s", Json::num(outcome.duration)),
        ("warmup_s", Json::num(outcome.warmup)),
        (
            "best_system",
            match outcome.best() {
                Some(r) => Json::str(r.system.label()),
                None => Json::Null,
            },
        ),
        ("systems", Json::arr(outcome.rows.iter().map(row_to_json))),
    ];
    if let Some(block) = replay_to_json(&outcome.scenario) {
        fields.push(block);
    }
    Json::obj(fields)
}

/// The full suite report.
pub fn suite_to_json(outcomes: &[ScenarioOutcome], cfg: &ScenarioConfig) -> Json {
    Json::obj(vec![
        ("suite", Json::str("ecoserve-scenarios")),
        ("schema_version", Json::num(SCHEMA_VERSION)),
        ("seed", Json::num(cfg.seed as f64)),
        ("deployment", deployment_to_json(&cfg.deployment)),
        ("scenarios", Json::arr(outcomes.iter().map(outcome_to_json))),
    ])
}

/// One system's block in `BENCH_trace.json`: the derived diagnostics
/// from its flight-recorder capture. `None` when the row ran with the
/// recorder off (the caller skips such rows).
fn trace_row_to_json(row: &SystemRow) -> Option<Json> {
    let cap = row.trace.as_ref()?;
    let s = &cap.summary;
    Some(Json::obj(vec![
        ("system", Json::str(row.system.label())),
        ("events", Json::num(s.events as f64)),
        ("requests", Json::num(s.requests as f64)),
        ("max_prefill_gap_s", Json::num(s.max_prefill_gap_s)),
        ("p99_prefill_gap_s", Json::num(s.p99_prefill_gap_s)),
        ("unprefilled", Json::num(s.unprefilled as f64)),
        ("phase_overlap_frac", Json::num(s.phase_overlap_frac)),
        ("phase_windows", Json::num(s.phase_windows as f64)),
        (
            "miss_attribution",
            Json::arr(s.classes.iter().map(|c| {
                Json::obj(vec![
                    ("class", Json::str(c.class.as_str())),
                    ("arrived", Json::num(c.arrived as f64)),
                    ("misses", Json::num(c.misses as f64)),
                    ("shed", Json::num(c.shed as f64)),
                    ("fault_rerouted", Json::num(c.fault_rerouted as f64)),
                    ("brownout_truncated", Json::num(c.brownout_truncated as f64)),
                    ("queued_behind_prefill", Json::num(c.queued_behind_prefill as f64)),
                    ("slow_decode", Json::num(c.slow_decode as f64)),
                ])
            })),
        ),
    ]))
}

/// The flight-recorder report (`BENCH_trace.json`): derived diagnostics
/// per traced (scenario × system) cell. Rows that ran with the recorder
/// off are omitted, so the file only ever describes what was actually
/// recorded. Shares [`SCHEMA_VERSION`] with the other two artifacts.
pub fn trace_suite_to_json(outcomes: &[ScenarioOutcome], cfg: &ScenarioConfig) -> Json {
    Json::obj(vec![
        ("bench", Json::str("ecoserve-trace")),
        ("schema_version", Json::num(SCHEMA_VERSION)),
        ("seed", Json::num(cfg.seed as f64)),
        ("deployment", deployment_to_json(&cfg.deployment)),
        (
            "scenarios",
            Json::arr(outcomes.iter().map(|o| {
                Json::obj(vec![
                    ("scenario", Json::str(o.scenario.name)),
                    ("offered_rate_rps", Json::num(o.rate)),
                    ("duration_s", Json::num(o.duration)),
                    ("warmup_s", Json::num(o.warmup)),
                    ("systems", Json::arr(o.rows.iter().filter_map(trace_row_to_json))),
                ])
            })),
        ),
    ])
}

/// Human-readable table for one scenario outcome.
pub fn render_table(outcome: &ScenarioOutcome) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "--- scenario '{}' @ {:.2} req/s (window {:.0}..{:.0}s) ---\n",
        outcome.scenario.name, outcome.rate, outcome.warmup, outcome.duration
    ));
    out.push_str(&format!(
        "{:<10} {:>8} {:>9} {:>10} {:>11} {:>11} {:>11}\n",
        "system", "arrived", "attain %", "goodput/s", "p99TTFT s", "p99TPOT ms", "tok/s"
    ));
    for row in &outcome.rows {
        let s = &row.summary;
        out.push_str(&format!(
            "{:<10} {:>8} {:>9.1} {:>10.2} {:>11.2} {:>11.1} {:>11.0}\n",
            row.system.label(),
            row.arrived,
            row.attainment * 100.0,
            row.goodput_rps,
            s.ttft_p99,
            s.tpot_p99 * 1e3,
            s.token_throughput,
        ));
        if row.classes.len() > 1 {
            for c in &row.classes {
                out.push_str(&format!(
                    "  {:<12} class '{}': {}/{} met ({:.1}%)\n",
                    "", c.class, c.met, c.arrived, c.attainment * 100.0
                ));
            }
        }
    }
    if let Some(best) = outcome.best() {
        out.push_str(&format!("  best: {}\n", best.system.label()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemKind;
    use crate::metrics::Summary;
    use crate::scenarios::driver::{run_scenario, ClassScore};
    use crate::scenarios::registry::{by_name, LoadShape, Scenario, SweepBounds, TrafficClass};
    use crate::workload::Dataset;

    fn outcome() -> (ScenarioOutcome, ScenarioConfig) {
        let mut cfg = ScenarioConfig::default_l20();
        cfg.deployment.gpus_used = 16;
        cfg.duration_override = Some(45.0);
        cfg.rate = Some(2.0);
        let s = by_name("steady").unwrap();
        (
            run_scenario(&s, &cfg, &[SystemKind::EcoServe, SystemKind::Vllm]),
            cfg,
        )
    }

    #[test]
    fn json_roundtrips_and_has_the_contract_fields() {
        let (o, cfg) = outcome();
        let j = suite_to_json(&[o], &cfg);
        let text = j.to_string();
        let back = Json::parse(&text).expect("report must be valid JSON");
        assert_eq!(back.path(&["suite"]).unwrap().as_str(), Some("ecoserve-scenarios"));
        assert_eq!(
            back.path(&["deployment", "instances"]).unwrap().as_i64(),
            Some(4)
        );
        let scenarios = back.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(scenarios.len(), 1);
        let sc = &scenarios[0];
        assert_eq!(sc.get("name").unwrap().as_str(), Some("steady"));
        let systems = sc.get("systems").unwrap().as_arr().unwrap();
        assert_eq!(systems.len(), 2);
        for sys in systems {
            for key in [
                "system", "arrived", "attainment", "goodput_rps", "ttft_s",
                "tpot_s", "classes",
            ] {
                assert!(sys.get(key).is_some(), "missing {key}");
            }
            let a = sys.get("attainment").unwrap().as_f64().unwrap();
            assert!((0.0..=1.0).contains(&a));
            assert!(sys.path(&["ttft_s", "p99"]).unwrap().as_f64().is_some());
        }
        assert!(sc.get("best_system").unwrap().as_str().is_some());
    }

    #[test]
    fn table_renders_every_system() {
        let (o, _) = outcome();
        let table = render_table(&o);
        assert!(table.contains("EcoServe"));
        assert!(table.contains("vLLM"));
        assert!(table.contains("best:"));
    }

    /// Golden output: a fully synthetic outcome must serialize to exactly
    /// this string. Locks key names, key order (BTreeMap = alphabetical),
    /// number formatting, and the shared `schema_version` — any schema
    /// change, additive or not, must consciously update this fixture.
    #[test]
    fn suite_json_matches_golden_output() {
        let scenario = Scenario {
            name: "golden",
            summary: "synthetic fixture",
            classes: vec![TrafficClass {
                name: "chat",
                dataset: Dataset::sharegpt(),
                share: 1.0,
            }],
            shape: LoadShape::Steady,
            duration: 100.0,
            warmup: 10.0,
            default_rate: 2.0,
            sweep: SweepBounds::around(2.0),
            churn: None,
            overload: None,
        };
        let row = SystemRow {
            system: SystemKind::EcoServe,
            arrived: 100,
            completed: 98,
            met: 95,
            attainment: 0.95,
            goodput_rps: 1.25,
            summary: Summary {
                count: 98,
                ttft_p50: 0.5,
                ttft_p90: 1.5,
                ttft_p99: 2.5,
                tpot_p50: 0.05,
                tpot_p90: 0.075,
                tpot_p99: 0.125,
                attained_frac: 0.95,
                throughput_rps: 1.5,
                token_throughput: 250.0,
            },
            classes: vec![ClassScore {
                class: "chat",
                arrived: 100,
                met: 95,
                attainment: 0.95,
            }],
            events: 4242,
            events_saved: 0,
            abandoned: false,
            allocs: 77,
            wall: std::time::Duration::from_secs(2),
            autoscale: None,
            churn: None,
            overload: None,
            trace: None,
        };
        let outcome = ScenarioOutcome {
            scenario,
            rate: 2.0,
            duration: 100.0,
            warmup: 10.0,
            rows: vec![row],
        };
        let mut cfg = ScenarioConfig::default_l20();
        cfg.deployment.gpus_used = 16;
        cfg.seed = 7;
        cfg.rate = Some(2.0);
        let text = suite_to_json(&[outcome], &cfg).to_string();
        let golden = "{\"deployment\":{\"cluster\":\"L20-cluster\",\"gpus_used\":16,\
\"instances\":4,\"model\":\"CodeLlama2-34B\",\"pp\":1,\"tp\":4},\"scenarios\":\
[{\"best_system\":\"EcoServe\",\"duration_s\":100,\"name\":\"golden\",\
\"offered_rate_rps\":2,\"summary\":\"synthetic fixture\",\"systems\":\
[{\"abandoned\":false,\"arrived\":100,\"attainment\":0.95,\"classes\":\
[{\"arrived\":100,\"attainment\":0.95,\"class\":\"chat\",\"met_slo\":95}],\
\"completed\":98,\"goodput_rps\":1.25,\"met_slo\":95,\"sim_allocs\":77,\
\"sim_events\":4242,\"sim_events_saved\":0,\"system\":\"EcoServe\",\
\"token_throughput\":250,\
\"tpot_s\":{\"p50\":0.05,\"p90\":0.075,\"p99\":0.125},\
\"ttft_s\":{\"p50\":0.5,\"p90\":1.5,\"p99\":2.5},\"wall_s\":2}],\
\"warmup_s\":10}],\
\"schema_version\":2,\"seed\":7,\"suite\":\"ecoserve-scenarios\"}";
        assert_eq!(text, golden);
        // And it round-trips through the parser.
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn trace_report_carries_diagnostics_and_skips_untraced_rows() {
        let mut cfg = ScenarioConfig::default_l20();
        cfg.deployment.gpus_used = 16;
        cfg.duration_override = Some(45.0);
        cfg.rate = Some(2.0);
        cfg.trace = true;
        let s = by_name("steady").unwrap();
        let mut o = run_scenario(&s, &cfg, &[SystemKind::EcoServe, SystemKind::Vllm]);
        // Simulate a recorder-off row mixed into the same outcome.
        o.rows[1].trace = None;
        let j = trace_suite_to_json(&[o], &cfg);
        let back = Json::parse(&j.to_string()).expect("trace report must be valid JSON");
        assert_eq!(back.get("bench").unwrap().as_str(), Some("ecoserve-trace"));
        assert_eq!(back.get("schema_version").unwrap().as_f64(), Some(SCHEMA_VERSION));
        let sc = &back.get("scenarios").unwrap().as_arr().unwrap()[0];
        assert_eq!(sc.get("scenario").unwrap().as_str(), Some("steady"));
        let systems = sc.get("systems").unwrap().as_arr().unwrap();
        assert_eq!(systems.len(), 1, "untraced rows are omitted");
        let sys = &systems[0];
        assert_eq!(sys.get("system").unwrap().as_str(), Some("EcoServe"));
        assert!(sys.get("events").unwrap().as_i64().unwrap() > 0);
        assert!(sys.get("requests").unwrap().as_i64().unwrap() > 0);
        assert!(sys.get("max_prefill_gap_s").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(sys.get("phase_overlap_frac").unwrap().as_f64(), Some(0.0));
        let miss = sys.get("miss_attribution").unwrap().as_arr().unwrap();
        assert_eq!(miss.len(), 1);
        for key in [
            "class", "arrived", "misses", "shed", "fault_rerouted",
            "brownout_truncated", "queued_behind_prefill", "slow_decode",
        ] {
            assert!(miss[0].get(key).is_some(), "missing {key}");
        }
    }
}
