//! The overload evaluation: each overload scenario sweeps its load
//! points with closed-loop clients attached (timeouts, bounded retries,
//! jittered backoff), and every system runs each point twice — once
//! undefended (the system's pre-defense behaviour, so timed-out work is
//! still served and retries amplify the offered load) and once defended
//! (PaDG's full shed/brownout set; the baselines' native bounded queue).
//!
//! ```text
//! ecoserve scenarios --scenario retry-storm --overload-out BENCH_overload.json
//! ```
//!
//! The headline metric is the goodput-vs-offered-load curve past
//! saturation: an undefended system collapses (goodput *falls* as load
//! rises — servers burn capacity on attempts whose clients already gave
//! up), while a defended coordinator sheds early and plateaus. The JSON
//! artifact (`BENCH_overload.json`) embeds the full per-cell system rows
//! (the suite-report shape, including client and defense telemetry)
//! under the shared [`super::report::SCHEMA_VERSION`].

use std::time::Duration;

use super::driver::{run_system_variant, ScenarioConfig, SystemRow};
use super::registry::Scenario;
use super::report::{deployment_to_json, row_to_json, SCHEMA_VERSION};
use super::spec::RunSpec;
use crate::config::{DefenseConfig, SystemKind};
use crate::util::json::Json;
use crate::util::threads::parallel_map;

/// One (system × load point) pairing: the same closed-loop cell run
/// undefended and defended.
#[derive(Debug)]
pub struct OverloadCell {
    /// Offered-load multiplier (× the swept base rate).
    pub load_mult: f64,
    /// Offered rate actually driven, req/s.
    pub rate: f64,
    /// Client-on, defenses off — native pre-defense handling.
    pub undefended: SystemRow,
    /// Client-on, defenses armed (PaDG full set; baselines queue cap).
    pub defended: SystemRow,
}

/// One system's goodput curve across a scenario's load points.
#[derive(Debug)]
pub struct OverloadRow {
    pub system: SystemKind,
    /// One cell per load point, ascending with the profile's multipliers.
    pub cells: Vec<OverloadCell>,
}

impl OverloadRow {
    /// Undefended goodput at each load point (the collapse curve).
    pub fn undefended_goodputs(&self) -> Vec<f64> {
        self.cells.iter().map(|c| c.undefended.goodput_rps).collect()
    }

    /// Defended goodput at each load point (the plateau curve).
    pub fn defended_goodputs(&self) -> Vec<f64> {
        self.cells.iter().map(|c| c.defended.goodput_rps).collect()
    }

    /// Goodput at the heaviest load point relative to the first — below
    /// 1.0 means offering *more* load delivered *less* goodput.
    fn retained_at_peak(curve: &[f64]) -> f64 {
        match (curve.first(), curve.last()) {
            (Some(&first), Some(&last)) if first > 0.0 => last / first,
            _ => 1.0,
        }
    }

    pub fn undefended_retained_at_peak(&self) -> f64 {
        Self::retained_at_peak(&self.undefended_goodputs())
    }

    pub fn defended_retained_at_peak(&self) -> f64 {
        Self::retained_at_peak(&self.defended_goodputs())
    }

    /// Defended / undefended goodput at the heaviest load point — the
    /// value the defenses buy exactly where it matters.
    pub fn defended_gain_at_peak(&self) -> f64 {
        match self.cells.last() {
            Some(c) if c.undefended.goodput_rps > 0.0 => {
                c.defended.goodput_rps / c.undefended.goodput_rps
            }
            _ => 1.0,
        }
    }
}

/// All systems' curves on one overload scenario.
#[derive(Debug)]
pub struct OverloadOutcome {
    pub scenario: Scenario,
    /// Rate the multipliers scale (CLI `--rate` or the scenario default).
    pub base_rate: f64,
    pub load_points: Vec<f64>,
    pub rows: Vec<OverloadRow>,
}

impl OverloadOutcome {
    /// The row with the highest defended goodput at the heaviest point.
    pub fn best(&self) -> Option<&OverloadRow> {
        self.rows.iter().max_by(|a, b| {
            let g = |r: &OverloadRow| {
                r.cells.last().map(|c| c.defended.goodput_rps).unwrap_or(0.0)
            };
            g(a).partial_cmp(&g(b)).unwrap_or(std::cmp::Ordering::Equal)
        })
    }
}

/// Run the undefended-vs-defended pairing for every (overload scenario ×
/// system × load point) as one parallel job pool. Scenarios without an
/// overload profile are skipped (they define no load sweep or client).
pub fn run_overload_suite(
    scenarios: &[Scenario],
    cfg: &ScenarioConfig,
    systems: &[SystemKind],
    workers: usize,
) -> Vec<OverloadOutcome> {
    let list: Vec<&Scenario> = scenarios.iter().filter(|s| s.overload.is_some()).collect();

    // Every half-cell is an independent simulation; push the pairs
    // adjacently so `parallel_map`'s order-preservation hands them back
    // paired, mirroring the churn suite.
    let mut jobs: Vec<(usize, usize, usize, bool)> = Vec::new();
    for si in 0..list.len() {
        let profile = list[si].overload.expect("filtered on overload profiles");
        for ki in 0..systems.len() {
            for pi in 0..profile.load_points.len() {
                jobs.push((si, ki, pi, false));
                jobs.push((si, ki, pi, true));
            }
        }
    }
    let rows = parallel_map(jobs, workers.max(1), |(si, ki, pi, defended)| {
        let s = list[si];
        let profile = s.overload.expect("filtered on overload profiles");
        let base = cfg.rate.unwrap_or(s.default_rate);
        let mut cell_cfg = cfg.clone();
        cell_cfg.rate = Some(base * profile.load_points[pi]);
        let mut spec = RunSpec::new(systems[ki]).with_client(profile.client);
        if defended {
            spec = spec.with_defense(DefenseConfig::default());
        }
        run_system_variant(s, &cell_cfg, &spec)
    });

    let mut outcomes: Vec<OverloadOutcome> = list
        .iter()
        .map(|s| {
            let profile = s.overload.expect("filtered on overload profiles");
            OverloadOutcome {
                scenario: (*s).clone(),
                base_rate: cfg.rate.unwrap_or(s.default_rate),
                load_points: profile.load_points.to_vec(),
                rows: Vec::new(),
            }
        })
        .collect();
    let mut rows = rows.into_iter();
    for outcome in &mut outcomes {
        for &kind in systems {
            let mut cells = Vec::with_capacity(outcome.load_points.len());
            for &mult in &outcome.load_points {
                let undefended = rows.next().expect("one undefended half per point");
                let defended = rows.next().expect("one defended half per point");
                cells.push(OverloadCell {
                    load_mult: mult,
                    rate: outcome.base_rate * mult,
                    undefended,
                    defended,
                });
            }
            outcome.rows.push(OverloadRow { system: kind, cells });
        }
    }
    outcomes
}

fn row_json(r: &OverloadRow) -> Json {
    Json::obj(vec![
        ("system", Json::str(r.system.label())),
        (
            "undefended_goodput_rps",
            Json::arr(r.undefended_goodputs().into_iter().map(Json::num)),
        ),
        (
            "defended_goodput_rps",
            Json::arr(r.defended_goodputs().into_iter().map(Json::num)),
        ),
        ("undefended_retained_at_peak", Json::num(r.undefended_retained_at_peak())),
        ("defended_retained_at_peak", Json::num(r.defended_retained_at_peak())),
        ("defended_gain_at_peak", Json::num(r.defended_gain_at_peak())),
        (
            "cells",
            Json::arr(r.cells.iter().map(|c| {
                Json::obj(vec![
                    ("load_mult", Json::num(c.load_mult)),
                    ("offered_rate_rps", Json::num(c.rate)),
                    ("undefended", row_to_json(&c.undefended)),
                    ("defended", row_to_json(&c.defended)),
                ])
            })),
        ),
    ])
}

fn outcome_to_json(o: &OverloadOutcome) -> Json {
    Json::obj(vec![
        ("name", Json::str(o.scenario.name)),
        ("summary", Json::str(o.scenario.summary)),
        ("base_rate_rps", Json::num(o.base_rate)),
        ("load_points", Json::arr(o.load_points.iter().copied().map(Json::num))),
        (
            "best_system",
            match o.best() {
                Some(r) => Json::str(r.system.label()),
                None => Json::Null,
            },
        ),
        ("systems", Json::arr(o.rows.iter().map(row_json))),
    ])
}

/// The `BENCH_overload.json` artifact.
pub fn overload_to_json(
    outcomes: &[OverloadOutcome],
    cfg: &ScenarioConfig,
    wall: Duration,
) -> Json {
    Json::obj(vec![
        ("bench", Json::str("ecoserve-overload")),
        ("schema_version", Json::num(SCHEMA_VERSION)),
        ("seed", Json::num(cfg.seed as f64)),
        ("deployment", deployment_to_json(&cfg.deployment)),
        ("wall_s", Json::num(wall.as_secs_f64())),
        ("scenarios", Json::arr(outcomes.iter().map(outcome_to_json))),
    ])
}

/// Human-readable table for one overload outcome.
pub fn render_overload_table(o: &OverloadOutcome) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "--- overload '{}' base {:.2} req/s, load points {:?} ---\n",
        o.scenario.name, o.base_rate, o.load_points
    ));
    out.push_str(&format!(
        "{:<10} {:>5} {:>11} {:>11} {:>8} {:>8} {:>7} {:>7}\n",
        "system", "load", "undef g/s", "defend g/s", "timeouts", "retries", "sheds", "brown s"
    ));
    for r in &o.rows {
        for c in &r.cells {
            let ct = c.undefended.overload.map(|t| t.client).unwrap_or_default();
            let dt = c.defended.overload.and_then(|t| t.defense).unwrap_or_default();
            out.push_str(&format!(
                "{:<10} {:>4.2}x {:>11.2} {:>11.2} {:>8} {:>8} {:>7} {:>7.1}\n",
                r.system.label(),
                c.load_mult,
                c.undefended.goodput_rps,
                c.defended.goodput_rps,
                ct.timeouts,
                ct.retries,
                dt.sheds(),
                dt.brownout_s,
            ));
        }
    }
    if let Some(best) = o.best() {
        out.push_str(&format!("  best past saturation: {}\n", best.system.label()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::registry::by_name;

    fn quick_cfg() -> ScenarioConfig {
        let mut cfg = ScenarioConfig::default_l20();
        cfg.deployment.gpus_used = 16; // 4 instances — fast tests
        cfg.duration_override = Some(60.0);
        cfg.rate = Some(3.0); // near the 4-instance knee; points sweep past it
        cfg
    }

    #[test]
    fn suite_pairs_undefended_and_defended_cells_per_load_point() {
        let s = by_name("retry-storm").unwrap();
        let points = s.overload.unwrap().load_points.len();
        let systems = [SystemKind::EcoServe, SystemKind::Vllm];
        let outcomes = run_overload_suite(&[s], &quick_cfg(), &systems, 4);
        assert_eq!(outcomes.len(), 1);
        let o = &outcomes[0];
        assert_eq!(o.rows.len(), 2);
        for (row, kind) in o.rows.iter().zip(systems) {
            assert_eq!(row.system, kind);
            assert_eq!(row.cells.len(), points);
            for c in &row.cells {
                assert!((c.rate - o.base_rate * c.load_mult).abs() < 1e-12);
                let u = c.undefended.overload.expect("client half carries telemetry");
                assert!(u.defense.is_none(), "undefended half has no defense block");
                let d = c.defended.overload.expect("defended half carries telemetry");
                assert!(d.defense.is_some(), "defended half reports its defenses");
            }
            // Past saturation the closed loop must actually fire.
            let top = row.cells.last().unwrap();
            let ct = top.undefended.overload.unwrap().client;
            assert!(ct.timeouts > 0, "{:?}", ct);
            assert!(ct.retries > 0, "{:?}", ct);
        }
    }

    #[test]
    fn scenarios_without_profiles_are_skipped() {
        let scenarios = vec![by_name("steady").unwrap(), by_name("retry-storm").unwrap()];
        let outcomes =
            run_overload_suite(&scenarios, &quick_cfg(), &[SystemKind::EcoServe], 2);
        assert_eq!(outcomes.len(), 1);
        assert_eq!(outcomes[0].scenario.name, "retry-storm");
    }

    #[test]
    fn overload_json_has_the_contract_fields_and_roundtrips() {
        let s = by_name("retry-storm").unwrap();
        let cfg = quick_cfg();
        let outcomes = run_overload_suite(&[s], &cfg, &[SystemKind::EcoServe], 2);
        let j = overload_to_json(&outcomes, &cfg, Duration::from_secs(1));
        let text = j.to_string();
        let back = Json::parse(&text).expect("valid JSON");
        assert_eq!(back.get("bench").unwrap().as_str(), Some("ecoserve-overload"));
        for key in ["schema_version", "seed", "deployment", "wall_s", "scenarios"] {
            assert!(back.get(key).is_some(), "missing {key}");
        }
        let sc = &back.get("scenarios").unwrap().as_arr().unwrap()[0];
        assert_eq!(sc.get("name").unwrap().as_str(), Some("retry-storm"));
        assert!(sc.get("load_points").unwrap().as_arr().unwrap().len() >= 2);
        let sys = &sc.get("systems").unwrap().as_arr().unwrap()[0];
        for key in [
            "undefended_goodput_rps",
            "defended_goodput_rps",
            "undefended_retained_at_peak",
            "defended_retained_at_peak",
            "defended_gain_at_peak",
        ] {
            assert!(sys.get(key).is_some(), "missing {key}");
        }
        let cell = &sys.get("cells").unwrap().as_arr().unwrap()[0];
        assert!(cell.path(&["undefended", "overload", "client", "retries"]).is_some());
        assert!(
            cell.path(&["defended", "overload", "defense", "sheds"]).is_some(),
            "defended half must serialize its defense block"
        );
        assert!(
            cell.path(&["undefended", "overload", "defense"]).is_none(),
            "undefended half carries no defense block"
        );
        // The table renders the curve columns.
        let table = render_overload_table(&outcomes[0]);
        assert!(table.contains("undef g/s"));
        assert!(table.contains("EcoServe"));
    }
}
