//! The scenario suite — a declarative, multi-scenario evaluation driver.
//!
//! The paper evaluates on three datasets at fixed Poisson rates; real
//! fleets also face bursts, day curves, long-context heavy tails, and
//! mixed interactive/batch SLO populations (DistServe arXiv:2401.09670
//! and DynaServe arXiv:2504.09285 both show disaggregation trade-offs
//! inverting under exactly these shapes). This subsystem turns each such
//! shape into a named, deterministic scenario and runs every serving
//! system through all of them with one command:
//!
//! ```text
//! ecoserve scenarios --list
//! ecoserve scenarios --scenario bursty --out report.json
//! ecoserve scenarios --system vllm --rate 4 --duration 120
//! ecoserve scenarios --replay trace.jsonl     # recorded arrival log
//! ```
//!
//! * [`registry`] — the scenario catalog: traffic classes (dataset + SLO
//!   + rate share) × load shape (steady / on-off / diurnal / ramp /
//!   recorded-log replay) × horizon, built on
//!   [`crate::workload::TraceGenerator`], [`crate::workload::RampTrace`],
//!   and [`crate::workload::ReplayTrace`] ([`Scenario::from_log`] wraps a
//!   log; `ecoserve record` exports one).
//! * [`spec`] — the declarative [`RunSpec`] (system × variant × monitor
//!   × fault schedule) both this driver and [`crate::frontier`] consume.
//! * [`driver`] — runs (scenario × system) cells through
//!   [`crate::harness::build_system`] and the simulator in parallel
//!   ([`crate::util::threads::parallel_map`]), scoring strict per-class
//!   attainment and delivered goodput.
//! * [`churn`] — the clean-vs-faulted pairing behind `ecoserve scenarios
//!   --churn-out`: goodput retained under churn per system, with the
//!   recovery telemetry each system's fault handling accumulated.
//! * [`overload`] — the undefended-vs-defended load sweep behind
//!   `ecoserve scenarios --overload-out`: closed-loop clients push each
//!   system past saturation and the goodput-vs-offered-load curve shows
//!   retry-amplified collapse vs the defended plateau.
//! * [`report`] — the JSON contract (via [`crate::util::json`]) and the
//!   human table.

pub mod churn;
pub mod driver;
pub mod overload;
pub mod registry;
pub mod report;
pub mod spec;

pub use churn::{
    churn_to_json, render_churn_table, run_churn_suite, ChurnOutcome, ChurnRow,
};
pub use driver::{
    run_scenario, run_suite, run_system, run_system_variant, AutoscaleTelemetry,
    ClassScore, OverloadTelemetry, ScenarioConfig, ScenarioOutcome, SystemRow, VariantSpec,
};
pub use overload::{
    overload_to_json, render_overload_table, run_overload_suite, OverloadCell,
    OverloadOutcome, OverloadRow,
};
pub use registry::{
    by_name, registry, LoadShape, OverloadProfile, Scenario, SweepBounds, TrafficClass,
};
pub use report::{
    churn_telemetry_to_json, class_to_json, deployment_to_json, overload_telemetry_to_json,
    render_table, replay_to_json, row_to_json, suite_to_json, trace_suite_to_json,
    SCHEMA_VERSION,
};
pub use spec::RunSpec;
