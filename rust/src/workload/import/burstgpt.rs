//! BurstGPT-style CSV adapter (arXiv:2401.17644 release format).
//!
//! ```text
//! Timestamp,Model,Request tokens,Response tokens,Total tokens,Log Type
//! 9,ChatGPT,472,50,522,Conversation log
//! 10,GPT-4,317,7,324,API log
//! ```
//!
//! `Timestamp` is seconds from the capture start (integer-granularity in
//! the public release). `Log Type` is the class signal: conversation
//! traffic is interactive (ShareGPT SLOs), API traffic is
//! programmatic/short (Alpaca SLOs). `Total tokens` is validated as a
//! number but not cross-checked against the sum — public dumps disagree
//! by the EoS token.

use anyhow::{bail, Result};

use super::{tokens_field, RawRecord};

pub(crate) const HEADER: &str =
    "Timestamp,Model,Request tokens,Response tokens,Total tokens,Log Type";

pub(crate) fn check_header(line: &str, src: &str) -> Result<()> {
    if line.trim() != HEADER {
        bail!(
            "{src}:1: not a BurstGPT CSV — expected header '{HEADER}', got '{}'",
            line.trim()
        );
    }
    Ok(())
}

pub(crate) fn parse_row(line: &str, src: &str, n: usize) -> Result<RawRecord> {
    let fields: Vec<&str> = line.split(',').collect();
    if fields.len() != 6 {
        bail!(
            "{src}:{n}: expected 6 comma-separated fields (Timestamp,Model,Request \
             tokens,Response tokens,Total tokens,Log Type), got {}",
            fields.len()
        );
    }
    let ts = fields[0].trim();
    let t: f64 = ts
        .parse()
        .map_err(|_| anyhow::anyhow!("{src}:{n}: 'Timestamp' must be a number, got '{ts}'"))?;
    if !t.is_finite() || t < 0.0 {
        bail!("{src}:{n}: 'Timestamp' must be non-negative and finite, got {t}");
    }
    if fields[1].trim().is_empty() {
        bail!("{src}:{n}: empty 'Model' field");
    }
    let input_len = tokens_field(fields[2], "Request tokens", src, n)?;
    let output_len = tokens_field(fields[3], "Response tokens", src, n)?;
    let total = fields[4].trim();
    if total.parse::<u64>().is_err() {
        bail!("{src}:{n}: 'Total tokens' must be a non-negative integer, got '{total}'");
    }
    let class = match fields[5].trim() {
        "Conversation log" => 0,
        "API log" => 1,
        other => bail!(
            "{src}:{n}: unknown 'Log Type' '{other}' (expected 'Conversation log' or \
             'API log')"
        ),
    };
    Ok(RawRecord { t, input_len, output_len, class })
}
