//! Azure LLM-inference-style CSV adapter (the AzurePublicDataset trace
//! published with Splitwise, arXiv:2311.18677).
//!
//! ```text
//! TIMESTAMP,ContextTokens,GeneratedTokens
//! 2023-11-16 18:13:01.50,473,64
//! 127.25,1002,14
//! ```
//!
//! `TIMESTAMP` is either a datetime (`YYYY-MM-DD HH:MM:SS[.frac]`, as in
//! the published code trace) or plain float seconds (as in rebased
//! slices). The trace carries no class signal, so every request maps to
//! one "azure-llm" class scored against ShareGPT SLOs.

use anyhow::{bail, Result};

use super::{tokens_field, RawRecord};

pub(crate) const HEADER: &str = "TIMESTAMP,ContextTokens,GeneratedTokens";

pub(crate) fn check_header(line: &str, src: &str) -> Result<()> {
    if line.trim() != HEADER {
        bail!(
            "{src}:1: not an Azure LLM inference CSV — expected header '{HEADER}', \
             got '{}'",
            line.trim()
        );
    }
    Ok(())
}

pub(crate) fn parse_row(line: &str, src: &str, n: usize) -> Result<RawRecord> {
    let fields: Vec<&str> = line.split(',').collect();
    if fields.len() != 3 {
        bail!(
            "{src}:{n}: expected 3 comma-separated fields \
             (TIMESTAMP,ContextTokens,GeneratedTokens), got {}",
            fields.len()
        );
    }
    let t = parse_timestamp(fields[0].trim(), src, n)?;
    let input_len = tokens_field(fields[1], "ContextTokens", src, n)?;
    let output_len = tokens_field(fields[2], "GeneratedTokens", src, n)?;
    Ok(RawRecord { t, input_len, output_len, class: 0 })
}

/// Seconds (absolute; origin is arbitrary since the importer rebases to
/// the first arrival) from either timestamp form.
fn parse_timestamp(field: &str, src: &str, n: usize) -> Result<f64> {
    if let Ok(t) = field.parse::<f64>() {
        if !t.is_finite() || t < 0.0 {
            bail!("{src}:{n}: 'TIMESTAMP' must be non-negative and finite, got {t}");
        }
        return Ok(t);
    }
    let err = || {
        anyhow::anyhow!(
            "{src}:{n}: 'TIMESTAMP' must be seconds or 'YYYY-MM-DD HH:MM:SS[.frac]', \
             got '{field}'"
        )
    };
    let (date, time) = field.split_once(' ').ok_or_else(err)?;
    let mut dp = date.split('-');
    let year: i64 = dp.next().and_then(|s| s.parse().ok()).ok_or_else(err)?;
    let month: i64 = dp.next().and_then(|s| s.parse().ok()).ok_or_else(err)?;
    let day: i64 = dp.next().and_then(|s| s.parse().ok()).ok_or_else(err)?;
    if dp.next().is_some() || !(1..=12).contains(&month) || !(1..=31).contains(&day) {
        return Err(err());
    }
    let mut tp = time.split(':');
    let hour: i64 = tp.next().and_then(|s| s.parse().ok()).ok_or_else(err)?;
    let minute: i64 = tp.next().and_then(|s| s.parse().ok()).ok_or_else(err)?;
    let second: f64 = tp.next().and_then(|s| s.parse().ok()).ok_or_else(err)?;
    if tp.next().is_some()
        || !(0..24).contains(&hour)
        || !(0..60).contains(&minute)
        || !second.is_finite()
        || !(0.0..60.0).contains(&second)
    {
        return Err(err());
    }
    let days = days_from_civil(year, month, day);
    Ok(days as f64 * 86_400.0 + hour as f64 * 3600.0 + minute as f64 * 60.0 + second)
}

/// Days from 1970-01-01 for a proleptic-Gregorian civil date (Howard
/// Hinnant's `days_from_civil` algorithm) — enough calendar to subtract
/// two trace timestamps without a chrono dependency.
fn days_from_civil(y: i64, m: i64, d: i64) -> i64 {
    let y = if m <= 2 { y - 1 } else { y };
    let era = if y >= 0 { y } else { y - 399 } / 400;
    let yoe = y - era * 400; // [0, 399]
    let doy = (153 * (if m > 2 { m - 3 } else { m + 9 }) + 2) / 5 + d - 1; // [0, 365]
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy; // [0, 146096]
    era * 146_097 + doe - 719_468
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn civil_calendar_matches_known_epochs() {
        assert_eq!(days_from_civil(1970, 1, 1), 0);
        assert_eq!(days_from_civil(1970, 1, 2), 1);
        assert_eq!(days_from_civil(2000, 3, 1), 11017);
        // Leap-year boundary: 2024-02-29 exists, one day before 03-01.
        assert_eq!(days_from_civil(2024, 3, 1) - days_from_civil(2024, 2, 29), 1);
    }

    #[test]
    fn datetime_and_float_timestamps_agree_on_differences() {
        let a = parse_timestamp("2023-11-16 18:13:01.50", "t", 1).unwrap();
        let b = parse_timestamp("2023-11-16 18:14:03", "t", 1).unwrap();
        assert_eq!(b - a, 61.5);
        // Midnight rollover.
        let c = parse_timestamp("2023-11-16 23:59:59", "t", 1).unwrap();
        let d = parse_timestamp("2023-11-17 00:00:01", "t", 1).unwrap();
        assert_eq!(d - c, 2.0);
        assert_eq!(parse_timestamp("12.75", "t", 1).unwrap(), 12.75);
    }

    #[test]
    fn bad_timestamps_are_rejected() {
        for bad in ["2023-11-16", "2023-13-01 00:00:00", "2023-01-01 24:00:00",
                    "2023-01-01 00:61:00", "2023-01-01 00:00:60", "-5.0", "inf", "abc"] {
            assert!(parse_timestamp(bad, "t", 3).is_err(), "{bad} should fail");
        }
    }
}
